//! Micro-op benchmark: the paper's §2.3/§3.1 claim that one `Perm` costs
//! ~56 `Add`s and ~34 `Mult`s — the observation motivating CHEETAH, plus
//! a counted per-(op, variant) rotation ledger persisted to
//! `BENCH_micro.json` and gated exactly by `scripts/bench_trend.py
//! --micro` (a Perm-count regression fails even when wall-time noise
//! hides it).
//!
//! Run: `cargo bench --bench microops_bench [-- --big-ring]`

use cheetah::bench_util::{time_adaptive, BenchArgs, Table};
use cheetah::fixed::ScalePlan;
use cheetah::nn::Layer;
use cheetah::phe::{Context, Encryptor, Evaluator, GaloisKeys, Params};
use cheetah::protocol::{gala, gazelle};
use cheetah::util::rng::{ChaCha20Rng, SplitMix64};
use std::time::Duration;

fn main() {
    let args = BenchArgs::from_env();
    let params = if args.has("--big-ring") { Params::big_ring() } else { Params::default_params() };
    let ctx = std::sync::Arc::new(Context::new(params));
    let mut rng = ChaCha20Rng::from_u64_seed(1);
    let enc = Encryptor::new(ctx.clone(), &mut rng);
    let ev = Evaluator::new(ctx.clone());
    let gk = GaloisKeys::generate_default(&ctx, &enc.sk, &mut rng);

    let vals: Vec<i64> = (0..ctx.params.n as i64).map(|i| i % 251 - 125).collect();
    let mut ct_a = enc.encrypt_slots(&vals, &mut rng);
    let mut ct_b = enc.encrypt_slots(&vals, &mut rng);
    ev.to_ntt(&mut ct_a);
    ev.to_ntt(&mut ct_b);
    let mult_op = ctx.mult_operand(&vals);
    let add_op = ctx.add_operand(&vals);

    let budget = Duration::from_millis(400);
    let t_add = time_adaptive(budget, 20_000, || {
        let _ = std::hint::black_box(ev.add(&ct_a, &ct_b));
    });
    let t_add_plain = time_adaptive(budget, 20_000, || {
        let mut c = ct_a.clone();
        ev.add_plain(&mut c, &add_op);
        std::hint::black_box(c);
    });
    let t_mult = time_adaptive(budget, 20_000, || {
        let _ = std::hint::black_box(ev.mult_plain(&ct_a, &mult_op));
    });
    let t_perm = time_adaptive(budget, 2_000, || {
        let _ = std::hint::black_box(ev.rotate_rows(&ct_a, 1, &gk));
    });
    let t_dec = time_adaptive(budget, 5_000, || {
        let _ = std::hint::black_box(enc.decrypt(&ct_a));
    });
    let t_enc = time_adaptive(budget, 5_000, || {
        let mut r = ChaCha20Rng::from_u64_seed(7);
        let _ = std::hint::black_box(enc.encrypt_slots(&vals, &mut r));
    });

    let mut t = Table::new(&["op", "median", "samples", "x Add", "paper says"]);
    let base = t_add.median.as_secs_f64();
    let rows = [
        ("Add (ct+ct)", t_add, "1x"),
        ("AddPlain", t_add_plain, "-"),
        ("MultPlain", t_mult, "Perm ~ 34x Mult"),
        ("Perm (rotate+keyswitch)", t_perm, "Perm ~ 56x Add"),
        ("Decrypt", t_dec, "-"),
        ("Encrypt", t_enc, "-"),
    ];
    for (name, m, note) in rows {
        t.row(&[
            name.into(),
            cheetah::util::fmt_duration(m.median),
            m.samples.to_string(),
            format!("{:.1}x", m.median.as_secs_f64() / base),
            note.into(),
        ]);
    }
    t.print(&format!(
        "Micro-ops (paper §2.3 claim) — n={}, q≈2^{}",
        ctx.params.n,
        ctx.params.q_bits(),
    ));
    println!(
        "\nmeasured: Perm/Add = {:.1}, Perm/Mult = {:.1}  (paper: 56, 34)",
        t_perm.median.as_secs_f64() / t_add.median.as_secs_f64(),
        t_perm.median.as_secs_f64() / t_mult.median.as_secs_f64()
    );

    // ---- counted (op, variant) ledger → BENCH_micro.json ----
    // Real counted kernel runs on fixed shapes (not analytic formulas):
    // FC 16×128 on the shared hybrid packing, conv 2→3 channels 8×8 r=3.
    let plan = ScalePlan::default_plan();
    let mut srng = SplitMix64::new(11);
    let mut micro = Table::new(&["op", "variant", "perm", "mult", "add"]);

    let (n_o, n_i) = (16usize, 128usize);
    let mut fc_layer = Layer::fc(n_o);
    fc_layer.init_weights(1, 1, n_i, &mut srng);
    let fc_gk = gazelle::fc_galois_keys(&ctx, &enc.sk, n_i, &mut rng);
    let x_q: Vec<i64> = (0..n_i).map(|_| srng.gen_i64_range(-128, 128)).collect();
    let mut fc_ct = enc.encrypt_slots(
        &gazelle::pack_fc_input(&ctx, &x_q, gazelle::FcMethod::Hybrid),
        &mut rng,
    );
    ev.to_ntt(&mut fc_ct);
    ev.reset_counts();
    let _ = gazelle::fc(
        &ev,
        gazelle::FcMethod::Hybrid,
        &fc_ct,
        &fc_layer,
        n_i,
        &plan,
        1.0,
        &fc_gk,
    );
    let c = ev.counts();
    micro.row(&[
        "fc".into(),
        "hybrid".into(),
        c.perm.to_string(),
        c.mult.to_string(),
        c.add.to_string(),
    ]);
    ev.reset_counts();
    let _ = gala::fc(&ev, &fc_ct, &fc_layer, n_i, &plan, 1.0);
    let c = ev.counts();
    micro.row(&[
        "fc".into(),
        "gala".into(),
        c.perm.to_string(),
        c.mult.to_string(),
        c.add.to_string(),
    ]);

    let (c_i, c_o, h, w, r) = (2usize, 3usize, 8usize, 8usize, 3usize);
    let mut conv_layer = Layer::conv(c_o, r, 1, 1);
    conv_layer.init_weights(c_i, h, w, &mut srng);
    let input_q: Vec<i64> = (0..c_i * h * w).map(|_| srng.gen_i64_range(-128, 128)).collect();
    let conv_gk = gazelle::conv_galois_keys(&ctx, &enc.sk, r, w, &mut rng);
    let mut ch_cts: Vec<_> = (0..c_i)
        .map(|i| enc.encrypt_slots(&input_q[i * h * w..(i + 1) * h * w], &mut rng))
        .collect();
    for ct in ch_cts.iter_mut() {
        ev.to_ntt(ct);
    }
    for (variant, key) in [
        (gazelle::ConvVariant::InputRotation, "ir"),
        (gazelle::ConvVariant::OutputRotation, "or"),
    ] {
        ev.reset_counts();
        let _ = gazelle::conv(
            &ev,
            variant,
            &ch_cts,
            &conv_layer,
            (c_i, h, w),
            &plan,
            1.0,
            &conv_gk,
        );
        let c = ev.counts();
        micro.row(&[
            "conv".into(),
            key.into(),
            c.perm.to_string(),
            c.mult.to_string(),
            c.add.to_string(),
        ]);
    }
    let geom = gala::GalaConvGeometry::new(ctx.params.row_size(), (c_i, h, w), c_o, r);
    let gala_gk = gala::gala_conv_galois_keys(&ctx, &enc.sk, r, w, &mut rng);
    let residues: Vec<u64> = input_q
        .iter()
        .map(|&v| if v < 0 { ctx.params.p - (-v) as u64 } else { v as u64 })
        .collect();
    let mut gala_cts: Vec<_> = gala::pack_conv_input(&geom, &residues)
        .iter()
        .map(|slots| enc.encrypt(&ctx.encoder.encode_unsigned(slots), &mut rng))
        .collect();
    for ct in gala_cts.iter_mut() {
        ev.to_ntt(ct);
    }
    ev.reset_counts();
    let _ = gala::conv(&ev, &geom, &gala_cts, &conv_layer, &plan, 1.0, &gala_gk);
    let c = ev.counts();
    micro.row(&[
        "conv".into(),
        "gala".into(),
        c.perm.to_string(),
        c.mult.to_string(),
        c.add.to_string(),
    ]);

    micro.print("Counted op ledger by (op, variant) — gated by bench_trend.py --micro");
    micro
        .write_json("BENCH_micro.json", "micro op counts by (op, variant)")
        .expect("write BENCH_micro.json");
    println!("\nwrote BENCH_micro.json");
}
