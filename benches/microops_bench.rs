//! Micro-op benchmark: the paper's §2.3/§3.1 claim that one `Perm` costs
//! ~56 `Add`s and ~34 `Mult`s — the observation motivating CHEETAH.
//!
//! Run: `cargo bench --bench microops_bench [-- --big-ring]`

use cheetah::bench_util::{time_adaptive, BenchArgs, Table};
use cheetah::phe::{Context, Encryptor, Evaluator, GaloisKeys, Params};
use cheetah::util::rng::ChaCha20Rng;
use std::time::Duration;

fn main() {
    let args = BenchArgs::from_env();
    let params = if args.has("--big-ring") { Params::big_ring() } else { Params::default_params() };
    let ctx = std::sync::Arc::new(Context::new(params));
    let mut rng = ChaCha20Rng::from_u64_seed(1);
    let enc = Encryptor::new(ctx.clone(), &mut rng);
    let ev = Evaluator::new(ctx.clone());
    let gk = GaloisKeys::generate_default(&ctx, &enc.sk, &mut rng);

    let vals: Vec<i64> = (0..ctx.params.n as i64).map(|i| i % 251 - 125).collect();
    let mut ct_a = enc.encrypt_slots(&vals, &mut rng);
    let mut ct_b = enc.encrypt_slots(&vals, &mut rng);
    ev.to_ntt(&mut ct_a);
    ev.to_ntt(&mut ct_b);
    let mult_op = ctx.mult_operand(&vals);
    let add_op = ctx.add_operand(&vals);

    let budget = Duration::from_millis(400);
    let t_add = time_adaptive(budget, 20_000, || {
        let _ = std::hint::black_box(ev.add(&ct_a, &ct_b));
    });
    let t_add_plain = time_adaptive(budget, 20_000, || {
        let mut c = ct_a.clone();
        ev.add_plain(&mut c, &add_op);
        std::hint::black_box(c);
    });
    let t_mult = time_adaptive(budget, 20_000, || {
        let _ = std::hint::black_box(ev.mult_plain(&ct_a, &mult_op));
    });
    let t_perm = time_adaptive(budget, 2_000, || {
        let _ = std::hint::black_box(ev.rotate_rows(&ct_a, 1, &gk));
    });
    let t_dec = time_adaptive(budget, 5_000, || {
        let _ = std::hint::black_box(enc.decrypt(&ct_a));
    });
    let t_enc = time_adaptive(budget, 5_000, || {
        let mut r = ChaCha20Rng::from_u64_seed(7);
        let _ = std::hint::black_box(enc.encrypt_slots(&vals, &mut r));
    });

    let mut t = Table::new(&["op", "median", "samples", "x Add", "paper says"]);
    let base = t_add.median.as_secs_f64();
    let rows = [
        ("Add (ct+ct)", t_add, "1x"),
        ("AddPlain", t_add_plain, "-"),
        ("MultPlain", t_mult, "Perm ~ 34x Mult"),
        ("Perm (rotate+keyswitch)", t_perm, "Perm ~ 56x Add"),
        ("Decrypt", t_dec, "-"),
        ("Encrypt", t_enc, "-"),
    ];
    for (name, m, note) in rows {
        t.row(&[
            name.into(),
            cheetah::util::fmt_duration(m.median),
            m.samples.to_string(),
            format!("{:.1}x", m.median.as_secs_f64() / base),
            note.into(),
        ]);
    }
    t.print(&format!(
        "Micro-ops (paper §2.3 claim) — n={}, q≈2^{}",
        ctx.params.n,
        ctx.params.q_bits(),
    ));
    println!(
        "\nmeasured: Perm/Add = {:.1}, Perm/Mult = {:.1}  (paper: 56, 34)",
        t_perm.median.as_secs_f64() / t_add.median.as_secs_f64(),
        t_perm.median.as_secs_f64() / t_mult.median.as_secs_f64()
    );
}
