//! Tables 1 & 2 — the analytic scheme-lineage and op-count complexity
//! tables, cross-checked against the measured evaluator counters on a
//! concrete shape.
//!
//! Run: `cargo bench --bench complexity_tables`

use cheetah::complexity::{print_table1, print_table2, ConvShape, FcShape};
use cheetah::fixed::ScalePlan;
use cheetah::nn::{Layer, Network};
use cheetah::phe::{Context, Params};
use cheetah::protocol::cheetah::CheetahRunner;

fn main() {
    print_table1();

    let params = Params::default_params();
    let conv = ConvShape { c_i: 1, c_o: 5, r: 5, hw: 28 * 28, n: params.n as u64 };
    let fc = FcShape { n_i: 2048, n_o: 1, n: params.n as u64 };
    print_table2(conv, fc);

    // Cross-check: the analytic CH-MIMO counts equal the runner's measured
    // server counters on the same shape.
    let ctx = std::sync::Arc::new(Context::new(params));
    let plan = ScalePlan::default_plan();
    let mut net = Network {
        name: "xcheck".into(),
        input_shape: (1, 28, 28),
        layers: vec![Layer::conv(5, 5, 1, 2)],
    };
    net.init_weights(1);
    let mut runner = CheetahRunner::new(ctx, net, plan, 0.0, 2).expect("valid network");
    runner.run_offline();
    let input = cheetah::nn::SyntheticDigits::new(28, 3).render(1).image;
    let rep = runner.infer(&input);
    let measured = rep.steps[0].server_ops;
    let analytic = conv.cheetah();
    println!(
        "\ncross-check CH-MIMO 28x28@1 r=5 @5: analytic (perm={}, mult={}) vs measured (perm={}, mult={}) — {}",
        analytic.perm,
        analytic.mult,
        measured.perm,
        measured.mult,
        if analytic.perm == measured.perm && analytic.mult == measured.mult {
            "MATCH"
        } else {
            "MISMATCH"
        }
    );
}
