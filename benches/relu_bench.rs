//! ReLU benchmark — paper **Table 6** (GC-based GAZELLE vs CHEETAH's
//! obscure-HE nonlinearity at 1 000 / 10 000 outputs) and **Fig. 6**
//! (speedup + communication vs output dimension); `--vgg-relu` reproduces
//! the §5.1 claim (~263 s for a 3.2 M-element GC ReLU) by measurement +
//! linear extrapolation.
//!
//! Run: `cargo bench --bench relu_bench [-- --sweep] [-- --vgg-relu]`

use cheetah::bench_util::{BenchArgs, Table};
use cheetah::fixed::ScalePlan;
use cheetah::gc::GcRelu;
use cheetah::phe::{Context, Params};
use cheetah::util::fmt_bytes;
use cheetah::util::rng::{ChaCha20Rng, SplitMix64};

/// GC ReLU cost for `dim` elements: (online_ms, garble_ms, online_bytes,
/// offline_bytes). Large dims are measured on a subsample and scaled
/// linearly (GC cost is exactly per-element).
fn gc_cost(relu: &GcRelu, dim: usize, p: u64) -> (f64, f64, u64, u64) {
    let measure = dim.min(2000);
    let mut rng = ChaCha20Rng::from_u64_seed(11);
    let mut srng = SplitMix64::new(12);
    let sg: Vec<u64> = (0..measure).map(|_| srng.gen_range(p)).collect();
    let se: Vec<u64> = (0..measure).map(|_| srng.gen_range(p)).collect();
    let (_, _, rep) = relu.run_batch(&sg, &se, &mut rng);
    let scale = dim as f64 / measure as f64;
    (
        rep.eval_time.as_secs_f64() * 1e3 * scale,
        rep.garble_time.as_secs_f64() * 1e3 * scale,
        (rep.online_bytes as f64 * scale) as u64,
        (rep.offline_bytes as f64 * scale) as u64,
    )
}

/// CHEETAH nonlinear cost for `dim` outputs, measured exactly as the paper
/// defines it (§5.1): given the already-summed scrambled values `y`, the
/// client computes the polar-indicator recovery — 2 `MultPlain` + 1 `Add`
/// per output-indexed ciphertext under the server's key — plus the fresh
/// share subtraction; one-way communication of the recovery ciphertexts.
/// (Decrypt + block-sum is part of the *linear* benchmark, Table 3/4.)
fn cheetah_cost(ctx: &std::sync::Arc<Context>, dim: usize) -> (f64, u64) {
    use cheetah::bench_util::time_fn;
    use cheetah::phe::serial::ciphertext_bytes;
    use cheetah::phe::{Encryptor, Evaluator};
    use cheetah::protocol::cheetah::blinding::{client_y_pair, Blind};

    let plan = ScalePlan::default_plan();
    let mut rng = ChaCha20Rng::from_u64_seed(21);
    let mut srng = SplitMix64::new(22);
    let server_enc = Encryptor::new(ctx.clone(), &mut rng);
    let ev = Evaluator::new(ctx.clone());
    let n = ctx.params.n;
    let p = ctx.params.p;
    let n_cts = dim.div_ceil(n);

    // Offline: the server's indicator ciphertexts for `dim` outputs.
    let mut id1_cts = Vec::new();
    let mut id2_cts = Vec::new();
    let blinds: Vec<Blind> = (0..dim).map(|_| Blind::sample(&mut rng)).collect();
    for c in 0..n_cts {
        let lo = c * n;
        let hi = ((c + 1) * n).min(dim);
        let id1: Vec<i64> = blinds[lo..hi].iter().map(|b| b.indicator(&plan).0).collect();
        let id2: Vec<i64> = blinds[lo..hi].iter().map(|b| b.indicator(&plan).1).collect();
        let mut c1 = server_enc.encrypt_slots(&id1, &mut rng);
        let mut c2 = server_enc.encrypt_slots(&id2, &mut rng);
        ev.to_ntt(&mut c1);
        ev.to_ntt(&mut c2);
        id1_cts.push(c1);
        id2_cts.push(c2);
    }

    // The client's scrambled block sums (product scale).
    let sums: Vec<i64> =
        (0..dim).map(|_| srng.gen_i64_range(-(1 << 20), 1 << 20)).collect();

    let mut out_rng = ChaCha20Rng::from_u64_seed(23);
    let m = time_fn(1, 3, || {
        for c in 0..n_cts {
            let lo = c * n;
            let hi = ((c + 1) * n).min(dim);
            let mut y_req = vec![0i64; hi - lo];
            let mut relu_y = vec![0i64; hi - lo];
            for (i, &s) in sums[lo..hi].iter().enumerate() {
                let (a, b) = client_y_pair(s, &plan);
                y_req[i] = a;
                relu_y[i] = b;
            }
            let op_y = ctx.mult_operand(&y_req);
            let op_r = ctx.mult_operand(&relu_y);
            let mut rec = ev.mult_plain(&id1_cts[c], &op_y);
            let rec2 = ev.mult_plain(&id2_cts[c], &op_r);
            ev.add_assign(&mut rec, &rec2);
            let neg_s1: Vec<u64> = (0..hi - lo).map(|_| out_rng.gen_range(p)).collect();
            ev.add_plain(&mut rec, &ctx.add_operand_unsigned(&neg_s1));
            std::hint::black_box(rec);
        }
    });
    let bytes = (n_cts * ciphertext_bytes(&ctx.params, false)) as u64;
    (m.millis(), bytes)
}

fn main() {
    let args = BenchArgs::from_env();
    let ctx = std::sync::Arc::new(Context::new(Params::default_params()));
    let relu = GcRelu::new(ctx.params.p, ScalePlan::default_plan().k.frac_bits as usize);

    let mut t = Table::new(&[
        "output dim",
        "method",
        "online (ms)",
        "offline/garble (ms)",
        "online bytes",
        "speedup",
    ]);
    for dim in [1000usize, 10000] {
        let (gc_on, gc_off, gc_ob, _) = gc_cost(&relu, dim, ctx.params.p);
        let (ch_on, ch_b) = cheetah_cost(&ctx, dim);
        t.row(&[
            dim.to_string(),
            "GAZELLE (GC)".into(),
            format!("{gc_on:.1}"),
            format!("{gc_off:.1}"),
            fmt_bytes(gc_ob),
            String::new(),
        ]);
        t.row(&[
            dim.to_string(),
            "CHEETAH".into(),
            format!("{ch_on:.2}"),
            "0 (2 fresh ID cts)".into(),
            fmt_bytes(ch_b),
            format!("{:.0}x", gc_on / ch_on),
        ]);
    }
    t.print("Table 6 — ReLU online cost (paper: 267x @1k, 1793x @10k)");

    if args.has("--sweep") {
        let mut t = Table::new(&["dim", "GC online (ms)", "CH online (ms)", "speedup", "GC bytes", "CH bytes"]);
        for dim in [100usize, 1000, 10_000, 100_000] {
            let (gc_on, _, gc_ob, _) = gc_cost(&relu, dim, ctx.params.p);
            let (ch_on, ch_b) = cheetah_cost(&ctx, dim.min(20_000));
            t.row(&[
                dim.to_string(),
                format!("{gc_on:.1}"),
                format!("{ch_on:.2}"),
                format!("{:.0}x", gc_on / ch_on),
                fmt_bytes(gc_ob),
                fmt_bytes(ch_b),
            ]);
        }
        t.print("Fig. 6 — ReLU speedup & comm vs output dimension");
    }

    if args.has("--vgg-relu") {
        // §5.1: "GC takes about 263 seconds to compute a ReLU with 3.2M
        // inputs" — measure 2k, extrapolate linearly (exact for GC).
        let dim = 3_200_000usize;
        let (gc_on, gc_off, gc_ob, gc_fb) = gc_cost(&relu, dim, ctx.params.p);
        println!(
            "\n§5.1 VGG ReLU (3.2M elements): GC online {:.1} s (+ garble {:.1} s offline), \
             online {} offline {}   [paper: ~263 s]",
            gc_on / 1e3,
            gc_off / 1e3,
            fmt_bytes(gc_ob),
            fmt_bytes(gc_fb)
        );
    }
}
