//! Ablations over the design choices DESIGN.md calls out:
//!
//! 1. **Ring size** (n = 4096 vs 8192): throughput per slot of the core
//!    CHEETAH ops — bigger rings amortize better but cost more per op.
//! 2. **Blinding overhead**: obscure linear with full blinding (v, b)
//!    vs plain MultPlain-only — what privacy costs on the linear path.
//! 3. **GC ReLU bit-width**: AND gates and online time vs plaintext-modulus
//!    width — why the paper's 20-bit p matters for the GC baseline too.
//!
//! Run: `cargo bench --bench ablation_bench`

use cheetah::bench_util::{time_adaptive, Table};
use cheetah::gc::GcRelu;
use cheetah::phe::{Context, Encryptor, Evaluator, Params};
use cheetah::util::rng::{ChaCha20Rng, SplitMix64};
use std::time::Duration;

fn main() {
    // ---- 1. ring size ----
    let mut t = Table::new(&["n", "MultPlain", "per-slot (ns)", "AddPlain", "Encrypt", "Decrypt"]);
    for params in [Params::default_params(), Params::big_ring()] {
        let ctx = std::sync::Arc::new(Context::new(params));
        let mut rng = ChaCha20Rng::from_u64_seed(1);
        let enc = Encryptor::new(ctx.clone(), &mut rng);
        let ev = Evaluator::new(ctx.clone());
        let vals: Vec<i64> = (0..ctx.params.n as i64).map(|i| i % 101 - 50).collect();
        let mut ct = enc.encrypt_slots(&vals, &mut rng);
        ev.to_ntt(&mut ct);
        let mop = ctx.mult_operand(&vals);
        let aop = ctx.add_operand(&vals);
        let budget = Duration::from_millis(300);
        let m = time_adaptive(budget, 5000, || {
            let _ = std::hint::black_box(ev.mult_plain(&ct, &mop));
        });
        let a = time_adaptive(budget, 5000, || {
            let mut c = ct.clone();
            ev.add_plain(&mut c, &aop);
            std::hint::black_box(c);
        });
        let e = time_adaptive(budget, 2000, || {
            let mut r = ChaCha20Rng::from_u64_seed(2);
            let _ = std::hint::black_box(enc.encrypt_slots(&vals, &mut r));
        });
        let d = time_adaptive(budget, 2000, || {
            let _ = std::hint::black_box(enc.decrypt(&ct));
        });
        t.row(&[
            ctx.params.n.to_string(),
            cheetah::util::fmt_duration(m.median),
            format!("{:.1}", m.median.as_nanos() as f64 / ctx.params.n as f64),
            cheetah::util::fmt_duration(a.median),
            cheetah::util::fmt_duration(e.median),
            cheetah::util::fmt_duration(d.median),
        ]);
    }
    t.print("Ablation 1 — ring size (per-slot cost is what e2e scales with)");

    // ---- 2. blinding overhead ----
    {
        let ctx = std::sync::Arc::new(Context::new(Params::default_params()));
        let mut rng = ChaCha20Rng::from_u64_seed(3);
        let mut srng = SplitMix64::new(4);
        let enc = Encryptor::new(ctx.clone(), &mut rng);
        let ev = Evaluator::new(ctx.clone());
        let n = ctx.params.n;
        let x: Vec<i64> = (0..n as i64).map(|_| srng.gen_i64_range(-256, 256)).collect();
        let k: Vec<i64> = (0..n as i64).map(|_| srng.gen_i64_range(-128, 128)).collect();
        let kv: Vec<i64> = k.iter().map(|&v| v * 16).collect(); // v=1.0 at 2^4
        let b: Vec<i64> = (0..n as i64).map(|_| srng.gen_i64_range(-(1 << 17), 1 << 17)).collect();
        let mut ct = enc.encrypt_slots(&x, &mut rng);
        ev.to_ntt(&mut ct);
        let op_plain = ctx.mult_operand(&k);
        let op_kv = ctx.mult_operand(&kv);
        let op_b = ctx.add_operand(&b);
        let budget = Duration::from_millis(300);
        let plain = time_adaptive(budget, 5000, || {
            let _ = std::hint::black_box(ev.mult_plain(&ct, &op_plain));
        });
        let blinded = time_adaptive(budget, 5000, || {
            let mut c = ev.mult_plain(&ct, &op_kv);
            ev.add_plain(&mut c, &op_b);
            std::hint::black_box(c);
        });
        let mut t = Table::new(&["variant", "time", "overhead"]);
        t.row(&["MultPlain only (no privacy)".into(), cheetah::util::fmt_duration(plain.median), "1.00x".into()]);
        t.row(&[
            "blinded (k∘v) + noise b (CHEETAH)".into(),
            cheetah::util::fmt_duration(blinded.median),
            format!("{:.2}x", blinded.median.as_secs_f64() / plain.median.as_secs_f64()),
        ]);
        t.print("Ablation 2 — cost of the obscuring blinding on the linear path");
    }

    // ---- 3. GC bit-width ----
    {
        let mut t = Table::new(&["plaintext bits", "AND gates/ReLU", "online µs/ReLU", "offline B/ReLU"]);
        for bits in [16u32, 20, 23] {
            let p = cheetah::util::math::find_ntt_prime_below(1 << bits, 2 * 4096);
            let relu = GcRelu::new(p, 0);
            let mut rng = ChaCha20Rng::from_u64_seed(5);
            let mut srng = SplitMix64::new(6);
            let nvals = 200;
            let sg: Vec<u64> = (0..nvals).map(|_| srng.gen_range(p)).collect();
            let se: Vec<u64> = (0..nvals).map(|_| srng.gen_range(p)).collect();
            let (_, _, rep) = relu.run_batch(&sg, &se, &mut rng);
            t.row(&[
                bits.to_string(),
                relu.and_gates_per_relu().to_string(),
                format!("{:.1}", rep.eval_time.as_secs_f64() * 1e6 / nvals as f64),
                relu.offline_bytes_per_relu().to_string(),
            ]);
        }
        t.print("Ablation 3 — GC ReLU cost vs plaintext-modulus width (linear in ℓ)");
    }
}
