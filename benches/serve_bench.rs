//! Secure-serving benchmark: CHEETAH-over-TCP throughput and latency as a
//! function of concurrent session count and offline blinding-pool depth.
//!
//! Each cell starts a fresh `SecureServer` on loopback, connects N
//! concurrent `Backend::CheetahNet` engines (each session's `prepare()`
//! pays handshake + offline indicator transfer — or just the transfer when
//! the pool is warm), runs Q private inferences per session, and reports:
//!
//! * session-setup latency (pool off vs pool on — the offline/online split),
//! * per-query online latency (server-side p50 over completed queries),
//! * end-to-end secure throughput in queries/second,
//! * pool effectiveness (engines prebuilt vs built inline).
//!
//! Run: `cargo bench --bench serve_bench [-- --sessions 4] [-- --queries 2]
//!       [-- --depth 4] [-- --net netA] [-- --threads 4] [-- --batch 8]
//!       [-- --stats]`
//! `--stats` binds a live [`cheetah::obs::StatsServer`] endpoint and
//! scrapes it mid-run (server and pool still up), recording blinding-pool
//! occupancy and the server-side `serve.query` p99 into the `pool_occ` /
//! `query_p99_ms` columns of `BENCH_serve.json`; without the flag the
//! columns stay empty. `scripts/bench_trend.py` ignores unknown columns.
//! `--batch N` makes each session submit its queries as **one**
//! `infer_batch` call (pipelined over the session's ordered socket) instead
//! of N separate `infer` calls, so the batch path over real TCP shows up in
//! `BENCH_serve.json` (batch=0 rows are the per-query path).
//! Default is a small conv+fc model so the sweep finishes quickly; `--net
//! netA` runs the paper's Network A (28×28) at realistic cost. Results are
//! also persisted to `BENCH_serve.json` (wall time, bytes, threads) so the
//! serving-perf trajectory is recorded across PRs; CI uploads it.

use cheetah::bench_util::{BenchArgs, Table};
use cheetah::engine::{Backend, EngineBuilder, InferenceEngine};
use cheetah::fixed::ScalePlan;
use cheetah::nn::{Layer, Network, NetworkArch, SyntheticDigits, Tensor};
use cheetah::phe::{Context, Params};
use cheetah::serve::{PoolConfig, SecureConfig, SecureServer};
use cheetah::util::rng::SplitMix64;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn bench_net(name: &str) -> Network {
    match name {
        "netA" => Network::build(NetworkArch::NetA, 17),
        _ => {
            let mut net = Network {
                name: "small-serve".into(),
                input_shape: (1, 8, 8),
                layers: vec![Layer::conv(2, 3, 1, 1), Layer::relu(), Layer::fc(4)],
            };
            net.init_weights(17);
            net
        }
    }
}

fn input_for(net: &Network, seed: u64) -> Tensor {
    let (c, h, w) = net.input_shape;
    if c == 1 && h >= 12 {
        SyntheticDigits::new(h, seed).render(3).image
    } else {
        let mut rng = SplitMix64::new(seed);
        Tensor::from_vec((0..c * h * w).map(|_| rng.gen_f64_range(-1.0, 1.0)).collect(), c, h, w)
    }
}

fn p50(durations: &mut [Duration]) -> Duration {
    if durations.is_empty() {
        return Duration::ZERO;
    }
    durations.sort();
    durations[durations.len() / 2]
}

fn main() {
    let args = BenchArgs::from_env();
    let max_sessions = args.get_usize("--sessions", 4);
    let queries = args.get_usize("--queries", 2);
    let batch = args.get_usize("--batch", 0);
    let depth = args.get_usize("--depth", max_sessions);
    let net_name = args.get("--net").unwrap_or("small").to_string();
    let threads = args.get_usize("--threads", cheetah::par::threads()).max(1);
    cheetah::par::set_threads(threads);
    let stats = args.has("--stats");
    // The endpoint serves the process-global obs snapshot; the secure
    // server under test runs in this process, so scraping it over HTTP
    // exercises the exact surface an operator curls in production.
    let stats_srv = if stats {
        let srv = cheetah::obs::StatsServer::serve("127.0.0.1:0").expect("bind stats endpoint");
        println!("telemetry endpoint on http://{}/ (scraped per cell)", srv.addr);
        Some(srv)
    } else {
        None
    };

    let ctx = Arc::new(Context::new(Params::default_params()));
    let plan = ScalePlan::default_plan();
    let net = bench_net(&net_name);
    println!(
        "secure serving of {} — sessions up to {max_sessions}, {queries} queries/session, \
         {threads} compute threads",
        net.name
    );

    let mut t = Table::new(&[
        "sessions",
        "pool",
        "setup p50",
        "query p50 (server)",
        "wall",
        "req/s",
        "online bytes",
        "pool built/hits/inline",
    ]);
    // Machine-readable companion (BENCH_serve.json).
    let mut jt = Table::new(&[
        "sessions",
        "pool_depth",
        "threads",
        "batch",
        "setup_p50_ms",
        "query_p50_ms",
        "wall_s",
        "req_per_s",
        "online_bytes",
        "pool_produced",
        "pool_hits",
        "pool_inline",
        "pool_occ",
        "query_p99_ms",
    ]);

    let session_counts: Vec<usize> =
        [1usize, 2, 4, 8].into_iter().filter(|&s| s <= max_sessions).collect();
    for pool_on in [false, true] {
        for &sessions in &session_counts {
            // Scope the global obs registry to this cell so the scraped
            // occupancy gauge and query histogram describe one server.
            if stats {
                cheetah::obs::reset();
            }
            let pool = if pool_on {
                PoolConfig { depth, workers: 1 }
            } else {
                PoolConfig::disabled()
            };
            let cfg = SecureConfig {
                epsilon: 0.0,
                workers: sessions.min(4),
                pool,
                threads,
                ..Default::default()
            };
            let server = SecureServer::serve(ctx.clone(), net.clone(), plan, "127.0.0.1:0", cfg)
                .expect("bind secure server");
            if pool_on {
                // Warm the bank so the measurement sees the offline/online
                // split rather than a cold-start artifact.
                server.wait_pool_ready(sessions.min(depth) as u64, Duration::from_secs(60));
            }
            let addr = server.addr;
            let input = input_for(&net, 23);

            let t0 = Instant::now();
            let mut handles = Vec::new();
            for s in 0..sessions {
                let input = input.clone();
                let ctx = ctx.clone();
                handles.push(std::thread::spawn(move || {
                    // Each session is a `CheetahNet` engine pointed at the
                    // shared server; `prepare()` is the measured setup
                    // (handshake + offline indicator transfer).
                    let mut engine = EngineBuilder::new(Backend::CheetahNet)
                        .context(ctx)
                        .plan(plan)
                        .seed(9000 + s as u64)
                        .connect_to(addr)
                        .build()
                        .expect("secure engine");
                    let t_setup = Instant::now();
                    engine.prepare().expect("secure session setup");
                    let setup = t_setup.elapsed();
                    let mut bytes = 0u64;
                    if batch > 0 {
                        // One infer_batch call per session: the batch path
                        // over a real socket (queries pipeline in order on
                        // the session; per-query compute still fans out).
                        let inputs = vec![input.clone(); batch];
                        for rep in engine.infer_batch(&inputs).expect("secure batch") {
                            let traffic =
                                rep.traffic.expect("networked engine meters traffic");
                            bytes += traffic.c2s + traffic.s2c;
                        }
                    } else {
                        for _ in 0..queries {
                            let rep = engine.infer(&input).expect("secure inference");
                            let traffic =
                                rep.traffic.expect("networked engine meters traffic");
                            bytes += traffic.c2s + traffic.s2c;
                        }
                    }
                    (setup, bytes)
                }));
            }
            let (mut setups, online_bytes): (Vec<Duration>, u64) = handles
                .into_iter()
                .map(|h| h.join().expect("client thread"))
                .fold((Vec::new(), 0), |(mut v, b), (s, bytes)| {
                    v.push(s);
                    (v, b + bytes)
                });
            let wall = t0.elapsed();

            let total = sessions * if batch > 0 { batch } else { queries };
            let m = server.metrics.summary();
            assert_eq!(m.requests as usize, total, "metered queries mismatch");
            let ps = server.pool_stats();
            // Scrape the endpoint while the server and its pool are still
            // up: the occupancy gauge shows engines banked right now and
            // `serve.query` holds this cell's server-side latencies (ns).
            // Empty cells when --stats is off or obs is compiled out.
            let (pool_occ, query_p99_ms) = match &stats_srv {
                Some(srv) => {
                    let body =
                        cheetah::obs::stats::scrape(&srv.addr).expect("scrape stats endpoint");
                    let snap = cheetah::obs::Snapshot::from_json(&body)
                        .expect("stats endpoint must serve a schema-valid snapshot");
                    let occ = snap
                        .get("serve.pool.occupancy")
                        .map(|m| m.value.to_string())
                        .unwrap_or_default();
                    let p99 = snap
                        .get("serve.query")
                        .and_then(|m| m.hist.as_ref().map(|h| h.percentile(99.0)))
                        .map(|ns| format!("{:.3}", ns as f64 / 1e6))
                        .unwrap_or_default();
                    (occ, p99)
                }
                None => (String::new(), String::new()),
            };
            let setup_p50 = p50(&mut setups);
            t.row(&[
                sessions.to_string(),
                if pool_on { format!("on (d={depth})") } else { "off".into() },
                cheetah::util::fmt_duration(setup_p50),
                cheetah::util::fmt_duration(m.p50),
                format!("{:.2}s", wall.as_secs_f64()),
                format!("{:.2}", total as f64 / wall.as_secs_f64()),
                cheetah::util::fmt_bytes(online_bytes),
                format!("{}/{}/{}", ps.produced, ps.pool_hits, ps.inline_builds),
            ]);
            jt.row(&[
                sessions.to_string(),
                if pool_on { depth.to_string() } else { "0".into() },
                threads.to_string(),
                batch.to_string(),
                format!("{:.3}", setup_p50.as_secs_f64() * 1e3),
                format!("{:.3}", m.p50.as_secs_f64() * 1e3),
                format!("{:.3}", wall.as_secs_f64()),
                format!("{:.3}", total as f64 / wall.as_secs_f64()),
                online_bytes.to_string(),
                ps.produced.to_string(),
                ps.pool_hits.to_string(),
                ps.inline_builds.to_string(),
                pool_occ,
                query_p99_ms,
            ]);
            server.shutdown();
        }
    }

    t.print(&format!(
        "secure serving ({}) — session setup amortized by the blinding pool; \
         online latency unchanged",
        net.name
    ));
    jt.write_json(
        "BENCH_serve.json",
        "secure serving: wall/bytes per (sessions, pool, threads, batch)",
    )
    .expect("write BENCH_serve.json");
    println!("\nwrote BENCH_serve.json");
}
