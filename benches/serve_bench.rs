//! Secure-serving benchmark: CHEETAH-over-TCP throughput and latency as a
//! function of concurrent session count, serving front, offline
//! blinding-pool depth, and client-side session pooling.
//!
//! Each cell starts a fresh `SecureServer` on loopback, connects N
//! concurrent `Backend::CheetahNet` engines (each session's `prepare()`
//! pays handshake + offline indicator transfer — or just the transfer when
//! the pool is warm), runs Q private inferences per session, and reports:
//!
//! * session-setup latency (pool off vs pool on — the offline/online split),
//! * per-query online latency (server-side p50 over completed queries),
//! * end-to-end secure throughput in queries/second,
//! * pool effectiveness (engines prebuilt vs built inline).
//!
//! Run: `cargo bench --bench serve_bench [-- --sessions 4] [-- --queries 2]
//!       [-- --depth 4] [-- --net netA] [-- --threads 4] [-- --batch 8]
//!       [-- --mode threads|reactor|both] [-- --net-sessions 4]
//!       [-- --client-batch 8] [-- --stats] [-- --fault 11]
//!       [-- --deadline-ms 30000]`
//!
//! `--fault <seed>` runs the primary sweep under deterministic fault
//! injection on both sides of every socket (a fixed moderate
//! [`cheetah::serve::FaultSpec`] derived from the seed): queries may then
//! end in typed errors, and the `retries` / `evictions` / `error_rate`
//! columns of `BENCH_serve.json` record how the robustness layer coped
//! (they read 0/empty in fault-free runs, and the trend keys are
//! unchanged). `--deadline-ms` sets the client per-round deadline.
//!
//! `--mode` selects the serving front (the `mode` column): the default
//! thread-per-connection front, the readiness `reactor`
//! ([`cheetah::serve::reactor`]), or `both`. Session counts above 8
//! (`--sessions 1000` is the ROADMAP's C10K measuring stick) run in
//! reactor mode only — they hold every session open concurrently on the
//! server's bounded reactor+worker threads, with client drivers fanning
//! the queries — and record the server-side
//! `serve.reactor.sessions_peak` / `.wakeups` / `.write_queue_depth`
//! gauges into the `reactor_sessions` / `reactor_wakeups` / `reactor_wq`
//! columns when `--stats` is on.
//!
//! `--net-sessions K` adds the pooled-client experiment: one
//! `Backend::CheetahNet` engine with `EngineBuilder::net_sessions(k)` for
//! k ∈ {1, K} submits one `infer_batch` of `--client-batch` queries, so
//! BENCH_serve.json records whole-query TCP parallelism (the
//! `net_sessions` column; wall-clock at k=4 below k=1 is the win).
//!
//! `--stats` binds a live [`cheetah::obs::StatsServer`] endpoint and
//! scrapes it mid-run (server and pool still up), recording blinding-pool
//! occupancy and the server-side `serve.query` p99 into the `pool_occ` /
//! `query_p99_ms` columns of `BENCH_serve.json`; without the flag the
//! columns stay empty. `scripts/bench_trend.py` ignores unknown columns.
//! `--batch N` makes each session submit its queries as **one**
//! `infer_batch` call (pipelined over the session's ordered socket) instead
//! of N separate `infer` calls, so the batch path over real TCP shows up in
//! `BENCH_serve.json` (batch=0 rows are the per-query path).
//! Default is a small conv+fc model so the sweep finishes quickly; `--net
//! netA` runs the paper's Network A (28×28) at realistic cost. Results are
//! also persisted to `BENCH_serve.json` (wall time, bytes, threads) so the
//! serving-perf trajectory is recorded across PRs; CI uploads it.

use cheetah::bench_util::{BenchArgs, Table};
use cheetah::engine::{Backend, EngineBuilder, InferenceEngine};
use cheetah::fixed::ScalePlan;
use cheetah::nn::{Layer, Network, NetworkArch, SyntheticDigits, Tensor};
use cheetah::phe::{Context, Params};
use cheetah::serve::{FaultSpec, PoolConfig, SecureConfig, SecureServer};
use cheetah::util::rng::SplitMix64;
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

fn bench_net(name: &str) -> Network {
    match name {
        "netA" => Network::build(NetworkArch::NetA, 17),
        _ => {
            let mut net = Network {
                name: "small-serve".into(),
                input_shape: (1, 8, 8),
                layers: vec![Layer::conv(2, 3, 1, 1), Layer::relu(), Layer::fc(4)],
            };
            net.init_weights(17);
            net
        }
    }
}

fn input_for(net: &Network, seed: u64) -> Tensor {
    let (c, h, w) = net.input_shape;
    if c == 1 && h >= 12 {
        SyntheticDigits::new(h, seed).render(3).image
    } else {
        let mut rng = SplitMix64::new(seed);
        Tensor::from_vec((0..c * h * w).map(|_| rng.gen_f64_range(-1.0, 1.0)).collect(), c, h, w)
    }
}

fn p50(durations: &mut [Duration]) -> Duration {
    if durations.is_empty() {
        return Duration::ZERO;
    }
    durations.sort();
    durations[durations.len() / 2]
}

fn mode_name(reactor: bool) -> &'static str {
    if reactor { "reactor" } else { "threads" }
}

/// Current value of an obs counter (0 when absent or compiled out).
fn counter(name: &str) -> i64 {
    cheetah::obs::snapshot().get(name).map(|m| m.value).unwrap_or(0)
}

/// Idle + slow reactor evictions, summed.
fn evictions_now() -> i64 {
    counter("serve.reactor.idle_evictions") + counter("serve.reactor.slow_evictions")
}

/// Values scraped from the live stats endpoint while a cell's server is
/// still up; empty strings when `--stats` is off or obs is compiled out.
#[derive(Default)]
struct Scraped {
    pool_occ: String,
    query_p99_ms: String,
    reactor_sessions: String,
    reactor_wakeups: String,
    reactor_wq: String,
}

fn scrape(stats_srv: &Option<cheetah::obs::StatsServer>) -> Scraped {
    let Some(srv) = stats_srv else { return Scraped::default() };
    let body = cheetah::obs::stats::scrape(&srv.addr).expect("scrape stats endpoint");
    let snap = cheetah::obs::Snapshot::from_json(&body)
        .expect("stats endpoint must serve a schema-valid snapshot");
    let val = |name: &str| snap.get(name).map(|m| m.value.to_string()).unwrap_or_default();
    Scraped {
        pool_occ: val("serve.pool.occupancy"),
        query_p99_ms: snap
            .get("serve.query")
            .and_then(|m| m.hist.as_ref().map(|h| h.percentile(99.0)))
            .map(|ns| format!("{:.3}", ns as f64 / 1e6))
            .unwrap_or_default(),
        reactor_sessions: val("serve.reactor.sessions_peak"),
        reactor_wakeups: val("serve.reactor.wakeups"),
        reactor_wq: val("serve.reactor.write_queue_depth"),
    }
}

/// One measured serving cell, already reduced to row values.
struct Cell {
    sessions: usize,
    pool_depth: usize,
    batch: usize,
    net_sessions: usize,
    reactor: bool,
    setup_p50: Duration,
    query_p50: Duration,
    wall: Duration,
    total_queries: usize,
    online_bytes: u64,
    pool: (u64, u64, u64),
    scraped: Scraped,
    /// Client reconnect-and-replay retries during this cell (obs delta).
    retries: i64,
    /// Reactor idle + slow evictions during this cell (obs delta).
    evictions: i64,
    /// Queries that ended in a typed error (nonzero only under `--fault`).
    errored: usize,
}

fn main() {
    let args = BenchArgs::from_env();
    let max_sessions = args.get_usize("--sessions", 4);
    let queries = args.get_usize("--queries", 2);
    let batch = args.get_usize("--batch", 0);
    let depth = args.get_usize("--depth", max_sessions.min(8));
    let net_name = args.get("--net").unwrap_or("small").to_string();
    let threads = args.get_usize("--threads", cheetah::par::threads()).max(1);
    cheetah::par::set_threads(threads);
    let mode = args.get("--mode").unwrap_or("threads").to_string();
    let modes: Vec<bool> = match mode.as_str() {
        "threads" => vec![false],
        "reactor" => vec![true],
        "both" => vec![false, true],
        other => panic!("--mode must be threads|reactor|both (got `{other}`)"),
    };
    let net_sessions = args.get_usize("--net-sessions", 1);
    let client_batch = args.get_usize("--client-batch", 8).max(1);
    let stats = args.has("--stats");
    let deadline_ms = args.get_usize("--deadline-ms", 30_000) as u64;
    // A moderate fixed schedule: enough injected trouble that retries and
    // evictions actually show up, low enough that most queries complete.
    let fault: Option<FaultSpec> = args.get("--fault").map(|s| {
        let seed: u64 = s.parse().expect("--fault takes a numeric seed");
        FaultSpec::parse(&format!(
            "seed={seed},disconnect=0.01,corrupt=0.005,short=0.2,delay=0.02:1"
        ))
        .expect("valid fault spec")
    });
    // The endpoint serves the process-global obs snapshot; the secure
    // server under test runs in this process, so scraping it over HTTP
    // exercises the exact surface an operator curls in production.
    let stats_srv = if stats {
        let srv = cheetah::obs::StatsServer::serve("127.0.0.1:0").expect("bind stats endpoint");
        println!("telemetry endpoint on http://{}/ (scraped per cell)", srv.addr);
        Some(srv)
    } else {
        None
    };

    let ctx = Arc::new(Context::new(Params::default_params()));
    let plan = ScalePlan::default_plan();
    let net = bench_net(&net_name);
    println!(
        "secure serving of {} — sessions up to {max_sessions}, {queries} queries/session, \
         {threads} compute threads, mode {mode}",
        net.name
    );

    let mut t = Table::new(&[
        "mode",
        "sessions",
        "pool",
        "net_sess",
        "setup p50",
        "query p50 (server)",
        "wall",
        "req/s",
        "online bytes",
        "pool built/hits/inline",
    ]);
    // Machine-readable companion (BENCH_serve.json). Rows are keyed by
    // (sessions, mode, pool_depth, batch, net_sessions) in bench_trend.
    let mut jt = Table::new(&[
        "sessions",
        "mode",
        "pool_depth",
        "threads",
        "batch",
        "net_sessions",
        "setup_p50_ms",
        "query_p50_ms",
        "wall_s",
        "req_per_s",
        "online_bytes",
        "pool_produced",
        "pool_hits",
        "pool_inline",
        "pool_occ",
        "query_p99_ms",
        "reactor_sessions",
        "reactor_wakeups",
        "reactor_wq",
        "retries",
        "evictions",
        "error_rate",
    ]);
    let record = |t: &mut Table, jt: &mut Table, c: Cell| {
        let m = mode_name(c.reactor);
        t.row(&[
            m.to_string(),
            c.sessions.to_string(),
            if c.pool_depth > 0 { format!("on (d={})", c.pool_depth) } else { "off".into() },
            c.net_sessions.to_string(),
            cheetah::util::fmt_duration(c.setup_p50),
            cheetah::util::fmt_duration(c.query_p50),
            format!("{:.2}s", c.wall.as_secs_f64()),
            format!("{:.2}", c.total_queries as f64 / c.wall.as_secs_f64()),
            cheetah::util::fmt_bytes(c.online_bytes),
            format!("{}/{}/{}", c.pool.0, c.pool.1, c.pool.2),
        ]);
        jt.row(&[
            c.sessions.to_string(),
            m.to_string(),
            c.pool_depth.to_string(),
            threads.to_string(),
            c.batch.to_string(),
            c.net_sessions.to_string(),
            format!("{:.3}", c.setup_p50.as_secs_f64() * 1e3),
            format!("{:.3}", c.query_p50.as_secs_f64() * 1e3),
            format!("{:.3}", c.wall.as_secs_f64()),
            format!("{:.3}", c.total_queries as f64 / c.wall.as_secs_f64()),
            c.online_bytes.to_string(),
            c.pool.0.to_string(),
            c.pool.1.to_string(),
            c.pool.2.to_string(),
            c.scraped.pool_occ.clone(),
            c.scraped.query_p99_ms.clone(),
            c.scraped.reactor_sessions.clone(),
            c.scraped.reactor_wakeups.clone(),
            c.scraped.reactor_wq.clone(),
            c.retries.to_string(),
            c.evictions.to_string(),
            format!("{:.4}", c.errored as f64 / c.total_queries.max(1) as f64),
        ]);
    };

    let small_counts: Vec<usize> =
        [1usize, 2, 4, 8].into_iter().filter(|&s| s <= max_sessions).collect();
    // The C10K sweep (reactor only: the threads front would need one OS
    // thread per session, which is exactly the cap under test).
    let big_counts: Vec<usize> =
        [64usize, 256, 1000].into_iter().filter(|&s| s <= max_sessions).collect();

    for &reactor in &modes {
        for pool_on in [false, true] {
            for &sessions in &small_counts {
                // Scope the global obs registry to this cell so the scraped
                // occupancy gauge and query histogram describe one server.
                if stats {
                    cheetah::obs::reset();
                }
                let pool = if pool_on {
                    PoolConfig { depth, workers: 1 }
                } else {
                    PoolConfig::disabled()
                };
                let cfg = SecureConfig {
                    epsilon: 0.0,
                    workers: sessions.min(4),
                    pool,
                    threads,
                    reactor,
                    fault,
                    ..Default::default()
                };
                let server =
                    SecureServer::serve(ctx.clone(), net.clone(), plan, "127.0.0.1:0", cfg)
                        .expect("bind secure server");
                if pool_on {
                    // Warm the bank so the measurement sees the
                    // offline/online split, not a cold-start artifact.
                    server.wait_pool_ready(sessions.min(depth) as u64, Duration::from_secs(60));
                }
                let addr = server.addr;
                let input = input_for(&net, 23);
                let retries0 = counter("serve.retries");
                let evict0 = evictions_now();

                let t0 = Instant::now();
                let mut handles = Vec::new();
                for s in 0..sessions {
                    let input = input.clone();
                    let ctx = ctx.clone();
                    handles.push(std::thread::spawn(move || {
                        // Each session is a `CheetahNet` engine pointed at
                        // the shared server; `prepare()` is the measured
                        // setup (handshake + offline indicator transfer).
                        let mut b = EngineBuilder::new(Backend::CheetahNet)
                            .context(ctx)
                            .plan(plan)
                            .seed(9000 + s as u64)
                            .connect_to(addr)
                            .net_deadline_ms(deadline_ms);
                        if let Some(spec) = fault {
                            b = b.net_fault(spec);
                        }
                        let mut engine = b.build().expect("secure engine");
                        let per_session = if batch > 0 { batch } else { queries };
                        let t_setup = Instant::now();
                        let prepared = engine.prepare();
                        let setup = t_setup.elapsed();
                        let mut bytes = 0u64;
                        let mut errored = 0usize;
                        match prepared {
                            Err(e) => {
                                // Under injection a session may fail to come
                                // up at all — typed, counted, not fatal.
                                assert!(fault.is_some(), "secure session setup: {e}");
                                errored = per_session;
                            }
                            Ok(_) if batch > 0 => {
                                // One infer_batch call per session: the batch
                                // path over a real socket (queries pipeline in
                                // order on the session; per-query compute still
                                // fans out).
                                let inputs = vec![input.clone(); batch];
                                match engine.infer_batch(&inputs) {
                                    Ok(reps) => {
                                        for rep in reps {
                                            let traffic = rep
                                                .traffic
                                                .expect("networked engine meters traffic");
                                            bytes += traffic.c2s + traffic.s2c;
                                        }
                                    }
                                    Err(e) => {
                                        assert!(fault.is_some(), "secure batch: {e}");
                                        errored = batch;
                                    }
                                }
                            }
                            Ok(_) => {
                                for _ in 0..queries {
                                    match engine.infer(&input) {
                                        Ok(rep) => {
                                            let traffic = rep
                                                .traffic
                                                .expect("networked engine meters traffic");
                                            bytes += traffic.c2s + traffic.s2c;
                                        }
                                        Err(e) => {
                                            assert!(fault.is_some(), "secure inference: {e}");
                                            errored += 1;
                                        }
                                    }
                                }
                            }
                        }
                        (setup, bytes, errored)
                    }));
                }
                let (mut setups, online_bytes, errored): (Vec<Duration>, u64, usize) = handles
                    .into_iter()
                    .map(|h| h.join().expect("client thread"))
                    .fold((Vec::new(), 0, 0), |(mut v, b, n), (s, bytes, e)| {
                        v.push(s);
                        (v, b + bytes, n + e)
                    });
                let wall = t0.elapsed();

                let total = sessions * if batch > 0 { batch } else { queries };
                let m = server.metrics.summary();
                if fault.is_none() {
                    // Retries and error paths change the request count, so
                    // the exact meter only holds fault-free.
                    assert_eq!(m.requests as usize, total, "metered queries mismatch");
                }
                let ps = server.pool_stats();
                // Scrape while the server and its pool are still up.
                let scraped = scrape(&stats_srv);
                let cell = Cell {
                    sessions,
                    pool_depth: if pool_on { depth } else { 0 },
                    batch,
                    net_sessions: 1,
                    reactor,
                    setup_p50: p50(&mut setups),
                    query_p50: m.p50,
                    wall,
                    total_queries: total,
                    online_bytes,
                    pool: (ps.produced, ps.pool_hits, ps.inline_builds),
                    scraped,
                    retries: counter("serve.retries") - retries0,
                    evictions: evictions_now() - evict0,
                    errored,
                };
                record(&mut t, &mut jt, cell);
                server.shutdown();
            }
        }

        if reactor {
            for &sessions in &big_counts {
                if stats {
                    cheetah::obs::reset();
                }
                let cfg = SecureConfig {
                    epsilon: 0.0,
                    workers: 4,
                    pool: PoolConfig::disabled(),
                    threads,
                    reactor: true,
                    ..Default::default()
                };
                let server =
                    SecureServer::serve(ctx.clone(), net.clone(), plan, "127.0.0.1:0", cfg)
                        .expect("bind secure server");
                let addr = server.addr;
                let input = input_for(&net, 23);

                // Bounded client drivers, each owning a slice of sessions:
                // every session connects and stays open before any query
                // runs, so `sessions` secure sessions are concurrently
                // live on the server's handful of reactor+worker threads.
                let drivers = 16.min(sessions);
                let connected = Arc::new(Barrier::new(drivers + 1));
                let go = Arc::new(Barrier::new(drivers + 1));
                let t0 = Instant::now();
                let mut handles = Vec::new();
                for d in 0..drivers {
                    let input = input.clone();
                    let ctx = ctx.clone();
                    let connected = connected.clone();
                    let go = go.clone();
                    handles.push(std::thread::spawn(move || {
                        let mut engines = Vec::new();
                        let mut setups = Vec::new();
                        for s in (d..sessions).step_by(drivers) {
                            let mut engine = EngineBuilder::new(Backend::CheetahNet)
                                .context(ctx.clone())
                                .plan(plan)
                                .seed(9000 + s as u64)
                                .connect_to(addr)
                                .build()
                                .expect("secure engine");
                            let t_setup = Instant::now();
                            engine.prepare().expect("secure session setup");
                            setups.push(t_setup.elapsed());
                            engines.push(engine);
                        }
                        connected.wait();
                        go.wait();
                        let mut bytes = 0u64;
                        for engine in &mut engines {
                            for _ in 0..queries.max(1) {
                                let rep = engine.infer(&input).expect("secure inference");
                                let traffic =
                                    rep.traffic.expect("networked engine meters traffic");
                                bytes += traffic.c2s + traffic.s2c;
                            }
                        }
                        (setups, bytes)
                    }));
                }
                connected.wait();
                let live = server.session_count();
                assert_eq!(live, sessions, "all sessions must be concurrently live");
                go.wait();
                let (mut setups, online_bytes): (Vec<Duration>, u64) = handles
                    .into_iter()
                    .map(|h| h.join().expect("driver thread"))
                    .fold((Vec::new(), 0), |(mut v, b), (s, bytes)| {
                        v.extend(s);
                        (v, b + bytes)
                    });
                let wall = t0.elapsed();

                let total = sessions * queries.max(1);
                let m = server.metrics.summary();
                assert_eq!(m.requests as usize, total, "metered queries mismatch");
                let scraped = scrape(&stats_srv);
                let cell = Cell {
                    sessions,
                    pool_depth: 0,
                    batch: 0,
                    net_sessions: 1,
                    reactor: true,
                    setup_p50: p50(&mut setups),
                    query_p50: m.p50,
                    wall,
                    total_queries: total,
                    online_bytes,
                    pool: (0, 0, 0),
                    scraped,
                    retries: 0,
                    evictions: 0,
                    errored: 0,
                };
                record(&mut t, &mut jt, cell);
                server.shutdown();
            }
        }

        // Pooled-client experiment: one engine, k TCP sessions behind
        // `infer_batch` — whole-query parallelism over the wire (compare
        // the k=1 pipelining row with the k=K fan-out row).
        if net_sessions > 1 {
            for k in [1usize, net_sessions] {
                if stats {
                    cheetah::obs::reset();
                }
                let cfg = SecureConfig {
                    epsilon: 0.0,
                    workers: 4,
                    pool: PoolConfig::disabled(),
                    threads,
                    reactor,
                    ..Default::default()
                };
                let server =
                    SecureServer::serve(ctx.clone(), net.clone(), plan, "127.0.0.1:0", cfg)
                        .expect("bind secure server");
                let input = input_for(&net, 23);
                let mut engine = EngineBuilder::new(Backend::CheetahNet)
                    .context(ctx.clone())
                    .plan(plan)
                    .seed(4100)
                    .connect_to(server.addr)
                    .net_sessions(k)
                    .build()
                    .expect("secure engine");
                let t_setup = Instant::now();
                engine.prepare().expect("pooled session setup");
                let setup = t_setup.elapsed();
                let inputs = vec![input; client_batch];
                let t0 = Instant::now();
                let reps = engine.infer_batch(&inputs).expect("pooled batch");
                let wall = t0.elapsed();
                let online_bytes = reps
                    .iter()
                    .map(|r| {
                        let tr = r.traffic.expect("networked engine meters traffic");
                        tr.c2s + tr.s2c
                    })
                    .sum();
                let m = server.metrics.summary();
                assert_eq!(m.requests as usize, client_batch, "metered queries mismatch");
                let scraped = scrape(&stats_srv);
                let cell = Cell {
                    sessions: 1,
                    pool_depth: 0,
                    batch: client_batch,
                    net_sessions: k,
                    reactor,
                    setup_p50: setup,
                    query_p50: m.p50,
                    wall,
                    total_queries: client_batch,
                    online_bytes,
                    pool: (0, 0, 0),
                    scraped,
                    retries: 0,
                    evictions: 0,
                    errored: 0,
                };
                record(&mut t, &mut jt, cell);
                drop(engine);
                server.shutdown();
            }
        }
    }

    t.print(&format!(
        "secure serving ({}) — session setup amortized by the blinding pool; \
         online latency unchanged",
        net.name
    ));
    jt.write_json(
        "BENCH_serve.json",
        "secure serving: wall/bytes per (sessions, mode, pool, threads, batch, net_sessions)",
    )
    .expect("write BENCH_serve.json");
    println!("\nwrote BENCH_serve.json");
}
