//! Fully-connected benchmark — paper **Table 4** (matrix-vector product,
//! five shapes, with #Perm/#Mult/#Add) and **Table 5** (communication).
//!
//! Run: `cargo bench --bench fc_bench`

use cheetah::bench_util::{time_fn, BenchArgs, Table};
use cheetah::fixed::ScalePlan;
use cheetah::nn::{Layer, Network};
use cheetah::phe::serial::ciphertext_bytes;
use cheetah::phe::{Context, Encryptor, Evaluator, Params};
use cheetah::protocol::cheetah::CheetahRunner;
use cheetah::protocol::gala::fc as gala_fc;
use cheetah::protocol::gazelle::{fc, fc_galois_keys, pack_fc_input, FcMethod};
use cheetah::util::rng::{ChaCha20Rng, SplitMix64};

fn main() {
    let args = BenchArgs::from_env();
    let ctx = std::sync::Arc::new(Context::new(Params::default_params()));
    let plan = ScalePlan::default_plan();
    let samples = args.get_usize("--samples", 5);

    let shapes: [(usize, usize); 5] = [(1, 2048), (2, 1024), (4, 512), (8, 256), (16, 128)];

    let mut t4 = Table::new(&[
        "n_o x n_i",
        "method",
        "#Perm",
        "#Mult",
        "#Add",
        "time (ms)",
        "speedup",
    ]);
    let mut t5 = Table::new(&["n_o x n_i", "GAZELLE (KB)", "CHEETAH (KB)"]);

    for (n_o, n_i) in shapes {
        let mut rng = ChaCha20Rng::from_u64_seed(7);
        let mut srng = SplitMix64::new(8);
        let enc = Encryptor::new(ctx.clone(), &mut rng);
        let ev = Evaluator::new(ctx.clone());
        let mut layer = Layer::fc(n_o);
        layer.init_weights(1, 1, n_i, &mut srng);
        let gk = fc_galois_keys(&ctx, &enc.sk, n_i, &mut rng);
        let x_q: Vec<i64> = (0..n_i).map(|_| srng.gen_i64_range(-128, 128)).collect();

        // GAZELLE hybrid.
        let packed = pack_fc_input(&ctx, &x_q, FcMethod::Hybrid);
        let mut ct = enc.encrypt_slots(&packed, &mut rng);
        ev.to_ntt(&mut ct);
        ev.reset_counts();
        let (outs, _) = fc(&ev, FcMethod::Hybrid, &ct, &layer, n_i, &plan, 1.0, &gk);
        let gz_counts = ev.counts();
        let gz_out_cts = outs.len();
        let t_gz = time_fn(1, samples, || {
            let _ =
                std::hint::black_box(fc(&ev, FcMethod::Hybrid, &ct, &layer, n_i, &plan, 1.0, &gk));
        });

        // GALA: same packed ciphertext, rotation-free (the rotate-and-sum
        // tree lives in share generation).
        ev.reset_counts();
        let _ = gala_fc(&ev, &ct, &layer, n_i, &plan, 1.0);
        let ga_counts = ev.counts();
        let t_ga = time_fn(1, samples, || {
            let _ = std::hint::black_box(gala_fc(&ev, &ct, &layer, n_i, &plan, 1.0));
        });

        // CHEETAH single FC step.
        let mut net = Network {
            name: "fc".into(),
            input_shape: (1, 1, n_i),
            layers: vec![Layer::fc(n_o)],
        };
        net.init_weights(9);
        let mut runner = CheetahRunner::new(ctx.clone(), net, plan, 0.0, 10).expect("valid network");
        runner.run_offline();
        let input = cheetah::nn::Tensor::from_flat(
            (0..n_i).map(|_| srng.gen_f64_range(-1.0, 1.0)).collect(),
        );
        let mut ch_ms = f64::MAX;
        let mut ch_ops = Default::default();
        let mut ch_s2c = 0u64;
        for _ in 0..samples {
            let rep = runner.infer(&input);
            ch_ms = ch_ms.min(rep.steps[0].server_online.as_secs_f64() * 1e3);
            ch_ops = rep.steps[0].server_ops;
            ch_s2c = rep.steps[0].s2c_bytes;
        }

        let label = format!("{n_o}x{n_i}");
        t4.row(&[
            label.clone(),
            "GAZELLE".into(),
            gz_counts.perm.to_string(),
            gz_counts.mult.to_string(),
            gz_counts.add.to_string(),
            format!("{:.3}", t_gz.millis()),
            String::new(),
        ]);
        t4.row(&[
            label.clone(),
            "GALA".into(),
            ga_counts.perm.to_string(),
            ga_counts.mult.to_string(),
            ga_counts.add.to_string(),
            format!("{:.3}", t_ga.millis()),
            format!("{:.0}x", t_gz.millis() / t_ga.millis()),
        ]);
        t4.row(&[
            label.clone(),
            "CHEETAH".into(),
            ch_ops.perm.to_string(),
            ch_ops.mult.to_string(),
            ch_ops.add.to_string(),
            format!("{ch_ms:.3}"),
            format!("{:.0}x", t_gz.millis() / ch_ms),
        ]);

        // Table 5: total online comm for the layer *including the
        // nonlinearity* (as the paper does): GAZELLE pays GC label/OT
        // traffic per output, CHEETAH one recovery ciphertext.
        let gc = cheetah::gc::GcRelu::new(ctx.params.p, plan.k.frac_bits as usize);
        let gc_online_per_relu = 2 * gc.ell * 16 + gc.ell * (16 + 32) + gc.ell.div_ceil(8);
        let gz_kb = ((ciphertext_bytes(&ctx.params, true)
            + gz_out_cts * ciphertext_bytes(&ctx.params, false)
            + n_o * gc_online_per_relu) as f64)
            / 1024.0;
        let ch_kb = (ciphertext_bytes(&ctx.params, true)
            .saturating_mul((n_i * n_o).div_ceil(ctx.params.n))
            + ch_s2c as usize
            + ciphertext_bytes(&ctx.params, false)) as f64
            / 1024.0;
        t5.row(&[label, format!("{gz_kb:.1}"), format!("{ch_kb:.1}")]);
    }

    t4.print("Table 4 — matrix-vector product (paper: CHEETAH 294-422x, 0 Perm, 1 Mult)");
    t5.print("Table 5 — FC communication (paper: CHEETAH 143.1 KB flat; GAZELLE grows with n_o)");
}
