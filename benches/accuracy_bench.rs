//! Accuracy-vs-noise benchmark — paper **Fig. 7**: classification accuracy
//! as a function of the obscuring-noise bound ε for the four benchmark
//! networks.
//!
//! * Network A / Network B: *trained* weights (from `make artifacts`),
//!   evaluated through the **PJRT runtime** on the AOT-lowered noisy
//!   forward graphs (the L2+L1 stack measured end-to-end from Rust).
//! * AlexNet / VGG-16: no trained weights exist offline (ImageNet gate —
//!   see DESIGN.md); we report the noise-propagation proxy instead: top-1
//!   *agreement* between the noisy and noise-free quantized forward pass
//!   of the same seeded random-weight network (scaled spatially). The ε
//!   threshold shape matches the paper's (stable below ~0.25).
//!
//! Run: `cargo bench --bench accuracy_bench [-- --samples N]`

use cheetah::bench_util::{BenchArgs, Table};
use cheetah::engine::{Backend, EngineBuilder, InferenceEngine};
use cheetah::nn::{Network, NetworkArch};

const EPS_GRID: [f64; 6] = [0.0, 0.05, 0.1, 0.25, 0.4, 0.5];

/// Trained Net A / Net B rows via the PJRT artifacts (needs the external
/// `xla` crate, so this path only exists under the `pjrt` feature).
#[cfg(feature = "pjrt")]
fn trained_rows(t: &mut Table, samples: usize) {
    use cheetah::nn::SyntheticDigits;
    use cheetah::runtime::Runtime;
    if !std::path::Path::new("artifacts/manifest.txt").exists() {
        eprintln!("artifacts/ missing — run `make artifacts` for the trained-net rows");
        return;
    }
    let mut rt = Runtime::new("artifacts").expect("PJRT runtime");
    for arch in ["netA", "netB"] {
        let mut gen = SyntheticDigits::new(28, 777);
        let batch = gen.batch(samples);
        let mut row = vec![format!("{arch} (trained)"), "accuracy".into()];
        for (ei, &eps) in EPS_GRID.iter().enumerate() {
            let mut correct = 0usize;
            for chunk in batch.chunks(32) {
                if chunk.len() < 32 {
                    break;
                }
                let mut pixels = Vec::with_capacity(32 * 784);
                for s in chunk {
                    pixels.extend(s.image.data.iter().map(|&v| v as f32));
                }
                let logits = rt
                    .noisy_forward(arch, &pixels, 32, 28, [42, ei as u32], eps as f32)
                    .expect("noisy_forward");
                for (s, l) in chunk.iter().zip(&logits) {
                    let am = l
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .unwrap()
                        .0;
                    if am == s.label {
                        correct += 1;
                    }
                }
            }
            let total = (samples / 32) * 32;
            row.push(format!("{:.1}%", 100.0 * correct as f64 / total as f64));
        }
        t.row(&row);
    }
}

#[cfg(not(feature = "pjrt"))]
fn trained_rows(_t: &mut Table, _samples: usize) {
    eprintln!("built without the `pjrt` feature — trained Net A/B rows skipped");
}

fn main() {
    let args = BenchArgs::from_env();
    let samples = args.get_usize("--samples", 96); // multiple of batch 32

    let mut t = Table::new(&[
        "network",
        "metric",
        "eps=0",
        "0.05",
        "0.1",
        "0.25",
        "0.4",
        "0.5",
    ]);

    trained_rows(&mut t, samples);

    // ---- AlexNet / VGG-16 noise-propagation proxy ----
    for arch in [NetworkArch::AlexNet, NetworkArch::Vgg16] {
        let net = Network::build_scaled(arch, 31, 0.14);
        let mut gen = cheetah::util::rng::SplitMix64::new(32);
        let n_inputs = 12usize;
        let (c, h, w) = net.input_shape;
        let inputs: Vec<cheetah::nn::Tensor> = (0..n_inputs)
            .map(|_| {
                cheetah::nn::Tensor::from_vec(
                    (0..c * h * w).map(|_| gen.gen_f64_range(0.0, 1.0)).collect(),
                    c,
                    h,
                    w,
                )
            })
            .collect();
        // Random-weight logit margins are ~1e-3 (no training signal), so
        // top-1 agreement is meaningless; the proxy is the relative logit
        // perturbation ‖noisy − clean‖/‖clean‖ — the quantity that governs
        // accuracy degradation once real margins exist. The paper's Fig. 7
        // shape (flat below ε ≈ 0.25) appears as sub-~10% perturbation.
        // Both passes run through the unified engine API: the
        // `PlaintextQuantized` backend is the protocol's fixed-point mirror
        // (dequantization is linear, so the ratio is scale-invariant).
        let mut clean_engine = EngineBuilder::new(Backend::PlaintextQuantized)
            .network(net.clone())
            .epsilon(0.0)
            .build()
            .expect("clean engine");
        let clean: Vec<Vec<f64>> = inputs
            .iter()
            .map(|x| clean_engine.infer(x).expect("clean inference").logits)
            .collect();
        let mut row =
            vec![format!("{} (proxy)", net.name), "rel. logit perturbation".into()];
        for &eps in &EPS_GRID {
            let mut noisy_engine = EngineBuilder::new(Backend::PlaintextQuantized)
                .network(net.clone())
                .epsilon(eps)
                .seed(99)
                .build()
                .expect("noisy engine");
            let mut rel_sum = 0f64;
            for (i, x) in inputs.iter().enumerate() {
                let q = noisy_engine.infer(x).expect("noisy inference").logits;
                let num: f64 = q
                    .iter()
                    .zip(&clean[i])
                    .map(|(&a, &b)| (a - b).powi(2))
                    .sum::<f64>()
                    .sqrt();
                let den: f64 = clean[i].iter().map(|&b| b.powi(2)).sum::<f64>().sqrt();
                rel_sum += num / den.max(1e-6);
            }
            row.push(format!("{:.1}%", 100.0 * rel_sum / n_inputs as f64));
        }
        t.row(&row);
    }

    t.print("Fig. 7 — accuracy vs noise bound ε (paper: negligible drop for ε < 0.25)");
}
