//! Convolution benchmark — paper **Table 3** (three input/kernel configs ×
//! {GAZELLE In_rot, GAZELLE Out_rot, GALA, CHEETAH}) and **Fig. 5**
//! (speedup and communication vs kernel size r).
//!
//! Timing convention follows the paper: the measured span is the server's
//! linear computation, from receipt of the encrypted input to the obscured
//! (or rotated-and-summed) products being ready to send; communication is
//! reported separately as exact serialized bytes.
//!
//! Run: `cargo bench --bench conv_bench [-- --sweep] [-- --paper]`

use cheetah::bench_util::{time_fn, BenchArgs, Table};
use cheetah::fixed::ScalePlan;
use cheetah::nn::{Layer, Network};
use cheetah::phe::serial::ciphertext_bytes;
use cheetah::phe::{Context, Encryptor, Evaluator, Params};
use cheetah::protocol::cheetah::CheetahRunner;
use cheetah::protocol::gala;
use cheetah::protocol::gazelle::{conv, conv_galois_keys, ConvVariant};
use cheetah::util::fmt_bytes;
use cheetah::util::rng::{ChaCha20Rng, SplitMix64};

struct Cfg {
    name: &'static str,
    c_i: usize,
    hw: usize,
    c_o: usize,
    r: usize,
}

/// One measurement row:
/// (gazelle_ir_ms, gazelle_or_ms, gala_ms, cheetah_ms, gz_bytes, ga_bytes, ch_bytes).
fn run_config(
    ctx: &std::sync::Arc<Context>,
    cfg: &Cfg,
    samples: usize,
) -> (f64, f64, f64, f64, u64, u64, u64) {
    let plan = ScalePlan::default_plan();
    let mut rng = ChaCha20Rng::from_u64_seed(3);
    let mut srng = SplitMix64::new(4);
    let enc = Encryptor::new(ctx.clone(), &mut rng);
    let ev = Evaluator::new(ctx.clone());

    let mut layer = Layer::conv(cfg.c_o, cfg.r, 1, cfg.r / 2);
    layer.init_weights(cfg.c_i, cfg.hw, cfg.hw, &mut srng);

    // ---- GAZELLE variants ----
    let gk = conv_galois_keys(ctx, &enc.sk, cfg.r, cfg.hw, &mut rng);
    let input_q: Vec<i64> =
        (0..cfg.c_i * cfg.hw * cfg.hw).map(|_| srng.gen_i64_range(-128, 128)).collect();
    let mut in_cts: Vec<_> = (0..cfg.c_i)
        .map(|i| enc.encrypt_slots(&input_q[i * cfg.hw * cfg.hw..(i + 1) * cfg.hw * cfg.hw], &mut rng))
        .collect();
    for ct in in_cts.iter_mut() {
        ev.to_ntt(ct);
    }
    let shape = (cfg.c_i, cfg.hw, cfg.hw);
    let t_ir = time_fn(1, samples, || {
        let _ = std::hint::black_box(conv(
            &ev,
            ConvVariant::InputRotation,
            &in_cts,
            &layer,
            shape,
            &plan,
            1.0,
            &gk,
        ));
    });
    let t_or = time_fn(1, samples, || {
        let _ = std::hint::black_box(conv(
            &ev,
            ConvVariant::OutputRotation,
            &in_cts,
            &layer,
            shape,
            &plan,
            1.0,
            &gk,
        ));
    });
    // GAZELLE s→c bytes: c_o evaluated ciphertexts.
    let gz_bytes = (cfg.c_o * ciphertext_bytes(&ctx.params, false)) as u64;

    // ---- GALA (greedy packing on the same substrate) ----
    let geom = gala::GalaConvGeometry::new(ctx.params.row_size(), shape, cfg.c_o, cfg.r);
    let ga_gk = gala::gala_conv_galois_keys(ctx, &enc.sk, cfg.r, cfg.hw, &mut rng);
    let residues: Vec<u64> = input_q
        .iter()
        .map(|&v| if v < 0 { ctx.params.p - (-v) as u64 } else { v as u64 })
        .collect();
    let mut ga_cts: Vec<_> = gala::pack_conv_input(&geom, &residues)
        .iter()
        .map(|slots| enc.encrypt(&ctx.encoder.encode_unsigned(slots), &mut rng))
        .collect();
    for ct in ga_cts.iter_mut() {
        ev.to_ntt(ct);
    }
    let t_ga = time_fn(1, samples, || {
        let _ = std::hint::black_box(gala::conv(&ev, &geom, &ga_cts, &layer, &plan, 1.0, &ga_gk));
    });
    // GALA s→c bytes: one ciphertext per output group.
    let ga_bytes = (geom.out_groups * ciphertext_bytes(&ctx.params, false)) as u64;

    // ---- CHEETAH (single conv layer as a 1-step network) ----
    let mut net = Network {
        name: "bench".into(),
        input_shape: shape,
        layers: vec![Layer::conv(cfg.c_o, cfg.r, 1, cfg.r / 2)],
    };
    net.init_weights(5);
    let mut runner = CheetahRunner::new(ctx.clone(), net, plan, 0.0, 6).expect("valid network");
    runner.run_offline();
    let input = cheetah::nn::Tensor::from_vec(
        (0..cfg.c_i * cfg.hw * cfg.hw).map(|_| srng.gen_f64_range(-1.0, 1.0)).collect(),
        cfg.c_i,
        cfg.hw,
        cfg.hw,
    );
    // Warm + measure: server_online of the conv step only.
    let mut ch_ms = f64::MAX;
    let mut ch_bytes = 0u64;
    for _ in 0..samples.max(2) {
        let rep = runner.infer(&input);
        ch_ms = ch_ms.min(rep.steps[0].server_online.as_secs_f64() * 1e3);
        ch_bytes = rep.steps[0].s2c_bytes;
    }
    (t_ir.millis(), t_or.millis(), t_ga.millis(), ch_ms, gz_bytes, ga_bytes, ch_bytes)
}

fn main() {
    let args = BenchArgs::from_env();
    let params = Params::default_params();
    let ctx = std::sync::Arc::new(Context::new(params));
    let samples = args.get_usize("--samples", 3);

    // Paper Table 3 configs (spatial dims reduced by default so the
    // rotation variants fit one half-row; --paper uses the printed sizes).
    let paper = args.has("--paper");
    let configs = if paper {
        vec![
            Cfg { name: "28x28@1, 5x5@5", c_i: 1, hw: 28, c_o: 5, r: 5 },
            Cfg { name: "16x16@128, 1x1@2", c_i: 128, hw: 16, c_o: 2, r: 1 },
            Cfg { name: "32x32@2, 3x3@1", c_i: 2, hw: 32, c_o: 1, r: 3 },
        ]
    } else {
        vec![
            Cfg { name: "28x28@1, 5x5@5", c_i: 1, hw: 28, c_o: 5, r: 5 },
            Cfg { name: "16x16@16, 1x1@2", c_i: 16, hw: 16, c_o: 2, r: 1 },
            Cfg { name: "32x32@2, 3x3@1", c_i: 2, hw: 32, c_o: 1, r: 3 },
        ]
    };

    let mut t = Table::new(&[
        "config (in, kernel)",
        "In_rot (ms)",
        "Out_rot (ms)",
        "GALA (ms)",
        "CHEETAH (ms)",
        "speedup IR/CH",
        "speedup GA/CH",
        "GZ s2c",
        "GA s2c",
        "CH s2c",
    ]);
    for cfg in &configs {
        let (ir, or, ga, ch, gb, ab, cb) = run_config(&ctx, cfg, samples);
        t.row(&[
            cfg.name.into(),
            format!("{ir:.2}"),
            format!("{or:.2}"),
            format!("{ga:.2}"),
            format!("{ch:.3}"),
            format!("{:.0}x", ir / ch),
            format!("{:.0}x", ga / ch),
            fmt_bytes(gb),
            fmt_bytes(ab),
            fmt_bytes(cb),
        ]);
    }
    t.print("Table 3 — convolution benchmark (paper: CHEETAH 66-306x faster)");

    if args.has("--sweep") {
        // Fig. 5: kernel-size sweep on the paper's three input configs.
        let mut t =
            Table::new(&["config", "r", "IR (ms)", "OR (ms)", "GA (ms)", "CH (ms)", "best-GZ/CH"]);
        for (name, c_i, hw, c_o) in
            [("28x28@1 rxr@5", 1usize, 28usize, 5usize), ("16x16@16 rxr@2", 16, 16, 2), ("32x32@2 rxr@1", 2, 32, 1)]
        {
            for r in [1usize, 3, 5, 7] {
                let cfg = Cfg { name, c_i, hw, c_o, r };
                let (ir, or, ga, ch, _, _, _) = run_config(&ctx, &cfg, 2);
                t.row(&[
                    name.into(),
                    r.to_string(),
                    format!("{ir:.2}"),
                    format!("{or:.2}"),
                    format!("{ga:.2}"),
                    format!("{ch:.3}"),
                    format!("{:.0}x", ir.min(or) / ch),
                ]);
            }
        }
        t.print("Fig. 5 — speedup vs kernel size (paper: 60-400x, growing with r)");
    }
}
