//! End-to-end network benchmark — paper **Table 7** (online/offline time +
//! communication for Network A, Network B, AlexNet, VGG-16, CHEETAH vs
//! GAZELLE vs GALA) and **Fig. 8** (accumulated per-layer breakdown,
//! `--breakdown`)
//! — both frameworks driven through the unified engine API
//! (`cheetah::engine::EngineBuilder`), so each row is literally the same
//! build→prepare→infer calls with a different [`Backend`].
//!
//! CHEETAH additionally runs twice per network — once at `--threads 1`
//! (the sequential baseline) and once at `--threads N` (default: all
//! cores) — so the parallel-runtime speedup is measured and recorded.
//! Results are persisted to `BENCH_e2e.json` (machine-readable; uploaded
//! by CI) so the perf trajectory is tracked across PRs.
//!
//! Default: scaled-down AlexNet/VGG so the GAZELLE rotation path fits one
//! half-row per channel and the bench finishes in minutes; `--paper` runs
//! CHEETAH at full scale (GAZELLE full-scale cost is extrapolated from its
//! measured per-op costs — see EXPERIMENTS.md).
//!
//! `--batch N` (default 4) additionally measures **batch-level
//! parallelism**: N queries scored as one `infer_batch` fork-join region
//! vs the same N through a sequential `infer` loop, asserting bit-equal
//! logits and recording both throughputs (queries/sec) in the JSON
//! (`framework = cheetah-loop` / `cheetah-batch`). `--batch 1` disables
//! the section.
//!
//! `--obs` turns telemetry on for the run and embeds the final
//! `cheetah::obs` snapshot (span histograms for the phe/protocol/par
//! layers) as an `"obs"` section of `BENCH_e2e.json`, plus a standalone
//! `BENCH_e2e_obs.json` that CI uploads next to the bench artifacts.
//!
//! `--params auto|default|big` (default `default`) picks the RLWE
//! parameter policy for the CHEETAH engines (`auto` runs the
//! [`cheetah::plan`] planner per network; GAZELLE stays on the default
//! set, whose rotation-key geometry the baseline is tuned for). Every JSON
//! row records the parameter set it ran under in a `params` column
//! (`n4096p23`-style; `-` where no HE parameters apply). Independent of
//! the flag, one **auto-params cell** always runs: netRes — whose residual
//! tower overflows the default plaintext modulus — through the planner,
//! recording the bigger rung it climbs to.
//!
//! Run: `cargo bench --bench e2e_bench [-- --breakdown] [-- --paper]
//!       [-- --network netB] [-- --threads 4] [-- --batch 8] [-- --obs]
//!       [-- --params auto]`

use cheetah::bench_util::{BenchArgs, Table};
use cheetah::engine::{Backend, EngineBuilder, InferenceEngine};
use cheetah::nn::{Network, NetworkArch, SyntheticDigits, Tensor};
use cheetah::phe::{Context, Params};
use cheetah::plan::ParamsChoice;
use cheetah::util::fmt_bytes;
use cheetah::util::rng::SplitMix64;
use std::sync::Arc;
use std::time::Instant;

fn input_for(net: &Network, seed: u64) -> Tensor {
    let (c, h, w) = net.input_shape;
    if c == 1 && h >= 12 {
        SyntheticDigits::new(h, seed).render(3).image
    } else {
        let mut rng = SplitMix64::new(seed);
        Tensor::from_vec((0..c * h * w).map(|_| rng.gen_f64_range(0.0, 1.0)).collect(), c, h, w)
    }
}

fn main() {
    let args = BenchArgs::from_env();
    let paper = args.has("--paper");
    let obs = args.has("--obs");
    if obs {
        cheetah::obs::set_level(cheetah::obs::Level::On);
        cheetah::obs::reset();
    }
    let threads = args.get_usize("--threads", cheetah::par::threads()).max(1);
    let batch = args.get_usize("--batch", 4).max(1);
    let net_filter = args.get("--network").map(|s| s.to_string());
    let params_raw = args.get("--params").unwrap_or("default").to_string();
    let params_choice = ParamsChoice::parse(&params_raw)
        .unwrap_or_else(|| panic!("unknown --params value `{params_raw}` (auto|default|big)"));
    let ctx = Arc::new(Context::new(Params::default_params()));

    // Spatial scale factors: GAZELLE needs h·w ≤ row_size (2048) per
    // channel and ≥1 pixel after every pool: AlexNet at 0.2 (45×45),
    // VGG-16 at 32/224 (32×32). CHEETAH has no such limit.
    let nets: Vec<(NetworkArch, f64, f64)> = vec![
        // (arch, cheetah_scale, gazelle_scale)
        (NetworkArch::NetA, 1.0, 1.0),
        (NetworkArch::NetB, 1.0, 1.0),
        (NetworkArch::AlexNet, if paper { 1.0 } else { 0.2 }, 0.2),
        (NetworkArch::Vgg16, if paper { 1.0 } else { 32.0 / 224.0 }, 32.0 / 224.0),
    ];
    let nets: Vec<(NetworkArch, f64, f64)> = nets
        .into_iter()
        .filter(|(arch, _, _)| {
            net_filter.as_deref().is_none_or(|f| NetworkArch::from_key(f) == Some(*arch))
        })
        .collect();
    // `--network netRes` selects just the auto-params cell below.
    let netres_only = net_filter
        .as_deref()
        .is_some_and(|f| NetworkArch::from_key(f) == Some(NetworkArch::NetRes));
    assert!(
        !nets.is_empty() || netres_only,
        "--network matched no architecture (try netA/netB/alexnet/vgg16/netRes)"
    );

    let mut t = Table::new(&[
        "network",
        "framework",
        "online time",
        "offline time",
        "online comm",
        "offline comm",
        "speedup",
        "#Perm",
    ]);
    // Machine-readable companion (BENCH_e2e.json): one row per
    // (network, framework, params, threads, batch) cell, times in
    // milliseconds. Single-query rows have batch=1;
    // `cheetah-loop`/`cheetah-batch` rows record whole-batch wall ms in
    // online_ms plus throughput in qps. `params` is the RLWE set the cell
    // ran under (`n4096p23`-style).
    let mut jt = Table::new(&[
        "network",
        "framework",
        "params",
        "threads",
        "online_ms",
        "offline_ms",
        "online_bytes",
        "offline_bytes",
        "perm",
        "par_speedup",
        "batch",
        "qps",
    ]);

    for (arch, ch_scale, gz_scale) in nets {
        // ---- CHEETAH: sequential baseline, then the parallel runtime ----
        let net = Network::build_scaled(arch, 21, ch_scale);
        let name = net.name.clone();
        let input = input_for(&net, 22);
        // Batch inputs drawn up front (the net moves into the builder).
        let batch_inputs: Vec<Tensor> =
            (0..batch).map(|i| input_for(&net, 30 + i as u64)).collect();
        // The params policy applies to the CHEETAH engines; `Default`
        // keeps today's shared context (bit-identical rows), `auto`/`big`
        // let each engine resolve its own.
        let builder = EngineBuilder::new(Backend::Cheetah).network(net).epsilon(0.05).seed(23);
        let builder = match params_choice {
            ParamsChoice::Default => builder.context(ctx.clone()),
            choice => builder.params(choice),
        };
        let mut ch = builder.build().expect("cheetah engine");

        // Offline and online are measured at each thread count: prepare()
        // rebuilds the deployment from the same seed, so both runs carry
        // identical blinding material and each infer is the deployment's
        // first query — the logits must match bit for bit.
        cheetah::par::set_threads(1);
        let seq_prep = ch.prepare().expect("cheetah offline (threads=1)");
        let seq_rep = ch.infer(&input).expect("cheetah inference (threads=1)");
        let seq_online = seq_rep.online_total();

        cheetah::par::set_threads(threads);
        let ch_prep = ch.prepare().expect("cheetah offline");
        let ch_rep = ch.infer(&input).expect("cheetah inference");
        let ch_online = ch_rep.online_total();
        assert_eq!(
            seq_rep.logits, ch_rep.logits,
            "{name}: parallel run diverged from the sequential baseline"
        );
        let par_speedup = seq_rep.online_compute().as_secs_f64()
            / ch_rep.online_compute().as_secs_f64().max(1e-9);

        // ---- GAZELLE (skip full-scale big nets; see header) ----
        let gz_net = Network::build_scaled(arch, 21, gz_scale);
        let gz_name = gz_net.name.clone();
        let gz_input = input_for(&gz_net, 22);
        let mut gz = EngineBuilder::new(Backend::Gazelle)
            .network(gz_net)
            .context(ctx.clone())
            .seed(24)
            .build()
            .expect("gazelle engine");
        let gz_prep = gz.prepare().expect("gazelle offline");
        let gz_rep = gz.infer(&gz_input).expect("gazelle inference");
        let gz_online = gz_rep.online_total();
        let gz_timing = gz_rep.timing.expect("gazelle timing");

        // ---- GALA: same baseline substrate, greedy packing. Same
        // weights + input as the GAZELLE row, so the logits must match
        // bit for bit (masks cancel; HE and GC are exact mod p). ----
        let ga_net = Network::build_scaled(arch, 21, gz_scale);
        let mut ga = EngineBuilder::new(Backend::Gala)
            .network(ga_net)
            .context(ctx.clone())
            .seed(24)
            .build()
            .expect("gala engine");
        let ga_prep = ga.prepare().expect("gala offline");
        let ga_rep = ga.infer(&gz_input).expect("gala inference");
        let ga_online = ga_rep.online_total();
        assert_eq!(
            gz_rep.logits, ga_rep.logits,
            "{name}: GALA logits diverged bitwise from hybrid GAZELLE"
        );

        let scale_note = if (ch_scale - gz_scale).abs() > 1e-9 {
            format!(" [GZ @ {gz_name}]")
        } else {
            String::new()
        };
        t.row(&[
            format!("{name}{scale_note}"),
            "GAZELLE".into(),
            format!("{:.0} ms", gz_online.as_secs_f64() * 1e3),
            format!(
                "{:.0} ms (+garble {:.0} ms)",
                gz_prep.offline_time.as_secs_f64() * 1e3,
                gz_timing.offline.as_secs_f64() * 1e3
            ),
            fmt_bytes(gz_rep.online_bytes()),
            fmt_bytes(gz_prep.offline_bytes),
            String::new(),
            gz_rep.ops.map(|o| o.perm).unwrap_or(0).to_string(),
        ]);
        t.row(&[
            format!("{name}{scale_note}"),
            "GALA".into(),
            format!("{:.0} ms", ga_online.as_secs_f64() * 1e3),
            format!("{:.0} ms", ga_prep.offline_time.as_secs_f64() * 1e3),
            fmt_bytes(ga_rep.online_bytes()),
            fmt_bytes(ga_prep.offline_bytes),
            format!(
                "{:.1}x",
                gz_online.as_secs_f64() / ga_online.as_secs_f64().max(1e-9)
            ),
            ga_rep.ops.map(|o| o.perm).unwrap_or(0).to_string(),
        ]);
        t.row(&[
            format!("{name} [T=1]"),
            "CHEETAH".into(),
            format!("{:.0} ms", seq_online.as_secs_f64() * 1e3),
            format!("{:.0} ms", seq_prep.offline_time.as_secs_f64() * 1e3),
            fmt_bytes(seq_rep.online_bytes()),
            fmt_bytes(seq_prep.offline_bytes),
            format!(
                "{:.0}x",
                gz_online.as_secs_f64() / seq_online.as_secs_f64().max(1e-9)
            ),
            seq_rep.ops.map(|o| o.perm).unwrap_or(0).to_string(),
        ]);
        t.row(&[
            format!("{name} [T={threads}]"),
            "CHEETAH".into(),
            format!("{:.0} ms", ch_online.as_secs_f64() * 1e3),
            format!("{:.0} ms", ch_prep.offline_time.as_secs_f64() * 1e3),
            fmt_bytes(ch_rep.online_bytes()),
            fmt_bytes(ch_prep.offline_bytes),
            format!(
                "{:.0}x (par {:.2}x)",
                gz_online.as_secs_f64() / ch_online.as_secs_f64().max(1e-9),
                par_speedup
            ),
            ch_rep.ops.map(|o| o.perm).unwrap_or(0).to_string(),
        ]);

        // JSON rows record online *compute* (no wire) for both frameworks —
        // the quantity the thread sweep varies; the printed table shows
        // online totals (compute + modeled wire).
        jt.row(&[
            name.clone(),
            "gazelle".into(),
            gz_rep.params_key(),
            threads.to_string(),
            format!("{:.3}", gz_rep.online_compute().as_secs_f64() * 1e3),
            format!("{:.3}", gz_prep.offline_time.as_secs_f64() * 1e3),
            gz_rep.online_bytes().to_string(),
            gz_prep.offline_bytes.to_string(),
            gz_rep.ops.map(|o| o.perm).unwrap_or(0).to_string(),
            String::new(),
            "1".into(),
            String::new(),
        ]);
        jt.row(&[
            name.clone(),
            "gala".into(),
            ga_rep.params_key(),
            threads.to_string(),
            format!("{:.3}", ga_rep.online_compute().as_secs_f64() * 1e3),
            format!("{:.3}", ga_prep.offline_time.as_secs_f64() * 1e3),
            ga_rep.online_bytes().to_string(),
            ga_prep.offline_bytes.to_string(),
            ga_rep.ops.map(|o| o.perm).unwrap_or(0).to_string(),
            String::new(),
            "1".into(),
            String::new(),
        ]);
        for (thr, rep, prep, speedup) in [
            (1usize, &seq_rep, &seq_prep, String::new()),
            (threads, &ch_rep, &ch_prep, format!("{par_speedup:.3}")),
        ] {
            jt.row(&[
                name.clone(),
                "cheetah".into(),
                rep.params_key(),
                thr.to_string(),
                format!("{:.3}", rep.online_compute().as_secs_f64() * 1e3),
                format!("{:.3}", prep.offline_time.as_secs_f64() * 1e3),
                rep.online_bytes().to_string(),
                prep.offline_bytes.to_string(),
                rep.ops.map(|o| o.perm).unwrap_or(0).to_string(),
                speedup,
                "1".into(),
                String::new(),
            ]);
        }

        // ---- batch-level parallelism: sequential loop vs one fork-join
        // batch over the same prepared deployment (threads stays at N) ----
        if batch > 1 {
            let t0 = Instant::now();
            let loop_reps: Vec<_> = batch_inputs
                .iter()
                .map(|x| ch.infer(x).expect("cheetah loop inference"))
                .collect();
            let loop_wall = t0.elapsed();
            let t1 = Instant::now();
            let batch_reps = ch.infer_batch(&batch_inputs).expect("cheetah batch inference");
            let batch_wall = t1.elapsed();
            for (i, (a, b)) in loop_reps.iter().zip(&batch_reps).enumerate() {
                assert_eq!(
                    a.logits, b.logits,
                    "{name}: batched query {i} diverged bitwise from the sequential loop"
                );
            }
            let loop_qps = batch as f64 / loop_wall.as_secs_f64().max(1e-9);
            let batch_qps = batch as f64 / batch_wall.as_secs_f64().max(1e-9);
            println!(
                "{name}: batch {batch} @ {threads} threads — loop {loop_qps:.2} q/s vs \
                 batch {batch_qps:.2} q/s ({:.2}x)",
                batch_qps / loop_qps.max(1e-9)
            );
            // Each row meters its own run's traffic, so a drift between the
            // loop and batch accounting would show up in the JSON too.
            let loop_bytes: u64 = loop_reps.iter().map(|r| r.online_bytes()).sum();
            let batch_bytes: u64 = batch_reps.iter().map(|r| r.online_bytes()).sum();
            for (fw, wall, qps, bytes) in [
                ("cheetah-loop", loop_wall, loop_qps, loop_bytes),
                ("cheetah-batch", batch_wall, batch_qps, batch_bytes),
            ] {
                jt.row(&[
                    name.clone(),
                    fw.into(),
                    loop_reps[0].params_key(),
                    threads.to_string(),
                    format!("{:.3}", wall.as_secs_f64() * 1e3),
                    String::new(),
                    bytes.to_string(),
                    String::new(),
                    String::new(),
                    String::new(),
                    batch.to_string(),
                    format!("{qps:.3}"),
                ]);
            }
        }

        if args.has("--breakdown") && arch == NetworkArch::Vgg16 {
            let mut bt = Table::new(&[
                "layer",
                "CH server (ms)",
                "CH client (ms)",
                "CH cumul (ms)",
                "CH cumul bytes",
                "GZ cumul (ms)",
            ]);
            let mut cum = 0.0f64;
            let mut cum_b = 0u64;
            let mut gz_cum = 0.0f64;
            for (i, s) in ch_rep.steps.iter().enumerate() {
                cum += (s.server_time + s.client_time).as_secs_f64() * 1e3;
                cum_b += s.c2s_bytes + s.s2c_bytes;
                gz_cum += gz_rep
                    .steps
                    .get(i)
                    .map(|g| g.server_time.as_secs_f64() * 1e3)
                    .unwrap_or(0.0);
                bt.row(&[
                    s.name.clone(),
                    format!("{:.1}", s.server_time.as_secs_f64() * 1e3),
                    format!("{:.1}", s.client_time.as_secs_f64() * 1e3),
                    format!("{cum:.1}"),
                    fmt_bytes(cum_b),
                    format!("{gz_cum:.1}"),
                ]);
            }
            bt.print("Fig. 8 — VGG-16 accumulated per-layer cost");
        }
    }

    // ---- the auto-params cell: netRes through the planner ----
    // netRes's ten-block residual tower overflows the default plaintext
    // modulus, so `ParamsChoice::Auto` must climb the ladder; this cell
    // records which rung it landed on and what the query cost there.
    // Skipped only when `--network` filters it out.
    if net_filter.as_deref().is_none_or(|f| NetworkArch::from_key(f) == Some(NetworkArch::NetRes))
    {
        cheetah::par::set_threads(threads);
        let net = Network::build_scaled(NetworkArch::NetRes, 21, 1.0);
        let name = net.name.clone();
        let input = input_for(&net, 22);
        let mut auto = EngineBuilder::new(Backend::Cheetah)
            .network(net)
            .params(ParamsChoice::Auto)
            .epsilon(0.05)
            .seed(23)
            .build()
            .expect("auto-params cheetah engine");
        let prep = auto.prepare().expect("auto-params offline");
        let rep = auto.infer(&input).expect("auto-params inference");
        let key = rep.params_key();
        assert_ne!(key, "n4096p23", "{name}: the planner must climb past the default rung");
        println!("{name}: auto params selected {key}");
        t.row(&[
            format!("{name} [auto {key}]"),
            "CHEETAH".into(),
            format!("{:.0} ms", rep.online_total().as_secs_f64() * 1e3),
            format!("{:.0} ms", prep.offline_time.as_secs_f64() * 1e3),
            fmt_bytes(rep.online_bytes()),
            fmt_bytes(prep.offline_bytes),
            String::new(),
            rep.ops.map(|o| o.perm).unwrap_or(0).to_string(),
        ]);
        jt.row(&[
            name,
            "cheetah".into(),
            key,
            threads.to_string(),
            format!("{:.3}", rep.online_compute().as_secs_f64() * 1e3),
            format!("{:.3}", prep.offline_time.as_secs_f64() * 1e3),
            rep.online_bytes().to_string(),
            prep.offline_bytes.to_string(),
            rep.ops.map(|o| o.perm).unwrap_or(0).to_string(),
            String::new(),
            "1".into(),
            String::new(),
        ]);
    }

    t.print(
        "Table 7 — end-to-end networks (paper: CHEETAH 218x/334x/130x/140x over GAZELLE)",
    );
    let title = "e2e networks: online/offline per (network, framework, threads, batch)";
    if obs {
        // One snapshot covers the whole run: the span histograms show
        // where time went (phe kernels, protocol phases, par decisions)
        // for every measured cell above.
        let snap = cheetah::obs::snapshot().to_json();
        jt.write_json_with_sections("BENCH_e2e.json", title, &[("obs", snap.as_str())])
            .expect("write BENCH_e2e.json");
        std::fs::write("BENCH_e2e_obs.json", &snap).expect("write BENCH_e2e_obs.json");
        println!("\nwrote BENCH_e2e.json (+obs section) and BENCH_e2e_obs.json");
    } else {
        jt.write_json("BENCH_e2e.json", title).expect("write BENCH_e2e.json");
        println!("\nwrote BENCH_e2e.json");
    }
}
