//! PHE micro-throughput benchmark — the substrate numbers behind every
//! protocol row: NTT/iNTT, `MultPlain` (in-place, the online hot op),
//! `AddPlain`, `Perm`, and the two ways to build an `AddPlain` operand —
//! allocating ([`Context::add_operand_unsigned`]) vs the scratch-arena path
//! the online scoring loop uses (`encode_unsigned_into` → `scale_plain_into`
//! → NTT → `add_plain_raw`), with the arena hit rate reported.
//!
//! Each row times a fixed `iters`-op batch (median of 5 batches after
//! warm-up) so `total_ms` is comfortably above timer/scheduler noise; the
//! CI bench-trend job gates on these rows via `BENCH_phe.json`
//! (`scripts/bench_trend.py --phe`).
//!
//! Run: `cargo bench --bench phe_bench [-- --big-ring]`

use cheetah::bench_util::{time_fn, BenchArgs, Table};
use cheetah::phe::scratch::Arena;
use cheetah::phe::{Context, Encryptor, Evaluator, Form, GaloisKeys, Params};
use cheetah::util::rng::ChaCha20Rng;
use std::sync::Arc;

fn main() {
    let args = BenchArgs::from_env();
    let params = if args.has("--big-ring") { Params::big_ring() } else { Params::default_params() };
    let ctx = Arc::new(Context::new(params));
    let n = ctx.params.n;
    let mut rng = ChaCha20Rng::from_u64_seed(5);
    let enc = Encryptor::new(ctx.clone(), &mut rng);
    let ev = Evaluator::new(ctx.clone());
    let gk = GaloisKeys::generate_default(&ctx, &enc.sk, &mut rng);

    let vals: Vec<i64> = (0..n as i64).map(|i| i % 251 - 125).collect();
    let residues: Vec<u64> = (0..n as u64).map(|i| (i * 7919) % ctx.params.p).collect();
    let mut ct = enc.encrypt_slots(&vals, &mut rng);
    ev.to_ntt(&mut ct);
    let mult_op = ctx.mult_operand(&vals);
    let add_op = ctx.add_operand(&vals);
    let mut poly = ctx.sample_uniform_ntt(&mut rng);
    let arena = Arena::new();
    arena.reserve(&ctx.params, 2);

    let mut t = Table::new(&["op", "n", "iters", "total_ms", "per_op_us", "arena_hit_rate"]);
    // The hit-rate column is populated only by the dedicated `arena` row
    // appended after the timed rows (it isn't known until they have run).
    let mut bench = |op: &str, iters: usize, f: &mut dyn FnMut()| {
        let m = time_fn(1, 5, || {
            for _ in 0..iters {
                f();
            }
        });
        t.row(&[
            op.into(),
            n.to_string(),
            iters.to_string(),
            format!("{:.3}", m.millis()),
            format!("{:.3}", m.micros() / iters as f64),
            String::new(),
        ]);
        println!(
            "{op:<18} {iters:>6} iters  {:>10.3} ms total  {:>8.3} us/op",
            m.millis(),
            m.micros() / iters as f64
        );
    };

    bench("ntt_forward", 200, &mut || {
        ctx.to_coeff(&mut poly);
        ctx.to_ntt(&mut poly);
        std::hint::black_box(&poly);
    });
    // Output ciphertexts are hoisted and reused so the timed loops measure
    // the op, not allocator traffic (the regression gate must not trip on
    // allocator variance across shared CI runners).
    let mut mult_out = ct.clone();
    bench("mult_plain_into", 200, &mut || {
        ev.mult_plain_into(&ct, &mult_op, &mut mult_out);
        std::hint::black_box(&mult_out);
    });
    let mut add_acc = ct.clone();
    bench("add_plain", 2000, &mut || {
        ev.add_plain(&mut add_acc, &add_op);
        std::hint::black_box(&add_acc);
    });
    bench("perm", 10, &mut || {
        let _ = std::hint::black_box(ev.rotate_rows(&ct, 1, &gk));
    });
    bench("add_operand_alloc", 200, &mut || {
        let _ = std::hint::black_box(ctx.add_operand_unsigned(&residues));
    });
    // The online path's operand build: fully scratch-backed, then applied
    // with add_plain_raw — zero allocations once the arena is warm.
    let mut scratch_ct = ct.clone();
    bench("add_operand_scratch", 200, &mut || {
        let mut pt = arena.plain(n);
        ctx.encoder.encode_unsigned_into(&residues, &mut pt);
        let mut p = arena.poly(&ctx.params, Form::Coeff);
        ctx.scale_plain_into(&pt, &mut p);
        ctx.to_ntt(&mut p);
        ev.add_plain_raw(&mut scratch_ct, &p);
        std::hint::black_box(&*p);
    });
    // Re-emit the scratch row's hit rate as its own row so the JSON carries
    // it without re-timing (the table closure can't know it in advance).
    let stats = arena.stats();
    t.row(&[
        "arena".into(),
        n.to_string(),
        stats.checkouts.to_string(),
        String::new(),
        String::new(),
        format!("{:.4}", stats.hit_rate()),
    ]);
    println!(
        "arena: {} checkouts, {} fresh allocs (hit rate {:.4})",
        stats.checkouts,
        stats.fresh_allocs,
        stats.hit_rate()
    );

    t.print(&format!("PHE micro-throughput — n={}, q≈2^{}", n, ctx.params.q_bits()));
    t.write_json("BENCH_phe.json", "phe micro-ops: batch totals per (op, n, iters)")
        .expect("write BENCH_phe.json");
    println!("\nwrote BENCH_phe.json");
}
