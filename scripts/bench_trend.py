#!/usr/bin/env python3
"""Bench-trend gate: compare BENCH_*.json files and fail on regression.

Usage:
    bench_trend.py PREVIOUS.json CURRENT.json [--max-regression 0.15]
                   [--phe PREV_PHE.json CURR_PHE.json]
                   [--serve PREV_SERVE.json CURR_SERVE.json]
                   [--micro PREV_MICRO.json CURR_MICRO.json]

The JSON layout is what `bench_util::Table::write_json` emits: a `headers`
list and `rows` of {header: string-cell} objects.

Four schemas are gated:

* e2e (positional args): rows keyed by (network, framework, params, threads,
  batch); `params` defaults to "n4096p23" for artifacts that predate the
  parameter planner
  — `batch` is absent in pre-batch-PR artifacts and defaults to "1" — and
  the gated metric is `online_ms` (whole-batch wall ms for the
  cheetah-loop/cheetah-batch rows, per-query online compute otherwise).
* phe (`--phe` pair): rows keyed by (op, n, iters), gated on `total_ms`
  (a fixed-size op batch, sized above the noise floor). Rows with an empty
  metric cell (the arena hit-rate row) are informational and skipped.
* serve (`--serve` pair): rows keyed by (sessions, mode, pool_depth,
  batch, net_sessions) — `mode` defaults to "threads" and `net_sessions`
  to "1" for artifacts predating the reactor PR, so the thread-front rows
  stay comparable across the schema change — gated on `query_p50_ms` (the
  server-side online latency; the sessions=1000 reactor row is the C10K
  measuring stick).
* micro (`--micro` pair): rows keyed by (op, variant), gated on the
  counted `perm` column with **zero tolerance** — op counts are exact
  integers, not timings, so any increase is a real algorithmic regression
  and fails regardless of `--max-regression` (no noise exemption either).

Exit codes: 0 pass / skipped (no previous artifact for that pair — first
run on a branch, or an older artifact predating the phe bench); 1
regression beyond the threshold or zero comparable e2e rows (a schema/key
rename must not silently disable the gate); 2 malformed input.

Noise guard: CI runners are shared machines, so rows faster than
MIN_ABS_MS in *both* runs are reported but never gate.

Forward compatibility: rows are read by *named* column, and only the keys
named above participate, so new columns (e.g. serve_bench `--stats`'s
`pool_occ`/`query_p99_ms`) and extra top-level sections (e.g. the `obs`
snapshot `e2e_bench --obs` embeds) are ignored without any flag.
"""

import argparse
import json
import os
import sys

MIN_ABS_MS = 5.0  # sub-5ms cells are timer noise on shared runners


def load_rows(path):
    with open(path, "r", encoding="utf-8") as f:
        doc = json.load(f)
    if "rows" not in doc or "headers" not in doc:
        print(f"error: {path} is not a bench_util Table JSON", file=sys.stderr)
        sys.exit(2)
    return doc["rows"]


def e2e_key(row):
    # `params` arrived with the parameter planner; older artifacts predate
    # the column, so absent/empty values default to the historical set.
    return (
        row.get("network", ""),
        row.get("framework", ""),
        row.get("params", "n4096p23") or "n4096p23",
        row.get("threads", ""),
        row.get("batch", "1") or "1",
    )


def phe_key(row):
    return (row.get("op", ""), row.get("n", ""), row.get("iters", ""))


def serve_key(row):
    return (
        row.get("sessions", ""),
        row.get("mode", "threads") or "threads",
        row.get("pool_depth", ""),
        row.get("batch", ""),
        row.get("net_sessions", "1") or "1",
    )


def micro_key(row):
    return (row.get("op", ""), row.get("variant", ""))


def metric_of(row, field):
    cell = row.get(field, "")
    try:
        return float(cell)
    except ValueError:
        return None


def compare(label, prev_path, curr_path, key_fn, metric_field, max_regression):
    """Returns (compared_row_count, regression_list) or None when the
    previous artifact is missing (skip, not failure)."""
    if not os.path.exists(prev_path):
        print(f"[{label}] no previous artifact at {prev_path} — skipping trend gate")
        return None
    if not os.path.exists(curr_path):
        print(f"error: current artifact {curr_path} missing", file=sys.stderr)
        sys.exit(2)

    prev = {key_fn(r): metric_of(r, metric_field) for r in load_rows(prev_path)}
    curr = {key_fn(r): metric_of(r, metric_field) for r in load_rows(curr_path)}

    regressions = []
    compared = 0
    for key, now in sorted(curr.items()):
        before = prev.get(key)
        if before is None or now is None or before <= 0.0:
            continue
        compared += 1
        ratio = now / before
        marker = ""
        if ratio > 1.0 + max_regression:
            if before < MIN_ABS_MS and now < MIN_ABS_MS:
                marker = "  (noise-exempt: sub-5ms cell)"
            else:
                marker = "  << REGRESSION"
                regressions.append((key, before, now, ratio))
        print(
            f"[{label}] {'/'.join(key):40s} {before:10.3f} ms -> {now:10.3f} ms"
            f"  ({ratio:5.2f}x){marker}"
        )
    return compared, regressions


def compare_exact(label, prev_path, curr_path, key_fn, metric_field):
    """Zero-tolerance integer gate: any increase in the counted metric is a
    regression (no ratio threshold, no noise floor). Returns
    (compared_row_count, regression_list) or None when the previous
    artifact is missing."""
    if not os.path.exists(prev_path):
        print(f"[{label}] no previous artifact at {prev_path} — skipping trend gate")
        return None
    if not os.path.exists(curr_path):
        print(f"error: current artifact {curr_path} missing", file=sys.stderr)
        sys.exit(2)

    prev = {key_fn(r): metric_of(r, metric_field) for r in load_rows(prev_path)}
    curr = {key_fn(r): metric_of(r, metric_field) for r in load_rows(curr_path)}

    regressions = []
    compared = 0
    for key, now in sorted(curr.items()):
        before = prev.get(key)
        if before is None or now is None:
            continue
        compared += 1
        marker = ""
        if now > before:
            marker = "  << REGRESSION"
            ratio = now / before if before > 0 else float("inf")
            regressions.append((key, before, now, ratio))
        print(
            f"[{label}] {'/'.join(key):40s} {before:10.0f}    -> {now:10.0f}   {marker}"
        )
    return compared, regressions


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("previous")
    ap.add_argument("current")
    ap.add_argument(
        "--max-regression",
        type=float,
        default=0.15,
        help="fail when a gated metric exceeds the previous run by this fraction",
    )
    ap.add_argument(
        "--phe",
        nargs=2,
        metavar=("PREV_PHE", "CURR_PHE"),
        help="additionally gate a BENCH_phe.json pair keyed by (op, n, iters)",
    )
    ap.add_argument(
        "--serve",
        nargs=2,
        metavar=("PREV_SERVE", "CURR_SERVE"),
        help="additionally gate a BENCH_serve.json pair keyed by "
        "(sessions, mode, pool_depth, batch, net_sessions)",
    )
    ap.add_argument(
        "--micro",
        nargs=2,
        metavar=("PREV_MICRO", "CURR_MICRO"),
        help="additionally gate a BENCH_micro.json pair keyed by "
        "(op, variant): exact integer `perm` counts, zero tolerance",
    )
    args = ap.parse_args()

    failures = []

    e2e = compare("e2e", args.previous, args.current, e2e_key, "online_ms", args.max_regression)
    if e2e is not None:
        compared, regressions = e2e
        if compared == 0:
            # Both artifacts exist but share no (key, metric) rows: almost
            # certainly a schema/key rename. Fail loudly rather than leaving
            # the gate permanently green-but-dead; the run after the rename
            # lands on main compares new-vs-new and goes green again.
            print(
                "error: e2e artifacts share zero comparable rows — schema or "
                "key rename? The trend gate would otherwise be silently "
                "disabled.",
                file=sys.stderr,
            )
            return 1
        failures.extend(("e2e", *r) for r in regressions)

    if args.phe:
        phe = compare("phe", args.phe[0], args.phe[1], phe_key, "total_ms", args.max_regression)
        if phe is not None:
            compared, regressions = phe
            if compared == 0:
                # A previous artifact predating the phe bench is already a
                # skip (missing file, handled inside compare). Both files
                # existing but sharing zero keys is a schema/op rename —
                # fail loudly, same policy as the e2e gate.
                print(
                    "error: phe artifacts share zero comparable rows — "
                    "schema or op rename? The trend gate would otherwise "
                    "be silently disabled.",
                    file=sys.stderr,
                )
                return 1
            failures.extend(("phe", *r) for r in regressions)

    if args.serve:
        serve = compare(
            "serve",
            args.serve[0],
            args.serve[1],
            serve_key,
            "query_p50_ms",
            args.max_regression,
        )
        if serve is not None:
            compared, regressions = serve
            if compared == 0:
                # The serve_key defaults keep pre-reactor artifacts (no
                # `mode`/`net_sessions` columns) comparable on their
                # thread-front rows, so zero overlap means a schema or
                # key rename — fail loudly, same policy as the e2e gate.
                print(
                    "error: serve artifacts share zero comparable rows — "
                    "schema or key rename? The trend gate would otherwise "
                    "be silently disabled.",
                    file=sys.stderr,
                )
                return 1
            failures.extend(("serve", *r) for r in regressions)

    if args.micro:
        micro = compare_exact("micro", args.micro[0], args.micro[1], micro_key, "perm")
        if micro is not None:
            compared, regressions = micro
            if compared == 0:
                # Same policy as the other gates: both files existing but
                # sharing zero (op, variant) keys is a rename, and the
                # count gate must not go silently dead.
                print(
                    "error: micro artifacts share zero comparable rows — "
                    "schema or key rename? The trend gate would otherwise "
                    "be silently disabled.",
                    file=sys.stderr,
                )
                return 1
            failures.extend(("micro", *r) for r in regressions)

    if failures:
        print(
            f"\nFAIL: {len(failures)} row(s) regressed more than "
            f"{args.max_regression:.0%}:",
            file=sys.stderr,
        )
        for label, key, before, now, ratio in failures:
            unit = "" if label == "micro" else " ms"
            print(
                f"  [{label}] {'/'.join(key)}: {before:.3f}{unit} -> "
                f"{now:.3f}{unit} ({ratio:.2f}x)",
                file=sys.stderr,
            )
        return 1
    print("\nOK: no gated row beyond the threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
