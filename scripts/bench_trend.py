#!/usr/bin/env python3
"""Bench-trend gate: compare two BENCH_e2e.json files and fail on regression.

Usage:
    bench_trend.py PREVIOUS.json CURRENT.json [--max-regression 0.15]

The JSON layout is what `bench_util::Table::write_json` emits: a `headers`
list and `rows` of {header: string-cell} objects. Rows are keyed by
(network, framework, threads, batch) — `batch` is absent in pre-batch-PR
artifacts and defaults to "1" — and the gated metric is `online_ms`
(whole-batch wall ms for the cheetah-loop/cheetah-batch rows, per-query
online compute otherwise).

Exit codes: 0 pass / skipped (no previous artifact, so nothing to compare
against — first run on a branch); 1 regression beyond the threshold or
zero comparable rows (a schema/key rename must not silently disable the
gate); 2 malformed input.

Noise guard: CI runners are shared machines, so rows faster than
MIN_ABS_MS in *both* runs are reported but never gate.
"""

import argparse
import json
import os
import sys

MIN_ABS_MS = 5.0  # sub-5ms cells are timer noise on shared runners


def load_rows(path):
    with open(path, "r", encoding="utf-8") as f:
        doc = json.load(f)
    if "rows" not in doc or "headers" not in doc:
        print(f"error: {path} is not a bench_util Table JSON", file=sys.stderr)
        sys.exit(2)
    return doc["rows"]


def key_of(row):
    return (
        row.get("network", ""),
        row.get("framework", ""),
        row.get("threads", ""),
        row.get("batch", "1") or "1",
    )


def metric_of(row):
    cell = row.get("online_ms", "")
    try:
        return float(cell)
    except ValueError:
        return None


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("previous")
    ap.add_argument("current")
    ap.add_argument(
        "--max-regression",
        type=float,
        default=0.15,
        help="fail when current online_ms exceeds previous by this fraction",
    )
    args = ap.parse_args()

    if not os.path.exists(args.previous):
        print(f"no previous artifact at {args.previous} — skipping trend gate")
        return 0
    if not os.path.exists(args.current):
        print(f"error: current artifact {args.current} missing", file=sys.stderr)
        return 2

    prev = {key_of(r): metric_of(r) for r in load_rows(args.previous)}
    curr = {key_of(r): metric_of(r) for r in load_rows(args.current)}

    regressions = []
    compared = 0
    for key, now in sorted(curr.items()):
        before = prev.get(key)
        if before is None or now is None or before <= 0.0:
            continue
        compared += 1
        ratio = now / before
        marker = ""
        if ratio > 1.0 + args.max_regression:
            if before < MIN_ABS_MS and now < MIN_ABS_MS:
                marker = "  (noise-exempt: sub-5ms cell)"
            else:
                marker = "  << REGRESSION"
                regressions.append((key, before, now, ratio))
        print(
            f"{'/'.join(key):40s} {before:10.3f} ms -> {now:10.3f} ms"
            f"  ({ratio:5.2f}x){marker}"
        )

    if compared == 0:
        # Both artifacts exist but share no (key, metric) rows: almost
        # certainly a schema/key rename. Fail loudly rather than leaving
        # the gate permanently green-but-dead; the run after the rename
        # lands on main compares new-vs-new and goes green again.
        print(
            "error: artifacts share zero comparable rows — schema or key "
            "rename? The trend gate would otherwise be silently disabled.",
            file=sys.stderr,
        )
        return 1
    if regressions:
        print(
            f"\nFAIL: {len(regressions)} row(s) regressed more than "
            f"{args.max_regression:.0%} in online compute:",
            file=sys.stderr,
        )
        for key, before, now, ratio in regressions:
            print(
                f"  {'/'.join(key)}: {before:.3f} ms -> {now:.3f} ms ({ratio:.2f}x)",
                file=sys.stderr,
            )
        return 1
    print(f"\nOK: {compared} row(s) compared, none beyond the threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
