"""L2 — JAX forward/backward graphs of the benchmark networks.

Two forward paths:

* ``forward_float`` — plain float inference (training & reference),
* ``forward_noisy`` — the paper's Fig. 7 experiment: quantized weights and
  activations per the Rust ``ScalePlan`` (x: 2^7, k: 2^6) with uniform
  noise ``δ ~ U[-ε, ε]`` added to every linear output and the CHEETAH
  requantization applied after every ReLU. The block-sum and recovery
  hot-spots route through the L1 Pallas kernels so the whole stack lowers
  into one HLO module.

Training is a tiny SGD-with-momentum loop on the synthetic-digits corpus;
``aot.py`` runs it at build time and bakes the weights into the exported
HLO as constants (the Rust runtime only feeds images + noise keys).
"""

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.obscure import relu_recover
from .kernels.ref import relu_recover_ref

# Mirrors rust/src/fixed/mod.rs ScalePlan::default_plan().
X_SCALE = 2.0**7
K_SCALE = 2.0**6
Y_SCALE = 2.0**6
X_MAX = 2.0
Y_MAX = 3.0

# Network A (DeepSecure): conv 5×5@5/s2 + fc100 + fc10.
# Network B (MiniONN): conv 5×5@16 + pool + conv 5×5@16 + pool + fc100 + fc10.
ARCHS = {
    "netA": {
        "conv": [(5, 5, 2, 2)],  # (out_ch, kernel, stride, pad)
        "fc": [100, 10],
        "pool_after_conv": [False],
    },
    "netB": {
        "conv": [(16, 5, 1, 2), (16, 5, 1, 2)],
        "fc": [100, 10],
        "pool_after_conv": [True, True],
    },
}


def init_params(arch: str, size: int, key):
    cfg = ARCHS[arch]
    params = []
    c_in, h, w = 1, size, size
    for (c_out, k, stride, pad), pool in zip(cfg["conv"], cfg["pool_after_conv"]):
        key, sub = jax.random.split(key)
        fan_in = c_in * k * k
        wconv = jax.random.uniform(
            sub, (c_out, c_in, k, k), minval=-1.0, maxval=1.0
        ) * np.sqrt(2.0 / fan_in)
        params.append(wconv)
        h = (h + 2 * pad - k) // stride + 1
        w = (w + 2 * pad - k) // stride + 1
        if pool:
            h //= 2
            w //= 2
        c_in = c_out
    n_in = c_in * h * w
    for n_out in cfg["fc"]:
        key, sub = jax.random.split(key)
        wfc = jax.random.uniform(sub, (n_out, n_in), minval=-1.0, maxval=1.0) * np.sqrt(
            2.0 / n_in
        )
        params.append(wfc)
        n_in = n_out
    return params


def _conv(x, w, stride, pad):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), [(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )


def _mean_pool(x):
    return jax.lax.reduce_window(
        x, 0.0, jax.lax.add, (1, 1, 2, 2), (1, 1, 2, 2), "VALID"
    ) / 4.0


def forward_float(arch: str, params, x):
    """Plain float forward pass: x (B,1,H,W) → logits (B,10)."""
    cfg = ARCHS[arch]
    i = 0
    for (_, _, stride, pad), pool in zip(cfg["conv"], cfg["pool_after_conv"]):
        x = jax.nn.relu(_conv(x, params[i], stride, pad))
        if pool:
            x = _mean_pool(x)
        i += 1
    x = x.reshape(x.shape[0], -1)
    for j, _n_out in enumerate(cfg["fc"]):
        x = x @ params[i].T
        if j + 1 < len(cfg["fc"]):
            x = jax.nn.relu(x)
        i += 1
    return x


def _quant(v, scale, vmax):
    return jnp.round(jnp.clip(v, -vmax, vmax) * scale) / scale


def _relu_requant(pre, key, eps, use_pallas):
    """ReLU with the paper's δ-noise and CHEETAH's two-step requantization
    (linear-output scale → y-scale → activation scale), with the recovery
    arithmetic routed through the L1 kernel."""
    noise = jax.random.uniform(key, pre.shape, minval=-eps, maxval=eps)
    noisy = pre + noise
    # y at Y_SCALE, clamped at ±Y_MAX (the client's view, v=1 w.l.o.g. —
    # blinds are exact powers of two so they cancel bit-for-bit).
    y = jnp.round(jnp.clip(noisy, -Y_MAX, Y_MAX) * Y_SCALE)
    flat = y.reshape(-1)
    pad = (-flat.shape[0]) % 256
    flat = jnp.pad(flat, (0, pad))
    id1 = jnp.zeros_like(flat)
    id2 = jnp.ones_like(flat)  # v=+1 → (ID1, ID2) = (0, 1)
    rec = (
        relu_recover(flat, id1, id2)
        if use_pallas
        else relu_recover_ref(flat, id1, id2)
    )
    rec = rec[: y.size].reshape(y.shape)
    # Back to activation scale, clamped to the representable range.
    return jnp.clip(rec / Y_SCALE, 0.0, X_MAX)


def forward_noisy(arch: str, params, x, key, eps, use_pallas=True):
    """Quantized + δ-noised forward pass (the Fig. 7 measurement path)."""
    cfg = ARCHS[arch]
    qp = [_quant(p, K_SCALE, X_MAX) for p in params]
    x = _quant(x, X_SCALE, X_MAX)
    i = 0
    for (_, _, stride, pad), pool in zip(cfg["conv"], cfg["pool_after_conv"]):
        key, sub = jax.random.split(key)
        pre = _conv(x, qp[i], stride, pad)
        x = _relu_requant(pre, sub, eps, use_pallas)
        if pool:
            x = _mean_pool(x)
        x = _quant(x, X_SCALE, X_MAX)
        i += 1
    x = x.reshape(x.shape[0], -1)
    for j, _n_out in enumerate(cfg["fc"]):
        key, sub = jax.random.split(key)
        pre = x @ qp[i].T
        if j + 1 < len(cfg["fc"]):
            x = _quant(_relu_requant(pre, sub, eps, use_pallas), X_SCALE, X_MAX)
        else:
            noise = jax.random.uniform(sub, pre.shape, minval=-eps, maxval=eps)
            x = pre + noise
        i += 1
    return x


@partial(jax.jit, static_argnames=("arch",))
def _loss(arch, params, x, y):
    logits = forward_float(arch, params, x)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(logp[jnp.arange(y.shape[0]), y])


def train(arch: str, size: int, steps: int = 300, batch_size: int = 256, seed: int = 0):
    """SGD-with-momentum training on synthetic digits. Returns (params,
    train-accuracy, test-accuracy)."""
    from . import digits

    key = jax.random.PRNGKey(seed)
    params = init_params(arch, size, key)
    xs, ys = digits.batch(size, batch_size * 4, seed=1000 + seed)
    xt, yt = digits.batch(size, 500, seed=2000 + seed)
    xs_j, ys_j = jnp.asarray(xs), jnp.asarray(ys)

    grad_fn = jax.jit(jax.grad(_loss, argnums=1), static_argnames=("arch",))
    momentum = [jnp.zeros_like(p) for p in params]
    lr, beta = 0.08, 0.9
    n = xs.shape[0]
    for step in range(steps):
        lo = (step * batch_size) % n
        xb = xs_j[lo : lo + batch_size]
        yb = ys_j[lo : lo + batch_size]
        grads = grad_fn(arch, params, xb, yb)
        momentum = [beta * m + g for m, g in zip(momentum, grads)]
        params = [p - lr * m for p, m in zip(params, momentum)]

    def acc(xv, yv):
        logits = forward_float(arch, params, jnp.asarray(xv))
        return float(jnp.mean(jnp.argmax(logits, axis=1) == jnp.asarray(yv)))

    params = equalize(arch, params, jnp.asarray(xt[:64]))
    return params, acc(xs, ys), acc(xt, yt)


def equalize(arch: str, params, calib_x, target: float = 1.2):
    """Activation equalization: rescale each hidden layer so calibration
    activations stay within `target` (the protocol's clamp-safe range,
    X_MAX·y_max margins) and push the inverse factor into the next layer —
    function-preserving by ReLU positive homogeneity (the final logits get
    one uniform positive factor; argmax unchanged). Mirrors
    `runtime::equalize_activations` on the Rust side."""
    cfg = ARCHS[arch]
    params = [p for p in params]
    n_linear = len(cfg["conv"]) + len(cfg["fc"])
    for i in range(n_linear - 1):
        # Forward through layers 0..=i.
        x = calib_x
        j = 0
        for (_, _, stride, pad), pool in zip(cfg["conv"], cfg["pool_after_conv"]):
            if j > i:
                break
            x = jax.nn.relu(_conv(x, params[j], stride, pad))
            if pool:
                x = _mean_pool(x)
            j += 1
        if j <= i:
            x = x.reshape(x.shape[0], -1)
            while j <= i:
                x = jax.nn.relu(x @ params[j].T)
                j += 1
        m = float(jnp.max(jnp.abs(x)))
        if m > 0:
            # Normalize up as well as down: small activations waste
            # fixed-point resolution (quantization SNR), large ones clamp.
            s = target / m
            params[i] = params[i] * s
            params[i + 1] = params[i + 1] / s
    return params
