"""Synthetic-digits corpus — a faithful Python port of
``rust/src/nn/dataset.rs`` (same 5×7 glyph font, same jitter model, same
SplitMix64 generator) so the JAX-trained weights see the same distribution
the Rust evaluation pipeline renders.
"""

import numpy as np

FONT = [
    [0b01110, 0b10001, 0b10011, 0b10101, 0b11001, 0b10001, 0b01110],
    [0b00100, 0b01100, 0b00100, 0b00100, 0b00100, 0b00100, 0b01110],
    [0b01110, 0b10001, 0b00001, 0b00010, 0b00100, 0b01000, 0b11111],
    [0b11111, 0b00010, 0b00100, 0b00010, 0b00001, 0b10001, 0b01110],
    [0b00010, 0b00110, 0b01010, 0b10010, 0b11111, 0b00010, 0b00010],
    [0b11111, 0b10000, 0b11110, 0b00001, 0b00001, 0b10001, 0b01110],
    [0b00110, 0b01000, 0b10000, 0b11110, 0b10001, 0b10001, 0b01110],
    [0b11111, 0b00001, 0b00010, 0b00100, 0b01000, 0b01000, 0b01000],
    [0b01110, 0b10001, 0b10001, 0b01110, 0b10001, 0b10001, 0b01110],
    [0b01110, 0b10001, 0b10001, 0b01111, 0b00001, 0b00010, 0b01100],
]

MASK64 = (1 << 64) - 1


class SplitMix64:
    """Bit-exact port of rust/src/util/rng.rs::SplitMix64."""

    def __init__(self, seed: int):
        self.state = seed & MASK64

    def next_u64(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & MASK64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
        return z ^ (z >> 31)

    def next_f64(self) -> float:
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def gen_range_f64(self, lo: float, hi: float) -> float:
        return lo + self.next_f64() * (hi - lo)


def render(size: int, label: int, rng: SplitMix64) -> np.ndarray:
    """Render one digit; mirrors SyntheticDigits::render exactly."""
    glyph = FONT[label]
    scale = size * 0.6 / 7.0
    margin = size * 0.06
    ox = rng.gen_range_f64(-margin, margin) + size * 0.25
    oy = rng.gen_range_f64(-margin, margin) + size * 0.15
    amp = rng.gen_range_f64(0.75, 1.0)
    noise_lvl = rng.gen_range_f64(0.02, 0.08)
    img = np.zeros((size, size), dtype=np.float64)
    for y in range(size):
        for x in range(size):
            gy = (y - oy) / scale
            gx = (x - ox) / (scale * 5.0 / 7.0 * 1.4)
            v = 0.0
            if 0.0 <= gy < 7.0 and 0.0 <= gx < 5.0:
                row = glyph[int(gy)]
                bit = 4 - int(gx)
                if (row >> bit) & 1:
                    v = amp
            v += rng.gen_range_f64(-noise_lvl, noise_lvl)
            img[y, x] = min(max(v, 0.0), 1.0)
    return img


def batch(size: int, count: int, seed: int):
    """Balanced batch (round-robin labels), mirroring SyntheticDigits::batch."""
    rng = SplitMix64(seed)
    xs = np.zeros((count, 1, size, size), dtype=np.float32)
    ys = np.zeros((count,), dtype=np.int32)
    for i in range(count):
        label = i % 10
        xs[i, 0] = render(size, label, rng)
        ys[i] = label
    return xs, ys
