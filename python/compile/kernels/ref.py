"""Pure-jnp oracles for the L1 Pallas kernels (the correctness pins).

These are the definitions; the Pallas kernels must match them bit-for-bit
on integer inputs (hypothesis sweeps shapes/dtypes in python/tests), and
the Rust client's hot loops must match them on golden vectors.
"""

import jax.numpy as jnp


def obscure_dot_ref(prods):
    """Block sums of the decrypted obscured products (paper §3.1 step 3)."""
    return jnp.sum(prods, axis=1)


def relu_recover_ref(y, id1, id2):
    """Polar-indicator recovery (paper Eq. 6): ID1∘y + ID2∘ReLU(y)."""
    return id1 * y + id2 * jnp.maximum(y, 0)


def client_y_pair_ref(y_sum, shift, clamp):
    """Requantize the block sums to the y-scale and clamp (mirror of the
    Rust ``client_y_pair``): round-half-up shift then clamp."""
    half = 1 << (shift - 1)
    y = (y_sum + half) >> shift
    y = jnp.clip(y, -clamp, clamp)
    return y, jnp.maximum(y, 0)
