"""L1 Pallas kernels for CHEETAH's client-side hot loops.

Two kernels, both lowered with ``interpret=True`` (CPU PJRT cannot run
Mosaic custom-calls; see /opt/xla-example/README.md):

* ``obscure_dot`` — the per-block reduction of the decrypted obscured
  products: given the slot stream ``prods = x' ∘ k' ∘ v + b`` reshaped to
  ``(n_blocks, block)``, produce the block sums ``y[i] = Σ_t prods[i, t]``.
  This is the plaintext sum that replaces GAZELLE's rotate-and-sum
  (paper §3.1 step 3) and the exact mirror of the Rust client's
  ``block_sums`` hot loop.

* ``relu_recover`` — the polar-indicator recovery (paper Eq. 6):
  ``out = id1 ∘ y + id2 ∘ relu(y)`` over requantized ``y``.

TPU adaptation (DESIGN.md §Hardware-Adaptation): the grid walks blocks of
rows so each (TILE_B × block) tile sits in VMEM; the reduction maps onto
the VPU lanes. ``block`` is padded to the 128-lane boundary by the caller.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows of the (n_blocks, block) matrix handled per grid step.
TILE_B = 256


def _obscure_dot_kernel(prods_ref, out_ref):
    """Sum each row of a (TILE_B, block) tile."""
    out_ref[...] = jnp.sum(prods_ref[...], axis=1)


@partial(jax.jit, static_argnames=("interpret",))
def obscure_dot(prods, interpret=True):
    """Block sums: prods (n_blocks, block) int32/float32 → (n_blocks,).

    n_blocks must be a multiple of TILE_B (callers pad; aot.py exports the
    padded shape).
    """
    n_blocks, block = prods.shape
    assert n_blocks % TILE_B == 0, f"n_blocks {n_blocks} % {TILE_B} != 0"
    grid = (n_blocks // TILE_B,)
    return pl.pallas_call(
        _obscure_dot_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((TILE_B, block), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((TILE_B,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_blocks,), prods.dtype),
        interpret=interpret,
    )(prods)


def _relu_recover_kernel(y_ref, id1_ref, id2_ref, out_ref):
    """Polar-indicator recovery on one tile (Eq. 6)."""
    y = y_ref[...]
    relu_y = jnp.maximum(y, 0)
    out_ref[...] = id1_ref[...] * y + id2_ref[...] * relu_y


@partial(jax.jit, static_argnames=("interpret",))
def relu_recover(y, id1, id2, interpret=True):
    """Recovery: all inputs (n,), n a multiple of TILE_B·... (padded)."""
    (n,) = y.shape
    assert n % TILE_B == 0
    grid = (n // TILE_B,)
    spec = pl.BlockSpec((TILE_B,), lambda i: (i,))
    return pl.pallas_call(
        _relu_recover_kernel,
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n,), y.dtype),
        interpret=interpret,
    )(y, id1, id2)
