"""AOT compile path: train the benchmark networks, lower the L2 graphs
(with L1 Pallas kernels inlined) to HLO **text**, and write everything to
``artifacts/``. Runs once at build time (``make artifacts``); Python is
never on the request path.

HLO text — not ``.serialize()`` — is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
(the version the Rust ``xla`` crate binds) rejects; the text parser
reassigns ids. See /opt/xla-example/README.md.

Artifacts:
  netA_noisy.hlo.txt / netB_noisy.hlo.txt — Fig. 7 accuracy path:
      fn(images f32[B,1,S,S], key u32[2], eps f32[]) -> logits f32[B,10]
      with trained weights baked in as constants.
  netA_weights.bin / netB_weights.bin — trained weights (f32 LE, concat),
      consumed by the Rust serving path (examples/serve_mlaas).
  obscure_dot.hlo.txt — the L1 block-sum kernel as a standalone module
      (int32 (1024, 32) → (1024,)), cross-checked by the Rust runtime.
  relu_recover.hlo.txt — the L1 recovery kernel ((1024,)×3 → (1024,)).
  manifest.txt — shapes + training metrics for every artifact.
"""

import argparse
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .kernels.obscure import obscure_dot, relu_recover
from .model import ARCHS, forward_noisy, train

BATCH = 32
SIZE = 28


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def export_net(arch: str, params, out_dir: str, manifest):
    def fn(x, key, eps):
        return (forward_noisy(arch, params, x, key, eps, use_pallas=True),)

    x_spec = jax.ShapeDtypeStruct((BATCH, 1, SIZE, SIZE), jnp.float32)
    key_spec = jax.ShapeDtypeStruct((2,), jnp.uint32)
    eps_spec = jax.ShapeDtypeStruct((), jnp.float32)
    lowered = jax.jit(fn).lower(x_spec, key_spec, eps_spec)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{arch}_noisy.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    manifest.append(f"{arch}_noisy.hlo.txt inputs=f32[{BATCH},1,{SIZE},{SIZE}],u32[2],f32[] outputs=f32[{BATCH},10]")

    # Raw weights for the Rust serving path.
    flat = np.concatenate([np.asarray(p, dtype=np.float32).reshape(-1) for p in params])
    wpath = os.path.join(out_dir, f"{arch}_weights.bin")
    flat.tofile(wpath)
    shapes = ";".join("x".join(str(d) for d in p.shape) for p in params)
    manifest.append(f"{arch}_weights.bin f32le shapes={shapes}")


def export_kernels(out_dir: str, manifest):
    # obscure_dot: (1024, 32) int32 → (1024,)
    spec = jax.ShapeDtypeStruct((1024, 32), jnp.int32)
    lowered = jax.jit(lambda p: (obscure_dot(p),)).lower(spec)
    with open(os.path.join(out_dir, "obscure_dot.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))
    manifest.append("obscure_dot.hlo.txt inputs=i32[1024,32] outputs=i32[1024]")

    vspec = jax.ShapeDtypeStruct((1024,), jnp.int32)
    lowered = jax.jit(lambda y, a, b: (relu_recover(y, a, b),)).lower(vspec, vspec, vspec)
    with open(os.path.join(out_dir, "relu_recover.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))
    manifest.append("relu_recover.hlo.txt inputs=i32[1024]x3 outputs=i32[1024]")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    manifest = []

    export_kernels(args.out, manifest)
    print("kernels exported", flush=True)

    for arch in ARCHS:
        params, train_acc, test_acc = train(arch, SIZE, steps=args.steps)
        print(f"{arch}: train_acc={train_acc:.3f} test_acc={test_acc:.3f}", flush=True)
        if test_acc < 0.8:
            print(f"WARNING: {arch} test accuracy below 0.8", file=sys.stderr)
        manifest.append(f"{arch} train_acc={train_acc:.4f} test_acc={test_acc:.4f}")
        export_net(arch, params, args.out, manifest)

    with open(os.path.join(args.out, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote {len(manifest)} artifact entries to {args.out}/manifest.txt")


if __name__ == "__main__":
    main()
