"""L1 kernel correctness: Pallas (interpret mode) vs the pure-jnp oracle,
swept over shapes/dtypes/values with hypothesis. This is the CORE
correctness signal for the compile path."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.obscure import TILE_B, obscure_dot, relu_recover
from compile.kernels.ref import client_y_pair_ref, obscure_dot_ref, relu_recover_ref


@settings(max_examples=25, deadline=None)
@given(
    tiles=st.integers(min_value=1, max_value=4),
    block=st.sampled_from([8, 25, 32, 128]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    dtype=st.sampled_from([np.int32, np.float32]),
)
def test_obscure_dot_matches_ref(tiles, block, seed, dtype):
    rng = np.random.default_rng(seed)
    n_blocks = tiles * TILE_B
    if dtype == np.int32:
        prods = rng.integers(-(2**20), 2**20, size=(n_blocks, block), dtype=np.int64).astype(dtype)
    else:
        prods = rng.uniform(-8.0, 8.0, size=(n_blocks, block)).astype(dtype)
    got = obscure_dot(jnp.asarray(prods))
    want = obscure_dot_ref(jnp.asarray(prods))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=25, deadline=None)
@given(
    tiles=st.integers(min_value=1, max_value=4),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    dtype=st.sampled_from([np.int32, np.float32]),
)
def test_relu_recover_matches_ref(tiles, seed, dtype):
    rng = np.random.default_rng(seed)
    n = tiles * TILE_B
    if dtype == np.int32:
        y = rng.integers(-192, 193, size=n, dtype=np.int64).astype(dtype)
        id1 = rng.choice([0, 2, 4, -2, -4], size=n).astype(dtype)
        id2 = rng.choice([1, 2, 4, -1, -2, -4], size=n).astype(dtype)
    else:
        y = rng.uniform(-3.0, 3.0, size=n).astype(dtype)
        id1 = rng.uniform(-2.0, 2.0, size=n).astype(dtype)
        id2 = rng.uniform(-2.0, 2.0, size=n).astype(dtype)
    got = relu_recover(jnp.asarray(y), jnp.asarray(id1), jnp.asarray(id2))
    want = relu_recover_ref(jnp.asarray(y), jnp.asarray(id1), jnp.asarray(id2))
    if dtype == np.int32:
        # Integer path (the protocol's) must be bit-exact.
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    else:
        # Float path may differ by a few ulp (mul-add fusion order).
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_recovery_all_sign_cases():
    """Paper Eq. 7: recovery equals ReLU(Con+δ) in all four sign cases —
    golden mirror of the Rust blinding tests (v = ±2^j, exact)."""
    # (s, j) → v1 = s·2^j at scale 2^4, v2 = s·2^-j at scale 2^1.
    for s in (1, -1):
        for j in (-1, 0, 1):
            for con_times_64 in (80, -80, 0, 1):  # y-scale (2^6) integers
                v1 = s * (2.0**j)
                y = np.array([con_times_64 * v1], dtype=np.float32)
                if s > 0:
                    id1, id2 = 0.0, 1.0 / v1
                else:
                    id1, id2 = 1.0 / v1, -1.0 / v1
                pad = 256
                yv = jnp.zeros(pad, jnp.float32).at[0].set(y[0])
                a = jnp.full(pad, id1, jnp.float32)
                b = jnp.full(pad, id2, jnp.float32)
                rec = np.asarray(relu_recover(yv, a, b))[0]
                want = max(con_times_64, 0)
                assert rec == pytest.approx(want), f"s={s} j={j} con={con_times_64}"


def test_client_y_pair_ref_matches_rust_semantics():
    """Round-half-up shift + clamp, mirroring rust client_y_pair
    (shift = x+k+v−y = 11, clamp = y_max·2^y = 192)."""
    sums = jnp.array([0, 1 << 11, (1 << 11) + (1 << 10), -(1 << 11), 10_000_000], dtype=jnp.int64)
    y, relu_y = client_y_pair_ref(sums, 11, 192)
    np.testing.assert_array_equal(np.asarray(y), [0, 1, 2, -1, 192])
    np.testing.assert_array_equal(np.asarray(relu_y), [0, 1, 2, 0, 192])


def test_obscure_dot_rejects_ragged():
    with pytest.raises(AssertionError):
        obscure_dot(jnp.zeros((100, 8), jnp.int32))  # not a TILE_B multiple
