"""L2 model tests: shapes, float-vs-noisy consistency, Pallas-vs-ref parity
inside the full graph, and the training loop's learnability signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import digits
from compile.model import ARCHS, forward_float, forward_noisy, init_params, train


@pytest.fixture(scope="module")
def small_batch():
    xs, ys = digits.batch(28, 20, seed=7)
    return jnp.asarray(xs), jnp.asarray(ys)


@pytest.mark.parametrize("arch", list(ARCHS))
def test_forward_shapes(arch, small_batch):
    xs, _ = small_batch
    params = init_params(arch, 28, jax.random.PRNGKey(0))
    logits = forward_float(arch, params, xs)
    assert logits.shape == (20, 10)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", list(ARCHS))
def test_noisy_eps0_close_to_float(arch, small_batch):
    xs, _ = small_batch
    params = init_params(arch, 28, jax.random.PRNGKey(1))
    f = forward_float(arch, params, xs)
    q = forward_noisy(arch, params, xs, jax.random.PRNGKey(2), 0.0)
    # Quantization-only drift must be small in value. (Argmax agreement is
    # not asserted on random-weight nets — their logit margins are ~1e-3,
    # below the quantization step; trained-weight argmax stability is
    # covered by the accuracy benchmark.)
    assert float(jnp.max(jnp.abs(f - q))) < 0.25, f"{arch}: quantization drift too large"
    # Logits must still be strongly correlated.
    fc = f - jnp.mean(f)
    qc = q - jnp.mean(q)
    corr = float(jnp.sum(fc * qc) / (jnp.linalg.norm(fc) * jnp.linalg.norm(qc) + 1e-9))
    assert corr > 0.9, f"{arch}: correlation {corr}"


def test_noisy_pallas_matches_ref_path(small_batch):
    xs, _ = small_batch
    params = init_params("netA", 28, jax.random.PRNGKey(3))
    key = jax.random.PRNGKey(4)
    a = forward_noisy("netA", params, xs, key, 0.1, use_pallas=True)
    b = forward_noisy("netA", params, xs, key, 0.1, use_pallas=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=0, atol=1e-5)


def test_large_eps_degrades(small_batch):
    xs, _ = small_batch
    params = init_params("netA", 28, jax.random.PRNGKey(5))
    clean = forward_noisy("netA", params, xs, jax.random.PRNGKey(6), 0.0)
    noisy = forward_noisy("netA", params, xs, jax.random.PRNGKey(6), 2.0)
    assert float(jnp.max(jnp.abs(clean - noisy))) > 0.1


def test_training_learns():
    params, train_acc, test_acc = train("netA", 28, steps=120, batch_size=128, seed=3)
    assert train_acc > 0.85, f"train accuracy {train_acc}"
    assert test_acc > 0.75, f"test accuracy {test_acc}"
    assert len(params) == 3


def test_digits_port_is_deterministic():
    a, la = digits.batch(28, 10, seed=42)
    b, lb = digits.batch(28, 10, seed=42)
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(la, lb)
    c, _ = digits.batch(28, 10, seed=43)
    assert np.abs(a - c).max() > 0
