//! Integration tests across the public API: PHE × protocol × GC ×
//! coordinator × runtime working together (cargo test --test integration).

use cheetah::engine::{Backend, EngineBuilder, InferenceEngine};
use cheetah::fixed::ScalePlan;
use cheetah::gc::GcRelu;
use cheetah::nn::{Layer, Network, NetworkArch, SyntheticDigits, Tensor};
use cheetah::phe::{Context, Params};
use cheetah::protocol::cheetah::CheetahRunner;
use cheetah::protocol::gazelle::GazelleRunner;
use cheetah::serve::{CheetahNetClient, PoolConfig, SecureConfig, SecureServer};
use cheetah::util::rng::{ChaCha20Rng, SplitMix64};
use std::sync::Arc;

/// The headline property: CHEETAH and GAZELLE produce consistent
/// predictions on the same model, with CHEETAH using zero permutations
/// and no garbled circuits, and GAZELLE paying both.
#[test]
fn cheetah_vs_gazelle_same_model() {
    let ctx = Arc::new(Context::new(Params::default_params()));
    let plan = ScalePlan::default_plan();
    let mut net = Network {
        name: "shared".into(),
        input_shape: (1, 8, 8),
        layers: vec![Layer::conv(3, 3, 1, 1), Layer::relu(), Layer::fc(5)],
    };
    net.init_weights(404);
    let float_net = net.clone();

    let mut ch =
        CheetahRunner::new(ctx.clone(), net.clone(), plan, 0.0, 405).expect("valid network");
    ch.run_offline();
    let mut gz = GazelleRunner::new(ctx.clone(), net, plan, 406).expect("valid network");

    let mut srng = SplitMix64::new(407);
    let input = Tensor::from_vec(
        (0..64).map(|_| srng.gen_f64_range(-1.0, 1.0)).collect(),
        1,
        8,
        8,
    );
    let ch_rep = ch.infer(&input);
    let gz_rep = gz.infer(&input);
    let float_out = float_net.forward(&input);

    // CHEETAH: no Perms, logits close to float.
    assert_eq!(ch_rep.total_ops().perm, 0);
    for (i, (&got, &want)) in ch_rep.logits.iter().zip(&float_out.data).enumerate() {
        assert!((got - want).abs() < 0.15, "cheetah logit {i}: {got} vs {want}");
    }
    // GAZELLE: pays Perms + GC, logits close to its flat-border reference
    // (not identical to float at the borders — see gazelle::conv docs) and
    // close to CHEETAH's in the interior-dominated logit sums.
    assert!(gz_rep.ops.perm > 0);
    assert!(gz_rep.gc.and_gates_total > 0);
    for (i, (&a, &b)) in ch_rep.logits.iter().zip(&gz_rep.logits).enumerate() {
        assert!((a - b).abs() < 0.6, "frameworks disagree at logit {i}: {a} vs {b}");
    }
}

/// Trained-model path: artifacts → runtime loader → private inference.
#[test]
fn trained_model_private_inference() {
    if !std::path::Path::new("artifacts/manifest.txt").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let ctx = Arc::new(Context::new(Params::default_params()));
    let plan = ScalePlan::default_plan();
    let net = cheetah::runtime::load_trained_network("artifacts", "netA").unwrap();
    let mut runner =
        CheetahRunner::new(ctx.clone(), net, plan, 0.05, 500).expect("valid network");
    runner.run_offline();
    let mut gen = SyntheticDigits::new(28, 501);
    let mut correct = 0;
    let total = 8;
    for s in gen.batch(total) {
        let rep = runner.infer(&s.image);
        correct += (rep.argmax == s.label) as usize;
    }
    assert!(correct >= total - 1, "trained private accuracy {correct}/{total}");
}

/// GC ReLU and the CHEETAH nonlinearity agree on the same share values.
#[test]
fn gc_and_obscure_relu_agree() {
    let ctx = Arc::new(Context::new(Params::default_params()));
    let p = ctx.params.p;
    let relu = GcRelu::new(p, 0);
    let mut rng = ChaCha20Rng::from_u64_seed(600);
    let mut srng = SplitMix64::new(601);
    let xs: Vec<i64> = (0..8).map(|_| srng.gen_i64_range(-100_000, 100_000)).collect();
    let se: Vec<u64> = (0..8).map(|_| srng.gen_range(p)).collect();
    let sg: Vec<u64> = xs
        .iter()
        .zip(&se)
        .map(|(&x, &s)| ((x.rem_euclid(p as i64) as u64) + p - s) % p)
        .collect();
    let (ev_sh, g_sh, _) = relu.run_batch(&sg, &se, &mut rng);
    let rec = relu.reconstruct(&ev_sh, &g_sh);
    for (i, &x) in xs.iter().enumerate() {
        assert_eq!(rec[i] as i64, x.max(0), "GC relu mismatch at {i}");
    }
}

/// The serving stack: batcher + TCP server + client, loaded concurrently.
#[test]
fn coordinator_under_concurrent_load() {
    use cheetah::coordinator::{BatchPolicy, Client, Server};
    let net = Network::build(NetworkArch::NetA, 700);
    let reference = net.clone();
    let server = Server::serve(net, "127.0.0.1:0", BatchPolicy::default()).unwrap();
    let addr = server.addr;
    let mut threads = Vec::new();
    for t in 0..4 {
        let reference = reference.clone();
        threads.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).unwrap();
            let mut gen = SyntheticDigits::new(28, 800 + t);
            for s in gen.batch(5) {
                let (argmax, logits) = client.infer(&s.image.data).unwrap();
                assert_eq!(argmax, reference.forward(&s.image).argmax());
                assert_eq!(logits.len(), 10);
            }
            client.bye().unwrap();
        }));
    }
    for t in threads {
        t.join().unwrap();
    }
    assert_eq!(server.metrics.summary().requests, 20);
    server.shutdown();
}

/// The secure serving stack end to end over real TCP sockets: two
/// concurrent clients each drive full CHEETAH inferences through
/// `SecureServer` (session registry + worker pool + wire codec), and every
/// result is **bit-identical** to the in-process `CheetahRunner` on the
/// same model with the same blinding seed — serialization is exact and
/// `v₁v₂ = 1` with no rounding, so the transport must not perturb a bit.
///
/// Seeding: recovery requantization rounds exact-tie values toward the
/// blind's sign, so bit-exactness is a *per-seed* property. With the pool
/// disabled, the two sessions get engine seeds `{seed, seed+1}` (arrival
/// order unknown), so each client must match one of the two seed-matched
/// references.
#[test]
fn secure_serving_two_concurrent_sessions_bit_exact() {
    let ctx = Arc::new(Context::new(Params::default_params()));
    let plan = ScalePlan::default_plan();
    let mut net = Network {
        name: "secure-e2e".into(),
        input_shape: (1, 6, 6),
        layers: vec![Layer::conv(2, 3, 1, 1), Layer::relu(), Layer::fc(4)],
    };
    net.init_weights(2024);
    let base_seed = 7u64;

    // Per-client inputs.
    let inputs: Vec<Vec<Tensor>> = (0..2)
        .map(|c| {
            let mut rng = SplitMix64::new(600 + c as u64);
            (0..2)
                .map(|_| {
                    Tensor::from_vec(
                        (0..36).map(|_| rng.gen_f64_range(-1.0, 1.0)).collect(),
                        1,
                        6,
                        6,
                    )
                })
                .collect()
        })
        .collect();

    // In-process references for both possible engine seeds.
    let expected: Vec<Vec<Vec<Vec<f64>>>> = (0..2u64)
        .map(|s| {
            let mut runner = CheetahRunner::new(ctx.clone(), net.clone(), plan, 0.0, base_seed + s)
                .expect("valid network");
            runner.run_offline();
            inputs
                .iter()
                .map(|qs| qs.iter().map(|q| runner.infer(q).logits).collect())
                .collect()
        })
        .collect();

    let server = SecureServer::serve(
        ctx.clone(),
        net,
        plan,
        "127.0.0.1:0",
        SecureConfig {
            epsilon: 0.0,
            workers: 2,
            seed: Some(base_seed),
            pool: PoolConfig::disabled(),
            ..Default::default()
        },
    )
    .unwrap();
    let addr = server.addr;

    let mut threads = Vec::new();
    for (c, qs) in inputs.into_iter().enumerate() {
        let ctx = ctx.clone();
        threads.push(std::thread::spawn(move || {
            let mut client =
                CheetahNetClient::connect(ctx, plan, &addr, 800 + c as u64).unwrap();
            let logits: Vec<Vec<f64>> =
                qs.iter().map(|q| client.infer(q).unwrap().logits).collect();
            client.bye().unwrap();
            logits
        }));
    }
    for (c, t) in threads.into_iter().enumerate() {
        let got = t.join().unwrap();
        assert!(
            got == expected[0][c] || got == expected[1][c],
            "client {c}: secure-served logits diverge bitwise from both \
             seed-matched in-process references\n got: {got:?}"
        );
    }
    assert_eq!(server.metrics.summary().requests, 4);
    server.shutdown();
}

/// The engine API's reason to exist: the same seeded input through the
/// `PlaintextQuantized`, `Cheetah`, `Gazelle`, `Gala`, and `CheetahNet`
/// engines must produce the identical argmax — and the two CHEETAH deployments
/// (in-process and over TCP) must be **bit-exact** on logits, since with a
/// pinned blinding seed the transport may not perturb a single bit (see
/// CHANGES.md: exact-tie rounding follows the blind's sign, so
/// bit-exactness is a per-seed property).
#[test]
fn engines_cross_backend_agreement() {
    let ctx = Arc::new(Context::new(Params::default_params()));
    // Network A + a rendered digit: the configuration the protocol tests
    // already pin down (large logit margins, so quantization/border drift
    // cannot flip the prediction).
    let net = Network::build(NetworkArch::NetA, 11);
    let input = SyntheticDigits::new(28, 9).render(3).image;
    let seed = 43u64;

    let build = |backend: Backend| {
        EngineBuilder::new(backend)
            .network(net.clone())
            .context(ctx.clone())
            .epsilon(0.0)
            .seed(seed)
            .build()
            .expect("engine build")
    };

    let mut quant = build(Backend::PlaintextQuantized);
    let mut cheetah = build(Backend::Cheetah);
    let mut gazelle = build(Backend::Gazelle);
    let mut gala = build(Backend::Gala);
    let mut net_engine = build(Backend::CheetahNet); // self-hosted loopback server

    let q = quant.infer(&input).unwrap();
    let ch = cheetah.infer(&input).unwrap();
    let gz = gazelle.infer(&input).unwrap();
    let ga = gala.infer(&input).unwrap();
    let nt = net_engine.infer(&input).unwrap();

    assert_eq!(ch.argmax, q.argmax, "cheetah vs quantized mirror");
    assert_eq!(ch.argmax, gz.argmax, "cheetah vs gazelle baseline");
    assert_eq!(ch.argmax, nt.argmax, "cheetah in-process vs over TCP");

    // Bit-exactness where the protocol guarantees it: same server blinding
    // seed ⇒ the socket deployment reproduces the in-process logits bit
    // for bit.
    assert_eq!(ch.logits, nt.logits, "TCP transport perturbed the logits");

    // GALA is the same GAZELLE runner with a cheaper linear algebra: the
    // logits must be bit-identical to the hybrid baseline under the shared
    // seed, with strictly fewer permutations (but still more than
    // CHEETAH's zero).
    assert_eq!(gz.logits, ga.logits, "GALA logits diverge from hybrid GAZELLE");
    assert_eq!(gz.argmax, ga.argmax);

    // Section sanity: both protocol engines meter traffic; CHEETAH pays
    // zero permutations while GAZELLE pays many and GALA strictly fewer.
    assert!(ch.online_bytes() > 0 && nt.online_bytes() > 0);
    assert_eq!(ch.ops.unwrap().perm, 0);
    assert!(gz.ops.unwrap().perm > 0);
    let (gz_perm, ga_perm) = (gz.ops.unwrap().perm, ga.ops.unwrap().perm);
    assert!(
        ga_perm > 0 && ga_perm < gz_perm,
        "gala perms {ga_perm} must be strictly below hybrid {gz_perm}"
    );
    assert!(
        ga.traffic.unwrap().offline < gz.traffic.unwrap().offline,
        "gala must ship less offline key material"
    );
    assert!(nt.traffic.unwrap().offline > 0, "offline indicators metered over the wire");
}

/// The parallel runtime's determinism contract, end to end: for every
/// protocol backend, the logits at 2 and 8 threads are **bit-identical** to
/// the sequential (threads = 1) run under pinned seeds. Work is statically
/// partitioned by index with per-channel RNG streams, so no arithmetic —
/// modular or float — may depend on scheduling.
#[test]
fn thread_sweep_is_bit_exact_across_backends() {
    // `.threads(n)` is engine-scoped (not global) since the batch PR, but
    // under the CI sequential gate (CHEETAH_THREADS=1) the point is an
    // all-sequential process, so skip the parallel sweep there — the
    // default-threads CI job still runs it in full.
    if std::env::var("CHEETAH_THREADS").as_deref() == Ok("1") {
        eprintln!("skipping thread sweep: CHEETAH_THREADS=1 pins the sequential gate");
        return;
    }
    let ctx = Arc::new(Context::new(Params::default_params()));
    let mut net = Network {
        name: "sweep".into(),
        input_shape: (1, 6, 6),
        layers: vec![Layer::conv(3, 3, 1, 1), Layer::relu(), Layer::fc(4)],
    };
    net.init_weights(7070);
    let input = {
        let mut rng = SplitMix64::new(7071);
        Tensor::from_vec((0..36).map(|_| rng.gen_f64_range(-1.0, 1.0)).collect(), 1, 6, 6)
    };

    let run = |backend: Backend, threads: usize| -> Vec<f64> {
        // A fresh engine per (backend, thread-count) with the same pinned
        // seed: identical keys and blinding material every time, so any
        // logit difference can only come from the parallel runtime.
        let mut engine = EngineBuilder::new(backend)
            .network(net.clone())
            .context(ctx.clone())
            .epsilon(0.0)
            .seed(7072)
            .threads(threads)
            .build()
            .expect("engine build");
        engine.infer(&input).expect("inference").logits
    };

    for backend in [Backend::Cheetah, Backend::Gazelle, Backend::Gala, Backend::CheetahNet] {
        let reference = run(backend, 1);
        for threads in [2usize, 8] {
            let got = run(backend, threads);
            assert_eq!(
                got, reference,
                "{backend}: logits at threads={threads} diverge bitwise from sequential"
            );
        }
    }
}

/// Batch determinism, end to end: for every protocol backend,
/// `infer_batch` logits are **bit-identical** to looped single-query
/// `infer` on an identically-seeded fresh engine — at threads 1/2/8 and
/// batch sizes 1/4/9. The batch driver fans whole queries across the par
/// pool with per-query RNG streams derived from `(seed, query index)`, so
/// neither scheduling nor batch shape may perturb a bit.
#[test]
fn batch_inference_matches_looped_at_every_thread_count() {
    // Same rationale as the thread sweep: scoped `.threads(n)` overrides
    // would re-enable parallel regions under the CHEETAH_THREADS=1
    // sequential CI gate, whose point is an all-sequential process.
    if std::env::var("CHEETAH_THREADS").as_deref() == Ok("1") {
        eprintln!("skipping batch sweep: CHEETAH_THREADS=1 pins the sequential gate");
        return;
    }
    let ctx = Arc::new(Context::new(Params::default_params()));
    let mut net = Network {
        name: "batch-sweep".into(),
        input_shape: (1, 6, 6),
        layers: vec![Layer::conv(2, 3, 1, 1), Layer::relu(), Layer::fc(4)],
    };
    net.init_weights(4040);
    let inputs: Vec<Tensor> = {
        let mut rng = SplitMix64::new(4041);
        (0..9)
            .map(|_| {
                Tensor::from_vec(
                    (0..36).map(|_| rng.gen_f64_range(-1.0, 1.0)).collect(),
                    1,
                    6,
                    6,
                )
            })
            .collect()
    };

    let fresh_engine = |backend: Backend, threads: usize| {
        EngineBuilder::new(backend)
            .network(net.clone())
            .context(ctx.clone())
            .epsilon(0.0)
            .seed(4042)
            .threads(threads)
            .build()
            .expect("engine build")
    };

    for backend in [Backend::Cheetah, Backend::Gazelle, Backend::Gala, Backend::CheetahNet] {
        // Reference: looped single-query inference, sequential.
        let mut looped = fresh_engine(backend, 1);
        let want: Vec<Vec<f64>> = inputs
            .iter()
            .map(|x| looped.infer(x).expect("looped inference").logits)
            .collect();

        for threads in [1usize, 2, 8] {
            for batch in [1usize, 4, 9] {
                let mut engine = fresh_engine(backend, threads);
                let reps = engine
                    .infer_batch(&inputs[..batch])
                    .expect("batched inference");
                assert_eq!(reps.len(), batch);
                for (i, rep) in reps.iter().enumerate() {
                    assert_eq!(
                        rep.logits, want[i],
                        "{backend}: batch={batch} threads={threads} query {i} \
                         diverged bitwise from the sequential loop"
                    );
                }
            }
        }
    }
    // `.threads(n)` is engine-scoped now — no global state to restore.
}

/// Property: private inference is deterministic given seeds, and the
/// metered traffic equals the sum of serialized ciphertext sizes.
#[test]
fn traffic_accounting_consistent() {
    let ctx = Arc::new(Context::new(Params::default_params()));
    let plan = ScalePlan::default_plan();
    let mut net = Network {
        name: "acct".into(),
        input_shape: (1, 6, 6),
        layers: vec![Layer::conv(2, 3, 1, 1), Layer::relu(), Layer::fc(3)],
    };
    net.init_weights(900);
    let mut runner =
        CheetahRunner::new(ctx.clone(), net, plan, 0.0, 901).expect("valid network");
    runner.run_offline();
    let input = Tensor::from_vec((0..36).map(|i| i as f64 / 36.0).collect(), 1, 6, 6);
    let rep = runner.infer(&input);
    let n = ctx.params.n;
    use cheetah::phe::serial::ciphertext_bytes;
    let expected: u64 = runner
        .spec()
        .steps
        .iter()
        .enumerate()
        .map(|(si, s)| {
            let mut b = (s.linear.num_in_cts(n) * ciphertext_bytes(&ctx.params, true)) as u64;
            b += (s.linear.num_out_cts(n) * ciphertext_bytes(&ctx.params, false)) as u64;
            if si != runner.spec().last_idx() {
                b += (s.linear.num_recovery_cts(n) * ciphertext_bytes(&ctx.params, false)) as u64;
            }
            b
        })
        .sum();
    assert_eq!(rep.online_bytes(), expected);
}
