//! Boolean circuit representation and builders.
//!
//! Circuits are XOR/AND netlists (NOT is XOR with the constant-one wire),
//! matching the free-XOR garbling model: XOR gates cost nothing, AND gates
//! cost one garbled table. The ReLU circuit used by the GAZELLE baseline is
//! built here; its AND-gate count is the unit the paper's GC costs scale
//! with.

/// Wire identifier.
pub type Wire = usize;

/// A gate in topological order. `Xor` is free under free-XOR garbling;
/// `And` requires a garbled table.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Gate {
    /// `out = a ⊕ b` — free under free-XOR garbling.
    Xor {
        /// Left input wire.
        a: Wire,
        /// Right input wire.
        b: Wire,
        /// Output wire.
        out: Wire,
    },
    /// `out = a ∧ b` — one 4-row garbled table.
    And {
        /// Left input wire.
        a: Wire,
        /// Right input wire.
        b: Wire,
        /// Output wire.
        out: Wire,
    },
}

/// A two-party circuit: garbler inputs, evaluator inputs, one constant-one
/// wire, gates, outputs.
#[derive(Clone, Debug)]
pub struct Circuit {
    /// Total wire count (inputs, constant, and every gate output).
    pub n_wires: usize,
    /// Wires carrying the garbler's input bits (LSB first per block).
    pub garbler_inputs: Vec<Wire>,
    /// Wires carrying the evaluator's input bits (LSB first per block).
    pub evaluator_inputs: Vec<Wire>,
    /// The constant-true wire (fed by the garbler).
    pub one: Wire,
    /// Gates in topological order.
    pub gates: Vec<Gate>,
    /// Output wires, in output-bit order.
    pub outputs: Vec<Wire>,
}

impl Circuit {
    /// Number of AND gates — the unit garbled-table size and GC traffic
    /// scale with (XORs are free).
    pub fn num_and_gates(&self) -> usize {
        self.gates.iter().filter(|g| matches!(g, Gate::And { .. })).count()
    }

    /// Plaintext evaluation (the correctness oracle for garbling).
    pub fn eval_plain(&self, garbler_bits: &[bool], evaluator_bits: &[bool]) -> Vec<bool> {
        let mut vals = vec![false; self.n_wires];
        vals[self.one] = true;
        for (w, &b) in self.garbler_inputs.iter().zip(garbler_bits) {
            vals[*w] = b;
        }
        for (w, &b) in self.evaluator_inputs.iter().zip(evaluator_bits) {
            vals[*w] = b;
        }
        for g in &self.gates {
            match *g {
                Gate::Xor { a, b, out } => vals[out] = vals[a] ^ vals[b],
                Gate::And { a, b, out } => vals[out] = vals[a] & vals[b],
            }
        }
        self.outputs.iter().map(|&w| vals[w]).collect()
    }
}

/// Incremental circuit builder.
pub struct Builder {
    n_wires: usize,
    garbler_inputs: Vec<Wire>,
    evaluator_inputs: Vec<Wire>,
    one: Wire,
    gates: Vec<Gate>,
}

impl Builder {
    /// Empty circuit with just the constant-one wire (wire 0).
    pub fn new() -> Self {
        // Wire 0 is the constant-one wire.
        Self { n_wires: 1, garbler_inputs: vec![], evaluator_inputs: vec![], one: 0, gates: vec![] }
    }

    fn fresh(&mut self) -> Wire {
        let w = self.n_wires;
        self.n_wires += 1;
        w
    }

    /// Allocate one garbler input wire.
    pub fn garbler_input(&mut self) -> Wire {
        let w = self.fresh();
        self.garbler_inputs.push(w);
        w
    }

    /// Allocate one evaluator input wire.
    pub fn evaluator_input(&mut self) -> Wire {
        let w = self.fresh();
        self.evaluator_inputs.push(w);
        w
    }

    /// `n`-bit garbler input vector (LSB first).
    pub fn garbler_inputs(&mut self, n: usize) -> Vec<Wire> {
        (0..n).map(|_| self.garbler_input()).collect()
    }

    /// `n`-bit evaluator input vector (LSB first).
    pub fn evaluator_inputs(&mut self, n: usize) -> Vec<Wire> {
        (0..n).map(|_| self.evaluator_input()).collect()
    }

    /// The constant-true wire.
    pub fn one(&self) -> Wire {
        self.one
    }

    /// `a ⊕ b` — free.
    pub fn xor(&mut self, a: Wire, b: Wire) -> Wire {
        let out = self.fresh();
        self.gates.push(Gate::Xor { a, b, out });
        out
    }

    /// `a ∧ b` — 1 AND (one garbled table).
    pub fn and(&mut self, a: Wire, b: Wire) -> Wire {
        let out = self.fresh();
        self.gates.push(Gate::And { a, b, out });
        out
    }

    /// `¬a` — free (XOR with the constant-one wire).
    pub fn not(&mut self, a: Wire) -> Wire {
        self.xor(a, self.one)
    }

    /// OR via De Morgan: 1 AND.
    pub fn or(&mut self, a: Wire, b: Wire) -> Wire {
        let na = self.not(a);
        let nb = self.not(b);
        let n = self.and(na, nb);
        self.not(n)
    }

    /// 2:1 multiplexer: `sel ? t : f` — 1 AND (`f ⊕ sel·(t⊕f)`).
    pub fn mux(&mut self, sel: Wire, t: Wire, f: Wire) -> Wire {
        let d = self.xor(t, f);
        let m = self.and(sel, d);
        self.xor(m, f)
    }

    /// Full adder: returns (sum, carry_out) — 1 AND via the standard
    /// free-XOR trick: carry = c ⊕ ((a⊕c)·(b⊕c)).
    pub fn full_adder(&mut self, a: Wire, b: Wire, c: Wire) -> (Wire, Wire) {
        let axc = self.xor(a, c);
        let bxc = self.xor(b, c);
        let sum = self.xor(axc, b);
        let t = self.and(axc, bxc);
        let carry = self.xor(t, c);
        (sum, carry)
    }

    /// Ripple-carry addition of two little-endian vectors; returns
    /// (sum bits, carry out). `ℓ` AND gates.
    pub fn add(&mut self, a: &[Wire], b: &[Wire]) -> (Vec<Wire>, Wire) {
        assert_eq!(a.len(), b.len());
        let mut c = self.xor(self.one, self.one); // constant zero
        let mut out = Vec::with_capacity(a.len());
        for i in 0..a.len() {
            let (s, nc) = self.full_adder(a[i], b[i], c);
            out.push(s);
            c = nc;
        }
        (out, c)
    }

    /// `a - constant` (little-endian), returns (diff, borrow_out).
    /// Implemented as `a + ~k + 1`; borrow = NOT carry. `ℓ` ANDs.
    pub fn sub_const(&mut self, a: &[Wire], k: u64) -> (Vec<Wire>, Wire) {
        let zero = self.xor(self.one, self.one);
        let notk: Vec<Wire> = (0..a.len())
            .map(|i| if (k >> i) & 1 == 1 { zero } else { self.one })
            .collect();
        // carry-in = 1
        let mut c = self.one;
        let mut out = Vec::with_capacity(a.len());
        for i in 0..a.len() {
            let (s, nc) = self.full_adder(a[i], notk[i], c);
            out.push(s);
            c = nc;
        }
        let borrow = self.not(c);
        (out, borrow)
    }

    /// `sel ? t : f` bitwise over vectors.
    pub fn mux_vec(&mut self, sel: Wire, t: &[Wire], f: &[Wire]) -> Vec<Wire> {
        assert_eq!(t.len(), f.len());
        t.iter().zip(f).map(|(&ti, &fi)| self.mux(sel, ti, fi)).collect()
    }

    /// AND every bit with `g`.
    pub fn gate_vec(&mut self, g: Wire, v: &[Wire]) -> Vec<Wire> {
        v.iter().map(|&b| self.and(g, b)).collect()
    }

    /// Finish the netlist, naming the output wires.
    pub fn build(self, outputs: Vec<Wire>) -> Circuit {
        Circuit {
            n_wires: self.n_wires,
            garbler_inputs: self.garbler_inputs,
            evaluator_inputs: self.evaluator_inputs,
            one: self.one,
            gates: self.gates,
            outputs,
        }
    }
}

impl Default for Builder {
    fn default() -> Self {
        Self::new()
    }
}

/// Little-endian bit decomposition: the low `n` bits of `x`.
pub fn to_bits(x: u64, n: usize) -> Vec<bool> {
    (0..n).map(|i| (x >> i) & 1 == 1).collect()
}

/// Inverse of [`to_bits`]: reassemble a little-endian bit vector.
pub fn from_bits(bits: &[bool]) -> u64 {
    bits.iter().rev().fold(0u64, |acc, &b| (acc << 1) | b as u64)
}

/// The GAZELLE-style ReLU circuit over additive shares modulo prime `p`
/// (`ℓ = ⌈log2 p⌉` bits), with built-in fixed-point truncation and mod-p
/// re-sharing so its outputs feed the next HE layer directly:
///
/// 1. `t = s_g + s_e` (ℓ+1-bit),
/// 2. `t ≥ p ⟹ t -= p` (modular reduction),
/// 3. `positive = t ≤ (p-1)/2` (sign in centered representation),
/// 4. `relu = positive ? (t >> shift) : 0` — the truncation requantizes
///    from the linear-output scale back to the activation scale for free
///    (bit slicing costs no gates),
/// 5. output `relu + (p − r) mod p` — a fresh additive re-sharing mod p
///    with the garbler's mask `r` (second garbler input block).
///
/// AND-gate count ≈ 7ℓ — this is what the paper's "GC is costly" claim is
/// about, and what Table 6 measures.
pub fn build_relu_mod_p(p: u64, shift: usize) -> Circuit {
    let ell = 64 - p.leading_zeros() as usize; // bits to hold values < p
    let mut b = Builder::new();
    let sg = b.garbler_inputs(ell);
    let mask = b.garbler_inputs(ell); // (p − r) mod p
    let se = b.evaluator_inputs(ell);

    // t = sg + se, with carry bit → ℓ+1 bit value.
    let (mut t, carry) = b.add(&sg, &se);
    t.push(carry);
    // Conditional subtract p (t < 2p always).
    let (sub, borrow) = b.sub_const(&t, p);
    let not_borrow = b.not(borrow); // t >= p
    let t_red = b.mux_vec(not_borrow, &sub, &t);
    let t_red = &t_red[..ell]; // value now < p
    // positive ⟺ t_red <= (p-1)/2 ⟺ t_red - ((p-1)/2 + 1) borrows.
    let (_, neg_borrow) = b.sub_const(t_red, (p - 1) / 2 + 1);
    let positive = neg_borrow;
    // relu = positive ? (t_red >> shift) : 0 (truncation = bit slice).
    let zero = b.xor(b.one(), b.one());
    let mut shifted: Vec<Wire> = t_red[shift..].to_vec();
    shifted.resize(ell, zero);
    let relu = b.gate_vec(positive, &shifted);
    // reshare mod p: out = relu + mask, conditionally subtract p.
    let (mut t2, carry2) = b.add(&relu, &mask);
    t2.push(carry2);
    let (sub2, borrow2) = b.sub_const(&t2, p);
    let not_borrow2 = b.not(borrow2);
    let out = b.mux_vec(not_borrow2, &sub2, &t2);
    b.build(out[..ell].to_vec())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SplitMix64;

    #[test]
    fn adder_correct() {
        let mut b = Builder::new();
        let x = b.garbler_inputs(8);
        let y = b.evaluator_inputs(8);
        let (s, c) = b.add(&x, &y);
        let mut outs = s;
        outs.push(c);
        let circ = b.build(outs);
        let mut rng = SplitMix64::new(1);
        for _ in 0..50 {
            let a = rng.gen_range(256);
            let bb = rng.gen_range(256);
            let out = circ.eval_plain(&to_bits(a, 8), &to_bits(bb, 8));
            assert_eq!(from_bits(&out), a + bb);
        }
    }

    #[test]
    fn sub_const_and_borrow() {
        let mut b = Builder::new();
        let x = b.garbler_inputs(8);
        let (d, borrow) = b.sub_const(&x, 100);
        let mut outs = d;
        outs.push(borrow);
        let circ = b.build(outs);
        for a in [0u64, 50, 99, 100, 101, 255] {
            let out = circ.eval_plain(&to_bits(a, 8), &[]);
            let diff = from_bits(&out[..8]);
            let borrow = out[8];
            assert_eq!(diff, a.wrapping_sub(100) & 0xff);
            assert_eq!(borrow, a < 100, "borrow wrong for {a}");
        }
    }

    #[test]
    fn mux_selects() {
        let mut b = Builder::new();
        let s = b.garbler_input();
        let t = b.garbler_input();
        let f = b.garbler_input();
        let m = b.mux(s, t, f);
        let circ = b.build(vec![m]);
        for (s, t, f) in [(false, true, false), (true, true, false), (true, false, true)] {
            let out = circ.eval_plain(&[s, t, f], &[]);
            assert_eq!(out[0], if s { t } else { f });
        }
    }

    #[test]
    fn relu_mod_p_circuit_correct() {
        let p = 8380417u64; // a 23-bit prime like the default plan's
        let ell = 23;
        let mut rng = SplitMix64::new(2);
        for shift in [0usize, 6] {
            let circ = build_relu_mod_p(p, shift);
            for _ in 0..40 {
                // True value x centered in a small range, shared mod p.
                let x = rng.gen_i64_range(-1_000_000, 1_000_000);
                let xm = x.rem_euclid(p as i64) as u64;
                let se = rng.gen_range(p);
                let sg = (xm + p - se) % p;
                let r = rng.gen_range(p);
                let mask = (p - r) % p;

                let mut gin = to_bits(sg, ell);
                gin.extend(to_bits(mask, ell));
                let out = circ.eval_plain(&gin, &to_bits(se, ell));
                let out_val = from_bits(&out);
                let relu = if x > 0 { (x as u64) >> shift } else { 0 };
                // Reconstruction: out + r ≡ relu (mod p).
                assert_eq!((out_val + r) % p, relu, "x={x} shift={shift}");
            }
        }
    }

    #[test]
    fn relu_and_count_is_linear_in_bits() {
        let p = 8380417u64;
        let circ = build_relu_mod_p(p, 6);
        let ands = circ.num_and_gates();
        // ~7ℓ for ℓ=23 → between 5ℓ and 9ℓ.
        assert!(
            (5 * 23..9 * 23 + 10).contains(&ands),
            "unexpected AND count {ands}"
        );
    }
}
