//! The GC-based ReLU protocol used by the GAZELLE baseline: batched Yao
//! evaluation of [`build_relu_mod_p`] over additive shares mod p.
//!
//! Cost accounting mirrors GAZELLE's phases:
//! * **offline**: garbling all circuits + transferring tables,
//! * **online**: label transfer (direct for the garbler's bits; dealer-model
//!   OT for the evaluator's — bytes accounted analytically) + evaluation +
//!   returning masked outputs.
//!
//! One protocol instance processes a whole activation vector (the paper's
//! Table 6 measures 1 000 / 10 000 elements; §5.1 quotes ~263 s for the
//! 3.2 M-element VGG ReLU).

use super::circuit::{build_relu_mod_p, from_bits, to_bits, Circuit};
use super::garble::{evaluate, ot_bytes_per_bit, Garbler, GarbledCircuit, Label};
use crate::util::rng::ChaCha20Rng;
use std::time::{Duration, Instant};

/// Cost/result report for one batched GC ReLU execution.
#[derive(Clone, Debug, Default)]
pub struct GcReluReport {
    /// Time spent garbling (offline phase).
    pub garble_time: Duration,
    /// Time spent evaluating (online phase).
    pub eval_time: Duration,
    /// Garbled tables + decode info (offline transfer).
    pub offline_bytes: u64,
    /// Input labels + OT traffic + masked outputs (online transfer).
    pub online_bytes: u64,
    /// Total AND gates garbled across the batch.
    pub and_gates_total: u64,
}

impl GcReluReport {
    /// Accumulate another execution's costs into this report.
    pub fn merge(&mut self, o: &GcReluReport) {
        self.garble_time += o.garble_time;
        self.eval_time += o.eval_time;
        self.offline_bytes += o.offline_bytes;
        self.online_bytes += o.online_bytes;
        self.and_gates_total += o.and_gates_total;
    }
}

/// Batched GC ReLU over shares mod `p`, with built-in `>> shift`
/// requantization and mod-p output re-sharing.
pub struct GcRelu {
    /// The share modulus (the HE plaintext prime).
    pub p: u64,
    /// Bits per share: `⌈log₂ p⌉`.
    pub ell: usize,
    /// Built-in right-shift requantization applied to positive outputs.
    pub shift: usize,
    circuit: Circuit,
}

impl GcRelu {
    /// Build the protocol instance (compiles the ReLU circuit once; it is
    /// re-garbled per element with fresh labels).
    pub fn new(p: u64, shift: usize) -> Self {
        let circuit = build_relu_mod_p(p, shift);
        let ell = 64 - p.leading_zeros() as usize;
        Self { p, ell, shift, circuit }
    }

    /// AND gates per element (the unit GC cost scales with).
    pub fn and_gates_per_relu(&self) -> usize {
        self.circuit.num_and_gates()
    }

    /// Offline bytes per element (tables + decode bits).
    pub fn offline_bytes_per_relu(&self) -> usize {
        self.and_gates_per_relu() * 64 + self.ell.div_ceil(8)
    }

    /// Run the batched protocol: the garbler holds shares `sg` and samples
    /// masks `r` (its fresh output shares); the evaluator holds shares
    /// `se`. Returns (evaluator shares, garbler shares, report); both
    /// output share vectors are mod p and reconstruct to
    /// `ReLU(x) >> shift`.
    pub fn run_batch(
        &self,
        sg: &[u64],
        se: &[u64],
        rng: &mut ChaCha20Rng,
    ) -> (Vec<u64>, Vec<u64>, GcReluReport) {
        assert_eq!(sg.len(), se.len());
        let n = sg.len();
        let mut report = GcReluReport::default();

        let mut eval_shares = Vec::with_capacity(n);
        let mut garbler_shares = Vec::with_capacity(n);

        // Offline: garble one circuit instance per element.
        let mut garbled: Vec<(Garbler, GarbledCircuit, u64)> = Vec::with_capacity(n);
        let t0 = Instant::now();
        for _ in 0..n {
            let (g, gc) = Garbler::garble(&self.circuit, rng);
            let r = rng.gen_range(self.p);
            report.offline_bytes += gc.size_bytes() as u64;
            garbled.push((g, gc, r));
        }
        report.garble_time = t0.elapsed();
        report.and_gates_total = (self.and_gates_per_relu() * n) as u64;

        // Online: transfer labels (garbler direct, evaluator via modeled
        // OT), evaluate, decode.
        let t1 = Instant::now();
        for i in 0..n {
            let (g, gc, r) = &garbled[i];
            let mask = (self.p - r) % self.p;
            let mut gbits = to_bits(sg[i], self.ell);
            gbits.extend(to_bits(mask, self.ell));
            let glabels: Vec<Label> = self
                .circuit
                .garbler_inputs
                .iter()
                .zip(&gbits)
                .map(|(&w, &v)| g.input_label(w, v))
                .collect();
            let ebits = to_bits(se[i], self.ell);
            let elabels: Vec<Label> = self
                .circuit
                .evaluator_inputs
                .iter()
                .zip(&ebits)
                .map(|(&w, &v)| g.input_label(w, v))
                .collect();
            report.online_bytes += (glabels.len() * 16) as u64; // direct labels
            report.online_bytes += (elabels.len() * ot_bytes_per_bit()) as u64; // OT model
            let one = g.input_label(self.circuit.one, true);
            let out = evaluate(&self.circuit, gc, one, &glabels, &elabels);
            eval_shares.push(from_bits(&out));
            garbler_shares.push(*r);
            report.online_bytes += (self.ell as u64).div_ceil(8); // masked result back
        }
        report.eval_time = t1.elapsed();
        (eval_shares, garbler_shares, report)
    }

    /// Reconstruct values from the two output share vectors (mod p).
    pub fn reconstruct(&self, eval_shares: &[u64], garbler_shares: &[u64]) -> Vec<u64> {
        eval_shares.iter().zip(garbler_shares).map(|(&a, &b)| (a + b) % self.p).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SplitMix64;

    #[test]
    fn batched_relu_correct() {
        let p = 8380417u64;
        let relu = GcRelu::new(p, 0);
        let mut rng = SplitMix64::new(7);
        let mut crng = ChaCha20Rng::from_u64_seed(8);
        let n = 16;
        let xs: Vec<i64> = (0..n).map(|_| rng.gen_i64_range(-500_000, 500_000)).collect();
        let se: Vec<u64> = (0..n).map(|_| rng.gen_range(p)).collect();
        let sg: Vec<u64> = xs
            .iter()
            .zip(&se)
            .map(|(&x, &s)| ((x.rem_euclid(p as i64) as u64) + p - s) % p)
            .collect();
        let (ev, gv, report) = relu.run_batch(&sg, &se, &mut crng);
        let rec = relu.reconstruct(&ev, &gv);
        for i in 0..n {
            let expect = xs[i].max(0) as u64;
            assert_eq!(rec[i], expect, "x={}", xs[i]);
        }
        assert!(report.offline_bytes > 0 && report.online_bytes > 0);
        assert_eq!(report.and_gates_total, (relu.and_gates_per_relu() * n) as u64);
    }

    #[test]
    fn batched_relu_with_truncation() {
        let p = 8380417u64;
        let shift = 6;
        let relu = GcRelu::new(p, shift);
        let mut rng = SplitMix64::new(9);
        let mut crng = ChaCha20Rng::from_u64_seed(10);
        let xs: Vec<i64> = (0..8).map(|_| rng.gen_i64_range(-100_000, 100_000)).collect();
        let se: Vec<u64> = (0..8).map(|_| rng.gen_range(p)).collect();
        let sg: Vec<u64> = xs
            .iter()
            .zip(&se)
            .map(|(&x, &s)| ((x.rem_euclid(p as i64) as u64) + p - s) % p)
            .collect();
        let (ev, gv, _) = relu.run_batch(&sg, &se, &mut crng);
        let rec = relu.reconstruct(&ev, &gv);
        for i in 0..8 {
            let expect = (xs[i].max(0) as u64) >> shift;
            assert_eq!(rec[i], expect, "x={}", xs[i]);
        }
    }

    #[test]
    fn per_relu_cost_is_stable() {
        let relu = GcRelu::new(8380417, 6);
        // ~7ℓ AND gates at ℓ=23.
        let ands = relu.and_gates_per_relu();
        assert!((100..230).contains(&ands), "AND count {ands}");
        let mut crng = ChaCha20Rng::from_u64_seed(3);
        let (_, _, rep) = relu.run_batch(&[0, 1], &[5, 5], &mut crng);
        assert_eq!(rep.offline_bytes as usize, 2 * relu.offline_bytes_per_relu());
    }
}
