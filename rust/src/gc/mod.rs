//! Yao garbled circuits — the nonlinear-layer engine of the GAZELLE
//! baseline (and of most prior work in the paper's Table 1).
//!
//! * [`circuit`] — XOR/AND netlists, builders, and the mod-p ReLU circuit,
//! * [`garble`] — free-XOR + point-and-permute garbling over SHA-256,
//! * [`relu`] — the batched two-party GC ReLU protocol with GAZELLE-style
//!   offline/online cost accounting.
//!
//! CHEETAH's contribution is precisely *avoiding* all of this: its
//! PHE-based secret-share nonlinearity replaces per-element garbled tables
//! (≈ 5ℓ AND gates ≈ 7 KiB each) with two plaintext multiplications on an
//! existing ciphertext (paper §3.1 step 3, Table 6).

pub mod circuit;
pub mod garble;
pub mod relu;
pub mod sha256;

pub use circuit::{build_relu_mod_p, Builder, Circuit, Gate};
pub use garble::{evaluate, Garbler, GarbledCircuit};
pub use relu::{GcRelu, GcReluReport};
