//! Yao garbling with free-XOR and point-and-permute.
//!
//! * Wire labels are 128-bit; the global offset Δ has LSB 1 so a label's
//!   LSB is its permute bit (point-and-permute).
//! * XOR gates are free: `W_out = W_a ⊕ W_b` (Kolesnikov–Schneider).
//! * AND gates carry a classic 4-row garbled table; rows are keyed by the
//!   permute bits and encrypted with `H(A ‖ B ‖ gate_id)` where `H` is
//!   SHA-256 truncated to 128 bits. (No half-gates/row-reduction — the
//!   paper's baseline predates them; table size 4×16 B per AND. The
//!   benches report bytes from this real layout.)
//!
//! The evaluator's input labels are delivered by a trusted-dealer stand-in
//! for OT (no big-integer group available offline — see DESIGN.md); OT
//! bytes are accounted analytically in [`ot_bytes_per_bit`].

use super::circuit::{Circuit, Gate};
use super::sha256::Sha256;
use crate::util::rng::ChaCha20Rng;

/// A 128-bit wire label.
pub type Label = [u8; 16];

/// Modeled OT-extension traffic per evaluator input bit (IKNP-style: one
/// λ-bit column + two masked labels).
pub const fn ot_bytes_per_bit() -> usize {
    16 + 2 * 16
}

#[inline]
fn xor_label(a: &Label, b: &Label) -> Label {
    let mut out = [0u8; 16];
    for i in 0..16 {
        out[i] = a[i] ^ b[i];
    }
    out
}

#[inline]
fn lsb(l: &Label) -> bool {
    l[0] & 1 == 1
}

/// `H(A ‖ B ‖ gate_id)` truncated to 128 bits.
#[inline]
fn hash_gate(a: &Label, b: &Label, gid: u64) -> Label {
    let mut h = Sha256::new();
    h.update(a);
    h.update(b);
    h.update(gid.to_le_bytes());
    let d = h.finalize();
    let mut out = [0u8; 16];
    out.copy_from_slice(&d[..16]);
    out
}

/// The garbled form of a circuit: AND-gate tables plus output permute bits.
pub struct GarbledCircuit {
    /// One 4-row table per AND gate, in gate order.
    pub tables: Vec<[Label; 4]>,
    /// Permute bits of the output wires (decoding information).
    pub output_perm: Vec<bool>,
}

impl GarbledCircuit {
    /// Serialized size in bytes (tables + decode bits) — the offline GC
    /// transfer the paper's Table 6/7 communication includes.
    pub fn size_bytes(&self) -> usize {
        self.tables.len() * 64 + self.output_perm.len().div_ceil(8)
    }
}

/// Garbler state: all wire zero-labels plus Δ.
pub struct Garbler {
    /// The global free-XOR offset Δ (LSB forced to 1 for point-and-permute).
    pub delta: Label,
    /// Zero-label of every wire.
    pub w0: Vec<Label>,
}

impl Garbler {
    /// Garble `circuit`, returning the garbler state and the tables.
    pub fn garble(circuit: &Circuit, rng: &mut ChaCha20Rng) -> (Self, GarbledCircuit) {
        let _span = crate::obs::span("gc.garble");
        let mut delta = [0u8; 16];
        rng.fill_bytes(&mut delta);
        delta[0] |= 1; // permute-bit invariant

        let mut w0 = vec![[0u8; 16]; circuit.n_wires];
        let mut assigned = vec![false; circuit.n_wires];
        // Constant-one wire: label for TRUE is w0[one] ⊕ Δ; give it a random
        // zero-label like any input.
        let init = |w: usize, w0: &mut Vec<Label>, assigned: &mut Vec<bool>, rng: &mut ChaCha20Rng| {
            let mut l = [0u8; 16];
            rng.fill_bytes(&mut l);
            w0[w] = l;
            assigned[w] = true;
        };
        init(circuit.one, &mut w0, &mut assigned, rng);
        for &w in circuit.garbler_inputs.iter().chain(circuit.evaluator_inputs.iter()) {
            init(w, &mut w0, &mut assigned, rng);
        }

        let mut tables = Vec::with_capacity(circuit.num_and_gates());
        for (gid, gate) in circuit.gates.iter().enumerate() {
            match *gate {
                Gate::Xor { a, b, out } => {
                    debug_assert!(assigned[a] && assigned[b]);
                    w0[out] = xor_label(&w0[a], &w0[b]);
                    assigned[out] = true;
                }
                Gate::And { a, b, out } => {
                    debug_assert!(assigned[a] && assigned[b]);
                    let mut wo = [0u8; 16];
                    rng.fill_bytes(&mut wo);
                    w0[out] = wo;
                    assigned[out] = true;
                    let mut table = [[0u8; 16]; 4];
                    for va in 0..2u8 {
                        for vb in 0..2u8 {
                            let la = if va == 1 { xor_label(&w0[a], &delta) } else { w0[a] };
                            let lb = if vb == 1 { xor_label(&w0[b], &delta) } else { w0[b] };
                            let row = (lsb(&la) as usize) << 1 | lsb(&lb) as usize;
                            let vo = va & vb;
                            let lo =
                                if vo == 1 { xor_label(&w0[out], &delta) } else { w0[out] };
                            table[row] = xor_label(&hash_gate(&la, &lb, gid as u64), &lo);
                        }
                    }
                    tables.push(table);
                }
            }
        }
        let output_perm = circuit.outputs.iter().map(|&w| lsb(&w0[w])).collect();
        (Self { delta, w0 }, GarbledCircuit { tables, output_perm })
    }

    /// Label for wire `w` carrying bit `v`.
    pub fn input_label(&self, w: usize, v: bool) -> Label {
        if v {
            xor_label(&self.w0[w], &self.delta)
        } else {
            self.w0[w]
        }
    }
}

/// Evaluate a garbled circuit given active input labels.
/// `garbler_labels` must include the constant-one wire's TRUE label first.
pub fn evaluate(
    circuit: &Circuit,
    garbled: &GarbledCircuit,
    one_label: Label,
    garbler_labels: &[Label],
    evaluator_labels: &[Label],
) -> Vec<bool> {
    let _span = crate::obs::span("gc.eval");
    let mut labels = vec![[0u8; 16]; circuit.n_wires];
    labels[circuit.one] = one_label;
    for (w, l) in circuit.garbler_inputs.iter().zip(garbler_labels) {
        labels[*w] = *l;
    }
    for (w, l) in circuit.evaluator_inputs.iter().zip(evaluator_labels) {
        labels[*w] = *l;
    }
    let mut and_idx = 0usize;
    for (gid, gate) in circuit.gates.iter().enumerate() {
        match *gate {
            Gate::Xor { a, b, out } => {
                labels[out] = xor_label(&labels[a], &labels[b]);
            }
            Gate::And { a, b, out } => {
                let la = labels[a];
                let lb = labels[b];
                let row = (lsb(&la) as usize) << 1 | lsb(&lb) as usize;
                labels[out] =
                    xor_label(&hash_gate(&la, &lb, gid as u64), &garbled.tables[and_idx][row]);
                and_idx += 1;
            }
        }
    }
    circuit
        .outputs
        .iter()
        .zip(&garbled.output_perm)
        .map(|(&w, &p)| lsb(&labels[w]) ^ p)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gc::circuit::{build_relu_mod_p, from_bits, to_bits, Builder};
    use crate::util::proptest;
    use crate::util::rng::{ChaCha20Rng, SplitMix64};

    fn run_garbled(circ: &Circuit, gbits: &[bool], ebits: &[bool], seed: u64) -> Vec<bool> {
        let mut rng = ChaCha20Rng::from_u64_seed(seed);
        let (g, gc) = Garbler::garble(circ, &mut rng);
        let one = g.input_label(circ.one, true);
        let glabels: Vec<Label> = circ
            .garbler_inputs
            .iter()
            .zip(gbits)
            .map(|(&w, &v)| g.input_label(w, v))
            .collect();
        let elabels: Vec<Label> = circ
            .evaluator_inputs
            .iter()
            .zip(ebits)
            .map(|(&w, &v)| g.input_label(w, v))
            .collect();
        evaluate(circ, &gc, one, &glabels, &elabels)
    }

    #[test]
    fn garbled_and_xor_gates() {
        let mut b = Builder::new();
        let x = b.garbler_input();
        let y = b.evaluator_input();
        let a = b.and(x, y);
        let o = b.xor(a, x);
        let n = b.not(o);
        let circ = b.build(vec![a, o, n]);
        for x in [false, true] {
            for y in [false, true] {
                let out = run_garbled(&circ, &[x], &[y], 7);
                assert_eq!(out[0], x & y);
                assert_eq!(out[1], (x & y) ^ x);
                assert_eq!(out[2], !((x & y) ^ x));
            }
        }
    }

    #[test]
    fn garbled_adder_matches_plain() {
        let mut b = Builder::new();
        let x = b.garbler_inputs(12);
        let y = b.evaluator_inputs(12);
        let (s, c) = b.add(&x, &y);
        let mut outs = s;
        outs.push(c);
        let circ = b.build(outs);
        proptest::check_with_rng(17, 15, |rng| {
            let a = rng.gen_range(1 << 12);
            let bb = rng.gen_range(1 << 12);
            let out = run_garbled(&circ, &to_bits(a, 12), &to_bits(bb, 12), rng.next_u64());
            if from_bits(&out) == a + bb {
                Ok(())
            } else {
                Err(format!("{a}+{bb} != {}", from_bits(&out)))
            }
        });
    }

    #[test]
    fn garbled_relu_mod_p() {
        let p = 8380417u64;
        let circ = build_relu_mod_p(p, 0);
        let ell = 23;
        let mut rng = SplitMix64::new(5);
        for trial in 0..10 {
            let x = rng.gen_i64_range(-100_000, 100_000);
            let xm = x.rem_euclid(p as i64) as u64;
            let se = rng.gen_range(p);
            let sg = (xm + p - se) % p;
            let r = rng.gen_range(p);
            let mask = (p - r) % p;
            let mut gin = to_bits(sg, ell);
            gin.extend(to_bits(mask, ell));
            let out = run_garbled(&circ, &gin, &to_bits(se, ell), 100 + trial);
            let relu = if x > 0 { x as u64 } else { 0 };
            assert_eq!((from_bits(&out) + r) % p, relu, "x={x}");
        }
    }

    #[test]
    fn xor_gates_cost_no_tables() {
        let mut b = Builder::new();
        let x = b.garbler_input();
        let y = b.evaluator_input();
        let o1 = b.xor(x, y);
        let o2 = b.not(o1);
        let circ = b.build(vec![o2]);
        let mut rng = ChaCha20Rng::from_u64_seed(1);
        let (_, gc) = Garbler::garble(&circ, &mut rng);
        assert_eq!(gc.tables.len(), 0, "free-XOR violated");
    }

    #[test]
    fn wrong_labels_garble_output() {
        let mut b = Builder::new();
        let x = b.garbler_input();
        let y = b.evaluator_input();
        let a = b.and(x, y);
        let circ = b.build(vec![a]);
        let mut rng = ChaCha20Rng::from_u64_seed(2);
        let (g, gc) = Garbler::garble(&circ, &mut rng);
        let one = g.input_label(circ.one, true);
        let bogus: Label = [0xAA; 16];
        // Evaluating with a bogus label must not produce the honest result
        // deterministically — we just check it doesn't panic and that honest
        // evaluation still works afterwards.
        let _ = evaluate(&circ, &gc, one, &[bogus], &[g.input_label(circ.evaluator_inputs[0], true)]);
        let honest = evaluate(
            &circ,
            &gc,
            one,
            &[g.input_label(circ.garbler_inputs[0], true)],
            &[g.input_label(circ.evaluator_inputs[0], true)],
        );
        assert_eq!(honest[0], true);
    }
}
