//! The MLaaS TCP server: hosts trained models (from `artifacts/`), routes
//! framed requests into the dynamic batcher, and reports serving metrics.
//!
//! Wire protocol (length-prefixed frames, `transport::write_frame`):
//! * `0x01` INFER  — payload: f64-LE image pixels → reply `0x81` with
//!   `argmax (u32)` + logits (f64-LE).
//! * `0x02` STATS  — reply `0x82` with a text summary.
//! * `0x03` BYE    — close the session.

use super::batcher::{spawn_batcher, BatcherHandle, BatchPolicy};
use super::metrics::Metrics;
use crate::engine::InferenceEngine;
use crate::nn::{Network, Tensor};
use crate::protocol::transport::{read_frame, write_frame};
use std::io::Write as _;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// Client → server: score one image (f64-LE pixel payload).
pub const TAG_INFER: u8 = 0x01;
/// Client → server: request a text metrics summary.
pub const TAG_STATS: u8 = 0x02;
/// Client → server: close the session.
pub const TAG_BYE: u8 = 0x03;
/// Server → client: inference reply (`argmax (u32)` + f64-LE logits).
pub const TAG_INFER_OK: u8 = 0x81;
/// Server → client: metrics summary reply (UTF-8 text).
pub const TAG_STATS_OK: u8 = 0x82;

/// A TCP listener that blocks in `accept` (no busy-poll) but can be stopped
/// from another thread: set the stop flag, then [`StoppableListener::wake`]
/// makes a throw-away self-connection to unblock the pending `accept`.
/// Shared by the plaintext coordinator and the secure `serve` listener.
pub struct StoppableListener {
    listener: TcpListener,
    stop: Arc<AtomicBool>,
    /// The locally bound address (resolved, e.g. after a `:0` bind).
    pub addr: std::net::SocketAddr,
}

impl StoppableListener {
    /// Bind `addr` (standard `host:port` syntax; port `0` picks a free one).
    pub fn bind(addr: &str) -> std::io::Result<Self> {
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        Ok(Self { listener, stop: Arc::new(AtomicBool::new(false)), addr })
    }

    /// The shared stop flag; setting it (plus a `wake`) ends the accept loop.
    pub fn stop_flag(&self) -> Arc<AtomicBool> {
        self.stop.clone()
    }

    /// Blocking accept. Returns `None` once the stop flag is set (the wakeup
    /// connection itself is swallowed). Transient errors never kill the
    /// accept loop: a peer that resets before `accept` completes
    /// (ECONNABORTED/ECONNRESET) is retried immediately, and resource
    /// exhaustion (EMFILE etc.) backs off briefly and retries — the stop
    /// flag is rechecked every iteration, so shutdown still works.
    pub fn accept(&self) -> Option<TcpStream> {
        loop {
            if self.stop.load(Ordering::SeqCst) {
                return None;
            }
            match self.listener.accept() {
                Ok((stream, _)) => {
                    if self.stop.load(Ordering::SeqCst) {
                        return None;
                    }
                    return Some(stream);
                }
                Err(ref e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::Interrupted
                            | std::io::ErrorKind::ConnectionAborted
                            | std::io::ErrorKind::ConnectionReset
                    ) =>
                {
                    continue
                }
                Err(_) => std::thread::sleep(std::time::Duration::from_millis(50)),
            }
        }
    }

    /// Unblock a pending `accept` on `addr` after its stop flag was set.
    /// Wildcard binds (`0.0.0.0` / `[::]`) are rewritten to loopback — you
    /// cannot connect to an unspecified address on every platform.
    pub fn wake(addr: std::net::SocketAddr) {
        use std::net::{IpAddr, Ipv4Addr, Ipv6Addr};
        let mut addr = addr;
        if addr.ip().is_unspecified() {
            addr.set_ip(match addr.ip() {
                IpAddr::V4(_) => IpAddr::V4(Ipv4Addr::LOCALHOST),
                IpAddr::V6(_) => IpAddr::V6(Ipv6Addr::LOCALHOST),
            });
        }
        let _ = TcpStream::connect(addr);
    }
}

/// Tracked live connections — `(fd clone, thread handle)` pairs with
/// per-accept reaping — shared by the plaintext and secure servers so the
/// bookkeeping (and any future fix to it) lives in one place.
pub struct LiveConns {
    inner: Mutex<Vec<(TcpStream, JoinHandle<()>)>>,
}

impl LiveConns {
    /// An empty tracker, shared behind an `Arc`.
    pub fn new() -> Arc<Self> {
        Arc::new(Self { inner: Mutex::new(Vec::new()) })
    }

    /// Reap finished entries (dropping their fd clones, joining their
    /// threads), then track a new connection.
    pub fn track(&self, stream: TcpStream, handle: JoinHandle<()>) {
        let mut guard = self.inner.lock().unwrap();
        let mut live = Vec::with_capacity(guard.len() + 1);
        for (s, h) in guard.drain(..) {
            if h.is_finished() {
                let _ = h.join();
            } else {
                live.push((s, h));
            }
        }
        live.push((stream, handle));
        *guard = live;
    }

    /// Close every tracked socket (unblocking reads), then join every
    /// thread.
    pub fn close_and_join(&self) {
        let conns: Vec<(TcpStream, JoinHandle<()>)> =
            self.inner.lock().unwrap().drain(..).collect();
        for (s, _) in &conns {
            let _ = s.shutdown(std::net::Shutdown::Both);
        }
        for (_, h) in conns {
            let _ = h.join();
        }
    }
}

/// Shared shutdown prologue: set the stop flag, wake the blocking accept,
/// and join the accept thread. Idempotent.
pub fn stop_accept_thread(
    stop: &AtomicBool,
    addr: std::net::SocketAddr,
    accept_thread: &Mutex<Option<JoinHandle<()>>>,
) {
    stop.store(true, Ordering::SeqCst);
    StoppableListener::wake(addr);
    if let Some(h) = accept_thread.lock().unwrap().take() {
        let _ = h.join();
    }
}

/// A running server handle.
pub struct Server {
    /// The bound serving address.
    pub addr: std::net::SocketAddr,
    /// Live latency/throughput recorder (shared with the batcher).
    pub metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
    /// Total sessions accepted since start.
    pub sessions: Arc<AtomicU64>,
    accept_thread: Mutex<Option<JoinHandle<()>>>,
    live_sessions: Arc<LiveConns>,
}

impl Server {
    /// Serve `net` (plaintext scoring path) on `addr` with the given batch
    /// policy; returns once the listener is bound (serving continues on
    /// background threads). Convenience wrapper over [`Server::serve_engine`]
    /// with a [`crate::engine::PlaintextFloatEngine`] scorer.
    pub fn serve(net: Network, addr: &str, policy: BatchPolicy) -> std::io::Result<Server> {
        let shape = net.input_shape;
        let engine = Box::new(crate::engine::PlaintextFloatEngine::new(net));
        Self::serve_engine(engine, shape, addr, policy)
    }

    /// Serve any [`crate::engine::InferenceEngine`] behind the dynamic
    /// batcher — the scoring path is backend-agnostic: a quantized mirror,
    /// an in-process CHEETAH deployment, or a networked client all drop in.
    /// `input_shape` describes the flat pixel payload clients send. Each
    /// collected batch is dispatched as **one** `infer_batch` call, so the
    /// in-process engines fan the queries across the [`crate::par`] pool.
    pub fn serve_engine(
        mut engine: Box<dyn InferenceEngine>,
        input_shape: (usize, usize, usize),
        addr: &str,
        policy: BatchPolicy,
    ) -> std::io::Result<Server> {
        let listener = StoppableListener::bind(addr)?;
        let local = listener.addr;
        let metrics = Arc::new(Metrics::new());
        let stop = listener.stop_flag();
        let sessions = Arc::new(AtomicU64::new(0));
        let live_sessions = LiveConns::new();

        let (c, h, w) = input_shape;
        let handle = spawn_batcher(policy, metrics.clone(), move |batch| {
            let tensors: Vec<Tensor> =
                batch.iter().map(|flat| Tensor::from_vec(flat.clone(), c, h, w)).collect();
            match engine.infer_batch(&tensors) {
                Ok(reps) => reps.into_iter().map(|r| r.logits).collect(),
                Err(e) => {
                    // Score path must never kill the batcher: reply with
                    // empty logits (argmax 0) and keep serving.
                    eprintln!("scoring engine failed: {e}");
                    batch.iter().map(|_| Vec::new()).collect()
                }
            }
        });

        let accept_thread = {
            let metrics = metrics.clone();
            let sessions = sessions.clone();
            let live_sessions = live_sessions.clone();
            std::thread::spawn(move || {
                while let Some(stream) = listener.accept() {
                    sessions.fetch_add(1, Ordering::Relaxed);
                    let clone = match stream.try_clone() {
                        Ok(c) => c,
                        Err(_) => continue,
                    };
                    let h = handle.clone();
                    let m = metrics.clone();
                    let jh = std::thread::spawn(move || {
                        let _ = handle_session(stream, h, m);
                    });
                    live_sessions.track(clone, jh);
                }
            })
        };
        Ok(Server {
            addr: local,
            metrics,
            stop,
            sessions,
            accept_thread: Mutex::new(Some(accept_thread)),
            live_sessions,
        })
    }

    /// Stop accepting, close every live session socket, and join all
    /// server-owned threads. Idempotent; safe to call from any thread.
    pub fn shutdown(&self) {
        stop_accept_thread(&self.stop, self.addr, &self.accept_thread);
        // Closing the sockets unblocks session threads parked in read_frame.
        self.live_sessions.close_and_join();
    }
}

fn handle_session(
    mut stream: TcpStream,
    batcher: BatcherHandle,
    metrics: Arc<Metrics>,
) -> std::io::Result<()> {
    loop {
        let (tag, payload) = match read_frame(&mut stream) {
            Ok(f) => f,
            Err(_) => return Ok(()), // peer hung up
        };
        match tag {
            TAG_INFER => {
                let pixels: Vec<f64> = payload
                    .chunks_exact(8)
                    .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                let resp = batcher.infer_blocking(pixels);
                if resp.logits.is_empty() {
                    // The scoring engine failed for this batch (see
                    // serve_engine); the wire protocol has no error tag, so
                    // drop the connection rather than reply with a fake
                    // class-0 prediction.
                    return Ok(());
                }
                let mut out = Vec::with_capacity(4 + resp.logits.len() * 8);
                out.extend_from_slice(&(resp.argmax as u32).to_le_bytes());
                for l in &resp.logits {
                    out.extend_from_slice(&l.to_le_bytes());
                }
                write_frame(&mut stream, TAG_INFER_OK, &out)?;
            }
            TAG_STATS => {
                let s = metrics.summary();
                let text = format!(
                    "requests={} batches={} mean_batch={:.2} p50={:?} p95={:?} p99={:?}",
                    s.requests, s.batches, s.mean_batch, s.p50, s.p95, s.p99
                );
                write_frame(&mut stream, TAG_STATS_OK, text.as_bytes())?;
            }
            TAG_BYE => {
                stream.flush()?;
                return Ok(());
            }
            other => {
                eprintln!("unknown frame tag {other}");
                return Ok(());
            }
        }
    }
}

/// A minimal blocking client for the serving protocol.
pub struct Client {
    stream: TcpStream,
}

impl Client {
    /// Connect to a running [`Server`].
    pub fn connect(addr: &std::net::SocketAddr) -> std::io::Result<Self> {
        Ok(Self { stream: TcpStream::connect(addr)? })
    }

    /// Score one image; returns `(argmax, logits)`.
    pub fn infer(&mut self, pixels: &[f64]) -> std::io::Result<(usize, Vec<f64>)> {
        let mut payload = Vec::with_capacity(pixels.len() * 8);
        for p in pixels {
            payload.extend_from_slice(&p.to_le_bytes());
        }
        write_frame(&mut self.stream, TAG_INFER, &payload)?;
        let (tag, resp) = read_frame(&mut self.stream)?;
        assert_eq!(tag, TAG_INFER_OK);
        let argmax = u32::from_le_bytes(resp[..4].try_into().unwrap()) as usize;
        let logits =
            resp[4..].chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect();
        Ok((argmax, logits))
    }

    /// Fetch the server's text metrics summary.
    pub fn stats(&mut self) -> std::io::Result<String> {
        write_frame(&mut self.stream, TAG_STATS, &[])?;
        let (tag, resp) = read_frame(&mut self.stream)?;
        assert_eq!(tag, TAG_STATS_OK);
        Ok(String::from_utf8_lossy(&resp).into_owned())
    }

    /// Announce an orderly close.
    pub fn bye(&mut self) -> std::io::Result<()> {
        write_frame(&mut self.stream, TAG_BYE, &[])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{NetworkArch, SyntheticDigits};

    #[test]
    fn serve_and_query_over_tcp() {
        let net = Network::build(NetworkArch::NetA, 5);
        let reference = net.clone();
        let server = Server::serve(net, "127.0.0.1:0", BatchPolicy::default()).unwrap();

        let mut gen = SyntheticDigits::new(28, 17);
        let mut client = Client::connect(&server.addr).unwrap();
        for s in gen.batch(6) {
            let (argmax, logits) = client.infer(&s.image.data).unwrap();
            let want = reference.forward(&s.image);
            assert_eq!(argmax, want.argmax());
            assert_eq!(logits.len(), 10);
        }
        let stats = client.stats().unwrap();
        assert!(stats.contains("requests=6"), "{stats}");
        client.bye().unwrap();
        server.shutdown();
        assert!(server.metrics.summary().requests >= 6);
    }

    /// The scoring path is engine-generic: a quantized-mirror backend drops
    /// in behind the same batcher + wire protocol.
    #[test]
    fn serve_engine_scores_through_quantized_backend() {
        use crate::engine::{Backend, EngineBuilder};
        use crate::fixed::ScalePlan;
        let net = Network::build(NetworkArch::NetA, 5);
        let shape = net.input_shape;
        let engine = EngineBuilder::new(Backend::PlaintextQuantized)
            .network(net.clone())
            .build()
            .unwrap();
        let server =
            Server::serve_engine(engine, shape, "127.0.0.1:0", BatchPolicy::default()).unwrap();
        let sample = SyntheticDigits::new(28, 17).render(3);
        let mut client = Client::connect(&server.addr).unwrap();
        let (argmax, logits) = client.infer(&sample.image.data).unwrap();
        assert_eq!(logits.len(), 10);
        // Oracle: the quantized mirror itself (ε = 0 is seed-independent).
        let q = net.forward_quantized(&sample.image, &ScalePlan::default_plan(), 0.0, 0);
        let want = q.iter().enumerate().max_by_key(|(_, &v)| v).unwrap().0;
        assert_eq!(argmax, want);
        client.bye().unwrap();
        server.shutdown();
    }

    /// Shutdown must join the accept/session threads and close live
    /// sessions even while a client is still connected mid-protocol — no
    /// leaked threads, no busy-poll keeping the listener alive.
    #[test]
    fn shutdown_joins_threads_and_closes_sessions() {
        let net = Network::build(NetworkArch::NetA, 6);
        let server = Server::serve(net, "127.0.0.1:0", BatchPolicy::default()).unwrap();
        let addr = server.addr;
        // An idle session parked in read_frame.
        let _client = Client::connect(&addr).unwrap();
        server.shutdown();
        server.shutdown(); // idempotent
        // The listener is gone: new connections are refused.
        assert!(
            std::net::TcpStream::connect(addr).is_err(),
            "listener still accepting after shutdown"
        );
    }

    #[test]
    fn stoppable_listener_wakes_out_of_blocking_accept() {
        let listener = StoppableListener::bind("127.0.0.1:0").unwrap();
        let stop = listener.stop_flag();
        let addr = listener.addr;
        let t = std::thread::spawn(move || listener.accept().is_none());
        stop.store(true, Ordering::SeqCst);
        StoppableListener::wake(addr);
        assert!(t.join().unwrap(), "accept should return None after stop+wake");
    }
}
