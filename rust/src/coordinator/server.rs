//! The MLaaS TCP server: hosts trained models (from `artifacts/`), routes
//! framed requests into the dynamic batcher, and reports serving metrics.
//!
//! Wire protocol (length-prefixed frames, `transport::write_frame`):
//! * `0x01` INFER  — payload: f64-LE image pixels → reply `0x81` with
//!   `argmax (u32)` + logits (f64-LE).
//! * `0x02` STATS  — reply `0x82` with a text summary.
//! * `0x03` BYE    — close the session.

use super::batcher::{spawn_batcher, BatcherHandle, BatchPolicy};
use super::metrics::Metrics;
use crate::nn::{Network, Tensor};
use crate::protocol::transport::{read_frame, write_frame};
use std::io::Write as _;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

pub const TAG_INFER: u8 = 0x01;
pub const TAG_STATS: u8 = 0x02;
pub const TAG_BYE: u8 = 0x03;
pub const TAG_INFER_OK: u8 = 0x81;
pub const TAG_STATS_OK: u8 = 0x82;

/// A running server handle.
pub struct Server {
    pub addr: std::net::SocketAddr,
    pub metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
    pub sessions: Arc<AtomicU64>,
}

impl Server {
    /// Serve `net` (plaintext scoring path) on `addr` with the given batch
    /// policy; returns once the listener is bound (serving continues on
    /// background threads).
    pub fn serve(net: Network, addr: &str, policy: BatchPolicy) -> std::io::Result<Server> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let metrics = Arc::new(Metrics::new());
        let stop = Arc::new(AtomicBool::new(false));
        let sessions = Arc::new(AtomicU64::new(0));

        let shape = net.input_shape;
        let scorer_net = net;
        let handle = spawn_batcher(policy, metrics.clone(), move |batch| {
            batch
                .iter()
                .map(|flat| {
                    let t = Tensor::from_vec(flat.clone(), shape.0, shape.1, shape.2);
                    scorer_net.forward(&t).data
                })
                .collect()
        });

        {
            let stop = stop.clone();
            let metrics = metrics.clone();
            let sessions = sessions.clone();
            std::thread::spawn(move || {
                listener.set_nonblocking(true).ok();
                loop {
                    if stop.load(Ordering::Relaxed) {
                        return;
                    }
                    match listener.accept() {
                        Ok((stream, _)) => {
                            sessions.fetch_add(1, Ordering::Relaxed);
                            let h = handle.clone();
                            let m = metrics.clone();
                            std::thread::spawn(move || {
                                let _ = handle_session(stream, h, m);
                            });
                        }
                        Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(1));
                        }
                        Err(_) => return,
                    }
                }
            });
        }
        Ok(Server { addr: local, metrics, stop, sessions })
    }

    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::Relaxed);
    }
}

fn handle_session(
    mut stream: TcpStream,
    batcher: BatcherHandle,
    metrics: Arc<Metrics>,
) -> std::io::Result<()> {
    loop {
        let (tag, payload) = match read_frame(&mut stream) {
            Ok(f) => f,
            Err(_) => return Ok(()), // peer hung up
        };
        match tag {
            TAG_INFER => {
                let pixels: Vec<f64> = payload
                    .chunks_exact(8)
                    .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                let resp = batcher.infer_blocking(pixels);
                let mut out = Vec::with_capacity(4 + resp.logits.len() * 8);
                out.extend_from_slice(&(resp.argmax as u32).to_le_bytes());
                for l in &resp.logits {
                    out.extend_from_slice(&l.to_le_bytes());
                }
                write_frame(&mut stream, TAG_INFER_OK, &out)?;
            }
            TAG_STATS => {
                let s = metrics.summary();
                let text = format!(
                    "requests={} batches={} mean_batch={:.2} p50={:?} p95={:?} p99={:?}",
                    s.requests, s.batches, s.mean_batch, s.p50, s.p95, s.p99
                );
                write_frame(&mut stream, TAG_STATS_OK, text.as_bytes())?;
            }
            TAG_BYE => {
                stream.flush()?;
                return Ok(());
            }
            other => {
                eprintln!("unknown frame tag {other}");
                return Ok(());
            }
        }
    }
}

/// A minimal blocking client for the serving protocol.
pub struct Client {
    stream: TcpStream,
}

impl Client {
    pub fn connect(addr: &std::net::SocketAddr) -> std::io::Result<Self> {
        Ok(Self { stream: TcpStream::connect(addr)? })
    }

    pub fn infer(&mut self, pixels: &[f64]) -> std::io::Result<(usize, Vec<f64>)> {
        let mut payload = Vec::with_capacity(pixels.len() * 8);
        for p in pixels {
            payload.extend_from_slice(&p.to_le_bytes());
        }
        write_frame(&mut self.stream, TAG_INFER, &payload)?;
        let (tag, resp) = read_frame(&mut self.stream)?;
        assert_eq!(tag, TAG_INFER_OK);
        let argmax = u32::from_le_bytes(resp[..4].try_into().unwrap()) as usize;
        let logits =
            resp[4..].chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect();
        Ok((argmax, logits))
    }

    pub fn stats(&mut self) -> std::io::Result<String> {
        write_frame(&mut self.stream, TAG_STATS, &[])?;
        let (tag, resp) = read_frame(&mut self.stream)?;
        assert_eq!(tag, TAG_STATS_OK);
        Ok(String::from_utf8_lossy(&resp).into_owned())
    }

    pub fn bye(&mut self) -> std::io::Result<()> {
        write_frame(&mut self.stream, TAG_BYE, &[])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{NetworkArch, SyntheticDigits};

    #[test]
    fn serve_and_query_over_tcp() {
        let net = Network::build(NetworkArch::NetA, 5);
        let reference = net.clone();
        let server = Server::serve(net, "127.0.0.1:0", BatchPolicy::default()).unwrap();

        let mut gen = SyntheticDigits::new(28, 17);
        let mut client = Client::connect(&server.addr).unwrap();
        for s in gen.batch(6) {
            let (argmax, logits) = client.infer(&s.image.data).unwrap();
            let want = reference.forward(&s.image);
            assert_eq!(argmax, want.argmax());
            assert_eq!(logits.len(), 10);
        }
        let stats = client.stats().unwrap();
        assert!(stats.contains("requests=6"), "{stats}");
        client.bye().unwrap();
        server.shutdown();
        assert!(server.metrics.summary().requests >= 6);
    }
}
