//! The MLaaS coordinator — the serving layer around the private-inference
//! protocols (paper Fig. 1: client → cloud service hosting the model).
//!
//! * [`batcher`] — dynamic request batching (max-batch + linger window),
//! * [`server`] — framed TCP serving of trained models with per-session
//!   threads and live metrics,
//! * [`metrics`] — latency percentiles / throughput counters, built on
//!   the lock-free [`crate::obs`] histogram.
//!
//! Two serving paths share this infrastructure: the *plaintext* scorer
//! (trusted-cloud baseline; runs the PJRT artifacts or the native forward
//! pass) and the *private* CHEETAH path (`examples/serve_mlaas.rs` drives
//! both and reports the privacy overhead).

pub mod batcher;
pub mod metrics;
pub mod server;

pub use batcher::{BatchPolicy, BatcherHandle, Response};
pub use metrics::{Metrics, Summary};
pub use server::{Client, Server, StoppableListener};
