//! Serving metrics: latency percentiles and throughput counters.

use std::sync::Mutex;
use std::time::Duration;

/// A concurrent latency/throughput recorder.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Default)]
struct Inner {
    latencies_us: Vec<u64>,
    requests: u64,
    batches: u64,
    batch_sizes: u64,
}

/// A point-in-time summary.
#[derive(Clone, Copy, Debug, Default)]
pub struct Summary {
    pub requests: u64,
    pub batches: u64,
    pub mean_batch: f64,
    pub p50: Duration,
    pub p95: Duration,
    pub p99: Duration,
    pub max: Duration,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_request(&self, latency: Duration) {
        let mut g = self.inner.lock().unwrap();
        g.latencies_us.push(latency.as_micros() as u64);
        g.requests += 1;
    }

    pub fn record_batch(&self, size: usize) {
        let mut g = self.inner.lock().unwrap();
        g.batches += 1;
        g.batch_sizes += size as u64;
    }

    pub fn summary(&self) -> Summary {
        let g = self.inner.lock().unwrap();
        let mut lat = g.latencies_us.clone();
        lat.sort_unstable();
        let pick = |q: f64| -> Duration {
            if lat.is_empty() {
                return Duration::ZERO;
            }
            let idx = ((lat.len() as f64 - 1.0) * q).round() as usize;
            Duration::from_micros(lat[idx])
        };
        Summary {
            requests: g.requests,
            batches: g.batches,
            mean_batch: if g.batches > 0 { g.batch_sizes as f64 / g.batches as f64 } else { 0.0 },
            p50: pick(0.50),
            p95: pick(0.95),
            p99: pick(0.99),
            max: pick(1.0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let m = Metrics::new();
        for i in 1..=100u64 {
            m.record_request(Duration::from_micros(i * 100));
        }
        m.record_batch(4);
        m.record_batch(8);
        let s = m.summary();
        assert_eq!(s.requests, 100);
        assert_eq!(s.mean_batch, 6.0);
        assert!(s.p50 >= Duration::from_micros(4900) && s.p50 <= Duration::from_micros(5200));
        assert_eq!(s.max, Duration::from_micros(10000));
        assert!(s.p99 >= s.p95 && s.p95 >= s.p50);
    }

    #[test]
    fn empty_summary_is_zero() {
        let s = Metrics::new().summary();
        assert_eq!(s.requests, 0);
        assert_eq!(s.p99, Duration::ZERO);
    }
}
