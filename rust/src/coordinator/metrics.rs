//! Serving metrics: latency percentiles and throughput counters.
//!
//! Built on the telemetry histogram ([`crate::obs::Hist`]): recording is
//! a few relaxed atomic ops with **no lock and no allocation**, and
//! memory is a fixed bucket array for the life of the process. (The
//! original implementation pushed every latency into a `Vec` under a
//! mutex and clone-and-sorted it per summary — unbounded growth and
//! O(n log n) on the read path.) Percentiles come from the log₂ bucket
//! layout, accurate to ≤3.1%; `max` stays exact.

use crate::obs::Hist;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// A concurrent latency/throughput recorder.
#[derive(Default)]
pub struct Metrics {
    /// Request latencies in microseconds.
    latency_us: Hist,
    requests: AtomicU64,
    batches: AtomicU64,
    batch_sizes: AtomicU64,
}

/// A point-in-time summary.
#[derive(Clone, Copy, Debug, Default)]
pub struct Summary {
    /// Requests recorded so far.
    pub requests: u64,
    /// Batches dispatched so far.
    pub batches: u64,
    /// Mean requests per batch (0 when no batch was dispatched).
    pub mean_batch: f64,
    /// Median request latency (bucket-quantized, ≤3.1% error).
    pub p50: Duration,
    /// 95th-percentile request latency.
    pub p95: Duration,
    /// 99th-percentile request latency.
    pub p99: Duration,
    /// Maximum request latency (exact).
    pub max: Duration,
}

impl Metrics {
    /// An empty recorder.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one completed request's end-to-end latency.
    pub fn record_request(&self, latency: Duration) {
        self.latency_us.record(latency.as_micros() as u64);
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one dispatched batch of `size` requests.
    pub fn record_batch(&self, size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batch_sizes.fetch_add(size as u64, Ordering::Relaxed);
    }

    /// Capture a point-in-time summary. Lock-free; concurrent recorders
    /// may land between the counter and histogram reads.
    pub fn summary(&self) -> Summary {
        let h = self.latency_us.snapshot();
        let pick = |p: f64| -> Duration {
            if h.count == 0 {
                return Duration::ZERO;
            }
            Duration::from_micros(h.percentile(p))
        };
        let batches = self.batches.load(Ordering::Relaxed);
        let batch_sizes = self.batch_sizes.load(Ordering::Relaxed);
        Summary {
            requests: self.requests.load(Ordering::Relaxed),
            batches,
            mean_batch: if batches > 0 { batch_sizes as f64 / batches as f64 } else { 0.0 },
            p50: pick(50.0),
            p95: pick(95.0),
            p99: pick(99.0),
            max: if h.count == 0 { Duration::ZERO } else { Duration::from_micros(h.max) },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let m = Metrics::new();
        for i in 1..=100u64 {
            m.record_request(Duration::from_micros(i * 100));
        }
        m.record_batch(4);
        m.record_batch(8);
        let s = m.summary();
        assert_eq!(s.requests, 100);
        assert_eq!(s.mean_batch, 6.0);
        assert!(s.p50 >= Duration::from_micros(4900) && s.p50 <= Duration::from_micros(5200));
        assert_eq!(s.max, Duration::from_micros(10000), "max must stay exact");
        assert!(s.p99 >= s.p95 && s.p95 >= s.p50);
    }

    #[test]
    fn empty_summary_is_zero() {
        let s = Metrics::new().summary();
        assert_eq!(s.requests, 0);
        assert_eq!(s.p99, Duration::ZERO);
        assert_eq!(s.max, Duration::ZERO);
    }

    #[test]
    fn concurrent_recording_loses_nothing() {
        let m = std::sync::Arc::new(Metrics::new());
        let handles: Vec<_> = (0..4)
            .map(|t| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for i in 0..250u64 {
                        m.record_request(Duration::from_micros(100 + t * 250 + i));
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let s = m.summary();
        assert_eq!(s.requests, 1000);
        assert_eq!(s.max, Duration::from_micros(100 + 3 * 250 + 249));
    }
}
