//! Dynamic request batcher: collects inference requests until the batch is
//! full or the linger timer fires, then hands the batch to a scorer — the
//! standard MLaaS serving pattern (vLLM-style continuous batching,
//! simplified to fixed windows since CNN inference has no autoregressive
//! state).
//!
//! The scorer sees the **whole batch at once** (`score(&[inputs])`), and
//! the engine-backed scorer (`Server::serve_engine`) forwards it to
//! `InferenceEngine::infer_batch` — one fork-join region over the
//! [`crate::par`] pool, so queries that were queued together are scored
//! concurrently instead of back to back. Batch logits are bit-identical to
//! sequential scoring (per-query RNG stream isolation in the protocol
//! backends), so batching is purely a throughput knob.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// One queued request: input image (flattened) + reply channel.
pub struct Request {
    /// Flattened input pixels.
    pub input: Vec<f64>,
    /// When the request entered the queue (latency epoch).
    pub enqueued: Instant,
    /// Where the scored [`Response`] is delivered.
    pub reply: Sender<Response>,
}

/// Scored response.
#[derive(Clone, Debug)]
pub struct Response {
    /// Per-class scores.
    pub logits: Vec<f64>,
    /// Index of the winning class.
    pub argmax: usize,
    /// Queue-to-reply latency.
    pub latency: Duration,
}

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Dispatch as soon as this many requests are queued.
    pub max_batch: usize,
    /// Dispatch a partial batch after waiting this long for more.
    pub linger: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self { max_batch: 16, linger: Duration::from_millis(2) }
    }
}

/// The batcher queue handle (clone to submit from many threads).
#[derive(Clone)]
pub struct BatcherHandle {
    tx: Sender<Request>,
}

impl BatcherHandle {
    /// Submit an input and wait for its response.
    pub fn infer_blocking(&self, input: Vec<f64>) -> Response {
        let (tx, rx) = channel();
        self.tx
            .send(Request { input, enqueued: Instant::now(), reply: tx })
            .expect("batcher gone");
        rx.recv().expect("batcher dropped reply")
    }
}

/// Run the batching loop on the current thread until the handle side hangs
/// up. `score` maps a batch of inputs to per-input logits.
pub fn run_batcher<F>(
    rx: Receiver<Request>,
    policy: BatchPolicy,
    metrics: Arc<crate::coordinator::metrics::Metrics>,
    mut score: F,
) where
    F: FnMut(&[Vec<f64>]) -> Vec<Vec<f64>>,
{
    loop {
        // Block for the first request of a batch.
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return,
        };
        let mut batch = vec![first];
        let deadline = Instant::now() + policy.linger;
        while batch.len() < policy.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => batch.push(r),
                Err(_) => break,
            }
        }
        metrics.record_batch(batch.len());
        let inputs: Vec<Vec<f64>> = batch.iter().map(|r| r.input.clone()).collect();
        let outputs = score(&inputs);
        for (req, logits) in batch.into_iter().zip(outputs) {
            let latency = req.enqueued.elapsed();
            metrics.record_request(latency);
            let argmax = logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap_or(0);
            let _ = req.reply.send(Response { logits, argmax, latency });
        }
    }
}

/// Spawn a batcher on a background thread; returns the submit handle.
pub fn spawn_batcher<F>(
    policy: BatchPolicy,
    metrics: Arc<crate::coordinator::metrics::Metrics>,
    score: F,
) -> BatcherHandle
where
    F: FnMut(&[Vec<f64>]) -> Vec<Vec<f64>> + Send + 'static,
{
    let (tx, rx) = channel();
    std::thread::spawn(move || run_batcher(rx, policy, metrics, score));
    BatcherHandle { tx }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::metrics::Metrics;

    #[test]
    fn batches_and_replies() {
        let metrics = Arc::new(Metrics::new());
        let handle = spawn_batcher(
            BatchPolicy { max_batch: 4, linger: Duration::from_millis(5) },
            metrics.clone(),
            |batch| {
                batch
                    .iter()
                    .map(|x| vec![x.iter().sum::<f64>(), 0.0])
                    .collect()
            },
        );
        let mut threads = Vec::new();
        for i in 0..8 {
            let h = handle.clone();
            threads.push(std::thread::spawn(move || h.infer_blocking(vec![i as f64; 3])));
        }
        for (i, t) in threads.into_iter().enumerate() {
            let resp = t.join().unwrap();
            assert_eq!(resp.logits[0], (i as f64) * 3.0);
            assert_eq!(resp.argmax, if i == 0 { 1 } else { 0 });
        }
        let s = metrics.summary();
        assert_eq!(s.requests, 8);
        assert!(s.batches >= 2, "expected batching, got {} batches", s.batches);
        assert!(s.mean_batch > 1.0, "no batching happened");
    }
}
