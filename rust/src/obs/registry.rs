//! Global metric registry: a lock-free interning table mapping static
//! metric names to heap-pinned [`Metric`] cells.
//!
//! The hot path (`intern` on an already-registered name) is a hash plus a
//! short linear probe over an `AtomicPtr` slot array — no lock, no
//! allocation. A name's first use allocates its `Metric` once and
//! publishes it with a compare-exchange; the loser of a racing first use
//! frees its candidate and adopts the winner's. Metrics live for the
//! process lifetime (`Box::leak`), which is what makes handing out
//! `&'static Metric` references sound.
//!
//! The table is fixed-capacity ([`TABLE_SLOTS`]). The span taxonomy is a
//! few dozen names, so the table never fills in practice; if it ever does,
//! further names all resolve to one shared `obs.overflow` metric instead
//! of failing — telemetry degrades, the program does not.

use super::hist::Hist;
use std::sync::atomic::{AtomicI64, AtomicPtr, AtomicU64, Ordering};
use std::sync::OnceLock;

/// Slot capacity of the interning table (power of two).
pub const TABLE_SLOTS: usize = 512;

/// What a metric measures — fixes how its cells are interpreted and how
/// the snapshot serializes it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MetricKind {
    /// Monotonic event count (`value` accumulates).
    Counter,
    /// Instantaneous level, settable and signed (`value` is last-set/±delta).
    Gauge,
    /// Duration/value distribution (records land in the histogram).
    Span,
}

impl MetricKind {
    /// Stable lowercase name used in the JSON snapshot schema.
    pub fn as_str(self) -> &'static str {
        match self {
            MetricKind::Counter => "counter",
            MetricKind::Gauge => "gauge",
            MetricKind::Span => "span",
        }
    }

    /// Parse the JSON schema name back into a kind.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "counter" => Some(MetricKind::Counter),
            "gauge" => Some(MetricKind::Gauge),
            "span" => Some(MetricKind::Span),
            _ => None,
        }
    }
}

/// One registered metric: a name, a kind, a scalar cell (counter/gauge)
/// and — for [`MetricKind::Span`] — a histogram. All mutation is atomic;
/// a `&'static Metric` can be recorded into from any thread.
pub struct Metric {
    name: &'static str,
    kind: MetricKind,
    value: AtomicI64,
    hist: Option<Hist>,
}

impl Metric {
    fn new(name: &'static str, kind: MetricKind) -> Self {
        Self {
            name,
            kind,
            value: AtomicI64::new(0),
            hist: (kind == MetricKind::Span).then(Hist::new),
        }
    }

    /// The interned metric name.
    pub fn name(&self) -> &'static str {
        self.name
    }

    /// The kind fixed at first registration.
    pub fn kind(&self) -> MetricKind {
        self.kind
    }

    /// Add to the scalar cell (counter increment or signed gauge delta).
    pub fn add(&self, delta: i64) {
        self.value.fetch_add(delta, Ordering::Relaxed);
    }

    /// Overwrite the scalar cell (gauge set).
    pub fn set(&self, v: i64) {
        self.value.store(v, Ordering::Relaxed);
    }

    /// Current scalar cell value.
    pub fn value(&self) -> i64 {
        self.value.load(Ordering::Relaxed)
    }

    /// Record a value into the histogram (no-op for non-span kinds).
    pub fn record(&self, v: u64) {
        if let Some(h) = &self.hist {
            h.record(v);
        }
    }

    /// The span histogram, when this metric has one.
    pub fn hist(&self) -> Option<&Hist> {
        self.hist.as_ref()
    }

    /// Zero every cell (bench/test scoping; not atomic vs recorders).
    pub fn reset(&self) {
        self.value.store(0, Ordering::Relaxed);
        if let Some(h) = &self.hist {
            h.reset();
        }
    }
}

struct Table {
    slots: Box<[AtomicPtr<Metric>]>,
    /// Names that could not be interned because the table filled.
    overflowed: AtomicU64,
}

static TABLE: OnceLock<Table> = OnceLock::new();

fn table() -> &'static Table {
    TABLE.get_or_init(|| Table {
        slots: (0..TABLE_SLOTS).map(|_| AtomicPtr::new(std::ptr::null_mut())).collect(),
        overflowed: AtomicU64::new(0),
    })
}

fn fnv1a(name: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in name.as_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The shared sink for names that arrive after the table filled.
fn overflow_metric() -> &'static Metric {
    static M: OnceLock<&'static Metric> = OnceLock::new();
    M.get_or_init(|| Box::leak(Box::new(Metric::new("obs.overflow", MetricKind::Span))))
}

/// Resolve `name` to its process-wide metric cell, registering it with
/// `kind` on first use. Lock-free; allocates only on a name's first use.
/// If the same name is first registered with a different kind, the first
/// registration wins.
pub fn intern(name: &'static str, kind: MetricKind) -> &'static Metric {
    let t = table();
    let h = fnv1a(name) as usize;
    for i in 0..TABLE_SLOTS {
        let slot = &t.slots[(h + i) & (TABLE_SLOTS - 1)];
        let p = slot.load(Ordering::Acquire);
        if p.is_null() {
            let candidate = Box::into_raw(Box::new(Metric::new(name, kind)));
            match slot.compare_exchange(
                std::ptr::null_mut(),
                candidate,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => return unsafe { &*candidate },
                Err(existing) => {
                    // Lost the race for this slot: free our candidate and
                    // inspect the winner.
                    drop(unsafe { Box::from_raw(candidate) });
                    let m = unsafe { &*existing };
                    if m.name == name {
                        return m;
                    }
                }
            }
        } else {
            let m = unsafe { &*p };
            if m.name == name {
                return m;
            }
        }
    }
    t.overflowed.fetch_add(1, Ordering::Relaxed);
    overflow_metric()
}

/// Every registered metric, sorted by name (snapshot iteration order).
pub fn all() -> Vec<&'static Metric> {
    let t = table();
    let mut out: Vec<&'static Metric> = t
        .slots
        .iter()
        .filter_map(|s| {
            let p = s.load(Ordering::Acquire);
            (!p.is_null()).then(|| unsafe { &*p })
        })
        .collect();
    out.sort_by_key(|m| m.name);
    out
}

/// Zero every registered metric (bench/test scoping; concurrent recorders
/// may land records mid-reset).
pub fn reset_all() {
    for m in all() {
        m.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interning_is_stable_and_kind_sticky() {
        let a = intern("obs.test.interning", MetricKind::Counter);
        let b = intern("obs.test.interning", MetricKind::Gauge);
        assert!(std::ptr::eq(a, b), "same name must intern to the same cell");
        assert_eq!(b.kind(), MetricKind::Counter, "first registration wins");
        let c = intern("obs.test.interning2", MetricKind::Counter);
        assert!(!std::ptr::eq(a, c));
    }

    #[test]
    fn counters_accumulate_and_spans_record() {
        let c = intern("obs.test.counter", MetricKind::Counter);
        let before = c.value();
        c.add(3);
        c.add(4);
        assert_eq!(c.value() - before, 7);

        let s = intern("obs.test.span", MetricKind::Span);
        let n0 = s.hist().unwrap().count();
        s.record(123);
        assert_eq!(s.hist().unwrap().count() - n0, 1);

        let g = intern("obs.test.gauge", MetricKind::Gauge);
        g.set(9);
        g.add(-4);
        assert_eq!(g.value(), 5);
    }

    #[test]
    fn concurrent_first_use_interns_one_cell() {
        let handles: Vec<_> = (0..8)
            .map(|_| {
                std::thread::spawn(|| {
                    intern("obs.test.race", MetricKind::Counter) as *const Metric as usize
                })
            })
            .collect();
        let ptrs: Vec<usize> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert!(ptrs.windows(2).all(|w| w[0] == w[1]), "racing interns diverged: {ptrs:?}");
    }

    #[test]
    fn all_lists_registered_names_sorted() {
        intern("obs.test.list.b", MetricKind::Counter);
        intern("obs.test.list.a", MetricKind::Counter);
        let names: Vec<&str> = all().iter().map(|m| m.name()).collect();
        let ia = names.iter().position(|n| *n == "obs.test.list.a").unwrap();
        let ib = names.iter().position(|n| *n == "obs.test.list.b").unwrap();
        assert!(ia < ib);
        let mut sorted = names.clone();
        sorted.sort_unstable();
        assert_eq!(names, sorted);
    }
}
