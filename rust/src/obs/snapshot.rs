//! Point-in-time telemetry snapshot and its JSON schema.
//!
//! One schema serves every surface: the `STATS` admin frame on the secure
//! server, the `--stats-addr` HTTP endpoint, and the `obs` section
//! `e2e_bench --obs` embeds in `BENCH_e2e.json`. The document is:
//!
//! ```json
//! {"version":1,
//!  "metrics":[
//!    {"name":"par.regions.forked","kind":"counter","value":42},
//!    {"name":"serve.pool.occupancy","kind":"gauge","value":2},
//!    {"name":"phe.mult_plain","kind":"span","count":9,"sum":12345,
//!     "min":800,"max":2100,"p50":1300,"p95":2000,"p99":2100,
//!     "buckets":[[161,4],[162,5]]}],
//!  "timeline":[["cheetah.online.step_linear",1042,350]]}
//! ```
//!
//! Span units are nanoseconds; timeline entries are
//! `[name, start_us, dur_us]` relative to the process telemetry epoch and
//! appear only at trace level. `p50/p95/p99` are derived from the buckets
//! at serialization time (with the documented one-bucket error bound), so
//! [`Snapshot::from_json`] → [`Snapshot::to_json`] reproduces the exact
//! document — the round-trip property the schema test pins.

use super::hist::HistSnapshot;
use super::json::{escape, Json, JsonError};
use super::registry::MetricKind;
use std::fmt::Write as _;

/// Schema version stamped into every document.
pub const SNAPSHOT_VERSION: i64 = 1;

/// One metric's point-in-time state.
#[derive(Clone, Debug, PartialEq)]
pub struct MetricSnapshot {
    /// Registered metric name.
    pub name: String,
    /// Counter, gauge, or span.
    pub kind: MetricKind,
    /// Scalar cell (counter total / gauge level; 0 for spans).
    pub value: i64,
    /// Histogram state (span metrics only).
    pub hist: Option<HistSnapshot>,
}

/// One timeline event (trace level only).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TimelineEvent {
    /// Span name.
    pub name: String,
    /// Start, µs since the telemetry epoch.
    pub start_us: u64,
    /// Duration in µs.
    pub dur_us: u64,
}

/// A full registry snapshot: every metric (sorted by name) plus the
/// recent timeline window.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Snapshot {
    /// All registered metrics, sorted by name.
    pub metrics: Vec<MetricSnapshot>,
    /// Recent span events (empty below trace level).
    pub timeline: Vec<TimelineEvent>,
}

impl Snapshot {
    /// Look a metric up by name.
    pub fn get(&self, name: &str) -> Option<&MetricSnapshot> {
        self.metrics.iter().find(|m| m.name == name)
    }

    /// Serialize to the canonical JSON document (see module docs).
    pub fn to_json(&self) -> String {
        let mut out = String::with_capacity(256 + self.metrics.len() * 96);
        let _ = write!(out, "{{\"version\":{SNAPSHOT_VERSION},\"metrics\":[");
        for (i, m) in self.metrics.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str("{\"name\":");
            escape(&mut out, &m.name);
            let _ = write!(out, ",\"kind\":\"{}\"", m.kind.as_str());
            match &m.hist {
                None => {
                    let _ = write!(out, ",\"value\":{}", m.value);
                }
                Some(h) => {
                    let _ = write!(
                        out,
                        ",\"count\":{},\"sum\":{},\"min\":{},\"max\":{},\
                         \"p50\":{},\"p95\":{},\"p99\":{},\"buckets\":[",
                        h.count,
                        h.sum,
                        h.min,
                        h.max,
                        h.percentile(50.0),
                        h.percentile(95.0),
                        h.percentile(99.0)
                    );
                    for (j, &(idx, c)) in h.buckets.iter().enumerate() {
                        if j > 0 {
                            out.push(',');
                        }
                        let _ = write!(out, "[{idx},{c}]");
                    }
                    out.push(']');
                }
            }
            out.push('}');
        }
        out.push_str("],\"timeline\":[");
        for (i, e) in self.timeline.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push('[');
            escape(&mut out, &e.name);
            let _ = write!(out, ",{},{}]", e.start_us, e.dur_us);
        }
        out.push_str("]}");
        out
    }

    /// Parse a document produced by [`Snapshot::to_json`]. Derived fields
    /// (`p50/p95/p99`) are ignored on input and recomputed on re-emit, so
    /// `from_json(to_json(s)).to_json() == to_json(s)`.
    pub fn from_json(doc: &str) -> Result<Snapshot, JsonError> {
        let v = Json::parse(doc)?;
        let bad = |msg: &'static str| JsonError { msg, at: 0 };
        if v.get("version").and_then(Json::as_i64) != Some(SNAPSHOT_VERSION) {
            return Err(bad("unsupported snapshot version"));
        }
        let mut metrics = Vec::new();
        for m in v.get("metrics").and_then(Json::as_arr).ok_or(bad("missing metrics"))? {
            let name = m
                .get("name")
                .and_then(Json::as_str)
                .ok_or(bad("metric missing name"))?
                .to_string();
            let kind = m
                .get("kind")
                .and_then(Json::as_str)
                .and_then(MetricKind::parse)
                .ok_or(bad("metric missing kind"))?;
            let hist = if kind == MetricKind::Span {
                let field = |k: &str| {
                    m.get(k)
                        .and_then(Json::as_i64)
                        .map(|v| v as u64)
                        .ok_or(bad("span metric missing histogram field"))
                };
                let mut buckets = Vec::new();
                for b in m.get("buckets").and_then(Json::as_arr).ok_or(bad("missing buckets"))? {
                    let pair = b.as_arr().ok_or(bad("bad bucket entry"))?;
                    let idx =
                        pair.first().and_then(Json::as_i64).ok_or(bad("bad bucket entry"))?;
                    let c = pair.get(1).and_then(Json::as_i64).ok_or(bad("bad bucket entry"))?;
                    buckets.push((idx as u64, c as u64));
                }
                Some(HistSnapshot {
                    count: field("count")?,
                    sum: field("sum")?,
                    min: field("min")?,
                    max: field("max")?,
                    buckets,
                })
            } else {
                None
            };
            let value = if hist.is_some() {
                0
            } else {
                m.get("value").and_then(Json::as_i64).ok_or(bad("metric missing value"))?
            };
            metrics.push(MetricSnapshot { name, kind, value, hist });
        }
        let mut timeline = Vec::new();
        for e in v.get("timeline").and_then(Json::as_arr).ok_or(bad("missing timeline"))? {
            let t = e.as_arr().ok_or(bad("bad timeline entry"))?;
            let name = t
                .first()
                .and_then(Json::as_str)
                .ok_or(bad("bad timeline entry"))?
                .to_string();
            let start_us = t.get(1).and_then(Json::as_i64).ok_or(bad("bad timeline entry"))?;
            let dur_us = t.get(2).and_then(Json::as_i64).ok_or(bad("bad timeline entry"))?;
            timeline.push(TimelineEvent {
                name,
                start_us: start_us as u64,
                dur_us: dur_us as u64,
            });
        }
        Ok(Snapshot { metrics, timeline })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::hist::Hist;

    fn sample_snapshot() -> Snapshot {
        let h = Hist::new();
        for v in [800u64, 1_300, 1_700, 2_100, 950_000] {
            h.record(v);
        }
        Snapshot {
            metrics: vec![
                MetricSnapshot {
                    name: "par.regions.forked".into(),
                    kind: MetricKind::Counter,
                    value: 42,
                    hist: None,
                },
                MetricSnapshot {
                    name: "phe.mult_plain".into(),
                    kind: MetricKind::Span,
                    value: 0,
                    hist: Some(h.snapshot()),
                },
                MetricSnapshot {
                    name: "serve.pool.occupancy".into(),
                    kind: MetricKind::Gauge,
                    value: -2,
                    hist: None,
                },
            ],
            timeline: vec![TimelineEvent {
                name: "cheetah.online.step_linear".into(),
                start_us: 1042,
                dur_us: 350,
            }],
        }
    }

    /// Satellite requirement: the snapshot schema round-trips.
    #[test]
    fn json_round_trip_is_lossless() {
        let snap = sample_snapshot();
        let doc = snap.to_json();
        let back = Snapshot::from_json(&doc).expect("own output must parse");
        assert_eq!(back, snap);
        assert_eq!(back.to_json(), doc, "re-serialization must be byte-identical");
    }

    #[test]
    fn lookup_and_percentiles_survive_the_wire() {
        let doc = sample_snapshot().to_json();
        let back = Snapshot::from_json(&doc).unwrap();
        let span = back.get("phe.mult_plain").unwrap();
        let h = span.hist.as_ref().unwrap();
        assert_eq!(h.count, 5);
        assert_eq!(h.max, 950_000, "max is exact through serialization");
        let p50 = h.percentile(50.0);
        assert!((1_250..=1_400).contains(&p50), "p50 {p50} out of expected bucket");
        assert_eq!(back.get("par.regions.forked").unwrap().value, 42);
        assert_eq!(back.get("serve.pool.occupancy").unwrap().value, -2);
        assert!(back.get("no.such.metric").is_none());
    }

    #[test]
    fn rejects_wrong_version_and_malformed_documents() {
        assert!(Snapshot::from_json("{\"version\":99,\"metrics\":[],\"timeline\":[]}").is_err());
        assert!(Snapshot::from_json("{\"metrics\":[]}").is_err());
        assert!(Snapshot::from_json("not json").is_err());
    }

    #[test]
    fn empty_snapshot_round_trips() {
        let empty = Snapshot::default();
        let doc = empty.to_json();
        assert_eq!(doc, "{\"version\":1,\"metrics\":[],\"timeline\":[]}");
        assert_eq!(Snapshot::from_json(&doc).unwrap(), empty);
    }
}
