//! Lock-free fixed-bucket log₂ histogram with linear sub-buckets.
//!
//! The recording path is wait-free and allocation-free: one `fetch_add` on
//! the count, one on the sum, a `fetch_max`/`fetch_min` pair, and one
//! `fetch_add` on the owning bucket — all `Relaxed`, so concurrent
//! recorders never contend on anything but cache lines. Bucket layout is
//! HDR-style: values below [`SUBS`] get exact unit buckets; above that,
//! each power-of-two range `[2^k, 2^(k+1))` is split into [`SUBS`] linear
//! sub-buckets, bounding the relative quantization error of any recorded
//! value by `1/SUBS` (≈3.1%). Percentile estimates interpolate by rank
//! inside the owning bucket and are clamped to the exact tracked min/max,
//! so `max()` is always exact and percentile error is bounded by one
//! bucket width.
//!
//! The histogram is unit-agnostic (it records `u64` values); the
//! conventions in this crate are nanoseconds for [`crate::obs::span`]
//! timings and microseconds for [`crate::coordinator::metrics`] request
//! latencies.

use std::sync::atomic::{AtomicU64, Ordering};

/// log₂ of the number of linear sub-buckets per power-of-two range.
pub const SUB_BITS: u32 = 5;
/// Linear sub-buckets per power-of-two range (relative error ≤ `1/SUBS`).
pub const SUBS: usize = 1 << SUB_BITS;
/// Total bucket count covering the full `u64` range.
/// Groups run `g = 0` (exact values `0..SUBS`) through `g = 64 - SUB_BITS`.
pub const N_BUCKETS: usize = SUBS * (64 - SUB_BITS as usize + 1);

/// Bucket index owning value `v`. Values below [`SUBS`] map exactly;
/// larger values map to `32·g + sub` where `g` is the power-of-two group
/// and `sub` the linear sub-bucket within it.
pub fn bucket_of(v: u64) -> usize {
    if v < SUBS as u64 {
        return v as usize;
    }
    let bit_len = 64 - v.leading_zeros(); // ≥ SUB_BITS + 1
    let g = (bit_len - SUB_BITS) as usize; // ≥ 1
    let sub = (v >> (g - 1)) as usize - SUBS;
    g * SUBS + sub
}

/// Half-open value range `[lo, hi)` covered by bucket `i` (the top bucket
/// saturates at `u64::MAX`).
pub fn bucket_bounds(i: usize) -> (u64, u64) {
    if i < SUBS {
        return (i as u64, i as u64 + 1);
    }
    let g = i / SUBS;
    let sub = (i % SUBS) as u64;
    let width = 1u64 << (g - 1);
    let lo = (SUBS as u64 + sub) << (g - 1);
    (lo, lo.saturating_add(width))
}

/// A thread-safe latency/value histogram. See the module docs for the
/// bucket layout and accuracy bounds.
pub struct Hist {
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
    min: AtomicU64,
    buckets: Box<[AtomicU64]>,
}

impl Default for Hist {
    fn default() -> Self {
        Self::new()
    }
}

impl Hist {
    /// An empty histogram (allocates its bucket array once, here — the
    /// recording path never allocates).
    pub fn new() -> Self {
        Self {
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            buckets: (0..N_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    /// Record one value. Wait-free; safe from any number of threads.
    pub fn record(&self, v: u64) {
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
        self.min.fetch_min(v, Ordering::Relaxed);
        self.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
    }

    /// Values recorded so far.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Exact maximum recorded value (0 when empty).
    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// Fold another histogram into this one (bucket-wise addition; min/max
    /// combine exactly). Used to aggregate per-shard histograms.
    pub fn merge(&self, other: &Hist) {
        self.count.fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
        self.sum.fetch_add(other.sum.load(Ordering::Relaxed), Ordering::Relaxed);
        self.max.fetch_max(other.max.load(Ordering::Relaxed), Ordering::Relaxed);
        self.min.fetch_min(other.min.load(Ordering::Relaxed), Ordering::Relaxed);
        for (b, o) in self.buckets.iter().zip(other.buckets.iter()) {
            b.fetch_add(o.load(Ordering::Relaxed), Ordering::Relaxed);
        }
    }

    /// Reset every cell to the empty state. Not atomic with respect to
    /// concurrent recorders — intended for bench/test scoping only.
    pub fn reset(&self) {
        self.count.store(0, Ordering::Relaxed);
        self.sum.store(0, Ordering::Relaxed);
        self.max.store(0, Ordering::Relaxed);
        self.min.store(u64::MAX, Ordering::Relaxed);
        for b in self.buckets.iter() {
            b.store(0, Ordering::Relaxed);
        }
    }

    /// A point-in-time copy. Under concurrent recording the header fields
    /// and buckets may disagree by in-flight records; percentiles are
    /// computed from the bucket totals, so they stay internally consistent.
    pub fn snapshot(&self) -> HistSnapshot {
        let count = self.count.load(Ordering::Relaxed);
        let min = self.min.load(Ordering::Relaxed);
        HistSnapshot {
            count,
            sum: self.sum.load(Ordering::Relaxed),
            min: if min == u64::MAX { 0 } else { min },
            max: self.max.load(Ordering::Relaxed),
            buckets: self
                .buckets
                .iter()
                .enumerate()
                .filter_map(|(i, b)| {
                    let c = b.load(Ordering::Relaxed);
                    (c > 0).then_some((i as u64, c))
                })
                .collect(),
        }
    }
}

/// A point-in-time copy of a [`Hist`]: header fields plus the non-empty
/// `(bucket index, count)` pairs.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HistSnapshot {
    /// Values recorded.
    pub count: u64,
    /// Sum of all recorded values.
    pub sum: u64,
    /// Exact minimum recorded value (0 when empty).
    pub min: u64,
    /// Exact maximum recorded value (0 when empty).
    pub max: u64,
    /// Non-empty buckets as `(bucket index, count)` in index order.
    pub buckets: Vec<(u64, u64)>,
}

impl HistSnapshot {
    /// Estimate the `p`-th percentile (`0.0..=100.0`) by rank interpolation
    /// inside the owning bucket, clamped to the exact min/max. The estimate
    /// is off by at most one bucket width — a relative error of `1/SUBS`
    /// (≈3.1%) plus one unit for values above [`SUBS`], and exact below.
    pub fn percentile(&self, p: f64) -> u64 {
        let total: u64 = self.buckets.iter().map(|&(_, c)| c).sum();
        if total == 0 {
            return 0;
        }
        let rank = ((p / 100.0) * total as f64).ceil().clamp(1.0, total as f64) as u64;
        let mut cum = 0u64;
        for &(idx, c) in &self.buckets {
            if cum + c >= rank {
                let (lo, hi) = bucket_bounds(idx as usize);
                let into = (rank - cum) as f64 / c as f64;
                let est = lo as f64 + (hi - lo) as f64 * into;
                return (est as u64).clamp(self.min, self.max.max(self.min));
            }
            cum += c;
        }
        self.max
    }

    /// Mean of the recorded values (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::ChaCha20Rng;

    #[test]
    fn bucket_layout_is_contiguous_and_ordered() {
        // Every value maps into a bucket whose bounds contain it, and
        // bucket lows are non-decreasing in index.
        let probes = [
            0u64,
            1,
            31,
            32,
            33,
            63,
            64,
            65,
            100,
            1000,
            5000,
            u32::MAX as u64,
            u64::MAX / 2,
            u64::MAX,
        ];
        for &v in &probes {
            let i = bucket_of(v);
            assert!(i < N_BUCKETS, "index {i} out of range for {v}");
            let (lo, hi) = bucket_bounds(i);
            assert!(lo <= v && (v < hi || hi == u64::MAX), "{v} not in [{lo},{hi}) (bucket {i})");
        }
        let mut prev = 0u64;
        for i in 0..N_BUCKETS {
            let (lo, _) = bucket_bounds(i);
            assert!(lo >= prev, "bucket {i} lo {lo} below previous {prev}");
            prev = lo;
        }
    }

    /// Satellite requirement: percentile estimates vs an exact sort on
    /// random samples stay within the advertised one-bucket error bound.
    #[test]
    fn percentiles_match_exact_sort_within_bucket_error() {
        let mut rng = ChaCha20Rng::from_u64_seed(0x0b5);
        // Mixed magnitudes: exercise the exact region, mid groups, and
        // large values.
        let mut vals: Vec<u64> = (0..4000)
            .map(|i| match i % 4 {
                0 => rng.next_u64() % 16,
                1 => 100 + rng.next_u64() % 900,
                2 => 10_000 + rng.next_u64() % 90_000,
                _ => rng.next_u64() % 10_000_000,
            })
            .collect();
        let h = Hist::new();
        for &v in &vals {
            h.record(v);
        }
        vals.sort_unstable();
        let snap = h.snapshot();
        for &p in &[1.0, 10.0, 50.0, 90.0, 95.0, 99.0, 99.9] {
            let rank = ((p / 100.0) * vals.len() as f64).ceil().max(1.0) as usize - 1;
            let exact = vals[rank];
            let est = snap.percentile(p);
            // One bucket width: lo/SUBS relative error, plus one unit for
            // interpolation rounding.
            let tol = exact / (SUBS as u64 / 2) + 2;
            assert!(
                est.abs_diff(exact) <= tol,
                "p{p}: est {est} vs exact {exact} (tol {tol})"
            );
        }
        assert_eq!(snap.max, *vals.last().unwrap(), "max must be exact");
        assert_eq!(snap.min, vals[0], "min must be exact");
        assert_eq!(snap.count, vals.len() as u64);
    }

    /// Satellite requirement: merging two histograms equals recording the
    /// union of their samples.
    #[test]
    fn merge_equals_combined_recording() {
        let mut rng = ChaCha20Rng::from_u64_seed(7);
        let (a, b, both) = (Hist::new(), Hist::new(), Hist::new());
        for i in 0..500 {
            let v = rng.next_u64() % 1_000_000;
            if i % 2 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
            both.record(v);
        }
        a.merge(&b);
        assert_eq!(a.snapshot(), both.snapshot());
    }

    /// Satellite requirement: concurrent recording at 1, 2, and 8 threads
    /// loses nothing.
    #[test]
    fn concurrent_recording_is_lossless() {
        for threads in [1usize, 2, 8] {
            let h = std::sync::Arc::new(Hist::new());
            let per = 2000u64;
            let handles: Vec<_> = (0..threads)
                .map(|t| {
                    let h = h.clone();
                    std::thread::spawn(move || {
                        for i in 0..per {
                            h.record(t as u64 * 1000 + i);
                        }
                    })
                })
                .collect();
            for jh in handles {
                jh.join().unwrap();
            }
            let snap = h.snapshot();
            assert_eq!(snap.count, threads as u64 * per, "{threads} threads");
            let bucket_total: u64 = snap.buckets.iter().map(|&(_, c)| c).sum();
            assert_eq!(bucket_total, snap.count, "{threads} threads: bucket totals");
            let want_sum: u64 =
                (0..threads as u64).map(|t| (0..per).map(|i| t * 1000 + i).sum::<u64>()).sum();
            assert_eq!(snap.sum, want_sum, "{threads} threads: sum");
        }
    }

    #[test]
    fn empty_histogram_is_all_zero() {
        let snap = Hist::new().snapshot();
        assert_eq!((snap.count, snap.sum, snap.min, snap.max), (0, 0, 0, 0));
        assert_eq!(snap.percentile(50.0), 0);
        assert_eq!(snap.mean(), 0.0);
        assert!(snap.buckets.is_empty());
    }

    #[test]
    fn reset_empties_the_histogram() {
        let h = Hist::new();
        for v in [5u64, 500, 50_000] {
            h.record(v);
        }
        assert_eq!(h.count(), 3);
        h.reset();
        assert_eq!(h.snapshot(), Hist::new().snapshot());
    }
}
