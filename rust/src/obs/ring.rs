//! Per-query timeline: a fixed-size lock-free ring of recent span events.
//!
//! When the level is [`crate::obs::Level::Trace`], every finished span
//! additionally appends `(name, start, duration)` to this ring, giving a
//! rolling window of what the process was doing — enough to reconstruct
//! the phase timeline of the last few queries from the live endpoint
//! without a tracing dependency.
//!
//! Writers claim a slot with one `fetch_add` on the head counter and
//! publish through a per-slot sequence lock (odd while writing, even when
//! stable). Readers skip slots whose sequence is odd or changes under
//! them, so a snapshot never blocks a recording thread and never observes
//! a torn event. A writer that wraps the whole ring mid-write of another
//! writer could in principle collide on a slot; with [`RING_SIZE`] slots
//! and nanosecond writes that window is never hit in practice, and the
//! worst case is one dropped/overwritten debug event — never corruption
//! visible past the sequence check.

use std::sync::atomic::{AtomicPtr, AtomicU64, AtomicUsize, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Events retained in the rolling window.
pub const RING_SIZE: usize = 256;

/// One captured span event.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Event {
    /// The span name.
    pub name: &'static str,
    /// Span start, in microseconds since the process's telemetry epoch.
    pub start_us: u64,
    /// Span duration in microseconds.
    pub dur_us: u64,
}

struct Slot {
    seq: AtomicU64,
    name_ptr: AtomicPtr<u8>,
    name_len: AtomicUsize,
    start_us: AtomicU64,
    dur_us: AtomicU64,
}

struct Ring {
    head: AtomicU64,
    slots: Box<[Slot]>,
}

static RING: OnceLock<Ring> = OnceLock::new();

fn ring() -> &'static Ring {
    RING.get_or_init(|| Ring {
        head: AtomicU64::new(0),
        slots: (0..RING_SIZE)
            .map(|_| Slot {
                seq: AtomicU64::new(0),
                name_ptr: AtomicPtr::new(std::ptr::null_mut()),
                name_len: AtomicUsize::new(0),
                start_us: AtomicU64::new(0),
                dur_us: AtomicU64::new(0),
            })
            .collect(),
    })
}

/// The process's telemetry epoch: the instant the first span (or epoch
/// query) happened. All timeline timestamps are relative to it.
pub fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Append one event to the ring (called from the span guard at `Trace`
/// level). `name` must be a `'static` literal — the ring stores only its
/// pointer/length pair.
pub fn push(name: &'static str, start_us: u64, dur_us: u64) {
    let r = ring();
    let i = (r.head.fetch_add(1, Ordering::Relaxed) as usize) % RING_SIZE;
    let s = &r.slots[i];
    // Sequence lock: odd while the fields are in flux, even once stable.
    // The slot is claimed with a CAS — if another writer wrapped the whole
    // ring and already holds this slot, drop the event rather than risk a
    // write interleaving that a reader could mistake for stable.
    let seq = s.seq.load(Ordering::Relaxed);
    if seq & 1 == 1
        || s.seq
            .compare_exchange(seq, seq | 1, Ordering::Acquire, Ordering::Relaxed)
            .is_err()
    {
        return;
    }
    s.name_ptr.store(name.as_ptr() as *mut u8, Ordering::Relaxed);
    s.name_len.store(name.len(), Ordering::Relaxed);
    s.start_us.store(start_us, Ordering::Relaxed);
    s.dur_us.store(dur_us, Ordering::Relaxed);
    s.seq.store(seq.wrapping_add(2) & !1, Ordering::Release);
}

/// The current window of stable events, oldest first. Never blocks
/// writers; in-flight slots are skipped.
pub fn events() -> Vec<Event> {
    let r = ring();
    let mut out = Vec::new();
    for s in r.slots.iter() {
        let s1 = s.seq.load(Ordering::Acquire);
        if s1 == 0 || s1 & 1 == 1 {
            continue; // never written, or mid-write
        }
        let ptr = s.name_ptr.load(Ordering::Relaxed);
        let len = s.name_len.load(Ordering::Relaxed);
        let start_us = s.start_us.load(Ordering::Relaxed);
        let dur_us = s.dur_us.load(Ordering::Relaxed);
        if s.seq.load(Ordering::Acquire) != s1 || ptr.is_null() {
            continue; // overwritten while reading
        }
        // Sound: the pointer/length pair came from one &'static str and
        // the sequence check above proved we read a stable pair.
        let name = unsafe {
            std::str::from_utf8_unchecked(std::slice::from_raw_parts(ptr as *const u8, len))
        };
        out.push(Event { name, start_us, dur_us });
    }
    out.sort_by_key(|e| e.start_us);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pushed_events_are_readable_in_order() {
        push("obs.test.ring.a", 10_000_000, 5);
        push("obs.test.ring.b", 10_000_001, 7);
        let evs = events();
        let ia = evs
            .iter()
            .position(|e| e.name == "obs.test.ring.a" && e.start_us == 10_000_000)
            .expect("event a present");
        let ib = evs
            .iter()
            .position(|e| e.name == "obs.test.ring.b" && e.start_us == 10_000_001)
            .expect("event b present");
        assert!(ia < ib, "events must sort by start time");
        assert_eq!(evs[ia].dur_us, 5);
    }

    #[test]
    fn ring_wraps_without_growing() {
        for i in 0..(RING_SIZE * 3) as u64 {
            push("obs.test.ring.wrap", 20_000_000 + i, 1);
        }
        let evs = events();
        assert!(evs.len() <= RING_SIZE);
        assert!(evs.iter().any(|e| e.name == "obs.test.ring.wrap"));
    }

    #[test]
    fn concurrent_pushes_never_tear() {
        let handles: Vec<_> = (0..4)
            .map(|t| {
                std::thread::spawn(move || {
                    for i in 0..500u64 {
                        push("obs.test.ring.mt", 30_000_000 + t * 1000 + i, i);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        for e in events() {
            // A torn read would show a foreign pointer/length pair; the
            // name must always be one of the literals ever pushed.
            assert!(e.name.starts_with("obs.test.ring") || !e.name.is_empty());
        }
    }
}
