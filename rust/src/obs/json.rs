//! Minimal dependency-free JSON support for the telemetry snapshot.
//!
//! The crate deliberately carries no external dependencies, so the
//! snapshot schema ships with its own tiny writer ([`escape`]) and
//! recursive-descent parser ([`Json::parse`]). The parser covers the JSON
//! the snapshot emits (and anything a scraper is likely to feed back):
//! objects, arrays, strings with standard escapes, integer and float
//! numbers, booleans, and null. It is used by the snapshot round-trip
//! test and by `serve_bench --stats` to read the live endpoint.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value. Numbers keep integer/float identity so that
/// integer-valued telemetry round-trips byte-for-byte.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// A number without `.`/`e` that fits `i64`.
    Int(i64),
    /// Any other number.
    Num(f64),
    /// A string (unescaped).
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object. `BTreeMap` keeps key order deterministic.
    Obj(BTreeMap<String, Json>),
}

/// Parse failure: a message and the byte offset it occurred at.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct JsonError {
    /// What went wrong.
    pub msg: &'static str,
    /// Byte offset into the input.
    pub at: usize,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse one JSON document (trailing whitespace allowed, trailing
    /// content rejected).
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let b = s.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(b, &mut pos)?;
        skip_ws(b, &mut pos);
        if pos != b.len() {
            return Err(JsonError { msg: "trailing content", at: pos });
        }
        Ok(v)
    }

    /// Object field lookup (None for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// The value as `i64` (integers only; floats are not coerced).
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(v) => Some(*v),
            _ => None,
        }
    }

    /// The value as `f64` (accepts both number forms).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Int(v) => Some(*v as f64),
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    /// The value as `&str`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The value as an array slice.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(b: &[u8], pos: &mut usize, c: u8) -> Result<(), JsonError> {
    if *pos < b.len() && b[*pos] == c {
        *pos += 1;
        Ok(())
    } else {
        Err(JsonError { msg: "unexpected character", at: *pos })
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, JsonError> {
    skip_ws(b, pos);
    match b.get(*pos) {
        None => Err(JsonError { msg: "unexpected end of input", at: *pos }),
        Some(b'{') => parse_obj(b, pos),
        Some(b'[') => parse_arr(b, pos),
        Some(b'"') => Ok(Json::Str(parse_string(b, pos)?)),
        Some(b't') => parse_lit(b, pos, b"true", Json::Bool(true)),
        Some(b'f') => parse_lit(b, pos, b"false", Json::Bool(false)),
        Some(b'n') => parse_lit(b, pos, b"null", Json::Null),
        Some(_) => parse_number(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &[u8], v: Json) -> Result<Json, JsonError> {
    if b.len() - *pos >= lit.len() && &b[*pos..*pos + lit.len()] == lit {
        *pos += lit.len();
        Ok(v)
    } else {
        Err(JsonError { msg: "bad literal", at: *pos })
    }
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<Json, JsonError> {
    let start = *pos;
    if b.get(*pos) == Some(&b'-') {
        *pos += 1;
    }
    let mut float = false;
    while let Some(&c) = b.get(*pos) {
        match c {
            b'0'..=b'9' => *pos += 1,
            b'.' | b'e' | b'E' | b'+' | b'-' => {
                float = true;
                *pos += 1;
            }
            _ => break,
        }
    }
    let text = std::str::from_utf8(&b[start..*pos])
        .map_err(|_| JsonError { msg: "bad number", at: start })?;
    if !float {
        if let Ok(v) = text.parse::<i64>() {
            return Ok(Json::Int(v));
        }
    }
    text.parse::<f64>()
        .map(Json::Num)
        .map_err(|_| JsonError { msg: "bad number", at: start })
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, JsonError> {
    expect(b, pos, b'"')?;
    let mut out = String::new();
    loop {
        match b.get(*pos) {
            None => return Err(JsonError { msg: "unterminated string", at: *pos }),
            Some(b'"') => {
                *pos += 1;
                return Ok(out);
            }
            Some(b'\\') => {
                *pos += 1;
                let esc = *b.get(*pos).ok_or(JsonError { msg: "bad escape", at: *pos })?;
                *pos += 1;
                match esc {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        if b.len() - *pos < 4 {
                            return Err(JsonError { msg: "bad \\u escape", at: *pos });
                        }
                        let hex = std::str::from_utf8(&b[*pos..*pos + 4])
                            .map_err(|_| JsonError { msg: "bad \\u escape", at: *pos })?;
                        let cp = u32::from_str_radix(hex, 16)
                            .map_err(|_| JsonError { msg: "bad \\u escape", at: *pos })?;
                        *pos += 4;
                        // Surrogate pairs are not needed by the snapshot
                        // schema; map them to the replacement character.
                        out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(JsonError { msg: "bad escape", at: *pos }),
                }
            }
            Some(_) => {
                // Consume one UTF-8 scalar (input came from &str, so the
                // boundaries are valid).
                let rest = &b[*pos..];
                let s = unsafe { std::str::from_utf8_unchecked(rest) };
                let ch = s.chars().next().unwrap();
                out.push(ch);
                *pos += ch.len_utf8();
            }
        }
    }
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Json, JsonError> {
    expect(b, pos, b'[')?;
    let mut out = Vec::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b']') {
        *pos += 1;
        return Ok(Json::Arr(out));
    }
    loop {
        out.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(Json::Arr(out));
            }
            _ => return Err(JsonError { msg: "expected ',' or ']'", at: *pos }),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Json, JsonError> {
    expect(b, pos, b'{')?;
    let mut out = BTreeMap::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b'}') {
        *pos += 1;
        return Ok(Json::Obj(out));
    }
    loop {
        skip_ws(b, pos);
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        expect(b, pos, b':')?;
        let val = parse_value(b, pos)?;
        out.insert(key, val);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(Json::Obj(out));
            }
            _ => return Err(JsonError { msg: "expected ',' or '}'", at: *pos }),
        }
    }
}

/// Append `s` as a JSON string literal (quotes included) to `out`.
pub fn escape(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_snapshot_shaped_documents() {
        let doc = r#"{"version":1,"metrics":[{"name":"a.b","kind":"counter","value":12},
            {"name":"s","kind":"span","count":2,"buckets":[[5,1],[6,1]]}],"timeline":[["x",1,2]]}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("version").and_then(Json::as_i64), Some(1));
        let metrics = v.get("metrics").and_then(Json::as_arr).unwrap();
        assert_eq!(metrics.len(), 2);
        assert_eq!(metrics[0].get("name").and_then(Json::as_str), Some("a.b"));
        assert_eq!(metrics[0].get("value").and_then(Json::as_i64), Some(12));
        let buckets = metrics[1].get("buckets").and_then(Json::as_arr).unwrap();
        assert_eq!(buckets[0].as_arr().unwrap()[0].as_i64(), Some(5));
    }

    #[test]
    fn numbers_keep_integer_identity() {
        assert_eq!(Json::parse("42").unwrap(), Json::Int(42));
        assert_eq!(Json::parse("-7").unwrap(), Json::Int(-7));
        assert_eq!(Json::parse("1.5").unwrap(), Json::Num(1.5));
        assert_eq!(Json::parse("1e3").unwrap(), Json::Num(1000.0));
    }

    #[test]
    fn string_escapes_round_trip() {
        let original = "a\"b\\c\nd\te\u{1}f";
        let mut enc = String::new();
        escape(&mut enc, original);
        assert_eq!(Json::parse(&enc).unwrap(), Json::Str(original.to_string()));
    }

    #[test]
    fn rejects_malformed_input() {
        for bad in ["", "{", "[1,", "\"open", "{\"a\":}", "1 2", "nul"] {
            assert!(Json::parse(bad).is_err(), "{bad:?} should fail");
        }
    }
}
