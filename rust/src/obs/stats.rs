//! Live introspection endpoint: a tiny HTTP/1.0 responder that serves the
//! current telemetry snapshot as JSON.
//!
//! `main.rs serve-secure --stats-addr 127.0.0.1:9911` binds one of these
//! next to the secure server, so a long-running deployment can be
//! inspected with `curl http://127.0.0.1:9911/` (or scraped by
//! `serve_bench --stats`) without restarting — the snapshot itself is
//! lock-free to capture. Every connection gets the full document and is
//! closed; there is no routing, no keep-alive, and no request parsing
//! beyond draining the request head.

use crate::coordinator::server::{stop_accept_thread, StoppableListener};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::AtomicBool;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// A running stats endpoint. Serving continues on a background thread
/// until [`StatsServer::shutdown`] (or drop).
pub struct StatsServer {
    /// The bound address.
    pub addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Mutex<Option<JoinHandle<()>>>,
}

impl StatsServer {
    /// Bind `addr` and serve snapshots. Returns once the listener is
    /// bound.
    pub fn serve(addr: &str) -> std::io::Result<StatsServer> {
        let listener = StoppableListener::bind(addr)?;
        let local = listener.addr;
        let stop = listener.stop_flag();
        let accept_thread = std::thread::spawn(move || {
            while let Some(stream) = listener.accept() {
                // Serialized handling is fine for an admin endpoint; a
                // stuck peer is bounded by the read/write timeouts.
                let _ = respond(stream);
            }
        });
        Ok(StatsServer { addr: local, stop, accept_thread: Mutex::new(Some(accept_thread)) })
    }

    /// Stop accepting and join the accept thread. Idempotent.
    pub fn shutdown(&self) {
        stop_accept_thread(&self.stop, self.addr, &self.accept_thread);
    }
}

impl Drop for StatsServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn respond(mut stream: TcpStream) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_millis(500))).ok();
    stream.set_write_timeout(Some(Duration::from_secs(5))).ok();
    // Drain the request head (best-effort: until a blank line, EOF, a
    // bounded amount of bytes, or the timeout). The response is the same
    // regardless of the request.
    let mut head = [0u8; 1024];
    let mut seen = 0usize;
    while seen < head.len() {
        match stream.read(&mut head[seen..]) {
            Ok(0) => break,
            Ok(n) => {
                seen += n;
                if head[..seen].windows(4).any(|w| w == b"\r\n\r\n") {
                    break;
                }
            }
            Err(_) => break, // timeout or reset: respond anyway
        }
    }
    let body = crate::obs::snapshot().to_json();
    let header = format!(
        "HTTP/1.0 200 OK\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(header.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

/// Fetch and parse the snapshot served at `addr`: issues a minimal HTTP
/// GET and returns the JSON body. Used by `serve_bench --stats` and
/// available to tests/operator tooling.
pub fn scrape(addr: &SocketAddr) -> std::io::Result<String> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_read_timeout(Some(Duration::from_secs(5))).ok();
    stream.write_all(b"GET / HTTP/1.0\r\n\r\n")?;
    let mut response = Vec::new();
    stream.read_to_end(&mut response)?;
    let text = String::from_utf8_lossy(&response);
    match text.split_once("\r\n\r\n") {
        Some((_, body)) => Ok(body.to_string()),
        None => Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "stats endpoint returned no header/body separator",
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::Snapshot;

    #[test]
    fn endpoint_serves_a_parsable_snapshot() {
        crate::obs::inc("obs.test.stats.requests");
        let server = StatsServer::serve("127.0.0.1:0").expect("bind stats endpoint");
        let body = scrape(&server.addr).expect("scrape endpoint");
        let snap = Snapshot::from_json(&body).expect("endpoint body must be schema-valid");
        #[cfg(not(feature = "obs-off"))]
        assert!(
            snap.get("obs.test.stats.requests").is_some(),
            "scraped snapshot misses a registered counter"
        );
        #[cfg(feature = "obs-off")]
        assert!(snap.metrics.is_empty());
        server.shutdown();
        server.shutdown(); // idempotent
        assert!(
            TcpStream::connect(server.addr).is_err() || scrape(&server.addr).is_err(),
            "endpoint still serving after shutdown"
        );
    }
}
