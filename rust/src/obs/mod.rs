//! Unified telemetry: structured spans, a lock-free metrics registry, and
//! JSON snapshots for live introspection.
//!
//! CHEETAH's whole pitch is a performance claim; this module is the
//! instrument that proves it on a running system. It is dependency-free
//! and built so the hot path stays hot:
//!
//! * **Registry** ([`registry`]) — named counters, gauges, and span
//!   histograms interned once into a lock-free table; recording is a
//!   handful of relaxed atomic ops, with no allocation and no lock.
//! * **Spans** ([`span`]) — RAII guards that time a scope into a
//!   [`Hist`] (log₂ buckets with linear sub-buckets, ≤3.1% quantization
//!   error, exact max). At [`Level::Trace`] each span also lands in a
//!   rolling timeline ring ([`ring`]).
//! * **Snapshots** ([`snapshot`]) — one JSON schema served by the secure
//!   server's `STATS` frame, the `serve-secure --stats-addr` endpoint
//!   ([`StatsServer`]), and the `obs` section of `BENCH_e2e.json`.
//!
//! Instrumented layers and their span taxonomy are tabulated in
//! `DESIGN.md` §9: `phe.*` op kernels, `cheetah.*` protocol phases,
//! `gc.*` garbling, `par.*` pool decisions, and `serve.*` pool/session
//! counters.
//!
//! # Cost model
//!
//! A disabled span (`CHEETAH_OBS=0`) is one relaxed atomic load. An
//! enabled span is two `Instant::now()` calls plus ~5 relaxed atomic
//! RMWs — ~100ns, against instrumented scopes that are microseconds to
//! milliseconds. Instrumentation reads no data and draws no randomness,
//! so pinned-seed bit-exactness is unaffected at any level. The
//! `obs-off` cargo feature compiles every recording path down to nothing
//! for the paranoid deployment; the snapshot surfaces then serve an
//! empty (but schema-valid) document.
//!
//! # Knobs
//!
//! * `CHEETAH_OBS` env var: `0`/`off` disables recording, `trace` adds
//!   the timeline ring, anything else (or unset) records counters and
//!   histograms. Read once at first use; [`set_level`] overrides.
//! * `obs-off` cargo feature: compile out all recording.
//!
//! # Example
//!
//! ```
//! {
//!     let _span = cheetah::obs::span("online.mult_plain");
//!     // … timed work …
//! }
//! cheetah::obs::inc("example.events");
//! let snap = cheetah::obs::snapshot();
//! let _json = snap.to_json();
//! ```

pub mod hist;
pub mod json;
pub mod registry;
pub mod ring;
pub mod snapshot;
pub mod stats;

pub use hist::{Hist, HistSnapshot};
pub use registry::{Metric, MetricKind};
pub use snapshot::{MetricSnapshot, Snapshot, TimelineEvent};
pub use stats::StatsServer;

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

/// Runtime telemetry level (compile-time kill switch: the `obs-off`
/// feature).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Level {
    /// Record nothing (spans cost one atomic load).
    Off,
    /// Record counters, gauges, and span histograms (the default).
    On,
    /// Additionally append every span to the timeline ring.
    Trace,
}

const LEVEL_UNSET: u8 = 0;
const LEVEL_OFF: u8 = 1;
const LEVEL_ON: u8 = 2;
const LEVEL_TRACE: u8 = 3;

static LEVEL: AtomicU8 = AtomicU8::new(LEVEL_UNSET);

fn level_code() -> u8 {
    let c = LEVEL.load(Ordering::Relaxed);
    if c != LEVEL_UNSET {
        return c;
    }
    // First use: resolve CHEETAH_OBS and pin the telemetry epoch so all
    // timeline timestamps are relative to it.
    ring::epoch();
    let resolved = match std::env::var("CHEETAH_OBS").as_deref() {
        Ok("0") | Ok("off") | Ok("false") => LEVEL_OFF,
        Ok("trace") | Ok("2") => LEVEL_TRACE,
        _ => LEVEL_ON,
    };
    // A racing first use resolves the same env var; either store wins.
    LEVEL.store(resolved, Ordering::Relaxed);
    resolved
}

/// The current telemetry level.
pub fn level() -> Level {
    match level_code() {
        LEVEL_OFF => Level::Off,
        LEVEL_TRACE => Level::Trace,
        _ => Level::On,
    }
}

/// Override the telemetry level at runtime (e.g. `e2e_bench --obs`
/// forcing trace). With the `obs-off` feature this is accepted but
/// recording stays compiled out.
pub fn set_level(l: Level) {
    let code = match l {
        Level::Off => LEVEL_OFF,
        Level::On => LEVEL_ON,
        Level::Trace => LEVEL_TRACE,
    };
    ring::epoch();
    LEVEL.store(code, Ordering::Relaxed);
}

/// Whether recording is on at all.
#[inline]
pub fn enabled() -> bool {
    if cfg!(feature = "obs-off") {
        return false;
    }
    level_code() >= LEVEL_ON
}

/// Whether the timeline ring is recording.
#[inline]
pub fn trace_enabled() -> bool {
    if cfg!(feature = "obs-off") {
        return false;
    }
    level_code() == LEVEL_TRACE
}

/// An RAII span guard: created by [`span`], records its scope's wall
/// duration (nanoseconds) into the named histogram on drop.
#[must_use = "a span measures until dropped — bind it with `let _span = …`"]
pub struct Span(Option<(&'static Metric, Instant)>);

impl Drop for Span {
    fn drop(&mut self) {
        if let Some((m, t0)) = self.0.take() {
            let dur = t0.elapsed();
            m.record(dur.as_nanos() as u64);
            if trace_enabled() {
                let start_us = t0
                    .checked_duration_since(ring::epoch())
                    .map(|d| d.as_micros() as u64)
                    .unwrap_or(0);
                ring::push(m.name(), start_us, dur.as_micros() as u64);
            }
        }
    }
}

/// Start a span: `let _span = obs::span("online.mult_plain");` times the
/// enclosing scope into the named histogram (ns). Disabled levels return
/// an inert guard at the cost of one atomic load.
#[inline]
pub fn span(name: &'static str) -> Span {
    if !enabled() {
        return Span(None);
    }
    let m = registry::intern(name, MetricKind::Span);
    Span(Some((m, Instant::now())))
}

/// Add `n` to the named counter.
#[inline]
pub fn add(name: &'static str, n: u64) {
    if enabled() {
        registry::intern(name, MetricKind::Counter).add(n as i64);
    }
}

/// Increment the named counter by one.
#[inline]
pub fn inc(name: &'static str) {
    add(name, 1);
}

/// Set the named gauge to an instantaneous level.
#[inline]
pub fn gauge_set(name: &'static str, v: i64) {
    if enabled() {
        registry::intern(name, MetricKind::Gauge).set(v);
    }
}

/// Apply a signed delta to the named gauge.
#[inline]
pub fn gauge_add(name: &'static str, delta: i64) {
    if enabled() {
        registry::intern(name, MetricKind::Gauge).add(delta);
    }
}

/// Record one value into the named histogram (for durations measured
/// outside a guard, or non-time distributions).
#[inline]
pub fn record(name: &'static str, v: u64) {
    if enabled() {
        registry::intern(name, MetricKind::Span).record(v);
    }
}

/// Capture a point-in-time snapshot of every registered metric (plus the
/// timeline window at trace level). Under `obs-off` the snapshot is empty
/// but schema-valid.
pub fn snapshot() -> Snapshot {
    #[cfg(feature = "obs-off")]
    return Snapshot::default();
    #[cfg(not(feature = "obs-off"))]
    {
        let metrics = registry::all()
            .into_iter()
            .map(|m| MetricSnapshot {
                name: m.name().to_string(),
                kind: m.kind(),
                value: m.value(),
                hist: m.hist().map(Hist::snapshot),
            })
            .collect();
        let timeline = if trace_enabled() {
            ring::events()
                .into_iter()
                .map(|e| TimelineEvent {
                    name: e.name.to_string(),
                    start_us: e.start_us,
                    dur_us: e.dur_us,
                })
                .collect()
        } else {
            Vec::new()
        };
        Snapshot { metrics, timeline }
    }
}

/// Zero every registered metric. Bench/test scoping only — concurrent
/// recorders may land records mid-reset.
pub fn reset() {
    #[cfg(not(feature = "obs-off"))]
    registry::reset_all();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[cfg(not(feature = "obs-off"))]
    #[test]
    fn spans_and_counters_land_in_the_snapshot() {
        {
            let _span = span("obs.test.api.span");
            std::hint::black_box(0u64);
        }
        inc("obs.test.api.counter");
        add("obs.test.api.counter", 4);
        gauge_set("obs.test.api.gauge", 17);
        let snap = snapshot();
        let c = snap.get("obs.test.api.counter").expect("counter registered");
        assert_eq!(c.kind, MetricKind::Counter);
        assert!(c.value >= 5, "counter should hold at least this test's 5, got {}", c.value);
        let g = snap.get("obs.test.api.gauge").expect("gauge registered");
        assert_eq!(g.value, 17);
        let s = snap.get("obs.test.api.span").expect("span registered");
        assert!(s.hist.as_ref().unwrap().count >= 1);
    }

    #[cfg(not(feature = "obs-off"))]
    #[test]
    fn snapshot_serializes_and_round_trips_live_data() {
        inc("obs.test.api.roundtrip");
        let snap = snapshot();
        let doc = snap.to_json();
        let back = Snapshot::from_json(&doc).expect("live snapshot must round-trip");
        assert_eq!(back.to_json(), doc);
    }

    #[cfg(feature = "obs-off")]
    #[test]
    fn obs_off_compiles_recording_to_nothing() {
        {
            let _span = span("obs.test.off.span");
        }
        inc("obs.test.off.counter");
        record("obs.test.off.hist", 5);
        assert!(!enabled());
        let snap = snapshot();
        assert!(snap.metrics.is_empty(), "obs-off must record nothing");
        assert_eq!(snap.to_json(), "{\"version\":1,\"metrics\":[],\"timeline\":[]}");
    }
}
