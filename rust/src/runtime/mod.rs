//! PJRT runtime: loads the HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from Rust — Python is never
//! on the request path.
//!
//! Pattern (from /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`.
//!
//! Used by the accuracy benchmark (Fig. 7 — the noisy quantized forward
//! pass of the trained networks) and by the coordinator's plaintext-scoring
//! path; the kernel artifacts double as a cross-check that the L1 Pallas
//! kernels and the Rust client hot loops compute the same function.

// The crate builds in an offline environment with no crate registry, so
// error plumbing is a plain boxed error rather than `anyhow`, and the
// PJRT/XLA executor (which needs the external `xla` crate) is gated behind
// the `pjrt` cargo feature. The trained-weight loader below is pure std
// and always available.
use std::path::Path;
#[cfg(feature = "pjrt")]
use std::{collections::HashMap, path::PathBuf};

/// Boxed runtime error (artifact loading / PJRT execution).
pub type Error = Box<dyn std::error::Error + Send + Sync>;
pub type Result<T> = std::result::Result<T, Error>;

/// A compiled artifact ready to execute.
#[cfg(feature = "pjrt")]
pub struct LoadedModule {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
}

/// The artifact registry + PJRT client.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    modules: HashMap<String, LoadedModule>,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Create a CPU PJRT client rooted at an artifacts directory.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| format!("create PJRT CPU client: {e}"))?;
        Ok(Self { client, dir: artifacts_dir.as_ref().to_path_buf(), modules: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load and compile `<name>.hlo.txt` from the artifacts directory.
    pub fn load(&mut self, name: &str) -> Result<()> {
        if self.modules.contains_key(name) {
            return Ok(());
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or("artifact path not utf-8")?,
        )
        .map_err(|e| format!("parse HLO text {path:?} (run `make artifacts`): {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| format!("compile {name}: {e}"))?;
        self.modules.insert(name.to_string(), LoadedModule { name: name.to_string(), exe });
        Ok(())
    }

    /// Execute a loaded module on literal inputs; returns the elements of
    /// the result tuple (aot.py lowers with `return_tuple=True`).
    pub fn execute(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let module = self
            .modules
            .get(name)
            .ok_or_else(|| format!("module {name} not loaded"))?;
        let result = module.exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        Ok(result.to_tuple()?.into_iter().collect())
    }

    /// Run the `<arch>_noisy` artifact: images (flattened NCHW f32), a PRNG
    /// key and the noise bound ε → per-image logits.
    pub fn noisy_forward(
        &mut self,
        arch: &str,
        images: &[f32],
        batch: usize,
        size: usize,
        key: [u32; 2],
        eps: f32,
    ) -> Result<Vec<Vec<f32>>> {
        let name = format!("{arch}_noisy");
        self.load(&name)?;
        let x = xla::Literal::vec1(images)
            .reshape(&[batch as i64, 1, size as i64, size as i64])?;
        let k = xla::Literal::vec1(&key[..]);
        let e = xla::Literal::from(eps);
        let out = self.execute(&name, &[x, k, e])?;
        let flat = out[0].to_vec::<f32>()?;
        Ok(flat.chunks(10).map(|c| c.to_vec()).collect())
    }
}

/// Load the trained-weights artifact (`<arch>_weights.bin` + manifest
/// shapes) into a [`crate::nn::Network`].
pub fn load_trained_network(
    artifacts_dir: impl AsRef<Path>,
    arch: &str,
) -> Result<crate::nn::Network> {
    use crate::nn::Network;
    let dir = artifacts_dir.as_ref();
    let manifest = std::fs::read_to_string(dir.join("manifest.txt"))
        .map_err(|e| format!("read manifest.txt (run `make artifacts`): {e}"))?;
    let shapes_line = manifest
        .lines()
        .find(|l| l.starts_with(&format!("{arch}_weights.bin")))
        .ok_or("weights entry missing from manifest")?;
    let shapes_str = shapes_line.split("shapes=").nth(1).ok_or("malformed manifest")?;
    let shapes: Vec<Vec<usize>> = shapes_str
        .trim()
        .split(';')
        .map(|s| s.split('x').map(|d| d.parse().unwrap()).collect())
        .collect();

    let bytes = std::fs::read(dir.join(format!("{arch}_weights.bin")))?;
    let floats: Vec<f64> = bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()) as f64)
        .collect();

    // The layer stack comes from the single source of architecture truth
    // (`Network::build` via `NetworkArch::from_key`), so this loader can
    // never drift from the zoo — it only replaces the seeded weights with
    // the trained ones.
    let arch_id = crate::nn::NetworkArch::from_key(arch)
        .ok_or_else(|| format!("unknown arch {arch}"))?;
    let mut net = Network::build(arch_id, 0);
    net.name = format!("{arch} (trained)");

    let mut offset = 0usize;
    let mut shape_idx = 0usize;
    for layer in net.layers.iter_mut() {
        if matches!(layer.kind, crate::nn::LayerKind::Relu | crate::nn::LayerKind::MeanPool { .. })
        {
            continue;
        }
        let count: usize = shapes[shape_idx].iter().product();
        layer.weights = floats[offset..offset + count].to_vec();
        offset += count;
        shape_idx += 1;
    }
    if offset != floats.len() {
        return Err("weight size mismatch".into());
    }
    if let Err(e) = equalize_activations(&mut net, 1.2, 32) {
        eprintln!("warning: activation equalization skipped for {arch}: {e}");
    }
    Ok(net)
}

/// Activation equalization: rescale each hidden linear layer so calibration
/// activations stay within `target` (the protocol's clamp-safe range), and
/// push the inverse factor into the next linear layer — exactly preserving
/// the float function by ReLU positive homogeneity (the final logits pick
/// up one uniform positive factor, leaving the argmax unchanged). Standard
/// deployment-time conditioning for fixed-point inference.
///
/// The calibration corpus is the synthetic-digit generator, replicated
/// across input channels for multi-channel networks (AlexNet/VGG style) —
/// see [`crate::nn::SyntheticDigits::render_channels`]. Errors (instead of
/// silently no-opping) when the input shape fits no corpus at all
/// (non-square or smaller than the 12-px glyph floor).
pub fn equalize_activations(
    net: &mut crate::nn::Network,
    target: f64,
    calib: usize,
) -> Result<()> {
    use crate::nn::layers::{forward_layer, LayerKind};
    let (c_in, h, w) = net.input_shape;
    if h != w || h < 12 {
        return Err(format!(
            "no calibration corpus for input shape {:?} (needs square images ≥ 12 px)",
            net.input_shape
        )
        .into());
    }
    let mut gen = crate::nn::SyntheticDigits::new(h, 2024);
    let samples: Vec<crate::nn::Tensor> = (0..calib)
        .map(|i| gen.render_channels(i % 10, c_in).image)
        .collect();
    let linear_idxs: Vec<usize> = net
        .layers
        .iter()
        .enumerate()
        .filter(|(_, l)| matches!(l.kind, LayerKind::Conv2d { .. } | LayerKind::Fc { .. }))
        .map(|(i, _)| i)
        .collect();
    // Iterate hidden linear layers (all but the last).
    for w in linear_idxs.windows(2) {
        let (li, next) = (w[0], w[1]);
        // Max |activation| right after this layer's ReLU across calibration.
        let mut max_abs = 0f64;
        for x in &samples {
            let mut t = x.clone();
            for l in &net.layers[..=li] {
                t = forward_layer(l, &t);
            }
            max_abs = max_abs.max(t.max_abs());
        }
        if max_abs == 0.0 {
            continue;
        }
        // Normalize up as well as down: small activations waste fixed-point
        // resolution, large ones clamp.
        let s = target / max_abs;
        for v in net.layers[li].weights.iter_mut() {
            *v *= s;
        }
        for v in net.layers[next].weights.iter_mut() {
            *v /= s;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_ready() -> bool {
        Path::new("artifacts/manifest.txt").exists()
    }

    #[test]
    fn equalize_activations_calibrates_multichannel_input() {
        // 3-channel (RGB-style) network: the replicated-digit corpus now
        // calibrates it instead of erroring out (AlexNet/VGG path).
        let mut net = crate::nn::Network {
            name: "rgb".into(),
            input_shape: (3, 12, 12),
            layers: vec![
                crate::nn::Layer::conv(2, 3, 1, 1),
                crate::nn::Layer::relu(),
                crate::nn::Layer::fc(2),
            ],
        };
        net.init_weights(1);
        let reference = net.clone();
        equalize_activations(&mut net, 1.2, 4).expect("multi-channel calibration");
        // Function preserved up to one uniform positive factor on the
        // logits (ReLU positive homogeneity) — argmax must not move.
        let mut gen = crate::nn::SyntheticDigits::new(12, 77);
        for s in (0..4).map(|i| gen.render_channels(i, 3)) {
            assert_eq!(
                net.forward(&s.image).argmax(),
                reference.forward(&s.image).argmax(),
                "calibration changed a prediction"
            );
        }
    }

    #[test]
    fn equalize_activations_rejects_shapes_without_a_corpus() {
        // Too small for the glyph renderer (< 12 px): typed error, weights
        // untouched.
        let mut net = crate::nn::Network {
            name: "tiny".into(),
            input_shape: (3, 4, 4),
            layers: vec![crate::nn::Layer::fc(2)],
        };
        net.init_weights(1);
        let before = net.layers[0].weights.clone();
        let err = equalize_activations(&mut net, 1.2, 4).unwrap_err();
        assert!(err.to_string().contains("no calibration corpus"), "{err}");
        assert_eq!(net.layers[0].weights, before, "failed calibration must not touch weights");
    }

    #[test]
    fn equalize_activations_runs_on_single_channel() {
        let mut net = crate::nn::Network {
            name: "mono".into(),
            input_shape: (1, 12, 12),
            layers: vec![
                crate::nn::Layer::fc(6),
                crate::nn::Layer::relu(),
                crate::nn::Layer::fc(3),
            ],
        };
        net.init_weights(2);
        equalize_activations(&mut net, 1.2, 4).expect("single-channel calibration");
    }

    #[test]
    fn unknown_arch_is_an_error_not_a_panic() {
        let err = load_trained_network("artifacts", "resnet152").unwrap_err();
        // Either the manifest is missing entirely (no artifacts) or the
        // arch key fails to resolve — both must surface as errors.
        assert!(!err.to_string().is_empty());
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn pjrt_client_starts() {
        let rt = Runtime::new("artifacts").expect("PJRT client");
        assert!(rt.platform().to_lowercase().contains("cpu") || !rt.platform().is_empty());
    }

    /// Kernel artifact cross-check: the lowered Pallas obscure_dot must
    /// match the Rust client's block_sums on the same input.
    #[cfg(feature = "pjrt")]
    #[test]
    fn pallas_kernel_matches_rust_hot_loop() {
        if !artifacts_ready() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        }
        let mut rt = Runtime::new("artifacts").unwrap();
        rt.load("obscure_dot").unwrap();
        let mut rng = crate::util::rng::SplitMix64::new(77);
        let prods: Vec<i32> =
            (0..1024 * 32).map(|_| rng.gen_i64_range(-(1 << 20), 1 << 20) as i32).collect();
        let input = xla::Literal::vec1(&prods).reshape(&[1024, 32]).unwrap();
        let out = rt.execute("obscure_dot", &[input]).unwrap();
        let got = out[0].to_vec::<i32>().unwrap();
        let stream: Vec<i64> = prods.iter().map(|&v| v as i64).collect();
        let want = crate::protocol::cheetah::packing::block_sums(&stream, 32, 1024);
        for i in 0..1024 {
            assert_eq!(got[i] as i64, want[i], "block {i}");
        }
    }

    #[test]
    fn trained_network_loads_and_classifies() {
        if !artifacts_ready() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let net = load_trained_network("artifacts", "netA").unwrap();
        let mut gen = crate::nn::SyntheticDigits::new(28, 123);
        let mut correct = 0;
        let total = 40;
        for s in gen.batch(total) {
            if net.forward(&s.image).argmax() == s.label {
                correct += 1;
            }
        }
        assert!(correct * 10 >= total * 7, "trained netA accuracy {correct}/{total}");
    }
}
