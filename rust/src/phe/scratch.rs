//! Reusable scratch buffers for the online scoring hot path.
//!
//! The CHEETAH server's online phase builds one query-dependent `AddPlain`
//! operand per (channel × ciphertext) slot: slot residues → plaintext
//! encoding → Δ-scaled RNS poly → forward NTT. Allocating those three
//! buffers fresh per slot puts an allocator round-trip (and a cold cache
//! line sweep) inside the tightest loop of the serving path. An [`Arena`]
//! instead banks the buffers: a worker checks one out for the duration of a
//! region, overwrites it completely, and the guard returns it on drop — so
//! after a brief warm-up the online path performs **zero operand-poly
//! allocations** (asserted by the protocol's instrumentation test).
//!
//! Design notes:
//!
//! * The arena is owned (one per `CheetahServer`), not global, so its
//!   counters are test-isolatable and concurrent deployments in one process
//!   never share or skew each other's statistics.
//! * Checkout/check-in take a `Mutex` held only for a `Vec` push/pop —
//!   tens of nanoseconds against the tens of microseconds a poly operation
//!   costs, so contention across pool workers is negligible. Each worker
//!   holds its own guards while it computes (the "per-worker" usage
//!   pattern); only the free-list is shared.
//! * Returned buffers contain **stale data**. Every consumer in this crate
//!   fully overwrites them (`encode_unsigned_into`, `scale_plain_into`,
//!   `lift_centered_into` write all `n` coefficients of every residue);
//!   new consumers must follow the same contract.
//! * The pool is unbounded but naturally sized by peak concurrency: a
//!   region checks out at most a few buffers per worker thread, and they
//!   all come back when the region ends.

use super::encoder::Plaintext;
use super::params::{Params, NUM_Q_PRIMES};
use super::poly::{Form, RnsPoly};
use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Point-in-time arena counters ([`Arena::stats`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct ArenaStats {
    /// Buffers handed out (hits + fresh allocations).
    pub checkouts: u64,
    /// Checkouts that had to allocate because the free-list was empty (or
    /// held no size-matching buffer). Steady-state serving keeps this flat.
    pub fresh_allocs: u64,
    /// Buffers pre-allocated via [`Arena::reserve`] (not counted as fresh).
    pub reserved: u64,
}

impl ArenaStats {
    /// Fraction of checkouts served from the free-list (`1.0` = fully
    /// warmed; `phe_bench` reports this per workload).
    pub fn hit_rate(&self) -> f64 {
        if self.checkouts == 0 {
            return 1.0;
        }
        1.0 - self.fresh_allocs as f64 / self.checkouts as f64
    }
}

/// A bank of reusable [`RnsPoly`] / [`Plaintext`] / slot-value buffers with
/// hit/miss instrumentation. See the module docs for the usage contract.
#[derive(Default)]
pub struct Arena {
    polys: Mutex<Vec<RnsPoly>>,
    plains: Mutex<Vec<Plaintext>>,
    slots: Mutex<Vec<Vec<u64>>>,
    checkouts: AtomicU64,
    fresh: AtomicU64,
    reserved: AtomicU64,
}

impl Arena {
    /// An empty arena (buffers are banked as guards return them).
    pub fn new() -> Self {
        Self::default()
    }

    /// Pre-allocate `count` buffers of each kind sized for `params`, so a
    /// scoring path that never exceeds `count` concurrent checkouts per
    /// kind performs no allocation at all — not even on its first query.
    pub fn reserve(&self, params: &Params, count: usize) {
        let n = params.n;
        {
            let mut pool = self.polys.lock().unwrap();
            for _ in 0..count {
                pool.push(RnsPoly::zero(params, Form::Coeff));
            }
        }
        {
            let mut pool = self.plains.lock().unwrap();
            for _ in 0..count {
                pool.push(Plaintext { coeffs: vec![0u64; n] });
            }
        }
        {
            let mut pool = self.slots.lock().unwrap();
            for _ in 0..count {
                pool.push(vec![0u64; n]);
            }
        }
        self.reserved.fetch_add(3 * count as u64, Ordering::Relaxed);
    }

    /// Check out an [`RnsPoly`] sized for `params`, in `form`. Contents are
    /// stale; the caller must overwrite every coefficient.
    pub fn poly(&self, params: &Params, form: Form) -> PolyGuard<'_> {
        self.checkouts.fetch_add(1, Ordering::Relaxed);
        let mut poly = {
            let mut pool = self.polys.lock().unwrap();
            let found = pool
                .iter()
                .rposition(|p| p.coeffs.len() == NUM_Q_PRIMES && p.n() == params.n);
            found.map(|i| pool.swap_remove(i))
        }
        .unwrap_or_else(|| {
            self.fresh.fetch_add(1, Ordering::Relaxed);
            RnsPoly::zero(params, form)
        });
        poly.form = form;
        PolyGuard { arena: self, poly: Some(poly) }
    }

    /// Check out a [`Plaintext`] with `n` (stale) coefficients.
    pub fn plain(&self, n: usize) -> PlainGuard<'_> {
        self.checkouts.fetch_add(1, Ordering::Relaxed);
        let pt = {
            let mut pool = self.plains.lock().unwrap();
            let found = pool.iter().rposition(|p| p.coeffs.len() == n);
            found.map(|i| pool.swap_remove(i))
        }
        .unwrap_or_else(|| {
            self.fresh.fetch_add(1, Ordering::Relaxed);
            Plaintext { coeffs: vec![0u64; n] }
        });
        PlainGuard { arena: self, pt: Some(pt) }
    }

    /// Check out a zeroed slot-value buffer of length `len`.
    pub fn slots(&self, len: usize) -> SlotsGuard<'_> {
        self.checkouts.fetch_add(1, Ordering::Relaxed);
        let mut buf = {
            let mut pool = self.slots.lock().unwrap();
            let found = pool.iter().rposition(|b| b.capacity() >= len);
            found.map(|i| pool.swap_remove(i))
        }
        .unwrap_or_else(|| {
            self.fresh.fetch_add(1, Ordering::Relaxed);
            Vec::with_capacity(len)
        });
        buf.clear();
        buf.resize(len, 0);
        SlotsGuard { arena: self, buf: Some(buf) }
    }

    /// Point-in-time counters.
    pub fn stats(&self) -> ArenaStats {
        ArenaStats {
            checkouts: self.checkouts.load(Ordering::Relaxed),
            fresh_allocs: self.fresh.load(Ordering::Relaxed),
            reserved: self.reserved.load(Ordering::Relaxed),
        }
    }
}

/// Checked-out [`RnsPoly`]; derefs to the poly, returns it on drop.
pub struct PolyGuard<'a> {
    arena: &'a Arena,
    poly: Option<RnsPoly>,
}

impl Deref for PolyGuard<'_> {
    type Target = RnsPoly;
    fn deref(&self) -> &RnsPoly {
        self.poly.as_ref().expect("guard holds until drop")
    }
}

impl DerefMut for PolyGuard<'_> {
    fn deref_mut(&mut self) -> &mut RnsPoly {
        self.poly.as_mut().expect("guard holds until drop")
    }
}

impl Drop for PolyGuard<'_> {
    fn drop(&mut self) {
        if let Some(p) = self.poly.take() {
            self.arena.polys.lock().unwrap().push(p);
        }
    }
}

/// Checked-out [`Plaintext`]; derefs to the plaintext, returns it on drop.
pub struct PlainGuard<'a> {
    arena: &'a Arena,
    pt: Option<Plaintext>,
}

impl Deref for PlainGuard<'_> {
    type Target = Plaintext;
    fn deref(&self) -> &Plaintext {
        self.pt.as_ref().expect("guard holds until drop")
    }
}

impl DerefMut for PlainGuard<'_> {
    fn deref_mut(&mut self) -> &mut Plaintext {
        self.pt.as_mut().expect("guard holds until drop")
    }
}

impl Drop for PlainGuard<'_> {
    fn drop(&mut self) {
        if let Some(p) = self.pt.take() {
            self.arena.plains.lock().unwrap().push(p);
        }
    }
}

/// Checked-out slot-value buffer; derefs to `Vec<u64>`, returns on drop.
pub struct SlotsGuard<'a> {
    arena: &'a Arena,
    buf: Option<Vec<u64>>,
}

impl Deref for SlotsGuard<'_> {
    type Target = Vec<u64>;
    fn deref(&self) -> &Vec<u64> {
        self.buf.as_ref().expect("guard holds until drop")
    }
}

impl DerefMut for SlotsGuard<'_> {
    fn deref_mut(&mut self) -> &mut Vec<u64> {
        self.buf.as_mut().expect("guard holds until drop")
    }
}

impl Drop for SlotsGuard<'_> {
    fn drop(&mut self) {
        if let Some(b) = self.buf.take() {
            self.arena.slots.lock().unwrap().push(b);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params() -> Params {
        Params::new(1024, 20)
    }

    #[test]
    fn checkout_return_reuses_buffers() {
        let pr = params();
        let arena = Arena::new();
        {
            let mut p = arena.poly(&pr, Form::Ntt);
            p.coeffs[0][0] = 7;
        } // returned
        let s = arena.stats();
        assert_eq!(s.checkouts, 1);
        assert_eq!(s.fresh_allocs, 1);
        {
            let p = arena.poly(&pr, Form::Coeff);
            assert_eq!(p.form, Form::Coeff, "form is re-set on checkout");
            assert_eq!(p.coeffs[0][0], 7, "contents are stale by contract");
        }
        let s = arena.stats();
        assert_eq!(s.checkouts, 2);
        assert_eq!(s.fresh_allocs, 1, "second checkout must hit the free-list");
        assert!(s.hit_rate() > 0.49);
    }

    #[test]
    fn reserve_prevents_fresh_allocs() {
        let pr = params();
        let arena = Arena::new();
        arena.reserve(&pr, 2);
        assert_eq!(arena.stats().reserved, 6);
        {
            let _a = arena.poly(&pr, Form::Coeff);
            let _b = arena.poly(&pr, Form::Coeff);
            let _c = arena.plain(pr.n);
            let _d = arena.slots(100);
        }
        assert_eq!(arena.stats().fresh_allocs, 0, "reserved buffers must cover");
    }

    #[test]
    fn size_mismatch_allocates_fresh() {
        let arena = Arena::new();
        {
            let _small = arena.poly(&Params::new(1024, 20), Form::Coeff);
        }
        {
            let big = arena.poly(&Params::new(2048, 20), Form::Coeff);
            assert_eq!(big.n(), 2048);
        }
        assert_eq!(arena.stats().fresh_allocs, 2);
    }

    #[test]
    fn slots_are_zeroed_and_sized() {
        let arena = Arena::new();
        {
            let mut s = arena.slots(8);
            s.iter_mut().for_each(|v| *v = 9);
        }
        let s = arena.slots(4);
        assert_eq!(s.len(), 4);
        assert!(s.iter().all(|&v| v == 0), "slot buffers are re-zeroed");
    }
}
