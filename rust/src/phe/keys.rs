//! Secret keys, Galois automorphisms, and key-switching keys.
//!
//! `Perm` (slot rotation) applies a Galois automorphism `X → X^g` to the
//! ciphertext, which re-keys it under `s(X^g)`; a key-switching key (one
//! small ciphertext pair per RNS digit) converts it back to `s`. This is
//! the operation the paper measures as 34–56× slower than Mult/Add, and the
//! one CHEETAH eliminates entirely.

use super::params::{Params, NUM_Q_PRIMES};
use super::poly::{Form, RnsPoly};
use super::Context;
use crate::util::math::pow_mod;
use crate::util::rng::ChaCha20Rng;
use std::collections::HashMap;

/// The secret key: a ternary polynomial, cached in both domains.
pub struct SecretKey {
    /// NTT form (used for encrypt/decrypt inner products).
    pub s_ntt: RnsPoly,
    /// Coefficient form (used to derive automorphed keys).
    pub s_coeff: RnsPoly,
}

impl SecretKey {
    /// Sample a fresh ternary secret and cache it in both domains.
    pub fn generate(ctx: &Context, rng: &mut ChaCha20Rng) -> Self {
        let s_coeff = ctx.sample_ternary(rng);
        let mut s_ntt = s_coeff.clone();
        ctx.to_ntt(&mut s_ntt);
        Self { s_ntt, s_coeff }
    }
}

/// Galois element implementing a cyclic left-rotation of each half-row by
/// `steps` (positive) slots. `steps` must be non-zero mod `n/2`.
pub fn galois_elt_for_step(params: &Params, steps: i64) -> u64 {
    let row = params.row_size() as i64;
    let m = 2 * params.n as u64;
    let k = steps.rem_euclid(row);
    assert!(k != 0, "rotation step must be non-zero");
    pow_mod(3, k as u64, m)
}

/// Galois element swapping the two rows (SEAL's `rotate_columns`).
pub fn galois_elt_for_row_swap(params: &Params) -> u64 {
    2 * params.n as u64 - 1
}

/// Apply the automorphism `a(X) → a(X^g)` to a coefficient-form poly.
pub fn apply_galois_coeff(params: &Params, a: &RnsPoly, g: u64) -> RnsPoly {
    assert_eq!(a.form, Form::Coeff);
    let n = params.n;
    let m = 2 * n as u64;
    let mut out = RnsPoly::zero(params, Form::Coeff);
    for (i, &q) in params.qs.iter().enumerate() {
        for j in 0..n {
            let idx = (j as u64 * g) % m;
            let c = a.coeffs[i][j];
            if idx < n as u64 {
                out.coeffs[i][idx as usize] = c;
            } else {
                // X^n = -1 wraps with a sign flip.
                out.coeffs[i][(idx - n as u64) as usize] = if c == 0 { 0 } else { q - c };
            }
        }
    }
    out
}

/// Apply the automorphism to an NTT-form poly: in bit-reversed evaluation
/// order this is a pure permutation of the evaluations
/// (`B[i] = A[π_g(i)]` with `π_g` derived from the odd-exponent indexing).
pub fn apply_galois_ntt(params: &Params, a: &RnsPoly, g: u64) -> RnsPoly {
    assert_eq!(a.form, Form::Ntt);
    let n = params.n;
    let log_n = params.log_n;
    let m = 2 * n as u64;
    let mut out = RnsPoly::zero(params, Form::Ntt);
    // Precompute the permutation once; shared across RNS primes.
    let mut perm = vec![0usize; n];
    for (i, pi) in perm.iter_mut().enumerate() {
        let rb = crate::util::math::reverse_bits(i as u64, log_n);
        let idx_raw = ((2 * rb + 1) * g) % m;
        *pi = crate::util::math::reverse_bits((idx_raw - 1) >> 1, log_n) as usize;
    }
    for i in 0..NUM_Q_PRIMES {
        for j in 0..n {
            out.coeffs[i][j] = a.coeffs[i][perm[j]];
        }
    }
    out
}

/// Key-switching digit width in bits. Each 45-bit RNS residue splits into
/// `ceil(45/W)` digits of base `2^W`; finer digits mean more NTTs per Perm
/// but far lower key-switch noise (≈ `e·2^W·√n` instead of `e·q_j·√n`),
/// which is required for GAZELLE's Mult-after-Perm pattern to decrypt.
pub const KSK_DIGIT_BITS: u32 = 15;

/// Digits per RNS prime.
pub const fn digits_per_prime() -> usize {
    (45 + KSK_DIGIT_BITS as usize - 1) / KSK_DIGIT_BITS as usize
}

/// One key-switching key: for each RNS prime `j` and digit `t`, a pair
/// `(−a·s − e + 2^{Wt}·P_j·s_g,  a)` in NTT form, where `P_j` is the CRT
/// interpolation constant (`≡ 1 mod q_j`, `≡ 0` elsewhere).
pub struct KeySwitchKey {
    /// `pairs[j][t]` for prime `j`, digit `t`.
    pub pairs: Vec<Vec<(RnsPoly, RnsPoly)>>,
}

impl KeySwitchKey {
    /// Generate a key switching key re-keying from `s_from` (NTT form) to
    /// the context's secret `s`.
    pub fn generate(
        ctx: &Context,
        sk: &SecretKey,
        s_from_ntt: &RnsPoly,
        rng: &mut ChaCha20Rng,
    ) -> Self {
        let params = &ctx.params;
        let d = digits_per_prime();
        let mut pairs = Vec::with_capacity(NUM_Q_PRIMES);
        for j in 0..NUM_Q_PRIMES {
            let mut prime_pairs = Vec::with_capacity(d);
            for t in 0..d {
                let a = ctx.sample_uniform_ntt(rng);
                let mut e = ctx.sample_error(rng);
                ctx.to_ntt(&mut e);
                // k0 = -(a*s) - e + 2^{Wt}·P_j·s_from
                let mut k0 = a.clone();
                k0.mul_assign_pointwise(&sk.s_ntt, params);
                k0.negate(params);
                k0.sub_assign(&e, params);
                // P_j in RNS is the indicator (1 at prime j, 0 elsewhere);
                // scale the j-th residue of s_from by 2^{Wt} mod q_j.
                let mut pjs = s_from_ntt.clone();
                for i in 0..NUM_Q_PRIMES {
                    if i != j {
                        for c in pjs.coeffs[i].iter_mut() {
                            *c = 0;
                        }
                    } else {
                        let shift = crate::util::math::pow_mod(
                            2,
                            (KSK_DIGIT_BITS as u64) * t as u64,
                            params.qs[i],
                        );
                        for c in pjs.coeffs[i].iter_mut() {
                            *c = crate::util::math::mul_mod(*c, shift, params.qs[i]);
                        }
                    }
                }
                k0.add_assign(&pjs, params);
                prime_pairs.push((k0, a));
            }
            pairs.push(prime_pairs);
        }
        Self { pairs }
    }

    /// Serialized size in bytes (for offline-communication accounting).
    pub fn serialized_size(params: &Params) -> usize {
        let poly_bits = params.n * 45 * NUM_Q_PRIMES;
        NUM_Q_PRIMES * digits_per_prime() * 2 * poly_bits / 8
    }
}

/// A set of Galois (rotation) keys, lazily generated per Galois element.
pub struct GaloisKeys {
    /// Key-switching key per Galois element.
    pub keys: HashMap<u64, KeySwitchKey>,
}

impl GaloisKeys {
    /// Generate keys for the power-of-two row rotations plus the row swap —
    /// the set GAZELLE's rotate-and-sum networks need (arbitrary rotations
    /// compose from powers of two).
    pub fn generate_default(ctx: &Context, sk: &SecretKey, rng: &mut ChaCha20Rng) -> Self {
        let mut elts = vec![galois_elt_for_row_swap(&ctx.params)];
        let mut step = 1i64;
        while (step as usize) < ctx.params.row_size() {
            elts.push(galois_elt_for_step(&ctx.params, step));
            elts.push(galois_elt_for_step(&ctx.params, -step));
            step <<= 1;
        }
        Self::generate_for(ctx, sk, rng, &elts)
    }

    /// Generate keys for an explicit set of Galois elements.
    pub fn generate_for(
        ctx: &Context,
        sk: &SecretKey,
        rng: &mut ChaCha20Rng,
        elts: &[u64],
    ) -> Self {
        let mut keys = HashMap::new();
        for &g in elts {
            if keys.contains_key(&g) {
                continue;
            }
            // s(X^g) in NTT form.
            let s_g = apply_galois_coeff(&ctx.params, &sk.s_coeff, g);
            let mut s_g_ntt = s_g;
            ctx.to_ntt(&mut s_g_ntt);
            keys.insert(g, KeySwitchKey::generate(ctx, sk, &s_g_ntt, rng));
        }
        Self { keys }
    }

    /// The key-switching key for Galois element `g`, if generated.
    pub fn get(&self, g: u64) -> Option<&KeySwitchKey> {
        self.keys.get(&g)
    }

    /// Total serialized size (offline comm accounting).
    pub fn serialized_size(&self, params: &Params) -> usize {
        self.keys.len() * KeySwitchKey::serialized_size(params)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> Context {
        Context::new(Params::new(1024, 20))
    }

    #[test]
    fn galois_elements() {
        let c = ctx();
        let g1 = galois_elt_for_step(&c.params, 1);
        assert_eq!(g1, 3);
        assert_eq!(galois_elt_for_row_swap(&c.params), 2 * 1024 - 1);
        // Rotation by row_size-1 == rotation by -1.
        let gneg = galois_elt_for_step(&c.params, -1);
        let gpos = galois_elt_for_step(&c.params, c.params.row_size() as i64 - 1);
        assert_eq!(gneg, gpos);
    }

    #[test]
    fn galois_coeff_ntt_agree() {
        // NTT(auto_coeff(x)) == auto_ntt(NTT(x)) for several elements.
        let c = ctx();
        let mut rng = ChaCha20Rng::from_u64_seed(10);
        let mut x = c.sample_uniform_ntt(&mut rng);
        c.to_coeff(&mut x);
        for g in [3u64, 9, 2 * 1024 - 1, pow_mod(3, 17, 2 * 1024)] {
            let via_coeff = {
                let mut y = apply_galois_coeff(&c.params, &x, g);
                c.to_ntt(&mut y);
                y
            };
            let via_ntt = {
                let mut xn = x.clone();
                c.to_ntt(&mut xn);
                apply_galois_ntt(&c.params, &xn, g)
            };
            assert_eq!(via_coeff, via_ntt, "mismatch for galois element {g}");
        }
    }

    #[test]
    fn automorphism_composes() {
        let c = ctx();
        let mut rng = ChaCha20Rng::from_u64_seed(11);
        let mut x = c.sample_uniform_ntt(&mut rng);
        c.to_coeff(&mut x);
        let m = 2 * c.params.n as u64;
        let (g1, g2) = (3u64, 27u64);
        let a = apply_galois_coeff(&c.params, &apply_galois_coeff(&c.params, &x, g1), g2);
        let b = apply_galois_coeff(&c.params, &x, (g1 * g2) % m);
        assert_eq!(a, b);
    }

    #[test]
    fn default_keys_cover_powers_of_two() {
        let c = ctx();
        let mut rng = ChaCha20Rng::from_u64_seed(12);
        let sk = SecretKey::generate(&c, &mut rng);
        let gk = GaloisKeys::generate_default(&c, &sk, &mut rng);
        assert!(gk.get(galois_elt_for_row_swap(&c.params)).is_some());
        for step in [1i64, 2, 4, 256, -1, -256] {
            assert!(gk.get(galois_elt_for_step(&c.params, step)).is_some());
        }
    }
}
