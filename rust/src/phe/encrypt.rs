//! Symmetric (private-key) BFV encryption, decryption, and noise metering.
//!
//! The paper's protocols use private-key BFV on both sides (`[·]_C` and
//! `[·]_S` denote ciphertexts under the client's and server's keys). Fresh
//! symmetric ciphertexts are *seed-compressed*: the uniform `c1` component
//! is regenerated from a 32-byte seed, halving fresh-ciphertext bandwidth
//! (this matches how SEAL serializes symmetric ciphertexts and is reflected
//! in the communication accounting).

use super::encoder::Plaintext;
use super::keys::SecretKey;
use super::poly::{Form, RnsPoly};
use super::Context;
use crate::util::rng::ChaCha20Rng;
use std::sync::Arc;

/// A BFV ciphertext `(c0, c1)` with `c0 + c1·s = Δ·m + e (mod q)`.
#[derive(Clone, Debug)]
pub struct Ciphertext {
    /// The masked component `Δ·m − c1·s − e`.
    pub c0: RnsPoly,
    /// The uniform component `a` (regenerable from `seed` when fresh).
    pub c1: RnsPoly,
    /// Present iff this is a fresh symmetric encryption whose `c1` is
    /// derivable from the seed (seed-compressed wire format).
    pub seed: Option<[u8; 32]>,
}

impl Ciphertext {
    /// The representation form of both components (always equal).
    pub fn form(&self) -> Form {
        debug_assert_eq!(self.c0.form, self.c1.form);
        self.c0.form
    }

    /// Any in-place evaluation invalidates seed compression.
    pub fn mark_evaluated(&mut self) {
        self.seed = None;
    }
}

/// Holds a secret key; performs encryption, decryption and noise metering.
/// Owns a shared `Arc<Context>` (no lifetime plumbing — see DESIGN.md).
pub struct Encryptor {
    /// Shared PHE context (parameters, encoder, NTT tables).
    pub ctx: Arc<Context>,
    /// This party's secret key.
    pub sk: SecretKey,
}

impl Encryptor {
    /// Generate a fresh secret key from `rng` and wrap it with the context.
    pub fn new(ctx: Arc<Context>, rng: &mut ChaCha20Rng) -> Self {
        let sk = SecretKey::generate(&ctx, rng);
        Self { ctx, sk }
    }

    /// Symmetric encryption: sample uniform `a` from a fresh seed, small
    /// error `e`, and output `(Δm − a·s − e, a)` in NTT form.
    pub fn encrypt(&self, pt: &Plaintext, rng: &mut ChaCha20Rng) -> Ciphertext {
        let ctx = &*self.ctx;
        let mut seed = [0u8; 32];
        rng.fill_bytes(&mut seed);
        let mut a_rng = ChaCha20Rng::new(&seed, 1);
        let a = ctx.sample_uniform_ntt(&mut a_rng);

        let mut e = ctx.sample_error(rng);
        ctx.to_ntt(&mut e);

        let mut c0 = ctx.scale_plain(pt);
        ctx.to_ntt(&mut c0);
        // c0 = Δm − a·s − e
        let mut a_s = a.clone();
        a_s.mul_assign_pointwise(&self.sk.s_ntt, &ctx.params);
        c0.sub_assign(&a_s, &ctx.params);
        c0.sub_assign(&e, &ctx.params);

        Ciphertext { c0, c1: a, seed: Some(seed) }
    }

    /// Convenience: encode + encrypt signed slot values.
    pub fn encrypt_slots(&self, values: &[i64], rng: &mut ChaCha20Rng) -> Ciphertext {
        self.encrypt(&self.ctx.encoder.encode(values), rng)
    }

    /// Regenerate the `c1` component of a seed-compressed ciphertext.
    pub fn expand_seed(ctx: &Context, seed: &[u8; 32]) -> RnsPoly {
        let mut a_rng = ChaCha20Rng::new(seed, 1);
        ctx.sample_uniform_ntt(&mut a_rng)
    }

    /// The raw decryption inner product `w = c0 + c1·s` in coefficient form.
    fn decrypt_inner(&self, ct: &Ciphertext) -> RnsPoly {
        let ctx = &*self.ctx;
        let mut c0 = ct.c0.clone();
        let mut c1 = ct.c1.clone();
        ctx.to_ntt(&mut c0);
        ctx.to_ntt(&mut c1);
        c1.mul_assign_pointwise(&self.sk.s_ntt, &ctx.params);
        c0.add_assign(&c1, &ctx.params);
        ctx.to_coeff(&mut c0);
        c0
    }

    /// Decrypt to a plaintext polynomial.
    pub fn decrypt(&self, ct: &Ciphertext) -> Plaintext {
        let ctx = &*self.ctx;
        let w = self.decrypt_inner(ct);
        let coeffs =
            (0..ctx.params.n).map(|j| ctx.params.unscale_from_q(ctx.crt_reconstruct(&w, j))).collect();
        Plaintext { coeffs }
    }

    /// Decrypt + decode to centered signed slot values.
    pub fn decrypt_slots(&self, ct: &Ciphertext) -> Vec<i64> {
        self.ctx.encoder.decode(&self.decrypt(ct))
    }

    /// Remaining noise budget in bits: `log2(q/2p) − log2(max|err|)`.
    /// Returns 0 when decryption is no longer guaranteed correct.
    pub fn noise_budget(&self, ct: &Ciphertext) -> u32 {
        let allowance_bits = (127
            - (self.ctx.params.q() / (2 * self.ctx.params.p as u128)).leading_zeros())
            as i64;
        (allowance_bits - self.noise_bits(ct) as i64).max(0) as u32
    }

    /// Measured noise magnitude in bits: `ceil(log2(max|err|)) + 1` where
    /// `err` is the centered residual between the raw decryption inner
    /// product and the re-scaled rounded plaintext. This is the empirical
    /// counterpart of the static model in [`crate::plan::noise`]: decryption
    /// is exact while this stays below `log2(q/2p)`.
    pub fn noise_bits(&self, ct: &Ciphertext) -> u32 {
        let ctx = &*self.ctx;
        let q = ctx.params.q();
        let w = self.decrypt_inner(ct);
        let pt = Plaintext {
            coeffs: (0..ctx.params.n)
                .map(|j| ctx.params.unscale_from_q(ctx.crt_reconstruct(&w, j)))
                .collect(),
        };
        let clean = ctx.scale_plain(&pt);
        let mut max_err: u128 = 0;
        for j in 0..ctx.params.n {
            let a = ctx.crt_reconstruct(&w, j);
            let b = ctx.crt_reconstruct(&clean, j);
            let d = if a >= b { a - b } else { b - a };
            let centered = d.min(q - d);
            max_err = max_err.max(centered);
        }
        (128 - max_err.leading_zeros()) as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::phe::params::Params;
    use crate::util::proptest;

    fn setup() -> (Arc<Context>, ChaCha20Rng) {
        (Arc::new(Context::new(Params::new(1024, 20))), ChaCha20Rng::from_u64_seed(99))
    }

    #[test]
    fn encrypt_decrypt_roundtrip() {
        let (ctx, mut rng) = setup();
        let enc = Encryptor::new(ctx.clone(), &mut rng);
        let vals: Vec<i64> = (0..ctx.params.n as i64).map(|i| i - 512).collect();
        let ct = enc.encrypt_slots(&vals, &mut rng);
        assert_eq!(enc.decrypt_slots(&ct), vals);
    }

    #[test]
    fn fresh_ciphertext_has_budget() {
        let (ctx, mut rng) = setup();
        let enc = Encryptor::new(ctx.clone(), &mut rng);
        let ct = enc.encrypt_slots(&[1, 2, 3], &mut rng);
        let budget = enc.noise_budget(&ct);
        // q ≈ 2^90, p ≈ 2^20, fresh noise ≈ 2^7 with s·e terms → plenty left.
        assert!(budget > 40, "fresh budget only {budget} bits");
    }

    #[test]
    fn seed_expansion_matches_c1() {
        let (ctx, mut rng) = setup();
        let enc = Encryptor::new(ctx.clone(), &mut rng);
        let ct = enc.encrypt_slots(&[7, -9], &mut rng);
        let a = Encryptor::expand_seed(&ctx, &ct.seed.unwrap());
        assert_eq!(a, ct.c1);
    }

    #[test]
    fn wrong_key_garbles() {
        let (ctx, mut rng) = setup();
        let enc1 = Encryptor::new(ctx.clone(), &mut rng);
        let enc2 = Encryptor::new(ctx.clone(), &mut rng);
        let ct = enc1.encrypt_slots(&[42; 16], &mut rng);
        let dec = enc2.decrypt_slots(&ct);
        assert_ne!(&dec[..16], &[42i64; 16][..]);
    }

    #[test]
    fn prop_roundtrip_random_values() {
        let (ctx, _) = setup();
        let half = ctx.params.max_slot_value();
        proptest::check_with_rng(2024, 8, |rng| {
            let mut crng = ChaCha20Rng::from_u64_seed(rng.next_u64());
            let enc = Encryptor::new(ctx.clone(), &mut crng);
            let vals: Vec<i64> =
                (0..ctx.params.n).map(|_| rng.gen_i64_range(-half, half)).collect();
            let ct = enc.encrypt_slots(&vals, &mut crng);
            let dec = enc.decrypt_slots(&ct);
            if dec == vals {
                Ok(())
            } else {
                Err("roundtrip mismatch".into())
            }
        });
    }
}
