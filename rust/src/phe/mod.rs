//! A from-scratch packed (batched) homomorphic encryption library in the
//! BFV style — the cryptographic substrate of both CHEETAH and the GAZELLE
//! baseline.
//!
//! Supported operations (exactly the set the paper needs; §2.3):
//!
//! * symmetric (private-key) encrypt / decrypt with SIMD batching,
//! * `Add(ct, ct)`, `AddPlain(ct, pt)`, `Sub`, `Negate`,
//! * `MultPlain(ct, pt)` — ciphertext × plaintext only; CHEETAH never needs
//!   ciphertext × ciphertext,
//! * `Perm` — slot rotations via Galois automorphisms with RNS-decomposition
//!   key switching (the expensive operation CHEETAH eliminates),
//! * exact serialized-size accounting (for the paper's communication costs).
//!
//! Every evaluator operation increments an [`eval::OpCounts`] so the
//! protocol layers can report `#Perm / #Mult / #Add` exactly as the paper's
//! Tables 2–4 do.

pub mod encoder;
pub mod encrypt;
pub mod eval;
pub mod keys;
pub mod ntt;
pub mod params;
pub mod poly;
pub mod scratch;
pub mod serial;

pub use encoder::{BatchEncoder, Plaintext};
pub use encrypt::{Ciphertext, Encryptor};
pub use eval::{Evaluator, OpCounts, PlainOperand};
pub use keys::{GaloisKeys, SecretKey};
pub use params::Params;
pub use poly::{Form, RnsPoly};

use crate::util::math::{inv_mod, mul_mod, sub_mod};
use crate::util::rng::ChaCha20Rng;
use ntt::NttTables;
use params::NUM_Q_PRIMES;
use std::sync::atomic::{AtomicU64, Ordering};

/// Shared precomputed context: parameters, NTT tables for each RNS prime,
/// the batching encoder, and CRT reconstruction constants.
pub struct Context {
    /// The parameter set this context was built for.
    pub params: Params,
    /// Forward/inverse NTT tables, one per RNS prime (same order as
    /// `params.qs`).
    pub ntt: Vec<NttTables>,
    /// SIMD batching encoder over the plaintext modulus `p`.
    pub encoder: BatchEncoder,
    /// `inv(q0) mod q1` for Garner CRT reconstruction.
    inv_q0_mod_q1: u64,
    /// Allocating plaintext-operand constructions ([`Context::mult_operand`]
    /// / [`Context::add_operand`] families). The `*_into` variants writing
    /// into scratch buffers do **not** count — this counter is how the
    /// protocol's instrumentation test asserts the online scoring path
    /// builds zero fresh operand polynomials.
    operand_builds: AtomicU64,
}

impl Context {
    /// Precompute NTT tables, the batching encoder, and CRT constants for
    /// `params`.
    pub fn new(params: Params) -> Self {
        let ntt = params.qs.iter().map(|&q| NttTables::new(params.n, q)).collect();
        let encoder = BatchEncoder::new(params.n, params.p);
        let inv_q0_mod_q1 = inv_mod(params.qs[0] % params.qs[1], params.qs[1]);
        Self { params, ntt, encoder, inv_q0_mod_q1, operand_builds: AtomicU64::new(0) }
    }

    /// Number of allocating operand constructions so far (see the
    /// `operand_builds` field docs).
    pub fn operand_builds(&self) -> u64 {
        self.operand_builds.load(Ordering::Relaxed)
    }

    pub(crate) fn count_operand_build(&self) {
        self.operand_builds.fetch_add(1, Ordering::Relaxed);
    }

    /// Convert a poly to NTT form in place (no-op if already there).
    pub fn to_ntt(&self, poly: &mut RnsPoly) {
        if poly.form == Form::Ntt {
            return;
        }
        for (i, t) in self.ntt.iter().enumerate() {
            t.forward(&mut poly.coeffs[i]);
        }
        poly.form = Form::Ntt;
    }

    /// Convert a poly to coefficient form in place (no-op if already there).
    pub fn to_coeff(&self, poly: &mut RnsPoly) {
        if poly.form == Form::Coeff {
            return;
        }
        for (i, t) in self.ntt.iter().enumerate() {
            t.inverse(&mut poly.coeffs[i]);
        }
        poly.form = Form::Coeff;
    }

    /// Sample a uniform polynomial directly in NTT form (uniform in either
    /// domain — the NTT is a bijection).
    pub fn sample_uniform_ntt(&self, rng: &mut ChaCha20Rng) -> RnsPoly {
        let mut p = RnsPoly::zero(&self.params, Form::Ntt);
        for (i, &q) in self.params.qs.iter().enumerate() {
            for c in p.coeffs[i].iter_mut() {
                *c = rng.gen_range(q);
            }
        }
        p
    }

    /// Sample a small error polynomial (centered binomial, σ ≈ 3.2) in
    /// coefficient form.
    pub fn sample_error(&self, rng: &mut ChaCha20Rng) -> RnsPoly {
        let mut p = RnsPoly::zero(&self.params, Form::Coeff);
        for j in 0..self.params.n {
            let e = rng.sample_cbd(21);
            for (i, &q) in self.params.qs.iter().enumerate() {
                p.coeffs[i][j] = if e < 0 { q - ((-e) as u64) } else { e as u64 };
            }
        }
        p
    }

    /// Sample a ternary polynomial (the secret distribution) in coeff form.
    pub fn sample_ternary(&self, rng: &mut ChaCha20Rng) -> RnsPoly {
        let mut p = RnsPoly::zero(&self.params, Form::Coeff);
        for j in 0..self.params.n {
            let t = rng.sample_ternary();
            for (i, &q) in self.params.qs.iter().enumerate() {
                p.coeffs[i][j] = if t < 0 { q - 1 } else { t as u64 };
            }
        }
        p
    }

    /// Garner CRT reconstruction of coefficient `j` of `poly` into `[0, q)`.
    #[inline]
    pub fn crt_reconstruct(&self, poly: &RnsPoly, j: usize) -> u128 {
        debug_assert_eq!(poly.form, Form::Coeff);
        let (q0, q1) = (self.params.qs[0], self.params.qs[1]);
        let x0 = poly.coeffs[0][j];
        let x1 = poly.coeffs[1][j];
        let t = mul_mod(sub_mod(x1, x0 % q1, q1), self.inv_q0_mod_q1, q1);
        x0 as u128 + q0 as u128 * t as u128
    }

    /// Lift a plaintext (mod p, coefficient domain) into an RNS poly over q
    /// with **centered** lifting: residues above p/2 map to negatives mod q.
    /// This is the representation used as a `MultPlain` operand.
    pub fn lift_centered(&self, pt: &Plaintext) -> RnsPoly {
        let mut out = RnsPoly::zero(&self.params, Form::Coeff);
        self.lift_centered_into(pt, &mut out);
        out
    }

    /// [`Context::lift_centered`] into a caller-provided (scratch) poly —
    /// every coefficient of every residue is overwritten, so stale arena
    /// buffers are fine. The poly must be sized for this context.
    pub fn lift_centered_into(&self, pt: &Plaintext, out: &mut RnsPoly) {
        debug_assert_eq!(out.n(), self.params.n, "scratch poly sized for another ring");
        let p = self.params.p;
        let half = p / 2;
        for j in 0..self.params.n {
            let c = pt.coeffs[j];
            for (i, &q) in self.params.qs.iter().enumerate() {
                out.coeffs[i][j] = if c > half { q - (p - c) } else { c };
            }
        }
        out.form = Form::Coeff;
    }

    /// Scale a plaintext by `Δ = q/p` with exact rounding:
    /// `round(c·q/p)` per coefficient, in RNS. This is the representation
    /// used as an `AddPlain` operand and inside `encrypt`.
    pub fn scale_plain(&self, pt: &Plaintext) -> RnsPoly {
        let mut out = RnsPoly::zero(&self.params, Form::Coeff);
        self.scale_plain_into(pt, &mut out);
        out
    }

    /// [`Context::scale_plain`] into a caller-provided (scratch) poly —
    /// fully overwritten, so stale arena buffers are fine.
    pub fn scale_plain_into(&self, pt: &Plaintext, out: &mut RnsPoly) {
        debug_assert_eq!(out.n(), self.params.n, "scratch poly sized for another ring");
        for j in 0..self.params.n {
            let rns = self.params.scale_to_q(pt.coeffs[j]);
            for i in 0..NUM_Q_PRIMES {
                out.coeffs[i][j] = rns[i];
            }
        }
        out.form = Form::Coeff;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn context_builds() {
        let ctx = Context::new(Params::new(1024, 20));
        assert_eq!(ctx.ntt.len(), NUM_Q_PRIMES);
        assert_eq!(ctx.encoder.n, 1024);
    }

    #[test]
    fn ntt_form_roundtrip() {
        let ctx = Context::new(Params::new(1024, 20));
        let mut rng = ChaCha20Rng::from_u64_seed(1);
        let mut poly = ctx.sample_uniform_ntt(&mut rng);
        let orig = poly.clone();
        ctx.to_coeff(&mut poly);
        assert_eq!(poly.form, Form::Coeff);
        ctx.to_ntt(&mut poly);
        assert_eq!(poly, orig);
    }

    #[test]
    fn crt_reconstruct_consistent() {
        let ctx = Context::new(Params::new(1024, 20));
        let q = ctx.params.q();
        // Known value: w = 123456789012345 should reconstruct exactly.
        let w: u128 = 123_456_789_012_345;
        assert!(w < q);
        let mut poly = RnsPoly::zero(&ctx.params, Form::Coeff);
        poly.coeffs[0][0] = (w % ctx.params.qs[0] as u128) as u64;
        poly.coeffs[1][0] = (w % ctx.params.qs[1] as u128) as u64;
        assert_eq!(ctx.crt_reconstruct(&poly, 0), w);
    }

    #[test]
    fn centered_lift_negatives() {
        let ctx = Context::new(Params::new(1024, 20));
        let enc = &ctx.encoder;
        let pt = enc.encode(&[-1i64]);
        let lifted = ctx.lift_centered(&pt);
        // Reconstruct coefficient 0..n and verify each equals the centered
        // value of the plaintext coefficient mod q.
        for j in 0..8 {
            let c = pt.coeffs[j];
            let w = ctx.crt_reconstruct(&lifted, j);
            let q = ctx.params.q();
            let expect = if c > ctx.params.p / 2 {
                q - (ctx.params.p - c) as u128
            } else {
                c as u128
            };
            assert_eq!(w, expect);
        }
    }

    #[test]
    fn error_is_small() {
        let ctx = Context::new(Params::new(1024, 20));
        let mut rng = ChaCha20Rng::from_u64_seed(2);
        let e = ctx.sample_error(&mut rng);
        for j in 0..ctx.params.n {
            let w = ctx.crt_reconstruct(&e, j);
            let q = ctx.params.q();
            let centered = if w > q / 2 { (q - w) as i128 } else { w as i128 };
            assert!(centered.unsigned_abs() < 64, "error coefficient too large");
        }
    }
}
