//! SEAL-style SIMD batching encoder.
//!
//! The plaintext ring `Z_p[X]/(X^n+1)` with `p ≡ 1 (mod 2n)` splits into `n`
//! slots arranged as a `2 × n/2` matrix; `rotate_rows` cyclically shifts each
//! half-row and `rotate_columns` swaps the rows. The slot↔coefficient maps
//! are a negacyclic NTT over `Z_p` composed with the index permutation
//! induced by the group `⟨3⟩ × ⟨-1⟩ ⊂ Z_{2n}^*`.
//!
//! Values are signed, centered in `[-(p-1)/2, (p-1)/2]`.

use super::ntt::NttTables;
use crate::util::math::reverse_bits;

/// A plaintext polynomial: coefficients modulo `p`, coefficient domain.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Plaintext {
    /// The `n` polynomial coefficients, each in `[0, p)`.
    pub coeffs: Vec<u64>,
}

/// Batching encoder for a given `(n, p)`.
pub struct BatchEncoder {
    /// Plaintext modulus (batching prime, `≡ 1 mod 2n`).
    pub p: u64,
    /// Ring degree == SIMD slot count.
    pub n: usize,
    ntt: NttTables,
    /// slot index → coefficient index (after the plaintext NTT).
    index_map: Vec<usize>,
}

impl BatchEncoder {
    /// Build the encoder: plaintext NTT tables plus the slot→coefficient
    /// index permutation induced by `⟨3⟩ × ⟨-1⟩ ⊂ Z_{2n}^*`.
    pub fn new(n: usize, p: u64) -> Self {
        let ntt = NttTables::new(n, p);
        let log_n = (n as u64).trailing_zeros();
        let m = 2 * n as u64;
        let row_size = n / 2;
        let mut index_map = vec![0usize; n];
        let gen: u64 = 3;
        let mut pos: u64 = 1;
        for i in 0..row_size {
            let idx1 = ((pos - 1) >> 1) as usize;
            let idx2 = ((m - pos - 1) >> 1) as usize;
            index_map[i] = reverse_bits(idx1 as u64, log_n) as usize;
            index_map[row_size + i] = reverse_bits(idx2 as u64, log_n) as usize;
            pos = (pos * gen) & (m - 1);
        }
        Self { p, n, ntt, index_map }
    }

    /// Reduce a signed value into `[0, p)`.
    #[inline]
    pub fn to_mod_p(&self, v: i64) -> u64 {
        let p = self.p as i64;
        let r = v % p;
        (if r < 0 { r + p } else { r }) as u64
    }

    /// Center a residue `[0, p)` into `[-(p-1)/2, (p-1)/2]`.
    #[inline]
    pub fn center(&self, v: u64) -> i64 {
        if v > (self.p - 1) / 2 {
            v as i64 - self.p as i64
        } else {
            v as i64
        }
    }

    /// Encode up to `n` signed slot values into a plaintext polynomial.
    /// Missing slots are zero.
    pub fn encode(&self, values: &[i64]) -> Plaintext {
        assert!(values.len() <= self.n, "too many slots ({} > {})", values.len(), self.n);
        let mut coeffs = vec![0u64; self.n];
        for (i, &v) in values.iter().enumerate() {
            coeffs[self.index_map[i]] = self.to_mod_p(v);
        }
        self.ntt.inverse(&mut coeffs);
        Plaintext { coeffs }
    }

    /// Encode unsigned residues (already in `[0, p)`).
    pub fn encode_unsigned(&self, values: &[u64]) -> Plaintext {
        let mut pt = Plaintext { coeffs: vec![0u64; self.n] };
        self.encode_unsigned_into(values, &mut pt);
        pt
    }

    /// [`BatchEncoder::encode_unsigned`] into a caller-provided (scratch)
    /// plaintext — the buffer is resized and fully overwritten, so stale
    /// arena contents are fine. This is the allocation-free encoding the
    /// online scoring path uses for its query-dependent `AddPlain` operands.
    pub fn encode_unsigned_into(&self, values: &[u64], pt: &mut Plaintext) {
        assert!(values.len() <= self.n, "too many slots ({} > {})", values.len(), self.n);
        pt.coeffs.clear();
        pt.coeffs.resize(self.n, 0);
        for (i, &v) in values.iter().enumerate() {
            debug_assert!(v < self.p);
            pt.coeffs[self.index_map[i]] = v;
        }
        self.ntt.inverse(&mut pt.coeffs);
    }

    /// Decode a plaintext into `n` centered signed slot values.
    pub fn decode(&self, pt: &Plaintext) -> Vec<i64> {
        let mut buf = pt.coeffs.clone();
        self.ntt.forward(&mut buf);
        (0..self.n).map(|i| self.center(buf[self.index_map[i]])).collect()
    }

    /// Decode into unsigned residues `[0, p)`.
    pub fn decode_unsigned(&self, pt: &Plaintext) -> Vec<u64> {
        let mut buf = pt.coeffs.clone();
        self.ntt.forward(&mut buf);
        (0..self.n).map(|i| buf[self.index_map[i]]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::phe::params::Params;
    use crate::util::rng::SplitMix64;

    fn encoder() -> BatchEncoder {
        let pr = Params::new(1024, 20);
        BatchEncoder::new(pr.n, pr.p)
    }

    #[test]
    fn roundtrip_signed() {
        let enc = encoder();
        let mut rng = SplitMix64::new(11);
        let half = (enc.p as i64 - 1) / 2;
        let vals: Vec<i64> = (0..enc.n).map(|_| rng.gen_i64_range(-half, half)).collect();
        let pt = enc.encode(&vals);
        assert_eq!(enc.decode(&pt), vals);
    }

    #[test]
    fn partial_slots_zero_fill() {
        let enc = encoder();
        let vals = vec![5i64, -7, 123];
        let pt = enc.encode(&vals);
        let dec = enc.decode(&pt);
        assert_eq!(&dec[..3], &[5, -7, 123]);
        assert!(dec[3..].iter().all(|&v| v == 0));
    }

    #[test]
    fn slotwise_addition_is_poly_addition() {
        // encode(a) + encode(b) (coefficient-wise mod p) == encode(a + b)
        let enc = encoder();
        let mut rng = SplitMix64::new(5);
        let a: Vec<i64> = (0..enc.n).map(|_| rng.gen_i64_range(-1000, 1000)).collect();
        let b: Vec<i64> = (0..enc.n).map(|_| rng.gen_i64_range(-1000, 1000)).collect();
        let pa = enc.encode(&a);
        let pb = enc.encode(&b);
        let sum_coeffs: Vec<u64> = pa
            .coeffs
            .iter()
            .zip(&pb.coeffs)
            .map(|(&x, &y)| crate::util::math::add_mod(x, y, enc.p))
            .collect();
        let dec = enc.decode(&Plaintext { coeffs: sum_coeffs });
        let expect: Vec<i64> = a.iter().zip(&b).map(|(&x, &y)| x + y).collect();
        assert_eq!(dec, expect);
    }

    #[test]
    fn slotwise_mult_is_poly_mult() {
        // Negacyclic product of encodings == slotwise product of values.
        let enc = encoder();
        let mut rng = SplitMix64::new(6);
        let a: Vec<i64> = (0..enc.n).map(|_| rng.gen_i64_range(-100, 100)).collect();
        let b: Vec<i64> = (0..enc.n).map(|_| rng.gen_i64_range(-100, 100)).collect();
        let pa = enc.encode(&a);
        let pb = enc.encode(&b);
        // Multiply via the encoder's own NTT (over Z_p).
        let mut fa = pa.coeffs.clone();
        let mut fb = pb.coeffs.clone();
        enc.ntt.forward(&mut fa);
        enc.ntt.forward(&mut fb);
        let mut fc: Vec<u64> =
            fa.iter().zip(&fb).map(|(&x, &y)| crate::util::math::mul_mod(x, y, enc.p)).collect();
        enc.ntt.inverse(&mut fc);
        let dec = enc.decode(&Plaintext { coeffs: fc });
        let expect: Vec<i64> = a.iter().zip(&b).map(|(&x, &y)| x * y).collect();
        assert_eq!(dec, expect);
    }

    #[test]
    fn encode_unsigned_into_matches_alloc_on_stale_buffer() {
        let enc = encoder();
        let vals: Vec<u64> = (0..100u64).map(|i| (i * 37) % enc.p).collect();
        let want = enc.encode_unsigned(&vals);
        let mut pt = Plaintext { coeffs: vec![7u64; 3] }; // wrong size + stale
        enc.encode_unsigned_into(&vals, &mut pt);
        assert_eq!(pt, want);
    }

    #[test]
    fn index_map_is_permutation() {
        let enc = encoder();
        let mut seen = vec![false; enc.n];
        for &i in &enc.index_map {
            assert!(!seen[i], "index map not injective");
            seen[i] = true;
        }
    }
}
