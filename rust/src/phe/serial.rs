//! Ciphertext and plaintext wire serialization with exact bit-packing —
//! the source of truth for every communication cost the benchmarks report
//! (paper Tables 5, 7, Figs 5(d), 6(b)).
//!
//! Coefficients are packed at 45 bits per RNS residue (the prime width).
//! Fresh symmetric ciphertexts are seed-compressed: `c1` is replaced by its
//! 32-byte generation seed.

use super::encrypt::{Ciphertext, Encryptor};
use super::params::{Params, NUM_Q_PRIMES};
use super::poly::{Form, RnsPoly};
use super::Context;

/// Bits per packed RNS coefficient (the q-prime width).
pub const COEFF_BITS: usize = 45;

/// Little-endian bit writer.
pub struct BitWriter {
    buf: Vec<u8>,
    acc: u64,
    nbits: u32,
}

impl BitWriter {
    /// An empty writer.
    pub fn new() -> Self {
        Self { buf: Vec::new(), acc: 0, nbits: 0 }
    }

    /// Append the low `bits` bits of `value` (at most 57 per call).
    pub fn write(&mut self, value: u64, bits: u32) {
        debug_assert!(bits <= 57, "write at most 57 bits at a time");
        debug_assert!(bits == 64 || value < (1u64 << bits));
        self.acc |= value << self.nbits;
        self.nbits += bits;
        while self.nbits >= 8 {
            self.buf.push((self.acc & 0xff) as u8);
            self.acc >>= 8;
            self.nbits -= 8;
        }
    }

    /// Flush the partial byte and return the packed buffer.
    pub fn finish(mut self) -> Vec<u8> {
        if self.nbits > 0 {
            self.buf.push((self.acc & 0xff) as u8);
        }
        self.buf
    }
}

impl Default for BitWriter {
    fn default() -> Self {
        Self::new()
    }
}

/// Little-endian bit reader.
pub struct BitReader<'a> {
    buf: &'a [u8],
    pos: usize,
    acc: u64,
    nbits: u32,
}

impl<'a> BitReader<'a> {
    /// A reader over `buf`, positioned at the first bit.
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0, acc: 0, nbits: 0 }
    }

    /// Read the next `bits` bits (at most 57; reads past the end yield 0s).
    pub fn read(&mut self, bits: u32) -> u64 {
        debug_assert!(bits <= 57);
        while self.nbits < bits {
            let byte = self.buf.get(self.pos).copied().unwrap_or(0);
            self.acc |= (byte as u64) << self.nbits;
            self.pos += 1;
            self.nbits += 8;
        }
        let v = self.acc & ((1u64 << bits) - 1);
        self.acc >>= bits;
        self.nbits -= bits;
        v
    }
}

fn write_poly(w: &mut BitWriter, poly: &RnsPoly) {
    for i in 0..NUM_Q_PRIMES {
        for &c in &poly.coeffs[i] {
            w.write(c, COEFF_BITS as u32);
        }
    }
}

fn read_poly(r: &mut BitReader, params: &Params, form: Form) -> RnsPoly {
    let mut poly = RnsPoly::zero(params, form);
    for i in 0..NUM_Q_PRIMES {
        for j in 0..params.n {
            poly.coeffs[i][j] = r.read(COEFF_BITS as u32);
        }
    }
    poly
}

/// Serialized size in bytes of one RNS polynomial.
pub fn poly_bytes(params: &Params) -> usize {
    (params.n * NUM_Q_PRIMES * COEFF_BITS).div_ceil(8)
}

/// Serialized size of a ciphertext: seed-compressed fresh ciphertexts carry
/// one poly + 32-byte seed; evaluated ciphertexts carry two polys.
/// (+2 bytes header: form flag + seed flag.)
pub fn ciphertext_bytes(params: &Params, fresh: bool) -> usize {
    2 + if fresh { poly_bytes(params) + 32 } else { 2 * poly_bytes(params) }
}

/// Serialize a ciphertext (exact wire format used by the TCP transport).
pub fn serialize_ct(ct: &Ciphertext) -> Vec<u8> {
    let mut w = BitWriter::new();
    w.write(matches!(ct.form(), Form::Ntt) as u64, 8);
    w.write(ct.seed.is_some() as u64, 8);
    if let Some(seed) = &ct.seed {
        for &b in seed {
            w.write(b as u64, 8);
        }
        write_poly(&mut w, &ct.c0);
    } else {
        write_poly(&mut w, &ct.c0);
        write_poly(&mut w, &ct.c1);
    }
    w.finish()
}

/// Deserialize a ciphertext (expanding the seed if compressed).
pub fn deserialize_ct(ctx: &Context, buf: &[u8]) -> Ciphertext {
    let mut r = BitReader::new(buf);
    let form = if r.read(8) == 1 { Form::Ntt } else { Form::Coeff };
    let has_seed = r.read(8) == 1;
    if has_seed {
        let mut seed = [0u8; 32];
        for b in seed.iter_mut() {
            *b = r.read(8) as u8;
        }
        let c0 = read_poly(&mut r, &ctx.params, form);
        let c1 = Encryptor::expand_seed(ctx, &seed);
        debug_assert_eq!(c1.form, Form::Ntt);
        // Seeded c1 is always NTT form; fresh ciphertexts are produced in
        // NTT form, so forms agree.
        Ciphertext { c0, c1, seed: Some(seed) }
    } else {
        let c0 = read_poly(&mut r, &ctx.params, form);
        let c1 = read_poly(&mut r, &ctx.params, form);
        Ciphertext { c0, c1, seed: None }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::phe::{Encryptor, Evaluator, Params};
    use crate::util::rng::ChaCha20Rng;

    #[test]
    fn bit_rw_roundtrip() {
        let mut w = BitWriter::new();
        let vals = [(0u64, 1u32), (1, 1), (12345, 45), ((1 << 45) - 1, 45), (7, 3)];
        for &(v, b) in &vals {
            w.write(v, b);
        }
        let buf = w.finish();
        let mut r = BitReader::new(&buf);
        for &(v, b) in &vals {
            assert_eq!(r.read(b), v);
        }
    }

    #[test]
    fn ct_roundtrip_fresh_and_evaluated() {
        let ctx = std::sync::Arc::new(crate::phe::Context::new(Params::new(1024, 20)));
        let mut rng = ChaCha20Rng::from_u64_seed(77);
        let enc = Encryptor::new(ctx.clone(), &mut rng);
        let ev = Evaluator::new(ctx.clone());
        let vals: Vec<i64> = (0..100).map(|i| i * 3 - 150).collect();

        // Fresh (seed-compressed).
        let ct = enc.encrypt_slots(&vals, &mut rng);
        let buf = serialize_ct(&ct);
        assert_eq!(buf.len(), ciphertext_bytes(&ctx.params, true));
        let back = deserialize_ct(&ctx, &buf);
        assert_eq!(&enc.decrypt_slots(&back)[..100], &vals[..]);

        // Evaluated (two polys).
        let mut ct2 = ct.clone();
        ev.to_ntt(&mut ct2);
        let op = ctx.mult_operand(&vec![2i64; ctx.params.n]);
        let prod = ev.mult_plain(&ct2, &op);
        let buf2 = serialize_ct(&prod);
        assert_eq!(buf2.len(), ciphertext_bytes(&ctx.params, false));
        let back2 = deserialize_ct(&ctx, &buf2);
        let dec = enc.decrypt_slots(&back2);
        for i in 0..100 {
            assert_eq!(dec[i], vals[i] * 2);
        }
    }

    #[test]
    fn sizes_are_plausible() {
        let p = Params::default_params();
        // One poly: 4096 coeffs × 2 primes × 45 bits = 46080 bytes.
        assert_eq!(poly_bytes(&p), 46080);
        assert!(ciphertext_bytes(&p, true) < ciphertext_bytes(&p, false));
    }
}
