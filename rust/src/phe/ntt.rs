//! Negacyclic number-theoretic transform over a prime field.
//!
//! The in-place Cooley–Tukey (decimation-in-time, forward) / Gentleman–Sande
//! (inverse) pair with ψ-twisting folded into the twiddle tables, i.e. the
//! transform computes evaluations of `a(X)` at the odd powers of the
//! primitive `2n`-th root ψ — multiplication in `Z_q[X]/(X^n + 1)` becomes
//! pointwise multiplication of transforms. Twiddles are stored in
//! bit-reversed order (Longa–Naehrig / SEAL layout).
//!
//! Twiddle factors carry Shoup precomputations so the butterfly uses one
//! widening multiply and no division (see `mul_mod_shoup`); this is the
//! hot-path of the whole PHE layer.

use crate::util::math::{inv_mod, pow_mod, primitive_nth_root, reverse_bits};

/// Shoup modular multiplication: computes `a·w mod q` given the
/// precomputation `w_shoup = floor(w·2^64 / q)`. Requires `w < q`,
/// `a < 2q`, `q < 2^63`; result `< 2q` (lazy). Caller reduces when needed.
#[inline(always)]
pub fn mul_mod_shoup_lazy(a: u64, w: u64, w_shoup: u64, q: u64) -> u64 {
    let hi = ((a as u128 * w_shoup as u128) >> 64) as u64;
    a.wrapping_mul(w).wrapping_sub(hi.wrapping_mul(q))
}

/// Fully-reduced Shoup multiplication.
#[inline(always)]
pub fn mul_mod_shoup(a: u64, w: u64, w_shoup: u64, q: u64) -> u64 {
    let r = mul_mod_shoup_lazy(a, w, w_shoup, q);
    if r >= q {
        r - q
    } else {
        r
    }
}

/// Precompute the Shoup companion of `w` for modulus `q`.
#[inline]
pub fn shoup_precompute(w: u64, q: u64) -> u64 {
    (((w as u128) << 64) / q as u128) as u64
}

/// Precomputed NTT tables for one prime modulus and one ring degree.
pub struct NttTables {
    /// The prime modulus (`≡ 1 mod 2n`).
    pub q: u64,
    /// The ring degree (power of two).
    pub n: usize,
    #[allow(dead_code)]
    log_n: u32,
    /// ψ^bitrev(i) for the forward transform.
    psi_rev: Vec<u64>,
    psi_rev_shoup: Vec<u64>,
    /// ψ^{-bitrev(i)} for the inverse transform.
    psi_inv_rev: Vec<u64>,
    psi_inv_rev_shoup: Vec<u64>,
    /// n^{-1} mod q for the inverse scaling.
    n_inv: u64,
    n_inv_shoup: u64,
}

impl NttTables {
    /// Precompute ψ-twisted twiddle tables (with Shoup companions) for ring
    /// degree `n` and modulus `q`.
    pub fn new(n: usize, q: u64) -> Self {
        assert!(n.is_power_of_two());
        assert_eq!(q % (2 * n as u64), 1, "q must be ≡ 1 mod 2n");
        let log_n = (n as u64).trailing_zeros();
        let psi = primitive_nth_root(2 * n as u64, q);
        let psi_inv = inv_mod(psi, q);
        let mut psi_rev = vec![0u64; n];
        let mut psi_inv_rev = vec![0u64; n];
        for i in 0..n {
            let r = reverse_bits(i as u64, log_n);
            psi_rev[i] = pow_mod(psi, r, q);
            psi_inv_rev[i] = pow_mod(psi_inv, r, q);
        }
        let psi_rev_shoup = psi_rev.iter().map(|&w| shoup_precompute(w, q)).collect();
        let psi_inv_rev_shoup = psi_inv_rev.iter().map(|&w| shoup_precompute(w, q)).collect();
        let n_inv = inv_mod(n as u64, q);
        Self {
            q,
            n,
            log_n,
            psi_rev,
            psi_rev_shoup,
            psi_inv_rev,
            psi_inv_rev_shoup,
            n_inv,
            n_inv_shoup: shoup_precompute(n_inv, q),
        }
    }

    /// In-place forward negacyclic NTT (coefficient → evaluation,
    /// bit-reversed evaluation order). Input coefficients `< q`, output `< q`.
    pub fn forward(&self, a: &mut [u64]) {
        debug_assert_eq!(a.len(), self.n);
        let q = self.q;
        let two_q = 2 * q;
        let mut t = self.n;
        let mut m = 1usize;
        while m < self.n {
            t >>= 1;
            for i in 0..m {
                let w = self.psi_rev[m + i];
                let ws = self.psi_rev_shoup[m + i];
                let j1 = 2 * i * t;
                for j in j1..j1 + t {
                    // Harvey butterfly with lazy reduction: values stay < 4q
                    // transiently, normalized to < 2q per level.
                    let mut u = a[j];
                    if u >= two_q {
                        u -= two_q;
                    }
                    let v = mul_mod_shoup_lazy(a[j + t], w, ws, q);
                    a[j] = u + v;
                    a[j + t] = u + two_q - v;
                }
            }
            m <<= 1;
        }
        for x in a.iter_mut() {
            if *x >= two_q {
                *x -= two_q;
            }
            if *x >= q {
                *x -= q;
            }
        }
    }

    /// In-place inverse negacyclic NTT (evaluation → coefficient).
    pub fn inverse(&self, a: &mut [u64]) {
        debug_assert_eq!(a.len(), self.n);
        let q = self.q;
        let two_q = 2 * q;
        let mut t = 1usize;
        let mut m = self.n;
        while m > 1 {
            let h = m >> 1;
            let mut j1 = 0usize;
            for i in 0..h {
                let w = self.psi_inv_rev[h + i];
                let ws = self.psi_inv_rev_shoup[h + i];
                for j in j1..j1 + t {
                    let u = a[j];
                    let v = a[j + t];
                    let mut s = u + v;
                    if s >= two_q {
                        s -= two_q;
                    }
                    a[j] = s;
                    a[j + t] = mul_mod_shoup_lazy(u + two_q - v, w, ws, q);
                }
                j1 += 2 * t;
            }
            t <<= 1;
            m = h;
        }
        for x in a.iter_mut() {
            *x = mul_mod_shoup(*x, self.n_inv, self.n_inv_shoup, q);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::math::{add_mod, find_ntt_prime_below, mul_mod, sub_mod};
    use crate::util::rng::SplitMix64;

    fn naive_negacyclic_mul(a: &[u64], b: &[u64], q: u64) -> Vec<u64> {
        let n = a.len();
        let mut out = vec![0u64; n];
        for i in 0..n {
            for j in 0..n {
                let prod = mul_mod(a[i], b[j], q);
                let k = i + j;
                if k < n {
                    out[k] = add_mod(out[k], prod, q);
                } else {
                    out[k - n] = sub_mod(out[k - n], prod, q);
                }
            }
        }
        out
    }

    #[test]
    fn shoup_matches_widening() {
        let q = find_ntt_prime_below(1 << 45, 2048 * 2);
        let mut rng = SplitMix64::new(9);
        for _ in 0..1000 {
            let a = rng.gen_range(q);
            let w = rng.gen_range(q);
            let ws = shoup_precompute(w, q);
            assert_eq!(mul_mod_shoup(a, w, ws, q), mul_mod(a, w, q));
        }
    }

    #[test]
    fn forward_inverse_roundtrip() {
        for n in [1024usize, 4096] {
            let q = find_ntt_prime_below(1 << 45, 2 * n as u64);
            let t = NttTables::new(n, q);
            let mut rng = SplitMix64::new(42);
            let orig: Vec<u64> = (0..n).map(|_| rng.gen_range(q)).collect();
            let mut a = orig.clone();
            t.forward(&mut a);
            assert_ne!(a, orig); // transform does something
            t.inverse(&mut a);
            assert_eq!(a, orig);
        }
    }

    #[test]
    fn pointwise_is_negacyclic_convolution() {
        let n = 64usize; // small so the naive O(n^2) reference is fast
        let q = find_ntt_prime_below(1 << 45, 2 * n as u64);
        let t = NttTables::new(n, q);
        let mut rng = SplitMix64::new(7);
        let a: Vec<u64> = (0..n).map(|_| rng.gen_range(q)).collect();
        let b: Vec<u64> = (0..n).map(|_| rng.gen_range(q)).collect();
        let expect = naive_negacyclic_mul(&a, &b, q);

        let mut fa = a.clone();
        let mut fb = b.clone();
        t.forward(&mut fa);
        t.forward(&mut fb);
        let mut fc: Vec<u64> = fa.iter().zip(&fb).map(|(&x, &y)| mul_mod(x, y, q)).collect();
        t.inverse(&mut fc);
        assert_eq!(fc, expect);
    }

    #[test]
    fn linearity() {
        let n = 256usize;
        let q = find_ntt_prime_below(1 << 45, 2 * n as u64);
        let t = NttTables::new(n, q);
        let mut rng = SplitMix64::new(3);
        let a: Vec<u64> = (0..n).map(|_| rng.gen_range(q)).collect();
        let b: Vec<u64> = (0..n).map(|_| rng.gen_range(q)).collect();
        let sum: Vec<u64> = a.iter().zip(&b).map(|(&x, &y)| add_mod(x, y, q)).collect();
        let mut fa = a.clone();
        let mut fb = b.clone();
        let mut fs = sum.clone();
        t.forward(&mut fa);
        t.forward(&mut fb);
        t.forward(&mut fs);
        for i in 0..n {
            assert_eq!(fs[i], add_mod(fa[i], fb[i], q));
        }
    }
}
