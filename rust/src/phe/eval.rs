//! Homomorphic evaluation: Add / AddPlain / MultPlain / Sub / Negate and the
//! expensive `Perm` (rotation with key switching). Every operation ticks an
//! [`OpCounts`] so protocols report `#Perm/#Mult/#Add` exactly as the
//! paper's Tables 2–4 do.
//!
//! Convention: server-side linear algebra keeps ciphertexts in **NTT form**
//! (as GAZELLE does) so `MultPlain` and `Add` are pointwise loops; `Perm`
//! pays inverse-NTT + digit decomposition + forward NTTs — which is exactly
//! why the paper measures one `Perm` at 34–56× a `Mult`/`Add`, and why
//! eliminating `Perm` (CHEETAH's contribution) matters.

use super::encoder::Plaintext;
use super::keys::{
    apply_galois_ntt, galois_elt_for_row_swap, galois_elt_for_step, GaloisKeys, KeySwitchKey,
};
use super::params::NUM_Q_PRIMES;
use super::poly::{Form, RnsPoly};
use super::{Ciphertext, Context};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Operation counters (the paper's cost unit).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OpCounts {
    /// `Add` / `AddPlain` / `Sub` operations.
    pub add: u64,
    /// `MultPlain` operations.
    pub mult: u64,
    /// `Perm` (rotation + key switch) operations.
    pub perm: u64,
}

impl OpCounts {
    /// Component-wise sum of two counter snapshots.
    pub fn plus(&self, o: &OpCounts) -> OpCounts {
        OpCounts { add: self.add + o.add, mult: self.mult + o.mult, perm: self.perm + o.perm }
    }
}

/// What a plaintext operand is prepared for.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OperandKind {
    /// Centered lift to Z_q — for `MultPlain`.
    Mult,
    /// Δ-scaled — for `AddPlain`.
    Add,
}

/// A precomputed plaintext operand (NTT form over q). Preparation is the
/// offline phase; applying it online is a pointwise loop.
#[derive(Clone, Debug)]
pub struct PlainOperand {
    /// The prepared (lifted or Δ-scaled) operand polynomial, NTT form.
    pub poly: RnsPoly,
    /// Which operation this operand was prepared for.
    pub kind: OperandKind,
}

impl Context {
    /// Prepare a `MultPlain` operand from slot values (offline).
    pub fn mult_operand(&self, values: &[i64]) -> PlainOperand {
        self.mult_operand_pt(&self.encoder.encode(values))
    }

    /// Prepare a `MultPlain` operand from an already-encoded plaintext.
    ///
    /// Allocates the operand poly (counted by [`Context::operand_builds`]);
    /// the online scoring path instead builds its query-dependent operands
    /// into arena scratch ([`crate::phe::scratch`]) and applies them with
    /// [`Evaluator::add_plain_raw`].
    pub fn mult_operand_pt(&self, pt: &Plaintext) -> PlainOperand {
        self.count_operand_build();
        let mut poly = self.lift_centered(pt);
        self.to_ntt(&mut poly);
        PlainOperand { poly, kind: OperandKind::Mult }
    }

    /// Prepare an `AddPlain` operand from slot values (offline).
    pub fn add_operand(&self, values: &[i64]) -> PlainOperand {
        self.add_operand_pt(&self.encoder.encode(values))
    }

    /// Prepare an `AddPlain` operand from unsigned residues mod p
    /// (used for uniform secret shares).
    pub fn add_operand_unsigned(&self, values: &[u64]) -> PlainOperand {
        self.add_operand_pt(&self.encoder.encode_unsigned(values))
    }

    /// Prepare an `AddPlain` operand from an already-encoded plaintext
    /// (allocating; counted by [`Context::operand_builds`]).
    pub fn add_operand_pt(&self, pt: &Plaintext) -> PlainOperand {
        self.count_operand_build();
        let mut poly = self.scale_plain(pt);
        self.to_ntt(&mut poly);
        PlainOperand { poly, kind: OperandKind::Add }
    }
}

/// Lock-free op counters: ticked from parallel per-channel streams, so the
/// evaluator is `Sync` and one instance serves every worker thread. Totals
/// are exact regardless of interleaving (each op is one atomic increment).
#[derive(Default)]
struct Counters {
    add: AtomicU64,
    mult: AtomicU64,
    perm: AtomicU64,
}

/// Stateless evaluator over a shared context, with atomic op counters.
/// Owns an `Arc` so protocol parties and serving threads need no lifetime
/// plumbing (see DESIGN.md, "engine" section), and is `Sync` so the
/// parallel runtime ([`crate::par`]) can fan per-channel work across
/// threads sharing one evaluator.
pub struct Evaluator {
    /// Shared PHE context (parameters, encoder, NTT tables).
    pub ctx: Arc<Context>,
    counts: Counters,
}

impl Evaluator {
    /// Wrap a shared context into an evaluator with zeroed op counters.
    pub fn new(ctx: Arc<Context>) -> Self {
        Self { ctx, counts: Counters::default() }
    }

    /// Snapshot of the accumulated op counters.
    pub fn counts(&self) -> OpCounts {
        OpCounts {
            add: self.counts.add.load(Ordering::Relaxed),
            mult: self.counts.mult.load(Ordering::Relaxed),
            perm: self.counts.perm.load(Ordering::Relaxed),
        }
    }

    /// Zero the op counters.
    pub fn reset_counts(&self) {
        self.counts.add.store(0, Ordering::Relaxed);
        self.counts.mult.store(0, Ordering::Relaxed);
        self.counts.perm.store(0, Ordering::Relaxed);
    }

    /// Convert ciphertext to NTT form (free at the protocol level — done
    /// once on receipt; not counted as an op, matching GAZELLE's accounting).
    /// The two components transform independently, so they fork-join.
    pub fn to_ntt(&self, ct: &mut Ciphertext) {
        let _span = crate::obs::span("phe.ntt");
        let ctx = &self.ctx;
        let Ciphertext { c0, c1, .. } = ct;
        crate::par::join(|| ctx.to_ntt(c0), || ctx.to_ntt(c1));
    }

    /// Convert ciphertext to coefficient form (both components fork-join).
    pub fn to_coeff(&self, ct: &mut Ciphertext) {
        let _span = crate::obs::span("phe.intt");
        let ctx = &self.ctx;
        let Ciphertext { c0, c1, .. } = ct;
        crate::par::join(|| ctx.to_coeff(c0), || ctx.to_coeff(c1));
    }

    /// Convert a batch of independent ciphertexts to NTT form in parallel —
    /// the per-step ingest hot path of both protocol servers.
    pub fn to_ntt_batch(&self, cts: &mut [Ciphertext]) {
        let _span = crate::obs::span("phe.ntt_batch");
        crate::par::for_each_mut(cts, |_, ct| {
            self.ctx.to_ntt(&mut ct.c0);
            self.ctx.to_ntt(&mut ct.c1);
        });
    }

    /// `a += b` (ciphertext addition).
    pub fn add_assign(&self, a: &mut Ciphertext, b: &Ciphertext) {
        assert_eq!(a.form(), b.form(), "ciphertext form mismatch in add");
        a.c0.add_assign(&b.c0, &self.ctx.params);
        a.c1.add_assign(&b.c1, &self.ctx.params);
        a.mark_evaluated();
        self.counts.add.fetch_add(1, Ordering::Relaxed);
    }

    /// `a + b` into a fresh ciphertext.
    pub fn add(&self, a: &Ciphertext, b: &Ciphertext) -> Ciphertext {
        let mut out = a.clone();
        self.add_assign(&mut out, b);
        out
    }

    /// `a -= b`.
    pub fn sub_assign(&self, a: &mut Ciphertext, b: &Ciphertext) {
        assert_eq!(a.form(), b.form());
        a.c0.sub_assign(&b.c0, &self.ctx.params);
        a.c1.sub_assign(&b.c1, &self.ctx.params);
        a.mark_evaluated();
        self.counts.add.fetch_add(1, Ordering::Relaxed);
    }

    /// `a = -a`.
    pub fn negate(&self, a: &mut Ciphertext) {
        a.c0.negate(&self.ctx.params);
        a.c1.negate(&self.ctx.params);
        a.mark_evaluated();
    }

    /// `ct += pt` (plaintext addition; operand must be Δ-scaled and in the
    /// same form as `ct`).
    pub fn add_plain(&self, ct: &mut Ciphertext, op: &PlainOperand) {
        assert_eq!(op.kind, OperandKind::Add, "operand not prepared for AddPlain");
        self.add_plain_raw(ct, &op.poly);
    }

    /// `ct += poly` where `poly` is a raw Δ-scaled `AddPlain` operand
    /// polynomial — typically arena scratch the caller just built with
    /// [`Context::scale_plain_into`] + [`Context::to_ntt`]. Skipping the
    /// [`PlainOperand`] wrapper keeps the online path allocation-free; the
    /// caller is responsible for the operand being Δ-scaled (the kind check
    /// the wrapper would have performed). Counts as one `Add`.
    pub fn add_plain_raw(&self, ct: &mut Ciphertext, poly: &RnsPoly) {
        let _span = crate::obs::span("phe.add_plain");
        assert_eq!(ct.form(), poly.form, "form mismatch in add_plain");
        ct.c0.add_assign(poly, &self.ctx.params);
        ct.mark_evaluated();
        self.counts.add.fetch_add(1, Ordering::Relaxed);
    }

    /// `ct * pt` slot-wise into a fresh ciphertext (operand must be
    /// centered-lifted, both NTT form). Single pass: each output residue
    /// vec is built directly from the product stream — no clone-then-
    /// multiply and no zero-fill. Counts as one `Mult`.
    pub fn mult_plain(&self, ct: &Ciphertext, op: &PlainOperand) -> Ciphertext {
        let _span = crate::obs::span("phe.mult_plain");
        assert_eq!(op.kind, OperandKind::Mult, "operand not prepared for MultPlain");
        assert_eq!(ct.form(), Form::Ntt, "MultPlain requires NTT-form ciphertext");
        let params = &self.ctx.params;
        let out = Ciphertext {
            c0: RnsPoly::mul_pointwise(&ct.c0, &op.poly, params),
            c1: RnsPoly::mul_pointwise(&ct.c1, &op.poly, params),
            seed: None,
        };
        self.counts.mult.fetch_add(1, Ordering::Relaxed);
        out
    }

    /// In-place variant of [`Evaluator::mult_plain`].
    pub fn mult_plain_assign(&self, ct: &mut Ciphertext, op: &PlainOperand) {
        let _span = crate::obs::span("phe.mult_plain");
        assert_eq!(op.kind, OperandKind::Mult, "operand not prepared for MultPlain");
        assert_eq!(ct.form(), Form::Ntt, "MultPlain requires NTT-form ciphertext");
        ct.c0.mul_assign_pointwise(&op.poly, &self.ctx.params);
        ct.c1.mul_assign_pointwise(&op.poly, &self.ctx.params);
        ct.mark_evaluated();
        self.counts.mult.fetch_add(1, Ordering::Relaxed);
    }

    /// `out = ct * pt`, written directly into a preallocated output
    /// ciphertext in one pass (no clone-then-multiply temp traffic) — the
    /// online scoring path's `MultPlain`. `out`'s prior contents are
    /// irrelevant; its polys must be sized for this context. Counts as one
    /// `Mult`.
    pub fn mult_plain_into(&self, ct: &Ciphertext, op: &PlainOperand, out: &mut Ciphertext) {
        let _span = crate::obs::span("phe.mult_plain");
        assert_eq!(op.kind, OperandKind::Mult, "operand not prepared for MultPlain");
        assert_eq!(ct.form(), Form::Ntt, "MultPlain requires NTT-form ciphertext");
        out.c0.set_mul_pointwise(&ct.c0, &op.poly, &self.ctx.params);
        out.c1.set_mul_pointwise(&ct.c1, &op.poly, &self.ctx.params);
        out.seed = None;
        self.counts.mult.fetch_add(1, Ordering::Relaxed);
    }

    /// Key-switch the automorphed `c1` component back to the base key:
    /// digit-decompose each RNS residue (base `2^KSK_DIGIT_BITS`) and
    /// multiply-accumulate against the key-switching key.
    fn key_switch(&self, c1_auto: &RnsPoly, ksk: &KeySwitchKey) -> (RnsPoly, RnsPoly) {
        use crate::phe::keys::{digits_per_prime, KSK_DIGIT_BITS};
        let ctx = &*self.ctx;
        let params = &ctx.params;
        let mut c1_coeff = c1_auto.clone();
        ctx.to_coeff(&mut c1_coeff);
        let mask = (1u64 << KSK_DIGIT_BITS) - 1;
        // Each digit (j, t) contributes an independent NTT + two pointwise
        // MACs, so the digits fan out in parallel and the contributions are
        // summed afterwards (modular addition is exactly associative, so
        // the result is bit-identical to the sequential accumulation).
        let dpp = digits_per_prime();
        let contribs: Vec<(RnsPoly, RnsPoly)> =
            crate::par::map_indexed(NUM_Q_PRIMES * dpp, |jt| {
                let (j, t) = (jt / dpp, jt % dpp);
                // Digit (j, t): bits [Wt, W(t+1)) of the residue mod q_j,
                // lifted into every prime (digits are < all primes).
                let mut d = RnsPoly::zero(params, Form::Coeff);
                for k in 0..params.n {
                    let digit = (c1_coeff.coeffs[j][k] >> (KSK_DIGIT_BITS * t as u32)) & mask;
                    for i in 0..NUM_Q_PRIMES {
                        d.coeffs[i][k] = digit;
                    }
                }
                ctx.to_ntt(&mut d);
                let mut p0 = RnsPoly::zero(params, Form::Ntt);
                let mut p1 = RnsPoly::zero(params, Form::Ntt);
                p0.mac_pointwise(&d, &ksk.pairs[j][t].0, params);
                p1.mac_pointwise(&d, &ksk.pairs[j][t].1, params);
                (p0, p1)
            });
        let mut out0 = RnsPoly::zero(params, Form::Ntt);
        let mut out1 = RnsPoly::zero(params, Form::Ntt);
        for (p0, p1) in &contribs {
            out0.add_assign(p0, params);
            out1.add_assign(p1, params);
        }
        (out0, out1)
    }

    fn apply_galois(&self, ct: &Ciphertext, g: u64, gk: &GaloisKeys) -> Ciphertext {
        let _span = crate::obs::span("phe.perm");
        assert_eq!(ct.form(), Form::Ntt, "Perm requires NTT-form ciphertext");
        let ksk = gk
            .get(g)
            .unwrap_or_else(|| panic!("missing Galois key for element {g}"));
        let c0_auto = apply_galois_ntt(&self.ctx.params, &ct.c0, g);
        let c1_auto = apply_galois_ntt(&self.ctx.params, &ct.c1, g);
        let (k0, k1) = self.key_switch(&c1_auto, ksk);
        let mut c0 = c0_auto;
        c0.add_assign(&k0, &self.ctx.params);
        self.counts.perm.fetch_add(1, Ordering::Relaxed);
        Ciphertext { c0, c1: k1, seed: None }
    }

    /// `Perm`: rotate each half-row left by `steps` (may be negative).
    /// Requires the matching Galois key.
    pub fn rotate_rows(&self, ct: &Ciphertext, steps: i64, gk: &GaloisKeys) -> Ciphertext {
        let g = galois_elt_for_step(&self.ctx.params, steps);
        self.apply_galois(ct, g, gk)
    }

    /// `Perm`: swap the two rows.
    pub fn rotate_columns(&self, ct: &Ciphertext, gk: &GaloisKeys) -> Ciphertext {
        let g = galois_elt_for_row_swap(&self.ctx.params);
        self.apply_galois(ct, g, gk)
    }

    /// Rotate by an arbitrary step count using the power-of-two key set
    /// (costs `popcount(steps)` Perms — GAZELLE's composition strategy).
    pub fn rotate_rows_composed(&self, ct: &Ciphertext, steps: i64, gk: &GaloisKeys) -> Ciphertext {
        let row = self.ctx.params.row_size() as i64;
        let mut k = steps.rem_euclid(row) as u64;
        assert!(k != 0, "zero rotation");
        let mut out: Option<Ciphertext> = None;
        let mut bit = 1i64;
        while k > 0 {
            if k & 1 == 1 {
                let src = out.as_ref().unwrap_or(ct);
                out = Some(self.rotate_rows(src, bit, gk));
            }
            k >>= 1;
            bit <<= 1;
        }
        out.unwrap()
    }

    /// Rotate-and-sum: sum every half-row down to its slot 0 (and slot 0 of
    /// the second row), in `log2(row_size)` Perm+Add pairs. This is the
    /// pattern GAZELLE uses to finish a packed inner product — the cost
    /// CHEETAH's obscure computation removes.
    pub fn rotate_and_sum_rows(&self, ct: &Ciphertext, gk: &GaloisKeys) -> Ciphertext {
        let mut acc = ct.clone();
        let mut step = self.ctx.params.row_size() as i64 / 2;
        while step >= 1 {
            let rot = self.rotate_rows(&acc, step, gk);
            self.add_assign(&mut acc, &rot);
            step /= 2;
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::phe::params::Params;
    use crate::phe::Encryptor;
    use crate::util::rng::ChaCha20Rng;

    fn setup() -> (Arc<Context>, ChaCha20Rng) {
        (Arc::new(Context::new(Params::new(1024, 20))), ChaCha20Rng::from_u64_seed(5))
    }

    #[test]
    fn homomorphic_add() {
        let (ctx, mut rng) = setup();
        let enc = Encryptor::new(ctx.clone(), &mut rng);
        let ev = Evaluator::new(ctx.clone());
        let a: Vec<i64> = (0..64).collect();
        let b: Vec<i64> = (0..64).map(|i| 1000 - i).collect();
        let ca = enc.encrypt_slots(&a, &mut rng);
        let cb = enc.encrypt_slots(&b, &mut rng);
        let sum = ev.add(&ca, &cb);
        let dec = enc.decrypt_slots(&sum);
        for i in 0..64 {
            assert_eq!(dec[i], 1000);
        }
        assert_eq!(ev.counts().add, 1);
    }

    #[test]
    fn homomorphic_mult_plain() {
        let (ctx, mut rng) = setup();
        let enc = Encryptor::new(ctx.clone(), &mut rng);
        let ev = Evaluator::new(ctx.clone());
        let a: Vec<i64> = (0..ctx.params.n as i64).map(|i| i % 101 - 50).collect();
        let u: Vec<i64> = (0..ctx.params.n as i64).map(|i| i % 37 - 18).collect();
        let mut ca = enc.encrypt_slots(&a, &mut rng);
        ev.to_ntt(&mut ca);
        let op = ctx.mult_operand(&u);
        let prod = ev.mult_plain(&ca, &op);
        let dec = enc.decrypt_slots(&prod);
        for i in 0..ctx.params.n {
            assert_eq!(dec[i], a[i] * u[i], "slot {i}");
        }
        assert_eq!(ev.counts().mult, 1);
        assert!(enc.noise_budget(&prod) > 10, "budget exhausted by MultPlain");
    }

    #[test]
    fn homomorphic_add_plain() {
        let (ctx, mut rng) = setup();
        let enc = Encryptor::new(ctx.clone(), &mut rng);
        let ev = Evaluator::new(ctx.clone());
        let a = vec![10i64, -20, 30];
        let b = vec![5i64, 5, -5];
        let mut ca = enc.encrypt_slots(&a, &mut rng);
        ev.to_ntt(&mut ca);
        let op = ctx.add_operand(&b);
        ev.add_plain(&mut ca, &op);
        let dec = enc.decrypt_slots(&ca);
        assert_eq!(&dec[..3], &[15, -15, 25]);
    }

    #[test]
    fn mult_then_add_plain_exact_mod_p() {
        // The CHEETAH hop: MultPlain(kv) then AddPlain(b) must be *exact*
        // in Z_p so the client's block sums are exact.
        let (ctx, mut rng) = setup();
        let enc = Encryptor::new(ctx.clone(), &mut rng);
        let ev = Evaluator::new(ctx.clone());
        let n = ctx.params.n;
        let x: Vec<i64> = (0..n as i64).map(|i| (i * 7) % 200 - 100).collect();
        let k: Vec<i64> = (0..n as i64).map(|i| (i * 13) % 64 - 32).collect();
        let b: Vec<i64> = (0..n as i64).map(|i| (i * 31) % 5000 - 2500).collect();
        let mut cx = enc.encrypt_slots(&x, &mut rng);
        ev.to_ntt(&mut cx);
        let prod = ev.mult_plain(&cx, &ctx.mult_operand(&k));
        let mut out = prod;
        ev.add_plain(&mut out, &ctx.add_operand(&b));
        let dec = enc.decrypt_slots(&out);
        for i in 0..n {
            assert_eq!(dec[i], x[i] * k[i] + b[i], "slot {i}");
        }
    }

    #[test]
    fn into_variants_match_allocating_ops() {
        // mult_plain_into + add_plain_raw (the allocation-free online path)
        // must be bit-identical to mult_plain + add_plain.
        let (ctx, mut rng) = setup();
        let enc = Encryptor::new(ctx.clone(), &mut rng);
        let ev = Evaluator::new(ctx.clone());
        let a: Vec<i64> = (0..ctx.params.n as i64).map(|i| i % 97 - 48).collect();
        let k: Vec<i64> = (0..ctx.params.n as i64).map(|i| i % 31 - 15).collect();
        let b: Vec<i64> = (0..ctx.params.n as i64).map(|i| i % 19 - 9).collect();
        let mut ca = enc.encrypt_slots(&a, &mut rng);
        ev.to_ntt(&mut ca);
        let kop = ctx.mult_operand(&k);
        let bop = ctx.add_operand(&b);
        let mut want = ev.mult_plain(&ca, &kop);
        ev.add_plain(&mut want, &bop);
        // Stale preallocated output (wrong form, garbage contents).
        let mut got = Ciphertext {
            c0: RnsPoly::zero(&ctx.params, Form::Coeff),
            c1: RnsPoly::zero(&ctx.params, Form::Coeff),
            seed: None,
        };
        got.c0.coeffs[0][0] = 42;
        ev.mult_plain_into(&ca, &kop, &mut got);
        ev.add_plain_raw(&mut got, &bop.poly);
        assert_eq!(got.c0, want.c0);
        assert_eq!(got.c1, want.c1);
        let dec = enc.decrypt_slots(&got);
        for i in 0..ctx.params.n {
            assert_eq!(dec[i], a[i] * k[i] + b[i], "slot {i}");
        }
    }

    #[test]
    fn operand_builds_counter_ticks_on_allocating_builders_only() {
        let (ctx, mut rng) = setup();
        let enc = Encryptor::new(ctx.clone(), &mut rng);
        let ev = Evaluator::new(ctx.clone());
        let base = ctx.operand_builds();
        let op = ctx.mult_operand(&[1, 2, 3]);
        let _ = ctx.add_operand(&[4, 5]);
        assert_eq!(ctx.operand_builds() - base, 2);
        // Scratch-based application paths don't tick the counter.
        let mut ct = enc.encrypt_slots(&[1], &mut rng);
        ev.to_ntt(&mut ct);
        let mut out = Ciphertext {
            c0: RnsPoly::zero(&ctx.params, Form::Coeff),
            c1: RnsPoly::zero(&ctx.params, Form::Coeff),
            seed: None,
        };
        let arena = crate::phe::scratch::Arena::new();
        let mut pt = arena.plain(ctx.params.n);
        ctx.encoder.encode_unsigned_into(&[5, 6], &mut pt);
        let mut poly = arena.poly(&ctx.params, Form::Coeff);
        ctx.scale_plain_into(&pt, &mut poly);
        ctx.to_ntt(&mut poly);
        ev.mult_plain_into(&ct, &op, &mut out);
        ev.add_plain_raw(&mut out, &poly);
        assert_eq!(ctx.operand_builds() - base, 2, "into-variants must not tick");
    }

    #[test]
    fn rotation_rotates_rows_left() {
        let (ctx, mut rng) = setup();
        let enc = Encryptor::new(ctx.clone(), &mut rng);
        let ev = Evaluator::new(ctx.clone());
        let gk = GaloisKeys::generate_default(&ctx, &enc.sk, &mut rng);
        let row = ctx.params.row_size();
        let vals: Vec<i64> = (0..ctx.params.n as i64).collect();
        let mut ct = enc.encrypt_slots(&vals, &mut rng);
        ev.to_ntt(&mut ct);
        let rot = ev.rotate_rows(&ct, 1, &gk);
        let dec = enc.decrypt_slots(&rot);
        // Left rotation: slot i of each half-row takes the value of slot i+1.
        for i in 0..row {
            assert_eq!(dec[i], vals[(i + 1) % row], "row0 slot {i}");
            assert_eq!(dec[row + i], vals[row + (i + 1) % row], "row1 slot {i}");
        }
        assert_eq!(ev.counts().perm, 1);
    }

    #[test]
    fn rotation_negative_and_columns() {
        let (ctx, mut rng) = setup();
        let enc = Encryptor::new(ctx.clone(), &mut rng);
        let ev = Evaluator::new(ctx.clone());
        let gk = GaloisKeys::generate_default(&ctx, &enc.sk, &mut rng);
        let row = ctx.params.row_size();
        let vals: Vec<i64> = (0..ctx.params.n as i64).collect();
        let mut ct = enc.encrypt_slots(&vals, &mut rng);
        ev.to_ntt(&mut ct);

        let rot = ev.rotate_rows(&ct, -1, &gk);
        let dec = enc.decrypt_slots(&rot);
        for i in 0..row {
            assert_eq!(dec[i], vals[(i + row - 1) % row]);
        }

        let swapped = ev.rotate_columns(&ct, &gk);
        let dec = enc.decrypt_slots(&swapped);
        for i in 0..row {
            assert_eq!(dec[i], vals[row + i]);
            assert_eq!(dec[row + i], vals[i]);
        }
    }

    #[test]
    fn composed_rotation() {
        let (ctx, mut rng) = setup();
        let enc = Encryptor::new(ctx.clone(), &mut rng);
        let ev = Evaluator::new(ctx.clone());
        let gk = GaloisKeys::generate_default(&ctx, &enc.sk, &mut rng);
        let row = ctx.params.row_size();
        let vals: Vec<i64> = (0..ctx.params.n as i64).collect();
        let mut ct = enc.encrypt_slots(&vals, &mut rng);
        ev.to_ntt(&mut ct);
        let steps = 11i64; // 1011b → 3 Perms
        ev.reset_counts();
        let rot = ev.rotate_rows_composed(&ct, steps, &gk);
        assert_eq!(ev.counts().perm, 3);
        let dec = enc.decrypt_slots(&rot);
        for i in 0..row {
            assert_eq!(dec[i], vals[(i + 11) % row]);
        }
    }

    #[test]
    fn rotate_and_sum_computes_row_totals() {
        let (ctx, mut rng) = setup();
        let enc = Encryptor::new(ctx.clone(), &mut rng);
        let ev = Evaluator::new(ctx.clone());
        let gk = GaloisKeys::generate_default(&ctx, &enc.sk, &mut rng);
        let row = ctx.params.row_size();
        let vals: Vec<i64> = (0..ctx.params.n as i64).map(|i| i % 17).collect();
        let mut ct = enc.encrypt_slots(&vals, &mut rng);
        ev.to_ntt(&mut ct);
        let summed = ev.rotate_and_sum_rows(&ct, &gk);
        let dec = enc.decrypt_slots(&summed);
        let expect0: i64 = vals[..row].iter().sum();
        let expect1: i64 = vals[row..].iter().sum();
        assert_eq!(dec[0], expect0);
        assert_eq!(dec[row], expect1);
        // log2(row) Perm+Add pairs.
        assert_eq!(ev.counts().perm, (row as f64).log2() as u64);
    }

    #[test]
    fn noise_budget_decreases_monotonically() {
        let (ctx, mut rng) = setup();
        let enc = Encryptor::new(ctx.clone(), &mut rng);
        let ev = Evaluator::new(ctx.clone());
        let gk = GaloisKeys::generate_default(&ctx, &enc.sk, &mut rng);
        let mut ct = enc.encrypt_slots(&[3; 8], &mut rng);
        ev.to_ntt(&mut ct);
        let b0 = enc.noise_budget(&ct);
        let ct2 = ev.mult_plain(&ct, &ctx.mult_operand(&vec![100i64; ctx.params.n]));
        let b1 = enc.noise_budget(&ct2);
        let ct3 = ev.rotate_rows(&ct2, 1, &gk);
        let b2 = enc.noise_budget(&ct3);
        assert!(b0 > b1, "mult did not consume budget ({b0} -> {b1})");
        assert!(b1 >= b2, "perm increased budget ({b1} -> {b2})");
        assert!(b2 > 0, "budget exhausted");
    }
}
