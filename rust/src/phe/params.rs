//! BFV parameter sets.
//!
//! The paper (§2.3, §5) uses SEAL's BFV with a 20-bit plaintext modulus `p`,
//! a 60-bit ciphertext modulus `q` and "10,000 slots". A power-of-two ring
//! degree is required for negacyclic-NTT batching, so we use `n = 4096`
//! (default) or `8192`; and we represent `q` as a 2-prime RNS product
//! (2 × 45-bit ≈ 90-bit `q`) which gives the plaintext-times-ciphertext
//! noise headroom that batched `MultPlain` actually needs (see
//! `fixed/mod.rs` for the full scale-budget arithmetic). The plaintext
//! modulus defaults to 23 bits: the paper's 20-bit `p` leaves no headroom
//! for the blinded per-element products `x'∘k'∘v + b` at 8-bit quantization.
//!
//! All moduli are NTT-friendly primes `≡ 1 (mod 2n)` found deterministically
//! at construction time.

use crate::util::math::{find_ntt_prime_below, find_ntt_primes_below, ilog2};

/// Number of RNS primes composing the ciphertext modulus `q`.
pub const NUM_Q_PRIMES: usize = 2;

/// BFV-style parameter set.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Params {
    /// Ring degree (power of two). Also the SIMD slot count.
    pub n: usize,
    /// `log2(n)`.
    pub log_n: u32,
    /// RNS primes whose product is the ciphertext modulus `q`.
    pub qs: [u64; NUM_Q_PRIMES],
    /// Plaintext modulus (batching prime).
    pub p: u64,
}

impl Params {
    /// Build a parameter set with ring degree `n` and a plaintext modulus of
    /// about `plain_bits` bits. Panics if `n` is not a power of two ≥ 1024.
    pub fn new(n: usize, plain_bits: u32) -> Self {
        Self::with_q_bits(n, plain_bits, 45)
    }

    /// Build a parameter set with an explicit per-prime ciphertext-modulus
    /// width: each of the [`NUM_Q_PRIMES`] RNS primes is the largest
    /// NTT-friendly prime below `2^q_bits`. `q_bits` is capped at 45 because
    /// the wire format (`phe::serial::COEFF_BITS`) packs 45 bits per RNS
    /// residue; the planner's undersized-rung tests use smaller widths.
    /// Panics if `n` is not a power of two ≥ 1024, `plain_bits` is outside
    /// `14..=30`, or `q_bits` is outside `20..=45`.
    pub fn with_q_bits(n: usize, plain_bits: u32, q_bits: u32) -> Self {
        assert!(n.is_power_of_two() && n >= 1024, "ring degree must be a power of two >= 1024");
        assert!((14..=30).contains(&plain_bits), "plain_bits in 14..=30");
        assert!((20..=45).contains(&q_bits), "q_bits in 20..=45 (wire packs 45 bits/residue)");
        let m = 2 * n as u64;
        let qs_vec = find_ntt_primes_below(1u64 << q_bits, m, NUM_Q_PRIMES);
        let qs = [qs_vec[0], qs_vec[1]];
        let p = find_ntt_prime_below(1u64 << plain_bits, m);
        assert!(p < qs[1], "plain modulus must be below every q prime");
        Self { n, log_n: ilog2(n as u64), qs, p }
    }

    /// Default parameter set used throughout the benchmarks
    /// (n = 4096, 23-bit p, ~90-bit q).
    pub fn default_params() -> Self {
        Self::new(4096, 23)
    }

    /// Large ring (n = 8192) for paper-scale shapes.
    pub fn big_ring() -> Self {
        Self::new(8192, 23)
    }

    /// Full ciphertext modulus `q = Π qs` as u128.
    pub fn q(&self) -> u128 {
        self.qs.iter().map(|&q| q as u128).product()
    }

    /// log2(q), rounded down.
    pub fn q_bits(&self) -> u32 {
        let q = self.q();
        127 - q.leading_zeros()
    }

    /// Bit width of the plaintext modulus `p` (e.g. 23 for the default set).
    pub fn p_bits(&self) -> u32 {
        64 - self.p.leading_zeros()
    }

    /// Number of SIMD slots (== n for BFV batching; organized as a 2 × n/2
    /// matrix for rotations).
    pub fn slots(&self) -> usize {
        self.n
    }

    /// Half-row size (rotation group size).
    pub fn row_size(&self) -> usize {
        self.n / 2
    }

    /// Maximum signed value representable in a slot: `(p-1)/2`.
    pub fn max_slot_value(&self) -> i64 {
        ((self.p - 1) / 2) as i64
    }

    /// Scale a plaintext coefficient `m ∈ [0, p)` to `round(m·q/p) mod q_i`
    /// for each RNS prime (the BFV Δ-scaling with exact rounding, matching
    /// SEAL's `multiply_add_plain_with_scaling_variant`).
    #[inline]
    pub fn scale_to_q(&self, m: u64) -> [u64; NUM_Q_PRIMES] {
        debug_assert!(m < self.p);
        let q = self.q();
        let scaled = (m as u128 * q + self.p as u128 / 2) / self.p as u128;
        [
            (scaled % self.qs[0] as u128) as u64,
            (scaled % self.qs[1] as u128) as u64,
        ]
    }

    /// Round a CRT-reconstructed value `w ∈ [0, q)` back to the plaintext
    /// domain: `round(w·p/q) mod p`.
    #[inline]
    pub fn unscale_from_q(&self, w: u128) -> u64 {
        let q = self.q();
        debug_assert!(w < q);
        let m = ((w * self.p as u128 + q / 2) / q) as u64;
        m % self.p
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::math::is_prime;

    #[test]
    fn default_params_valid() {
        let pr = Params::default_params();
        assert_eq!(pr.n, 4096);
        assert_eq!(pr.log_n, 12);
        for &q in &pr.qs {
            assert!(is_prime(q));
            assert_eq!(q % (2 * pr.n as u64), 1);
            assert!(q < 1 << 45);
        }
        assert!(is_prime(pr.p));
        assert_eq!(pr.p % (2 * pr.n as u64), 1);
        assert!(pr.qs[0] != pr.qs[1]);
        assert!(pr.q_bits() >= 88);
    }

    #[test]
    fn scale_roundtrip() {
        let pr = Params::default_params();
        let q = pr.q();
        for m in [0u64, 1, 2, pr.p / 2, pr.p - 1, 12345] {
            let rns = pr.scale_to_q(m);
            // CRT-reconstruct via Garner.
            let (q0, q1) = (pr.qs[0], pr.qs[1]);
            let inv_q0 = crate::util::math::inv_mod(q0 % q1, q1);
            let x0 = rns[0];
            let x1 = rns[1];
            let t = crate::util::math::mul_mod(
                crate::util::math::sub_mod(x1, x0 % q1, q1),
                inv_q0,
                q1,
            );
            let w = x0 as u128 + q0 as u128 * t as u128;
            assert!(w < q);
            assert_eq!(pr.unscale_from_q(w), m, "roundtrip failed for {m}");
        }
    }

    #[test]
    fn big_ring_valid() {
        let pr = Params::big_ring();
        assert_eq!(pr.n, 8192);
        assert_eq!(pr.p % (2 * 8192), 1);
    }

    #[test]
    fn with_q_bits_shrinks_q() {
        let pr = Params::with_q_bits(4096, 23, 30);
        for &q in &pr.qs {
            assert!(is_prime(q));
            assert!(q < 1 << 30);
            assert_eq!(q % (2 * pr.n as u64), 1);
        }
        assert!(pr.q_bits() < 60);
        assert_eq!(pr.p_bits(), 23);
        // The default constructor is exactly the 45-bit instantiation.
        assert_eq!(Params::new(4096, 23), Params::with_q_bits(4096, 23, 45));
    }
}
