//! RNS polynomials: elements of `Z_q[X]/(X^n+1)` stored as one residue
//! vector per RNS prime, in either coefficient or evaluation (NTT) form.

use super::params::{Params, NUM_Q_PRIMES};
use crate::util::math::{add_mod, mul_mod, sub_mod};

/// Representation form of an [`RnsPoly`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Form {
    /// Coefficient domain.
    Coeff,
    /// Evaluation (NTT) domain, bit-reversed order.
    Ntt,
}

/// A polynomial in RNS representation: `coeffs[i][j]` is the `j`-th
/// coefficient (or evaluation) modulo `qs[i]`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RnsPoly {
    /// One residue vector per RNS prime: `coeffs[i][j]` is coefficient `j`
    /// modulo `qs[i]`.
    pub coeffs: Vec<Vec<u64>>,
    /// Which domain the residues currently live in.
    pub form: Form,
}

impl RnsPoly {
    /// The zero polynomial in the given form.
    pub fn zero(params: &Params, form: Form) -> Self {
        Self { coeffs: vec![vec![0u64; params.n]; NUM_Q_PRIMES], form }
    }

    /// Ring degree (coefficients per residue vector).
    pub fn n(&self) -> usize {
        self.coeffs[0].len()
    }

    /// `self += other` (componentwise; forms must match).
    pub fn add_assign(&mut self, other: &RnsPoly, params: &Params) {
        assert_eq!(self.form, other.form, "form mismatch in add");
        for (i, &q) in params.qs.iter().enumerate() {
            let (a, b) = (&mut self.coeffs[i], &other.coeffs[i]);
            for j in 0..a.len() {
                a[j] = add_mod(a[j], b[j], q);
            }
        }
    }

    /// `self -= other`.
    pub fn sub_assign(&mut self, other: &RnsPoly, params: &Params) {
        assert_eq!(self.form, other.form, "form mismatch in sub");
        for (i, &q) in params.qs.iter().enumerate() {
            let (a, b) = (&mut self.coeffs[i], &other.coeffs[i]);
            for j in 0..a.len() {
                a[j] = sub_mod(a[j], b[j], q);
            }
        }
    }

    /// `self = -self`.
    pub fn negate(&mut self, params: &Params) {
        for (i, &q) in params.qs.iter().enumerate() {
            for c in self.coeffs[i].iter_mut() {
                *c = if *c == 0 { 0 } else { q - *c };
            }
        }
    }

    /// `self ∘= other` pointwise (both must be in NTT form).
    pub fn mul_assign_pointwise(&mut self, other: &RnsPoly, params: &Params) {
        assert_eq!(self.form, Form::Ntt, "pointwise mul requires NTT form");
        assert_eq!(other.form, Form::Ntt, "pointwise mul requires NTT form");
        for (i, &q) in params.qs.iter().enumerate() {
            let (a, b) = (&mut self.coeffs[i], &other.coeffs[i]);
            for j in 0..a.len() {
                a[j] = mul_mod(a[j], b[j], q);
            }
        }
    }

    /// `a ∘ b` pointwise into a fresh poly (both NTT form) — single pass,
    /// no zero-fill of the output (each residue vec is built directly from
    /// the product stream).
    pub fn mul_pointwise(a: &RnsPoly, b: &RnsPoly, params: &Params) -> RnsPoly {
        assert_eq!(a.form, Form::Ntt, "pointwise mul requires NTT form");
        assert_eq!(b.form, Form::Ntt, "pointwise mul requires NTT form");
        let coeffs = params
            .qs
            .iter()
            .enumerate()
            .map(|(i, &q)| {
                a.coeffs[i]
                    .iter()
                    .zip(&b.coeffs[i])
                    .map(|(&x, &y)| mul_mod(x, y, q))
                    .collect()
            })
            .collect();
        RnsPoly { coeffs, form: Form::Ntt }
    }

    /// `self = a ∘ b` pointwise (both NTT form), fully overwriting `self` —
    /// the single-pass write-into-preallocated-output primitive behind
    /// [`crate::phe::Evaluator::mult_plain_into`]. `self`'s prior contents
    /// and form are irrelevant (stale scratch is fine); its dimensions must
    /// match.
    pub fn set_mul_pointwise(&mut self, a: &RnsPoly, b: &RnsPoly, params: &Params) {
        assert_eq!(a.form, Form::Ntt, "pointwise mul requires NTT form");
        assert_eq!(b.form, Form::Ntt, "pointwise mul requires NTT form");
        debug_assert_eq!(self.n(), a.n());
        for (i, &q) in params.qs.iter().enumerate() {
            let dst = &mut self.coeffs[i];
            let (x, y) = (&a.coeffs[i], &b.coeffs[i]);
            for j in 0..dst.len() {
                dst[j] = mul_mod(x[j], y[j], q);
            }
        }
        self.form = Form::Ntt;
    }

    /// `self += a ∘ b` pointwise multiply-accumulate (all NTT form).
    pub fn mac_pointwise(&mut self, a: &RnsPoly, b: &RnsPoly, params: &Params) {
        assert!(self.form == Form::Ntt && a.form == Form::Ntt && b.form == Form::Ntt);
        for (i, &q) in params.qs.iter().enumerate() {
            let dst = &mut self.coeffs[i];
            let (x, y) = (&a.coeffs[i], &b.coeffs[i]);
            for j in 0..dst.len() {
                dst[j] = add_mod(dst[j], mul_mod(x[j], y[j], q), q);
            }
        }
    }

    /// True if every residue is zero.
    pub fn is_zero(&self) -> bool {
        self.coeffs.iter().all(|v| v.iter().all(|&c| c == 0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params() -> Params {
        Params::new(1024, 20)
    }

    #[test]
    fn zero_identity() {
        let pr = params();
        let z = RnsPoly::zero(&pr, Form::Coeff);
        assert!(z.is_zero());
        let mut a = RnsPoly::zero(&pr, Form::Coeff);
        a.coeffs[0][3] = 17;
        a.coeffs[1][3] = 17;
        let b = a.clone();
        a.add_assign(&z, &pr);
        assert_eq!(a, b);
    }

    #[test]
    fn add_sub_inverse() {
        let pr = params();
        let mut a = RnsPoly::zero(&pr, Form::Coeff);
        let mut b = RnsPoly::zero(&pr, Form::Coeff);
        for i in 0..NUM_Q_PRIMES {
            for j in 0..pr.n {
                a.coeffs[i][j] = (j as u64 * 7 + 1) % pr.qs[i];
                b.coeffs[i][j] = (j as u64 * 13 + 5) % pr.qs[i];
            }
        }
        let orig = a.clone();
        a.add_assign(&b, &pr);
        a.sub_assign(&b, &pr);
        assert_eq!(a, orig);
    }

    #[test]
    fn negate_twice_is_identity() {
        let pr = params();
        let mut a = RnsPoly::zero(&pr, Form::Ntt);
        a.coeffs[0][0] = 5;
        a.coeffs[1][9] = pr.qs[1] - 1;
        let orig = a.clone();
        a.negate(&pr);
        assert_ne!(a, orig);
        a.negate(&pr);
        assert_eq!(a, orig);
    }

    #[test]
    fn set_mul_pointwise_matches_mul_assign() {
        let pr = params();
        let mut a = RnsPoly::zero(&pr, Form::Ntt);
        let mut b = RnsPoly::zero(&pr, Form::Ntt);
        for i in 0..NUM_Q_PRIMES {
            for j in 0..pr.n {
                a.coeffs[i][j] = (j as u64 * 11 + 3) % pr.qs[i];
                b.coeffs[i][j] = (j as u64 * 5 + 1) % pr.qs[i];
            }
        }
        let mut want = a.clone();
        want.mul_assign_pointwise(&b, &pr);
        // Stale scratch destination: garbage contents, wrong form.
        let mut got = RnsPoly::zero(&pr, Form::Coeff);
        got.coeffs[0][0] = 999;
        got.set_mul_pointwise(&a, &b, &pr);
        assert_eq!(got, want);
    }

    #[test]
    #[should_panic(expected = "form mismatch")]
    fn form_mismatch_panics() {
        let pr = params();
        let mut a = RnsPoly::zero(&pr, Form::Coeff);
        let b = RnsPoly::zero(&pr, Form::Ntt);
        a.add_assign(&b, &pr);
    }
}
