//! The unified inference engine: **one build→infer surface over every
//! backend** the repository implements.
//!
//! The paper's headline claim is comparative — CHEETAH vs GAZELLE vs
//! plaintext on the same networks — so the crate's entry point is a single
//! abstraction rather than four incompatible deployment types:
//!
//! ```no_run
//! use cheetah::engine::{comparison_table, Backend, EngineBuilder, InferenceEngine};
//! use cheetah::nn::{NetworkArch, SyntheticDigits};
//!
//! let input = SyntheticDigits::new(28, 99).render(5).image;
//! let reports: Vec<_> = [Backend::PlaintextQuantized, Backend::Cheetah, Backend::Gazelle]
//!     .into_iter()
//!     .map(|b| {
//!         let mut e = EngineBuilder::new(b).arch(NetworkArch::NetA).seed(42).build().unwrap();
//!         e.infer(&input).unwrap()
//!     })
//!     .collect();
//! println!("{}", comparison_table("same input, three backends", &reports));
//! ```
//!
//! * [`InferenceEngine`] — `prepare` (the offline phase), `infer`,
//!   `infer_batch`, `report`,
//! * [`EngineReport`] — argmax/logits plus optional timing / traffic /
//!   op-count sections that every native report type maps into,
//! * [`Backend`] + [`EngineBuilder`] — pick a backend, give it a network
//!   (by [`NetworkArch`] or a custom [`Network`]), a [`ScalePlan`], ε,
//!   seeds, a [`LinkModel`], and transport options; get a boxed engine.
//!
//! Ownership: everything shares one [`Arc<Context>`] — engines move freely
//! across threads (the coordinator's batcher, serve workers) with no
//! lifetime parameters anywhere in the public API.

pub mod backends;
pub mod report;

pub use backends::{
    CheetahEngine, CheetahNetEngine, GazelleEngine, NetTarget, PlaintextFloatEngine,
    PlaintextQuantizedEngine,
};
pub use report::{comparison_table, EngineReport, StepReport, Timing, Traffic};

use crate::fixed::ScalePlan;
use crate::nn::{Network, NetworkArch, Tensor};
use crate::phe::{Context, Params};
use crate::plan::{ParamsChoice, Plan, PlanError};
use crate::protocol::cheetah::{ProtocolSpec, SpecError};
use crate::protocol::gazelle::GazelleMode;
use crate::protocol::transport::LinkModel;
use crate::serve::{FaultSpec, NetClientOpts, PoolConfig, SecureConfig};
use std::net::SocketAddr;
use std::sync::Arc;
use std::time::Duration;

/// The inference backends the builder can construct.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Float reference forward pass (trusted-cloud baseline).
    PlaintextFloat,
    /// Fixed-point forward pass with the paper's δ-noise (protocol mirror).
    PlaintextQuantized,
    /// The paper's protocol, both parties in-process over a metered link.
    Cheetah,
    /// The GAZELLE baseline (rotations + GC ReLU), in-process.
    Gazelle,
    /// The GAZELLE runner in GALA greedy-packing mode (fewer rotations,
    /// bit-identical logits) — see `protocol::gala`.
    Gala,
    /// The CHEETAH protocol over real TCP via the serve subsystem.
    CheetahNet,
}

impl Backend {
    /// Stable CLI/report key for this backend (`cheetah`, `gazelle`, …).
    pub fn name(&self) -> &'static str {
        match self {
            Backend::PlaintextFloat => "plaintext-float",
            Backend::PlaintextQuantized => "plaintext-quantized",
            Backend::Cheetah => "cheetah",
            Backend::Gazelle => "gazelle",
            Backend::Gala => "gala",
            Backend::CheetahNet => "cheetah-net",
        }
    }

    /// Parse a CLI-style key (`--backend cheetah-net`). Accepts the names
    /// from [`Backend::name`] plus a few common aliases.
    pub fn from_key(key: &str) -> Option<Backend> {
        match key {
            "plaintext-float" | "plaintext" | "float" => Some(Backend::PlaintextFloat),
            "plaintext-quantized" | "quantized" => Some(Backend::PlaintextQuantized),
            "cheetah" => Some(Backend::Cheetah),
            "gazelle" => Some(Backend::Gazelle),
            "gala" | "gazelle-gala" => Some(Backend::Gala),
            "cheetah-net" | "net" | "tcp" => Some(Backend::CheetahNet),
            _ => None,
        }
    }

    /// Every backend, in the canonical comparison order.
    pub fn all() -> [Backend; 6] {
        [
            Backend::PlaintextFloat,
            Backend::PlaintextQuantized,
            Backend::Cheetah,
            Backend::Gazelle,
            Backend::Gala,
            Backend::CheetahNet,
        ]
    }
}

impl std::fmt::Display for Backend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Engine failure: a build-time configuration problem, a network the
/// protocol cannot express, or a transport error from a networked backend.
#[derive(Debug)]
pub enum EngineError {
    /// A build-time configuration problem (missing network, bad option).
    Build(String),
    /// The network cannot compile into a protocol spec (typed — previously
    /// a panic deep inside the protocol layer).
    Spec(SpecError),
    /// The parameter planner rejected the requested configuration
    /// ([`crate::plan`]): no ladder rung clears the network's noise or
    /// magnitude budget, raised before any key or ciphertext exists.
    Plan(PlanError),
    /// A transport error from a networked backend.
    Io(std::io::Error),
}

impl std::fmt::Display for EngineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EngineError::Build(msg) => write!(f, "engine build error: {msg}"),
            EngineError::Spec(e) => write!(f, "engine spec error: {e}"),
            EngineError::Plan(e) => write!(f, "engine parameter-plan error: {e}"),
            EngineError::Io(e) => write!(f, "engine transport error: {e}"),
        }
    }
}

impl std::error::Error for EngineError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            EngineError::Build(_) => None,
            EngineError::Spec(e) => Some(e),
            EngineError::Plan(e) => Some(e),
            EngineError::Io(e) => Some(e),
        }
    }
}

impl From<SpecError> for EngineError {
    fn from(e: SpecError) -> Self {
        EngineError::Spec(e)
    }
}

impl From<PlanError> for EngineError {
    fn from(e: PlanError) -> Self {
        EngineError::Plan(e)
    }
}

/// Shorthand for engine-returning results.
pub type EngineResult<T> = Result<T, EngineError>;

/// What the offline phase produced: its wall time and the bytes shipped
/// ahead of any query (indicator ciphertexts, rotation keys, garbled
/// tables — backend-dependent).
#[derive(Clone, Copy, Debug, Default)]
pub struct Prepared {
    /// Wall time of the offline phase.
    pub offline_time: Duration,
    /// Bytes shipped ahead of any query.
    pub offline_bytes: u64,
}

/// One build→infer surface over plaintext, CHEETAH, GAZELLE, and networked
/// backends. Engines are `Send`, so they drop into the coordinator's
/// batcher thread or any worker pool.
pub trait InferenceEngine: Send {
    /// Which backend this engine runs.
    fn backend(&self) -> Backend;

    /// Run the offline phase (keys, blinding material, indicator/rotation
    /// key transfer). `infer` calls this lazily if it has not run yet;
    /// calling it again refreshes the offline material.
    fn prepare(&mut self) -> EngineResult<Prepared>;

    /// Run one inference, producing the unified report.
    fn infer(&mut self, input: &Tensor) -> EngineResult<EngineReport>;

    /// Run a batch of independent inferences.
    ///
    /// Every in-process backend overrides this to fan the queries across
    /// the [`crate::par`] pool as one fork-join region, with logits
    /// **bit-identical** to looping [`InferenceEngine::infer`] over the
    /// same inputs at every thread count and batch size (per-query RNG
    /// stream isolation; see the `protocol::cheetah::client` docs). The
    /// networked backend pipelines the batch over one ordered session —
    /// or, with [`EngineBuilder::net_sessions`], fans whole queries
    /// across its pooled sessions. Batch reports fill timing and
    /// traffic; per-step breakdowns and HE op counts are
    /// single-query-mode features.
    ///
    /// The default implementation loops over `infer`.
    fn infer_batch(&mut self, inputs: &[Tensor]) -> EngineResult<Vec<EngineReport>> {
        inputs.iter().map(|x| self.infer(x)).collect()
    }

    /// The most recent inference's report, if any.
    fn report(&self) -> Option<&EngineReport>;
}

/// Builder for any [`Backend`]. Every option has a sensible default; the
/// only hard requirement is a network (via [`EngineBuilder::arch`] or
/// [`EngineBuilder::network`]) for backends that host the model themselves
/// — a [`Backend::CheetahNet`] engine pointed at a remote server with
/// [`EngineBuilder::connect_to`] downloads the architecture instead.
///
/// ```
/// use cheetah::engine::{Backend, EngineBuilder, InferenceEngine};
/// use cheetah::nn::{Layer, Network, Tensor};
///
/// // A tiny custom network through the quantized-mirror backend.
/// let mut net = Network {
///     name: "doctest".into(),
///     input_shape: (1, 4, 4),
///     layers: vec![Layer::fc(4), Layer::relu(), Layer::fc(3)],
/// };
/// net.init_weights(7);
/// let mut engine = EngineBuilder::new(Backend::PlaintextQuantized)
///     .network(net)
///     .threads(2) // scoped to this engine, not the process
///     .build()
///     .expect("valid network");
///
/// let input = Tensor::from_vec(vec![0.25; 16], 1, 4, 4);
/// let one = engine.infer(&input).expect("inference");
/// assert_eq!(one.logits.len(), 3);
///
/// // Batched inference fans out on the par pool and stays bit-identical
/// // to single queries (ε = 0 here, so repeats are exact).
/// let batch = engine.infer_batch(&[input.clone(), input]).expect("batch");
/// assert_eq!(batch.len(), 2);
/// assert_eq!(batch[0].logits, one.logits);
/// assert_eq!(batch[1].logits, one.logits);
/// ```
pub struct EngineBuilder {
    backend: Backend,
    arch: Option<NetworkArch>,
    arch_seed: u64,
    scale: f64,
    network: Option<Network>,
    plan: ScalePlan,
    epsilon: f64,
    seed: u64,
    ctx: Option<Arc<Context>>,
    params: ParamsChoice,
    link: LinkModel,
    remote: Option<SocketAddr>,
    secure: Option<SecureConfig>,
    threads: Option<usize>,
    net_sessions: usize,
    net_deadline_ms: Option<u64>,
    net_fault: Option<FaultSpec>,
}

impl EngineBuilder {
    /// Start a builder for `backend` with every option at its default.
    pub fn new(backend: Backend) -> Self {
        Self {
            backend,
            arch: None,
            arch_seed: 11,
            scale: 1.0,
            network: None,
            plan: ScalePlan::default_plan(),
            epsilon: 0.0,
            seed: 1,
            ctx: None,
            params: ParamsChoice::Default,
            link: LinkModel::gigabit_lan(),
            remote: None,
            secure: None,
            threads: None,
            net_sessions: 1,
            net_deadline_ms: None,
            net_fault: None,
        }
    }

    /// Use a named zoo architecture with seeded random weights.
    pub fn arch(mut self, arch: NetworkArch) -> Self {
        self.arch = Some(arch);
        self
    }

    /// Weight seed for [`EngineBuilder::arch`] (default 11).
    pub fn arch_seed(mut self, seed: u64) -> Self {
        self.arch_seed = seed;
        self
    }

    /// Spatial scale factor for [`EngineBuilder::arch`] (default 1.0).
    pub fn scaled(mut self, f: f64) -> Self {
        self.scale = f;
        self
    }

    /// Use a custom network (takes precedence over `arch`).
    pub fn network(mut self, net: Network) -> Self {
        self.network = Some(net);
        self
    }

    /// Fixed-point scale plan (default [`ScalePlan::default_plan`]).
    pub fn plan(mut self, plan: ScalePlan) -> Self {
        self.plan = plan;
        self
    }

    /// Obscuring-noise bound ε (default 0.0 = exact).
    pub fn epsilon(mut self, eps: f64) -> Self {
        self.epsilon = eps;
        self
    }

    /// Protocol seed: server blinding material uses `seed`; client keys
    /// use a distinct derivation (`seed + 1` in-process, a domain-separated
    /// value for the networked backend). Pin it for reproducible runs; see
    /// CHANGES.md on per-seed bit-exactness.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Share a pre-built PHE context (default: fresh
    /// [`Params::default_params`] context, built once per engine). Takes
    /// precedence over [`EngineBuilder::params`].
    pub fn context(mut self, ctx: Arc<Context>) -> Self {
        self.ctx = Some(ctx);
        self
    }

    /// RLWE parameter policy (default [`ParamsChoice::Default`], which is
    /// bit-compatible with every pinned-seed artifact):
    ///
    /// * [`ParamsChoice::Default`] — [`Params::default_params`];
    /// * [`ParamsChoice::Explicit`] — a caller-supplied set, used as-is;
    /// * [`ParamsChoice::Auto`] — run the [`crate::plan`] planner against
    ///   the resolved network and take the cheapest ladder rung whose
    ///   worst step clears the safety margin (a typed
    ///   [`EngineError::Plan`] if none does).
    ///
    /// Ignored when an explicit [`EngineBuilder::context`] is shared —
    /// that context's parameters win. `Auto` needs a local model, so it is
    /// a build error for a [`Backend::CheetahNet`] engine pointed at a
    /// remote server via [`EngineBuilder::connect_to`].
    pub fn params(mut self, choice: ParamsChoice) -> Self {
        self.params = choice;
        self
    }

    /// Link cost model for in-process backends (default gigabit LAN).
    pub fn link(mut self, link: LinkModel) -> Self {
        self.link = link;
        self
    }

    /// `CheetahNet`: connect to an already-running secure server instead of
    /// self-hosting one on loopback.
    pub fn connect_to(mut self, addr: SocketAddr) -> Self {
        self.remote = Some(addr);
        self
    }

    /// `CheetahNet` self-hosting: override the server configuration
    /// (default: ε/seed from this builder, pool disabled, 2 workers).
    pub fn secure_config(mut self, cfg: SecureConfig) -> Self {
        self.secure = Some(cfg);
        self
    }

    /// `CheetahNet`: pooled TCP sessions behind this one engine (default
    /// 1; clamped to ≥ 1). Single [`InferenceEngine::infer`] calls ride
    /// the first session; [`InferenceEngine::infer_batch`] splits the
    /// batch across all `n` sessions on scoped threads — whole-query
    /// parallelism over real sockets instead of pipelining every query
    /// down one ordered round stream. Each session handshakes and ships
    /// its own offline material; per-query results are independent of the
    /// pool size.
    pub fn net_sessions(mut self, n: usize) -> Self {
        self.net_sessions = n.max(1);
        self
    }

    /// `CheetahNet`: per-round client deadline in milliseconds (default
    /// 30 000). Reads that exceed it fail the attempt with a typed
    /// deadline error, which the client's bounded reconnect-and-replay
    /// loop then absorbs — see [`crate::serve::NetClientOpts`].
    pub fn net_deadline_ms(mut self, ms: u64) -> Self {
        self.net_deadline_ms = Some(ms);
        self
    }

    /// `CheetahNet`: inject deterministic client-side socket faults
    /// (chaos/robustness testing; see [`crate::serve::FaultSpec`]).
    /// Defaults to the `CHEETAH_FAULT` environment spec, or no faults.
    pub fn net_fault(mut self, spec: FaultSpec) -> Self {
        self.net_fault = Some(spec);
        self
    }

    /// Compute threads for the parallel runtime ([`crate::par`]): the
    /// protocol's per-channel ciphertext streams, NTT batches, plaintext
    /// conv loops, and the batch driver's per-query fan-out all target
    /// this many threads. Default: the global setting (`CHEETAH_THREADS`
    /// env var, [`crate::par::set_threads`], else
    /// `available_parallelism()`). `1` forces the exact sequential code
    /// path; the arithmetic is bit-identical at every thread count.
    ///
    /// **Scope: per-engine.** The built engine wraps every
    /// `prepare`/`infer`/`infer_batch` call in
    /// [`crate::par::with_threads`], so the override applies to this
    /// engine's own calls only — building an engine can never resize a
    /// live server's parallelism (servers pin theirs via
    /// [`SecureConfig::threads`]). `0` (or not calling this) keeps the
    /// global setting. For a self-hosted [`Backend::CheetahNet`] engine
    /// the value is also forwarded to the loopback server's config, so
    /// both sides of the socket honor it.
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = Some(n);
        self
    }

    fn resolve_network(&self) -> EngineResult<Network> {
        if let Some(net) = &self.network {
            return Ok(net.clone());
        }
        match self.arch {
            Some(arch) => Ok(Network::build_scaled(arch, self.arch_seed, self.scale)),
            None => Err(EngineError::Build(format!(
                "backend `{}` hosts the model itself: give the builder .network(...) or .arch(...)",
                self.backend
            ))),
        }
    }

    /// Resolve the PHE context: a shared [`EngineBuilder::context`] wins;
    /// otherwise the [`EngineBuilder::params`] policy decides, with `Auto`
    /// running the planner against `net` (which the remote networked path
    /// does not have).
    fn resolve_context(&self, net: Option<&Network>) -> EngineResult<Arc<Context>> {
        if let Some(ctx) = &self.ctx {
            return Ok(ctx.clone());
        }
        let params = match (self.params, net) {
            (ParamsChoice::Default, _) => Params::default_params(),
            (ParamsChoice::Explicit(p), _) => p,
            (ParamsChoice::Auto, Some(net)) => Plan::for_network(net)?.params,
            (ParamsChoice::Auto, None) => {
                return Err(EngineError::Build(
                    "auto parameter selection needs a local network to analyze: \
                     give the builder .network(...)/.arch(...), or share an explicit .context(...)"
                        .into(),
                ));
            }
        };
        Ok(Arc::new(Context::new(params)))
    }

    /// Construct the engine. Heavy offline work (key generation, blinding,
    /// handshakes) is deferred to [`InferenceEngine::prepare`] so builds are
    /// cheap and the offline phase stays measurable — but the network →
    /// protocol-spec compilation is validated **here** for every backend
    /// that hosts a model, so a malformed network is a typed build error
    /// (never a panic inside `prepare`/`infer` or a serving thread).
    pub fn build(self) -> EngineResult<Box<dyn InferenceEngine>> {
        let threads = self.threads;
        let engine: Box<dyn InferenceEngine> = match self.backend {
            Backend::PlaintextFloat => Box::new(PlaintextFloatEngine::new(self.resolve_network()?)),
            Backend::PlaintextQuantized => Box::new(PlaintextQuantizedEngine::new(
                self.resolve_network()?,
                self.plan,
                self.epsilon,
                self.seed,
            )),
            Backend::Cheetah => {
                let net = self.resolve_network()?;
                ProtocolSpec::compile(&net)?;
                let ctx = self.resolve_context(Some(&net))?;
                Box::new(CheetahEngine::new(
                    ctx,
                    net,
                    self.plan,
                    self.epsilon,
                    self.seed,
                    self.link,
                ))
            }
            Backend::Gazelle | Backend::Gala => {
                let net = self.resolve_network()?;
                ProtocolSpec::compile(&net)?;
                let ctx = self.resolve_context(Some(&net))?;
                let mode = match self.backend {
                    Backend::Gala => GazelleMode::Gala,
                    _ => GazelleMode::Hybrid,
                };
                Box::new(GazelleEngine::new(ctx, net, self.plan, self.seed, mode))
            }
            Backend::CheetahNet => {
                let (ctx, target) = match self.remote {
                    Some(addr) => (self.resolve_context(None)?, NetTarget::Remote(addr)),
                    None => {
                        let net = self.resolve_network()?;
                        ProtocolSpec::compile(&net)?;
                        let ctx = self.resolve_context(Some(&net))?;
                        let target = NetTarget::SelfHosted {
                            net,
                            cfg: self.secure.unwrap_or(SecureConfig {
                                epsilon: self.epsilon,
                                seed: Some(self.seed),
                                workers: 2,
                                pool: PoolConfig::disabled(),
                                // A per-engine thread override also scopes
                                // the loopback server's side of the work.
                                threads: self.threads.unwrap_or(0),
                                ..SecureConfig::default()
                            }),
                        };
                        (ctx, target)
                    }
                };
                let mut opts = NetClientOpts::default();
                if let Some(ms) = self.net_deadline_ms {
                    opts.deadline = Duration::from_millis(ms);
                }
                if let Some(spec) = self.net_fault {
                    opts.fault = Some(spec);
                }
                Box::new(
                    CheetahNetEngine::new(ctx, self.plan, self.seed, target, self.net_sessions)
                        .net_opts(opts),
                )
            }
        };
        Ok(match threads {
            Some(n) if n > 0 => Box::new(ScopedEngine { inner: engine, threads: n }),
            _ => engine,
        })
    }
}

/// Wrapper pinning the [`crate::par`] thread count around every call into
/// the inner engine — what `EngineBuilder::threads(n)` builds. The scope
/// travels with the calling thread only ([`crate::par::with_threads`]), so
/// two engines with different `threads` settings, or an engine and a live
/// [`crate::serve::SecureServer`], never fight over a global knob.
struct ScopedEngine {
    inner: Box<dyn InferenceEngine>,
    threads: usize,
}

impl InferenceEngine for ScopedEngine {
    fn backend(&self) -> Backend {
        self.inner.backend()
    }

    fn prepare(&mut self) -> EngineResult<Prepared> {
        let inner = &mut self.inner;
        crate::par::with_threads(self.threads, || inner.prepare())
    }

    fn infer(&mut self, input: &Tensor) -> EngineResult<EngineReport> {
        let inner = &mut self.inner;
        crate::par::with_threads(self.threads, || inner.infer(input))
    }

    fn infer_batch(&mut self, inputs: &[Tensor]) -> EngineResult<Vec<EngineReport>> {
        let inner = &mut self.inner;
        crate::par::with_threads(self.threads, || inner.infer_batch(inputs))
    }

    fn report(&self) -> Option<&EngineReport> {
        self.inner.report()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::SyntheticDigits;

    #[test]
    fn backend_keys_roundtrip() {
        for b in Backend::all() {
            assert_eq!(Backend::from_key(b.name()), Some(b), "{b}");
        }
        assert_eq!(Backend::from_key("quantized"), Some(Backend::PlaintextQuantized));
        assert_eq!(Backend::from_key("nope"), None);
    }

    #[test]
    fn builder_requires_a_network_for_self_hosting_backends() {
        let err = EngineBuilder::new(Backend::Cheetah).build().map(|_| ()).unwrap_err();
        assert!(matches!(err, EngineError::Build(_)), "{err}");
    }

    #[test]
    fn malformed_network_is_a_typed_build_error() {
        use crate::nn::Layer;
        let bad = Network {
            name: "relu-first".into(),
            input_shape: (1, 4, 4),
            layers: vec![Layer::relu(), Layer::fc(2)],
        };
        for backend in [Backend::Cheetah, Backend::Gazelle, Backend::Gala, Backend::CheetahNet] {
            let err = EngineBuilder::new(backend)
                .network(bad.clone())
                .build()
                .map(|_| ())
                .unwrap_err();
            assert!(matches!(err, EngineError::Spec(_)), "{backend}: {err}");
        }
    }

    #[test]
    fn plaintext_engines_agree_on_a_digit() {
        let sample = SyntheticDigits::new(28, 123).render(4);
        let mut float = EngineBuilder::new(Backend::PlaintextFloat)
            .arch(NetworkArch::NetA)
            .arch_seed(3)
            .build()
            .unwrap();
        let mut quant = EngineBuilder::new(Backend::PlaintextQuantized)
            .arch(NetworkArch::NetA)
            .arch_seed(3)
            .build()
            .unwrap();
        let f = float.infer(&sample.image).unwrap();
        let q = quant.infer(&sample.image).unwrap();
        assert_eq!(f.argmax, q.argmax, "quantization changed the argmax");
        assert_eq!(f.logits.len(), 10);
        assert!(float.report().is_some());
        // infer_batch default covers every input.
        let reps = quant.infer_batch(&[sample.image.clone(), sample.image]).unwrap();
        assert_eq!(reps.len(), 2);
        assert_eq!(reps[0].argmax, q.argmax);
    }

    /// A pooled networked engine (`net_sessions > 1`) keeps reports in
    /// input order and computes exactly what a hand-rolled client pool
    /// computes: pooled session `k` pairs server engine seed `base+k`
    /// (sequential connects, pool disabled) with the mixed client seed
    /// `client_session_seed(seed, k)`, so replaying that pairing against a
    /// second identically-seeded server must reproduce every logit.
    #[test]
    fn pooled_net_sessions_preserve_order_and_results() {
        use crate::nn::Layer;
        use crate::serve::{CheetahNetClient, SecureServer};
        let ctx = Arc::new(Context::new(Params::default_params()));
        let plan = ScalePlan::default_plan();
        let mut net = Network {
            name: "pool-test".into(),
            input_shape: (1, 5, 5),
            layers: vec![Layer::conv(2, 3, 1, 1), Layer::relu(), Layer::fc(3)],
        };
        net.init_weights(19);
        let cfg = SecureConfig {
            workers: 2,
            seed: Some(17),
            pool: PoolConfig::disabled(),
            ..SecureConfig::default()
        };
        let inputs: Vec<Tensor> = (0..5)
            .map(|i| {
                let data = (0..25).map(|j| (j as f64 - 12.0) / 13.0 + i as f64 * 0.01).collect();
                Tensor::from_vec(data, 1, 5, 5)
            })
            .collect();

        // Reference: a manual pool of 3 sessions against server A, fed the
        // same contiguous chunks the engine's batch splitter produces
        // (5 over 3 → lengths 2, 2, 1).
        let server_a =
            SecureServer::serve(ctx.clone(), net.clone(), plan, "127.0.0.1:0", cfg).unwrap();
        let mut want: Vec<Vec<f64>> = Vec::new();
        let chunks: [&[Tensor]; 3] = [&inputs[0..2], &inputs[2..4], &inputs[4..5]];
        for (k, chunk) in chunks.iter().enumerate() {
            let seed = backends::client_session_seed(17, k);
            let mut c = CheetahNetClient::connect(ctx.clone(), plan, &server_a.addr, seed).unwrap();
            for x in *chunk {
                want.push(c.infer(x).unwrap().logits);
            }
            c.bye().unwrap();
        }
        server_a.shutdown();

        // Pooled engine against server B (same seeds, fresh sessions).
        let server_b = SecureServer::serve(ctx.clone(), net, plan, "127.0.0.1:0", cfg).unwrap();
        let mut engine = EngineBuilder::new(Backend::CheetahNet)
            .connect_to(server_b.addr)
            .context(ctx)
            .plan(plan)
            .seed(17)
            .net_sessions(3)
            .build()
            .unwrap();
        let reps = engine.infer_batch(&inputs).unwrap();
        assert_eq!(reps.len(), inputs.len());
        let got: Vec<Vec<f64>> = reps.iter().map(|r| r.logits.clone()).collect();
        assert_eq!(got, want, "pooled batch diverged from the manual session pool");
        drop(engine);
        server_b.shutdown();
    }

    /// The params policy threads end to end: an `Auto` build runs the
    /// planner (a tiny net stays on the default rung and the report keys
    /// it), plaintext backends report no parameter set, and `Auto` on a
    /// remote networked engine — no local model to analyze — is a typed
    /// build error.
    #[test]
    fn params_choice_threads_through_build_and_report() {
        use crate::nn::Layer;
        let mut net = Network {
            name: "params-test".into(),
            input_shape: (1, 5, 5),
            layers: vec![Layer::conv(2, 3, 1, 1), Layer::relu(), Layer::fc(3)],
        };
        net.init_weights(23);
        let input = Tensor::from_vec((0..25).map(|i| (i as f64 - 12.0) / 13.0).collect(), 1, 5, 5);

        let mut auto = EngineBuilder::new(Backend::Cheetah)
            .network(net.clone())
            .seed(9)
            .params(ParamsChoice::Auto)
            .build()
            .unwrap();
        let rep = auto.infer(&input).unwrap();
        assert_eq!(rep.params_key(), "n4096p23", "tiny net stays on the default rung");

        let mut quant =
            EngineBuilder::new(Backend::PlaintextQuantized).network(net).build().unwrap();
        let rep = quant.infer(&input).unwrap();
        assert_eq!(rep.params_key(), "-", "plaintext backends report no params");

        let err = EngineBuilder::new(Backend::CheetahNet)
            .connect_to("127.0.0.1:9".parse().unwrap())
            .params(ParamsChoice::Auto)
            .build()
            .map(|_| ())
            .unwrap_err();
        assert!(matches!(err, EngineError::Build(_)), "{err}");
    }

    #[test]
    fn cheetah_engine_reports_all_sections_and_zero_perms() {
        use crate::nn::Layer;
        let mut net = Network {
            name: "engine-test".into(),
            input_shape: (1, 5, 5),
            layers: vec![Layer::conv(2, 3, 1, 1), Layer::relu(), Layer::fc(3)],
        };
        net.init_weights(21);
        let mut e = EngineBuilder::new(Backend::Cheetah)
            .network(net)
            .seed(7)
            .build()
            .unwrap();
        let prepared = e.prepare().unwrap();
        assert!(prepared.offline_bytes > 0, "indicators must ship offline");
        let input = Tensor::from_vec((0..25).map(|i| (i as f64 - 12.0) / 13.0).collect(), 1, 5, 5);
        let rep = e.infer(&input).unwrap();
        assert_eq!(rep.backend, Backend::Cheetah);
        assert_eq!(rep.ops.unwrap().perm, 0, "CHEETAH is permutation-free");
        assert!(rep.online_bytes() > 0);
        assert!(rep.traffic.unwrap().offline > 0);
        assert_eq!(rep.steps.len(), 2);
        assert_eq!(e.report().unwrap().argmax, rep.argmax);
    }
}
