//! The unified per-query report every backend maps into.
//!
//! All five backends answer with the same two fields (`argmax`, `logits`);
//! everything else is an *optional section* a backend fills in only when it
//! actually measures it:
//!
//! * [`Timing`] — online compute, modeled/real wire time, per-query offline
//!   work (blinding refresh, GC garbling),
//! * [`Traffic`] — exact serialized bytes per direction + round trips,
//! * ops — HE operation counts ([`OpCounts`]; the paper's `#Perm` headline),
//! * [`StepReport`] — per fused-step breakdown (Fig. 8).
//!
//! [`comparison_table`] renders N reports from different backends into one
//! fixed-width table — the "same input, N backends, one table" output the
//! engine API exists for.

use crate::bench_util::Table;
use crate::phe::OpCounts;
use crate::util::{fmt_bytes, fmt_duration};
use std::time::Duration;

use super::Backend;

/// Timing section (absent for backends that do not time themselves).
#[derive(Clone, Copy, Debug, Default)]
pub struct Timing {
    /// Query-dependent compute, both parties (the paper's "online time").
    pub online_compute: Duration,
    /// Wire time: modeled from exact bytes (in-process backends) or real
    /// socket time folded into `online_compute` (networked backend).
    pub wire: Duration,
    /// Query-attributed offline work observed during this inference
    /// (e.g. blinding-noise regeneration, GC garbling).
    pub offline: Duration,
}

impl Timing {
    /// Online compute plus wire time.
    pub fn online_total(&self) -> Duration {
        self.online_compute + self.wire
    }
}

/// Traffic section (absent for plaintext backends).
#[derive(Clone, Copy, Debug, Default)]
pub struct Traffic {
    /// Online client→server bytes (exact serialized sizes).
    pub c2s: u64,
    /// Online server→client bytes.
    pub s2c: u64,
    /// Offline bytes shipped ahead of queries (indicators, rotation keys,
    /// garbled tables).
    pub offline: u64,
    /// Communication round trips (0 = untracked by this backend).
    pub rounds: u64,
}

impl Traffic {
    /// Total online bytes, both directions.
    pub fn online_total(&self) -> u64 {
        self.c2s + self.s2c
    }
}

/// Per fused-step accounting (CHEETAH backends; GAZELLE reports coarser
/// whole-step durations).
#[derive(Clone, Debug, Default)]
pub struct StepReport {
    /// Step label (`step0:conv`, …).
    pub name: String,
    /// Server compute attributed to this step.
    pub server_time: Duration,
    /// Client compute attributed to this step.
    pub client_time: Duration,
    /// Client→server bytes for this step.
    pub c2s_bytes: u64,
    /// Server→client bytes for this step.
    pub s2c_bytes: u64,
}

/// The unified whole-query report.
#[derive(Clone, Debug)]
pub struct EngineReport {
    /// Which backend produced this report.
    pub backend: Backend,
    /// Predicted class (last maximum of the logits).
    pub argmax: usize,
    /// Dequantized logits.
    pub logits: Vec<f64>,
    /// Timing section, when the backend times itself.
    pub timing: Option<Timing>,
    /// Traffic section, when the backend meters bytes.
    pub traffic: Option<Traffic>,
    /// HE op counts (single-query mode only; `None` for batch reports).
    pub ops: Option<OpCounts>,
    /// RLWE parameter set the run used (`None` for plaintext backends) —
    /// keyed as `n{n}p{p_bits}` in benchmark artifacts.
    pub params: Option<crate::phe::Params>,
    /// Per fused-step breakdown (single-query protocol backends).
    pub steps: Vec<StepReport>,
}

impl EngineReport {
    /// A bare result with every optional section empty.
    pub fn bare(backend: Backend, argmax: usize, logits: Vec<f64>) -> Self {
        Self {
            backend,
            argmax,
            logits,
            timing: None,
            traffic: None,
            ops: None,
            params: None,
            steps: Vec::new(),
        }
    }

    /// Stable parameter key for benchmark artifacts (`n4096p23`); plaintext
    /// backends report `-`.
    pub fn params_key(&self) -> String {
        match &self.params {
            Some(p) => format!("n{}p{}", p.n, p.p_bits()),
            None => "-".to_string(),
        }
    }

    /// Total online time (compute + wire), when timed.
    pub fn online_total(&self) -> Duration {
        self.timing.map(|t| t.online_total()).unwrap_or_default()
    }

    /// Online compute alone (no wire), when timed — the quantity the
    /// parallel runtime accelerates, so the thread-sweep benches compare
    /// this across thread counts.
    pub fn online_compute(&self) -> Duration {
        self.timing.map(|t| t.online_compute).unwrap_or_default()
    }

    /// Total online bytes, when metered.
    pub fn online_bytes(&self) -> u64 {
        self.traffic.map(|t| t.online_total()).unwrap_or_default()
    }

    fn row(&self) -> Vec<String> {
        let dash = || "-".to_string();
        vec![
            self.backend.name().to_string(),
            self.argmax.to_string(),
            self.timing.map(|t| fmt_duration(t.online_compute)).unwrap_or_else(dash),
            self.timing.map(|t| fmt_duration(t.wire)).unwrap_or_else(dash),
            self.traffic.map(|t| fmt_bytes(t.online_total())).unwrap_or_else(dash),
            self.traffic.map(|t| fmt_bytes(t.offline)).unwrap_or_else(dash),
            self.ops.map(|o| o.perm.to_string()).unwrap_or_else(dash),
            self.ops.map(|o| o.mult.to_string()).unwrap_or_else(dash),
        ]
    }
}

/// Render one table comparing the same query across backends — the
/// five-line "N backends, one comparison" program's output.
pub fn comparison_table(title: &str, reports: &[EngineReport]) -> String {
    let mut t = Table::new(&[
        "backend",
        "argmax",
        "online compute",
        "wire",
        "online comm",
        "offline comm",
        "#Perm",
        "#Mult",
    ]);
    for r in reports {
        t.row(&r.row());
    }
    t.render(title)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comparison_table_renders_missing_sections_as_dashes() {
        let a = EngineReport::bare(Backend::PlaintextFloat, 3, vec![0.0; 10]);
        let mut b = EngineReport::bare(Backend::Cheetah, 3, vec![0.0; 10]);
        b.timing = Some(Timing {
            online_compute: Duration::from_millis(5),
            wire: Duration::from_millis(1),
            offline: Duration::ZERO,
        });
        b.traffic = Some(Traffic { c2s: 1024, s2c: 2048, offline: 512, rounds: 3 });
        b.ops = Some(OpCounts { add: 4, mult: 2, perm: 0 });
        let s = comparison_table("t", &[a, b]);
        assert!(s.contains("plaintext-float"));
        assert!(s.contains("cheetah"));
        assert!(s.contains('-'), "missing sections render as dashes");
        assert!(s.contains("3.00 KiB"), "traffic rendered: {s}");
    }
}
