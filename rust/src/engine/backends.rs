//! The five concrete engines behind [`super::EngineBuilder`].
//!
//! Each adapts one pre-existing deployment type onto the
//! [`InferenceEngine`] trait and maps its native report into the unified
//! [`EngineReport`]:
//!
//! | engine | wraps | sections filled |
//! |---|---|---|
//! | [`PlaintextFloatEngine`] | `Network::forward` | timing |
//! | [`PlaintextQuantizedEngine`] | `Network::forward_quantized` | timing |
//! | [`CheetahEngine`] | `CheetahRunner` (in-process) | timing, traffic, ops, steps |
//! | [`GazelleEngine`] | `GazelleRunner` (in-process, hybrid or GALA mode) | timing, traffic, ops, steps |
//! | [`CheetahNetEngine`] | `CheetahNetClient` over TCP | timing, traffic |
//!
//! `prepare()` is the offline phase everywhere: CHEETAH blinding + indicator
//! encryption, GAZELLE rotation-key generation, or the networked handshake +
//! indicator transfer. `infer()` auto-prepares on first use.

use super::report::{EngineReport, StepReport, Timing, Traffic};
use super::{Backend, EngineError, EngineResult, InferenceEngine, Prepared};
use crate::fixed::ScalePlan;
use crate::nn::{Network, Tensor};
use crate::par;
use crate::phe::Context;
use crate::protocol::cheetah::CheetahRunner;
use crate::protocol::gazelle::{GazelleMode, GazelleRunner};
use crate::protocol::transport::LinkModel;
use crate::serve::{CheetahNetClient, NetClientOpts, NetReport, SecureConfig, SecureServer};
use std::net::SocketAddr;
use std::sync::Arc;
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------------
// Plaintext backends
// ---------------------------------------------------------------------------

/// Float reference inference (the trusted-cloud baseline scorer).
pub struct PlaintextFloatEngine {
    net: Network,
    last: Option<EngineReport>,
}

impl PlaintextFloatEngine {
    /// Build from a network (weights already initialized or loaded).
    pub fn new(net: Network) -> Self {
        Self { net, last: None }
    }
}

impl InferenceEngine for PlaintextFloatEngine {
    fn backend(&self) -> Backend {
        Backend::PlaintextFloat
    }

    fn prepare(&mut self) -> EngineResult<Prepared> {
        Ok(Prepared::default())
    }

    fn infer(&mut self, input: &Tensor) -> EngineResult<EngineReport> {
        let t0 = Instant::now();
        let out = self.net.forward(input);
        let mut rep = EngineReport::bare(self.backend(), out.argmax(), out.data);
        rep.timing = Some(Timing { online_compute: t0.elapsed(), ..Default::default() });
        self.last = Some(rep.clone());
        Ok(rep)
    }

    /// Queries are independent forward passes — one fork-join region over
    /// the batch.
    fn infer_batch(&mut self, inputs: &[Tensor]) -> EngineResult<Vec<EngineReport>> {
        if inputs.is_empty() {
            return Ok(Vec::new());
        }
        let net = &self.net;
        let reps = par::map_indexed(inputs.len(), |i| {
            let t0 = Instant::now();
            let out = net.forward(&inputs[i]);
            let mut rep = EngineReport::bare(Backend::PlaintextFloat, out.argmax(), out.data);
            rep.timing = Some(Timing { online_compute: t0.elapsed(), ..Default::default() });
            rep
        });
        self.last = reps.last().cloned();
        Ok(reps)
    }

    fn report(&self) -> Option<&EngineReport> {
        self.last.as_ref()
    }
}

/// Fixed-point reference with the paper's per-output noise `δ ~ U[-ε, ε]` —
/// the plaintext mirror of the private protocol (same quantization plan).
pub struct PlaintextQuantizedEngine {
    net: Network,
    plan: ScalePlan,
    epsilon: f64,
    /// Per-query noise seed; incremented each inference so repeated noisy
    /// queries draw fresh δ (ε = 0 ignores it entirely).
    noise_seed: u64,
    last: Option<EngineReport>,
}

impl PlaintextQuantizedEngine {
    /// Build from a network, scale plan, noise bound ε, and base noise seed.
    pub fn new(net: Network, plan: ScalePlan, epsilon: f64, noise_seed: u64) -> Self {
        Self { net, plan, epsilon, noise_seed, last: None }
    }

    fn report_for(&self, q: Vec<i64>, elapsed: Duration) -> EngineReport {
        // Same tie-breaking as the protocol clients: last maximum wins.
        let argmax = q.iter().enumerate().max_by_key(|(_, &v)| v).map(|(i, _)| i).unwrap_or(0);
        let logits = q.iter().map(|&v| self.plan.x.dequantize(v)).collect();
        let mut rep = EngineReport::bare(Backend::PlaintextQuantized, argmax, logits);
        rep.timing = Some(Timing { online_compute: elapsed, ..Default::default() });
        rep
    }
}

impl InferenceEngine for PlaintextQuantizedEngine {
    fn backend(&self) -> Backend {
        Backend::PlaintextQuantized
    }

    fn prepare(&mut self) -> EngineResult<Prepared> {
        Ok(Prepared::default())
    }

    fn infer(&mut self, input: &Tensor) -> EngineResult<EngineReport> {
        let t0 = Instant::now();
        let q = self.net.forward_quantized(input, &self.plan, self.epsilon, self.noise_seed);
        self.noise_seed = self.noise_seed.wrapping_add(1);
        let rep = self.report_for(q, t0.elapsed());
        self.last = Some(rep.clone());
        Ok(rep)
    }

    /// Per-query noise seeds `base, base+1, …` — exactly the looped
    /// derivation — so the batched δ draws match the sequential path bit
    /// for bit while queries fan out in parallel.
    fn infer_batch(&mut self, inputs: &[Tensor]) -> EngineResult<Vec<EngineReport>> {
        if inputs.is_empty() {
            return Ok(Vec::new());
        }
        let base = self.noise_seed;
        self.noise_seed = base.wrapping_add(inputs.len() as u64);
        let this = &*self;
        let reps = par::map_indexed(inputs.len(), |i| {
            let t0 = Instant::now();
            let q = this.net.forward_quantized(
                &inputs[i],
                &this.plan,
                this.epsilon,
                base.wrapping_add(i as u64),
            );
            this.report_for(q, t0.elapsed())
        });
        self.last = reps.last().cloned();
        Ok(reps)
    }

    fn report(&self) -> Option<&EngineReport> {
        self.last.as_ref()
    }
}

// ---------------------------------------------------------------------------
// CHEETAH (in-process)
// ---------------------------------------------------------------------------

/// In-process CHEETAH deployment (both parties + metered link).
pub struct CheetahEngine {
    ctx: Arc<Context>,
    net: Network,
    plan: ScalePlan,
    epsilon: f64,
    seed: u64,
    link: LinkModel,
    runner: Option<CheetahRunner>,
    offline_bytes: u64,
    last: Option<EngineReport>,
}

impl CheetahEngine {
    /// Build from a shared context, network, scale plan, ε, seed, and link
    /// cost model.
    pub fn new(
        ctx: Arc<Context>,
        net: Network,
        plan: ScalePlan,
        epsilon: f64,
        seed: u64,
        link: LinkModel,
    ) -> Self {
        Self { ctx, net, plan, epsilon, seed, link, runner: None, offline_bytes: 0, last: None }
    }
}

impl InferenceEngine for CheetahEngine {
    fn backend(&self) -> Backend {
        Backend::Cheetah
    }

    /// The offline phase: key generation, blinding material, indicator
    /// encryption, and the (modeled) indicator shipment. Calling it again
    /// rebuilds the deployment from the same seed (deterministic).
    fn prepare(&mut self) -> EngineResult<Prepared> {
        let t0 = Instant::now();
        let mut runner = CheetahRunner::with_link(
            self.ctx.clone(),
            self.net.clone(),
            self.plan,
            self.epsilon,
            self.seed,
            self.link,
        )?;
        self.offline_bytes = runner.run_offline();
        self.runner = Some(runner);
        Ok(Prepared { offline_time: t0.elapsed(), offline_bytes: self.offline_bytes })
    }

    fn infer(&mut self, input: &Tensor) -> EngineResult<EngineReport> {
        if self.runner.is_none() {
            self.prepare()?;
        }
        let offline_bytes = self.offline_bytes;
        let runner = self.runner.as_mut().expect("prepared above");
        let r = runner.infer(input);
        let steps: Vec<StepReport> = r
            .steps
            .iter()
            .map(|s| StepReport {
                name: s.name.clone(),
                server_time: s.server_online,
                client_time: s.client_time,
                c2s_bytes: s.c2s_bytes,
                s2c_bytes: s.s2c_bytes,
            })
            .collect();
        let mut rep = EngineReport::bare(Backend::Cheetah, r.argmax, r.logits.clone());
        rep.params = Some(self.ctx.params);
        rep.timing = Some(Timing {
            online_compute: r.online_compute(),
            wire: r.wire_time,
            offline: r.steps.iter().map(|s| s.server_offline).sum(),
        });
        rep.traffic = Some(Traffic {
            c2s: r.steps.iter().map(|s| s.c2s_bytes).sum(),
            s2c: r.steps.iter().map(|s| s.s2c_bytes).sum(),
            offline: offline_bytes,
            rounds: (2 * r.steps.len() as u64).saturating_sub(1),
        });
        rep.ops = Some(r.total_ops());
        rep.steps = steps;
        self.last = Some(rep.clone());
        Ok(rep)
    }

    /// Batch driver: independent queries fanned across the [`crate::par`]
    /// pool against the one prepared deployment
    /// ([`CheetahRunner::infer_batch`]). Logits are bit-identical to
    /// looping `infer`; reports carry per-query wall time, exact traffic,
    /// and modeled wire time (no per-step/ops attribution in batch mode).
    fn infer_batch(&mut self, inputs: &[Tensor]) -> EngineResult<Vec<EngineReport>> {
        if inputs.is_empty() {
            return Ok(Vec::new());
        }
        if self.runner.is_none() {
            self.prepare()?;
        }
        let offline_bytes = self.offline_bytes;
        let params = self.ctx.params;
        let runner = self.runner.as_mut().expect("prepared above");
        let n_steps = runner.spec().steps.len() as u64;
        let out: Vec<EngineReport> = runner
            .infer_batch(inputs)
            .into_iter()
            .map(|r| {
                let mut rep = EngineReport::bare(Backend::Cheetah, r.argmax, r.logits.clone());
                rep.params = Some(params);
                rep.timing = Some(Timing {
                    online_compute: r.online_compute(),
                    wire: r.wire_time,
                    offline: Duration::ZERO,
                });
                rep.traffic = Some(Traffic {
                    c2s: r.steps.iter().map(|s| s.c2s_bytes).sum(),
                    s2c: r.steps.iter().map(|s| s.s2c_bytes).sum(),
                    offline: offline_bytes,
                    rounds: (2 * n_steps).saturating_sub(1),
                });
                rep
            })
            .collect();
        self.last = out.last().cloned();
        Ok(out)
    }

    fn report(&self) -> Option<&EngineReport> {
        self.last.as_ref()
    }
}

// ---------------------------------------------------------------------------
// GAZELLE (in-process baseline)
// ---------------------------------------------------------------------------

/// In-process GAZELLE baseline deployment — classic hybrid mode
/// ([`Backend::Gazelle`]) or GALA greedy-packing mode ([`Backend::Gala`]),
/// selected by the [`GazelleMode`] it is built with.
pub struct GazelleEngine {
    ctx: Arc<Context>,
    net: Network,
    plan: ScalePlan,
    seed: u64,
    mode: GazelleMode,
    runner: Option<GazelleRunner>,
    offline_bytes: u64,
    last: Option<EngineReport>,
}

impl GazelleEngine {
    /// Build from a shared context, network, scale plan, seed, and linear
    /// -algebra mode.
    pub fn new(
        ctx: Arc<Context>,
        net: Network,
        plan: ScalePlan,
        seed: u64,
        mode: GazelleMode,
    ) -> Self {
        Self { ctx, net, plan, seed, mode, runner: None, offline_bytes: 0, last: None }
    }

    fn backend_key(&self) -> Backend {
        match self.mode {
            GazelleMode::Hybrid => Backend::Gazelle,
            GazelleMode::Gala => Backend::Gala,
        }
    }
}

impl InferenceEngine for GazelleEngine {
    fn backend(&self) -> Backend {
        self.backend_key()
    }

    /// The offline phase: client key generation + rotation (Galois) keys
    /// for every step geometry; offline bytes additionally count the
    /// per-ReLU garbled tables.
    fn prepare(&mut self) -> EngineResult<Prepared> {
        let t0 = Instant::now();
        let runner = GazelleRunner::with_mode(
            self.ctx.clone(),
            self.net.clone(),
            self.plan,
            self.seed,
            self.mode,
        )?;
        self.offline_bytes = runner.offline_bytes();
        self.runner = Some(runner);
        Ok(Prepared { offline_time: t0.elapsed(), offline_bytes: self.offline_bytes })
    }

    fn infer(&mut self, input: &Tensor) -> EngineResult<EngineReport> {
        if self.runner.is_none() {
            self.prepare()?;
        }
        let offline_bytes = self.offline_bytes;
        let runner = self.runner.as_mut().expect("prepared above");
        let r = runner.infer(input);
        let backend = self.backend_key();
        let mut rep = EngineReport::bare(backend, r.argmax, r.logits.clone());
        rep.params = Some(self.ctx.params);
        rep.timing = Some(Timing {
            online_compute: r.online_compute(),
            wire: Duration::ZERO,
            offline: r.gc.garble_time,
        });
        rep.traffic = Some(Traffic {
            c2s: r.c2s_bytes,
            s2c: r.s2c_bytes,
            offline: offline_bytes,
            rounds: 0,
        });
        rep.ops = Some(r.ops);
        rep.steps = r
            .per_step
            .iter()
            .enumerate()
            .map(|(i, &d)| StepReport {
                name: format!("step{i}"),
                server_time: d,
                ..Default::default()
            })
            .collect();
        self.last = Some(rep.clone());
        Ok(rep)
    }

    /// Batch driver: independent queries fanned across the [`crate::par`]
    /// pool ([`GazelleRunner::infer_batch`]); logits bit-identical to the
    /// loop. HE op counts are a single-query-mode feature (`ops: None`).
    fn infer_batch(&mut self, inputs: &[Tensor]) -> EngineResult<Vec<EngineReport>> {
        if inputs.is_empty() {
            return Ok(Vec::new());
        }
        if self.runner.is_none() {
            self.prepare()?;
        }
        let offline_bytes = self.offline_bytes;
        let params = self.ctx.params;
        let backend = self.backend_key();
        let runner = self.runner.as_mut().expect("prepared above");
        let out: Vec<EngineReport> = runner
            .infer_batch(inputs)
            .into_iter()
            .map(|r| {
                let mut rep = EngineReport::bare(backend, r.argmax, r.logits.clone());
                rep.params = Some(params);
                rep.timing = Some(Timing {
                    online_compute: r.online_compute(),
                    wire: Duration::ZERO,
                    offline: r.gc.garble_time,
                });
                rep.traffic = Some(Traffic {
                    c2s: r.c2s_bytes,
                    s2c: r.s2c_bytes,
                    offline: offline_bytes,
                    rounds: 0,
                });
                rep
            })
            .collect();
        self.last = out.last().cloned();
        Ok(out)
    }

    fn report(&self) -> Option<&EngineReport> {
        self.last.as_ref()
    }
}

// ---------------------------------------------------------------------------
// CHEETAH over TCP (the serve subsystem)
// ---------------------------------------------------------------------------

/// Domain separator for the networked client's seed (ASCII "CLIENTSD"):
/// keeps the client RNG stream disjoint from the server-side session
/// engine seeds `seed, seed+1, …` handed out by the blinding pool.
const CLIENT_SEED_DOMAIN: u64 = 0x434c_4945_4e54_5344;

/// Where the networked engine finds its server.
pub enum NetTarget {
    /// Connect to an already-running [`SecureServer`] (or remote process).
    Remote(SocketAddr),
    /// Self-host a [`SecureServer`] on loopback and connect to it — gives a
    /// single builder call the full socket round trip.
    SelfHosted {
        /// The network the loopback server hosts.
        net: Network,
        /// The loopback server's configuration.
        cfg: SecureConfig,
    },
}

/// Client seed for pooled session `k`. Session 0 keeps the legacy
/// domain-separated derivation (bit-compatible with single-session runs);
/// later sessions run the SplitMix64 finalizer over a golden-ratio offset
/// of it — well mixed, so no pooled session's RNG stream collides with
/// another's, or with the server-side engine seeds `seed, seed+1, …` the
/// way any small additive offset could.
pub(crate) fn client_session_seed(seed: u64, k: usize) -> u64 {
    let base = seed ^ CLIENT_SEED_DOMAIN;
    if k == 0 {
        return base;
    }
    let mut z = base.wrapping_add((k as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15));
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// CHEETAH over real sockets: a pool of [`CheetahNetClient`] sessions
/// (size [`super::EngineBuilder::net_sessions`], default 1), optionally
/// backed by a self-hosted loopback [`SecureServer`]. Single queries ride
/// the first session; batches fan out across the pool.
pub struct CheetahNetEngine {
    ctx: Arc<Context>,
    plan: ScalePlan,
    seed: u64,
    sessions: usize,
    target: NetTarget,
    server: Option<SecureServer>,
    clients: Vec<CheetahNetClient>,
    opts: NetClientOpts,
    offline_bytes: u64,
    last: Option<EngineReport>,
}

impl CheetahNetEngine {
    /// Build from a shared context, scale plan, seed, server target, and
    /// pooled-session count (`sessions` is clamped to at least 1).
    pub fn new(
        ctx: Arc<Context>,
        plan: ScalePlan,
        seed: u64,
        target: NetTarget,
        sessions: usize,
    ) -> Self {
        Self {
            ctx,
            plan,
            seed,
            sessions: sessions.max(1),
            target,
            server: None,
            clients: Vec::new(),
            opts: NetClientOpts::default(),
            offline_bytes: 0,
            last: None,
        }
    }

    /// Override the client robustness options (per-round deadline, retry
    /// budget, fault injection) every pooled session connects with.
    pub fn net_opts(mut self, opts: NetClientOpts) -> Self {
        self.opts = opts;
        self
    }

    /// The bound address of the self-hosted server (after `prepare`).
    pub fn server_addr(&self) -> Option<SocketAddr> {
        self.server.as_ref().map(|s| s.addr)
    }

    fn report_for(r: &NetReport, offline_bytes: u64, params: crate::phe::Params) -> EngineReport {
        let mut rep = EngineReport::bare(Backend::CheetahNet, r.argmax, r.logits.clone());
        rep.params = Some(params);
        // Wall time over a real socket already includes wire time.
        rep.timing =
            Some(Timing { online_compute: r.wall, wire: Duration::ZERO, offline: Duration::ZERO });
        rep.traffic = Some(Traffic {
            c2s: r.c2s_bytes,
            s2c: r.s2c_bytes,
            offline: offline_bytes,
            rounds: r.rounds,
        });
        rep
    }
}

impl InferenceEngine for CheetahNetEngine {
    fn backend(&self) -> Backend {
        Backend::CheetahNet
    }

    /// The offline phase over the wire: TCP connect, handshake (parameter
    /// fingerprint, architecture download) and indicator-ciphertext
    /// transfer — once per pooled session, sequentially (so a self-hosted
    /// server's engine-seed assignment order is deterministic).
    /// Re-preparing opens fresh sessions; offline bytes sum over the pool.
    fn prepare(&mut self) -> EngineResult<Prepared> {
        let t0 = Instant::now();
        let addr = match &self.target {
            NetTarget::Remote(a) => *a,
            NetTarget::SelfHosted { net, cfg } => {
                if self.server.is_none() {
                    self.server = Some(SecureServer::serve(
                        self.ctx.clone(),
                        net.clone(),
                        self.plan,
                        "127.0.0.1:0",
                        *cfg,
                    )?);
                }
                self.server.as_ref().expect("just hosted").addr
            }
        };
        for mut old in self.clients.drain(..) {
            old.close().ok();
        }
        // Client keys/shares from a domain-separated derivation of the
        // seed — NOT `seed + k`: a self-hosted server hands its sessions
        // engine seeds `seed, seed+1, …`, so a small additive offset would
        // collide a later session's server RNG stream with the client's
        // (identical secret keys ⇒ the client could unblind the weights).
        // Pooled sessions mix further; see [`client_session_seed`].
        self.offline_bytes = 0;
        for k in 0..self.sessions {
            let client_seed = client_session_seed(self.seed, k);
            let client = CheetahNetClient::connect_with(
                self.ctx.clone(),
                self.plan,
                &addr,
                client_seed,
                self.opts,
            )?;
            self.offline_bytes += client.offline_bytes();
            self.clients.push(client);
        }
        Ok(Prepared { offline_time: t0.elapsed(), offline_bytes: self.offline_bytes })
    }

    fn infer(&mut self, input: &Tensor) -> EngineResult<EngineReport> {
        if self.clients.is_empty() {
            self.prepare()?;
        }
        let offline_bytes = self.offline_bytes;
        let params = self.ctx.params;
        let client = self.clients.first_mut().expect("prepared above");
        let r = client.infer(input)?;
        let rep = Self::report_for(&r, offline_bytes, params);
        self.last = Some(rep.clone());
        Ok(rep)
    }

    /// One TCP session is one ordered protocol stream — the server's
    /// per-session state machine serializes rounds — so within a session a
    /// batch pipelines sequentially. With `net_sessions > 1` the batch is
    /// split into contiguous chunks fanned across the pooled sessions on
    /// scoped threads: whole-query parallelism over real sockets, the TCP
    /// analogue of the in-process engines' batch fan-out. Per-query logits
    /// depend only on each session's own seeds, so results are independent
    /// of the pool size; report order matches input order.
    fn infer_batch(&mut self, inputs: &[Tensor]) -> EngineResult<Vec<EngineReport>> {
        if inputs.is_empty() {
            return Ok(Vec::new());
        }
        if self.clients.is_empty() {
            self.prepare()?;
        }
        if self.clients.len() == 1 || inputs.len() == 1 {
            return inputs.iter().map(|x| self.infer(x)).collect();
        }
        let offline_bytes = self.offline_bytes;
        let params = self.ctx.params;
        let k = self.clients.len().min(inputs.len());
        let per = inputs.len() / k;
        let rem = inputs.len() % k;
        let mut chunks: Vec<&[Tensor]> = Vec::with_capacity(k);
        let mut start = 0;
        for i in 0..k {
            let len = per + usize::from(i < rem);
            chunks.push(&inputs[start..start + len]);
            start += len;
        }
        let results: Vec<std::io::Result<Vec<EngineReport>>> = std::thread::scope(|s| {
            let handles: Vec<_> = self
                .clients
                .iter_mut()
                .zip(chunks)
                .map(|(client, chunk)| {
                    s.spawn(move || {
                        chunk
                            .iter()
                            .map(|x| {
                                client
                                    .infer(x)
                                    .map_err(std::io::Error::from)
                                    .map(|r| Self::report_for(&r, offline_bytes, params))
                            })
                            .collect()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("net batch thread panicked")).collect()
        });
        let mut out = Vec::with_capacity(inputs.len());
        for chunk in results {
            out.extend(chunk?);
        }
        self.last = out.last().cloned();
        Ok(out)
    }

    fn report(&self) -> Option<&EngineReport> {
        self.last.as_ref()
    }
}

impl Drop for CheetahNetEngine {
    fn drop(&mut self) {
        for mut c in self.clients.drain(..) {
            c.close().ok();
        }
        // A self-hosted server shuts itself down on drop.
    }
}

// EngineError <- io::Error used by the networked backend.
impl From<std::io::Error> for EngineError {
    fn from(e: std::io::Error) -> Self {
        EngineError::Io(e)
    }
}

// EngineError <- typed network-client error: the engine API keeps one I/O
// error channel, so the typed error rides in as its io::Error rendering
// (retries already happened inside the client).
impl From<crate::serve::NetError> for EngineError {
    fn from(e: crate::serve::NetError) -> Self {
        EngineError::Io(std::io::Error::from(e))
    }
}
