//! Benchmark harness (the offline registry has no `criterion`; see
//! DESIGN.md). Provides warmed-up median-of-N timing with MAD spread, and
//! fixed-width table printing used by every `benches/*` target so the output
//! mirrors the paper's tables.

use std::time::{Duration, Instant};

/// Result of a timed measurement.
#[derive(Clone, Copy, Debug)]
pub struct Measurement {
    /// Median over samples.
    pub median: Duration,
    /// Median absolute deviation (robust spread).
    pub mad: Duration,
    /// Number of samples taken.
    pub samples: usize,
}

impl Measurement {
    /// Median as fractional milliseconds.
    pub fn millis(&self) -> f64 {
        self.median.as_secs_f64() * 1e3
    }
    /// Median as fractional microseconds.
    pub fn micros(&self) -> f64 {
        self.median.as_secs_f64() * 1e6
    }
}

/// Time `f` with `warmup` unrecorded runs then `samples` recorded runs;
/// returns the median and MAD. `f` should include only the work under test.
pub fn time_fn<F: FnMut()>(warmup: usize, samples: usize, mut f: F) -> Measurement {
    for _ in 0..warmup {
        f();
    }
    let mut times: Vec<Duration> = Vec::with_capacity(samples);
    for _ in 0..samples.max(1) {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed());
    }
    times.sort();
    let median = times[times.len() / 2];
    let mut devs: Vec<Duration> = times
        .iter()
        .map(|&t| if t > median { t - median } else { median - t })
        .collect();
    devs.sort();
    Measurement { median, mad: devs[devs.len() / 2], samples: times.len() }
}

/// Adaptive timing: keep sampling until at least `min_total` wall time or
/// `max_samples` samples, whichever first (for very fast or very slow ops).
pub fn time_adaptive<F: FnMut()>(min_total: Duration, max_samples: usize, mut f: F) -> Measurement {
    f(); // warmup
    let mut times = Vec::new();
    let start = Instant::now();
    while start.elapsed() < min_total && times.len() < max_samples {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed());
    }
    if times.is_empty() {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed());
    }
    times.sort();
    let median = times[times.len() / 2];
    let mut devs: Vec<Duration> = times
        .iter()
        .map(|&t| if t > median { t - median } else { median - t })
        .collect();
    devs.sort();
    Measurement { median, mad: devs[devs.len() / 2], samples: times.len() }
}

/// Fixed-width table printer for bench output.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// A table with the given column headers and no rows.
    pub fn new(headers: &[&str]) -> Self {
        Self { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Append one row; panics if the cell count differs from the headers.
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Render the table (with a title banner) to a string — used by
    /// [`crate::engine::EngineReport`] comparisons as well as `print`.
    pub fn render(&self, title: &str) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let total: usize = widths.iter().sum::<usize>() + 3 * widths.len() + 1;
        let fmt_row = |cells: &[String]| {
            let mut line = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!(" {:<w$} |", c, w = widths[i]));
            }
            line
        };
        let mut out = String::new();
        out.push_str(&format!("\n{title}\n"));
        out.push_str(&format!("{}\n", "=".repeat(total.min(120))));
        out.push_str(&format!("{}\n", fmt_row(&self.headers)));
        out.push_str(&format!("{}\n", "-".repeat(total.min(120))));
        for row in &self.rows {
            out.push_str(&format!("{}\n", fmt_row(row)));
        }
        out
    }

    /// Render to stdout.
    pub fn print(&self, title: &str) {
        print!("{}", self.render(title));
    }

    /// Write the table as machine-readable JSON: `{"title", "headers",
    /// "rows": [{header: cell, …}, …]}` — every cell a string, exactly as
    /// rendered. Hand-rolled serialization (the offline registry has no
    /// `serde`); benches use this to persist `BENCH_*.json` so the perf
    /// trajectory is recorded across PRs and CI uploads it as an artifact.
    pub fn write_json(&self, path: impl AsRef<std::path::Path>, title: &str) -> std::io::Result<()> {
        self.write_json_with_sections(path, title, &[])
    }

    /// Like [`Table::write_json`], with extra top-level sections appended
    /// after `"rows"`. Each `(key, raw_json)` pair is emitted as
    /// `"key": raw_json` **verbatim** — the value must already be valid
    /// JSON (e.g. an [`crate::obs::Snapshot::to_json`] document, which is
    /// how `e2e_bench --obs` embeds its `"obs"` section). Consumers that
    /// only read `"headers"`/`"rows"` (`scripts/bench_trend.py`) ignore
    /// the extra keys.
    pub fn write_json_with_sections(
        &self,
        path: impl AsRef<std::path::Path>,
        title: &str,
        sections: &[(&str, &str)],
    ) -> std::io::Result<()> {
        fn esc(s: &str) -> String {
            let mut out = String::with_capacity(s.len());
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\r' => out.push_str("\\r"),
                    '\t' => out.push_str("\\t"),
                    c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                    c => out.push(c),
                }
            }
            out
        }
        let mut s = String::from("{\n");
        s.push_str(&format!("  \"title\": \"{}\",\n", esc(title)));
        s.push_str("  \"headers\": [");
        for (i, h) in self.headers.iter().enumerate() {
            if i > 0 {
                s.push_str(", ");
            }
            s.push_str(&format!("\"{}\"", esc(h)));
        }
        s.push_str("],\n  \"rows\": [\n");
        for (ri, row) in self.rows.iter().enumerate() {
            s.push_str("    {");
            for (i, cell) in row.iter().enumerate() {
                if i > 0 {
                    s.push_str(", ");
                }
                s.push_str(&format!("\"{}\": \"{}\"", esc(&self.headers[i]), esc(cell)));
            }
            s.push('}');
            if ri + 1 < self.rows.len() {
                s.push(',');
            }
            s.push('\n');
        }
        s.push_str("  ]");
        for (key, raw) in sections {
            s.push_str(&format!(",\n  \"{}\": {}", esc(key), raw));
        }
        s.push_str("\n}\n");
        std::fs::write(path, s)
    }
}

/// Parse simple `--flag value` / `--flag` CLI args for bench binaries.
pub struct BenchArgs {
    args: Vec<String>,
}

impl BenchArgs {
    /// Capture the process arguments (everything after the binary name).
    pub fn from_env() -> Self {
        Self { args: std::env::args().skip(1).collect() }
    }
    /// Whether the bare `flag` is present.
    pub fn has(&self, flag: &str) -> bool {
        self.args.iter().any(|a| a == flag)
    }
    /// The value following `flag`, if any.
    pub fn get(&self, flag: &str) -> Option<&str> {
        self.args.iter().position(|a| a == flag).and_then(|i| self.args.get(i + 1)).map(|s| s.as_str())
    }
    /// The value following `flag` parsed as `usize`, or `default`.
    pub fn get_usize(&self, flag: &str, default: usize) -> usize {
        self.get(flag).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
    /// The value following `flag` parsed as `f64`, or `default`.
    pub fn get_f64(&self, flag: &str, default: f64) -> f64 {
        self.get(flag).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_returns_positive() {
        let m = time_fn(1, 5, || {
            std::hint::black_box((0..1000u64).sum::<u64>());
        });
        assert!(m.median.as_nanos() > 0);
        assert_eq!(m.samples, 5);
    }

    #[test]
    fn table_roundtrip() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        t.print("test table"); // smoke: must not panic
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn table_rejects_bad_row() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into()]);
    }

    #[test]
    fn table_writes_machine_readable_json() {
        let mut t = Table::new(&["network", "online ms"]);
        t.row(&["netB \"quoted\"".into(), "12.5".into()]);
        t.row(&["netA".into(), "3.1".into()]);
        let path = std::env::temp_dir().join(format!(
            "cheetah_bench_json_test_{}.json",
            std::process::id()
        ));
        t.write_json(&path, "e2e\nbench").unwrap();
        let got = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).ok();
        // Escaping and structure (no serde available to parse; check the
        // load-bearing fragments).
        assert!(got.contains("\"title\": \"e2e\\nbench\""), "{got}");
        assert!(got.contains("\"headers\": [\"network\", \"online ms\"]"), "{got}");
        assert!(got.contains("\"network\": \"netB \\\"quoted\\\"\""), "{got}");
        assert!(got.contains("\"online ms\": \"3.1\""), "{got}");
        assert_eq!(got.matches('{').count(), 3, "one object per row plus the root: {got}");
    }

    #[test]
    fn table_json_embeds_extra_sections_verbatim() {
        let mut t = Table::new(&["a"]);
        t.row(&["1".into()]);
        let path = std::env::temp_dir().join(format!(
            "cheetah_bench_json_sections_test_{}.json",
            std::process::id()
        ));
        let obs = "{\"version\":1,\"metrics\":[],\"timeline\":[]}";
        t.write_json_with_sections(&path, "t", &[("obs", obs)]).unwrap();
        let got = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert!(got.contains(&format!("\"obs\": {obs}")), "{got}");
        assert!(got.contains("\"rows\": [\n"), "rows section must survive: {got}");
        assert!(got.trim_end().ends_with('}'), "document must stay closed: {got}");
    }
}
