//! Analytic complexity model — regenerates the paper's Table 1 (scheme
//! lineage) and Table 2 (op-count complexity per method), and provides
//! closed-form op counts the benchmarks cross-check against measured
//! evaluator counters.

use crate::bench_util::Table;

/// Concrete operation counts for one layer under one method.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Counts {
    pub perm: u64,
    pub mult: u64,
    pub add: u64,
}

/// Convolution shape (stride 1 analysis, as in the paper's Table 2).
#[derive(Clone, Copy, Debug)]
pub struct ConvShape {
    pub c_i: u64,
    pub c_o: u64,
    /// kernel side length
    pub r: u64,
    /// spatial size (h·w) — the paper folds this into `c_n`
    pub hw: u64,
    /// slots per ciphertext
    pub n: u64,
}

impl ConvShape {
    /// channels per ciphertext (paper's `c_n`), ≥ 1.
    pub fn c_n(&self) -> u64 {
        (self.n / self.hw).max(1)
    }

    /// GAZELLE input-rotation MIMO (Table 2 row IR-MIMO).
    pub fn gazelle_ir(&self) -> Counts {
        let r2 = self.r * self.r;
        Counts {
            perm: self.c_i * (r2 - 1),
            mult: self.c_i * self.c_o * r2,
            add: self.c_o * (self.c_i * r2 - 1),
        }
    }

    /// GAZELLE output-rotation MIMO (Table 2 row OR-MIMO).
    pub fn gazelle_or(&self) -> Counts {
        let r2 = self.r * self.r;
        Counts {
            perm: self.c_o * (r2 - 1),
            mult: self.c_i * self.c_o * r2,
            add: self.c_o * (self.c_i * r2 - 1),
        }
    }

    /// CHEETAH MIMO (Table 2 row CH-MIMO): zero permutations; one Mult and
    /// one Add per (output-channel × input-ciphertext) pair.
    pub fn cheetah(&self) -> Counts {
        let stream = self.hw * self.c_i * self.r * self.r;
        let in_cts = stream.div_ceil(self.n);
        Counts { perm: 0, mult: self.c_o * in_cts, add: self.c_o * in_cts }
    }
}

/// Fully-connected shape.
#[derive(Clone, Copy, Debug)]
pub struct FcShape {
    pub n_i: u64,
    pub n_o: u64,
    /// slots per ciphertext
    pub n: u64,
}

impl FcShape {
    fn log2(x: u64) -> u64 {
        64 - x.next_power_of_two().leading_zeros() as u64 - 1
    }

    /// Naive method (Table 2 row NA-FC): per output, Mult + log2(n_i)
    /// rotate-and-sum.
    pub fn naive(&self) -> Counts {
        let l = Self::log2(self.n_i);
        Counts { perm: self.n_o * l, mult: self.n_o, add: self.n_o * l }
    }

    /// Halevi–Shoup diagonals (Table 2 row HS-FC).
    pub fn halevi_shoup(&self) -> Counts {
        Counts { perm: self.n_i - 1, mult: self.n_i, add: self.n_i - 1 }
    }

    /// GAZELLE hybrid (Table 2 row GA-FC).
    pub fn gazelle_hybrid(&self) -> Counts {
        let row = self.n / 2;
        let n_i = self.n_i.next_power_of_two();
        let g_o = (row / n_i).max(1);
        let chunks = self.n_o.div_ceil(g_o);
        let l = Self::log2(n_i);
        Counts { perm: chunks * l, mult: chunks, add: chunks * l }
    }

    /// CHEETAH FC (Table 2 row CH-FC): zero permutations.
    pub fn cheetah(&self) -> Counts {
        let cts = (self.n_i * self.n_o).div_ceil(self.n);
        Counts { perm: 0, mult: cts, add: cts }
    }
}

/// Table 1: the scheme-comparison lineage (qualitative; speedups are the
/// paper's reported factors over CryptoNets).
pub fn print_table1() {
    let rows: [(&str, &str, &str, &str); 13] = [
        ("CryptoNets", "HE", "HE (square approx.)", "1x"),
        ("Faster CryptoNets", "HE", "HE (poly approx.)", "10x"),
        ("GELU-Net", "HE", "Plaintext (no approx.)", "14x"),
        ("E2DM", "Packed HE + matrix opt.", "HE (square approx.)", "30x"),
        ("SecureML", "HE + secret share", "GC (piecewise approx.)", "60x"),
        ("Chameleon", "Secret share", "GMW + GC (piecewise)", "150x"),
        ("MiniONN", "Packed HE + secret share", "GC (piecewise)", "230x"),
        ("DeepSecure", "GC", "GC (poly approx.)", "527x"),
        ("SecureNN", "Secret share (3-party)", "GMW (piecewise)", "1000x"),
        ("FALCON", "Packed HE + FFT", "GC (piecewise)", "1000x"),
        ("XONN", "GC (binary nets)", "GC (piecewise)", "1000x"),
        ("GAZELLE", "Packed HE + matrix opt.", "GC (piecewise)", "1000x"),
        ("CHEETAH", "Packed HE + obscure matrix", "Obscure HE + SS (exact)", "100000x"),
    ];
    let mut t = Table::new(&["Scheme", "Linear", "Non-linear", "Speedup vs CryptoNets"]);
    for (a, b, c, d) in rows {
        t.row(&[a.into(), b.into(), c.into(), d.into()]);
    }
    t.print("Table 1 — privacy-preserved NN framework lineage (paper's reported factors)");
}

/// Table 2: symbolic complexity comparison, instantiated at a concrete
/// shape so the numbers are checkable against the measured counters.
pub fn print_table2(conv: ConvShape, fc: FcShape) {
    let mut t = Table::new(&["Method", "#Perm", "#Mult", "#Add"]);
    let fmt = |c: Counts| [format!("{}", c.perm), format!("{}", c.mult), format!("{}", c.add)];
    let rows: Vec<(&str, Counts)> = vec![
        ("GA-SISO (r² perms)", ConvShape { c_i: 1, c_o: 1, ..conv }.gazelle_ir()),
        ("CH-SISO", ConvShape { c_i: 1, c_o: 1, ..conv }.cheetah()),
        ("IR-MIMO", conv.gazelle_ir()),
        ("OR-MIMO", conv.gazelle_or()),
        ("CH-MIMO", conv.cheetah()),
        ("NA-FC", fc.naive()),
        ("HS-FC", fc.halevi_shoup()),
        ("GA-FC", fc.gazelle_hybrid()),
        ("CH-FC", fc.cheetah()),
    ];
    for (name, c) in rows {
        let f = fmt(c);
        t.row(&[name.into(), f[0].clone(), f[1].clone(), f[2].clone()]);
    }
    t.print(&format!(
        "Table 2 — op counts at conv {}x{}@{}→@{} r={} (n={}), fc {}×{}",
        conv.hw.isqrt(),
        conv.hw.isqrt(),
        conv.c_i,
        conv.c_o,
        conv.r,
        conv.n,
        fc.n_o,
        fc.n_i
    ));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cheetah_never_permutes() {
        let conv = ConvShape { c_i: 16, c_o: 32, r: 5, hw: 28 * 28, n: 4096 };
        let fc = FcShape { n_i: 2048, n_o: 16, n: 4096 };
        assert_eq!(conv.cheetah().perm, 0);
        assert_eq!(fc.cheetah().perm, 0);
        assert!(conv.gazelle_ir().perm > 0);
        assert!(fc.gazelle_hybrid().perm > 0);
    }

    #[test]
    fn table4_perm_counts() {
        // Paper Table 4 (n as used there): 1×2048 → 11 Perms, 16×128 → 7.
        // With one half-row (row = n/2 = 2048) and n_i·n_o = 2048, chunks=1.
        let n = 4096;
        for (n_o, n_i, perms) in [(1u64, 2048u64, 11u64), (2, 1024, 10), (16, 128, 7)] {
            let c = FcShape { n_i, n_o, n }.gazelle_hybrid();
            assert_eq!(c.perm, perms, "{n_o}x{n_i}");
            assert_eq!(c.mult, 1);
        }
        // CHEETAH: always 1 Mult, 1 Add, 0 Perm for these shapes.
        let c = FcShape { n_i: 2048, n_o: 1, n }.cheetah();
        assert_eq!((c.perm, c.mult, c.add), (0, 1, 1));
    }

    #[test]
    fn ir_vs_or_tradeoff() {
        // IR wins when c_i < c_o and vice versa.
        let a = ConvShape { c_i: 2, c_o: 64, r: 3, hw: 256, n: 4096 };
        assert!(a.gazelle_ir().perm < a.gazelle_or().perm);
        let b = ConvShape { c_i: 128, c_o: 2, r: 3, hw: 256, n: 4096 };
        assert!(b.gazelle_or().perm < b.gazelle_ir().perm);
    }

    #[test]
    fn tables_print() {
        print_table1();
        print_table2(
            ConvShape { c_i: 1, c_o: 5, r: 5, hw: 28 * 28, n: 4096 },
            FcShape { n_i: 2048, n_o: 1, n: 4096 },
        );
    }
}
