//! # CHEETAH — ultra-fast privacy-preserved neural network inference
//!
//! A full-system reproduction of *CHEETAH: An Ultra-Fast, Approximation-Free,
//! and Privacy-Preserved Neural Network Framework based on Joint Obscure
//! Linear and Nonlinear Computations* (Zhang, Wang, Xin, Wu — 2019).
//!
//! The crate is a three-layer stack:
//!
//! * **L3 (this crate)** — the MLaaS coordinator and the complete
//!   cryptographic substrate: a from-scratch BFV-style packed homomorphic
//!   encryption library ([`phe`]), a Yao garbled-circuit engine ([`gc`], used
//!   by the GAZELLE baseline), the CHEETAH protocol
//!   ([`protocol::cheetah`]) and the GAZELLE baseline
//!   ([`protocol::gazelle`]), plus transport, benchmarking infrastructure,
//!   and two serving paths: the plaintext coordinator ([`coordinator`]) and
//!   the secure multi-session CHEETAH-over-TCP subsystem ([`serve`]).
//! * **L2 (python/compile, build-time)** — JAX forward graphs of the
//!   benchmark networks (with the paper's noise-injection experiment),
//!   AOT-lowered to HLO text artifacts.
//! * **L1 (python/compile/kernels, build-time)** — Pallas kernels for the
//!   client-side hot loops (`obscure_dot`, `relu_recover`), lowered into the
//!   L2 graphs and cross-checked against both a pure-jnp oracle and the Rust
//!   hot path.
//!
//! The [`runtime`] module loads the L2 artifacts through PJRT and executes
//! them from Rust; Python is never on the request path.
//!
//! The [`par`] module is the crate-wide parallel runtime: a dependency-free
//! fork-join pool that fans the protocol's per-channel ciphertext streams,
//! NTT batches, plaintext conv loops, **and whole independent queries**
//! (`InferenceEngine::infer_batch`) across cores, bit-exactly (the
//! `--threads`/`CHEETAH_THREADS` knob, default `available_parallelism()`;
//! per-engine scoping via `EngineBuilder::threads` /
//! [`par::with_threads`]).
//!
//! The [`obs`] module is the telemetry subsystem: lock-free counters and
//! log₂ latency histograms, structured spans through the `phe`, `protocol`,
//! `gc`, `par`, and `serve` layers, and a JSON snapshot served live by the
//! secure server's `STATS` frame and the `serve-secure --stats-addr`
//! endpoint (`CHEETAH_OBS` level knob; `obs-off` feature compiles it out).
//!
//! The [`engine`] module is the crate's front door: one build→infer surface
//! ([`engine::EngineBuilder`] / [`engine::InferenceEngine`]) over plaintext,
//! CHEETAH, GAZELLE, and networked backends, with a unified
//! [`engine::EngineReport`] for cross-backend comparisons.
//!
//! The [`plan`] module is the parameter planner: a static worst-case
//! noise/magnitude model over the compiled protocol and a ladder of RLWE
//! parameter rungs, so `EngineBuilder::params(ParamsChoice::Auto)` picks
//! the smallest parameter set that provably decrypts every step of a
//! network (or fails with a typed diagnostic before any garbage decrypt).
//!
//! See `README.md` for the quickstart and knob index, and `DESIGN.md` for
//! the system inventory and the experiment index (measured results
//! regenerate from the `benches/` targets into `BENCH_*.json`).

// Rustdoc coverage is enforced on the crate's driving surfaces (`par`,
// `engine`, `serve`, `phe`, `plan`, `nn`, `protocol::cheetah` and this
// root). Legacy
// modules below carry an explicit `#[allow(missing_docs)]` until their passes land
// — remove the allow when documenting one (CI's `cargo doc -D warnings`
// gate and clippy keep newly-warned modules clean thereafter).
#![warn(missing_docs)]

pub mod bench_util;
#[allow(missing_docs)]
pub mod complexity;
pub mod coordinator;
pub mod engine;
#[allow(missing_docs)]
pub mod fixed;
pub mod gc;
pub mod nn;
pub mod obs;
pub mod par;
pub mod phe;
pub mod plan;
pub mod protocol;
#[allow(missing_docs)]
pub mod runtime;
pub mod serve;
#[allow(missing_docs)]
pub mod util;
