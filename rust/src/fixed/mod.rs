//! Fixed-point encoding and the per-layer scale budget.
//!
//! Neural-network values are reals; the PHE plaintext space is `Z_p`
//! (signed, centered: `±(p−1)/2`). The paper (§2.3) quantizes to 8-bit
//! signed fixed point and relies on SEAL's encoder "without data overflow";
//! this module makes that budget explicit and machine-checked.
//!
//! ## The scale budget (default `p` ≈ 2^23, signed range ±2^22)
//!
//! | quantity                    | scale (frac bits) | max |val| | max int |
//! |-----------------------------|-------------------|-----------|---------|
//! | activation / input `x`      | 2^7               | 2.0       | 2^8     |
//! | weight `k`                  | 2^6               | 2.0       | 2^7     |
//! | blinding `v` (±{½,1,2})     | 2^4               | 2.0       | 2^5     |
//! | multiplier `k·v`            | 2^10              | 4.0       | 2^12    |
//! | element product `x·k·v`     | 2^17              | 8.0       | 2^20    |
//! | additive noise share `b`    | 2^17              | 8.0       | 2^20    |
//! | client re-encoded `y`       | 2^6               | 3.0       | 192     |
//! | indicator `1/v` (`ID2`)     | 2^1               | 2.0       | 4       |
//! | recovered activation        | 2^7               | 6.0       | 768     |
//!
//! Every product stays below ±2^22, so slot arithmetic never wraps except
//! where the protocol *wants* mod-p wrapping (uniform additive shares).
//! The block **sums** happen client-side in `i64` after decryption, so they
//! are unconstrained by `p`.
//!
//! **Exactness of the blinding:** the multiplicative blind is drawn as
//! `v₁ = ±2^j, j ∈ {-1,0,1}` so its inverse `v₂ = ±2^{-j}` is *exactly*
//! representable in fixed point and `v₁·v₂ = 1` holds with no rounding —
//! preserving the paper's approximation-free claim (a continuous
//! `v ∈ ±[0.5,2)` would need a rounded reciprocal and contaminate every
//! activation by ~1%). The hiding strength is the same as the paper's: the
//! scrambled magnitude `|y| = |v₁|·|Con+δ|` reveals `|Con+δ|` only up to a
//! 4× factor, and the sign is hidden by the random sign of `v₁`; the
//! additive noise δ provides the rest (paper §3.1, Fig. 7).

/// Fixed-point scale: values are represented as `round(x * 2^frac_bits)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Scale {
    pub frac_bits: u32,
}

impl Scale {
    pub const fn new(frac_bits: u32) -> Self {
        Self { frac_bits }
    }

    #[inline]
    pub fn factor(&self) -> f64 {
        (1u64 << self.frac_bits) as f64
    }

    /// Quantize a real to this scale.
    #[inline]
    pub fn quantize(&self, x: f64) -> i64 {
        (x * self.factor()).round() as i64
    }

    /// Dequantize an integer at this scale.
    #[inline]
    pub fn dequantize(&self, v: i64) -> f64 {
        v as f64 / self.factor()
    }

    /// The scale of a product of two quantities.
    pub fn mul(&self, other: Scale) -> Scale {
        Scale::new(self.frac_bits + other.frac_bits)
    }
}

/// The protocol-wide scale plan (see module docs). One instance is shared
/// by client and server; it is public model metadata, not a secret.
#[derive(Clone, Copy, Debug)]
pub struct ScalePlan {
    /// Activations and inputs.
    pub x: Scale,
    /// Weights.
    pub k: Scale,
    /// Multiplicative blinding factors `v`.
    pub v: Scale,
    /// Client's re-encoded post-sum value `y` (the `f_R(y)` multiplier).
    pub y: Scale,
    /// Indicator entries (`v2 = 1/v1`).
    pub id: Scale,
    /// Max absolute activation value (clamped by quantization).
    pub x_max: f64,
    /// Max absolute weight value.
    pub k_max: f64,
    /// Clamp bound for the scrambled value `y` (values above it saturate;
    /// the effective activation clamp is `y_max/|v|` ∈ [y_max/2, 2·y_max]).
    pub y_max: f64,
}

impl ScalePlan {
    /// The default plan matching the table in the module docs.
    pub fn default_plan() -> Self {
        Self {
            x: Scale::new(7),
            k: Scale::new(6),
            v: Scale::new(4),
            y: Scale::new(6),
            id: Scale::new(1),
            x_max: 2.0,
            k_max: 2.0,
            y_max: 3.0,
        }
    }

    /// Scale of the encrypted element-wise product `x·k·v` (and of `b`).
    pub fn product(&self) -> Scale {
        self.x.mul(self.k).mul(self.v)
    }

    /// Scale of the recovered activation `y · id = f(Con+δ)`.
    pub fn activation_out(&self) -> Scale {
        self.y.mul(self.id)
    }

    /// Verify the plan fits a plaintext modulus `p`: every intermediate must
    /// stay within the signed slot range. Returns the worst-case headroom in
    /// bits (panics if negative).
    pub fn check_fits(&self, p: u64) -> f64 {
        let half = ((p - 1) / 2) as f64;
        let prod_max = self.x_max * self.k_max * 2.0 * self.product().factor();
        // product + additive noise share b (same magnitude bound)
        let linear_max = 2.0 * prod_max;
        let y_int_max = self.y_max * self.y.factor();
        let recov_max = self.y_max * 2.0 * self.activation_out().factor();
        let worst = linear_max.max(y_int_max).max(recov_max);
        assert!(
            worst <= half,
            "scale plan overflows plaintext space: worst {worst} > {half}"
        );
        (half / worst).log2()
    }

    /// Quantize an activation (clamping to `x_max`).
    pub fn quant_x(&self, x: f64) -> i64 {
        self.x.quantize(x.clamp(-self.x_max, self.x_max))
    }

    /// Quantize a weight (clamping to `k_max`).
    pub fn quant_k(&self, k: f64) -> i64 {
        self.k.quantize(k.clamp(-self.k_max, self.k_max))
    }
}

/// Quantize a float slice to signed integers at scale `s` with clamping
/// (the paper's §2.3 quantization step).
pub fn quantize_vec(values: &[f64], s: Scale, max_abs: f64) -> Vec<i64> {
    values.iter().map(|&x| s.quantize(x.clamp(-max_abs, max_abs))).collect()
}

/// Dequantize back to floats.
pub fn dequantize_vec(values: &[i64], s: Scale) -> Vec<f64> {
    values.iter().map(|&v| s.dequantize(v)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_roundtrip() {
        let s = Scale::new(8);
        for x in [0.0, 1.5, -0.75, 1.99] {
            let q = s.quantize(x);
            assert!((s.dequantize(q) - x).abs() < 1.0 / 256.0);
        }
    }

    #[test]
    fn default_plan_fits_default_p() {
        let p = crate::phe::Params::default_params().p;
        let plan = ScalePlan::default_plan();
        let headroom = plan.check_fits(p);
        assert!(headroom >= 0.9, "want ~1 bit headroom, got {headroom}");
    }

    #[test]
    #[should_panic(expected = "overflows plaintext space")]
    fn plan_rejects_tiny_p() {
        let plan = ScalePlan::default_plan();
        plan.check_fits(1 << 16);
    }

    #[test]
    fn product_scales_compose() {
        let plan = ScalePlan::default_plan();
        assert_eq!(plan.product().frac_bits, 7 + 6 + 4);
        assert_eq!(plan.activation_out().frac_bits, 7);
        // Activation-out scale must equal the activation-in scale so layers
        // chain without rescaling ciphertexts.
        assert_eq!(plan.activation_out(), plan.x);
    }

    #[test]
    fn quantize_clamps() {
        let plan = ScalePlan::default_plan();
        assert_eq!(plan.quant_x(100.0), plan.quant_x(2.0));
        assert_eq!(plan.quant_k(-100.0), plan.quant_k(-2.0));
    }

    #[test]
    fn vec_helpers() {
        let s = Scale::new(6);
        let v = vec![0.5, -1.25, 3.0];
        let q = quantize_vec(&v, s, 2.0);
        assert_eq!(q, vec![32, -80, 128]); // 3.0 clamped to 2.0
        let d = dequantize_vec(&q, s);
        assert!((d[0] - 0.5).abs() < 1e-9);
    }
}
