//! Deterministic fault injection for the serving stack.
//!
//! Production serving has to survive an unreliable world — mid-frame
//! disconnects, partial writes, flipped bytes, stalls, kernels refusing
//! accepts — but reproducing those conditions by hand is hopeless. This
//! module makes them a *seeded, replayable input*: a [`FaultSpec`] describes
//! per-operation fault probabilities, a [`FaultPlan`] derives an independent
//! deterministic schedule per connection, and [`FaultyStream`] wraps any
//! `Read + Write` transport (both server fronts and
//! [`crate::serve::CheetahNetClient`] use it) injecting the scheduled faults
//! at the byte-stream boundary, where real networks misbehave.
//!
//! The whole subsystem is off by default: a [`FaultyStream`] built with
//! [`FaultyStream::passthrough`] carries `None` for its plan and every I/O
//! call is a direct delegation to the inner stream — no RNG draw, no branch
//! on probabilities — so the online-path benchmarks are unaffected unless
//! `CHEETAH_FAULT` (or `SecureConfig.fault` / `--fault`) arms it.
//!
//! Spec grammar (comma-separated `key=value`):
//!
//! ```text
//! CHEETAH_FAULT="seed=42,disconnect=0.02,corrupt=0.01,short=0.25,delay=0.05:2,reset=0.01,panic=0.02"
//! ```
//!
//! | key | meaning |
//! |-----|---------|
//! | `seed=N`        | base seed for every derived schedule (required for reproducibility; defaults to 1) |
//! | `disconnect=P`  | per-I/O-call probability of a hard connection drop |
//! | `corrupt=P`     | per-I/O-call probability of flipping one bit in the transferred bytes |
//! | `short=P`       | per-I/O-call probability of truncating the transfer (partial read/write) |
//! | `delay=P[:MS]`  | per-I/O-call probability of sleeping `MS` (default 1) milliseconds |
//! | `reset=P`       | per-accept probability of resetting the connection before serving it |
//! | `panic=P`       | per-job probability of a worker panic (exercises `catch_unwind` isolation) |
//!
//! Every injected fault ticks an `serve.faults.*` telemetry counter, so a
//! chaos run's schedule is observable from the stats endpoint.

use std::io::{self, Read, Write};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::util::rng::SplitMix64;

/// A seeded description of which faults to inject, and how often.
///
/// Probabilities are per I/O call (reads/writes), per accepted connection
/// (`reset`), or per worker job (`panic`). All-zero probabilities are legal
/// and equivalent to no injection, but the wrapper still draws from the
/// schedule RNG — use `None` instead of a zero spec on hot paths.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultSpec {
    /// Base seed; every per-connection [`FaultPlan`] is derived from it.
    pub seed: u64,
    /// Probability of a hard disconnect per I/O call.
    pub p_disconnect: f64,
    /// Probability of flipping one bit in a transfer.
    pub p_corrupt: f64,
    /// Probability of a short (partial) read or write.
    pub p_short: f64,
    /// Probability of an injected delay per I/O call.
    pub p_delay: f64,
    /// Length of an injected delay, in milliseconds.
    pub delay_ms: u64,
    /// Probability of resetting a connection at accept time.
    pub p_reset: f64,
    /// Probability of panicking a worker at job start.
    pub p_panic: f64,
}

impl Default for FaultSpec {
    fn default() -> Self {
        FaultSpec {
            seed: 1,
            p_disconnect: 0.0,
            p_corrupt: 0.0,
            p_short: 0.0,
            p_delay: 0.0,
            delay_ms: 1,
            p_reset: 0.0,
            p_panic: 0.0,
        }
    }
}

impl FaultSpec {
    /// Parse the `key=value,...` grammar (see the module docs). Returns
    /// `None` on any unknown key or unparseable value — a misspelled chaos
    /// config should fail loudly at startup, not silently run fault-free.
    pub fn parse(s: &str) -> Option<FaultSpec> {
        let mut spec = FaultSpec::default();
        for part in s.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (key, value) = part.split_once('=')?;
            let prob = |v: &str| -> Option<f64> {
                let p: f64 = v.parse().ok()?;
                (0.0..=1.0).contains(&p).then_some(p)
            };
            match key.trim() {
                "seed" => spec.seed = value.trim().parse().ok()?,
                "disconnect" => spec.p_disconnect = prob(value)?,
                "corrupt" => spec.p_corrupt = prob(value)?,
                "short" => spec.p_short = prob(value)?,
                "reset" => spec.p_reset = prob(value)?,
                "panic" => spec.p_panic = prob(value)?,
                "delay" => match value.split_once(':') {
                    Some((p, ms)) => {
                        spec.p_delay = prob(p)?;
                        spec.delay_ms = ms.trim().parse().ok()?;
                    }
                    None => spec.p_delay = prob(value)?,
                },
                _ => return None,
            }
        }
        Some(spec)
    }

    /// The process-wide spec from `CHEETAH_FAULT`, if set and well-formed.
    pub fn from_env() -> Option<FaultSpec> {
        std::env::var("CHEETAH_FAULT").ok().and_then(|s| FaultSpec::parse(&s))
    }
}

/// Shared per-server (or per-client) fault source: hands out one derived
/// [`FaultPlan`] per connection and owns the accept-reset / worker-panic
/// schedules, which are not tied to a single stream.
#[derive(Debug)]
pub struct FaultState {
    spec: FaultSpec,
    next_plan: AtomicU64,
    /// Schedule for stream-independent faults (accept resets, worker
    /// panics). Lock-poisoning is impossible here (no panics while held),
    /// but recover anyway rather than unwrap.
    control: Mutex<SplitMix64>,
}

impl FaultState {
    /// A fault source for `spec`.
    pub fn new(spec: FaultSpec) -> Self {
        FaultState {
            spec,
            next_plan: AtomicU64::new(0),
            control: Mutex::new(SplitMix64::new(spec.seed ^ 0xC0_17_20_11)),
        }
    }

    /// The spec this state was built from.
    pub fn spec(&self) -> FaultSpec {
        self.spec
    }

    /// Derive the next per-connection fault schedule. Each call yields an
    /// independent, reproducible stream: schedule `i` of seed `s` is the
    /// same in every run.
    pub fn next_plan(&self) -> FaultPlan {
        let index = self.next_plan.fetch_add(1, Ordering::Relaxed);
        FaultPlan::derive(self.spec, index)
    }

    /// Roll the accept-time reset fault (drop the connection unserved).
    pub fn roll_accept_reset(&self) -> bool {
        self.roll_control(self.spec.p_reset, "serve.faults.reset")
    }

    /// Roll the worker-panic fault (the worker loop panics at job start;
    /// `catch_unwind` isolation turns it into a typed `ERROR` frame).
    pub fn roll_worker_panic(&self) -> bool {
        self.roll_control(self.spec.p_panic, "serve.faults.panic")
    }

    fn roll_control(&self, p: f64, counter: &'static str) -> bool {
        if p <= 0.0 {
            return false;
        }
        let mut rng = match self.control.lock() {
            Ok(g) => g,
            Err(e) => e.into_inner(),
        };
        let hit = rng.next_f64() < p;
        if hit {
            crate::obs::inc(counter);
        }
        hit
    }
}

/// A deterministic per-connection fault schedule (see [`FaultState`]).
#[derive(Debug)]
pub struct FaultPlan {
    spec: FaultSpec,
    rng: SplitMix64,
    dead: bool,
}

impl FaultPlan {
    /// Schedule `index` of `spec` — the same `(seed, index)` pair always
    /// yields the same fault sequence.
    pub fn derive(spec: FaultSpec, index: u64) -> FaultPlan {
        // Domain-separate the per-plan seed with a SplitMix64-style step so
        // consecutive indices give uncorrelated streams.
        let salt = index.wrapping_mul(0x9e37_79b9_7f4a_7c15).wrapping_add(0xFA_17_57_4A);
        FaultPlan { spec, rng: SplitMix64::new(spec.seed ^ salt), dead: false }
    }

    fn roll(&mut self, p: f64) -> bool {
        p > 0.0 && self.rng.next_f64() < p
    }

    fn injected_disconnect(kind: io::ErrorKind) -> io::Error {
        io::Error::new(kind, "injected fault: connection dropped")
    }
}

/// A transport wrapper that injects the faults scheduled by a [`FaultPlan`].
///
/// With no plan ([`FaultyStream::passthrough`]) every call delegates
/// directly to the inner stream — the wrapper is a no-op and costs one
/// `Option` check per I/O call.
#[derive(Debug)]
pub struct FaultyStream<S> {
    inner: S,
    plan: Option<FaultPlan>,
}

impl<S> FaultyStream<S> {
    /// Wrap `inner` with no fault injection (pure delegation).
    pub fn passthrough(inner: S) -> Self {
        FaultyStream { inner, plan: None }
    }

    /// Wrap `inner`, injecting faults when `plan` is `Some`.
    pub fn new(inner: S, plan: Option<FaultPlan>) -> Self {
        FaultyStream { inner, plan }
    }

    /// The wrapped stream.
    pub fn get_ref(&self) -> &S {
        &self.inner
    }

    /// The wrapped stream, mutably.
    pub fn get_mut(&mut self) -> &mut S {
        &mut self.inner
    }

    /// Unwrap, discarding the fault schedule.
    pub fn into_inner(self) -> S {
        self.inner
    }

    /// Delay / disconnect / short-transfer rolls shared by reads and
    /// writes. Returns `Err` on an injected disconnect, otherwise the
    /// transfer-length cap (`None` = full length).
    fn pre_op(&mut self, len: usize, kind: io::ErrorKind) -> io::Result<Option<usize>> {
        let Some(plan) = &mut self.plan else { return Ok(None) };
        if plan.dead {
            return Err(FaultPlan::injected_disconnect(kind));
        }
        if plan.roll(plan.spec.p_delay) {
            crate::obs::inc("serve.faults.delay");
            std::thread::sleep(Duration::from_millis(plan.spec.delay_ms));
        }
        if plan.roll(plan.spec.p_disconnect) {
            crate::obs::inc("serve.faults.disconnect");
            plan.dead = true;
            return Err(FaultPlan::injected_disconnect(kind));
        }
        if len > 1 && plan.roll(plan.spec.p_short) {
            crate::obs::inc("serve.faults.short");
            let cap = 1 + plan.rng.gen_range(len as u64 - 1) as usize;
            return Ok(Some(cap));
        }
        Ok(None)
    }

    fn roll_corrupt(&mut self, n: usize) -> Option<(usize, u8)> {
        let plan = self.plan.as_mut()?;
        if n == 0 || !plan.roll(plan.spec.p_corrupt) {
            return None;
        }
        crate::obs::inc("serve.faults.corrupt");
        let idx = plan.rng.gen_range(n as u64) as usize;
        let mask = 1u8 << plan.rng.gen_range(8);
        Some((idx, mask))
    }
}

impl<S: Read> Read for FaultyStream<S> {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        if self.plan.is_none() {
            return self.inner.read(buf);
        }
        let cap = self.pre_op(buf.len(), io::ErrorKind::ConnectionReset)?;
        let window = cap.unwrap_or(buf.len()).min(buf.len());
        let n = self.inner.read(&mut buf[..window])?;
        if let Some((idx, mask)) = self.roll_corrupt(n) {
            buf[idx] ^= mask;
        }
        Ok(n)
    }
}

impl<S: Write> Write for FaultyStream<S> {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        if self.plan.is_none() {
            return self.inner.write(buf);
        }
        let cap = self.pre_op(buf.len(), io::ErrorKind::BrokenPipe)?;
        let window = cap.unwrap_or(buf.len()).min(buf.len());
        match self.roll_corrupt(window) {
            Some((idx, mask)) => {
                // Corrupt a copy so the caller's buffer (which it may
                // retry from) is untouched — only the wire sees the flip.
                let mut chunk = buf[..window].to_vec();
                chunk[idx] ^= mask;
                self.inner.write(&chunk)
            }
            None => self.inner.write(&buf[..window]),
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        self.inner.flush()
    }
}

#[cfg(unix)]
impl<S: std::os::unix::io::AsRawFd> std::os::unix::io::AsRawFd for FaultyStream<S> {
    fn as_raw_fd(&self) -> std::os::unix::io::RawFd {
        self.inner.as_raw_fd()
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parse_full_spec_and_rejects_garbage() {
        let spec = FaultSpec::parse(
            "seed=42,disconnect=0.02,corrupt=0.01,short=0.25,delay=0.05:2,reset=0.01,panic=0.02",
        )
        .unwrap();
        assert_eq!(spec.seed, 42);
        assert_eq!(spec.p_disconnect, 0.02);
        assert_eq!(spec.p_corrupt, 0.01);
        assert_eq!(spec.p_short, 0.25);
        assert_eq!(spec.p_delay, 0.05);
        assert_eq!(spec.delay_ms, 2);
        assert_eq!(spec.p_reset, 0.01);
        assert_eq!(spec.p_panic, 0.02);

        // Bare delay probability keeps the default 1 ms.
        let spec = FaultSpec::parse("seed=7,delay=0.5").unwrap();
        assert_eq!((spec.p_delay, spec.delay_ms), (0.5, 1));

        assert!(FaultSpec::parse("seed=1,bogus=0.5").is_none());
        assert!(FaultSpec::parse("disconnect=1.5").is_none());
        assert!(FaultSpec::parse("disconnect").is_none());
        assert!(FaultSpec::parse("seed=notanumber").is_none());
    }

    #[test]
    fn passthrough_wrapper_is_bit_exact() {
        let data: Vec<u8> = (0..=255u8).cycle().take(4096).collect();
        let mut s = FaultyStream::passthrough(Cursor::new(data.clone()));
        let mut out = Vec::new();
        s.read_to_end(&mut out).unwrap();
        assert_eq!(out, data);

        let mut w = FaultyStream::passthrough(Vec::new());
        w.write_all(&data).unwrap();
        assert_eq!(w.into_inner(), data);
    }

    /// The same `(seed, index)` pair must produce the identical fault
    /// schedule; different indices must diverge.
    #[test]
    fn plans_are_deterministic_per_index() {
        let spec =
            FaultSpec::parse("seed=9,disconnect=0.1,corrupt=0.2,short=0.4,delay=0.1:0").unwrap();
        let run = |index: u64| {
            let mut s = FaultyStream::new(
                Cursor::new(vec![0u8; 64 * 1024]),
                Some(FaultPlan::derive(spec, index)),
            );
            let mut trace = Vec::new();
            let mut buf = [0u8; 512];
            loop {
                match s.read(&mut buf) {
                    Ok(0) => break,
                    Ok(n) => trace.push((n as i64, buf[..n].iter().map(|&b| b as u64).sum::<u64>())),
                    Err(_) => {
                        trace.push((-1, 0));
                        break;
                    }
                }
            }
            trace
        };
        assert_eq!(run(3), run(3), "same index must replay the same schedule");
        assert_ne!(run(3), run(4), "distinct indices must give distinct schedules");
    }

    #[test]
    fn disconnect_is_sticky() {
        let spec = FaultSpec::parse("seed=5,disconnect=1").unwrap();
        let mut s =
            FaultyStream::new(Cursor::new(vec![1u8; 16]), Some(FaultPlan::derive(spec, 0)));
        let mut buf = [0u8; 4];
        assert!(s.read(&mut buf).is_err());
        assert!(s.read(&mut buf).is_err(), "a dropped connection stays dropped");
        let err = s.write(&[1, 2, 3]).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::BrokenPipe);
    }

    #[test]
    fn short_writes_truncate_but_never_fabricate() {
        let spec = FaultSpec::parse("seed=11,short=1").unwrap();
        let mut s = FaultyStream::new(Vec::new(), Some(FaultPlan::derive(spec, 0)));
        let n = s.write(&[9u8; 100]).unwrap();
        assert!(n >= 1 && n < 100, "short write must land in [1, len): got {n}");
        assert_eq!(s.get_ref().len(), n);
        // A 1-byte write cannot be shortened.
        assert_eq!(s.write(&[7u8]).unwrap(), 1);
    }

    #[test]
    fn corrupt_write_flips_exactly_one_bit_in_a_copy() {
        let spec = FaultSpec::parse("seed=13,corrupt=1").unwrap();
        let src = vec![0u8; 256];
        let mut s = FaultyStream::new(Vec::new(), Some(FaultPlan::derive(spec, 0)));
        let n = s.write(&src).unwrap();
        assert_eq!(n, 256);
        let wire = s.into_inner();
        let flipped: u32 = wire.iter().map(|b| b.count_ones()).sum();
        assert_eq!(flipped, 1, "exactly one bit flips on the wire");
        assert!(src.iter().all(|&b| b == 0), "the caller's buffer is untouched");
    }

    #[test]
    fn control_rolls_are_counted_and_bounded() {
        let state = FaultState::new(FaultSpec::parse("seed=3,reset=1,panic=0").unwrap());
        assert!(state.roll_accept_reset());
        assert!(!state.roll_worker_panic());
        let state = FaultState::new(FaultSpec::default());
        assert!(!state.roll_accept_reset());
    }
}
