//! Secure serving subsystem: the real CHEETAH two-party protocol
//! ([`crate::protocol::cheetah`]) over TCP for many concurrent clients.
//!
//! The paper's headline is ultra-fast *served* private inference; this
//! module is the serving layer that takes the protocol out of the
//! in-process [`crate::protocol::cheetah::CheetahRunner`] and onto real
//! sockets:
//!
//! * [`wire`] — the codec mapping each protocol round onto the
//!   length-prefixed frames of [`crate::protocol::transport`],
//! * [`session`] — per-client session ids and protocol state machines, so
//!   rounds from interleaved clients multiplex on one listener,
//! * [`precompute`] — the offline blinding pool (GAZELLE-style
//!   offline/online split): engines with fresh blinding material and
//!   encrypted indicators are built on background threads ahead of demand,
//! * [`SecureServer`] — listener + session-sticky worker pool with bounded
//!   queues; when a worker queue fills, the connection reader blocks and
//!   TCP flow control pushes back on the client (no unbounded buffering),
//! * [`CheetahNetClient`] — drives a full private inference over a socket.
//!
//! Threading model — two serving fronts behind one [`SecureServer`]
//! surface, selected by [`SecureConfig::reactor`]:
//!
//! * **Threads front** (default): one blocking accept thread (woken for
//!   shutdown via [`StoppableListener`]), one reader thread per
//!   connection, and a fixed worker pool — simple, but session count is
//!   capped by OS threads.
//! * **Reactor front** ([`reactor`], unix only): one event-loop thread
//!   multiplexes every connection over nonblocking sockets and an
//!   epoll/poll readiness poller, with incremental frame reassembly and
//!   per-connection write queues — thousands of concurrent sessions on a
//!   handful of threads, with idle reaping, slow-client eviction, and
//!   graceful `EMFILE` handling.
//!
//! Either way, rounds are routed to worker `session_id % workers`, so one
//! session's rounds execute in order while different sessions run in
//! parallel. Engines score through the stateless `&self` core (per-query
//! share state lives in the [`Session`]), so concurrent sessions never
//! contend on engine ownership; [`SecureConfig::threads`] pins the
//! compute fan-out of this server's workers and pool builders via
//! [`crate::par::with_threads`] — scoped, so no other engine or builder
//! in the process can resize it. Server metrics flow into
//! [`crate::coordinator::metrics`].
//!
//! Trust model: the server authenticates nothing (as in the paper — both
//! parties are semi-honest); malformed input from the network is rejected
//! with typed errors at every decode step, so a confused client can kill
//! its own session but not the server. Session ids come from a CSPRNG —
//! the unguessable id is what stops one client from forging rounds for
//! another's session. Sessions are owned by the connection that created
//! them and are retired when it closes (no leak on abrupt disconnect),
//! and server→client writes carry a timeout so a client that stops
//! reading cannot park a worker forever. The client, by contrast, trusts
//! the server it chose to connect to.
//!
//! Failure model (DESIGN.md §13): every layer assumes the network and the
//! peer *will* misbehave. Worker jobs run under `catch_unwind`, so a
//! panicking round costs one session (typed `ERROR`, `serve.worker_panics`
//! tick), never a worker. v2 bulk frames carry payload checksums
//! ([`wire::seal`]); corruption is caught at the frame boundary
//! (`ERR_CORRUPT`) instead of poisoning a decrypt. The client
//! ([`CheetahNetClient`]) turns every failure into a typed [`NetError`] —
//! per-round deadlines instead of hangs, bounded exponential-backoff
//! reconnect with full-query replay (bit-identical by construction:
//! per-query randomness is seed-derived — asserted via a replay digest).
//! [`SecureServer::shutdown`] drains: stop intake, finish in-flight rounds
//! under [`SecureConfig::drain_timeout`], then close. The [`fault`] module
//! injects seeded, reproducible network faults to prove all of it under
//! test (`CHEETAH_FAULT`, [`SecureConfig::fault`]).

// Satellite guarantee (ISSUE 10): no unwrap/expect on serving paths — an
// attacker-reachable decode or a poisoned lock must never panic a server
// thread. Tests opt out locally.
#![deny(clippy::unwrap_used, clippy::expect_used)]

pub mod fault;
pub mod precompute;
#[cfg(unix)]
pub mod reactor;
pub mod session;
pub mod wire;

pub use fault::{FaultPlan, FaultSpec, FaultState, FaultyStream};
pub use precompute::{BlindingPool, PoolConfig, PoolStats};
pub use session::{Phase, Session, SessionRegistry};

use crate::coordinator::metrics::Metrics;
use crate::coordinator::server::{stop_accept_thread, LiveConns, StoppableListener};
use crate::fixed::ScalePlan;
use crate::nn::{Network, Tensor};
use crate::phe::Context;
use crate::protocol::cheetah::{CheetahClient, ClientQuery, ProtocolSpec};
use crate::protocol::transport::{read_frame_limited, write_frame, DEFAULT_MAX_FRAME_LEN};
use crate::util::rng::ChaCha20Rng;
use std::net::{SocketAddr, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Lock a mutex, recovering the guard from a poisoned lock instead of
/// panicking. Worker panics are isolated with `catch_unwind`, so a lock a
/// panicking job held is poisoned but its data is still structurally sound
/// (session state is retired via the error path anyway) — propagating the
/// poison would turn one injected panic into a server-wide cascade.
pub(crate) fn lock_ok<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Secure-server tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct SecureConfig {
    /// Obscuring-noise bound ε (0.0 = exact inference).
    pub epsilon: f64,
    /// Base seed for per-session engine blinding material. `None` (the
    /// default) draws the base seed from OS entropy — the blinds are the
    /// cryptographic obscuring mechanism, so they must be unpredictable in
    /// deployment. Set `Some(seed)` only for reproducible tests/benches.
    pub seed: Option<u64>,
    /// Protocol worker threads (round computation).
    pub workers: usize,
    /// Offline precomputation pool sizing.
    pub pool: PoolConfig,
    /// Bounded per-worker queue depth (backpressure threshold).
    pub queue_depth: usize,
    /// Maximum accepted frame payload (defense against corrupt lengths).
    pub max_frame: usize,
    /// Server→client write deadline. Threads front: socket write timeout,
    /// so a client that stops reading fails its replies instead of parking
    /// a worker. Reactor front: a connection whose queued output makes no
    /// progress for this long is evicted.
    pub write_timeout: Duration,
    /// Serve through the readiness reactor (one event-loop thread over
    /// nonblocking sockets; unix only — see [`reactor`]) instead of
    /// thread-per-connection. Protocol, wire format, and results are
    /// identical on both fronts.
    pub reactor: bool,
    /// Reactor front only: maximum concurrent connections. At the cap the
    /// listener pauses (counted in `serve.reactor.accept_stalls`) and
    /// resumes as connections close.
    pub max_sessions: usize,
    /// Reactor front only: connections idle this long (no inbound bytes,
    /// nothing queued or in flight) are reaped. Zero disables reaping.
    pub idle_timeout: Duration,
    /// Reactor front only: per-connection write-queue bound in bytes. A
    /// client that lets this much output pile up is evicted instead of
    /// buffered unboundedly (`0` = unbounded).
    pub max_write_queue: usize,
    /// Compute threads for the parallel runtime ([`crate::par`]):
    /// per-channel ciphertext streams, NTT batches, and pool builds all
    /// fan out over this many threads. `0` (the default) keeps the global
    /// setting (`CHEETAH_THREADS` env var, else `available_parallelism()`);
    /// `1` forces the sequential code path. **Scoped to this server**: a
    /// non-zero value pins the server's protocol workers and pool builders
    /// via [`crate::par::with_threads`] — other engines and servers in the
    /// process are unaffected, and nothing they build can resize this
    /// server's parallelism.
    pub threads: usize,
    /// RLWE parameter policy ([`crate::plan::ParamsChoice`]). `Default`
    /// keeps the context handed to [`SecureServer::serve`] untouched;
    /// `Explicit`/`Auto` rebuild the serving context when the chosen
    /// parameters differ (Auto runs the [`crate::plan`] planner against
    /// the hosted network — an infeasible network is a bind-time
    /// `InvalidInput` error, raised before any session exists). Clients
    /// must connect with a matching context (handshake fingerprint).
    pub params: crate::plan::ParamsChoice,
    /// Graceful-shutdown budget: [`SecureServer::shutdown`] stops intake,
    /// then waits up to this long for in-flight rounds to finish before
    /// closing connections (`serve.drain_ms` records the observed wait).
    pub drain_timeout: Duration,
    /// Deterministic fault injection ([`fault::FaultSpec`]) applied to
    /// every accepted connection and worker job. Defaults to
    /// `CHEETAH_FAULT` from the environment; `None` (the normal case)
    /// compiles down to pass-through I/O with zero per-call RNG work.
    pub fault: Option<FaultSpec>,
}

impl Default for SecureConfig {
    fn default() -> Self {
        Self {
            epsilon: 0.0,
            seed: None,
            workers: 2,
            pool: PoolConfig::default(),
            queue_depth: 8,
            max_frame: DEFAULT_MAX_FRAME_LEN,
            write_timeout: Duration::from_secs(30),
            reactor: false,
            max_sessions: 4096,
            idle_timeout: Duration::from_secs(300),
            max_write_queue: 64 << 20,
            threads: 0,
            params: crate::plan::ParamsChoice::Default,
            drain_timeout: Duration::from_secs(5),
            fault: FaultSpec::from_env(),
        }
    }
}

/// State shared by every worker and reader thread.
struct ServeShared {
    ctx: Arc<Context>,
    net: Network,
    plan: ScalePlan,
    epsilon: f64,
    registry: Arc<SessionRegistry>,
    metrics: Arc<Metrics>,
    pool: Arc<BlindingPool>,
    /// Jobs dispatched but not yet finished — the drain condition.
    inflight: Arc<AtomicU64>,
    /// Armed fault injection, if any (`SecureConfig::fault`).
    fault: Option<Arc<FaultState>>,
}

impl ServeShared {
    /// Roll the injected worker-panic fault (no-op when faults are off).
    fn roll_worker_panic(&self) {
        if let Some(f) = &self.fault {
            if f.roll_worker_panic() {
                panic!("injected fault: worker panic");
            }
        }
    }
}

/// Per-connection state shared between the reader thread and the jobs it
/// dispatched: sessions created on this connection are retired when it
/// closes, so an abrupt disconnect (no `BYE`) cannot leak engines.
struct ConnState {
    closed: AtomicBool,
    sessions: Mutex<Vec<u64>>,
}

/// The threads front's shared write half (fault-wrapped socket).
type SharedWriter = Arc<Mutex<FaultyStream<TcpStream>>>;

/// One unit of protocol work, routed to a session-sticky worker. `v2`
/// carries the connection's negotiated wire version (checksummed frames).
enum Job {
    /// Session setup: pop a prepared engine, register, ship the offline
    /// material (indicator ciphertexts) to the client.
    Hello { writer: SharedWriter, conn: Arc<ConnState>, v2: bool },
    /// An online round (`SHARES`, `RECOVERY`, or `BYE`).
    Round { session_id: u64, tag: u8, payload: Vec<u8>, writer: SharedWriter, v2: bool },
}

/// Where a handler's reply frames go: the threads front's write-locked
/// socket, or a connection's reactor write queue. `send` returns `false`
/// when the connection is gone — the handler stops and retires the
/// session it was serving. Frames are atomic per send; ordering across
/// sessions multiplexed on one connection is unspecified (each frame
/// carries its session id).
trait ReplySink {
    /// Ship one frame; `false` means the connection is dead.
    fn send(&mut self, tag: u8, payload: &[u8]) -> bool;
}

/// [`ReplySink`] over the threads front's shared, write-locked socket.
struct StreamSink<'a> {
    writer: &'a SharedWriter,
}

impl ReplySink for StreamSink<'_> {
    fn send(&mut self, tag: u8, payload: &[u8]) -> bool {
        write_or_hangup(&mut lock_ok(self.writer), tag, payload)
    }
}

fn send_error(sink: &mut dyn ReplySink, sid: u64, code: u16, msg: &str) {
    let payload = wire::encode_error(sid, code, msg);
    let _ = sink.send(wire::TAG_ERROR, &payload);
}

/// A running secure server. All threads are joined by [`SecureServer::shutdown`].
pub struct SecureServer {
    /// The bound listen address.
    pub addr: SocketAddr,
    /// Serving metrics (completed queries, latency percentiles).
    pub metrics: Arc<Metrics>,
    registry: Arc<SessionRegistry>,
    pool: Arc<BlindingPool>,
    worker_threads: Mutex<Vec<JoinHandle<()>>>,
    inflight: Arc<AtomicU64>,
    drain_timeout: Duration,
    stopped: AtomicBool,
    front: Front,
}

/// The listener/dispatch machinery behind a [`SecureServer`] — one of the
/// two serving fronts ([`SecureConfig::reactor`] picks at bind time).
enum Front {
    /// Thread-per-connection: blocking readers + bounded worker queues.
    Threads {
        stop: Arc<AtomicBool>,
        accept_thread: Mutex<Option<JoinHandle<()>>>,
        conns: Arc<LiveConns>,
        worker_txs: Mutex<Option<Arc<Vec<SyncSender<Job>>>>>,
    },
    /// One readiness event loop multiplexing every connection (unix only).
    #[cfg(unix)]
    Reactor { handle: reactor::ReactorHandle },
}

impl SecureServer {
    /// Serve `net` through the CHEETAH protocol on `addr`. Returns once the
    /// listener is bound; serving continues on background threads. The
    /// shared [`Context`] is reference-counted across every worker, reader,
    /// and pool thread — no `'static` leak.
    pub fn serve(
        ctx: Arc<Context>,
        net: Network,
        plan: ScalePlan,
        addr: &str,
        cfg: SecureConfig,
    ) -> std::io::Result<SecureServer> {
        // Resolve the parameter policy before anything keyed on the context
        // exists (pool engines, fingerprints): `Auto` runs the static
        // planner against the hosted network, so an infeasible network is
        // refused here — never a garbage decrypt mid-session.
        let ctx = match cfg.params {
            crate::plan::ParamsChoice::Default => ctx,
            choice => {
                let (params, _) = choice
                    .resolve(&net)
                    .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidInput, e.to_string()))?;
                if ctx.params == params { ctx } else { Arc::new(Context::new(params)) }
            }
        };
        plan.check_fits(ctx.params.p);
        let metrics = Arc::new(Metrics::new());
        let registry = Arc::new(SessionRegistry::new());
        let base_seed = cfg
            .seed
            .unwrap_or_else(|| ChaCha20Rng::from_os_entropy().next_u64());
        // The pool validates the network → protocol-spec compilation once,
        // here: a malformed architecture is a bind-time error, never a
        // panic on a serving or builder thread.
        let pool = BlindingPool::start(
            ctx.clone(),
            net.clone(),
            plan,
            cfg.epsilon,
            base_seed,
            cfg.pool,
            cfg.threads,
        )
        .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidInput, e))?;
        let inflight = Arc::new(AtomicU64::new(0));
        let fault = cfg.fault.map(|spec| Arc::new(FaultState::new(spec)));
        let shared = Arc::new(ServeShared {
            ctx,
            net,
            plan,
            epsilon: cfg.epsilon,
            registry: registry.clone(),
            metrics: metrics.clone(),
            pool: pool.clone(),
            inflight: inflight.clone(),
            fault: fault.clone(),
        });

        if cfg.reactor {
            return serve_reactor(shared, metrics, registry, pool, inflight, addr, cfg);
        }

        let listener = StoppableListener::bind(addr)?;
        let local = listener.addr;
        let stop = listener.stop_flag();
        let n_workers = cfg.workers.max(1);
        let mut txs = Vec::with_capacity(n_workers);
        let mut worker_threads = Vec::with_capacity(n_workers);
        for _ in 0..n_workers {
            let (tx, rx) = sync_channel::<Job>(cfg.queue_depth.max(1));
            txs.push(tx);
            let shared = shared.clone();
            let threads = cfg.threads;
            // The per-server thread count rides the worker thread itself
            // (scoped, not global): every round this worker computes —
            // including inline engine builds on pool misses — fans out at
            // the server's configured width.
            worker_threads.push(std::thread::spawn(move || {
                crate::par::with_threads(threads, || worker_loop(rx, shared))
            }));
        }
        let txs = Arc::new(txs);

        let conns = LiveConns::new();
        let accept_thread = {
            let txs = txs.clone();
            let stop = stop.clone();
            let conns = conns.clone();
            let registry = registry.clone();
            let shared = shared.clone();
            let rr = Arc::new(AtomicU64::new(0));
            let max_frame = cfg.max_frame;
            let write_timeout = cfg.write_timeout;
            std::thread::spawn(move || {
                while let Some(stream) = listener.accept() {
                    // Accept-time reset fault: drop the connection unserved
                    // (the client sees a peer reset mid-handshake).
                    if let Some(f) = &shared.fault {
                        if f.roll_accept_reset() {
                            drop(stream);
                            continue;
                        }
                    }
                    stream.set_nodelay(true).ok();
                    let writer = match stream.try_clone() {
                        Ok(w) => {
                            w.set_write_timeout(Some(write_timeout)).ok();
                            let plan = shared.fault.as_ref().map(|f| f.next_plan());
                            Arc::new(Mutex::new(FaultyStream::new(w, plan)))
                        }
                        Err(_) => continue,
                    };
                    let clone = match stream.try_clone() {
                        Ok(c) => c,
                        Err(_) => continue,
                    };
                    let reader_plan = shared.fault.as_ref().map(|f| f.next_plan());
                    let reader = FaultyStream::new(stream, reader_plan);
                    let txs = txs.clone();
                    let stop = stop.clone();
                    let rr = rr.clone();
                    let registry = registry.clone();
                    let shared = shared.clone();
                    let jh = std::thread::spawn(move || {
                        read_loop(reader, writer, txs, rr, stop, max_frame, registry, shared)
                    });
                    conns.track(clone, jh);
                }
            })
        };

        Ok(SecureServer {
            addr: local,
            metrics,
            registry,
            pool,
            worker_threads: Mutex::new(worker_threads),
            inflight,
            drain_timeout: cfg.drain_timeout,
            stopped: AtomicBool::new(false),
            front: Front::Threads {
                stop,
                accept_thread: Mutex::new(Some(accept_thread)),
                conns,
                worker_txs: Mutex::new(Some(txs)),
            },
        })
    }

    /// Point-in-time blinding-pool counters.
    pub fn pool_stats(&self) -> PoolStats {
        self.pool.stats()
    }

    /// Block until the blinding pool has produced at least `n` engines
    /// (bench/ops warmup). Returns whether the target was reached in time.
    pub fn wait_pool_ready(&self, n: u64, timeout: Duration) -> bool {
        self.pool.wait_until_produced(n, timeout)
    }

    /// Number of live sessions.
    pub fn session_count(&self) -> usize {
        self.registry.len()
    }

    /// Protocol rounds currently executing or queued on workers.
    pub fn inflight(&self) -> u64 {
        self.inflight.load(Ordering::SeqCst)
    }

    /// Gracefully stop: stop accepting new connections, wait up to
    /// `timeout` for in-flight rounds to finish (`serve.drain_ms` records
    /// the observed wait), then close every live connection and join the
    /// accept (or reactor), reader, worker, and pool threads. Idempotent —
    /// the first caller drains, later calls (including `Drop`) return
    /// immediately.
    pub fn drain(&self, timeout: Duration) {
        if self.stopped.swap(true, Ordering::SeqCst) {
            return;
        }
        let t0 = Instant::now();
        if let Front::Threads { stop, accept_thread, .. } = &self.front {
            // Stops the listener and flips the readers' stop flag: no new
            // rounds are dispatched, queued ones keep draining.
            stop_accept_thread(stop, self.addr, accept_thread);
        }
        while self.inflight.load(Ordering::SeqCst) > 0 && t0.elapsed() < timeout {
            std::thread::sleep(Duration::from_millis(1));
        }
        crate::obs::record("serve.drain_ms", t0.elapsed().as_secs_f64() * 1e3);
        match &self.front {
            Front::Threads { conns, worker_txs, .. } => {
                // Closing the sockets unblocks readers parked in read_frame.
                conns.close_and_join();
                // Dropping the senders disconnects the worker queues.
                lock_ok(worker_txs).take();
            }
            // Joining the reactor thread drops its connections and worker
            // senders, which in turn disconnects the worker queues below.
            #[cfg(unix)]
            Front::Reactor { handle } => handle.shutdown(),
        }
        let workers: Vec<JoinHandle<()>> = lock_ok(&self.worker_threads).drain(..).collect();
        for h in workers {
            let _ = h.join();
        }
        self.registry.clear();
        self.pool.shutdown();
    }

    /// [`SecureServer::drain`] under [`SecureConfig::drain_timeout`].
    pub fn shutdown(&self) {
        self.drain(self.drain_timeout);
    }
}

/// Bind and launch the [`reactor`] front (unix only — see
/// [`SecureConfig::reactor`]).
#[cfg(unix)]
fn serve_reactor(
    shared: Arc<ServeShared>,
    metrics: Arc<Metrics>,
    registry: Arc<SessionRegistry>,
    pool: Arc<BlindingPool>,
    inflight: Arc<AtomicU64>,
    addr: &str,
    cfg: SecureConfig,
) -> std::io::Result<SecureServer> {
    let listener = std::net::TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    let (handle, worker_threads) = reactor::spawn(listener, shared, cfg)?;
    Ok(SecureServer {
        addr: local,
        metrics,
        registry,
        pool,
        worker_threads: Mutex::new(worker_threads),
        inflight,
        drain_timeout: cfg.drain_timeout,
        stopped: AtomicBool::new(false),
        front: Front::Reactor { handle },
    })
}

/// The reactor front needs readiness polling; refuse cleanly elsewhere.
#[cfg(not(unix))]
fn serve_reactor(
    _shared: Arc<ServeShared>,
    _metrics: Arc<Metrics>,
    _registry: Arc<SessionRegistry>,
    _pool: Arc<BlindingPool>,
    _inflight: Arc<AtomicU64>,
    _addr: &str,
    _cfg: SecureConfig,
) -> std::io::Result<SecureServer> {
    Err(std::io::Error::new(
        std::io::ErrorKind::Unsupported,
        "SecureConfig::reactor requires a unix target (epoll/poll readiness)",
    ))
}

impl Drop for SecureServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Per-connection reader: frames in, jobs out. Blocking `send` into the
/// bounded worker queues is the backpressure point — a flooded server stops
/// reading and TCP pushes back on the sender. On exit (hangup, protocol
/// garbage, shutdown) every session created on this connection is retired.
#[allow(clippy::too_many_arguments)]
fn read_loop(
    stream: FaultyStream<TcpStream>,
    writer: SharedWriter,
    txs: Arc<Vec<SyncSender<Job>>>,
    rr: Arc<AtomicU64>,
    stop: Arc<AtomicBool>,
    max_frame: usize,
    registry: Arc<SessionRegistry>,
    shared: Arc<ServeShared>,
) {
    let conn = Arc::new(ConnState {
        closed: AtomicBool::new(false),
        sessions: Mutex::new(Vec::new()),
    });
    read_frames(stream, &writer, &txs, &rr, &stop, max_frame, &conn, &shared);
    // The connection is gone: retire its sessions. A Hello still in flight
    // sees `closed` and retires its own session (see handle_hello).
    conn.closed.store(true, Ordering::SeqCst);
    for sid in lock_ok(&conn.sessions).drain(..) {
        registry.remove(sid);
    }
}

/// Dispatch one job to its session-sticky worker, keeping the in-flight
/// count exact: the increment happens before the send so the drain path
/// can never observe a dispatched-but-uncounted round.
fn dispatch(shared: &ServeShared, txs: &[SyncSender<Job>], w: usize, job: Job) -> bool {
    shared.inflight.fetch_add(1, Ordering::SeqCst);
    if txs[w].send(job).is_err() {
        shared.inflight.fetch_sub(1, Ordering::SeqCst);
        return false;
    }
    true
}

#[allow(clippy::too_many_arguments)]
fn read_frames(
    mut stream: FaultyStream<TcpStream>,
    writer: &SharedWriter,
    txs: &Arc<Vec<SyncSender<Job>>>,
    rr: &Arc<AtomicU64>,
    stop: &Arc<AtomicBool>,
    max_frame: usize,
    conn: &Arc<ConnState>,
    shared: &Arc<ServeShared>,
) {
    // Negotiated wire version for this connection (v2 ⇒ checksummed bulk
    // frames); set by the HELLO, false for rounds that precede one.
    let mut v2 = false;
    loop {
        if stop.load(Ordering::SeqCst) {
            return;
        }
        let (tag, payload) = match read_frame_limited(&mut stream, max_frame) {
            Ok(f) => f,
            Err(_) => return, // peer hung up, oversized frame, or shutdown
        };
        crate::obs::add("serve.rx_bytes", payload.len() as u64 + 5);
        match tag {
            wire::TAG_HELLO => {
                match wire::decode_hello(&payload) {
                    Ok(version) => v2 = version >= 2,
                    Err(e) => {
                        let mut sink = StreamSink { writer };
                        send_error(&mut sink, 0, wire::ERR_UNSUPPORTED, &e.to_string());
                        return;
                    }
                }
                let w = (rr.fetch_add(1, Ordering::Relaxed) as usize) % txs.len();
                let job = Job::Hello { writer: writer.clone(), conn: conn.clone(), v2 };
                if !dispatch(shared, txs, w, job) {
                    return;
                }
            }
            wire::TAG_STATS => {
                // Admin introspection: answered inline from the reader (the
                // snapshot capture is lock-free, so this cannot stall rounds
                // queued behind it on a worker).
                let body = crate::obs::snapshot().to_json();
                if !write_or_hangup(&mut lock_ok(writer), wire::TAG_STATS_OK, body.as_bytes()) {
                    return;
                }
            }
            wire::TAG_SHARES | wire::TAG_RECOVERY | wire::TAG_BYE => {
                let sid = match wire::peek_session_id(&payload) {
                    Ok(s) => s,
                    Err(e) => {
                        let mut sink = StreamSink { writer };
                        send_error(&mut sink, 0, wire::ERR_PROTOCOL, &e.to_string());
                        return;
                    }
                };
                let w = (sid % txs.len() as u64) as usize;
                let job =
                    Job::Round { session_id: sid, tag, payload, writer: writer.clone(), v2 };
                if !dispatch(shared, txs, w, job) {
                    return;
                }
            }
            other => {
                let mut sink = StreamSink { writer };
                send_error(
                    &mut sink,
                    0,
                    wire::ERR_PROTOCOL,
                    &format!("unknown frame tag {other:#04x}"),
                );
                return;
            }
        }
    }
}

fn worker_loop(rx: Receiver<Job>, shared: Arc<ServeShared>) {
    for job in rx {
        // Worker-panic isolation: a panicking round (engine bug, injected
        // fault) costs the offending session a typed ERROR and ticks
        // `serve.worker_panics` — the worker itself survives to take the
        // next job, and the in-flight count still comes down.
        match job {
            Job::Hello { writer, conn, v2 } => {
                let outcome = catch_unwind(AssertUnwindSafe(|| {
                    shared.roll_worker_panic();
                    let mut sink = StreamSink { writer: &writer };
                    handle_hello(&shared, &mut sink, &conn, v2);
                }));
                if outcome.is_err() {
                    crate::obs::inc("serve.worker_panics");
                    let mut sink = StreamSink { writer: &writer };
                    send_error(&mut sink, 0, wire::ERR_INTERNAL, "internal error: session setup panicked");
                }
            }
            Job::Round { session_id, tag, mut payload, writer, v2 } => {
                let outcome = catch_unwind(AssertUnwindSafe(|| {
                    shared.roll_worker_panic();
                    let mut sink = StreamSink { writer: &writer };
                    handle_round(&shared, session_id, tag, &mut payload, v2, &mut sink);
                }));
                if outcome.is_err() {
                    crate::obs::inc("serve.worker_panics");
                    let mut sink = StreamSink { writer: &writer };
                    send_error(&mut sink, session_id, wire::ERR_INTERNAL, "internal error: round panicked");
                    shared.registry.remove(session_id);
                }
            }
        }
        shared.inflight.fetch_sub(1, Ordering::SeqCst);
    }
}

/// A failed (or timed-out) reply write means the peer stopped reading or
/// the framing is now corrupt mid-stream: drop the whole connection so its
/// reader exits and the connection's sessions are retired.
fn write_or_hangup(w: &mut FaultyStream<TcpStream>, tag: u8, payload: &[u8]) -> bool {
    if write_frame(w, tag, payload).is_err() {
        let _ = w.get_ref().shutdown(std::net::Shutdown::Both);
        return false;
    }
    crate::obs::add("serve.tx_bytes", payload.len() as u64 + 5);
    true
}

fn handle_hello(shared: &ServeShared, sink: &mut dyn ReplySink, conn: &Arc<ConnState>, v2: bool) {
    let engine = Arc::new(shared.pool.take());
    let (sid, session) = shared.registry.create(engine);
    // Tie the session to its connection; if the connection closed while we
    // were setting up, retire it immediately (the reader's sweep may have
    // already run).
    lock_ok(&conn.sessions).push(sid);
    if conn.closed.load(Ordering::SeqCst) {
        shared.registry.remove(sid);
        return;
    }
    let session = lock_ok(&session);
    let n_steps = session.engine.spec.steps.len();
    let negotiated = if v2 { wire::VERSION } else { 1 };
    let hello_ok = wire::encode_hello_ok(
        sid,
        wire::plan_fingerprint(&shared.ctx.params, &shared.plan),
        shared.epsilon,
        n_steps as u32,
        &shared.net,
        negotiated,
    );
    if !sink.send(wire::TAG_HELLO_OK, &hello_ok) {
        shared.registry.remove(sid);
        return;
    }
    // Ship the offline material: indicator ciphertexts for every
    // intermediate step (the last step has none — its result is revealed
    // obscured, the paper's f^OMI).
    for si in 0..n_steps.saturating_sub(1) {
        let (id1, id2) = session.engine.indicator_cts(si);
        let mut payload = wire::round_header(sid, si as u32);
        wire::encode_cts(&mut payload, id1);
        wire::encode_cts(&mut payload, id2);
        if v2 {
            wire::seal(wire::TAG_OFFLINE_IDS, &mut payload);
        }
        if !sink.send(wire::TAG_OFFLINE_IDS, &payload) {
            shared.registry.remove(sid);
            return;
        }
    }
    let mut done = sid.to_le_bytes().to_vec();
    if v2 {
        wire::seal(wire::TAG_OFFLINE_DONE, &mut done);
    }
    let _ = sink.send(wire::TAG_OFFLINE_DONE, &done);
}

fn handle_round(
    shared: &ServeShared,
    session_id: u64,
    tag: u8,
    payload: &mut Vec<u8>,
    v2: bool,
    sink: &mut dyn ReplySink,
) {
    if tag == wire::TAG_BYE {
        shared.registry.remove(session_id);
        return;
    }
    // v2 bulk frames carry a payload checksum: a mismatch means the bytes
    // cannot be trusted (network corruption) — retire the session with the
    // dedicated code so the client knows to retry rather than give up.
    if v2 {
        if let Err(e) = wire::verify_and_strip(tag, payload) {
            send_error(sink, session_id, wire::ERR_CORRUPT, &e.to_string());
            shared.registry.remove(session_id);
            return;
        }
    }
    let Some(session) = shared.registry.get(session_id) else {
        send_error(sink, session_id, wire::ERR_PROTOCOL, "unknown session");
        return;
    };
    let mut r = wire::ByteReader::new(payload);
    let decoded = wire::read_round_header(&mut r)
        .and_then(|(_, step)| wire::decode_cts(&shared.ctx, &mut r).map(|cts| (step, cts)));
    let (step, cts) = match decoded {
        Ok(d) => d,
        Err(e) => {
            send_error(sink, session_id, wire::ERR_PROTOCOL, &e.to_string());
            shared.registry.remove(session_id);
            return;
        }
    };
    let result = {
        let mut s = lock_ok(&session);
        match tag {
            wire::TAG_SHARES => s
                .on_shares(step as usize, &cts, &shared.metrics)
                .map(|p| (wire::TAG_PRODUCTS, p)),
            _ => s.on_recovery(step as usize, &cts).map(|p| (wire::TAG_RECOVERY_OK, p)),
        }
    };
    match result {
        Ok((reply_tag, mut reply)) => {
            if v2 {
                wire::seal(reply_tag, &mut reply);
            }
            let _ = sink.send(reply_tag, &reply);
        }
        Err(violation) => {
            send_error(sink, session_id, wire::ERR_PROTOCOL, &violation.to_string());
            shared.registry.remove(session_id);
        }
    }
}

// ---------------------------------------------------------------------------
// Client
// ---------------------------------------------------------------------------

/// Client-side accounting for one secure inference over the wire.
#[derive(Clone, Debug, Default)]
pub struct NetReport {
    /// Predicted class (last maximum of the logits).
    pub argmax: usize,
    /// Dequantized logits.
    pub logits: Vec<f64>,
    /// Exact client→server bytes put on the wire (frame headers included).
    pub c2s_bytes: u64,
    /// Exact server→client bytes (frame headers included).
    pub s2c_bytes: u64,
    /// Round trips (SHARES→PRODUCTS and RECOVERY→RECOVERY_OK pairs).
    pub rounds: u64,
    /// End-to-end wall time of the query, wire included.
    pub wall: Duration,
}

/// Typed terminal failure of a networked client operation. Every failure
/// mode of [`CheetahNetClient`] lands here — a query either returns
/// bit-exact logits or one of these, never a hang (reads carry the
/// [`NetClientOpts::deadline`]) and never a panic.
#[derive(Debug)]
pub enum NetError {
    /// Transport failure: dial, send/recv, or an undecodable frame.
    Io(std::io::Error),
    /// The server replied with a typed `ERROR` frame.
    Server {
        /// Wire error code (`wire::ERR_*`).
        code: u16,
        /// Human-readable server message.
        msg: String,
    },
    /// The handshake was refused (fingerprint, architecture, or version) —
    /// retrying cannot help; the two parties are misconfigured.
    Handshake(String),
    /// A per-round deadline expired with no reply.
    Deadline,
    /// A replayed query's first round was not bit-identical to the original
    /// attempt — the seed-derived determinism contract is broken, so the
    /// replay was aborted before the server saw inconsistent shares.
    ReplayDiverged,
    /// Every retry attempt failed; `last` is the final attempt's error.
    RetriesExhausted {
        /// Attempts made (first try included).
        attempts: u32,
        /// The error that ended the final attempt.
        last: Box<NetError>,
    },
}

impl NetError {
    /// Whether a fresh attempt over a new connection could succeed:
    /// transport faults, deadlines, and transient server failures
    /// (`ERR_INTERNAL` worker panic, `ERR_CORRUPT` checksum) are
    /// retryable; handshake refusals, protocol violations, and replay
    /// divergence are terminal.
    pub fn is_retryable(&self) -> bool {
        match self {
            NetError::Io(_) | NetError::Deadline => true,
            NetError::Server { code, .. } => {
                *code == wire::ERR_INTERNAL || *code == wire::ERR_CORRUPT
            }
            NetError::Handshake(_)
            | NetError::ReplayDiverged
            | NetError::RetriesExhausted { .. } => false,
        }
    }
}

impl std::fmt::Display for NetError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            NetError::Io(e) => write!(f, "transport error: {e}"),
            NetError::Server { code, msg } => write!(f, "server error {code}: {msg}"),
            NetError::Handshake(msg) => write!(f, "handshake refused: {msg}"),
            NetError::Deadline => write!(f, "round deadline expired"),
            NetError::ReplayDiverged => {
                write!(f, "replayed query diverged from the original attempt")
            }
            NetError::RetriesExhausted { attempts, last } => {
                write!(f, "all {attempts} attempts failed; last: {last}")
            }
        }
    }
}

impl std::error::Error for NetError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            NetError::Io(e) => Some(e),
            NetError::RetriesExhausted { last, .. } => Some(last),
            _ => None,
        }
    }
}

impl From<std::io::Error> for NetError {
    fn from(e: std::io::Error) -> Self {
        // A read timeout surfaces as TimedOut (or WouldBlock on some
        // platforms): that is the per-round deadline, typed as such.
        match e.kind() {
            std::io::ErrorKind::TimedOut | std::io::ErrorKind::WouldBlock => NetError::Deadline,
            _ => NetError::Io(e),
        }
    }
}

impl From<wire::WireError> for NetError {
    fn from(e: wire::WireError) -> Self {
        NetError::Io(e.into())
    }
}

impl From<crate::protocol::transport::FrameError> for NetError {
    fn from(e: crate::protocol::transport::FrameError) -> Self {
        NetError::from(std::io::Error::from(e))
    }
}

impl From<NetError> for std::io::Error {
    fn from(e: NetError) -> Self {
        match e {
            NetError::Io(e) => e,
            NetError::Deadline => {
                std::io::Error::new(std::io::ErrorKind::TimedOut, "round deadline expired")
            }
            other => std::io::Error::other(other.to_string()),
        }
    }
}

/// Robustness knobs for [`CheetahNetClient`] (see
/// [`CheetahNetClient::connect_with`]).
#[derive(Clone, Copy, Debug)]
pub struct NetClientOpts {
    /// Per-round read deadline: a server that goes silent mid-round fails
    /// the attempt as [`NetError::Deadline`] instead of hanging forever.
    pub deadline: Duration,
    /// Retry budget per query *beyond* the first attempt. Each retry
    /// reconnects (new session, replayed query) after exponential backoff.
    pub max_retries: u32,
    /// Client-side fault injection, applied to this client's own socket
    /// (chaos tests exercise both directions). Defaults to `CHEETAH_FAULT`.
    pub fault: Option<FaultSpec>,
}

impl Default for NetClientOpts {
    fn default() -> Self {
        NetClientOpts {
            deadline: Duration::from_secs(30),
            max_retries: 3,
            fault: FaultSpec::from_env(),
        }
    }
}

/// Drives a full CHEETAH inference over a real socket against a
/// [`SecureServer`]. The constructor performs the handshake (parameter
/// fingerprint check, architecture download, offline indicator transfer);
/// [`CheetahNetClient::infer`] then runs queries on the cached session,
/// transparently reconnecting and replaying on transient failure (the
/// replay is bit-identical because per-query randomness is derived from
/// `(seed, query index)` — asserted via a first-round digest).
pub struct CheetahNetClient {
    ctx: Arc<Context>,
    plan: ScalePlan,
    addr: SocketAddr,
    seed: u64,
    opts: NetClientOpts,
    stream: FaultyStream<TcpStream>,
    /// The server-assigned session id (changes after a reconnect).
    pub session_id: u64,
    /// Negotiated v2 framing (payload checksums on bulk frames).
    v2: bool,
    client: CheetahClient,
    last_step: usize,
    max_frame: usize,
    /// Bytes received during the offline phase (handshake + indicators),
    /// frame headers included — the networked "offline communication".
    /// Reconnects repeat the offline phase and add to this.
    offline_bytes: u64,
    said_bye: bool,
    /// Dials performed (fault-schedule index for the client's own socket).
    dials: u64,
}

fn invalid(msg: &str) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, msg.to_string())
}

fn error_frame_to_net(payload: &[u8]) -> NetError {
    match wire::decode_error(payload) {
        Ok((_, code, msg)) => NetError::Server { code, msg },
        Err(e) => NetError::from(e),
    }
}

/// Dial the server and validate the session grant. Returns the connected
/// stream and the decoded [`wire::HelloOk`]; the offline phase is not yet
/// consumed.
fn dial_hello(
    ctx: &Arc<Context>,
    plan: &ScalePlan,
    addr: &SocketAddr,
    opts: &NetClientOpts,
    seed: u64,
    dial_index: u64,
    max_frame: usize,
) -> Result<(FaultyStream<TcpStream>, wire::HelloOk, u64), NetError> {
    let tcp = TcpStream::connect(addr).map_err(NetError::from)?;
    tcp.set_nodelay(true).ok();
    tcp.set_read_timeout(Some(opts.deadline)).ok();
    let fault_plan = opts
        .fault
        .map(|spec| FaultPlan::derive(spec, seed.rotate_left(17) ^ dial_index));
    let mut stream = FaultyStream::new(tcp, fault_plan);
    write_frame(&mut stream, wire::TAG_HELLO, &wire::encode_hello())?;
    let (tag, payload) = read_frame_limited(&mut stream, max_frame)?;
    let offline_bytes = payload.len() as u64 + 5;
    if tag == wire::TAG_ERROR {
        return Err(error_frame_to_net(&payload));
    }
    if tag != wire::TAG_HELLO_OK {
        return Err(NetError::Handshake("expected HELLO_OK".into()));
    }
    let hello = wire::decode_hello_ok(&payload)?;
    if hello.fingerprint != wire::plan_fingerprint(&ctx.params, plan) {
        return Err(NetError::Handshake(
            "server/client parameter or scale-plan mismatch (fingerprint)".into(),
        ));
    }
    Ok((stream, hello, offline_bytes))
}

/// Consume the offline phase (indicator ciphertexts per step) into
/// `client`, verifying v2 checksums. Returns the bytes received.
fn install_offline(
    ctx: &Arc<Context>,
    stream: &mut FaultyStream<TcpStream>,
    client: &mut CheetahClient,
    n_steps: usize,
    v2: bool,
    max_frame: usize,
) -> Result<u64, NetError> {
    let mut offline_bytes = 0u64;
    loop {
        let (tag, mut payload) = read_frame_limited(stream, max_frame)?;
        offline_bytes += payload.len() as u64 + 5;
        match tag {
            wire::TAG_OFFLINE_IDS => {
                if v2 {
                    wire::verify_and_strip(wire::TAG_OFFLINE_IDS, &mut payload)?;
                }
                let mut r = wire::ByteReader::new(&payload);
                let (_, step) = wire::read_round_header(&mut r)?;
                if step as usize >= n_steps {
                    return Err(NetError::Io(invalid("offline indicators for unknown step")));
                }
                let id1 = wire::decode_cts(ctx, &mut r)?;
                let id2 = wire::decode_cts(ctx, &mut r)?;
                client.install_indicators(step as usize, id1, id2);
            }
            wire::TAG_OFFLINE_DONE => {
                if v2 {
                    wire::verify_and_strip(wire::TAG_OFFLINE_DONE, &mut payload)?;
                }
                break;
            }
            wire::TAG_ERROR => return Err(error_frame_to_net(&payload)),
            _ => return Err(NetError::Io(invalid("unexpected frame during offline phase"))),
        }
    }
    Ok(offline_bytes)
}

impl CheetahNetClient {
    /// Connect and complete the offline phase with default robustness
    /// options ([`NetClientOpts::default`]). `ctx`/`plan` must match the
    /// server's (verified via the handshake fingerprint); `seed` drives the
    /// client's key generation and share randomness.
    pub fn connect(
        ctx: Arc<Context>,
        plan: ScalePlan,
        addr: &SocketAddr,
        seed: u64,
    ) -> std::io::Result<Self> {
        Self::connect_with(ctx, plan, addr, seed, NetClientOpts::default())
            .map_err(std::io::Error::from)
    }

    /// [`CheetahNetClient::connect`] with explicit deadline / retry / fault
    /// options.
    pub fn connect_with(
        ctx: Arc<Context>,
        plan: ScalePlan,
        addr: &SocketAddr,
        seed: u64,
        opts: NetClientOpts,
    ) -> Result<Self, NetError> {
        let max_frame = DEFAULT_MAX_FRAME_LEN;
        let (mut stream, hello, mut offline_bytes) =
            dial_hello(&ctx, &plan, addr, &opts, seed, 0, max_frame)?;
        // A server advertising an architecture the protocol cannot express
        // is a typed connect error, not a client panic.
        let spec = ProtocolSpec::compile(&hello.arch)
            .map_err(|e| NetError::Handshake(format!("server architecture rejected: {e}")))?;
        let n_steps = spec.steps.len();
        if n_steps != hello.n_steps as usize {
            return Err(NetError::Handshake(
                "handshake step count disagrees with architecture".into(),
            ));
        }
        let v2 = hello.version >= 2;
        let mut client = CheetahClient::new(ctx.clone(), spec, plan, seed);
        offline_bytes += install_offline(&ctx, &mut stream, &mut client, n_steps, v2, max_frame)?;
        Ok(Self {
            ctx,
            plan,
            addr: *addr,
            seed,
            opts,
            stream,
            session_id: hello.session_id,
            v2,
            client,
            last_step: n_steps - 1,
            max_frame,
            offline_bytes,
            said_bye: false,
            dials: 1,
        })
    }

    /// Re-dial and re-handshake after a transient failure, keeping the
    /// existing [`CheetahClient`] (and thus the query-index counter and
    /// seed-derived randomness — the basis of bit-exact replay). The old
    /// socket is dropped, which retires the old session server-side.
    fn reconnect(&mut self) -> Result<(), NetError> {
        let dial_index = self.dials;
        self.dials += 1;
        let (mut stream, hello, mut offline_bytes) = dial_hello(
            &self.ctx,
            &self.plan,
            &self.addr,
            &self.opts,
            self.seed,
            dial_index,
            self.max_frame,
        )?;
        if hello.n_steps as usize != self.last_step + 1 {
            return Err(NetError::Handshake(
                "server changed step count across reconnect".into(),
            ));
        }
        let v2 = hello.version >= 2;
        offline_bytes += install_offline(
            &self.ctx,
            &mut stream,
            &mut self.client,
            self.last_step + 1,
            v2,
            self.max_frame,
        )?;
        self.stream = stream;
        self.session_id = hello.session_id;
        self.v2 = v2;
        self.offline_bytes += offline_bytes;
        self.said_bye = false;
        Ok(())
    }

    /// Bytes shipped to this client during the offline phase (handshake +
    /// indicator ciphertexts, frame headers included).
    pub fn offline_bytes(&self) -> u64 {
        self.offline_bytes
    }

    /// Fetch the server's live telemetry snapshot over the `STATS` admin
    /// frame. Returns the raw JSON document (parse with
    /// [`crate::obs::Snapshot::from_json`]). Must not be interleaved with
    /// an in-flight [`CheetahNetClient::infer`] round.
    pub fn stats_json(&mut self) -> std::io::Result<String> {
        write_frame(&mut self.stream, wire::TAG_STATS, &[])?;
        let payload = self.read_expect(wire::TAG_STATS_OK).map_err(std::io::Error::from)?;
        String::from_utf8(payload).map_err(|_| invalid("stats snapshot is not valid UTF-8"))
    }

    /// Read a frame, demanding tag `want`: `ERROR` frames become
    /// [`NetError::Server`], v2 bulk replies are checksum-verified, and a
    /// silent server trips the deadline.
    fn read_expect(&mut self, want: u8) -> Result<Vec<u8>, NetError> {
        let (tag, mut payload) = read_frame_limited(&mut self.stream, self.max_frame)?;
        if tag == wire::TAG_ERROR {
            return Err(error_frame_to_net(&payload));
        }
        if tag != want {
            return Err(NetError::Io(invalid("unexpected frame tag")));
        }
        let sealed = matches!(want, wire::TAG_PRODUCTS | wire::TAG_RECOVERY_OK);
        if self.v2 && sealed {
            wire::verify_and_strip(want, &mut payload)?;
        }
        Ok(payload)
    }

    /// Run one private inference end to end over the socket.
    ///
    /// On a retryable failure (transport fault, deadline, transient server
    /// error) the client reconnects with exponential backoff — up to
    /// [`NetClientOpts::max_retries`] times, `serve.retries` counts them —
    /// and *replays the same query*: the per-query randomness stream is
    /// derived from `(seed, query index)`, so the replayed first round is
    /// bit-identical to the original (verified with a digest; divergence is
    /// the typed [`NetError::ReplayDiverged`]). The result is therefore
    /// exactly what the fault-free run would have produced, or a typed
    /// error — never a hang, never a silently different answer.
    pub fn infer(&mut self, input: &Tensor) -> Result<NetReport, NetError> {
        let query_index = self.client.reserve_queries(1);
        let mut replay_digest: Option<u64> = None;
        let mut last: Option<NetError> = None;
        for attempt in 0..=self.opts.max_retries {
            if attempt > 0 {
                crate::obs::inc("serve.retries");
                // Bounded exponential backoff: 10, 20, 40, … ms.
                std::thread::sleep(Duration::from_millis(10u64 << (attempt - 1).min(6)));
                if let Err(e) = self.reconnect() {
                    if e.is_retryable() {
                        last = Some(e);
                        continue;
                    }
                    return Err(e);
                }
            }
            match self.try_query(input, query_index, &mut replay_digest) {
                Ok(report) => return Ok(report),
                Err(e) if e.is_retryable() => last = Some(e),
                Err(e) => return Err(e),
            }
        }
        Err(NetError::RetriesExhausted {
            attempts: self.opts.max_retries + 1,
            last: Box::new(last.unwrap_or(NetError::Deadline)),
        })
    }

    /// One attempt at query `query_index` on the current connection.
    fn try_query(
        &mut self,
        input: &Tensor,
        query_index: u64,
        replay_digest: &mut Option<u64>,
    ) -> Result<NetReport, NetError> {
        let t0 = Instant::now();
        let mut q = self.client.start_query(input, query_index);
        let n = self.ctx.params.n;
        let (mut c2s, mut s2c, mut rounds) = (0u64, 0u64, 0u64);
        for si in 0..=self.last_step {
            // C → S: encrypted transformed share.
            let cts = self.client.step_send_with(si, &mut q);
            let mut payload = wire::round_header(self.session_id, si as u32);
            wire::encode_cts(&mut payload, &cts);
            if si == 0 {
                // Replay assertion: the first-round ciphertexts (everything
                // past the 12-byte session/step header, which legitimately
                // changes across reconnects) must be bit-identical on every
                // attempt — per-query randomness is seed-derived, so any
                // divergence means broken determinism, not a network fault.
                let digest = wire::checksum(wire::TAG_SHARES, &payload[12..]);
                match replay_digest {
                    None => *replay_digest = Some(digest),
                    Some(prev) if *prev != digest => return Err(NetError::ReplayDiverged),
                    Some(_) => {}
                }
            }
            if self.v2 {
                wire::seal(wire::TAG_SHARES, &mut payload);
            }
            c2s += payload.len() as u64 + 5;
            rounds += 1;
            write_frame(&mut self.stream, wire::TAG_SHARES, &payload)
                .map_err(NetError::from)?;

            // S → C: obscured products.
            let resp = self.read_expect(wire::TAG_PRODUCTS)?;
            s2c += resp.len() as u64 + 5;
            let mut r = wire::ByteReader::new(&resp);
            let (sid, step) = wire::read_round_header(&mut r)?;
            if sid != self.session_id || step as usize != si {
                return Err(NetError::Io(invalid("products round header mismatch")));
            }
            let out_cts = wire::decode_cts(&self.ctx, &mut r)?;
            if out_cts.len() != self.client.spec.steps[si].linear.num_out_cts(n) {
                return Err(NetError::Io(invalid("wrong obscured-product ciphertext count")));
            }

            // C → S: nonlinear recovery (intermediate steps only).
            if let Some(rec) = self.client.step_receive_with(si, &out_cts, &mut q) {
                let mut payload = wire::round_header(self.session_id, si as u32);
                wire::encode_cts(&mut payload, &rec);
                if self.v2 {
                    wire::seal(wire::TAG_RECOVERY, &mut payload);
                }
                c2s += payload.len() as u64 + 5;
                rounds += 1;
                write_frame(&mut self.stream, wire::TAG_RECOVERY, &payload)
                    .map_err(NetError::from)?;
                let ok = self.read_expect(wire::TAG_RECOVERY_OK)?;
                s2c += ok.len() as u64 + 5;
                let mut r = wire::ByteReader::new(&ok);
                let (sid, step) = wire::read_round_header(&mut r)?;
                if sid != self.session_id || step as usize != si {
                    return Err(NetError::Io(invalid("recovery-ack round header mismatch")));
                }
            }
        }
        Ok(NetReport {
            argmax: self.client.argmax_of(&q),
            logits: self.client.logits_of(&q),
            c2s_bytes: c2s,
            s2c_bytes: s2c,
            rounds,
            wall: t0.elapsed(),
        })
    }

    /// End the session politely without consuming the client (idempotent;
    /// used by engine wrappers on drop).
    pub fn close(&mut self) -> std::io::Result<()> {
        if self.said_bye {
            return Ok(());
        }
        self.said_bye = true;
        write_frame(&mut self.stream, wire::TAG_BYE, &self.session_id.to_le_bytes())
    }

    /// End the session politely.
    pub fn bye(mut self) -> std::io::Result<()> {
        self.close()
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::nn::Layer;
    use crate::phe::Params;
    use crate::protocol::cheetah::CheetahRunner;
    use crate::protocol::transport::read_frame;
    use std::collections::HashMap;
    use std::io::Read;

    fn tiny_net(seed: u64) -> Network {
        let mut net = Network {
            name: "serve-test".into(),
            input_shape: (1, 5, 5),
            layers: vec![Layer::conv(2, 3, 1, 1), Layer::relu(), Layer::fc(3)],
        };
        net.init_weights(seed);
        net
    }

    fn test_input(shift: f64) -> Tensor {
        Tensor::from_vec((0..25).map(|i| (i as f64 - 12.0) / 13.0 + shift).collect(), 1, 5, 5)
    }

    /// One session, repeated queries: results are bit-identical to the
    /// in-process runner, and the cached offline material is reused.
    ///
    /// Seeding note: recovery requantization rounds *exact-tie* values
    /// toward the blind's sign, so bit-exactness holds between runs with
    /// the same server blinding seed. The pool is disabled here so the
    /// single session deterministically gets engine seed `cfg.seed`,
    /// matching the reference runner's server seed.
    #[test]
    fn session_reuse_is_bit_exact_vs_in_process_runner() {
        let ctx = Arc::new(Context::new(Params::default_params()));
        let plan = ScalePlan::default_plan();
        let net = tiny_net(21);

        let mut runner =
            CheetahRunner::new(ctx.clone(), net.clone(), plan, 0.0, 99).expect("valid network");
        runner.run_offline();
        let want_a = runner.infer(&test_input(0.0));
        let want_b = runner.infer(&test_input(0.05));

        let server = SecureServer::serve(
            ctx.clone(),
            net,
            plan,
            "127.0.0.1:0",
            SecureConfig {
                workers: 2,
                seed: Some(99),
                pool: PoolConfig::disabled(),
                ..Default::default()
            },
        )
        .unwrap();
        let mut client = CheetahNetClient::connect(ctx.clone(), plan, &server.addr, 4242).unwrap();
        let got_a = client.infer(&test_input(0.0)).unwrap();
        let got_b = client.infer(&test_input(0.05)).unwrap();
        assert_eq!(got_a.logits, want_a.logits, "query 1 diverged from in-process runner");
        assert_eq!(got_b.logits, want_b.logits, "query 2 diverged from in-process runner");
        assert_eq!(got_a.argmax, want_a.argmax);
        assert!(got_a.rounds >= 3, "expected multiple round trips, got {}", got_a.rounds);
        assert!(got_a.c2s_bytes > 0 && got_a.s2c_bytes > 0);
        client.bye().unwrap();

        let m = server.metrics.summary();
        assert_eq!(m.requests, 2, "two completed secure queries should be metered");
        server.shutdown();
    }

    /// A network the protocol cannot express must be rejected when the
    /// server is configured — typed error, no worker-thread panic later.
    #[test]
    fn malformed_network_is_a_bind_time_error() {
        let ctx = Arc::new(Context::new(Params::default_params()));
        let bad = Network {
            name: "relu-first".into(),
            input_shape: (1, 4, 4),
            layers: vec![Layer::relu(), Layer::fc(2)],
        };
        let err = SecureServer::serve(
            ctx,
            bad,
            ScalePlan::default_plan(),
            "127.0.0.1:0",
            SecureConfig::default(),
        )
        .err()
        .expect("malformed network must not serve");
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidInput);
        assert!(err.to_string().contains("layer order"), "{err}");
    }

    /// `SecureConfig::params` rebuilds the serving context: a client on the
    /// chosen set completes the handshake and a full query, while one still
    /// on the default set is refused by the parameter fingerprint.
    #[test]
    fn secure_config_params_rebuilds_serving_context() {
        let default_ctx = Arc::new(Context::new(Params::default_params()));
        let wide = Params::new(4096, 26);
        let plan = ScalePlan::default_plan();
        let server = SecureServer::serve(
            default_ctx.clone(),
            tiny_net(9),
            plan,
            "127.0.0.1:0",
            SecureConfig {
                seed: Some(41),
                pool: PoolConfig::disabled(),
                params: crate::plan::ParamsChoice::Explicit(wide),
                ..Default::default()
            },
        )
        .unwrap();
        let err = CheetahNetClient::connect(default_ctx, plan, &server.addr, 70)
            .err()
            .expect("default-parameter client must be refused");
        assert!(err.to_string().contains("fingerprint"), "{err}");
        let wide_ctx = Arc::new(Context::new(wide));
        let mut client = CheetahNetClient::connect(wide_ctx, plan, &server.addr, 71).unwrap();
        let rep = client.infer(&test_input(0.0)).unwrap();
        assert_eq!(rep.logits.len(), 3);
        client.bye().unwrap();
        server.shutdown();
    }

    #[test]
    fn bad_hello_gets_error_frame() {
        let ctx = Arc::new(Context::new(Params::default_params()));
        let server = SecureServer::serve(
            ctx.clone(),
            tiny_net(3),
            ScalePlan::default_plan(),
            "127.0.0.1:0",
            SecureConfig { pool: PoolConfig::disabled(), ..Default::default() },
        )
        .unwrap();
        let mut stream = TcpStream::connect(server.addr).unwrap();
        write_frame(&mut stream, wire::TAG_HELLO, &[0xde, 0xad, 0xbe, 0xef, 0, 0]).unwrap();
        let (tag, payload) = read_frame(&mut stream).unwrap();
        assert_eq!(tag, wire::TAG_ERROR);
        let (_, code, _) = wire::decode_error(&payload).unwrap();
        assert_eq!(code, wire::ERR_UNSUPPORTED);
        server.shutdown();
    }

    /// The `STATS` admin frame serves a schema-valid snapshot mid-session,
    /// and (with obs on) the serve-layer counters it carries reflect the
    /// queries that ran before it.
    #[test]
    fn stats_frame_serves_live_snapshot() {
        let ctx = Arc::new(Context::new(Params::default_params()));
        let plan = ScalePlan::default_plan();
        let server = SecureServer::serve(
            ctx.clone(),
            tiny_net(8),
            plan,
            "127.0.0.1:0",
            SecureConfig {
                seed: Some(11),
                pool: PoolConfig::disabled(),
                ..Default::default()
            },
        )
        .unwrap();
        let mut client = CheetahNetClient::connect(ctx, plan, &server.addr, 77).unwrap();
        client.infer(&test_input(0.0)).unwrap();
        let doc = client.stats_json().unwrap();
        let snap = crate::obs::Snapshot::from_json(&doc).expect("STATS body must parse");
        #[cfg(not(feature = "obs-off"))]
        {
            let rounds = snap.get("serve.rounds").expect("serve.rounds registered");
            assert!(rounds.value >= 3, "one query is ≥3 rounds, got {}", rounds.value);
            let q = snap.get("serve.query").expect("serve.query registered");
            assert!(q.hist.as_ref().unwrap().count >= 1);
        }
        #[cfg(feature = "obs-off")]
        assert!(snap.metrics.is_empty());
        // The session survives the admin frame: a second query still works.
        client.infer(&test_input(0.05)).unwrap();
        client.bye().unwrap();
        server.shutdown();
    }

    /// The reactor front is protocol- and bit-identical to the threads
    /// front: pinned seeds, sequential session setup, then concurrent
    /// queries — per-session logits must match exactly at 2 and at 64
    /// concurrent sessions.
    ///
    /// Sequential connects pin the engine-seed assignment order (`base`,
    /// `base+1`, …, pool disabled) so session `k` gets the same blinding
    /// material on both fronts; the queries themselves then run fully
    /// concurrently.
    #[cfg(unix)]
    #[test]
    fn reactor_matches_threads_front_bit_exactly() {
        let ctx = Arc::new(Context::new(Params::default_params()));
        let plan = ScalePlan::default_plan();
        let net = tiny_net(13);
        for &n_sessions in &[2usize, 64] {
            let mut per_front: Vec<Vec<Vec<f64>>> = Vec::new();
            for &reactor in &[false, true] {
                let server = SecureServer::serve(
                    ctx.clone(),
                    net.clone(),
                    plan,
                    "127.0.0.1:0",
                    SecureConfig {
                        workers: 2,
                        seed: Some(501),
                        pool: PoolConfig::disabled(),
                        reactor,
                        ..Default::default()
                    },
                )
                .unwrap();
                let mut clients: Vec<CheetahNetClient> = (0..n_sessions)
                    .map(|k| {
                        let seed = 9000 + k as u64;
                        CheetahNetClient::connect(ctx.clone(), plan, &server.addr, seed).unwrap()
                    })
                    .collect();
                assert_eq!(server.session_count(), n_sessions);
                let logits: Vec<Vec<f64>> = std::thread::scope(|s| {
                    let handles: Vec<_> = clients
                        .iter_mut()
                        .enumerate()
                        .map(|(k, c)| {
                            s.spawn(move || c.infer(&test_input(k as f64 * 0.01)).unwrap().logits)
                        })
                        .collect();
                    handles.into_iter().map(|h| h.join().unwrap()).collect()
                });
                for c in &mut clients {
                    c.close().unwrap();
                }
                server.shutdown();
                per_front.push(logits);
            }
            assert_eq!(per_front[0], per_front[1], "fronts diverged at {n_sessions} sessions");
        }
    }

    /// With a lowered fd ulimit (CI: `ulimit -n 256`), the reactor sheds
    /// fd exhaustion gracefully: accepting pauses (counted in
    /// `serve.reactor.accept_stalls`) instead of busy-spinning or dying,
    /// and serving resumes once fds free up. Opt-in via
    /// `CHEETAH_FD_LIMIT_TEST` because it deliberately exhausts the
    /// process fd table (CI runs it alone, single-threaded).
    #[cfg(all(unix, not(feature = "obs-off")))]
    #[test]
    fn reactor_sheds_emfile_and_resumes_accepting() {
        if std::env::var("CHEETAH_FD_LIMIT_TEST").is_err() {
            eprintln!("skipping: set CHEETAH_FD_LIMIT_TEST=1 (under a low `ulimit -n`) to run");
            return;
        }
        let ctx = Arc::new(Context::new(Params::default_params()));
        let plan = ScalePlan::default_plan();
        let server = SecureServer::serve(
            ctx.clone(),
            tiny_net(6),
            plan,
            "127.0.0.1:0",
            SecureConfig {
                seed: Some(31),
                pool: PoolConfig::disabled(),
                reactor: true,
                ..Default::default()
            },
        )
        .unwrap();
        let stalls = || {
            let snap = crate::obs::snapshot();
            snap.get("serve.reactor.accept_stalls").map(|m| m.value).unwrap_or(0)
        };
        let base = stalls();

        // Exhaust the fd table: raw connects first (each pins fds on both
        // ends of this process), then /dev/null handles for the remainder.
        let mut flood = Vec::new();
        while let Ok(s) = TcpStream::connect(server.addr) {
            flood.push(s);
            if flood.len() > 4096 {
                break; // ulimit not actually low; the cap path still stalls
            }
        }
        let mut nulls = Vec::new();
        while let Ok(f) = std::fs::File::open("/dev/null") {
            nulls.push(f);
            if nulls.len() > 4096 {
                break;
            }
        }
        // Free exactly one fd so one more connect can park in the kernel
        // backlog while the server's accept still fails with EMFILE.
        drop(nulls.pop());
        let parked = TcpStream::connect(server.addr);

        let t0 = Instant::now();
        while stalls() <= base {
            assert!(t0.elapsed() < Duration::from_secs(10), "no accept stall recorded");
            std::thread::sleep(Duration::from_millis(10));
        }

        // Free the fds: accepting must resume and serving must work again.
        drop(parked);
        drop(flood);
        drop(nulls);
        let t0 = Instant::now();
        let mut client = loop {
            match CheetahNetClient::connect(ctx.clone(), plan, &server.addr, 77) {
                Ok(c) => break c,
                Err(_) => {
                    assert!(t0.elapsed() < Duration::from_secs(10), "accept never resumed");
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        };
        client.infer(&test_input(0.0)).unwrap();
        client.bye().unwrap();
        server.shutdown();
    }

    #[test]
    fn unknown_tag_gets_error_frame() {
        let ctx = Arc::new(Context::new(Params::default_params()));
        let server = SecureServer::serve(
            ctx.clone(),
            tiny_net(4),
            ScalePlan::default_plan(),
            "127.0.0.1:0",
            SecureConfig { pool: PoolConfig::disabled(), ..Default::default() },
        )
        .unwrap();
        let mut stream = TcpStream::connect(server.addr).unwrap();
        write_frame(&mut stream, 0x77, b"junk").unwrap();
        let (tag, _) = read_frame(&mut stream).unwrap();
        assert_eq!(tag, wire::TAG_ERROR);
        server.shutdown();
    }

    #[test]
    fn out_of_order_round_kills_session_with_error() {
        let ctx = Arc::new(Context::new(Params::default_params()));
        let plan = ScalePlan::default_plan();
        let server = SecureServer::serve(
            ctx.clone(),
            tiny_net(5),
            plan,
            "127.0.0.1:0",
            SecureConfig { pool: PoolConfig::disabled(), ..Default::default() },
        )
        .unwrap();
        // Complete a real handshake to obtain a session id…
        let mut stream = TcpStream::connect(server.addr).unwrap();
        write_frame(&mut stream, wire::TAG_HELLO, &wire::encode_hello()).unwrap();
        let (tag, payload) = read_frame(&mut stream).unwrap();
        assert_eq!(tag, wire::TAG_HELLO_OK);
        let hello = wire::decode_hello_ok(&payload).unwrap();
        loop {
            let (tag, _) = read_frame(&mut stream).unwrap();
            if tag == wire::TAG_OFFLINE_DONE {
                break;
            }
            assert_eq!(tag, wire::TAG_OFFLINE_IDS);
        }
        // …then violate the state machine: RECOVERY before any SHARES
        // (sealed — this handshake negotiated v2, so the checksum must be
        // valid for the violation to reach the state machine at all).
        let mut payload = wire::round_header(hello.session_id, 0);
        wire::encode_cts(&mut payload, &[]);
        wire::seal(wire::TAG_RECOVERY, &mut payload);
        write_frame(&mut stream, wire::TAG_RECOVERY, &payload).unwrap();
        let (tag, payload) = read_frame(&mut stream).unwrap();
        assert_eq!(tag, wire::TAG_ERROR);
        let (sid, code, msg) = wire::decode_error(&payload).unwrap();
        assert_eq!(sid, hello.session_id);
        assert_eq!(code, wire::ERR_PROTOCOL);
        assert!(msg.contains("protocol violation"), "{msg}");
        // The session is retired (the worker removes it just after sending
        // the error frame, hence the short grace loop); the server keeps
        // running for new sessions.
        let t0 = std::time::Instant::now();
        while server.session_count() != 0 {
            assert!(t0.elapsed() < Duration::from_secs(5), "session never removed");
            std::thread::sleep(Duration::from_millis(2));
        }
        server.shutdown();
    }

    /// Version negotiation: a v1 client (no checksum trailers) still
    /// completes the handshake — HELLO_OK mirrors version 1 and offline
    /// frames arrive unsealed (OFFLINE_DONE is exactly the 8-byte id).
    #[test]
    fn v1_hello_negotiates_unsealed_frames() {
        let ctx = Arc::new(Context::new(Params::default_params()));
        let server = SecureServer::serve(
            ctx.clone(),
            tiny_net(3),
            ScalePlan::default_plan(),
            "127.0.0.1:0",
            SecureConfig { pool: PoolConfig::disabled(), fault: None, ..Default::default() },
        )
        .unwrap();
        let mut stream = TcpStream::connect(server.addr).unwrap();
        write_frame(&mut stream, wire::TAG_HELLO, &wire::encode_hello_version(1)).unwrap();
        let (tag, payload) = read_frame(&mut stream).unwrap();
        assert_eq!(tag, wire::TAG_HELLO_OK);
        let hello = wire::decode_hello_ok(&payload).unwrap();
        assert_eq!(hello.version, 1, "server must mirror a v1 client's version");
        loop {
            let (tag, payload) = read_frame(&mut stream).unwrap();
            if tag == wire::TAG_OFFLINE_DONE {
                assert_eq!(payload.len(), 8, "v1 OFFLINE_DONE must carry no checksum trailer");
                break;
            }
            assert_eq!(tag, wire::TAG_OFFLINE_IDS);
        }
        write_frame(&mut stream, wire::TAG_BYE, &hello.session_id.to_le_bytes()).unwrap();
        server.shutdown();
    }

    /// v2 payload checksums catch in-flight corruption at the frame
    /// boundary: a flipped byte in a sealed round yields `ERR_CORRUPT`
    /// and retires only the offending session.
    #[test]
    fn corrupt_round_payload_gets_err_corrupt() {
        let ctx = Arc::new(Context::new(Params::default_params()));
        let server = SecureServer::serve(
            ctx.clone(),
            tiny_net(5),
            ScalePlan::default_plan(),
            "127.0.0.1:0",
            SecureConfig { pool: PoolConfig::disabled(), fault: None, ..Default::default() },
        )
        .unwrap();
        let mut stream = TcpStream::connect(server.addr).unwrap();
        write_frame(&mut stream, wire::TAG_HELLO, &wire::encode_hello()).unwrap();
        let (tag, payload) = read_frame(&mut stream).unwrap();
        assert_eq!(tag, wire::TAG_HELLO_OK);
        let hello = wire::decode_hello_ok(&payload).unwrap();
        assert_eq!(hello.version, wire::VERSION, "v2 handshake expected");
        loop {
            let (tag, _) = read_frame(&mut stream).unwrap();
            if tag == wire::TAG_OFFLINE_DONE {
                break;
            }
        }
        let mut payload = wire::round_header(hello.session_id, 0);
        wire::encode_cts(&mut payload, &[]);
        wire::seal(wire::TAG_SHARES, &mut payload);
        payload[13] ^= 0x40; // flip one bit inside the sealed body
        write_frame(&mut stream, wire::TAG_SHARES, &payload).unwrap();
        let (tag, payload) = read_frame(&mut stream).unwrap();
        assert_eq!(tag, wire::TAG_ERROR);
        let (sid, code, _) = wire::decode_error(&payload).unwrap();
        assert_eq!(sid, hello.session_id);
        assert_eq!(code, wire::ERR_CORRUPT);
        let t0 = Instant::now();
        while server.session_count() != 0 {
            assert!(t0.elapsed() < Duration::from_secs(5), "corrupt session never removed");
            std::thread::sleep(Duration::from_millis(2));
        }
        server.shutdown();
    }

    /// A server that accepts and then goes silent must not hang the
    /// client: the per-round deadline fails the attempt with the typed
    /// [`NetError::Deadline`].
    #[test]
    fn silent_server_trips_the_deadline_not_a_hang() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let silent = std::thread::spawn(move || {
            // Accept one connection, swallow the HELLO, reply nothing.
            if let Ok((mut s, _)) = listener.accept() {
                let _ = read_frame(&mut s);
                std::thread::sleep(Duration::from_millis(1500));
            }
        });
        let ctx = Arc::new(Context::new(Params::default_params()));
        let opts = NetClientOpts {
            deadline: Duration::from_millis(200),
            max_retries: 0,
            fault: None,
        };
        let t0 = Instant::now();
        let err =
            CheetahNetClient::connect_with(ctx, ScalePlan::default_plan(), &addr, 5, opts)
                .err()
                .expect("silent server must not yield a session");
        assert!(matches!(err, NetError::Deadline), "want Deadline, got {err}");
        assert!(t0.elapsed() < Duration::from_secs(5), "deadline did not bound the wait");
        silent.join().unwrap();
    }

    /// Worker panics are isolated: with panic injection at probability 1
    /// and a single worker, every HELLO job panics — each client gets a
    /// typed `ERR_INTERNAL` (not a hang, not a silent socket), the panic
    /// counter ticks, and the *same* worker keeps answering subsequent
    /// connections (no dead-worker wedge).
    #[test]
    fn worker_panics_are_isolated_and_typed() {
        let spec = FaultSpec::parse("seed=3,panic=1.0").expect("valid spec");
        let ctx = Arc::new(Context::new(Params::default_params()));
        let plan = ScalePlan::default_plan();
        #[cfg(not(feature = "obs-off"))]
        let panics_before =
            crate::obs::snapshot().get("serve.worker_panics").map(|m| m.value).unwrap_or(0);
        let server = SecureServer::serve(
            ctx.clone(),
            tiny_net(2),
            plan,
            "127.0.0.1:0",
            SecureConfig {
                workers: 1,
                seed: Some(5),
                pool: PoolConfig::disabled(),
                fault: Some(spec),
                ..Default::default()
            },
        )
        .unwrap();
        let opts =
            NetClientOpts { deadline: Duration::from_secs(5), max_retries: 0, fault: None };
        for k in 0..3u64 {
            let err = CheetahNetClient::connect_with(ctx.clone(), plan, &server.addr, 100 + k, opts)
                .err()
                .expect("handshake must fail on an injected worker panic");
            match err {
                NetError::Server { code, .. } => assert_eq!(code, wire::ERR_INTERNAL),
                other => panic!("want typed server error, got {other}"),
            }
        }
        #[cfg(not(feature = "obs-off"))]
        {
            let panics_after =
                crate::obs::snapshot().get("serve.worker_panics").map(|m| m.value).unwrap_or(0);
            assert!(panics_after >= panics_before + 3, "panic counter did not tick 3×");
        }
        assert_eq!(server.session_count(), 0, "panicked setups must leave no session");
        server.shutdown();
    }

    /// Reactor idle reaping: a connection that never sends a byte is
    /// reaped after `idle_timeout` — the client sees EOF and the eviction
    /// counter ticks.
    #[cfg(unix)]
    #[test]
    fn reactor_reaps_idle_connections() {
        let ctx = Arc::new(Context::new(Params::default_params()));
        let server = SecureServer::serve(
            ctx.clone(),
            tiny_net(6),
            ScalePlan::default_plan(),
            "127.0.0.1:0",
            SecureConfig {
                pool: PoolConfig::disabled(),
                reactor: true,
                idle_timeout: Duration::from_millis(200),
                fault: None,
                ..Default::default()
            },
        )
        .unwrap();
        #[cfg(not(feature = "obs-off"))]
        let idle_before = crate::obs::snapshot()
            .get("serve.reactor.idle_evictions")
            .map(|m| m.value)
            .unwrap_or(0);
        let mut stream = TcpStream::connect(server.addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let mut buf = [0u8; 1];
        match stream.read(&mut buf) {
            Ok(0) => {} // FIN from the reaper
            Ok(n) => panic!("unexpected {n} bytes from an idle connection"),
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::TimedOut | std::io::ErrorKind::WouldBlock
                ) =>
            {
                panic!("idle connection was never reaped")
            }
            Err(_) => {} // RST is an equally valid eviction signal
        }
        #[cfg(not(feature = "obs-off"))]
        {
            let idle_after = crate::obs::snapshot()
                .get("serve.reactor.idle_evictions")
                .map(|m| m.value)
                .unwrap_or(0);
            assert!(idle_after > idle_before, "idle eviction not counted");
        }
        server.shutdown();
    }

    /// Reactor slow-client eviction: a client that floods `STATS`
    /// requests without reading replies overruns `max_write_queue` and is
    /// evicted instead of buffered unboundedly.
    #[cfg(all(unix, not(feature = "obs-off")))]
    #[test]
    fn reactor_evicts_slow_clients_on_queue_overflow() {
        let ctx = Arc::new(Context::new(Params::default_params()));
        let server = SecureServer::serve(
            ctx.clone(),
            tiny_net(7),
            ScalePlan::default_plan(),
            "127.0.0.1:0",
            SecureConfig {
                pool: PoolConfig::disabled(),
                reactor: true,
                max_write_queue: 4096,
                fault: None,
                ..Default::default()
            },
        )
        .unwrap();
        let slow_before = crate::obs::snapshot()
            .get("serve.reactor.slow_evictions")
            .map(|m| m.value)
            .unwrap_or(0);
        let mut stream = TcpStream::connect(server.addr).unwrap();
        stream.set_write_timeout(Some(Duration::from_millis(100))).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        // Flood STATS (5-byte requests, KB-scale JSON replies) and never
        // read — the server's reply queue, not ours, must hit the bound.
        for _ in 0..20_000 {
            if write_frame(&mut stream, wire::TAG_STATS, &[]).is_err() {
                break; // evicted mid-flood
            }
        }
        // The eviction closes the socket under us: EOF or RST, never a
        // 10-second silence.
        let mut buf = [0u8; 4096];
        let t0 = Instant::now();
        loop {
            assert!(t0.elapsed() < Duration::from_secs(10), "no eviction observed");
            match stream.read(&mut buf) {
                Ok(0) => break, // FIN after the queue overran
                Ok(_) => {}     // drain whatever was already queued
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::TimedOut | std::io::ErrorKind::WouldBlock
                    ) =>
                {
                    panic!("slow client was never evicted")
                }
                Err(_) => break, // RST: queued replies discarded at close
            }
        }
        let slow_after = crate::obs::snapshot()
            .get("serve.reactor.slow_evictions")
            .map(|m| m.value)
            .unwrap_or(0);
        assert!(slow_after > slow_before, "slow eviction not counted");
        server.shutdown();
    }

    /// Sum of every `serve.faults.*` counter (0 when obs is compiled out).
    fn faults_fired() -> i64 {
        #[cfg(not(feature = "obs-off"))]
        {
            let snap = crate::obs::snapshot();
            return [
                "serve.faults.disconnect",
                "serve.faults.corrupt",
                "serve.faults.short",
                "serve.faults.delay",
                "serve.faults.reset",
                "serve.faults.panic",
            ]
            .iter()
            .filter_map(|n| snap.get(n).map(|m| m.value))
            .sum::<i64>();
        }
        #[cfg(feature = "obs-off")]
        0i64
    }

    /// The ISSUE-10 headline: N sessions × M queries with seeded faults on
    /// both sides of every socket and in the workers. Every query must end
    /// in logits bit-exact with a fault-free run (under the engine seed of
    /// whichever session served it — reconnects re-home queries onto fresh
    /// sessions) or a typed error; never a hang (per-round deadlines bound
    /// every wait, and the test harness timeout is the hang detector). The
    /// server must end clean: all sessions retired, drain completes.
    ///
    /// Knobs (CI chaos matrix): `CHEETAH_CHAOS_SESSIONS`,
    /// `CHEETAH_CHAOS_QUERIES`, `CHEETAH_CHAOS_SEED`.
    fn chaos_soak(reactor: bool) {
        let env_u64 = |name: &str, default: u64| {
            std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
        };
        let sessions = env_u64("CHEETAH_CHAOS_SESSIONS", 3) as usize;
        let queries = env_u64("CHEETAH_CHAOS_QUERIES", 3) as usize;
        let fault_seed = env_u64("CHEETAH_CHAOS_SEED", 7);
        let spec = FaultSpec::parse(&format!(
            "seed={fault_seed},disconnect=0.002,corrupt=0.002,short=0.1,delay=0.01:1,panic=0.02"
        ))
        .expect("valid fault spec");

        let ctx = Arc::new(Context::new(Params::default_params()));
        let plan = ScalePlan::default_plan();
        let net = tiny_net(17);
        let base_seed = 4242u64;
        let server = SecureServer::serve(
            ctx.clone(),
            net.clone(),
            plan,
            "127.0.0.1:0",
            SecureConfig {
                workers: 2,
                seed: Some(base_seed),
                pool: PoolConfig::disabled(),
                reactor,
                fault: Some(spec),
                ..Default::default()
            },
        )
        .unwrap();
        let fired_before = faults_fired();

        let opts = NetClientOpts {
            deadline: Duration::from_secs(2),
            max_retries: 4,
            fault: Some(spec),
        };
        type Outcome = (Tensor, Result<Vec<f64>, String>);
        let outcomes: Vec<Vec<Outcome>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..sessions)
                .map(|k| {
                    let ctx = ctx.clone();
                    let addr = server.addr;
                    s.spawn(move || {
                        let mut out: Vec<Outcome> = Vec::new();
                        // The handshake runs under fault injection too; a
                        // different client seed per attempt re-derives the
                        // client-side fault schedule (same-seed redials
                        // would replay the identical injected failure).
                        let mut client = None;
                        let mut connect_err = String::from("no attempt");
                        for attempt in 0..8u64 {
                            let seed = 9100 + k as u64 + attempt * 1000;
                            match CheetahNetClient::connect_with(
                                ctx.clone(),
                                plan,
                                &addr,
                                seed,
                                opts,
                            ) {
                                Ok(c) => {
                                    client = Some(c);
                                    break;
                                }
                                Err(e) => connect_err = e.to_string(), // typed
                            }
                        }
                        match client {
                            None => {
                                for q in 0..queries {
                                    let input =
                                        test_input(k as f64 * 0.01 + q as f64 * 0.001);
                                    out.push((
                                        input,
                                        Err(format!("connect failed: {connect_err}")),
                                    ));
                                }
                            }
                            Some(mut c) => {
                                for q in 0..queries {
                                    let input =
                                        test_input(k as f64 * 0.01 + q as f64 * 0.001);
                                    let res = match c.infer(&input) {
                                        Ok(rep) => Ok(rep.logits),
                                        Err(e) => Err(e.to_string()), // typed
                                    };
                                    out.push((input, res));
                                }
                                let _ = c.close();
                            }
                        }
                        out
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("chaos client thread")).collect()
        });

        // Bit-exactness: a successful query must match the fault-free
        // reference under SOME engine seed the server can have assigned
        // (`base, base+1, …`; reconnects allocate fresh sessions, hence
        // fresh seeds). Logits depend only on (input, engine seed) — see
        // the bit-exactness caveat in `protocol::cheetah`.
        let max_engines =
            (sessions * (1 + queries * (opts.max_retries as usize + 1)) + 8) as u64;
        let mut runners: HashMap<u64, CheetahRunner> = HashMap::new();
        let (mut ok_n, mut err_n) = (0usize, 0usize);
        for row in &outcomes {
            for (input, res) in row {
                match res {
                    Err(msg) => {
                        err_n += 1;
                        assert!(!msg.is_empty(), "errors must be typed, not silent");
                    }
                    Ok(logits) => {
                        ok_n += 1;
                        let matched = (0..max_engines).any(|off| {
                            let seed = base_seed + off;
                            let runner = runners.entry(seed).or_insert_with(|| {
                                let mut r = CheetahRunner::new(
                                    ctx.clone(),
                                    net.clone(),
                                    plan,
                                    0.0,
                                    seed,
                                )
                                .expect("valid network");
                                r.run_offline();
                                r
                            });
                            runner.infer(input).logits == *logits
                        });
                        assert!(
                            matched,
                            "chaos logits match no fault-free engine seed in [{}, {})",
                            base_seed,
                            base_seed + max_engines
                        );
                    }
                }
            }
        }
        assert_eq!(ok_n + err_n, sessions * queries, "every query must be accounted for");

        // Post-soak: the server ends clean — every session retired once
        // the clients are gone (BYE, EOF cleanup, or error-path removal).
        let t0 = Instant::now();
        while server.session_count() != 0 {
            assert!(t0.elapsed() < Duration::from_secs(10), "sessions leaked after soak");
            std::thread::sleep(Duration::from_millis(10));
        }
        server.shutdown();
        assert_eq!(server.session_count(), 0);
        #[cfg(not(feature = "obs-off"))]
        assert!(faults_fired() > fired_before, "no injected faults fired during the soak");
        #[cfg(feature = "obs-off")]
        let _ = fired_before;
    }

    #[test]
    fn chaos_soak_threads_front() {
        chaos_soak(false);
    }

    #[cfg(unix)]
    #[test]
    fn chaos_soak_reactor_front() {
        chaos_soak(true);
    }
}
