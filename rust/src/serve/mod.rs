//! Secure serving subsystem: the real CHEETAH two-party protocol
//! ([`crate::protocol::cheetah`]) over TCP for many concurrent clients.
//!
//! The paper's headline is ultra-fast *served* private inference; this
//! module is the serving layer that takes the protocol out of the
//! in-process [`crate::protocol::cheetah::CheetahRunner`] and onto real
//! sockets:
//!
//! * [`wire`] — the codec mapping each protocol round onto the
//!   length-prefixed frames of [`crate::protocol::transport`],
//! * [`session`] — per-client session ids and protocol state machines, so
//!   rounds from interleaved clients multiplex on one listener,
//! * [`precompute`] — the offline blinding pool (GAZELLE-style
//!   offline/online split): engines with fresh blinding material and
//!   encrypted indicators are built on background threads ahead of demand,
//! * [`SecureServer`] — listener + session-sticky worker pool with bounded
//!   queues; when a worker queue fills, the connection reader blocks and
//!   TCP flow control pushes back on the client (no unbounded buffering),
//! * [`CheetahNetClient`] — drives a full private inference over a socket.
//!
//! Threading model — two serving fronts behind one [`SecureServer`]
//! surface, selected by [`SecureConfig::reactor`]:
//!
//! * **Threads front** (default): one blocking accept thread (woken for
//!   shutdown via [`StoppableListener`]), one reader thread per
//!   connection, and a fixed worker pool — simple, but session count is
//!   capped by OS threads.
//! * **Reactor front** ([`reactor`], unix only): one event-loop thread
//!   multiplexes every connection over nonblocking sockets and an
//!   epoll/poll readiness poller, with incremental frame reassembly and
//!   per-connection write queues — thousands of concurrent sessions on a
//!   handful of threads, with idle reaping, slow-client eviction, and
//!   graceful `EMFILE` handling.
//!
//! Either way, rounds are routed to worker `session_id % workers`, so one
//! session's rounds execute in order while different sessions run in
//! parallel. Engines score through the stateless `&self` core (per-query
//! share state lives in the [`Session`]), so concurrent sessions never
//! contend on engine ownership; [`SecureConfig::threads`] pins the
//! compute fan-out of this server's workers and pool builders via
//! [`crate::par::with_threads`] — scoped, so no other engine or builder
//! in the process can resize it. Server metrics flow into
//! [`crate::coordinator::metrics`].
//!
//! Trust model: the server authenticates nothing (as in the paper — both
//! parties are semi-honest); malformed input from the network is rejected
//! with typed errors at every decode step, so a confused client can kill
//! its own session but not the server. Session ids come from a CSPRNG —
//! the unguessable id is what stops one client from forging rounds for
//! another's session. Sessions are owned by the connection that created
//! them and are retired when it closes (no leak on abrupt disconnect),
//! and server→client writes carry a timeout so a client that stops
//! reading cannot park a worker forever. The client, by contrast, trusts
//! the server it chose to connect to.

pub mod precompute;
#[cfg(unix)]
pub mod reactor;
pub mod session;
pub mod wire;

pub use precompute::{BlindingPool, PoolConfig, PoolStats};
pub use session::{Phase, Session, SessionRegistry};

use crate::coordinator::metrics::Metrics;
use crate::coordinator::server::{stop_accept_thread, LiveConns, StoppableListener};
use crate::fixed::ScalePlan;
use crate::nn::{Network, Tensor};
use crate::phe::Context;
use crate::protocol::cheetah::{CheetahClient, ProtocolSpec};
use crate::protocol::transport::{read_frame_limited, write_frame, DEFAULT_MAX_FRAME_LEN};
use crate::util::rng::ChaCha20Rng;
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Secure-server tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct SecureConfig {
    /// Obscuring-noise bound ε (0.0 = exact inference).
    pub epsilon: f64,
    /// Base seed for per-session engine blinding material. `None` (the
    /// default) draws the base seed from OS entropy — the blinds are the
    /// cryptographic obscuring mechanism, so they must be unpredictable in
    /// deployment. Set `Some(seed)` only for reproducible tests/benches.
    pub seed: Option<u64>,
    /// Protocol worker threads (round computation).
    pub workers: usize,
    /// Offline precomputation pool sizing.
    pub pool: PoolConfig,
    /// Bounded per-worker queue depth (backpressure threshold).
    pub queue_depth: usize,
    /// Maximum accepted frame payload (defense against corrupt lengths).
    pub max_frame: usize,
    /// Server→client write deadline. Threads front: socket write timeout,
    /// so a client that stops reading fails its replies instead of parking
    /// a worker. Reactor front: a connection whose queued output makes no
    /// progress for this long is evicted.
    pub write_timeout: Duration,
    /// Serve through the readiness reactor (one event-loop thread over
    /// nonblocking sockets; unix only — see [`reactor`]) instead of
    /// thread-per-connection. Protocol, wire format, and results are
    /// identical on both fronts.
    pub reactor: bool,
    /// Reactor front only: maximum concurrent connections. At the cap the
    /// listener pauses (counted in `serve.reactor.accept_stalls`) and
    /// resumes as connections close.
    pub max_sessions: usize,
    /// Reactor front only: connections idle this long (no inbound bytes,
    /// nothing queued or in flight) are reaped. Zero disables reaping.
    pub idle_timeout: Duration,
    /// Reactor front only: per-connection write-queue bound in bytes. A
    /// client that lets this much output pile up is evicted instead of
    /// buffered unboundedly (`0` = unbounded).
    pub max_write_queue: usize,
    /// Compute threads for the parallel runtime ([`crate::par`]):
    /// per-channel ciphertext streams, NTT batches, and pool builds all
    /// fan out over this many threads. `0` (the default) keeps the global
    /// setting (`CHEETAH_THREADS` env var, else `available_parallelism()`);
    /// `1` forces the sequential code path. **Scoped to this server**: a
    /// non-zero value pins the server's protocol workers and pool builders
    /// via [`crate::par::with_threads`] — other engines and servers in the
    /// process are unaffected, and nothing they build can resize this
    /// server's parallelism.
    pub threads: usize,
    /// RLWE parameter policy ([`crate::plan::ParamsChoice`]). `Default`
    /// keeps the context handed to [`SecureServer::serve`] untouched;
    /// `Explicit`/`Auto` rebuild the serving context when the chosen
    /// parameters differ (Auto runs the [`crate::plan`] planner against
    /// the hosted network — an infeasible network is a bind-time
    /// `InvalidInput` error, raised before any session exists). Clients
    /// must connect with a matching context (handshake fingerprint).
    pub params: crate::plan::ParamsChoice,
}

impl Default for SecureConfig {
    fn default() -> Self {
        Self {
            epsilon: 0.0,
            seed: None,
            workers: 2,
            pool: PoolConfig::default(),
            queue_depth: 8,
            max_frame: DEFAULT_MAX_FRAME_LEN,
            write_timeout: Duration::from_secs(30),
            reactor: false,
            max_sessions: 4096,
            idle_timeout: Duration::from_secs(300),
            max_write_queue: 64 << 20,
            threads: 0,
            params: crate::plan::ParamsChoice::Default,
        }
    }
}

/// State shared by every worker and reader thread.
struct ServeShared {
    ctx: Arc<Context>,
    net: Network,
    plan: ScalePlan,
    epsilon: f64,
    registry: Arc<SessionRegistry>,
    metrics: Arc<Metrics>,
    pool: Arc<BlindingPool>,
}

/// Per-connection state shared between the reader thread and the jobs it
/// dispatched: sessions created on this connection are retired when it
/// closes, so an abrupt disconnect (no `BYE`) cannot leak engines.
struct ConnState {
    closed: AtomicBool,
    sessions: Mutex<Vec<u64>>,
}

/// One unit of protocol work, routed to a session-sticky worker.
enum Job {
    /// Session setup: pop a prepared engine, register, ship the offline
    /// material (indicator ciphertexts) to the client.
    Hello { writer: Arc<Mutex<TcpStream>>, conn: Arc<ConnState> },
    /// An online round (`SHARES`, `RECOVERY`, or `BYE`).
    Round { session_id: u64, tag: u8, payload: Vec<u8>, writer: Arc<Mutex<TcpStream>> },
}

/// Where a handler's reply frames go: the threads front's write-locked
/// socket, or a connection's reactor write queue. `send` returns `false`
/// when the connection is gone — the handler stops and retires the
/// session it was serving. Frames are atomic per send; ordering across
/// sessions multiplexed on one connection is unspecified (each frame
/// carries its session id).
trait ReplySink {
    /// Ship one frame; `false` means the connection is dead.
    fn send(&mut self, tag: u8, payload: &[u8]) -> bool;
}

/// [`ReplySink`] over the threads front's shared, write-locked socket.
struct StreamSink<'a> {
    writer: &'a Arc<Mutex<TcpStream>>,
}

impl ReplySink for StreamSink<'_> {
    fn send(&mut self, tag: u8, payload: &[u8]) -> bool {
        match self.writer.lock() {
            Ok(mut w) => write_or_hangup(&mut w, tag, payload),
            Err(_) => false,
        }
    }
}

fn send_error(sink: &mut dyn ReplySink, sid: u64, code: u16, msg: &str) {
    let payload = wire::encode_error(sid, code, msg);
    let _ = sink.send(wire::TAG_ERROR, &payload);
}

/// A running secure server. All threads are joined by [`SecureServer::shutdown`].
pub struct SecureServer {
    /// The bound listen address.
    pub addr: SocketAddr,
    /// Serving metrics (completed queries, latency percentiles).
    pub metrics: Arc<Metrics>,
    registry: Arc<SessionRegistry>,
    pool: Arc<BlindingPool>,
    worker_threads: Mutex<Vec<JoinHandle<()>>>,
    front: Front,
}

/// The listener/dispatch machinery behind a [`SecureServer`] — one of the
/// two serving fronts ([`SecureConfig::reactor`] picks at bind time).
enum Front {
    /// Thread-per-connection: blocking readers + bounded worker queues.
    Threads {
        stop: Arc<AtomicBool>,
        accept_thread: Mutex<Option<JoinHandle<()>>>,
        conns: Arc<LiveConns>,
        worker_txs: Mutex<Option<Arc<Vec<SyncSender<Job>>>>>,
    },
    /// One readiness event loop multiplexing every connection (unix only).
    #[cfg(unix)]
    Reactor { handle: reactor::ReactorHandle },
}

impl SecureServer {
    /// Serve `net` through the CHEETAH protocol on `addr`. Returns once the
    /// listener is bound; serving continues on background threads. The
    /// shared [`Context`] is reference-counted across every worker, reader,
    /// and pool thread — no `'static` leak.
    pub fn serve(
        ctx: Arc<Context>,
        net: Network,
        plan: ScalePlan,
        addr: &str,
        cfg: SecureConfig,
    ) -> std::io::Result<SecureServer> {
        // Resolve the parameter policy before anything keyed on the context
        // exists (pool engines, fingerprints): `Auto` runs the static
        // planner against the hosted network, so an infeasible network is
        // refused here — never a garbage decrypt mid-session.
        let ctx = match cfg.params {
            crate::plan::ParamsChoice::Default => ctx,
            choice => {
                let (params, _) = choice
                    .resolve(&net)
                    .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidInput, e.to_string()))?;
                if ctx.params == params { ctx } else { Arc::new(Context::new(params)) }
            }
        };
        plan.check_fits(ctx.params.p);
        let metrics = Arc::new(Metrics::new());
        let registry = Arc::new(SessionRegistry::new());
        let base_seed = cfg
            .seed
            .unwrap_or_else(|| ChaCha20Rng::from_os_entropy().next_u64());
        // The pool validates the network → protocol-spec compilation once,
        // here: a malformed architecture is a bind-time error, never a
        // panic on a serving or builder thread.
        let pool = BlindingPool::start(
            ctx.clone(),
            net.clone(),
            plan,
            cfg.epsilon,
            base_seed,
            cfg.pool,
            cfg.threads,
        )
        .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidInput, e))?;
        let shared = Arc::new(ServeShared {
            ctx,
            net,
            plan,
            epsilon: cfg.epsilon,
            registry: registry.clone(),
            metrics: metrics.clone(),
            pool: pool.clone(),
        });

        if cfg.reactor {
            return serve_reactor(shared, metrics, registry, pool, addr, cfg);
        }

        let listener = StoppableListener::bind(addr)?;
        let local = listener.addr;
        let stop = listener.stop_flag();
        let n_workers = cfg.workers.max(1);
        let mut txs = Vec::with_capacity(n_workers);
        let mut worker_threads = Vec::with_capacity(n_workers);
        for _ in 0..n_workers {
            let (tx, rx) = sync_channel::<Job>(cfg.queue_depth.max(1));
            txs.push(tx);
            let shared = shared.clone();
            let threads = cfg.threads;
            // The per-server thread count rides the worker thread itself
            // (scoped, not global): every round this worker computes —
            // including inline engine builds on pool misses — fans out at
            // the server's configured width.
            worker_threads.push(std::thread::spawn(move || {
                crate::par::with_threads(threads, || worker_loop(rx, shared))
            }));
        }
        let txs = Arc::new(txs);

        let conns = LiveConns::new();
        let accept_thread = {
            let txs = txs.clone();
            let stop = stop.clone();
            let conns = conns.clone();
            let registry = registry.clone();
            let rr = Arc::new(AtomicU64::new(0));
            let max_frame = cfg.max_frame;
            let write_timeout = cfg.write_timeout;
            std::thread::spawn(move || {
                while let Some(stream) = listener.accept() {
                    stream.set_nodelay(true).ok();
                    let writer = match stream.try_clone() {
                        Ok(w) => {
                            w.set_write_timeout(Some(write_timeout)).ok();
                            Arc::new(Mutex::new(w))
                        }
                        Err(_) => continue,
                    };
                    let clone = match stream.try_clone() {
                        Ok(c) => c,
                        Err(_) => continue,
                    };
                    let txs = txs.clone();
                    let stop = stop.clone();
                    let rr = rr.clone();
                    let registry = registry.clone();
                    let jh = std::thread::spawn(move || {
                        read_loop(stream, writer, txs, rr, stop, max_frame, registry)
                    });
                    conns.track(clone, jh);
                }
            })
        };

        Ok(SecureServer {
            addr: local,
            metrics,
            registry,
            pool,
            worker_threads: Mutex::new(worker_threads),
            front: Front::Threads {
                stop,
                accept_thread: Mutex::new(Some(accept_thread)),
                conns,
                worker_txs: Mutex::new(Some(txs)),
            },
        })
    }

    /// Point-in-time blinding-pool counters.
    pub fn pool_stats(&self) -> PoolStats {
        self.pool.stats()
    }

    /// Block until the blinding pool has produced at least `n` engines
    /// (bench/ops warmup). Returns whether the target was reached in time.
    pub fn wait_pool_ready(&self, n: u64, timeout: Duration) -> bool {
        self.pool.wait_until_produced(n, timeout)
    }

    /// Number of live sessions.
    pub fn session_count(&self) -> usize {
        self.registry.len()
    }

    /// Stop accepting, close every live connection, and join the accept
    /// (or reactor), reader, worker, and pool threads. Idempotent.
    pub fn shutdown(&self) {
        match &self.front {
            Front::Threads { stop, accept_thread, conns, worker_txs } => {
                stop_accept_thread(stop, self.addr, accept_thread);
                // Closing the sockets unblocks readers parked in read_frame.
                conns.close_and_join();
                // Dropping the senders disconnects the worker queues.
                worker_txs.lock().unwrap().take();
            }
            // Joining the reactor thread drops its connections and worker
            // senders, which in turn disconnects the worker queues below.
            #[cfg(unix)]
            Front::Reactor { handle } => handle.shutdown(),
        }
        let workers: Vec<JoinHandle<()>> =
            self.worker_threads.lock().unwrap().drain(..).collect();
        for h in workers {
            let _ = h.join();
        }
        self.registry.clear();
        self.pool.shutdown();
    }
}

/// Bind and launch the [`reactor`] front (unix only — see
/// [`SecureConfig::reactor`]).
#[cfg(unix)]
fn serve_reactor(
    shared: Arc<ServeShared>,
    metrics: Arc<Metrics>,
    registry: Arc<SessionRegistry>,
    pool: Arc<BlindingPool>,
    addr: &str,
    cfg: SecureConfig,
) -> std::io::Result<SecureServer> {
    let listener = std::net::TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    let (handle, worker_threads) = reactor::spawn(listener, shared, cfg)?;
    Ok(SecureServer {
        addr: local,
        metrics,
        registry,
        pool,
        worker_threads: Mutex::new(worker_threads),
        front: Front::Reactor { handle },
    })
}

/// The reactor front needs readiness polling; refuse cleanly elsewhere.
#[cfg(not(unix))]
fn serve_reactor(
    _shared: Arc<ServeShared>,
    _metrics: Arc<Metrics>,
    _registry: Arc<SessionRegistry>,
    _pool: Arc<BlindingPool>,
    _addr: &str,
    _cfg: SecureConfig,
) -> std::io::Result<SecureServer> {
    Err(std::io::Error::new(
        std::io::ErrorKind::Unsupported,
        "SecureConfig::reactor requires a unix target (epoll/poll readiness)",
    ))
}

impl Drop for SecureServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Per-connection reader: frames in, jobs out. Blocking `send` into the
/// bounded worker queues is the backpressure point — a flooded server stops
/// reading and TCP pushes back on the sender. On exit (hangup, protocol
/// garbage, shutdown) every session created on this connection is retired.
fn read_loop(
    stream: TcpStream,
    writer: Arc<Mutex<TcpStream>>,
    txs: Arc<Vec<SyncSender<Job>>>,
    rr: Arc<AtomicU64>,
    stop: Arc<AtomicBool>,
    max_frame: usize,
    registry: Arc<SessionRegistry>,
) {
    let conn = Arc::new(ConnState {
        closed: AtomicBool::new(false),
        sessions: Mutex::new(Vec::new()),
    });
    read_frames(stream, &writer, &txs, &rr, &stop, max_frame, &conn);
    // The connection is gone: retire its sessions. A Hello still in flight
    // sees `closed` and retires its own session (see handle_hello).
    conn.closed.store(true, Ordering::SeqCst);
    for sid in conn.sessions.lock().unwrap().drain(..) {
        registry.remove(sid);
    }
}

fn read_frames(
    mut stream: TcpStream,
    writer: &Arc<Mutex<TcpStream>>,
    txs: &Arc<Vec<SyncSender<Job>>>,
    rr: &Arc<AtomicU64>,
    stop: &Arc<AtomicBool>,
    max_frame: usize,
    conn: &Arc<ConnState>,
) {
    loop {
        if stop.load(Ordering::SeqCst) {
            return;
        }
        let (tag, payload) = match read_frame_limited(&mut stream, max_frame) {
            Ok(f) => f,
            Err(_) => return, // peer hung up, oversized frame, or shutdown
        };
        crate::obs::add("serve.rx_bytes", payload.len() as u64 + 5);
        match tag {
            wire::TAG_HELLO => {
                if let Err(e) = wire::decode_hello(&payload) {
                    let mut sink = StreamSink { writer };
                    send_error(&mut sink, 0, wire::ERR_UNSUPPORTED, &e.to_string());
                    return;
                }
                let w = (rr.fetch_add(1, Ordering::Relaxed) as usize) % txs.len();
                let job = Job::Hello { writer: writer.clone(), conn: conn.clone() };
                if txs[w].send(job).is_err() {
                    return;
                }
            }
            wire::TAG_STATS => {
                // Admin introspection: answered inline from the reader (the
                // snapshot capture is lock-free, so this cannot stall rounds
                // queued behind it on a worker).
                let body = crate::obs::snapshot().to_json();
                if let Ok(mut w) = writer.lock() {
                    if !write_or_hangup(&mut w, wire::TAG_STATS_OK, body.as_bytes()) {
                        return;
                    }
                }
            }
            wire::TAG_SHARES | wire::TAG_RECOVERY | wire::TAG_BYE => {
                let sid = match wire::peek_session_id(&payload) {
                    Ok(s) => s,
                    Err(e) => {
                        let mut sink = StreamSink { writer };
                        send_error(&mut sink, 0, wire::ERR_PROTOCOL, &e.to_string());
                        return;
                    }
                };
                let w = (sid % txs.len() as u64) as usize;
                let job = Job::Round { session_id: sid, tag, payload, writer: writer.clone() };
                if txs[w].send(job).is_err() {
                    return;
                }
            }
            other => {
                let mut sink = StreamSink { writer };
                send_error(
                    &mut sink,
                    0,
                    wire::ERR_PROTOCOL,
                    &format!("unknown frame tag {other:#04x}"),
                );
                return;
            }
        }
    }
}

fn worker_loop(rx: Receiver<Job>, shared: Arc<ServeShared>) {
    for job in rx {
        match job {
            Job::Hello { writer, conn } => {
                let mut sink = StreamSink { writer: &writer };
                handle_hello(&shared, &mut sink, &conn);
            }
            Job::Round { session_id, tag, payload, writer } => {
                let mut sink = StreamSink { writer: &writer };
                handle_round(&shared, session_id, tag, &payload, &mut sink);
            }
        }
    }
}

/// A failed (or timed-out) reply write means the peer stopped reading or
/// the framing is now corrupt mid-stream: drop the whole connection so its
/// reader exits and the connection's sessions are retired.
fn write_or_hangup(w: &mut TcpStream, tag: u8, payload: &[u8]) -> bool {
    if write_frame(w, tag, payload).is_err() {
        let _ = w.shutdown(std::net::Shutdown::Both);
        return false;
    }
    crate::obs::add("serve.tx_bytes", payload.len() as u64 + 5);
    true
}

fn handle_hello(shared: &ServeShared, sink: &mut dyn ReplySink, conn: &Arc<ConnState>) {
    let engine = Arc::new(shared.pool.take());
    let (sid, session) = shared.registry.create(engine);
    // Tie the session to its connection; if the connection closed while we
    // were setting up, retire it immediately (the reader's sweep may have
    // already run).
    conn.sessions.lock().unwrap().push(sid);
    if conn.closed.load(Ordering::SeqCst) {
        shared.registry.remove(sid);
        return;
    }
    let session = session.lock().unwrap();
    let n_steps = session.engine.spec.steps.len();
    let hello_ok = wire::encode_hello_ok(
        sid,
        wire::plan_fingerprint(&shared.ctx.params, &shared.plan),
        shared.epsilon,
        n_steps as u32,
        &shared.net,
    );
    if !sink.send(wire::TAG_HELLO_OK, &hello_ok) {
        shared.registry.remove(sid);
        return;
    }
    // Ship the offline material: indicator ciphertexts for every
    // intermediate step (the last step has none — its result is revealed
    // obscured, the paper's f^OMI).
    for si in 0..n_steps.saturating_sub(1) {
        let (id1, id2) = session.engine.indicator_cts(si);
        let mut payload = wire::round_header(sid, si as u32);
        wire::encode_cts(&mut payload, id1);
        wire::encode_cts(&mut payload, id2);
        if !sink.send(wire::TAG_OFFLINE_IDS, &payload) {
            shared.registry.remove(sid);
            return;
        }
    }
    let _ = sink.send(wire::TAG_OFFLINE_DONE, &sid.to_le_bytes());
}

fn handle_round(
    shared: &ServeShared,
    session_id: u64,
    tag: u8,
    payload: &[u8],
    sink: &mut dyn ReplySink,
) {
    if tag == wire::TAG_BYE {
        shared.registry.remove(session_id);
        return;
    }
    let Some(session) = shared.registry.get(session_id) else {
        send_error(sink, session_id, wire::ERR_PROTOCOL, "unknown session");
        return;
    };
    let mut r = wire::ByteReader::new(payload);
    let decoded = wire::read_round_header(&mut r)
        .and_then(|(_, step)| wire::decode_cts(&shared.ctx, &mut r).map(|cts| (step, cts)));
    let (step, cts) = match decoded {
        Ok(d) => d,
        Err(e) => {
            send_error(sink, session_id, wire::ERR_PROTOCOL, &e.to_string());
            shared.registry.remove(session_id);
            return;
        }
    };
    let result = {
        let mut s = session.lock().unwrap();
        match tag {
            wire::TAG_SHARES => s
                .on_shares(step as usize, &cts, &shared.metrics)
                .map(|p| (wire::TAG_PRODUCTS, p)),
            _ => s.on_recovery(step as usize, &cts).map(|p| (wire::TAG_RECOVERY_OK, p)),
        }
    };
    match result {
        Ok((reply_tag, reply)) => {
            let _ = sink.send(reply_tag, &reply);
        }
        Err(violation) => {
            send_error(sink, session_id, wire::ERR_PROTOCOL, &violation.to_string());
            shared.registry.remove(session_id);
        }
    }
}

// ---------------------------------------------------------------------------
// Client
// ---------------------------------------------------------------------------

/// Client-side accounting for one secure inference over the wire.
#[derive(Clone, Debug, Default)]
pub struct NetReport {
    /// Predicted class (last maximum of the logits).
    pub argmax: usize,
    /// Dequantized logits.
    pub logits: Vec<f64>,
    /// Exact client→server bytes put on the wire (frame headers included).
    pub c2s_bytes: u64,
    /// Exact server→client bytes (frame headers included).
    pub s2c_bytes: u64,
    /// Round trips (SHARES→PRODUCTS and RECOVERY→RECOVERY_OK pairs).
    pub rounds: u64,
    /// End-to-end wall time of the query, wire included.
    pub wall: Duration,
}

/// Drives a full CHEETAH inference over a real socket against a
/// [`SecureServer`]. The constructor performs the handshake (parameter
/// fingerprint check, architecture download, offline indicator transfer);
/// [`CheetahNetClient::infer`] then runs queries on the cached session.
pub struct CheetahNetClient {
    ctx: Arc<Context>,
    stream: TcpStream,
    /// The server-assigned session id.
    pub session_id: u64,
    client: CheetahClient,
    last_step: usize,
    max_frame: usize,
    /// Bytes received during the offline phase (handshake + indicators),
    /// frame headers included — the networked "offline communication".
    offline_bytes: u64,
    said_bye: bool,
}

fn invalid(msg: &str) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, msg.to_string())
}

fn error_frame_to_io(payload: &[u8]) -> std::io::Error {
    match wire::decode_error(payload) {
        Ok((_, code, msg)) => std::io::Error::other(format!("server error {code}: {msg}")),
        Err(e) => e.into(),
    }
}

impl CheetahNetClient {
    /// Connect and complete the offline phase. `ctx`/`plan` must match the
    /// server's (verified via the handshake fingerprint); `seed` drives the
    /// client's key generation and share randomness.
    pub fn connect(
        ctx: Arc<Context>,
        plan: ScalePlan,
        addr: &SocketAddr,
        seed: u64,
    ) -> std::io::Result<Self> {
        let max_frame = DEFAULT_MAX_FRAME_LEN;
        let mut stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        write_frame(&mut stream, wire::TAG_HELLO, &wire::encode_hello())?;
        let (tag, payload) = read_frame_limited(&mut stream, max_frame)?;
        let mut offline_bytes = payload.len() as u64 + 5;
        if tag == wire::TAG_ERROR {
            return Err(error_frame_to_io(&payload));
        }
        if tag != wire::TAG_HELLO_OK {
            return Err(invalid("expected HELLO_OK"));
        }
        let hello = wire::decode_hello_ok(&payload)?;
        if hello.fingerprint != wire::plan_fingerprint(&ctx.params, &plan) {
            return Err(invalid(
                "server/client parameter or scale-plan mismatch (fingerprint)",
            ));
        }
        // A server advertising an architecture the protocol cannot express
        // is a typed connect error, not a client panic.
        let spec = ProtocolSpec::compile(&hello.arch)
            .map_err(|e| invalid(&format!("server architecture rejected: {e}")))?;
        let n_steps = spec.steps.len();
        if n_steps != hello.n_steps as usize {
            return Err(invalid("handshake step count disagrees with architecture"));
        }
        let mut client = CheetahClient::new(ctx.clone(), spec, plan, seed);

        // Offline phase: install the indicator ciphertexts per step.
        loop {
            let (tag, payload) = read_frame_limited(&mut stream, max_frame)?;
            offline_bytes += payload.len() as u64 + 5;
            match tag {
                wire::TAG_OFFLINE_IDS => {
                    let mut r = wire::ByteReader::new(&payload);
                    let (_, step) = wire::read_round_header(&mut r)?;
                    if step as usize >= n_steps {
                        return Err(invalid("offline indicators for unknown step"));
                    }
                    let id1 = wire::decode_cts(&ctx, &mut r)?;
                    let id2 = wire::decode_cts(&ctx, &mut r)?;
                    client.install_indicators(step as usize, id1, id2);
                }
                wire::TAG_OFFLINE_DONE => break,
                wire::TAG_ERROR => return Err(error_frame_to_io(&payload)),
                _ => return Err(invalid("unexpected frame during offline phase")),
            }
        }
        Ok(Self {
            ctx,
            stream,
            session_id: hello.session_id,
            client,
            last_step: n_steps - 1,
            max_frame,
            offline_bytes,
            said_bye: false,
        })
    }

    /// Bytes shipped to this client during the offline phase (handshake +
    /// indicator ciphertexts, frame headers included).
    pub fn offline_bytes(&self) -> u64 {
        self.offline_bytes
    }

    /// Fetch the server's live telemetry snapshot over the `STATS` admin
    /// frame. Returns the raw JSON document (parse with
    /// [`crate::obs::Snapshot::from_json`]). Must not be interleaved with
    /// an in-flight [`CheetahNetClient::infer`] round.
    pub fn stats_json(&mut self) -> std::io::Result<String> {
        write_frame(&mut self.stream, wire::TAG_STATS, &[])?;
        let payload = self.read_expect(wire::TAG_STATS_OK)?;
        String::from_utf8(payload)
            .map_err(|_| invalid("stats snapshot is not valid UTF-8"))
    }

    fn read_expect(&mut self, want: u8) -> std::io::Result<Vec<u8>> {
        let (tag, payload) = read_frame_limited(&mut self.stream, self.max_frame)?;
        if tag == wire::TAG_ERROR {
            return Err(error_frame_to_io(&payload));
        }
        if tag != want {
            return Err(invalid("unexpected frame tag"));
        }
        Ok(payload)
    }

    /// Run one private inference end to end over the socket.
    pub fn infer(&mut self, input: &Tensor) -> std::io::Result<NetReport> {
        let t0 = Instant::now();
        self.client.begin_query(input);
        let n = self.ctx.params.n;
        let (mut c2s, mut s2c, mut rounds) = (0u64, 0u64, 0u64);
        for si in 0..=self.last_step {
            // C → S: encrypted transformed share.
            let cts = self.client.step_send(si);
            let mut payload = wire::round_header(self.session_id, si as u32);
            wire::encode_cts(&mut payload, &cts);
            c2s += payload.len() as u64 + 5;
            rounds += 1;
            write_frame(&mut self.stream, wire::TAG_SHARES, &payload)?;

            // S → C: obscured products.
            let resp = self.read_expect(wire::TAG_PRODUCTS)?;
            s2c += resp.len() as u64 + 5;
            let mut r = wire::ByteReader::new(&resp);
            let (sid, step) = wire::read_round_header(&mut r)?;
            if sid != self.session_id || step as usize != si {
                return Err(invalid("products round header mismatch"));
            }
            let out_cts = wire::decode_cts(&self.ctx, &mut r)?;
            if out_cts.len() != self.client.spec.steps[si].linear.num_out_cts(n) {
                return Err(invalid("wrong obscured-product ciphertext count"));
            }

            // C → S: nonlinear recovery (intermediate steps only).
            if let Some(rec) = self.client.step_receive(si, &out_cts) {
                let mut payload = wire::round_header(self.session_id, si as u32);
                wire::encode_cts(&mut payload, &rec);
                c2s += payload.len() as u64 + 5;
                rounds += 1;
                write_frame(&mut self.stream, wire::TAG_RECOVERY, &payload)?;
                let ok = self.read_expect(wire::TAG_RECOVERY_OK)?;
                s2c += ok.len() as u64 + 5;
                let mut r = wire::ByteReader::new(&ok);
                let (sid, step) = wire::read_round_header(&mut r)?;
                if sid != self.session_id || step as usize != si {
                    return Err(invalid("recovery-ack round header mismatch"));
                }
            }
        }
        Ok(NetReport {
            argmax: self.client.argmax(),
            logits: self.client.logits(),
            c2s_bytes: c2s,
            s2c_bytes: s2c,
            rounds,
            wall: t0.elapsed(),
        })
    }

    /// End the session politely without consuming the client (idempotent;
    /// used by engine wrappers on drop).
    pub fn close(&mut self) -> std::io::Result<()> {
        if self.said_bye {
            return Ok(());
        }
        self.said_bye = true;
        write_frame(&mut self.stream, wire::TAG_BYE, &self.session_id.to_le_bytes())
    }

    /// End the session politely.
    pub fn bye(mut self) -> std::io::Result<()> {
        self.close()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Layer;
    use crate::phe::Params;
    use crate::protocol::cheetah::CheetahRunner;
    use crate::protocol::transport::read_frame;

    fn tiny_net(seed: u64) -> Network {
        let mut net = Network {
            name: "serve-test".into(),
            input_shape: (1, 5, 5),
            layers: vec![Layer::conv(2, 3, 1, 1), Layer::relu(), Layer::fc(3)],
        };
        net.init_weights(seed);
        net
    }

    fn test_input(shift: f64) -> Tensor {
        Tensor::from_vec((0..25).map(|i| (i as f64 - 12.0) / 13.0 + shift).collect(), 1, 5, 5)
    }

    /// One session, repeated queries: results are bit-identical to the
    /// in-process runner, and the cached offline material is reused.
    ///
    /// Seeding note: recovery requantization rounds *exact-tie* values
    /// toward the blind's sign, so bit-exactness holds between runs with
    /// the same server blinding seed. The pool is disabled here so the
    /// single session deterministically gets engine seed `cfg.seed`,
    /// matching the reference runner's server seed.
    #[test]
    fn session_reuse_is_bit_exact_vs_in_process_runner() {
        let ctx = Arc::new(Context::new(Params::default_params()));
        let plan = ScalePlan::default_plan();
        let net = tiny_net(21);

        let mut runner =
            CheetahRunner::new(ctx.clone(), net.clone(), plan, 0.0, 99).expect("valid network");
        runner.run_offline();
        let want_a = runner.infer(&test_input(0.0));
        let want_b = runner.infer(&test_input(0.05));

        let server = SecureServer::serve(
            ctx.clone(),
            net,
            plan,
            "127.0.0.1:0",
            SecureConfig {
                workers: 2,
                seed: Some(99),
                pool: PoolConfig::disabled(),
                ..Default::default()
            },
        )
        .unwrap();
        let mut client = CheetahNetClient::connect(ctx.clone(), plan, &server.addr, 4242).unwrap();
        let got_a = client.infer(&test_input(0.0)).unwrap();
        let got_b = client.infer(&test_input(0.05)).unwrap();
        assert_eq!(got_a.logits, want_a.logits, "query 1 diverged from in-process runner");
        assert_eq!(got_b.logits, want_b.logits, "query 2 diverged from in-process runner");
        assert_eq!(got_a.argmax, want_a.argmax);
        assert!(got_a.rounds >= 3, "expected multiple round trips, got {}", got_a.rounds);
        assert!(got_a.c2s_bytes > 0 && got_a.s2c_bytes > 0);
        client.bye().unwrap();

        let m = server.metrics.summary();
        assert_eq!(m.requests, 2, "two completed secure queries should be metered");
        server.shutdown();
    }

    /// A network the protocol cannot express must be rejected when the
    /// server is configured — typed error, no worker-thread panic later.
    #[test]
    fn malformed_network_is_a_bind_time_error() {
        let ctx = Arc::new(Context::new(Params::default_params()));
        let bad = Network {
            name: "relu-first".into(),
            input_shape: (1, 4, 4),
            layers: vec![Layer::relu(), Layer::fc(2)],
        };
        let err = SecureServer::serve(
            ctx,
            bad,
            ScalePlan::default_plan(),
            "127.0.0.1:0",
            SecureConfig::default(),
        )
        .err()
        .expect("malformed network must not serve");
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidInput);
        assert!(err.to_string().contains("layer order"), "{err}");
    }

    /// `SecureConfig::params` rebuilds the serving context: a client on the
    /// chosen set completes the handshake and a full query, while one still
    /// on the default set is refused by the parameter fingerprint.
    #[test]
    fn secure_config_params_rebuilds_serving_context() {
        let default_ctx = Arc::new(Context::new(Params::default_params()));
        let wide = Params::new(4096, 26);
        let plan = ScalePlan::default_plan();
        let server = SecureServer::serve(
            default_ctx.clone(),
            tiny_net(9),
            plan,
            "127.0.0.1:0",
            SecureConfig {
                seed: Some(41),
                pool: PoolConfig::disabled(),
                params: crate::plan::ParamsChoice::Explicit(wide),
                ..Default::default()
            },
        )
        .unwrap();
        let err = CheetahNetClient::connect(default_ctx, plan, &server.addr, 70)
            .err()
            .expect("default-parameter client must be refused");
        assert!(err.to_string().contains("fingerprint"), "{err}");
        let wide_ctx = Arc::new(Context::new(wide));
        let mut client = CheetahNetClient::connect(wide_ctx, plan, &server.addr, 71).unwrap();
        let rep = client.infer(&test_input(0.0)).unwrap();
        assert_eq!(rep.logits.len(), 3);
        client.bye().unwrap();
        server.shutdown();
    }

    #[test]
    fn bad_hello_gets_error_frame() {
        let ctx = Arc::new(Context::new(Params::default_params()));
        let server = SecureServer::serve(
            ctx.clone(),
            tiny_net(3),
            ScalePlan::default_plan(),
            "127.0.0.1:0",
            SecureConfig { pool: PoolConfig::disabled(), ..Default::default() },
        )
        .unwrap();
        let mut stream = TcpStream::connect(server.addr).unwrap();
        write_frame(&mut stream, wire::TAG_HELLO, &[0xde, 0xad, 0xbe, 0xef, 0, 0]).unwrap();
        let (tag, payload) = read_frame(&mut stream).unwrap();
        assert_eq!(tag, wire::TAG_ERROR);
        let (_, code, _) = wire::decode_error(&payload).unwrap();
        assert_eq!(code, wire::ERR_UNSUPPORTED);
        server.shutdown();
    }

    /// The `STATS` admin frame serves a schema-valid snapshot mid-session,
    /// and (with obs on) the serve-layer counters it carries reflect the
    /// queries that ran before it.
    #[test]
    fn stats_frame_serves_live_snapshot() {
        let ctx = Arc::new(Context::new(Params::default_params()));
        let plan = ScalePlan::default_plan();
        let server = SecureServer::serve(
            ctx.clone(),
            tiny_net(8),
            plan,
            "127.0.0.1:0",
            SecureConfig {
                seed: Some(11),
                pool: PoolConfig::disabled(),
                ..Default::default()
            },
        )
        .unwrap();
        let mut client = CheetahNetClient::connect(ctx, plan, &server.addr, 77).unwrap();
        client.infer(&test_input(0.0)).unwrap();
        let doc = client.stats_json().unwrap();
        let snap = crate::obs::Snapshot::from_json(&doc).expect("STATS body must parse");
        #[cfg(not(feature = "obs-off"))]
        {
            let rounds = snap.get("serve.rounds").expect("serve.rounds registered");
            assert!(rounds.value >= 3, "one query is ≥3 rounds, got {}", rounds.value);
            let q = snap.get("serve.query").expect("serve.query registered");
            assert!(q.hist.as_ref().unwrap().count >= 1);
        }
        #[cfg(feature = "obs-off")]
        assert!(snap.metrics.is_empty());
        // The session survives the admin frame: a second query still works.
        client.infer(&test_input(0.05)).unwrap();
        client.bye().unwrap();
        server.shutdown();
    }

    /// The reactor front is protocol- and bit-identical to the threads
    /// front: pinned seeds, sequential session setup, then concurrent
    /// queries — per-session logits must match exactly at 2 and at 64
    /// concurrent sessions.
    ///
    /// Sequential connects pin the engine-seed assignment order (`base`,
    /// `base+1`, …, pool disabled) so session `k` gets the same blinding
    /// material on both fronts; the queries themselves then run fully
    /// concurrently.
    #[cfg(unix)]
    #[test]
    fn reactor_matches_threads_front_bit_exactly() {
        let ctx = Arc::new(Context::new(Params::default_params()));
        let plan = ScalePlan::default_plan();
        let net = tiny_net(13);
        for &n_sessions in &[2usize, 64] {
            let mut per_front: Vec<Vec<Vec<f64>>> = Vec::new();
            for &reactor in &[false, true] {
                let server = SecureServer::serve(
                    ctx.clone(),
                    net.clone(),
                    plan,
                    "127.0.0.1:0",
                    SecureConfig {
                        workers: 2,
                        seed: Some(501),
                        pool: PoolConfig::disabled(),
                        reactor,
                        ..Default::default()
                    },
                )
                .unwrap();
                let mut clients: Vec<CheetahNetClient> = (0..n_sessions)
                    .map(|k| {
                        let seed = 9000 + k as u64;
                        CheetahNetClient::connect(ctx.clone(), plan, &server.addr, seed).unwrap()
                    })
                    .collect();
                assert_eq!(server.session_count(), n_sessions);
                let logits: Vec<Vec<f64>> = std::thread::scope(|s| {
                    let handles: Vec<_> = clients
                        .iter_mut()
                        .enumerate()
                        .map(|(k, c)| {
                            s.spawn(move || c.infer(&test_input(k as f64 * 0.01)).unwrap().logits)
                        })
                        .collect();
                    handles.into_iter().map(|h| h.join().unwrap()).collect()
                });
                for c in &mut clients {
                    c.close().unwrap();
                }
                server.shutdown();
                per_front.push(logits);
            }
            assert_eq!(per_front[0], per_front[1], "fronts diverged at {n_sessions} sessions");
        }
    }

    /// With a lowered fd ulimit (CI: `ulimit -n 256`), the reactor sheds
    /// fd exhaustion gracefully: accepting pauses (counted in
    /// `serve.reactor.accept_stalls`) instead of busy-spinning or dying,
    /// and serving resumes once fds free up. Opt-in via
    /// `CHEETAH_FD_LIMIT_TEST` because it deliberately exhausts the
    /// process fd table (CI runs it alone, single-threaded).
    #[cfg(all(unix, not(feature = "obs-off")))]
    #[test]
    fn reactor_sheds_emfile_and_resumes_accepting() {
        if std::env::var("CHEETAH_FD_LIMIT_TEST").is_err() {
            eprintln!("skipping: set CHEETAH_FD_LIMIT_TEST=1 (under a low `ulimit -n`) to run");
            return;
        }
        let ctx = Arc::new(Context::new(Params::default_params()));
        let plan = ScalePlan::default_plan();
        let server = SecureServer::serve(
            ctx.clone(),
            tiny_net(6),
            plan,
            "127.0.0.1:0",
            SecureConfig {
                seed: Some(31),
                pool: PoolConfig::disabled(),
                reactor: true,
                ..Default::default()
            },
        )
        .unwrap();
        let stalls = || {
            let snap = crate::obs::snapshot();
            snap.get("serve.reactor.accept_stalls").map(|m| m.value).unwrap_or(0)
        };
        let base = stalls();

        // Exhaust the fd table: raw connects first (each pins fds on both
        // ends of this process), then /dev/null handles for the remainder.
        let mut flood = Vec::new();
        while let Ok(s) = TcpStream::connect(server.addr) {
            flood.push(s);
            if flood.len() > 4096 {
                break; // ulimit not actually low; the cap path still stalls
            }
        }
        let mut nulls = Vec::new();
        while let Ok(f) = std::fs::File::open("/dev/null") {
            nulls.push(f);
            if nulls.len() > 4096 {
                break;
            }
        }
        // Free exactly one fd so one more connect can park in the kernel
        // backlog while the server's accept still fails with EMFILE.
        drop(nulls.pop());
        let parked = TcpStream::connect(server.addr);

        let t0 = Instant::now();
        while stalls() <= base {
            assert!(t0.elapsed() < Duration::from_secs(10), "no accept stall recorded");
            std::thread::sleep(Duration::from_millis(10));
        }

        // Free the fds: accepting must resume and serving must work again.
        drop(parked);
        drop(flood);
        drop(nulls);
        let t0 = Instant::now();
        let mut client = loop {
            match CheetahNetClient::connect(ctx.clone(), plan, &server.addr, 77) {
                Ok(c) => break c,
                Err(_) => {
                    assert!(t0.elapsed() < Duration::from_secs(10), "accept never resumed");
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        };
        client.infer(&test_input(0.0)).unwrap();
        client.bye().unwrap();
        server.shutdown();
    }

    #[test]
    fn unknown_tag_gets_error_frame() {
        let ctx = Arc::new(Context::new(Params::default_params()));
        let server = SecureServer::serve(
            ctx.clone(),
            tiny_net(4),
            ScalePlan::default_plan(),
            "127.0.0.1:0",
            SecureConfig { pool: PoolConfig::disabled(), ..Default::default() },
        )
        .unwrap();
        let mut stream = TcpStream::connect(server.addr).unwrap();
        write_frame(&mut stream, 0x77, b"junk").unwrap();
        let (tag, _) = read_frame(&mut stream).unwrap();
        assert_eq!(tag, wire::TAG_ERROR);
        server.shutdown();
    }

    #[test]
    fn out_of_order_round_kills_session_with_error() {
        let ctx = Arc::new(Context::new(Params::default_params()));
        let plan = ScalePlan::default_plan();
        let server = SecureServer::serve(
            ctx.clone(),
            tiny_net(5),
            plan,
            "127.0.0.1:0",
            SecureConfig { pool: PoolConfig::disabled(), ..Default::default() },
        )
        .unwrap();
        // Complete a real handshake to obtain a session id…
        let mut stream = TcpStream::connect(server.addr).unwrap();
        write_frame(&mut stream, wire::TAG_HELLO, &wire::encode_hello()).unwrap();
        let (tag, payload) = read_frame(&mut stream).unwrap();
        assert_eq!(tag, wire::TAG_HELLO_OK);
        let hello = wire::decode_hello_ok(&payload).unwrap();
        loop {
            let (tag, _) = read_frame(&mut stream).unwrap();
            if tag == wire::TAG_OFFLINE_DONE {
                break;
            }
            assert_eq!(tag, wire::TAG_OFFLINE_IDS);
        }
        // …then violate the state machine: RECOVERY before any SHARES.
        let mut payload = wire::round_header(hello.session_id, 0);
        wire::encode_cts(&mut payload, &[]);
        write_frame(&mut stream, wire::TAG_RECOVERY, &payload).unwrap();
        let (tag, payload) = read_frame(&mut stream).unwrap();
        assert_eq!(tag, wire::TAG_ERROR);
        let (sid, code, msg) = wire::decode_error(&payload).unwrap();
        assert_eq!(sid, hello.session_id);
        assert_eq!(code, wire::ERR_PROTOCOL);
        assert!(msg.contains("protocol violation"), "{msg}");
        // The session is retired (the worker removes it just after sending
        // the error frame, hence the short grace loop); the server keeps
        // running for new sessions.
        let t0 = std::time::Instant::now();
        while server.session_count() != 0 {
            assert!(t0.elapsed() < Duration::from_secs(5), "session never removed");
            std::thread::sleep(Duration::from_millis(2));
        }
        server.shutdown();
    }
}
