//! Offline precomputation pool — the GAZELLE-style offline/online split
//! applied to session setup.
//!
//! Preparing a CHEETAH serving engine is the expensive, query-independent
//! part of the protocol: quantize weights, sample the per-block blinding
//! factors `v₁ = ±2^j` and noise streams ([`crate::protocol::cheetah::blinding`]),
//! encrypt the polar-indicator vectors under the server's key, and build
//! the per-step prepared-operand cache (NTT-form `k'∘v` MultPlain operands,
//! first-layer `b` AddPlain operands, per-channel noise residues — budget
//! gated by `CHEETAH_OPERAND_CACHE_MB`). The pool runs that work on
//! background threads *ahead of demand* and hands a ready engine to each
//! new session, so session-setup latency collapses to a queue pop plus
//! indicator serialization — and every query on the session scores through
//! the construction-free online path. Note the banked engines carry their
//! operand caches, so `depth` now budgets memory as well as build time.
//!
//! The pool is a bounded channel: workers block (politely, with a stop
//! check) once `depth` engines are banked, so precomputation never runs
//! unbounded ahead of demand. `take` never blocks — a cold pool falls back
//! to building inline, and the hit/miss counters make the two paths
//! measurable (`benches/serve_bench.rs` reports both).

use crate::fixed::ScalePlan;
use crate::nn::Network;
use crate::phe::Context;
use crate::protocol::cheetah::{CheetahServer, ProtocolSpec, SpecError};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Pool sizing. `depth == 0` or `workers == 0` disables precomputation:
/// every session builds its engine inline (the measured "pool off" path).
#[derive(Clone, Copy, Debug)]
pub struct PoolConfig {
    /// Engines banked ahead of demand.
    pub depth: usize,
    /// Background builder threads.
    pub workers: usize,
}

impl PoolConfig {
    /// A disabled pool: every session builds its engine inline.
    pub fn disabled() -> Self {
        Self { depth: 0, workers: 0 }
    }

    /// Whether background precomputation is on.
    pub fn enabled(&self) -> bool {
        self.depth > 0 && self.workers > 0
    }
}

impl Default for PoolConfig {
    fn default() -> Self {
        Self { depth: 2, workers: 1 }
    }
}

/// Point-in-time pool counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolStats {
    /// Engines built by background workers.
    pub produced: u64,
    /// Sessions served from the bank.
    pub pool_hits: u64,
    /// Sessions that had to build inline (pool cold or disabled).
    pub inline_builds: u64,
}

/// Background bank of prepared CHEETAH serving engines.
pub struct BlindingPool {
    ctx: Arc<Context>,
    net: Network,
    /// Spec validated once at pool start — background builds are
    /// infallible, so a malformed network can never kill a builder thread.
    spec: ProtocolSpec,
    plan: ScalePlan,
    epsilon: f64,
    next_seed: AtomicU64,
    bank: Mutex<Option<Receiver<CheetahServer>>>,
    stop: Arc<AtomicBool>,
    workers: Mutex<Vec<JoinHandle<()>>>,
    produced: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl BlindingPool {
    /// Start the pool (spawning `cfg.workers` builder threads when enabled).
    /// Engine seeds are `base_seed, base_seed+1, …` — deterministic but
    /// distinct per engine, so every session gets fresh blinding material.
    /// Compiling the network into a protocol spec happens here, **once**:
    /// a malformed network is a typed error at configuration time instead
    /// of a panic on a background builder thread.
    ///
    /// `threads` pins the [`crate::par`] fan-out of the background builds
    /// (scoped per builder thread via [`crate::par::with_threads`]; `0`
    /// keeps the global setting) — the owning server's
    /// `SecureConfig::threads` is passed through here.
    pub fn start(
        ctx: Arc<Context>,
        net: Network,
        plan: ScalePlan,
        epsilon: f64,
        base_seed: u64,
        cfg: PoolConfig,
        threads: usize,
    ) -> Result<Arc<Self>, SpecError> {
        let spec = ProtocolSpec::compile(&net)?;
        let pool = Arc::new(Self {
            ctx,
            net,
            spec,
            plan,
            epsilon,
            next_seed: AtomicU64::new(base_seed),
            bank: Mutex::new(None),
            stop: Arc::new(AtomicBool::new(false)),
            workers: Mutex::new(Vec::new()),
            produced: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        });
        if cfg.enabled() {
            let (tx, rx) = sync_channel(cfg.depth);
            *super::lock_ok(&pool.bank) = Some(rx);
            let mut handles = super::lock_ok(&pool.workers);
            for _ in 0..cfg.workers {
                let pool = pool.clone();
                let tx: SyncSender<CheetahServer> = tx.clone();
                handles.push(std::thread::spawn(move || {
                    crate::par::with_threads(threads, || pool.worker_loop(tx))
                }));
            }
        }
        Ok(pool)
    }

    fn build(&self) -> CheetahServer {
        let _span = crate::obs::span("serve.pool.build");
        let seed = self.next_seed.fetch_add(1, Ordering::Relaxed);
        // The engine's own preparation (weight quantization, indicator
        // encryption) additionally fans out on the crate-wide `par` pool.
        CheetahServer::with_spec(
            self.ctx.clone(),
            self.net.clone(),
            self.spec.clone(),
            self.plan,
            self.epsilon,
            seed,
        )
    }

    fn worker_loop(&self, tx: SyncSender<CheetahServer>) {
        while !self.stop.load(Ordering::SeqCst) {
            let mut engine = self.build();
            self.produced.fetch_add(1, Ordering::Relaxed);
            // Park (with stop checks) until the bank has room.
            loop {
                if self.stop.load(Ordering::SeqCst) {
                    return;
                }
                match tx.try_send(engine) {
                    Ok(()) => {
                        crate::obs::gauge_add("serve.pool.occupancy", 1);
                        break;
                    }
                    Err(TrySendError::Full(e)) => {
                        engine = e;
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    Err(TrySendError::Disconnected(_)) => return,
                }
            }
        }
    }

    /// A ready engine: from the bank when warm, built inline otherwise.
    /// Never blocks on the background workers.
    pub fn take(&self) -> CheetahServer {
        let banked = {
            let guard = super::lock_ok(&self.bank);
            guard.as_ref().and_then(|rx| rx.try_recv().ok())
        };
        match banked {
            Some(engine) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                crate::obs::gauge_add("serve.pool.occupancy", -1);
                crate::obs::inc("serve.pool.hits");
                engine
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                crate::obs::inc("serve.pool.misses");
                self.build()
            }
        }
    }

    /// Point-in-time counters (builds, hits, inline fallbacks).
    pub fn stats(&self) -> PoolStats {
        PoolStats {
            produced: self.produced.load(Ordering::Relaxed),
            pool_hits: self.hits.load(Ordering::Relaxed),
            inline_builds: self.misses.load(Ordering::Relaxed),
        }
    }

    /// Block until at least `n` engines have been produced (bench warmup),
    /// or the timeout expires. Returns whether the target was reached.
    pub fn wait_until_produced(&self, n: u64, timeout: Duration) -> bool {
        let t0 = std::time::Instant::now();
        while self.produced.load(Ordering::Relaxed) < n {
            if t0.elapsed() > timeout {
                return false;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        true
    }

    /// Stop and join the builder threads, dropping any banked engines.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        // Dropping the receiver makes any in-flight try_send disconnect.
        super::lock_ok(&self.bank).take();
        let handles: Vec<JoinHandle<()>> = super::lock_ok(&self.workers).drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for BlindingPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::nn::Layer;
    use crate::phe::Params;

    fn tiny_net() -> Network {
        let mut net = Network {
            name: "pool-test".into(),
            input_shape: (1, 4, 4),
            layers: vec![Layer::fc(3)],
        };
        net.init_weights(1);
        net
    }

    #[test]
    fn disabled_pool_builds_inline() {
        // default_params: the default ScalePlan's product range needs the
        // 23-bit plaintext modulus (check_fits panics on smaller p).
        let ctx = Arc::new(Context::new(Params::default_params()));
        let pool = BlindingPool::start(
            ctx.clone(),
            tiny_net(),
            ScalePlan::default_plan(),
            0.0,
            100,
            PoolConfig::disabled(),
            0,
        )
        .expect("valid network");
        let _a = pool.take();
        let _b = pool.take();
        let s = pool.stats();
        assert_eq!(s.pool_hits, 0);
        assert_eq!(s.inline_builds, 2);
        assert_eq!(s.produced, 0, "no background workers ⇒ nothing counted as produced");
        pool.shutdown();
    }

    #[test]
    fn warm_pool_serves_hits_with_distinct_seeds() {
        let ctx = Arc::new(Context::new(Params::default_params()));
        let pool = BlindingPool::start(
            ctx.clone(),
            tiny_net(),
            ScalePlan::default_plan(),
            0.0,
            200,
            PoolConfig { depth: 2, workers: 1 },
            0,
        )
        .expect("valid network");
        assert!(pool.wait_until_produced(2, Duration::from_secs(10)), "pool never warmed");
        let _a = pool.take();
        let _b = pool.take();
        let s = pool.stats();
        assert_eq!(s.pool_hits + s.inline_builds, 2);
        assert!(s.pool_hits >= 1, "warm pool produced no hits: {s:?}");
        pool.shutdown();
        // Shutdown is idempotent and joins workers.
        pool.shutdown();
    }
}
