//! Session registry and per-session protocol state machines.
//!
//! Every connected client gets a session id and a dedicated CHEETAH serving
//! engine (with its own blinding material and indicator ciphertexts, pulled
//! from the [`super::precompute::BlindingPool`]). The registry multiplexes
//! rounds from interleaved clients on one listener: each online frame
//! carries its session id, the reader routes it to a session-sticky worker,
//! and the state machine enforces round ordering so a confused (or
//! malicious) client gets a typed protocol error instead of corrupting
//! engine state or panicking a worker.
//!
//! CHEETAH needs **no client evaluation keys**: the server's obscure linear
//! computation is `MultPlain`/`AddPlain` only (zero `Perm`s — the paper's
//! headline), so there are no Galois keys to cache. What the registry caches
//! instead is the per-session offline material — the prepared engine and its
//! indicator ciphertexts — so repeat queries on a session pay online cost
//! only.

use super::wire;
use crate::coordinator::metrics::Metrics;
use crate::phe::Ciphertext;
use crate::protocol::cheetah::CheetahServer;
use crate::util::rng::ChaCha20Rng;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Where a session is in the per-query round sequence.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Expecting the client's encrypted transformed share for `step`
    /// (step 0 starts a fresh query).
    AwaitShares(usize),
    /// Expecting the nonlinear recovery ciphertexts for `step`.
    AwaitRecovery(usize),
}

/// A protocol-ordering or validation failure; the worker converts this into
/// an `ERROR` frame and retires the session.
#[derive(Debug)]
pub struct ProtocolViolation(pub String);

impl std::fmt::Display for ProtocolViolation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "protocol violation: {}", self.0)
    }
}

impl std::error::Error for ProtocolViolation {}

/// One client's serving state: engine + state machine + counters.
pub struct Session {
    pub id: u64,
    pub engine: CheetahServer,
    pub phase: Phase,
    query_start: Option<Instant>,
    pub queries_done: u64,
}

impl Session {
    pub fn new(id: u64, engine: CheetahServer) -> Self {
        Self { id, engine, phase: Phase::AwaitShares(0), query_start: None, queries_done: 0 }
    }

    fn expect_shares(&self, step: usize) -> Result<(), ProtocolViolation> {
        match self.phase {
            Phase::AwaitShares(s) if s == step => Ok(()),
            phase => Err(ProtocolViolation(format!(
                "SHARES for step {step} while in {phase:?}"
            ))),
        }
    }

    /// Handle a `SHARES` round: run the obscure linear computation and
    /// return the `PRODUCTS` payload. Completing the last step finishes the
    /// query (recorded in `metrics`) and re-arms the session for the next
    /// one — the cached offline material is reused.
    pub fn on_shares(
        &mut self,
        step: usize,
        in_cts: &[Ciphertext],
        metrics: &Metrics,
    ) -> Result<Vec<u8>, ProtocolViolation> {
        self.expect_shares(step)?;
        let n = self.engine.ctx.params.n;
        let expected = self.engine.spec.steps[step].linear.num_in_cts(n);
        if in_cts.len() != expected {
            return Err(ProtocolViolation(format!(
                "step {step} expects {expected} input ciphertexts, got {}",
                in_cts.len()
            )));
        }
        if step == 0 {
            self.engine.begin_query();
            self.query_start = Some(Instant::now());
        }
        let out = self.engine.step_linear(step, in_cts);
        if step == self.engine.spec.last_idx() {
            if let Some(t0) = self.query_start.take() {
                metrics.record_request(t0.elapsed());
            }
            self.queries_done += 1;
            self.phase = Phase::AwaitShares(0);
        } else {
            self.phase = Phase::AwaitRecovery(step);
        }
        let mut payload = wire::round_header(self.id, step as u32);
        wire::encode_cts(&mut payload, &out);
        Ok(payload)
    }

    /// Handle a `RECOVERY` round: decrypt the server's share of the exact
    /// ReLU activation and return the `RECOVERY_OK` payload.
    pub fn on_recovery(
        &mut self,
        step: usize,
        rec_cts: &[Ciphertext],
    ) -> Result<Vec<u8>, ProtocolViolation> {
        match self.phase {
            Phase::AwaitRecovery(s) if s == step => {}
            phase => {
                return Err(ProtocolViolation(format!(
                    "RECOVERY for step {step} while in {phase:?}"
                )))
            }
        }
        let n = self.engine.ctx.params.n;
        let expected = self.engine.spec.steps[step].linear.num_recovery_cts(n);
        if rec_cts.len() != expected {
            return Err(ProtocolViolation(format!(
                "step {step} expects {expected} recovery ciphertexts, got {}",
                rec_cts.len()
            )));
        }
        self.engine.finish_nonlinear(step, rec_cts);
        self.phase = Phase::AwaitShares(step + 1);
        Ok(wire::round_header(self.id, step as u32))
    }
}

/// Concurrent session table. Sessions are created at `HELLO`, looked up per
/// round by id, and removed at `BYE`, protocol error, connection close, or
/// server shutdown.
///
/// Session ids are 64-bit values from a CSPRNG, not a counter: the wire
/// layer authenticates nobody, so the unguessable id *is* the isolation
/// boundary between clients — a peer cannot forge rounds (or `BYE`) for a
/// session it did not create without guessing its id.
pub struct SessionRegistry {
    sessions: Mutex<HashMap<u64, Arc<Mutex<Session>>>>,
    id_rng: Mutex<ChaCha20Rng>,
}

impl Default for SessionRegistry {
    fn default() -> Self {
        Self::new()
    }
}

impl SessionRegistry {
    pub fn new() -> Self {
        Self {
            sessions: Mutex::new(HashMap::new()),
            id_rng: Mutex::new(ChaCha20Rng::from_os_entropy()),
        }
    }

    pub fn create(&self, engine: CheetahServer) -> (u64, Arc<Mutex<Session>>) {
        let mut sessions = self.sessions.lock().unwrap();
        let id = {
            let mut rng = self.id_rng.lock().unwrap();
            loop {
                let id = rng.next_u64();
                if id != 0 && !sessions.contains_key(&id) {
                    break id;
                }
            }
        };
        let session = Arc::new(Mutex::new(Session::new(id, engine)));
        sessions.insert(id, session.clone());
        (id, session)
    }

    pub fn get(&self, id: u64) -> Option<Arc<Mutex<Session>>> {
        self.sessions.lock().unwrap().get(&id).cloned()
    }

    pub fn remove(&self, id: u64) -> bool {
        self.sessions.lock().unwrap().remove(&id).is_some()
    }

    pub fn len(&self) -> usize {
        self.sessions.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn clear(&self) {
        self.sessions.lock().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::ScalePlan;
    use crate::nn::{Layer, Network};
    use crate::phe::Params;

    fn session_on_tiny_net() -> Session {
        let ctx = Arc::new(crate::phe::Context::new(Params::default_params()));
        let mut net = Network {
            name: "sm".into(),
            input_shape: (1, 3, 3),
            layers: vec![Layer::fc(4), Layer::relu(), Layer::fc(2)],
        };
        net.init_weights(7);
        let engine =
            CheetahServer::new(ctx, net, ScalePlan::default_plan(), 0.0, 8).expect("valid net");
        Session::new(1, engine)
    }

    #[test]
    fn out_of_order_rounds_are_rejected_not_panicking() {
        let metrics = Metrics::new();
        let mut s = session_on_tiny_net();
        // RECOVERY before any SHARES.
        assert!(s.on_recovery(0, &[]).is_err());
        // SHARES for a later step first.
        assert!(s.on_shares(1, &[], &metrics).is_err());
        // Wrong ciphertext count for the right step.
        assert!(s.on_shares(0, &[], &metrics).is_err());
        // The session survives the rejections in its initial phase.
        assert_eq!(s.phase, Phase::AwaitShares(0));
    }

    #[test]
    fn registry_create_get_remove() {
        let ctx = Arc::new(crate::phe::Context::new(Params::default_params()));
        let mut net = Network {
            name: "r".into(),
            input_shape: (1, 2, 2),
            layers: vec![Layer::fc(2)],
        };
        net.init_weights(9);
        let reg = SessionRegistry::new();
        let engine = CheetahServer::new(ctx.clone(), net.clone(), ScalePlan::default_plan(), 0.0, 1)
            .expect("valid net");
        let (id1, _) = reg.create(engine);
        let engine = CheetahServer::new(ctx.clone(), net, ScalePlan::default_plan(), 0.0, 2)
            .expect("valid net");
        let (id2, _) = reg.create(engine);
        assert_ne!(id1, id2);
        assert_eq!(reg.len(), 2);
        assert!(reg.get(id1).is_some());
        assert!(reg.remove(id1));
        assert!(!reg.remove(id1));
        assert!(reg.get(id1).is_none());
        assert_eq!(reg.len(), 1);
        reg.clear();
        assert!(reg.is_empty());
    }
}
