//! Session registry and per-session protocol state machines.
//!
//! Every connected client gets a session id and a dedicated CHEETAH serving
//! engine (with its own blinding material and indicator ciphertexts, pulled
//! from the [`super::precompute::BlindingPool`]). The registry multiplexes
//! rounds from interleaved clients on one listener: each online frame
//! carries its session id, the reader (a blocking per-connection thread on
//! the threads front, the event loop on the [`super::reactor`] front)
//! routes it to a session-sticky worker, and the state machine enforces
//! round ordering so a confused (or malicious) client gets a typed
//! protocol error instead of corrupting engine state or panicking a
//! worker. Both fronts drive the *same* state machine — a session never
//! knows which front delivered its frames.
//!
//! CHEETAH needs **no client evaluation keys**: the server's obscure linear
//! computation is `MultPlain`/`AddPlain` only (zero `Perm`s — the paper's
//! headline), so there are no Galois keys to cache. What the registry caches
//! instead is the per-session offline material — the prepared engine and its
//! indicator ciphertexts — so repeat queries on a session pay online cost
//! only.
//!
//! Engines are held by `Arc` and scored through the **stateless** `&self`
//! core ([`CheetahServer::step_linear_with`]): the per-query mutable state
//! — the server's share of the activation chain — lives in the [`Session`],
//! not the engine. One engine instance can therefore serve any number of
//! concurrent queries; the TCP path still hands each session its own
//! freshly-blinded engine from the pool (per-session blinds are what keep
//! one client's view uncorrelated with another's), but nothing about the
//! scoring requires exclusive engine ownership any more.

use super::wire;
use crate::coordinator::metrics::Metrics;
use crate::phe::Ciphertext;
use crate::protocol::cheetah::CheetahServer;
use crate::util::rng::ChaCha20Rng;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Where a session is in the per-query round sequence.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Expecting the client's encrypted transformed share for `step`
    /// (step 0 starts a fresh query).
    AwaitShares(usize),
    /// Expecting the nonlinear recovery ciphertexts for `step`.
    AwaitRecovery(usize),
}

/// A protocol-ordering or validation failure; the worker converts this into
/// an `ERROR` frame and retires the session.
#[derive(Debug)]
pub struct ProtocolViolation(
    /// Human-readable description of the violation.
    pub String,
);

impl std::fmt::Display for ProtocolViolation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "protocol violation: {}", self.0)
    }
}

impl std::error::Error for ProtocolViolation {}

/// One client's serving state: shared engine + per-query share + state
/// machine + counters.
pub struct Session {
    /// The session id (the wire-level isolation boundary).
    pub id: u64,
    /// The prepared serving engine (stateless scoring; `Arc`-shared).
    pub engine: Arc<CheetahServer>,
    /// Server-side share of this session's in-flight query.
    share: Vec<u64>,
    /// Where this session is in the round sequence.
    pub phase: Phase,
    query_start: Option<Instant>,
    /// Completed queries on this session.
    pub queries_done: u64,
}

impl Session {
    /// Wrap a prepared engine into a fresh session.
    pub fn new(id: u64, engine: Arc<CheetahServer>) -> Self {
        let share = engine.fresh_share();
        Self { id, engine, share, phase: Phase::AwaitShares(0), query_start: None, queries_done: 0 }
    }

    fn expect_shares(&self, step: usize) -> Result<(), ProtocolViolation> {
        match self.phase {
            Phase::AwaitShares(s) if s == step => Ok(()),
            phase => Err(ProtocolViolation(format!(
                "SHARES for step {step} while in {phase:?}"
            ))),
        }
    }

    /// Handle a `SHARES` round: run the obscure linear computation and
    /// return the `PRODUCTS` payload. Completing the last step finishes the
    /// query (recorded in `metrics`) and re-arms the session for the next
    /// one — the cached offline material is reused.
    pub fn on_shares(
        &mut self,
        step: usize,
        in_cts: &[Ciphertext],
        metrics: &Metrics,
    ) -> Result<Vec<u8>, ProtocolViolation> {
        self.expect_shares(step)?;
        crate::obs::inc("serve.rounds");
        let n = self.engine.ctx.params.n;
        let expected = self.engine.spec.steps[step].linear.num_in_cts(n);
        if in_cts.len() != expected {
            return Err(ProtocolViolation(format!(
                "step {step} expects {expected} input ciphertexts, got {}",
                in_cts.len()
            )));
        }
        if step == 0 {
            self.share = self.engine.fresh_share();
            self.query_start = Some(Instant::now());
        }
        let out = self.engine.step_linear_with(step, in_cts, &self.share);
        if step == self.engine.spec.last_idx() {
            if let Some(t0) = self.query_start.take() {
                let elapsed = t0.elapsed();
                crate::obs::record("serve.query", elapsed.as_nanos() as u64);
                metrics.record_request(elapsed);
            }
            self.queries_done += 1;
            self.phase = Phase::AwaitShares(0);
        } else if self.engine.spec.steps[step].is_local() {
            // Local step (standalone AvgPool): no recovery round exists —
            // the server transforms its own share here and the client does
            // the same on its side, so the session moves straight to the
            // next SHARES round. The PRODUCTS payload is legitimately
            // empty (zero ciphertexts).
            let pooled = self.engine.local_share(step, &self.share);
            self.share = pooled;
            self.phase = Phase::AwaitShares(step + 1);
        } else {
            self.phase = Phase::AwaitRecovery(step);
        }
        let mut payload = wire::round_header(self.id, step as u32);
        wire::encode_cts(&mut payload, &out);
        Ok(payload)
    }

    /// Handle a `RECOVERY` round: decrypt the server's share of the exact
    /// ReLU activation and return the `RECOVERY_OK` payload.
    pub fn on_recovery(
        &mut self,
        step: usize,
        rec_cts: &[Ciphertext],
    ) -> Result<Vec<u8>, ProtocolViolation> {
        match self.phase {
            Phase::AwaitRecovery(s) if s == step => {}
            phase => {
                return Err(ProtocolViolation(format!(
                    "RECOVERY for step {step} while in {phase:?}"
                )))
            }
        }
        crate::obs::inc("serve.rounds");
        let n = self.engine.ctx.params.n;
        let expected = self.engine.spec.steps[step].linear.num_recovery_cts(n);
        if rec_cts.len() != expected {
            return Err(ProtocolViolation(format!(
                "step {step} expects {expected} recovery ciphertexts, got {}",
                rec_cts.len()
            )));
        }
        let next = self.engine.advance_share(step, rec_cts, &self.share);
        self.share = next;
        self.phase = Phase::AwaitShares(step + 1);
        Ok(wire::round_header(self.id, step as u32))
    }
}

/// Concurrent session table. Sessions are created at `HELLO`, looked up per
/// round by id, and removed at `BYE`, protocol error, connection close, or
/// server shutdown.
///
/// Session ids are 64-bit values from a CSPRNG, not a counter: the wire
/// layer authenticates nobody, so the unguessable id *is* the isolation
/// boundary between clients — a peer cannot forge rounds (or `BYE`) for a
/// session it did not create without guessing its id.
pub struct SessionRegistry {
    sessions: Mutex<HashMap<u64, Arc<Mutex<Session>>>>,
    id_rng: Mutex<ChaCha20Rng>,
}

impl Default for SessionRegistry {
    fn default() -> Self {
        Self::new()
    }
}

impl SessionRegistry {
    /// An empty registry with a CSPRNG id source.
    pub fn new() -> Self {
        Self {
            sessions: Mutex::new(HashMap::new()),
            id_rng: Mutex::new(ChaCha20Rng::from_os_entropy()),
        }
    }

    /// Mint an unguessable session id and register a session around the
    /// (shared) engine.
    pub fn create(&self, engine: Arc<CheetahServer>) -> (u64, Arc<Mutex<Session>>) {
        let mut sessions = super::lock_ok(&self.sessions);
        let id = {
            let mut rng = super::lock_ok(&self.id_rng);
            loop {
                let id = rng.next_u64();
                if id != 0 && !sessions.contains_key(&id) {
                    break id;
                }
            }
        };
        let session = Arc::new(Mutex::new(Session::new(id, engine)));
        sessions.insert(id, session.clone());
        crate::obs::gauge_set("serve.sessions", sessions.len() as i64);
        (id, session)
    }

    /// Look a session up by id.
    pub fn get(&self, id: u64) -> Option<Arc<Mutex<Session>>> {
        super::lock_ok(&self.sessions).get(&id).cloned()
    }

    /// Retire a session; returns whether it existed.
    pub fn remove(&self, id: u64) -> bool {
        let mut sessions = super::lock_ok(&self.sessions);
        let existed = sessions.remove(&id).is_some();
        crate::obs::gauge_set("serve.sessions", sessions.len() as i64);
        existed
    }

    /// Number of live sessions.
    pub fn len(&self) -> usize {
        super::lock_ok(&self.sessions).len()
    }

    /// Whether no session is live.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Retire every session (server shutdown).
    pub fn clear(&self) {
        super::lock_ok(&self.sessions).clear();
        crate::obs::gauge_set("serve.sessions", 0);
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::fixed::ScalePlan;
    use crate::nn::{Layer, Network};
    use crate::phe::Params;

    fn session_on_tiny_net() -> Session {
        let ctx = Arc::new(crate::phe::Context::new(Params::default_params()));
        let mut net = Network {
            name: "sm".into(),
            input_shape: (1, 3, 3),
            layers: vec![Layer::fc(4), Layer::relu(), Layer::fc(2)],
        };
        net.init_weights(7);
        let engine =
            CheetahServer::new(ctx, net, ScalePlan::default_plan(), 0.0, 8).expect("valid net");
        Session::new(1, Arc::new(engine))
    }

    #[test]
    fn out_of_order_rounds_are_rejected_not_panicking() {
        let metrics = Metrics::new();
        let mut s = session_on_tiny_net();
        // RECOVERY before any SHARES.
        assert!(s.on_recovery(0, &[]).is_err());
        // SHARES for a later step first.
        assert!(s.on_shares(1, &[], &metrics).is_err());
        // Wrong ciphertext count for the right step.
        assert!(s.on_shares(0, &[], &metrics).is_err());
        // The session survives the rejections in its initial phase.
        assert_eq!(s.phase, Phase::AwaitShares(0));
    }

    /// The stateless scoring core: two sessions sharing **one** engine
    /// `Arc`, driven from two threads concurrently, each produce the same
    /// results a dedicated single-session run does — the per-query state
    /// isolation the batch path relies on, exercised through the session
    /// layer.
    #[test]
    fn concurrent_sessions_share_one_engine() {
        use crate::nn::Tensor;
        use crate::protocol::cheetah::{CheetahClient, CheetahRunner};

        let ctx = Arc::new(crate::phe::Context::new(Params::default_params()));
        let plan = ScalePlan::default_plan();
        let mut net = Network {
            name: "shared-engine".into(),
            input_shape: (1, 4, 4),
            layers: vec![Layer::fc(4), Layer::relu(), Layer::fc(2)],
        };
        net.init_weights(31);

        // Reference: in-process runner, same server seed.
        let mut reference =
            CheetahRunner::new(ctx.clone(), net.clone(), plan, 0.0, 77).expect("valid net");
        reference.run_offline();
        let inputs: Vec<Tensor> = (0..2)
            .map(|k| {
                Tensor::from_vec(
                    (0..16).map(|i| (i as f64 - 8.0) / 9.0 + k as f64 * 0.03).collect(),
                    1,
                    4,
                    4,
                )
            })
            .collect();
        let want: Vec<Vec<f64>> = inputs.iter().map(|x| reference.infer(x).logits).collect();

        // One engine Arc, two sessions, two threads.
        let engine = Arc::new(
            CheetahServer::new(ctx.clone(), net, plan, 0.0, 77).expect("valid net"),
        );
        let metrics = Arc::new(Metrics::new());
        let mut threads = Vec::new();
        for (k, input) in inputs.into_iter().enumerate() {
            let engine = engine.clone();
            let ctx = ctx.clone();
            let metrics = metrics.clone();
            threads.push(std::thread::spawn(move || {
                use crate::serve::wire;
                let mut session = Session::new(1 + k as u64, engine.clone());
                // A driving client per thread (client seed is irrelevant to
                // the logits; see protocol::cheetah docs).
                let mut client = CheetahClient::new(
                    ctx.clone(),
                    engine.spec.clone(),
                    plan,
                    500 + k as u64,
                );
                for si in 0..engine.spec.steps.len() {
                    let (id1, id2) = engine.indicator_cts(si);
                    client.install_indicators(si, id1.to_vec(), id2.to_vec());
                }
                client.begin_query(&input);
                for si in 0..engine.spec.steps.len() {
                    let in_cts = client.step_send(si);
                    let payload =
                        session.on_shares(si, &in_cts, &metrics).expect("shares round");
                    let mut r = wire::ByteReader::new(&payload);
                    wire::read_round_header(&mut r).expect("round header");
                    let out = wire::decode_cts(&ctx, &mut r).expect("products decode");
                    if let Some(rec) = client.step_receive(si, &out) {
                        session.on_recovery(si, &rec).expect("recovery round");
                    }
                }
                (client.argmax(), client.logits())
            }));
        }
        for (k, t) in threads.into_iter().enumerate() {
            let (_, logits) = t.join().expect("session thread");
            assert_eq!(
                logits, want[k],
                "session {k} on the shared engine diverged from the dedicated runner"
            );
        }
        assert_eq!(metrics.summary().requests, 2);
    }

    #[test]
    fn registry_create_get_remove() {
        let ctx = Arc::new(crate::phe::Context::new(Params::default_params()));
        let mut net = Network {
            name: "r".into(),
            input_shape: (1, 2, 2),
            layers: vec![Layer::fc(2)],
        };
        net.init_weights(9);
        let reg = SessionRegistry::new();
        let engine = CheetahServer::new(ctx.clone(), net.clone(), ScalePlan::default_plan(), 0.0, 1)
            .expect("valid net");
        let (id1, _) = reg.create(Arc::new(engine));
        let engine = CheetahServer::new(ctx.clone(), net, ScalePlan::default_plan(), 0.0, 2)
            .expect("valid net");
        let (id2, _) = reg.create(Arc::new(engine));
        assert_ne!(id1, id2);
        assert_eq!(reg.len(), 2);
        assert!(reg.get(id1).is_some());
        assert!(reg.remove(id1));
        assert!(!reg.remove(id1));
        assert!(reg.get(id1).is_none());
        assert_eq!(reg.len(), 1);
        reg.clear();
        assert!(reg.is_empty());
    }
}
