//! Wire codec for the secure serving protocol: maps each CHEETAH round
//! (transformed-share ciphertexts, obscured linear products, nonlinear
//! recovery messages) onto the length-prefixed frames of
//! [`crate::protocol::transport`].
//!
//! Frame grammar (one protocol message per frame; all integers
//! little-endian; ciphertexts use the exact bit-packed format of
//! [`crate::phe::serial`]):
//!
//! | tag  | dir | payload |
//! |------|-----|---------|
//! | `HELLO`        0x20 | c→s | magic `u32` + version `u16` |
//! | `SHARES`       0x23 | c→s | sid `u64` + step `u32` + cts (`[T(share_C)]_C`) |
//! | `RECOVERY`     0x24 | c→s | sid `u64` + step `u32` + cts (`[ID₁∘y+ID₂∘ReLU(y)−s₁]_S`) |
//! | `STATS`        0x30 | c→s | (empty) — admin introspection request |
//! | `BYE`          0x2f | c→s | sid `u64` |
//! | `HELLO_OK`     0xa0 | s→c | sid `u64` + plan/params fingerprint `u64` + ε `f64` + n_steps `u32` + arch + version `u16` |
//! | `OFFLINE_IDS`  0xa1 | s→c | sid `u64` + step `u32` + id1 cts + id2 cts |
//! | `OFFLINE_DONE` 0xa2 | s→c | sid `u64` |
//! | `PRODUCTS`     0xa3 | s→c | sid `u64` + step `u32` + cts (obscured products) |
//! | `RECOVERY_OK`  0xa4 | s→c | sid `u64` + step `u32` |
//! | `STATS_OK`     0xa5 | s→c | utf-8 telemetry snapshot JSON ([`crate::obs::Snapshot`]) |
//! | `ERROR`        0xee | s→c | sid `u64` + code `u16` + utf-8 message |
//!
//! Every online frame carries the session id, so rounds from interleaved
//! clients multiplex on one listener (and, if a client chooses, on one
//! connection). Ciphertext vectors are encoded as `count u32` followed by
//! `len u32 + bytes` per ciphertext. Decoding is defensive: all counts and
//! lengths are validated against the remaining buffer before allocation,
//! and malformed input returns a typed [`WireError`], never a panic.
//!
//! ## Version negotiation and payload checksums
//!
//! `HELLO` carries the client's protocol version; the server accepts any
//! version in `[MIN_VERSION, VERSION]` and echoes the negotiated version as
//! a trailing `u16` on `HELLO_OK` (absent ⇒ v1 — v1 decoders never read
//! past the architecture, so the trailer is invisible to them). Under v2,
//! every bulk round frame — `SHARES`, `RECOVERY`, `OFFLINE_IDS`,
//! `OFFLINE_DONE`, `PRODUCTS`, `RECOVERY_OK` — carries a trailing FNV-1a
//! 64-bit checksum over `tag + payload` ([`seal`] / [`verify_and_strip`]),
//! so a flipped byte inside a multi-megabyte ciphertext shipment is caught
//! at the frame boundary (`ERR_CORRUPT`) instead of surfacing as garbage
//! plaintexts after decryption. Control frames (`HELLO*`, `STATS*`, `BYE`,
//! `ERROR`) stay plain in every version.

use crate::fixed::ScalePlan;
use crate::nn::{Layer, LayerKind, Network};
use crate::phe::serial::{deserialize_ct, serialize_ct};
use crate::phe::{Ciphertext, Context, Params};

/// Protocol magic: `"CHTA"`.
pub const MAGIC: u32 = 0x4348_5441;
/// Current wire protocol version (v2 adds bulk-frame payload checksums).
pub const VERSION: u16 = 2;
/// Oldest version the server still speaks (v1: no checksums).
pub const MIN_VERSION: u16 = 1;

/// c→s greeting (magic + version).
pub const TAG_HELLO: u8 = 0x20;
/// c→s encrypted transformed-share round.
pub const TAG_SHARES: u8 = 0x23;
/// c→s nonlinear recovery round.
pub const TAG_RECOVERY: u8 = 0x24;
/// c→s admin request for a telemetry snapshot (no session required).
pub const TAG_STATS: u8 = 0x30;
/// c→s polite session end.
pub const TAG_BYE: u8 = 0x2f;
/// s→c session grant (id, fingerprint, ε, architecture).
pub const TAG_HELLO_OK: u8 = 0xa0;
/// s→c offline indicator-ciphertext shipment for one step.
pub const TAG_OFFLINE_IDS: u8 = 0xa1;
/// s→c end of the offline phase.
pub const TAG_OFFLINE_DONE: u8 = 0xa2;
/// s→c obscured linear products.
pub const TAG_PRODUCTS: u8 = 0xa3;
/// s→c recovery acknowledgement.
pub const TAG_RECOVERY_OK: u8 = 0xa4;
/// s→c telemetry snapshot (UTF-8 JSON; see [`crate::obs::Snapshot`]).
pub const TAG_STATS_OK: u8 = 0xa5;
/// s→c typed failure; the session is retired.
pub const TAG_ERROR: u8 = 0xee;

/// `ERROR` code: protocol-ordering or validation failure.
pub const ERR_PROTOCOL: u16 = 1;
/// `ERROR` code: unsupported greeting (magic/version).
pub const ERR_UNSUPPORTED: u16 = 2;
/// `ERROR` code: internal server failure.
pub const ERR_INTERNAL: u16 = 3;
/// `ERROR` code: frame payload checksum mismatch (v2+).
pub const ERR_CORRUPT: u16 = 4;

/// Upper bound on ciphertexts per message (a paper-scale VGG step needs a
/// few hundred; this only guards against absurd counts from corrupt input).
const MAX_CTS_PER_MSG: usize = 1 << 16;
/// Upper bound on layers in a served architecture description.
const MAX_ARCH_LAYERS: usize = 256;
/// Upper bound on any single architecture dimension.
const MAX_ARCH_DIM: usize = 1 << 20;

/// Typed decode failure.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WireError {
    /// The buffer ended before the message did.
    Truncated,
    /// Structurally invalid content (bad magic, absurd count, …).
    Malformed(&'static str),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Truncated => write!(f, "message truncated"),
            WireError::Malformed(what) => write!(f, "malformed message: {what}"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<WireError> for std::io::Error {
    fn from(e: WireError) -> Self {
        std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string())
    }
}

/// Bounds-checked little-endian reader over a message payload.
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    /// Start reading at the front of `buf`.
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Consume exactly `len` bytes, or fail with `Truncated`.
    pub fn take(&mut self, len: usize) -> Result<&'a [u8], WireError> {
        if self.remaining() < len {
            return Err(WireError::Truncated);
        }
        let s = &self.buf[self.pos..self.pos + len];
        self.pos += len;
        Ok(s)
    }

    /// Read a `u8`.
    pub fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    /// Read a little-endian `u16`.
    pub fn u16(&mut self) -> Result<u16, WireError> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    /// Read a little-endian `u32`.
    pub fn u32(&mut self) -> Result<u32, WireError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    /// Read a little-endian `u64`.
    pub fn u64(&mut self) -> Result<u64, WireError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    /// Read an `f64` from its little-endian bit pattern.
    pub fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_bits(self.u64()?))
    }
}

// ---- ciphertext vectors ----

/// Append `count u32 + (len u32 + bytes)*` for a ciphertext vector.
pub fn encode_cts(out: &mut Vec<u8>, cts: &[Ciphertext]) {
    out.extend_from_slice(&(cts.len() as u32).to_le_bytes());
    for ct in cts {
        let bytes = serialize_ct(ct);
        out.extend_from_slice(&(bytes.len() as u32).to_le_bytes());
        out.extend_from_slice(&bytes);
    }
}

/// Decode a ciphertext vector; every length is validated against the
/// remaining buffer before any allocation.
pub fn decode_cts(ctx: &Context, r: &mut ByteReader) -> Result<Vec<Ciphertext>, WireError> {
    let count = r.u32()? as usize;
    if count > MAX_CTS_PER_MSG {
        return Err(WireError::Malformed("ciphertext count"));
    }
    let mut cts = Vec::with_capacity(count);
    for _ in 0..count {
        let len = r.u32()? as usize;
        let bytes = r.take(len)?;
        cts.push(deserialize_ct(ctx, bytes));
    }
    Ok(cts)
}

// ---- architecture description (kinds + shapes only, never weights) ----

/// Encode the layer geometry of `net` — the public model metadata the
/// client needs to compile its own [`crate::protocol::cheetah::spec::ProtocolSpec`].
/// Weights never cross the wire (they are the server's secret).
pub fn encode_arch(out: &mut Vec<u8>, net: &Network) {
    let (c, h, w) = net.input_shape;
    out.extend_from_slice(&(c as u32).to_le_bytes());
    out.extend_from_slice(&(h as u32).to_le_bytes());
    out.extend_from_slice(&(w as u32).to_le_bytes());
    out.extend_from_slice(&(net.layers.len() as u32).to_le_bytes());
    for layer in &net.layers {
        match layer.kind {
            LayerKind::Conv2d { out_channels, kernel, stride, pad } => {
                out.push(0);
                for v in [out_channels, kernel, stride, pad] {
                    out.extend_from_slice(&(v as u32).to_le_bytes());
                }
            }
            LayerKind::Relu => out.push(1),
            LayerKind::MeanPool { size } => {
                out.push(2);
                out.extend_from_slice(&(size as u32).to_le_bytes());
            }
            LayerKind::Fc { out_features } => {
                out.push(3);
                out.extend_from_slice(&(out_features as u32).to_le_bytes());
            }
            LayerKind::ResidualAdd => out.push(4),
        }
    }
}

fn arch_dim(r: &mut ByteReader) -> Result<usize, WireError> {
    let v = r.u32()? as usize;
    if v == 0 || v > MAX_ARCH_DIM {
        return Err(WireError::Malformed("architecture dimension"));
    }
    Ok(v)
}

/// Decode an architecture description into a weight-less [`Network`].
pub fn decode_arch(r: &mut ByteReader) -> Result<Network, WireError> {
    let c = arch_dim(r)?;
    let h = arch_dim(r)?;
    let w = arch_dim(r)?;
    let n_layers = r.u32()? as usize;
    if n_layers == 0 || n_layers > MAX_ARCH_LAYERS {
        return Err(WireError::Malformed("layer count"));
    }
    let mut layers = Vec::with_capacity(n_layers);
    for _ in 0..n_layers {
        layers.push(match r.u8()? {
            0 => {
                let out_channels = arch_dim(r)?;
                let kernel = arch_dim(r)?;
                let stride = arch_dim(r)?;
                let pad = r.u32()? as usize; // pad 0 is legal
                if pad > MAX_ARCH_DIM {
                    return Err(WireError::Malformed("architecture dimension"));
                }
                Layer::conv(out_channels, kernel, stride, pad)
            }
            1 => Layer::relu(),
            2 => Layer::mean_pool(arch_dim(r)?),
            3 => Layer::fc(arch_dim(r)?),
            4 => Layer::residual_add(),
            _ => return Err(WireError::Malformed("layer kind")),
        });
    }
    Ok(Network { name: "served".into(), input_shape: (c, h, w), layers })
}

// ---- handshake ----

fn mix(h: u64, v: u64) -> u64 {
    // SplitMix64 finalizer over a running fold.
    let mut z = h ^ v.wrapping_mul(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// A fingerprint over everything both parties must agree on byte-for-byte:
/// the PHE parameters and the fixed-point scale plan. A mismatch is caught
/// at the handshake instead of surfacing as garbage plaintexts mid-query.
pub fn plan_fingerprint(params: &Params, plan: &ScalePlan) -> u64 {
    let mut h = 0xC4EE_7A11u64; // arbitrary non-zero start
    for v in [params.n as u64, params.p, params.qs[0], params.qs[1]] {
        h = mix(h, v);
    }
    for s in [plan.x, plan.k, plan.v, plan.y, plan.id] {
        h = mix(h, s.frac_bits as u64);
    }
    for f in [plan.x_max, plan.k_max, plan.y_max] {
        h = mix(h, f.to_bits());
    }
    h
}

/// Client → server greeting at the current [`VERSION`].
pub fn encode_hello() -> Vec<u8> {
    encode_hello_version(VERSION)
}

/// Client → server greeting claiming an explicit protocol version (tests
/// use this to exercise the v1 compatibility path).
pub fn encode_hello_version(version: u16) -> Vec<u8> {
    let mut out = Vec::with_capacity(6);
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.extend_from_slice(&version.to_le_bytes());
    out
}

/// Validate a client greeting (magic + version) and return the negotiated
/// protocol version: any client version in `[MIN_VERSION, VERSION]` is
/// served at exactly the version it asked for.
pub fn decode_hello(payload: &[u8]) -> Result<u16, WireError> {
    let mut r = ByteReader::new(payload);
    if r.u32()? != MAGIC {
        return Err(WireError::Malformed("bad magic"));
    }
    let version = r.u16()?;
    if !(MIN_VERSION..=VERSION).contains(&version) {
        return Err(WireError::Malformed("unsupported version"));
    }
    Ok(version)
}

/// Server → client session grant.
pub struct HelloOk {
    /// The minted session id.
    pub session_id: u64,
    /// Parameter/scale-plan fingerprint ([`plan_fingerprint`]).
    pub fingerprint: u64,
    /// The server's obscuring-noise bound ε.
    pub epsilon: f64,
    /// Number of protocol steps the architecture compiles into.
    pub n_steps: u32,
    /// The served architecture (geometry only — never weights).
    pub arch: Network,
    /// Negotiated protocol version (trailing `u16`; absent on v1 grants).
    pub version: u16,
}

/// Encode a session grant ([`HelloOk`] layout). The negotiated `version`
/// rides as a trailing `u16` that v1 decoders never look at.
pub fn encode_hello_ok(
    session_id: u64,
    fingerprint: u64,
    epsilon: f64,
    n_steps: u32,
    net: &Network,
    version: u16,
) -> Vec<u8> {
    let mut out = Vec::with_capacity(64);
    out.extend_from_slice(&session_id.to_le_bytes());
    out.extend_from_slice(&fingerprint.to_le_bytes());
    out.extend_from_slice(&epsilon.to_bits().to_le_bytes());
    out.extend_from_slice(&n_steps.to_le_bytes());
    encode_arch(&mut out, net);
    out.extend_from_slice(&version.to_le_bytes());
    out
}

/// Decode a session grant. A missing version trailer means a v1 server.
pub fn decode_hello_ok(payload: &[u8]) -> Result<HelloOk, WireError> {
    let mut r = ByteReader::new(payload);
    let session_id = r.u64()?;
    let fingerprint = r.u64()?;
    let epsilon = r.f64()?;
    let n_steps = r.u32()?;
    let arch = decode_arch(&mut r)?;
    let version = if r.remaining() >= 2 { r.u16()? } else { 1 };
    Ok(HelloOk { session_id, fingerprint, epsilon, n_steps, arch, version })
}

// ---- payload checksums (v2+) ----

/// FNV-1a 64-bit over `tag` then `payload` — the v2 bulk-frame checksum.
pub fn checksum(tag: u8, payload: &[u8]) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET ^ tag as u64;
    h = h.wrapping_mul(PRIME);
    for &b in payload {
        h ^= b as u64;
        h = h.wrapping_mul(PRIME);
    }
    h
}

/// Append the v2 checksum trailer to a bulk-frame payload in place.
pub fn seal(tag: u8, payload: &mut Vec<u8>) {
    let sum = checksum(tag, payload);
    payload.extend_from_slice(&sum.to_le_bytes());
}

/// Verify and remove the v2 checksum trailer of a bulk-frame payload.
/// A short payload or a mismatched sum is a [`WireError::Malformed`] —
/// the frame cannot be trusted and the round must not be processed.
pub fn verify_and_strip(tag: u8, payload: &mut Vec<u8>) -> Result<(), WireError> {
    if payload.len() < 8 {
        return Err(WireError::Malformed("missing frame checksum"));
    }
    let body = payload.len() - 8;
    let mut got = [0u8; 8];
    got.copy_from_slice(&payload[body..]);
    if u64::from_le_bytes(got) != checksum(tag, &payload[..body]) {
        return Err(WireError::Malformed("frame checksum mismatch"));
    }
    payload.truncate(body);
    Ok(())
}

// ---- round headers ----

/// `sid u64 + step u32` — the routing prefix of every online round frame.
pub fn round_header(session_id: u64, step: u32) -> Vec<u8> {
    let mut out = Vec::with_capacity(12);
    out.extend_from_slice(&session_id.to_le_bytes());
    out.extend_from_slice(&step.to_le_bytes());
    out
}

/// Read the `(session id, step)` routing prefix of a round payload.
pub fn read_round_header(r: &mut ByteReader) -> Result<(u64, u32), WireError> {
    Ok((r.u64()?, r.u32()?))
}

/// Peek the session id from a round payload without consuming it (the
/// connection reader uses this to pick the session-sticky worker).
pub fn peek_session_id(payload: &[u8]) -> Result<u64, WireError> {
    ByteReader::new(payload).u64()
}

// ---- incremental (non-blocking) frame reassembly ----

/// Incremental reassembler for the length-prefixed frames of
/// [`crate::protocol::transport`]: the non-blocking twin of
/// [`crate::protocol::transport::read_frame_limited`].
///
/// A readiness-driven reader ([`crate::serve::reactor`]) hands every chunk
/// the socket yields to [`FrameAssembler::push`] — a chunk may carry half a
/// header, the middle of a payload, or several coalesced frames — and then
/// drains completed frames with [`FrameAssembler::next_frame`]. The
/// reassembled `(tag, payload)` stream is byte-identical to what the
/// blocking reader produces from the same bytes (pinned by a
/// split-at-every-boundary test below).
///
/// Defensive like the blocking path: the length header is validated against
/// `max_frame` as soon as the 5 header bytes are present — *before* any
/// payload accumulates — so a corrupt length can never drive allocation.
pub struct FrameAssembler {
    max_frame: usize,
    buf: Vec<u8>,
    /// Consumed prefix of `buf` (compacted lazily to keep pushes amortized
    /// O(bytes) instead of O(bytes × frames)).
    start: usize,
}

impl FrameAssembler {
    /// An empty assembler rejecting payloads longer than `max_frame`.
    pub fn new(max_frame: usize) -> Self {
        Self { max_frame, buf: Vec::new(), start: 0 }
    }

    /// Feed bytes as they arrived from the socket (any chunking).
    pub fn push(&mut self, data: &[u8]) {
        // Compact once the dead prefix dominates, so the buffer does not
        // grow without bound across a long-lived connection.
        if self.start > 4096 && self.start * 2 >= self.buf.len() {
            self.buf.drain(..self.start);
            self.start = 0;
        }
        self.buf.extend_from_slice(data);
    }

    /// Bytes buffered but not yet returned as part of a complete frame.
    pub fn buffered(&self) -> usize {
        self.buf.len() - self.start
    }

    /// Pop the next complete frame, if one has fully arrived.
    ///
    /// `Ok(None)` means "need more bytes". An oversized length header is a
    /// hard [`WireError`] — the connection is unrecoverable because framing
    /// can no longer be trusted.
    pub fn next_frame(&mut self) -> Result<Option<(u8, Vec<u8>)>, WireError> {
        let avail = self.buf.len() - self.start;
        if avail < 5 {
            return Ok(None);
        }
        let hdr = &self.buf[self.start..self.start + 5];
        let tag = hdr[0];
        let len = u32::from_le_bytes([hdr[1], hdr[2], hdr[3], hdr[4]]) as usize;
        if len > self.max_frame {
            return Err(WireError::Malformed("frame payload exceeds maximum"));
        }
        if avail < 5 + len {
            return Ok(None);
        }
        let payload = self.buf[self.start + 5..self.start + 5 + len].to_vec();
        self.start += 5 + len;
        if self.start == self.buf.len() {
            self.buf.clear();
            self.start = 0;
        }
        Ok(Some((tag, payload)))
    }
}

// ---- error frames ----

/// Encode an `ERROR` frame payload.
pub fn encode_error(session_id: u64, code: u16, msg: &str) -> Vec<u8> {
    let mut out = Vec::with_capacity(10 + msg.len());
    out.extend_from_slice(&session_id.to_le_bytes());
    out.extend_from_slice(&code.to_le_bytes());
    out.extend_from_slice(msg.as_bytes());
    out
}

/// Decode an `ERROR` frame payload into `(session id, code, message)`.
pub fn decode_error(payload: &[u8]) -> Result<(u64, u16, String), WireError> {
    let mut r = ByteReader::new(payload);
    let sid = r.u64()?;
    let code = r.u16()?;
    let msg = String::from_utf8_lossy(r.take(r.remaining())?).into_owned();
    Ok((sid, code, msg))
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::nn::NetworkArch;
    use crate::util::rng::ChaCha20Rng;

    #[test]
    fn hello_roundtrip_and_rejects() {
        assert_eq!(decode_hello(&encode_hello()).unwrap(), VERSION);
        // A v1 greeting still negotiates (at v1).
        assert_eq!(decode_hello(&encode_hello_version(1)).unwrap(), 1);
        assert_eq!(decode_hello(&[1, 2, 3]), Err(WireError::Truncated));
        let mut bad = encode_hello();
        bad[0] ^= 0xff;
        assert_eq!(decode_hello(&bad), Err(WireError::Malformed("bad magic")));
        // A from-the-future version is rejected, not silently downgraded.
        assert_eq!(
            decode_hello(&encode_hello_version(VERSION + 1)),
            Err(WireError::Malformed("unsupported version"))
        );
        assert_eq!(
            decode_hello(&encode_hello_version(0)),
            Err(WireError::Malformed("unsupported version"))
        );
    }

    #[test]
    fn checksum_seal_verify_roundtrip_and_detects_flips() {
        let mut payload: Vec<u8> = (0u8..200).collect();
        let original = payload.clone();
        seal(TAG_SHARES, &mut payload);
        assert_eq!(payload.len(), original.len() + 8);
        verify_and_strip(TAG_SHARES, &mut payload).unwrap();
        assert_eq!(payload, original);

        // Any single flipped bit — in body or trailer — is caught.
        for byte in [0usize, 57, 199, 203] {
            let mut tampered = original.clone();
            seal(TAG_SHARES, &mut tampered);
            tampered[byte] ^= 0x10;
            assert_eq!(
                verify_and_strip(TAG_SHARES, &mut tampered),
                Err(WireError::Malformed("frame checksum mismatch")),
                "flip at byte {byte} went undetected"
            );
        }

        // The tag is part of the sum: a relabeled frame fails.
        let mut relabeled = original.clone();
        seal(TAG_SHARES, &mut relabeled);
        assert!(verify_and_strip(TAG_RECOVERY, &mut relabeled).is_err());

        // Too short to even hold a trailer.
        let mut tiny = vec![1u8, 2, 3];
        assert_eq!(
            verify_and_strip(TAG_SHARES, &mut tiny),
            Err(WireError::Malformed("missing frame checksum"))
        );
    }

    #[test]
    fn arch_roundtrip_all_layer_kinds() {
        let net = Network::build(NetworkArch::NetB, 1); // conv+relu+pool+fc
        let mut buf = Vec::new();
        encode_arch(&mut buf, &net);
        let back = decode_arch(&mut ByteReader::new(&buf)).unwrap();
        assert_eq!(back.input_shape, net.input_shape);
        assert_eq!(back.layers.len(), net.layers.len());
        for (a, b) in back.layers.iter().zip(&net.layers) {
            assert_eq!(a.kind, b.kind);
            assert!(a.weights.is_empty(), "weights must never cross the wire");
        }
        // The client-compiled spec matches the server's.
        let spec_a = crate::protocol::cheetah::ProtocolSpec::compile(&back).unwrap();
        let spec_b = crate::protocol::cheetah::ProtocolSpec::compile(&net).unwrap();
        assert_eq!(spec_a.steps.len(), spec_b.steps.len());
    }

    #[test]
    fn cts_roundtrip_fresh_and_evaluated() {
        let ctx = std::sync::Arc::new(Context::new(Params::new(1024, 20)));
        let mut rng = ChaCha20Rng::from_u64_seed(3);
        let enc = crate::phe::Encryptor::new(ctx.clone(), &mut rng);
        let ev = crate::phe::Evaluator::new(ctx.clone());
        let vals: Vec<i64> = (0..50).map(|i| i - 25).collect();
        let fresh = enc.encrypt_slots(&vals, &mut rng);
        let mut ntt = fresh.clone();
        ev.to_ntt(&mut ntt);
        let threes = vec![3i64; ctx.params.n];
        let evaluated = ev.mult_plain(&ntt, &ctx.mult_operand(&threes));

        let mut buf = Vec::new();
        encode_cts(&mut buf, &[fresh.clone(), evaluated]);
        let mut r = ByteReader::new(&buf);
        let back = decode_cts(&ctx, &mut r).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(&enc.decrypt_slots(&back[0])[..50], &vals[..]);
        let dec = enc.decrypt_slots(&back[1]);
        for i in 0..50 {
            assert_eq!(dec[i], vals[i] * 3);
        }
    }

    #[test]
    fn decode_cts_rejects_garbage_without_panicking() {
        let ctx = std::sync::Arc::new(Context::new(Params::new(1024, 20)));
        // Absurd count.
        let mut buf = Vec::new();
        buf.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode_cts(&ctx, &mut ByteReader::new(&buf)).is_err());
        // Length past end of buffer.
        let mut buf = Vec::new();
        buf.extend_from_slice(&1u32.to_le_bytes());
        buf.extend_from_slice(&1_000_000u32.to_le_bytes());
        buf.extend_from_slice(&[0u8; 16]);
        assert_eq!(
            decode_cts(&ctx, &mut ByteReader::new(&buf)),
            Err(WireError::Truncated)
        );
    }

    #[test]
    fn hello_ok_roundtrip() {
        let net = Network::build(NetworkArch::NetA, 1);
        let params = Params::new(1024, 20);
        let plan = ScalePlan::default_plan();
        let fp = plan_fingerprint(&params, &plan);
        let buf = encode_hello_ok(42, fp, 0.125, 3, &net, VERSION);
        let ok = decode_hello_ok(&buf).unwrap();
        assert_eq!(ok.session_id, 42);
        assert_eq!(ok.fingerprint, fp);
        assert_eq!(ok.epsilon, 0.125);
        assert_eq!(ok.n_steps, 3);
        assert_eq!(ok.arch.input_shape, net.input_shape);
        assert_eq!(ok.version, VERSION);

        // A trailer-less grant (a v1 server) decodes as version 1.
        let v1 = &buf[..buf.len() - 2];
        assert_eq!(decode_hello_ok(v1).unwrap().version, 1);
    }

    #[test]
    fn fingerprint_distinguishes_params_and_plan() {
        let plan = ScalePlan::default_plan();
        let a = plan_fingerprint(&Params::new(1024, 20), &plan);
        let b = plan_fingerprint(&Params::new(2048, 20), &plan);
        assert_ne!(a, b);
        let mut plan2 = plan;
        plan2.x_max = 4.0;
        let c = plan_fingerprint(&Params::new(1024, 20), &plan2);
        assert_ne!(a, c);
    }

    /// The satellite correctness test for non-blocking decode: a frame
    /// stream split at **every** byte boundary (header split, payload
    /// split) and fully coalesced reassembles byte-identically to the
    /// blocking [`crate::protocol::transport::read_frame`] path.
    #[test]
    fn chunked_reassembly_matches_blocking_reader_at_every_split() {
        use crate::protocol::transport::{read_frame, write_frame};

        // Two frames with distinct tags/payloads, including an empty one
        // later, so header/payload and frame/frame boundaries all occur.
        let mut stream = Vec::new();
        write_frame(&mut stream, TAG_SHARES, &[0xaa, 0xbb, 0xcc, 0xdd, 0xee]).unwrap();
        write_frame(&mut stream, TAG_RECOVERY, b"payload-two").unwrap();
        write_frame(&mut stream, TAG_BYE, &[]).unwrap();

        // Oracle: the blocking reader over the same byte stream.
        let mut cursor = std::io::Cursor::new(stream.clone());
        let mut want = Vec::new();
        while (cursor.position() as usize) < stream.len() {
            want.push(read_frame(&mut cursor).unwrap());
        }
        assert_eq!(want.len(), 3);

        // Every split point: bytes [0..split) in one push, the rest in a
        // second push. split=0 and split=len cover "everything coalesced
        // in one read" from both sides.
        for split in 0..=stream.len() {
            let mut asm = FrameAssembler::new(1024);
            let mut got = Vec::new();
            asm.push(&stream[..split]);
            while let Some(f) = asm.next_frame().unwrap() {
                got.push(f);
            }
            asm.push(&stream[split..]);
            while let Some(f) = asm.next_frame().unwrap() {
                got.push(f);
            }
            assert_eq!(got, want, "divergence at split {split}");
            assert_eq!(asm.buffered(), 0, "leftover bytes at split {split}");
        }

        // One-byte-at-a-time delivery (the most hostile chunking).
        let mut asm = FrameAssembler::new(1024);
        let mut got = Vec::new();
        for &b in &stream {
            asm.push(&[b]);
            while let Some(f) = asm.next_frame().unwrap() {
                got.push(f);
            }
        }
        assert_eq!(got, want);
    }

    #[test]
    fn assembler_rejects_oversized_length_before_payload_arrives() {
        let mut asm = FrameAssembler::new(16);
        // Header claims a 1 MiB payload; only the header is pushed.
        let mut hdr = vec![TAG_SHARES];
        hdr.extend_from_slice(&(1_048_576u32).to_le_bytes());
        asm.push(&hdr);
        assert_eq!(
            asm.next_frame(),
            Err(WireError::Malformed("frame payload exceeds maximum"))
        );
        // At the exact limit the frame is accepted.
        let mut asm = FrameAssembler::new(16);
        let mut frame = vec![TAG_SHARES];
        frame.extend_from_slice(&(16u32).to_le_bytes());
        frame.extend_from_slice(&[7u8; 16]);
        asm.push(&frame);
        let (tag, payload) = asm.next_frame().unwrap().expect("complete frame");
        assert_eq!((tag, payload.len()), (TAG_SHARES, 16));
    }

    #[test]
    fn assembler_compacts_consumed_prefix_on_long_streams() {
        let mut asm = FrameAssembler::new(64);
        let mut frame = vec![0x20u8];
        frame.extend_from_slice(&(32u32).to_le_bytes());
        frame.extend_from_slice(&[3u8; 32]);
        for _ in 0..1000 {
            asm.push(&frame);
            assert!(asm.next_frame().unwrap().is_some());
        }
        assert_eq!(asm.buffered(), 0);
    }

    #[test]
    fn round_header_and_error_roundtrip() {
        let hdr = round_header(7, 2);
        assert_eq!(peek_session_id(&hdr).unwrap(), 7);
        let mut r = ByteReader::new(&hdr);
        assert_eq!(read_round_header(&mut r).unwrap(), (7, 2));

        let e = encode_error(9, ERR_PROTOCOL, "step out of order");
        let (sid, code, msg) = decode_error(&e).unwrap();
        assert_eq!((sid, code, msg.as_str()), (9, ERR_PROTOCOL, "step out of order"));
    }
}
