//! The readiness reactor: the C10K serving front behind
//! [`SecureConfig::reactor`](super::SecureConfig::reactor).
//!
//! The thread-per-session front ([`super::SecureServer`]'s default) caps
//! session count at OS-thread count. This module replaces it with one
//! event-loop thread multiplexing every connection over a level-triggered
//! readiness poller ([`sys::Poller`] — raw `epoll` on Linux, `poll(2)`
//! elsewhere on unix; no new crates), so a handful of reactor + worker
//! threads serve thousands of concurrent sessions:
//!
//! * **Nonblocking I/O, incremental framing.** Every socket is
//!   nonblocking. Inbound bytes accumulate in a per-connection
//!   [`wire::FrameAssembler`]; outbound frames queue in a per-connection
//!   [`OutBuf`] that the reactor drains opportunistically and finishes on
//!   `EPOLLOUT` after a `WouldBlock`.
//! * **Compute off the loop.** A completed frame becomes a [`WorkerMsg`]
//!   dispatched to session-sticky protocol workers (`session_id %
//!   workers`, HELLOs round-robin) — the same handlers as the threads
//!   front, each worker's fan-out pinned via [`crate::par::with_threads`].
//!   The reactor thread itself never computes a round.
//! * **Bounded everything.** At most one frame per connection is in
//!   flight at a worker; further frames park in a small per-connection
//!   queue, and past [`PARK_CAP`] the reactor drops the socket's read
//!   interest so TCP flow control pushes back on the client. Worker
//!   channels are unbounded but can hold at most one message per
//!   connection, so memory stays bounded by connection count.
//! * **Backpressure and eviction.** Idle connections (no bytes, no work)
//!   are reaped after `idle_timeout`; a client that stops reading while
//!   output is queued is evicted after `write_timeout` without progress,
//!   or immediately once its write queue exceeds `max_write_queue` —
//!   the server never buffers unboundedly for a slow client.
//! * **Graceful fd exhaustion.** `EMFILE`/`ENFILE` (or the
//!   `max_sessions` cap) pauses accepting — the listener is deregistered
//!   so level-triggered readiness cannot spin — and accepting resumes as
//!   soon as a connection closes. Counted in
//!   `serve.reactor.accept_stalls`.
//! * **STATS stays inline.** The admin frame is answered on the reactor
//!   thread from the lock-free telemetry snapshot and bypasses the worker
//!   queues entirely, so it can neither stall behind nor stall queued
//!   rounds — the same property the threads front gives it.
//!
//! Wakeups from worker completions ride a `UnixStream` pair with an
//! atomic coalescing flag (at most one wake byte in flight), so a burst
//! of completions costs one `epoll_wait` return. Telemetry:
//! `serve.reactor.sessions` / `.sessions_peak` (gauges),
//! `.wakeups`, `.accept_stalls`, `.idle_evictions`, `.slow_evictions`
//! (counters), and `.write_queue_depth` (gauge, bytes queued server-wide).

pub(crate) mod sys;

use super::fault::FaultyStream;
use super::wire;
use super::{
    handle_hello, handle_round, lock_ok, send_error, ConnState, ReplySink, SecureConfig,
    ServeShared,
};
use std::collections::{HashMap, VecDeque};
use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::os::unix::net::UnixStream;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

const TOKEN_LISTENER: u64 = 0;
const TOKEN_WAKE: u64 = 1;
const FIRST_CONN_TOKEN: u64 = 2;

/// Parked frames per connection before the reactor stops reading that
/// socket and lets TCP flow control push back on the client.
const PARK_CAP: usize = 32;

/// Max bytes read from one connection per wakeup — fairness under a
/// flood; the level-triggered poller re-fires for the remainder.
const READ_BUDGET: usize = 256 * 1024;

/// Poll timeout, which doubles as the sweep cadence for idle-session
/// reaping and write-timeout enforcement.
const SWEEP_MS: u64 = 250;

/// Per-connection outbound frame queue, shared between the worker that
/// produces replies and the reactor that drains them to the socket.
struct OutBuf {
    frames: Mutex<VecDeque<Vec<u8>>>,
    bytes: AtomicUsize,
    closed: AtomicBool,
}

impl OutBuf {
    fn new() -> Self {
        Self {
            frames: Mutex::new(VecDeque::new()),
            bytes: AtomicUsize::new(0),
            closed: AtomicBool::new(false),
        }
    }

    /// Queue one encoded frame for the reactor to drain. Returns `false`
    /// once the connection is gone (frame dropped) — callers treat that
    /// exactly like a failed socket write.
    fn push(&self, tag: u8, payload: &[u8]) -> bool {
        let mut f = Vec::with_capacity(5 + payload.len());
        f.push(tag);
        f.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        f.extend_from_slice(payload);
        let len = f.len();
        {
            let mut q = lock_ok(&self.frames);
            if self.closed.load(Ordering::SeqCst) {
                return false;
            }
            q.push_back(f);
            self.bytes.fetch_add(len, Ordering::SeqCst);
        }
        crate::obs::gauge_add("serve.reactor.write_queue_depth", len as i64);
        true
    }

    fn pop(&self) -> Option<Vec<u8>> {
        let mut q = lock_ok(&self.frames);
        let f = q.pop_front();
        if let Some(f) = &f {
            self.bytes.fetch_sub(f.len(), Ordering::SeqCst);
        }
        f
    }

    fn queued_bytes(&self) -> usize {
        self.bytes.load(Ordering::SeqCst)
    }

    /// Mark the connection gone and discard queued frames
    /// (gauge-balanced; late pushes from an in-flight worker are refused).
    fn close(&self) {
        let drained = {
            let mut q = lock_ok(&self.frames);
            self.closed.store(true, Ordering::SeqCst);
            let d = q.iter().map(|f| f.len()).sum::<usize>();
            q.clear();
            self.bytes.fetch_sub(d, Ordering::SeqCst);
            d
        };
        if drained > 0 {
            crate::obs::gauge_add("serve.reactor.write_queue_depth", -(drained as i64));
        }
    }
}

/// [`ReplySink`] over a connection's [`OutBuf`]: workers append encoded
/// frames; the reactor owns the socket.
struct OutSink {
    out: Arc<OutBuf>,
}

impl ReplySink for OutSink {
    fn send(&mut self, tag: u8, payload: &[u8]) -> bool {
        self.out.push(tag, payload)
    }
}

/// One completed inbound frame, dispatched to a protocol worker. `v2`
/// carries the connection's negotiated wire version (payload checksums).
enum WorkerMsg {
    /// Session setup (round-robin across workers).
    Hello { token: u64, out: Arc<OutBuf>, conn: Arc<ConnState>, v2: bool },
    /// An online round (session-sticky: `session_id % workers`).
    Round { token: u64, out: Arc<OutBuf>, session_id: u64, tag: u8, payload: Vec<u8>, v2: bool },
}

/// Worker thread: each job runs under `catch_unwind` so a panicking round
/// (library bug or injected fault) costs the client a typed `ERROR`
/// frame — never a dead worker with its sessions parked forever. The
/// completion *always* reaches the reactor, so the connection's in-flight
/// slot is released on the panic path too.
fn worker_loop(rx: Receiver<WorkerMsg>, shared: Arc<ServeShared>, r: Arc<ReactorShared>) {
    for msg in rx {
        match msg {
            WorkerMsg::Hello { token, out, conn, v2 } => {
                let ok = catch_unwind(AssertUnwindSafe(|| {
                    shared.roll_worker_panic();
                    let mut sink = OutSink { out: out.clone() };
                    handle_hello(&shared, &mut sink, &conn, v2);
                }));
                if ok.is_err() {
                    crate::obs::inc("serve.worker_panics");
                    let mut sink = OutSink { out };
                    send_error(
                        &mut sink,
                        0,
                        wire::ERR_INTERNAL,
                        "internal error: session setup panicked",
                    );
                }
                shared.inflight.fetch_sub(1, Ordering::SeqCst);
                r.complete(token);
            }
            WorkerMsg::Round { token, out, session_id, tag, mut payload, v2 } => {
                let ok = catch_unwind(AssertUnwindSafe(|| {
                    shared.roll_worker_panic();
                    let mut sink = OutSink { out: out.clone() };
                    handle_round(&shared, session_id, tag, &mut payload, v2, &mut sink);
                }));
                if ok.is_err() {
                    crate::obs::inc("serve.worker_panics");
                    let mut sink = OutSink { out };
                    send_error(
                        &mut sink,
                        session_id,
                        wire::ERR_INTERNAL,
                        "internal error: round panicked",
                    );
                    shared.registry.remove(session_id);
                }
                shared.inflight.fetch_sub(1, Ordering::SeqCst);
                r.complete(token);
            }
        }
    }
}

/// State shared between the reactor thread, the protocol workers, and
/// the owning [`super::SecureServer`]: the stop flag, the completion
/// list, and the coalesced wake channel.
struct ReactorShared {
    stop: AtomicBool,
    wake_flag: AtomicBool,
    wake_tx: Mutex<UnixStream>,
    completions: Mutex<Vec<u64>>,
}

impl ReactorShared {
    /// Wake the reactor. The atomic flag coalesces bursts: at most one
    /// wake byte is in flight, so the (blocking) one-byte write can
    /// never fill the socketpair buffer and block a worker.
    fn wake(&self) {
        if !self.wake_flag.swap(true, Ordering::SeqCst) {
            let _ = lock_ok(&self.wake_tx).write(&[1u8]);
        }
    }

    /// Report a finished worker job for `token` and wake the reactor.
    fn complete(&self, token: u64) {
        lock_ok(&self.completions).push(token);
        self.wake();
    }
}

/// Owner handle for a running reactor; [`shutdown`](Self::shutdown)
/// stops and joins the event-loop thread (idempotent).
pub(super) struct ReactorHandle {
    shared: Arc<ReactorShared>,
    thread: Mutex<Option<JoinHandle<()>>>,
}

impl ReactorHandle {
    pub(super) fn shutdown(&self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        self.shared.wake();
        if let Some(h) = lock_ok(&self.thread).take() {
            let _ = h.join();
        }
    }
}

/// Per-connection reactor state: socket, frame assembler, write queue,
/// dispatch bookkeeping, and the timestamps the sweeps act on.
struct Conn {
    stream: FaultyStream<TcpStream>,
    out: Arc<OutBuf>,
    state: Arc<ConnState>,
    asm: wire::FrameAssembler,
    /// Negotiated wire version ≥ 2 (set by the `HELLO` decode): bulk
    /// frames carry payload checksums both ways.
    v2: bool,
    /// Frame currently being written (popped off `out`), plus cursor.
    pending: Vec<u8>,
    pending_pos: usize,
    /// Whether a frame from this connection is at a worker.
    in_flight: bool,
    /// Completed frames waiting for the in-flight one to finish.
    parked: VecDeque<(u8, Vec<u8>)>,
    read_paused: bool,
    want_write: bool,
    /// An error frame is queued; close once the queue drains.
    closing: bool,
    /// Output has been queued since the last fully-drained state —
    /// arms the write-stall clock.
    had_backlog: bool,
    last_activity: Instant,
    last_progress: Instant,
}

impl Conn {
    fn queued_bytes(&self) -> usize {
        self.out.queued_bytes() + (self.pending.len() - self.pending_pos)
    }
}

struct Reactor {
    poller: sys::Poller,
    listener: TcpListener,
    wake_rx: UnixStream,
    rshared: Arc<ReactorShared>,
    shared: Arc<ServeShared>,
    cfg: SecureConfig,
    conns: HashMap<u64, Conn>,
    next_token: u64,
    txs: Vec<Sender<WorkerMsg>>,
    rr: usize,
    accept_paused: bool,
    peak: usize,
    last_sweep: Instant,
}

impl Reactor {
    fn run(mut self) {
        let mut events: Vec<sys::Event> = Vec::new();
        let mut rdbuf = vec![0u8; 64 * 1024];
        while !self.rshared.stop.load(Ordering::SeqCst) {
            if self.poller.wait(SWEEP_MS as i32, &mut events).is_err() {
                // A broken poller cannot be waited on again; stop serving
                // rather than spin.
                break;
            }
            crate::obs::inc("serve.reactor.wakeups");
            let mut accept_ready = false;
            for ev in &events {
                match ev.token {
                    TOKEN_LISTENER => accept_ready = true,
                    TOKEN_WAKE => self.drain_wake(),
                    tok => {
                        if ev.readable {
                            self.on_readable(tok, &mut rdbuf);
                        }
                        if ev.writable {
                            self.flush_conn(tok);
                        }
                    }
                }
            }
            self.drain_completions();
            if accept_ready {
                self.do_accept();
            }
            if self.last_sweep.elapsed() >= Duration::from_millis(SWEEP_MS) {
                self.last_sweep = Instant::now();
                self.sweep();
            }
        }
        // Shutdown: retire every connection (sessions included); dropping
        // `txs` with `self` then disconnects the worker channels.
        let toks: Vec<u64> = self.conns.keys().copied().collect();
        for tok in toks {
            self.close_conn(tok);
        }
    }

    /// Drain the wake pipe, then clear the coalescing flag. Order
    /// matters: the flag must be cleared *before* the completion list is
    /// drained (it is, right after event processing), so a completion
    /// posted mid-drain writes a fresh wake byte instead of being lost.
    fn drain_wake(&mut self) {
        let mut buf = [0u8; 64];
        loop {
            match self.wake_rx.read(&mut buf) {
                Ok(0) => break,
                Ok(_) => continue,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => break,
            }
        }
        self.rshared.wake_flag.store(false, Ordering::SeqCst);
    }

    fn drain_completions(&mut self) {
        let done: Vec<u64> = std::mem::take(&mut *lock_ok(&self.rshared.completions));
        for tok in done {
            let next = {
                let Some(c) = self.conns.get_mut(&tok) else { continue };
                c.in_flight = false;
                c.last_activity = Instant::now();
                if c.closing {
                    None
                } else {
                    c.parked.pop_front()
                }
            };
            if let Some((tag, payload)) = next {
                self.dispatch(tok, tag, payload);
            }
            self.maybe_resume_reads(tok);
            self.flush_conn(tok);
        }
    }

    fn on_readable(&mut self, tok: u64, buf: &mut [u8]) {
        let mut disconnect = false;
        let mut total = 0usize;
        {
            let Some(c) = self.conns.get_mut(&tok) else { return };
            if c.closing || c.read_paused {
                return;
            }
            loop {
                match c.stream.read(buf) {
                    Ok(0) => {
                        disconnect = true;
                        break;
                    }
                    Ok(n) => {
                        c.asm.push(&buf[..n]);
                        total += n;
                        if total >= READ_BUDGET {
                            break;
                        }
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(_) => {
                        disconnect = true;
                        break;
                    }
                }
            }
            if total > 0 {
                c.last_activity = Instant::now();
            }
        }
        if total > 0 {
            self.process_frames(tok);
        }
        if disconnect {
            self.close_conn(tok);
        }
    }

    fn process_frames(&mut self, tok: u64) {
        loop {
            let frame = {
                let Some(c) = self.conns.get_mut(&tok) else { return };
                if c.closing {
                    return;
                }
                match c.asm.next_frame() {
                    Ok(Some(f)) => Ok(f),
                    Ok(None) => return,
                    Err(e) => Err(e.to_string()),
                }
            };
            match frame {
                Ok((tag, payload)) => self.handle_frame(tok, tag, payload),
                Err(msg) => {
                    // Corrupt framing: the byte stream is unrecoverable.
                    self.fail_conn(tok, 0, wire::ERR_PROTOCOL, &msg);
                    return;
                }
            }
        }
    }

    fn handle_frame(&mut self, tok: u64, tag: u8, payload: Vec<u8>) {
        crate::obs::add("serve.rx_bytes", payload.len() as u64 + 5);
        match tag {
            wire::TAG_STATS => {
                // Admin introspection stays inline on the reactor thread:
                // the snapshot capture is lock-free and the reply skips
                // the worker queues entirely, so it can neither stall
                // behind nor stall queued rounds.
                let body = crate::obs::snapshot().to_json();
                if let Some(c) = self.conns.get_mut(&tok) {
                    c.out.push(wire::TAG_STATS_OK, body.as_bytes());
                }
                self.flush_conn(tok);
            }
            wire::TAG_HELLO => match wire::decode_hello(&payload) {
                Ok(version) => {
                    if let Some(c) = self.conns.get_mut(&tok) {
                        c.v2 = version >= 2;
                    }
                    self.enqueue(tok, tag, payload);
                }
                Err(e) => self.fail_conn(tok, 0, wire::ERR_UNSUPPORTED, &e.to_string()),
            },
            wire::TAG_SHARES | wire::TAG_RECOVERY | wire::TAG_BYE => {
                match wire::peek_session_id(&payload) {
                    Ok(_) => self.enqueue(tok, tag, payload),
                    Err(e) => self.fail_conn(tok, 0, wire::ERR_PROTOCOL, &e.to_string()),
                }
            }
            other => self.fail_conn(
                tok,
                0,
                wire::ERR_PROTOCOL,
                &format!("unknown frame tag {other:#04x}"),
            ),
        }
    }

    fn enqueue(&mut self, tok: u64, tag: u8, payload: Vec<u8>) {
        let busy = {
            let Some(c) = self.conns.get_mut(&tok) else { return };
            c.in_flight || !c.parked.is_empty()
        };
        if busy {
            self.park(tok, tag, payload);
        } else {
            self.dispatch(tok, tag, payload);
        }
    }

    fn park(&mut self, tok: u64, tag: u8, payload: Vec<u8>) {
        let Some(c) = self.conns.get_mut(&tok) else { return };
        c.parked.push_back((tag, payload));
        if !c.read_paused && c.parked.len() >= PARK_CAP {
            c.read_paused = true;
            let (fd, ww) = (c.stream.as_raw_fd(), c.want_write);
            let _ = self.poller.modify(fd, tok, false, ww);
        }
    }

    fn dispatch(&mut self, tok: u64, tag: u8, payload: Vec<u8>) {
        let msg = {
            let Some(c) = self.conns.get_mut(&tok) else { return };
            c.in_flight = true;
            match tag {
                wire::TAG_HELLO => WorkerMsg::Hello {
                    token: tok,
                    out: c.out.clone(),
                    conn: c.state.clone(),
                    v2: c.v2,
                },
                _ => {
                    // Validated at parse time; a race would only misroute
                    // to a worker that then reports "unknown session".
                    let session_id = wire::peek_session_id(&payload).unwrap_or(0);
                    WorkerMsg::Round {
                        token: tok,
                        out: c.out.clone(),
                        session_id,
                        tag,
                        payload,
                        v2: c.v2,
                    }
                }
            }
        };
        let wi = match &msg {
            WorkerMsg::Hello { .. } => {
                self.rr = self.rr.wrapping_add(1);
                self.rr % self.txs.len()
            }
            WorkerMsg::Round { session_id, .. } => (*session_id % self.txs.len() as u64) as usize,
        };
        // Unbounded send — never blocks the reactor. Memory stays bounded
        // by the per-connection in-flight cap (one message per connection
        // at a worker; the rest park, then reads pause). The in-flight
        // count is taken *before* the send so a drain can never observe
        // zero while a job sits unclaimed in a worker channel.
        self.shared.inflight.fetch_add(1, Ordering::SeqCst);
        if self.txs[wi].send(msg).is_err() {
            self.shared.inflight.fetch_sub(1, Ordering::SeqCst);
        }
    }

    fn maybe_resume_reads(&mut self, tok: u64) {
        let Some(c) = self.conns.get_mut(&tok) else { return };
        if c.read_paused && !c.closing && c.parked.len() <= PARK_CAP / 2 {
            c.read_paused = false;
            let (fd, ww) = (c.stream.as_raw_fd(), c.want_write);
            let _ = self.poller.modify(fd, tok, true, ww);
        }
    }

    /// Drain the connection's write queue as far as the socket allows;
    /// arm `EPOLLOUT` on `WouldBlock`, and close/evict on write failure,
    /// drained-after-error, or write-queue overflow.
    fn flush_conn(&mut self, tok: u64) {
        let mut evicted_slow = false;
        let mut close = false;
        {
            let Some(c) = self.conns.get_mut(&tok) else { return };
            let mut wrote = 0usize;
            let mut dead = false;
            loop {
                if c.pending_pos == c.pending.len() {
                    c.pending.clear();
                    c.pending_pos = 0;
                    match c.out.pop() {
                        Some(f) => c.pending = f,
                        None => break,
                    }
                }
                match c.stream.write(&c.pending[c.pending_pos..]) {
                    Ok(0) => {
                        dead = true;
                        break;
                    }
                    Ok(n) => {
                        c.pending_pos += n;
                        wrote += n;
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(_) => {
                        dead = true;
                        break;
                    }
                }
            }
            if wrote > 0 {
                crate::obs::add("serve.tx_bytes", wrote as u64);
                crate::obs::gauge_add("serve.reactor.write_queue_depth", -(wrote as i64));
                c.last_progress = Instant::now();
            }
            let queued = c.queued_bytes();
            if queued > 0 && !c.had_backlog {
                c.had_backlog = true;
                c.last_progress = Instant::now();
            } else if queued == 0 {
                c.had_backlog = false;
            }
            if dead || (queued == 0 && c.closing) {
                close = true;
            } else if self.cfg.max_write_queue > 0 && queued > self.cfg.max_write_queue {
                evicted_slow = true;
            } else {
                let want_write = queued > 0;
                if want_write != c.want_write {
                    c.want_write = want_write;
                    let want_read = !c.read_paused && !c.closing;
                    let fd = c.stream.as_raw_fd();
                    let _ = self.poller.modify(fd, tok, want_read, want_write);
                }
            }
        }
        if evicted_slow {
            crate::obs::inc("serve.reactor.slow_evictions");
            close = true;
        }
        if close {
            self.close_conn(tok);
        }
    }

    /// Queue an error frame, stop reading, and close once it drains —
    /// the nonblocking equivalent of the threads front's "send error,
    /// drop connection".
    fn fail_conn(&mut self, tok: u64, sid: u64, code: u16, msg: &str) {
        {
            let Some(c) = self.conns.get_mut(&tok) else { return };
            c.out.push(wire::TAG_ERROR, &wire::encode_error(sid, code, msg));
            c.closing = true;
            c.parked.clear();
            c.want_write = true;
            let fd = c.stream.as_raw_fd();
            let _ = self.poller.modify(fd, tok, false, true);
        }
        self.flush_conn(tok);
    }

    /// Retire a connection: deregister, discard queued output, retire
    /// its sessions (an in-flight Hello sees `closed` and retires its
    /// own, exactly as on the threads front), and resume accepting if
    /// fd pressure had paused it.
    fn close_conn(&mut self, tok: u64) {
        let Some(c) = self.conns.remove(&tok) else { return };
        let _ = self.poller.deregister(c.stream.as_raw_fd());
        c.out.close();
        let rem = c.pending.len() - c.pending_pos;
        if rem > 0 {
            crate::obs::gauge_add("serve.reactor.write_queue_depth", -(rem as i64));
        }
        c.state.closed.store(true, Ordering::SeqCst);
        for sid in lock_ok(&c.state.sessions).drain(..) {
            self.shared.registry.remove(sid);
        }
        crate::obs::gauge_set("serve.reactor.sessions", self.conns.len() as i64);
        self.resume_accept_if_possible();
    }

    fn do_accept(&mut self) {
        let mut transient = 0u32;
        loop {
            if self.conns.len() >= self.cfg.max_sessions.max(1) {
                crate::obs::inc("serve.reactor.accept_stalls");
                self.pause_accept();
                return;
            }
            match self.listener.accept() {
                Ok((stream, _)) => {
                    // Injected accept-time reset: drop the socket before it
                    // ever becomes a connection (client sees RST/EOF).
                    if self.shared.fault.as_ref().is_some_and(|f| f.roll_accept_reset()) {
                        drop(stream);
                        continue;
                    }
                    self.add_conn(stream);
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) if matches!(e.raw_os_error(), Some(23) | Some(24)) => {
                    // ENFILE/EMFILE: out of fds. Deregister the listener
                    // (level-triggered readiness would otherwise spin the
                    // loop) and resume once a close frees fds.
                    crate::obs::inc("serve.reactor.accept_stalls");
                    self.pause_accept();
                    return;
                }
                Err(_) => {
                    // Per-connection accept failures (ECONNABORTED & co):
                    // skip, with a cap so a persistent failure cannot
                    // wedge this pass.
                    transient += 1;
                    if transient > 64 {
                        return;
                    }
                }
            }
        }
    }

    fn add_conn(&mut self, stream: TcpStream) {
        if stream.set_nonblocking(true).is_err() {
            return;
        }
        stream.set_nodelay(true).ok();
        let tok = self.next_token;
        self.next_token += 1;
        if self.poller.register(stream.as_raw_fd(), tok, true, false).is_err() {
            return;
        }
        let plan = self.shared.fault.as_ref().map(|f| f.next_plan());
        let now = Instant::now();
        self.conns.insert(
            tok,
            Conn {
                stream: FaultyStream::new(stream, plan),
                out: Arc::new(OutBuf::new()),
                state: Arc::new(ConnState {
                    closed: AtomicBool::new(false),
                    sessions: Mutex::new(Vec::new()),
                }),
                asm: wire::FrameAssembler::new(self.cfg.max_frame),
                v2: false,
                pending: Vec::new(),
                pending_pos: 0,
                in_flight: false,
                parked: VecDeque::new(),
                read_paused: false,
                want_write: false,
                closing: false,
                had_backlog: false,
                last_activity: now,
                last_progress: now,
            },
        );
        crate::obs::gauge_set("serve.reactor.sessions", self.conns.len() as i64);
        if self.conns.len() > self.peak {
            self.peak = self.conns.len();
            crate::obs::gauge_set("serve.reactor.sessions_peak", self.peak as i64);
        }
    }

    fn pause_accept(&mut self) {
        if !self.accept_paused {
            self.accept_paused = true;
            let _ = self.poller.deregister(self.listener.as_raw_fd());
        }
    }

    fn resume_accept_if_possible(&mut self) {
        if self.accept_paused && self.conns.len() < self.cfg.max_sessions.max(1) {
            let fd = self.listener.as_raw_fd();
            if self.poller.register(fd, TOKEN_LISTENER, true, false).is_ok() {
                self.accept_paused = false;
            }
        }
    }

    /// Periodic enforcement: evict writes stalled past `write_timeout`,
    /// reap sessions idle past `idle_timeout`, and retry a paused accept
    /// (in case fds freed outside our close path).
    fn sweep(&mut self) {
        let now = Instant::now();
        let mut slow: Vec<u64> = Vec::new();
        let mut idle: Vec<u64> = Vec::new();
        for (&tok, c) in &self.conns {
            let queued = c.queued_bytes();
            if c.had_backlog
                && queued > 0
                && now.duration_since(c.last_progress) > self.cfg.write_timeout
            {
                slow.push(tok);
            } else if self.cfg.idle_timeout > Duration::ZERO
                && !c.in_flight
                && !c.closing
                && c.parked.is_empty()
                && queued == 0
                && now.duration_since(c.last_activity) > self.cfg.idle_timeout
            {
                idle.push(tok);
            }
        }
        for tok in slow {
            crate::obs::inc("serve.reactor.slow_evictions");
            self.close_conn(tok);
        }
        for tok in idle {
            crate::obs::inc("serve.reactor.idle_evictions");
            self.close_conn(tok);
        }
        self.resume_accept_if_possible();
    }
}

/// Bind the reactor front onto an already-bound listener: spawn the
/// event-loop thread plus `cfg.workers` protocol workers (each pinned to
/// `cfg.threads` compute fan-out). Returns the owner handle and the
/// worker join handles.
pub(super) fn spawn(
    listener: TcpListener,
    shared: Arc<ServeShared>,
    cfg: SecureConfig,
) -> io::Result<(ReactorHandle, Vec<JoinHandle<()>>)> {
    listener.set_nonblocking(true)?;
    let (wake_tx, wake_rx) = UnixStream::pair()?;
    wake_rx.set_nonblocking(true)?;
    let mut poller = sys::Poller::new()?;
    poller.register(listener.as_raw_fd(), TOKEN_LISTENER, true, false)?;
    poller.register(wake_rx.as_raw_fd(), TOKEN_WAKE, true, false)?;
    let rshared = Arc::new(ReactorShared {
        stop: AtomicBool::new(false),
        wake_flag: AtomicBool::new(false),
        wake_tx: Mutex::new(wake_tx),
        completions: Mutex::new(Vec::new()),
    });
    let n_workers = cfg.workers.max(1);
    let mut txs = Vec::with_capacity(n_workers);
    let mut worker_threads = Vec::with_capacity(n_workers);
    for _ in 0..n_workers {
        let (tx, rx) = channel::<WorkerMsg>();
        txs.push(tx);
        let shared = shared.clone();
        let rshared = rshared.clone();
        let threads = cfg.threads;
        worker_threads.push(std::thread::spawn(move || {
            crate::par::with_threads(threads, || worker_loop(rx, shared, rshared))
        }));
    }
    let reactor = Reactor {
        poller,
        listener,
        wake_rx,
        rshared: rshared.clone(),
        shared,
        cfg,
        conns: HashMap::new(),
        next_token: FIRST_CONN_TOKEN,
        txs,
        rr: 0,
        accept_paused: false,
        peak: 0,
        last_sweep: Instant::now(),
    };
    let thread = std::thread::spawn(move || reactor.run());
    Ok((ReactorHandle { shared: rshared, thread: Mutex::new(Some(thread)) }, worker_threads))
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    /// The write-queue accounting that backpressure and eviction key on:
    /// push/pop stay byte-balanced, and a closed buffer refuses frames
    /// (the signal a worker reads as "connection gone").
    #[test]
    fn outbuf_accounts_bytes_and_refuses_after_close() {
        let out = OutBuf::new();
        assert!(out.push(0x23, &[1, 2, 3]));
        assert!(out.push(0x24, &[]));
        assert_eq!(out.queued_bytes(), (5 + 3) + 5);
        let first = out.pop().expect("frame queued");
        assert_eq!(first[0], 0x23);
        assert_eq!(&first[5..], &[1, 2, 3]);
        assert_eq!(out.queued_bytes(), 5);
        out.close();
        assert_eq!(out.queued_bytes(), 0, "close discards queued frames");
        assert!(!out.push(0x30, &[9]), "closed buffer must refuse frames");
        assert!(out.pop().is_none());
    }
}
