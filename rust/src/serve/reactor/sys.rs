//! Readiness polling over raw OS interfaces — the dependency-free
//! substrate under the serve reactor.
//!
//! Linux gets `epoll` through direct `extern "C"` bindings against the
//! libc std already links (no new crates); every other unix target falls
//! back to `poll(2)`. Both sit behind one **level-triggered** [`Poller`]
//! API: register a fd with a `u64` token and an interest set, then
//! [`Poller::wait`] reports the ready tokens. Level-triggered semantics
//! are load-bearing for the reactor: a partially-drained read buffer or
//! write queue simply re-fires on the next wait, so the event loop never
//! has to prove it consumed everything before sleeping.

use std::io;

/// One readiness notification out of [`Poller::wait`].
///
/// Error/hangup conditions are folded into `readable`/`writable` (both
/// set) instead of a separate flag: the reactor's next `read`/`write`
/// then surfaces the real `io::Error` (or EOF), which is the only
/// error detail worth acting on.
#[derive(Clone, Copy, Debug)]
pub(crate) struct Event {
    /// The token the fd was registered under.
    pub(crate) token: u64,
    /// A `read` will not block (data, EOF, or a pending error).
    pub(crate) readable: bool,
    /// A `write` will not block (buffer space or a pending error).
    pub(crate) writable: bool,
}

pub(crate) use imp::Poller;

#[cfg(target_os = "linux")]
mod imp {
    use super::{io, Event};
    use std::os::unix::io::RawFd;

    // Stable values from the Linux UAPI headers.
    const EPOLL_CLOEXEC: i32 = 0o2000000;
    const EPOLL_CTL_ADD: i32 = 1;
    const EPOLL_CTL_DEL: i32 = 2;
    const EPOLL_CTL_MOD: i32 = 3;
    const EPOLLIN: u32 = 0x001;
    const EPOLLOUT: u32 = 0x004;
    const EPOLLERR: u32 = 0x008;
    const EPOLLHUP: u32 = 0x010;

    /// Mirror of the kernel's `struct epoll_event`. x86-64 is the one
    /// ABI where the struct is packed (no padding between `events` and
    /// `data`); everywhere else it is naturally aligned. Fields of the
    /// packed variant must only ever be read **by value** — taking a
    /// reference into a packed struct is unsound.
    #[cfg_attr(target_arch = "x86_64", repr(C, packed))]
    #[cfg_attr(not(target_arch = "x86_64"), repr(C))]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    extern "C" {
        fn epoll_create1(flags: i32) -> i32;
        fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
        fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout: i32) -> i32;
        fn close(fd: i32) -> i32;
    }

    /// A level-triggered epoll instance.
    pub(crate) struct Poller {
        epfd: i32,
        buf: Vec<EpollEvent>,
    }

    impl Poller {
        pub(crate) fn new() -> io::Result<Self> {
            // SAFETY: plain syscall; the returned fd is owned by `self`
            // and closed exactly once in `Drop`.
            let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            if epfd < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(Self { epfd, buf: vec![EpollEvent { events: 0, data: 0 }; 1024] })
        }

        fn ctl(&self, op: i32, fd: RawFd, token: u64, r: bool, w: bool) -> io::Result<()> {
            let mut events = 0u32;
            if r {
                events |= EPOLLIN;
            }
            if w {
                events |= EPOLLOUT;
            }
            let mut ev = EpollEvent { events, data: token };
            // SAFETY: `ev` outlives the call; the kernel copies it. A
            // non-null event pointer is also valid (and portable) for
            // EPOLL_CTL_DEL, which ignores it.
            let rc = unsafe { epoll_ctl(self.epfd, op, fd, &mut ev) };
            if rc < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(())
        }

        /// Start watching `fd` under `token` with the given interest set.
        pub(crate) fn register(
            &mut self,
            fd: RawFd,
            token: u64,
            r: bool,
            w: bool,
        ) -> io::Result<()> {
            self.ctl(EPOLL_CTL_ADD, fd, token, r, w)
        }

        /// Replace the interest set of an already-registered fd.
        pub(crate) fn modify(&mut self, fd: RawFd, token: u64, r: bool, w: bool) -> io::Result<()> {
            self.ctl(EPOLL_CTL_MOD, fd, token, r, w)
        }

        /// Stop watching `fd`.
        pub(crate) fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
            self.ctl(EPOLL_CTL_DEL, fd, 0, false, false)
        }

        /// Block up to `timeout_ms` (-1 = forever) and collect ready
        /// events into `out` (cleared first). EINTR reads as "no events".
        pub(crate) fn wait(&mut self, timeout_ms: i32, out: &mut Vec<Event>) -> io::Result<()> {
            out.clear();
            // SAFETY: `buf` is a live, properly-sized array of
            // `EpollEvent`; the kernel writes at most `buf.len()` entries.
            let n = unsafe {
                epoll_wait(self.epfd, self.buf.as_mut_ptr(), self.buf.len() as i32, timeout_ms)
            };
            if n < 0 {
                let err = io::Error::last_os_error();
                if err.kind() == io::ErrorKind::Interrupted {
                    return Ok(());
                }
                return Err(err);
            }
            for i in 0..n as usize {
                // Copy the (possibly packed) struct out by value before
                // touching fields — see the `EpollEvent` doc.
                let e = self.buf[i];
                let bits = e.events;
                let fired_err = bits & (EPOLLERR | EPOLLHUP) != 0;
                out.push(Event {
                    token: e.data,
                    readable: bits & EPOLLIN != 0 || fired_err,
                    writable: bits & EPOLLOUT != 0 || fired_err,
                });
            }
            Ok(())
        }
    }

    impl Drop for Poller {
        fn drop(&mut self) {
            // SAFETY: closes the fd this struct owns, exactly once.
            unsafe { close(self.epfd) };
        }
    }
}

#[cfg(all(unix, not(target_os = "linux")))]
mod imp {
    use super::{io, Event};
    use std::collections::HashMap;
    use std::os::unix::io::RawFd;

    const POLLIN: i16 = 0x001;
    const POLLOUT: i16 = 0x004;
    const POLLERR: i16 = 0x008;
    const POLLHUP: i16 = 0x010;
    const POLLNVAL: i16 = 0x020;

    /// Mirror of `struct pollfd` (identical layout across unix ABIs).
    #[repr(C)]
    #[derive(Clone, Copy)]
    struct PollFd {
        fd: i32,
        events: i16,
        revents: i16,
    }

    extern "C" {
        // `nfds_t` is `unsigned long` on most targets and `unsigned int`
        // on some BSDs; a zero-extended in-range value is passed
        // correctly under every 64-bit unix calling convention.
        fn poll(fds: *mut PollFd, nfds: std::ffi::c_ulong, timeout: i32) -> i32;
    }

    /// `poll(2)` fallback: the interest set lives in user space and the
    /// pollfd array is rebuilt per wait — O(fds) per call, acceptable
    /// for a portability fallback (Linux uses the epoll path).
    pub(crate) struct Poller {
        interest: HashMap<RawFd, (u64, bool, bool)>,
        buf: Vec<PollFd>,
    }

    impl Poller {
        pub(crate) fn new() -> io::Result<Self> {
            Ok(Self { interest: HashMap::new(), buf: Vec::new() })
        }

        /// Start watching `fd` under `token` with the given interest set.
        pub(crate) fn register(
            &mut self,
            fd: RawFd,
            token: u64,
            r: bool,
            w: bool,
        ) -> io::Result<()> {
            self.interest.insert(fd, (token, r, w));
            Ok(())
        }

        /// Replace the interest set of an already-registered fd.
        pub(crate) fn modify(&mut self, fd: RawFd, token: u64, r: bool, w: bool) -> io::Result<()> {
            self.interest.insert(fd, (token, r, w));
            Ok(())
        }

        /// Stop watching `fd`.
        pub(crate) fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
            self.interest.remove(&fd);
            Ok(())
        }

        /// Block up to `timeout_ms` (-1 = forever) and collect ready
        /// events into `out` (cleared first). EINTR reads as "no events".
        pub(crate) fn wait(&mut self, timeout_ms: i32, out: &mut Vec<Event>) -> io::Result<()> {
            out.clear();
            self.buf.clear();
            for (&fd, &(_, r, w)) in &self.interest {
                let mut events = 0i16;
                if r {
                    events |= POLLIN;
                }
                if w {
                    events |= POLLOUT;
                }
                self.buf.push(PollFd { fd, events, revents: 0 });
            }
            // SAFETY: `buf` is a live array of `PollFd`; the kernel only
            // writes the `revents` fields of its `len()` entries.
            let n = unsafe {
                poll(self.buf.as_mut_ptr(), self.buf.len() as std::ffi::c_ulong, timeout_ms)
            };
            if n < 0 {
                let err = io::Error::last_os_error();
                if err.kind() == io::ErrorKind::Interrupted {
                    return Ok(());
                }
                return Err(err);
            }
            for pf in &self.buf {
                if pf.revents == 0 {
                    continue;
                }
                let Some(&(token, _, _)) = self.interest.get(&pf.fd) else { continue };
                let fired_err = pf.revents & (POLLERR | POLLHUP | POLLNVAL) != 0;
                out.push(Event {
                    token,
                    readable: pf.revents & POLLIN != 0 || fired_err,
                    writable: pf.revents & POLLOUT != 0 || fired_err,
                });
            }
            Ok(())
        }
    }
}

#[cfg(all(test, unix))]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::os::unix::io::AsRawFd;
    use std::os::unix::net::UnixStream;

    /// Register / wait / modify / deregister against a real socketpair:
    /// readable fires only once data is queued, and deregistered fds go
    /// silent — exercised on whichever impl this target selects.
    #[test]
    fn poller_reports_readability_level_triggered() {
        let (mut a, mut b) = UnixStream::pair().unwrap();
        b.set_nonblocking(true).unwrap();
        let mut poller = Poller::new().unwrap();
        poller.register(b.as_raw_fd(), 7, true, false).unwrap();

        let mut events = Vec::new();
        poller.wait(0, &mut events).unwrap();
        assert!(events.iter().all(|e| e.token != 7), "idle socket must not fire");

        a.write_all(b"x").unwrap();
        poller.wait(1000, &mut events).unwrap();
        assert!(events.iter().any(|e| e.token == 7 && e.readable), "data must fire readable");

        // Level-triggered: unconsumed data fires again.
        poller.wait(0, &mut events).unwrap();
        assert!(events.iter().any(|e| e.token == 7 && e.readable), "level-trigger must re-fire");

        let mut sink = [0u8; 8];
        let _ = b.read(&mut sink).unwrap();
        poller.wait(0, &mut events).unwrap();
        assert!(events.iter().all(|e| e.token != 7), "drained socket must go quiet");

        // Write interest on an empty send buffer fires writable.
        poller.modify(b.as_raw_fd(), 7, false, true).unwrap();
        poller.wait(1000, &mut events).unwrap();
        assert!(events.iter().any(|e| e.token == 7 && e.writable));

        poller.deregister(b.as_raw_fd()).unwrap();
        a.write_all(b"y").unwrap();
        poller.wait(0, &mut events).unwrap();
        assert!(events.iter().all(|e| e.token != 7), "deregistered fd must go silent");
    }
}
