//! The **GALA** greedy-packing backend (Zhang et al., NDSS'21 — the same
//! authors' follow-up to CHEETAH's comparison target): block-combined
//! matrix-vector products and kernel-grouped convolution that cut the
//! dominant `Perm` (rotation) count of GAZELLE-style HE linear algebra.
//!
//! Two ideas, both implemented on the exact same PHE substrate as the
//! [`crate::protocol::gazelle`] baseline so op counts are comparable
//! slot-for-slot:
//!
//! * [`fc`] — **share-domain rotate-and-sum**: the hybrid GAZELLE layout
//!   already tiles the input across the half-row, so after one `MultPlain`
//!   per output chunk every output is a *contiguous run of partial
//!   products*. GALA stops there: the `log2(n_i)` rotate-and-sum tree is
//!   absorbed into secret-share generation (the client sums the run in
//!   plaintext after decryption). `#Perm = 0`, `#Mult = ⌈n_o/g_o⌉` —
//!   strictly below hybrid's `⌈n_o/g_o⌉·log2(n_i)` permutations whenever
//!   `n_i ≥ 2`.
//! * [`conv`] — **first-rotate-then-multiply with gap packing**: input
//!   channels are packed `γ` to a ciphertext (separated by a `c·(w+1)`-slot
//!   gap that reproduces the flat zero-tail border semantics) and
//!   replicated `ρ` times; per input-group the `r−1` column rotations are
//!   hoisted and shared by *every* output channel, and per output-group the
//!   `r−1` row rotations ride on accumulated partial sums (a baby-step /
//!   giant-step split of the kernel offset grid). `#Perm =
//!   (⌈c_i/γ⌉+⌈c_o/ρ⌉)(r−1)` versus the baseline's
//!   `min(c_i,c_o)·(r²−1)` independent per-(channel, offset) rotations.
//!
//! The per-output slot layout is no longer "one slot per output": an output
//! is the plaintext sum of a [`SlotRead`] (a strided run of slots). The
//! GAZELLE runner ([`crate::protocol::gazelle::runner`]) masks every slot of
//! a read individually, so the obscuring guarantee is unchanged.
//!
//! Counted formulas ([`gala_fc_counts`], [`gala_conv_counts`], with
//! [`hybrid_fc_counts`] / [`gazelle_conv_counts`] for the baseline) are
//! pinned against real counted evaluator runs in this module's tests and
//! asserted strictly below the baseline on every zoo shape.

pub mod conv;
pub mod fc;

pub use conv::{
    conv, gala_conv_counts, gala_conv_galois_keys, pack_conv_input, GalaConvGeometry,
};
pub use fc::{fc, gala_fc_counts};

/// A strided run of ciphertext slots whose plaintext sum is one protocol
/// output. The hybrid GAZELLE layout is the degenerate `count == 1` case.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SlotRead {
    /// Index of the ciphertext holding the run.
    pub ct: usize,
    /// First slot of the run.
    pub start: usize,
    /// Distance between consecutive slots of the run.
    pub stride: usize,
    /// Number of slots summed into the output.
    pub count: usize,
}

impl SlotRead {
    /// A single-slot read (the classic one-output-per-slot layout).
    pub fn single(ct: usize, slot: usize) -> Self {
        SlotRead { ct, start: slot, stride: 1, count: 1 }
    }

    /// The slot indices of the run, in order.
    pub fn slots(&self) -> impl Iterator<Item = usize> + '_ {
        (0..self.count).map(move |k| self.start + k * self.stride)
    }
}

/// Hybrid-GAZELLE FC op counts `(perm, mult)` for a `n_o × n_i_real`
/// layer on half-rows of `row` slots: `⌈n_o/g_o⌉` chunks, each 1 Mult +
/// `log2(n_i)` Perms (the rotate-and-sum tree), `g_o = max(1, row/n_i)`.
pub fn hybrid_fc_counts(row: usize, n_i_real: usize, n_o: usize) -> (u64, u64) {
    let n_i = super::gazelle::fc::pad_pow2(n_i_real);
    let g_o = (row / n_i).max(1);
    let n_chunks = n_o.div_ceil(g_o) as u64;
    (n_chunks * n_i.trailing_zeros() as u64, n_chunks)
}

/// Baseline GAZELLE conv op counts `(perm, mult)` with the runner's
/// variant choice (input-rotation when `c_i ≤ c_o`, else output-rotation):
/// `min(c_i, c_o)·(r²−1)` Perms, `c_i·c_o·r²` Mults.
pub fn gazelle_conv_counts(c_i: usize, c_o: usize, r: usize) -> (u64, u64) {
    let rot_channels = c_i.min(c_o) as u64;
    (rot_channels * (r * r - 1) as u64, (c_i * c_o * r * r) as u64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{Network, NetworkArch};
    use crate::protocol::cheetah::LinearSpec;
    use crate::protocol::cheetah::ProtocolSpec;

    fn zoo_net(arch: NetworkArch) -> Network {
        // Big ImageNet-era nets at the 0.125 test scale the planner and
        // benches use; everything else full size.
        match arch {
            NetworkArch::AlexNet | NetworkArch::Vgg16 => Network::build_scaled(arch, 5, 0.125),
            _ => Network::build(arch, 5),
        }
    }

    /// The acceptance property of the GALA backend: on *every* zoo
    /// network's FC and conv shapes, GALA's analytic Perm count is
    /// strictly below the hybrid/IR-OR GAZELLE path (whenever the
    /// baseline rotates at all), at both half-row sizes the parameter
    /// ladder uses.
    #[test]
    fn gala_perms_beat_gazelle_on_every_zoo_shape() {
        for row in [2048usize, 4096] {
            for arch in NetworkArch::all() {
                let net = zoo_net(arch);
                let spec = ProtocolSpec::compile(&net).expect("zoo net must compile");
                let mut linear_steps = 0;
                for step in &spec.steps {
                    match &step.linear {
                        LinearSpec::Conv(cp) => {
                            let (c_i, _, w) = cp.in_shape;
                            let hw = cp.in_shape.1 * cp.in_shape.2;
                            let c_o = cp.out_shape.0;
                            let r = cp.kernel;
                            let (gz_perm, _) = gazelle_conv_counts(c_i, c_o, r);
                            let (ga_perm, _) =
                                gala_conv_counts(row, (c_i, cp.in_shape.1, cp.in_shape.2), c_o, r);
                            assert!(
                                ga_perm <= gz_perm,
                                "{arch:?} conv {c_i}x{hw}(w={w})->{c_o} r={r} row={row}: \
                                 gala {ga_perm} > gazelle {gz_perm}"
                            );
                            if r >= 2 {
                                assert!(
                                    ga_perm < gz_perm,
                                    "{arch:?} conv {c_i}x{hw}->{c_o} r={r} row={row}: \
                                     gala {ga_perm} not strictly below gazelle {gz_perm}"
                                );
                            }
                            linear_steps += 1;
                        }
                        LinearSpec::Fc(fp) => {
                            let (hy_perm, hy_mult) = hybrid_fc_counts(row, fp.n_i, fp.n_o);
                            let (ga_perm, ga_mult) = gala_fc_counts(row, fp.n_i, fp.n_o);
                            assert_eq!(ga_perm, 0, "{arch:?} fc {}x{}", fp.n_i, fp.n_o);
                            assert_eq!(ga_mult, hy_mult, "{arch:?} fc {}x{}", fp.n_i, fp.n_o);
                            if crate::protocol::gazelle::fc::pad_pow2(fp.n_i) >= 2 {
                                assert!(
                                    hy_perm > ga_perm,
                                    "{arch:?} fc {}x{} row={row}: hybrid {hy_perm} perms \
                                     not strictly above gala {ga_perm}",
                                    fp.n_i,
                                    fp.n_o
                                );
                            }
                            linear_steps += 1;
                        }
                        LinearSpec::AvgPool { .. } => {} // zero-ciphertext local step
                    }
                }
                assert!(linear_steps > 0, "{arch:?}: no linear steps compared");
            }
        }
    }

    #[test]
    fn slot_read_iterates_strided_run() {
        let r = SlotRead { ct: 2, start: 10, stride: 7, count: 3 };
        assert_eq!(r.slots().collect::<Vec<_>>(), vec![10, 17, 24]);
        let s = SlotRead::single(0, 5);
        assert_eq!(s.slots().collect::<Vec<_>>(), vec![5]);
        assert_eq!(s.count, 1);
    }

    #[test]
    fn hybrid_fc_formula_matches_pinned_table4_cases() {
        // The same cases `hybrid_perm_count_matches_paper_table4` pins with
        // a counted evaluator run (row = 512 at n = 1024).
        assert_eq!(hybrid_fc_counts(512, 512, 4), (4 * 9, 4));
        assert_eq!(hybrid_fc_counts(512, 128, 16), (4 * 7, 4));
    }
}
