//! GALA block-combined matrix-vector product: the hybrid GAZELLE packing
//! with the rotate-and-sum tree moved into secret-share generation.
//!
//! The hybrid layout tiles the (power-of-two padded) input `row/n_i` times
//! across the half-row; one `MultPlain` against the chunk's weight mask
//! leaves output `o = chunk·g_o + t` as the `n_i` partial products in slots
//! `[t·n_i, (t+1)·n_i)`. GAZELLE then spends `log2(n_i)` `Perm`s per chunk
//! collapsing each run to one slot. GALA observes that the server's next
//! move is additive re-sharing anyway: the client can sum the run in
//! plaintext after decryption (and the server masks every slot of the run,
//! so nothing extra is revealed — see [`super::SlotRead`]). The entire
//! rotation tree disappears: `#Perm = 0`, `#Mult = ⌈n_o/g_o⌉`, and no FC
//! Galois keys are shipped offline at all.

use super::SlotRead;
use crate::fixed::ScalePlan;
use crate::nn::layers::Layer;
use crate::phe::{Ciphertext, Evaluator};
use crate::protocol::gazelle::fc::pad_pow2;

/// GALA FC op counts `(perm, mult)` for an `n_o × n_i_real` layer on
/// half-rows of `row` slots: zero permutations, one `MultPlain` per chunk
/// of `g_o = max(1, row/n_i)` outputs.
pub fn gala_fc_counts(row: usize, n_i_real: usize, n_o: usize) -> (u64, u64) {
    let n_i = pad_pow2(n_i_real);
    let g_o = (row / n_i).max(1);
    (0, n_o.div_ceil(g_o) as u64)
}

/// GALA matrix-vector product over a hybrid-packed input ciphertext (see
/// [`crate::protocol::gazelle::fc::pack_fc_input`] with
/// [`crate::protocol::gazelle::FcMethod::Hybrid`] — the packing is shared
/// with the baseline). Returns one ciphertext per output chunk and, per
/// output, the [`SlotRead`] whose plaintext sum is that output. Weights
/// are quantized at `plan.k` divided by `weight_div` (absorbing preceding
/// mean-pools), identically to the baseline path.
pub fn fc(
    ev: &Evaluator,
    in_ct: &Ciphertext,
    layer: &Layer,
    n_i_real: usize,
    plan: &ScalePlan,
    weight_div: f64,
) -> (Vec<Ciphertext>, Vec<SlotRead>) {
    let ctx = &*ev.ctx;
    let crate::nn::layers::LayerKind::Fc { out_features: n_o } = layer.kind else {
        panic!("fc requires Fc layer")
    };
    let n_i = pad_pow2(n_i_real);
    let row = ctx.params.row_size();
    let quant = |v: f64| plan.quant_k(v / weight_div);
    let w_at = |o: usize, j: usize| -> i64 {
        if j < n_i_real {
            quant(layer.fc_w(n_i_real, o, j))
        } else {
            0
        }
    };

    let g_o = (row / n_i).max(1);
    let n_chunks = n_o.div_ceil(g_o);
    let mut outs = Vec::with_capacity(n_chunks);
    let mut map = Vec::with_capacity(n_o);
    for chunk in 0..n_chunks {
        let mut m = vec![0i64; row];
        for t in 0..g_o {
            let o = chunk * g_o + t;
            if o >= n_o {
                break;
            }
            for j in 0..n_i {
                m[t * n_i + j] = w_at(o, j);
            }
        }
        let op = ctx.mult_operand(&m);
        // One MultPlain; no rotate-and-sum tree — the client sums the run.
        outs.push(ev.mult_plain(in_ct, &op));
        for t in 0..g_o {
            let o = chunk * g_o + t;
            if o < n_o {
                map.push(SlotRead { ct: chunk, start: t * n_i, stride: 1, count: n_i });
            }
        }
    }
    (outs, map)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::phe::{Context, Encryptor, Params};
    use crate::protocol::gazelle::fc::{
        fc as gazelle_fc, fc_galois_keys, fc_reference, pack_fc_input, FcMethod,
    };
    use crate::util::rng::{ChaCha20Rng, SplitMix64};
    use std::sync::Arc;

    fn setup(n_i: usize, n_o: usize, seed: u64) -> (Arc<Context>, Layer, Vec<i64>, Vec<i64>) {
        let ctx = Arc::new(Context::new(Params::new(1024, 20)));
        let plan = crate::fixed::ScalePlan::default_plan();
        let mut srng = SplitMix64::new(seed);
        let mut layer = Layer::fc(n_o);
        layer.init_weights(1, 1, n_i, &mut srng);
        let x_q: Vec<i64> = (0..n_i).map(|_| srng.gen_i64_range(-128, 128)).collect();
        let reference = fc_reference(&x_q, &layer, &plan, 1.0);
        (ctx, layer, x_q, reference)
    }

    /// Satellite: GALA's counted Perm/Mult match [`gala_fc_counts`] on the
    /// paper-table shapes and sit strictly below the hybrid baseline.
    #[test]
    fn gala_perm_count_matches_formula_and_beats_hybrid() {
        for (n_o, n_i) in [(4usize, 512usize), (16, 128), (10, 100)] {
            let (ctx, layer, x_q, _) = setup(n_i, n_o, 70 + n_o as u64);
            let plan = crate::fixed::ScalePlan::default_plan();
            let mut rng = ChaCha20Rng::from_u64_seed(7);
            let enc = Encryptor::new(ctx.clone(), &mut rng);
            let ev = crate::phe::Evaluator::new(ctx.clone());
            let gk = fc_galois_keys(&ctx, &enc.sk, n_i, &mut rng);
            let packed = pack_fc_input(&ctx, &x_q, FcMethod::Hybrid);
            let mut ct = enc.encrypt_slots(&packed, &mut rng);
            ev.to_ntt(&mut ct);

            ev.reset_counts();
            let _ = fc(&ev, &ct, &layer, n_i, &plan, 1.0);
            let gala = ev.counts();
            ev.reset_counts();
            let _ = gazelle_fc(&ev, FcMethod::Hybrid, &ct, &layer, n_i, &plan, 1.0, &gk);
            let hybrid = ev.counts();

            let row = ctx.params.row_size();
            let (ga_perm, ga_mult) = gala_fc_counts(row, n_i, n_o);
            let (hy_perm, hy_mult) = super::super::hybrid_fc_counts(row, n_i, n_o);
            assert_eq!(gala.perm, ga_perm, "{n_o}x{n_i} gala perm");
            assert_eq!(gala.mult, ga_mult, "{n_o}x{n_i} gala mult");
            assert_eq!(hybrid.perm, hy_perm, "{n_o}x{n_i} hybrid perm formula");
            assert_eq!(hybrid.mult, hy_mult, "{n_o}x{n_i} hybrid mult formula");
            assert_eq!(gala.perm, 0);
            assert!(
                gala.perm < hybrid.perm,
                "{n_o}x{n_i}: gala {} not strictly below hybrid {}",
                gala.perm,
                hybrid.perm
            );
        }
    }

    /// Satellite: seeded random layers — the summed GALA read, the hybrid
    /// tree slot, and the plaintext-quantized reference agree exactly.
    #[test]
    fn randomized_gala_hybrid_reference_equivalence() {
        let shapes: [(usize, usize); 12] = [
            (3, 5),
            (7, 3),
            (12, 9),
            (16, 10),
            (30, 4),
            (33, 7),
            (48, 6),
            (64, 4),
            (65, 3),
            (96, 5),
            (100, 10),
            (128, 3),
        ];
        for (case, &(n_i, n_o)) in shapes.iter().enumerate() {
            let (ctx, layer, x_q, reference) = setup(n_i, n_o, 900 + case as u64);
            let plan = crate::fixed::ScalePlan::default_plan();
            let mut rng = ChaCha20Rng::from_u64_seed(901 + case as u64);
            let enc = Encryptor::new(ctx.clone(), &mut rng);
            let ev = crate::phe::Evaluator::new(ctx.clone());
            let gk = fc_galois_keys(&ctx, &enc.sk, n_i, &mut rng);
            let packed = pack_fc_input(&ctx, &x_q, FcMethod::Hybrid);
            let mut ct = enc.encrypt_slots(&packed, &mut rng);
            ev.to_ntt(&mut ct);

            let (ga_outs, ga_map) = fc(&ev, &ct, &layer, n_i, &plan, 1.0);
            let (hy_outs, hy_map) =
                gazelle_fc(&ev, FcMethod::Hybrid, &ct, &layer, n_i, &plan, 1.0, &gk);
            let ga_dec: Vec<Vec<i64>> =
                ga_outs.iter().map(|c| enc.decrypt_slots(c)).collect();
            let hy_dec: Vec<Vec<i64>> =
                hy_outs.iter().map(|c| enc.decrypt_slots(c)).collect();
            for (o, read) in ga_map.iter().enumerate() {
                let summed: i64 = read.slots().map(|s| ga_dec[read.ct][s]).sum();
                assert_eq!(summed, reference[o], "case {case} ({n_i}x{n_o}) gala output {o}");
                let (hci, hslot) = hy_map[o];
                assert_eq!(
                    summed, hy_dec[hci][hslot],
                    "case {case} ({n_i}x{n_o}) gala vs hybrid output {o}"
                );
            }
        }
    }
}
