//! GALA kernel-grouped packed convolution: first-rotate-then-multiply with
//! gap packing, replacing the baseline's `c·(r²−1)` independent
//! per-(channel, offset) rotations with a baby-step/giant-step split of
//! the kernel offset grid.
//!
//! **Packing.** A half-row holds `blocks_per_ct = row/(hw + gap)` blocks of
//! `block = hw + gap` slots. The `gap = max(⌊r/2⌋, r−1−⌊r/2⌋)·(w+1)` zero
//! slots between images absorb every kernel displacement, reproducing the
//! baseline's flat zero-tail border semantics exactly (out-of-image taps
//! read zeros from the gap — including block 0's negative taps, which wrap
//! into the *last* block's gap at the end of the half-row). `γ =
//! min(c_i, blocks_per_ct)` distinct input channels share a ciphertext and
//! the whole group is replicated `ρ = min(c_o, blocks_per_ct/γ)` times, so
//! one ciphertext feeds `ρ` output channels at once.
//!
//! **Rotation schedule.** A kernel offset `d = dy·w + dx` splits into a
//! column part `dx` and a row part `dy·w`:
//!
//! 1. *baby*: each input-group ciphertext is rotated once per `dx` —
//!    `⌈c_i/γ⌉·(r−1)` Perms, shared by every output channel;
//! 2. *multiply*: per output group and `dy`, the masked partials
//!    `Σ_{ig,dx} mask ∘ rot(u_ig, dx)` accumulate with plain `Mult`/`Add`
//!    only — the mask places the weight `k[o][i][dy,dx]` over block `β`'s
//!    window shifted by `dy·w`;
//! 3. *giant*: the `dy` partial is rotated once by `dy·w` —
//!    `⌈c_o/ρ⌉·(r−1)` Perms total.
//!
//! `#Perm = (⌈c_i/γ⌉ + ⌈c_o/ρ⌉)(r−1)` and `#Mult = ⌈c_i/γ⌉·⌈c_o/ρ⌉·r²`,
//! versus the baseline's `min(c_i,c_o)(r²−1)` / `c_i·c_o·r²`. Only the
//! `2(r−1)` Galois elements `±dx` and `±dy·w` need offline keys.
//!
//! An output `(o, s)` is the plaintext sum of `γ` slots (`stride = block`,
//! one per packed input channel) of output-group ciphertext `o/ρ` — a
//! [`SlotRead`]; the runner masks each of those slots individually.

use super::SlotRead;
use crate::fixed::ScalePlan;
use crate::nn::layers::Layer;
use crate::phe::keys::galois_elt_for_step;
use crate::phe::{Ciphertext, Context, Evaluator, GaloisKeys, SecretKey};
use crate::util::rng::ChaCha20Rng;

/// The packing geometry of one GALA convolution step (all derived from the
/// half-row size, the input shape, the output channel count, and the
/// kernel size — both parties compute it deterministically).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GalaConvGeometry {
    /// Half-row size the geometry was computed for.
    pub row: usize,
    /// Input channels.
    pub c_i: usize,
    /// Input height.
    pub h: usize,
    /// Input width.
    pub w: usize,
    /// Output channels.
    pub c_o: usize,
    /// Kernel side length.
    pub r: usize,
    /// Negative kernel reach `⌊r/2⌋` (taps before the centre).
    pub c_lo: usize,
    /// Positive kernel reach `r − 1 − c_lo`.
    pub c_hi: usize,
    /// Zero slots between packed images (`max(c_lo, c_hi)·(w+1)`, 0 for
    /// 1×1 kernels).
    pub gap: usize,
    /// Block pitch: `h·w + gap` slots per packed image.
    pub block: usize,
    /// Blocks per half-row (`row / block`).
    pub blocks_per_ct: usize,
    /// Distinct input channels packed per ciphertext.
    pub gamma: usize,
    /// Replicas of the channel group per ciphertext (each replica feeds a
    /// different output channel).
    pub rho: usize,
    /// Input-group ciphertexts: `⌈c_i/γ⌉`.
    pub in_groups: usize,
    /// Output-group ciphertexts: `⌈c_o/ρ⌉`.
    pub out_groups: usize,
}

impl GalaConvGeometry {
    /// Derive the geometry for an input of `in_shape = (c_i, h, w)`, `c_o`
    /// output channels and an `r×r` kernel on half-rows of `row` slots.
    pub fn new(row: usize, in_shape: (usize, usize, usize), c_o: usize, r: usize) -> Self {
        let (c_i, h, w) = in_shape;
        let hw = h * w;
        let c_lo = r / 2;
        let c_hi = r - 1 - c_lo;
        let gap = if r == 1 { 0 } else { c_lo.max(c_hi) * (w + 1) };
        let block = hw + gap;
        let blocks_per_ct = row / block;
        let gamma = c_i.min(blocks_per_ct).max(1);
        let rho = c_o.min((blocks_per_ct / gamma).max(1)).max(1);
        GalaConvGeometry {
            row,
            c_i,
            h,
            w,
            c_o,
            r,
            c_lo,
            c_hi,
            gap,
            block,
            blocks_per_ct,
            gamma,
            rho,
            in_groups: c_i.div_ceil(gamma),
            out_groups: c_o.div_ceil(rho),
        }
    }

    /// Whether one packed image (plus gap) fits the half-row at all.
    pub fn fits(&self) -> bool {
        self.blocks_per_ct >= 1
    }

    /// Analytic `(perm, mult)` op counts of [`conv`] on this geometry.
    pub fn counts(&self) -> (u64, u64) {
        assert!(self.fits(), "image+gap exceeds the half-row");
        let perm = ((self.in_groups + self.out_groups) * (self.r - 1)) as u64;
        let mult = (self.in_groups * self.out_groups * self.r * self.r) as u64;
        (perm, mult)
    }

    /// The [`SlotRead`] whose plaintext sum is output channel `o`, spatial
    /// position `s`: the `γ` blocks of replica `o % ρ` in output-group
    /// ciphertext `o / ρ`.
    pub fn read(&self, o: usize, s: usize) -> SlotRead {
        SlotRead {
            ct: o / self.rho,
            start: (o % self.rho) * self.gamma * self.block + s,
            stride: self.block,
            count: self.gamma,
        }
    }
}

/// Analytic GALA conv op counts `(perm, mult)` (see
/// [`GalaConvGeometry::counts`]).
pub fn gala_conv_counts(
    row: usize,
    in_shape: (usize, usize, usize),
    c_o: usize,
    r: usize,
) -> (u64, u64) {
    GalaConvGeometry::new(row, in_shape, c_o, r).counts()
}

/// Galois elements of the baby (`±dx`) and giant (`±dy·w`) rotations for
/// an `r×r` kernel over a `w`-wide image (duplicates are deduplicated at
/// key generation).
pub fn needed_galois_elts(ctx: &Context, r: usize, w: usize) -> Vec<u64> {
    let c_lo = (r / 2) as i64;
    let c_hi = r as i64 - 1 - c_lo;
    let mut elts = Vec::new();
    for d in -c_lo..=c_hi {
        if d != 0 {
            elts.push(galois_elt_for_step(&ctx.params, d));
            elts.push(galois_elt_for_step(&ctx.params, d * w as i64));
        }
    }
    elts
}

/// Generate the GALA rotation keys for a conv shape (offline).
pub fn gala_conv_galois_keys(
    ctx: &Context,
    sk: &SecretKey,
    r: usize,
    w: usize,
    rng: &mut ChaCha20Rng,
) -> GaloisKeys {
    GaloisKeys::generate_for(ctx, sk, rng, &needed_galois_elts(ctx, r, w))
}

/// Pack a flat channel-major activation (residues mod `p`) into the GALA
/// slot layout: `in_groups` half-row vectors, each holding `γ` channels at
/// block pitch [`GalaConvGeometry::block`], replicated `ρ` times.
pub fn pack_conv_input(geom: &GalaConvGeometry, input: &[u64]) -> Vec<Vec<u64>> {
    let hw = geom.h * geom.w;
    assert_eq!(input.len(), geom.c_i * hw, "channel-major input expected");
    assert!(geom.fits(), "image+gap exceeds the half-row");
    (0..geom.in_groups)
        .map(|ig| {
            let mut slots = vec![0u64; geom.row];
            for q in 0..geom.rho {
                for b in 0..geom.gamma {
                    let i = ig * geom.gamma + b;
                    if i >= geom.c_i {
                        continue;
                    }
                    let beta = q * geom.gamma + b;
                    slots[beta * geom.block..beta * geom.block + hw]
                        .copy_from_slice(&input[i * hw..(i + 1) * hw]);
                }
            }
            slots
        })
        .collect()
}

/// GALA convolution: `in_cts` are the [`pack_conv_input`] ciphertexts (NTT
/// form), stride 1. Returns one ciphertext per output group; outputs are
/// recovered with [`GalaConvGeometry::read`]. Weights are quantized at
/// `plan.k` divided by `weight_div`, identically to the baseline path.
///
/// The baby rotations and the per-output-group accumulations fan out over
/// the [`crate::par`] pool; accumulation order within an output group is
/// fixed, so results are bit-identical at every thread count.
#[allow(clippy::too_many_arguments)]
pub fn conv(
    ev: &Evaluator,
    geom: &GalaConvGeometry,
    in_cts: &[Ciphertext],
    layer: &Layer,
    plan: &ScalePlan,
    weight_div: f64,
    gk: &GaloisKeys,
) -> Vec<Ciphertext> {
    let ctx = &*ev.ctx;
    assert_eq!(in_cts.len(), geom.in_groups, "one ciphertext per input group");
    assert!(geom.fits(), "image+gap exceeds the half-row");
    assert_eq!(geom.row, ctx.params.row_size(), "geometry/context mismatch");
    let crate::nn::layers::LayerKind::Conv2d { out_channels, kernel, stride, .. } = layer.kind
    else {
        panic!("conv requires Conv2d layer")
    };
    assert_eq!(stride, 1, "GALA packed conv path supports stride 1");
    assert_eq!(out_channels, geom.c_o, "layer/geometry mismatch");
    assert_eq!(kernel, geom.r, "layer/geometry mismatch");

    let (hw, w, row) = (geom.h * geom.w, geom.w as i64, geom.row as i64);
    let (c_lo, c_hi) = (geom.c_lo as i64, geom.c_hi as i64);
    let n_dx = geom.r; // dx ∈ [−c_lo, c_hi], zero included
    let quant = |v: f64| plan.quant_k(v / weight_div);

    // Baby step: rotate every input group once per column offset — all
    // (ig, dx) rotations are independent.
    let rotated_flat: Vec<Ciphertext> = crate::par::map_indexed(geom.in_groups * n_dx, |k| {
        let (ig, xi) = (k / n_dx, k % n_dx);
        let dx = xi as i64 - c_lo;
        if dx == 0 {
            in_cts[ig].clone()
        } else {
            ev.rotate_rows(&in_cts[ig], dx, gk)
        }
    });
    let rotated: Vec<&[Ciphertext]> = rotated_flat.chunks(n_dx).collect();

    // The weight mask for (og, ig, dy, dx): block β = q·γ + b carries
    // k[og·ρ+q][ig·γ+b][dy,dx] over its window shifted by dy·w. Windows of
    // distinct blocks never collide (the gap separates them, and block 0's
    // negative-dy wrap lands in the final gap at the end of the half-row).
    let mask = |og: usize, ig: usize, dy: i64, dx: i64| -> Vec<i64> {
        let (ky, kx) = ((dy + c_lo) as usize, (dx + c_lo) as usize);
        let mut m = vec![0i64; geom.row];
        for q in 0..geom.rho {
            let o = og * geom.rho + q;
            if o >= geom.c_o {
                continue;
            }
            for b in 0..geom.gamma {
                let i = ig * geom.gamma + b;
                if i >= geom.c_i {
                    continue;
                }
                let kv = quant(layer.conv_w(geom.c_i, geom.r, o, i, ky, kx));
                if kv == 0 {
                    continue;
                }
                let beta = (q * geom.gamma + b) as i64;
                let base = beta * geom.block as i64 + dy * w;
                for s in 0..hw as i64 {
                    m[(base + s).rem_euclid(row) as usize] = kv;
                }
            }
        }
        m
    };

    // Mid + giant step per output group: accumulate the masked partials of
    // every (ig, dx) for one dy, rotate the partial once by dy·w, sum.
    crate::par::map_indexed(geom.out_groups, |og| {
        let mut acc: Option<Ciphertext> = None;
        for dy in -c_lo..=c_hi {
            let mut partial: Option<Ciphertext> = None;
            for (ig, rot_ig) in rotated.iter().enumerate() {
                for xi in 0..n_dx {
                    let dx = xi as i64 - c_lo;
                    let op = ctx.mult_operand(&mask(og, ig, dy, dx));
                    let prod = ev.mult_plain(&rot_ig[xi], &op);
                    match &mut partial {
                        None => partial = Some(prod),
                        Some(p) => ev.add_assign(p, &prod),
                    }
                }
            }
            let mut part = partial.unwrap();
            if dy != 0 {
                part = ev.rotate_rows(&part, dy * w, gk);
            }
            match &mut acc {
                None => acc = Some(part),
                Some(a) => ev.add_assign(a, &part),
            }
        }
        acc.unwrap()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::phe::{Encryptor, Params};
    use crate::protocol::gazelle::conv::{
        conv as gazelle_conv, conv_flat_reference, conv_galois_keys, ConvVariant,
    };
    use crate::util::rng::SplitMix64;
    use std::sync::Arc;

    fn run_gala(
        ctx: &Arc<Context>,
        geom: &GalaConvGeometry,
        layer: &Layer,
        input_q: &[i64],
        rng: &mut ChaCha20Rng,
    ) -> (Vec<Vec<i64>>, crate::phe::OpCounts) {
        let plan = crate::fixed::ScalePlan::default_plan();
        let enc = Encryptor::new(ctx.clone(), rng);
        let ev = Evaluator::new(ctx.clone());
        let gk = gala_conv_galois_keys(ctx, &enc.sk, geom.r, geom.w, rng);
        let p = ctx.params.p;
        let residues: Vec<u64> = input_q
            .iter()
            .map(|&v| if v < 0 { p - (-v) as u64 } else { v as u64 })
            .collect();
        let mut in_cts: Vec<Ciphertext> = pack_conv_input(geom, &residues)
            .iter()
            .map(|slots| {
                let pt = ctx.encoder.encode_unsigned(slots);
                enc.encrypt(&pt, rng)
            })
            .collect();
        for ct in in_cts.iter_mut() {
            ev.to_ntt(ct);
        }
        ev.reset_counts();
        let outs = conv(&ev, geom, &in_cts, layer, &plan, 1.0, &gk);
        assert_eq!(outs.len(), geom.out_groups);
        let counts = ev.counts();
        (outs.iter().map(|c| enc.decrypt_slots(c)).collect(), counts)
    }

    /// Satellite: pinned geometry, counted Perm/Mult matching the analytic
    /// formula, exact agreement with the flat-border reference, and strict
    /// dominance over both baseline variants.
    #[test]
    fn gala_conv_matches_reference_and_counts() {
        let ctx = Arc::new(Context::new(Params::new(1024, 20)));
        let plan = crate::fixed::ScalePlan::default_plan();
        let mut rng = ChaCha20Rng::from_u64_seed(33);
        let mut srng = SplitMix64::new(34);

        let (c_i, c_o, h, w, r) = (2usize, 3usize, 8usize, 8usize, 3usize);
        let mut layer = Layer::conv(c_o, r, 1, 1);
        layer.init_weights(c_i, h, w, &mut srng);
        let input_q: Vec<i64> =
            (0..c_i * h * w).map(|_| srng.gen_i64_range(-128, 128)).collect();
        let reference = conv_flat_reference(&input_q, &layer, (c_i, h, w), &plan, 1.0);

        let geom = GalaConvGeometry::new(ctx.params.row_size(), (c_i, h, w), c_o, r);
        // row 512: gap = 9, block = 73, 7 blocks → γ=2, ρ=3, 1 in / 1 out group.
        assert_eq!((geom.gamma, geom.rho, geom.in_groups, geom.out_groups), (2, 3, 1, 1));
        let (expect_perm, expect_mult) = geom.counts();
        assert_eq!((expect_perm, expect_mult), (4, 9));
        let (gz_perm, _) = super::super::gazelle_conv_counts(c_i, c_o, r);
        assert!(expect_perm < gz_perm, "gala {expect_perm} vs gazelle {gz_perm}");

        let (decs, counts) = run_gala(&ctx, &geom, &layer, &input_q, &mut rng);
        assert_eq!(counts.perm, expect_perm, "perm count");
        assert_eq!(counts.mult, expect_mult, "mult count");
        for o in 0..c_o {
            for s in 0..h * w {
                let read = geom.read(o, s);
                let got: i64 = read.slots().map(|j| decs[read.ct][j]).sum();
                assert_eq!(got, reference[o * h * w + s], "o={o} s={s}");
            }
        }
    }

    /// Satellite: seeded random conv shapes — the summed GALA reads agree
    /// exactly with the plaintext flat-border reference and the baseline
    /// input-rotation variant, and the counted Perms match the formula.
    #[test]
    fn randomized_gala_gazelle_reference_equivalence() {
        let shapes: [(usize, usize, usize, usize); 12] = [
            // (c_i, c_o, h=w, r)
            (1, 1, 4, 3),
            (1, 3, 6, 3),
            (2, 2, 5, 3),
            (3, 2, 6, 3),
            (2, 4, 8, 3),
            (4, 2, 7, 3),
            (1, 2, 9, 5),
            (2, 3, 10, 5),
            (5, 4, 4, 3),
            (3, 3, 8, 1),
            (6, 2, 6, 3),
            (2, 6, 12, 3),
        ];
        let ctx = Arc::new(Context::new(Params::new(1024, 20)));
        let plan = crate::fixed::ScalePlan::default_plan();
        let row = ctx.params.row_size();
        for (case, &(c_i, c_o, hw_side, r)) in shapes.iter().enumerate() {
            let (h, w) = (hw_side, hw_side);
            let mut rng = ChaCha20Rng::from_u64_seed(800 + case as u64);
            let mut srng = SplitMix64::new(810 + case as u64);
            let mut layer = Layer::conv(c_o, r, 1, r / 2);
            layer.init_weights(c_i, h, w, &mut srng);
            let input_q: Vec<i64> =
                (0..c_i * h * w).map(|_| srng.gen_i64_range(-64, 64)).collect();
            let reference = conv_flat_reference(&input_q, &layer, (c_i, h, w), &plan, 1.0);

            let geom = GalaConvGeometry::new(row, (c_i, h, w), c_o, r);
            let (decs, counts) = run_gala(&ctx, &geom, &layer, &input_q, &mut rng);
            let (expect_perm, expect_mult) = geom.counts();
            assert_eq!(counts.perm, expect_perm, "case {case} perm");
            assert_eq!(counts.mult, expect_mult, "case {case} mult");

            // Baseline IR on the same inputs.
            let enc = Encryptor::new(ctx.clone(), &mut rng);
            let ev = Evaluator::new(ctx.clone());
            let gk = conv_galois_keys(&ctx, &enc.sk, r, w, &mut rng);
            let mut in_cts: Vec<Ciphertext> = (0..c_i)
                .map(|i| enc.encrypt_slots(&input_q[i * h * w..(i + 1) * h * w], &mut rng))
                .collect();
            for ct in in_cts.iter_mut() {
                ev.to_ntt(ct);
            }
            let gz = gazelle_conv(
                &ev,
                ConvVariant::InputRotation,
                &in_cts,
                &layer,
                (c_i, h, w),
                &plan,
                1.0,
                &gk,
            );
            let gz_decs: Vec<Vec<i64>> = gz.iter().map(|c| enc.decrypt_slots(c)).collect();

            for o in 0..c_o {
                for s in 0..h * w {
                    let read = geom.read(o, s);
                    let got: i64 = read.slots().map(|j| decs[read.ct][j]).sum();
                    assert_eq!(got, reference[o * h * w + s], "case {case} o={o} s={s}");
                    assert_eq!(got, gz_decs[o][s], "case {case} vs baseline o={o} s={s}");
                }
            }
        }
    }

    /// The NetA first-conv geometry at default parameters (row 2048):
    /// two blocks per ciphertext, one input group, three output groups.
    #[test]
    fn neta_conv1_geometry_is_pinned() {
        let geom = GalaConvGeometry::new(2048, (1, 28, 28), 5, 5);
        assert_eq!(geom.gap, 58);
        assert_eq!(geom.block, 842);
        assert_eq!(geom.blocks_per_ct, 2);
        assert_eq!((geom.gamma, geom.rho), (1, 2));
        assert_eq!((geom.in_groups, geom.out_groups), (1, 3));
        assert_eq!(geom.counts(), (16, 75));
        let (gz_perm, _) = super::super::gazelle_conv_counts(1, 5, 5);
        assert_eq!(gz_perm, 24);
    }
}
