//! Transport between client and server: message framing, byte metering, and
//! a configurable link cost model (the paper's testbed is two workstations
//! on Gigabit Ethernet; we measure compute for real and derive wire time
//! from exact serialized bytes × the link model — see DESIGN.md).
//!
//! Two concrete transports:
//! * [`MeteredChannel`] — in-process, zero-copy, counts every byte and
//!   models latency/bandwidth (used by all benchmarks),
//! * TCP framing helpers used by the real client/server binaries
//!   (`examples/serve_mlaas.rs`).

use std::io::{Read, Write};
use std::time::Duration;

/// Direction of a transfer, for accounting.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dir {
    /// Client → server (query shares, recovery requests).
    ClientToServer,
    /// Server → client (offline indicators, products, recovered values).
    ServerToClient,
}

/// A link cost model: RTT and symmetric bandwidth.
#[derive(Clone, Copy, Debug)]
pub struct LinkModel {
    /// Round-trip time; half is charged per one-way transfer.
    pub rtt: Duration,
    /// Symmetric link bandwidth in bits per second.
    pub bandwidth_bps: f64,
}

impl LinkModel {
    /// The paper's testbed: Gigabit Ethernet, sub-millisecond RTT.
    pub fn gigabit_lan() -> Self {
        Self { rtt: Duration::from_micros(200), bandwidth_bps: 1e9 }
    }

    /// A WAN profile (for the ablation on link sensitivity).
    pub fn wan() -> Self {
        Self { rtt: Duration::from_millis(20), bandwidth_bps: 100e6 }
    }

    /// Wire time for transferring `bytes` in one direction, including half
    /// an RTT of propagation.
    pub fn transfer_time(&self, bytes: u64) -> Duration {
        let serialize = bytes as f64 * 8.0 / self.bandwidth_bps;
        self.rtt / 2 + Duration::from_secs_f64(serialize)
    }
}

/// Accumulated traffic statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct TrafficStats {
    /// Bytes sent client → server.
    pub c2s_bytes: u64,
    /// Bytes sent server → client.
    pub s2c_bytes: u64,
    /// Messages sent client → server.
    pub c2s_msgs: u64,
    /// Messages sent server → client.
    pub s2c_msgs: u64,
    /// Number of communication *rounds* (direction flips).
    pub rounds: u64,
}

impl TrafficStats {
    /// Total bytes over the link, both directions.
    pub fn total_bytes(&self) -> u64 {
        self.c2s_bytes + self.s2c_bytes
    }
}

/// In-process metered channel: registers transfers (by size) and computes
/// modeled wire time. The benchmarks pass serialized sizes here rather than
/// moving real buffers; the TCP mode moves real bytes.
pub struct MeteredChannel {
    /// The link cost model transfers are priced against.
    pub link: LinkModel,
    stats: TrafficStats,
    last_dir: Option<Dir>,
    /// Modeled accumulated wire time (pipelined per message).
    pub wire_time: Duration,
}

impl MeteredChannel {
    /// A fresh channel with zeroed counters over the given link model.
    pub fn new(link: LinkModel) -> Self {
        Self { link, stats: TrafficStats::default(), last_dir: None, wire_time: Duration::ZERO }
    }

    /// Record a transfer of `bytes` in direction `dir`.
    pub fn send(&mut self, dir: Dir, bytes: u64) {
        match dir {
            Dir::ClientToServer => {
                self.stats.c2s_bytes += bytes;
                self.stats.c2s_msgs += 1;
            }
            Dir::ServerToClient => {
                self.stats.s2c_bytes += bytes;
                self.stats.s2c_msgs += 1;
            }
        }
        if self.last_dir != Some(dir) {
            self.stats.rounds += 1;
            self.last_dir = Some(dir);
        }
        self.wire_time += self.link.transfer_time(bytes);
    }

    /// Snapshot of the accumulated counters.
    pub fn stats(&self) -> TrafficStats {
        self.stats
    }

    /// Zero all counters and the modeled wire time.
    pub fn reset(&mut self) {
        self.stats = TrafficStats::default();
        self.last_dir = None;
        self.wire_time = Duration::ZERO;
    }
}

/// Default cap on a single frame's payload. The largest legitimate frames
/// are secure-serving rounds holding a few dozen ciphertexts (~100 KiB
/// each); 64 MiB leaves ample headroom while refusing to allocate
/// attacker-controlled sizes up to 4 GiB from a corrupt length header.
pub const DEFAULT_MAX_FRAME_LEN: usize = 64 << 20;

/// Frame-level read failure.
#[derive(Debug)]
pub enum FrameError {
    /// Underlying I/O failure; truncated frames surface as `UnexpectedEof`.
    Io(std::io::Error),
    /// The length header exceeds the configured maximum — the frame is
    /// rejected *before* any payload allocation.
    TooLarge { len: usize, max: usize },
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Io(e) => write!(f, "frame i/o error: {e}"),
            FrameError::TooLarge { len, max } => {
                write!(f, "frame payload length {len} exceeds maximum {max}")
            }
        }
    }
}

impl std::error::Error for FrameError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            FrameError::Io(e) => Some(e),
            FrameError::TooLarge { .. } => None,
        }
    }
}

impl From<std::io::Error> for FrameError {
    fn from(e: std::io::Error) -> Self {
        FrameError::Io(e)
    }
}

impl From<FrameError> for std::io::Error {
    fn from(e: FrameError) -> Self {
        match e {
            FrameError::Io(io) => io,
            FrameError::TooLarge { .. } => {
                std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string())
            }
        }
    }
}

/// Length-prefixed message framing over any `Read`/`Write` (TCP mode).
pub fn write_frame<W: Write>(w: &mut W, tag: u8, payload: &[u8]) -> std::io::Result<()> {
    w.write_all(&[tag])?;
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()
}

/// Read one framed message with the default payload cap: `(tag, payload)`.
pub fn read_frame<R: Read>(r: &mut R) -> Result<(u8, Vec<u8>), FrameError> {
    read_frame_limited(r, DEFAULT_MAX_FRAME_LEN)
}

/// Read one framed message, rejecting payloads longer than `max_len`
/// before allocating.
pub fn read_frame_limited<R: Read>(
    r: &mut R,
    max_len: usize,
) -> Result<(u8, Vec<u8>), FrameError> {
    let mut hdr = [0u8; 5];
    r.read_exact(&mut hdr)?;
    let tag = hdr[0];
    let len = u32::from_le_bytes([hdr[1], hdr[2], hdr[3], hdr[4]]) as usize;
    if len > max_len {
        return Err(FrameError::TooLarge { len, max: max_len });
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    Ok((tag, payload))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn link_model_times() {
        let l = LinkModel::gigabit_lan();
        // 1 MB at 1 Gbps ≈ 8 ms + 0.1 ms half-RTT.
        let t = l.transfer_time(1_000_000);
        assert!(t > Duration::from_millis(7) && t < Duration::from_millis(10), "{t:?}");
    }

    #[test]
    fn metering_accumulates_and_counts_rounds() {
        let mut ch = MeteredChannel::new(LinkModel::gigabit_lan());
        ch.send(Dir::ClientToServer, 1000);
        ch.send(Dir::ClientToServer, 500);
        ch.send(Dir::ServerToClient, 2000);
        ch.send(Dir::ClientToServer, 100);
        let s = ch.stats();
        assert_eq!(s.c2s_bytes, 1600);
        assert_eq!(s.s2c_bytes, 2000);
        assert_eq!(s.total_bytes(), 3600);
        assert_eq!(s.rounds, 3);
        assert!(ch.wire_time > Duration::ZERO);
        ch.reset();
        assert_eq!(ch.stats().total_bytes(), 0);
    }

    #[test]
    fn framing_roundtrip() {
        let mut buf = Vec::new();
        write_frame(&mut buf, 7, b"hello world").unwrap();
        write_frame(&mut buf, 9, &[]).unwrap();
        let mut cursor = std::io::Cursor::new(buf);
        let (t1, p1) = read_frame(&mut cursor).unwrap();
        assert_eq!((t1, p1.as_slice()), (7, b"hello world".as_slice()));
        let (t2, p2) = read_frame(&mut cursor).unwrap();
        assert_eq!((t2, p2.len()), (9, 0));
    }

    #[test]
    fn truncated_header_is_eof() {
        let mut cursor = std::io::Cursor::new(vec![7u8, 1, 0]); // 3 of 5 header bytes
        match read_frame(&mut cursor) {
            Err(FrameError::Io(e)) => assert_eq!(e.kind(), std::io::ErrorKind::UnexpectedEof),
            other => panic!("expected EOF, got {other:?}"),
        }
    }

    #[test]
    fn truncated_payload_is_eof() {
        let mut buf = Vec::new();
        write_frame(&mut buf, 7, b"hello world").unwrap();
        buf.truncate(buf.len() - 4); // cut the payload short
        let mut cursor = std::io::Cursor::new(buf);
        match read_frame(&mut cursor) {
            Err(FrameError::Io(e)) => assert_eq!(e.kind(), std::io::ErrorKind::UnexpectedEof),
            other => panic!("expected EOF, got {other:?}"),
        }
    }

    #[test]
    fn oversized_frame_rejected_before_allocation() {
        // A frame claiming a ~4 GiB payload must be rejected by the length
        // check, not by an allocation attempt.
        let mut buf = vec![1u8];
        buf.extend_from_slice(&u32::MAX.to_le_bytes());
        let mut cursor = std::io::Cursor::new(buf);
        match read_frame_limited(&mut cursor, 1024) {
            Err(FrameError::TooLarge { len, max }) => {
                assert_eq!(len, u32::MAX as usize);
                assert_eq!(max, 1024);
            }
            other => panic!("expected TooLarge, got {other:?}"),
        }
    }

    #[test]
    fn frame_at_exact_limit_accepted() {
        let payload = vec![0xabu8; 128];
        let mut buf = Vec::new();
        write_frame(&mut buf, 3, &payload).unwrap();
        let mut cursor = std::io::Cursor::new(buf);
        let (tag, got) = read_frame_limited(&mut cursor, 128).unwrap();
        assert_eq!((tag, got.len()), (3, 128));
    }

    #[test]
    fn frame_error_converts_to_io_error() {
        let e: std::io::Error = FrameError::TooLarge { len: 10, max: 1 }.into();
        assert_eq!(e.kind(), std::io::ErrorKind::InvalidData);
    }
}
