//! Transport between client and server: message framing, byte metering, and
//! a configurable link cost model (the paper's testbed is two workstations
//! on Gigabit Ethernet; we measure compute for real and derive wire time
//! from exact serialized bytes × the link model — see DESIGN.md).
//!
//! Two concrete transports:
//! * [`MeteredChannel`] — in-process, zero-copy, counts every byte and
//!   models latency/bandwidth (used by all benchmarks),
//! * TCP framing helpers used by the real client/server binaries
//!   (`examples/serve_mlaas.rs`).

use std::io::{Read, Write};
use std::time::Duration;

/// Direction of a transfer, for accounting.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dir {
    ClientToServer,
    ServerToClient,
}

/// A link cost model: RTT and symmetric bandwidth.
#[derive(Clone, Copy, Debug)]
pub struct LinkModel {
    pub rtt: Duration,
    pub bandwidth_bps: f64,
}

impl LinkModel {
    /// The paper's testbed: Gigabit Ethernet, sub-millisecond RTT.
    pub fn gigabit_lan() -> Self {
        Self { rtt: Duration::from_micros(200), bandwidth_bps: 1e9 }
    }

    /// A WAN profile (for the ablation on link sensitivity).
    pub fn wan() -> Self {
        Self { rtt: Duration::from_millis(20), bandwidth_bps: 100e6 }
    }

    /// Wire time for transferring `bytes` in one direction, including half
    /// an RTT of propagation.
    pub fn transfer_time(&self, bytes: u64) -> Duration {
        let serialize = bytes as f64 * 8.0 / self.bandwidth_bps;
        self.rtt / 2 + Duration::from_secs_f64(serialize)
    }
}

/// Accumulated traffic statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct TrafficStats {
    pub c2s_bytes: u64,
    pub s2c_bytes: u64,
    pub c2s_msgs: u64,
    pub s2c_msgs: u64,
    /// Number of communication *rounds* (direction flips).
    pub rounds: u64,
}

impl TrafficStats {
    pub fn total_bytes(&self) -> u64 {
        self.c2s_bytes + self.s2c_bytes
    }
}

/// In-process metered channel: registers transfers (by size) and computes
/// modeled wire time. The benchmarks pass serialized sizes here rather than
/// moving real buffers; the TCP mode moves real bytes.
pub struct MeteredChannel {
    pub link: LinkModel,
    stats: TrafficStats,
    last_dir: Option<Dir>,
    /// Modeled accumulated wire time (pipelined per message).
    pub wire_time: Duration,
}

impl MeteredChannel {
    pub fn new(link: LinkModel) -> Self {
        Self { link, stats: TrafficStats::default(), last_dir: None, wire_time: Duration::ZERO }
    }

    /// Record a transfer of `bytes` in direction `dir`.
    pub fn send(&mut self, dir: Dir, bytes: u64) {
        match dir {
            Dir::ClientToServer => {
                self.stats.c2s_bytes += bytes;
                self.stats.c2s_msgs += 1;
            }
            Dir::ServerToClient => {
                self.stats.s2c_bytes += bytes;
                self.stats.s2c_msgs += 1;
            }
        }
        if self.last_dir != Some(dir) {
            self.stats.rounds += 1;
            self.last_dir = Some(dir);
        }
        self.wire_time += self.link.transfer_time(bytes);
    }

    pub fn stats(&self) -> TrafficStats {
        self.stats
    }

    pub fn reset(&mut self) {
        self.stats = TrafficStats::default();
        self.last_dir = None;
        self.wire_time = Duration::ZERO;
    }
}

/// Length-prefixed message framing over any `Read`/`Write` (TCP mode).
pub fn write_frame<W: Write>(w: &mut W, tag: u8, payload: &[u8]) -> std::io::Result<()> {
    w.write_all(&[tag])?;
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()
}

/// Read one framed message: `(tag, payload)`.
pub fn read_frame<R: Read>(r: &mut R) -> std::io::Result<(u8, Vec<u8>)> {
    let mut hdr = [0u8; 5];
    r.read_exact(&mut hdr)?;
    let tag = hdr[0];
    let len = u32::from_le_bytes(hdr[1..5].try_into().unwrap()) as usize;
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    Ok((tag, payload))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn link_model_times() {
        let l = LinkModel::gigabit_lan();
        // 1 MB at 1 Gbps ≈ 8 ms + 0.1 ms half-RTT.
        let t = l.transfer_time(1_000_000);
        assert!(t > Duration::from_millis(7) && t < Duration::from_millis(10), "{t:?}");
    }

    #[test]
    fn metering_accumulates_and_counts_rounds() {
        let mut ch = MeteredChannel::new(LinkModel::gigabit_lan());
        ch.send(Dir::ClientToServer, 1000);
        ch.send(Dir::ClientToServer, 500);
        ch.send(Dir::ServerToClient, 2000);
        ch.send(Dir::ClientToServer, 100);
        let s = ch.stats();
        assert_eq!(s.c2s_bytes, 1600);
        assert_eq!(s.s2c_bytes, 2000);
        assert_eq!(s.total_bytes(), 3600);
        assert_eq!(s.rounds, 3);
        assert!(ch.wire_time > Duration::ZERO);
        ch.reset();
        assert_eq!(ch.stats().total_bytes(), 0);
    }

    #[test]
    fn framing_roundtrip() {
        let mut buf = Vec::new();
        write_frame(&mut buf, 7, b"hello world").unwrap();
        write_frame(&mut buf, 9, &[]).unwrap();
        let mut cursor = std::io::Cursor::new(buf);
        let (t1, p1) = read_frame(&mut cursor).unwrap();
        assert_eq!((t1, p1.as_slice()), (7, b"hello world".as_slice()));
        let (t2, p2) = read_frame(&mut cursor).unwrap();
        assert_eq!((t2, p2.len()), (9, 0));
    }
}
