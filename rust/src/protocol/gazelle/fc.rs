//! GAZELLE fully-connected (matrix-vector) baselines: the naive,
//! Halevi–Shoup diagonal, and GAZELLE-hybrid methods of the paper's
//! Table 2 / Table 4 — all built on real `Perm` operations.
//!
//! Shapes follow the paper's benchmark: `n_i` padded to a power of two,
//! `n_o·n_i ≤ n/2` for the hybrid (one half-row); larger layers chunk over
//! output groups.

use crate::fixed::ScalePlan;
use crate::nn::layers::Layer;
use crate::phe::keys::{galois_elt_for_step, SecretKey};
use crate::phe::{Ciphertext, Context, Evaluator, GaloisKeys};
use crate::util::rng::ChaCha20Rng;

/// FC method selector (paper Table 2 rows).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FcMethod {
    /// One output at a time: Mult + log2(n_i) rotate-and-sum per output.
    Naive,
    /// Halevi–Shoup diagonals: n_i Perms, n_i Mults.
    Diagonal,
    /// GAZELLE hybrid: input tiled n/n_i times, 1 Mult + log2(n_i) Perms
    /// per chunk of n_row/n_i outputs.
    Hybrid,
}

/// Round up to a power of two.
pub fn pad_pow2(x: usize) -> usize {
    x.next_power_of_two()
}

/// Galois elements the FC methods need for input width `n_i` (padded).
pub fn needed_galois_elts(ctx: &Context, n_i: usize) -> Vec<u64> {
    let n_i = pad_pow2(n_i);
    let mut elts = Vec::new();
    // Rotate-and-sum powers of two.
    let mut s = 1i64;
    while (s as usize) < ctx.params.row_size() {
        elts.push(galois_elt_for_step(&ctx.params, s));
        s <<= 1;
    }
    // Diagonal method: rotations by 1..n_i are composed from powers of two
    // (counted per composed Perm), so powers suffice.
    let _ = n_i;
    elts
}

/// Generate the FC rotation keys for input width `n_i` (offline).
pub fn fc_galois_keys(
    ctx: &Context,
    sk: &SecretKey,
    n_i: usize,
    rng: &mut ChaCha20Rng,
) -> GaloisKeys {
    GaloisKeys::generate_for(ctx, sk, rng, &needed_galois_elts(ctx, n_i))
}

/// Client-side packing of the FC input for a given method: `Hybrid` tiles
/// the (padded) input across the half-row; others place it once.
pub fn pack_fc_input(ctx: &Context, x_q: &[i64], method: FcMethod) -> Vec<i64> {
    let n_i = pad_pow2(x_q.len());
    let row = ctx.params.row_size();
    assert!(n_i <= row, "input must fit one half-row");
    let mut padded = x_q.to_vec();
    padded.resize(n_i, 0);
    match method {
        FcMethod::Hybrid => {
            let reps = row / n_i;
            let mut out = Vec::with_capacity(row);
            for _ in 0..reps {
                out.extend_from_slice(&padded);
            }
            out
        }
        FcMethod::Diagonal => {
            // The diagonal method reads x[(s+d) mod n_i] via rotations that
            // wrap at the half-row, so the input is tiled twice.
            assert!(2 * n_i <= row, "diagonal method needs 2·n_i ≤ row");
            let mut out = padded.clone();
            out.extend_from_slice(&padded);
            out
        }
        FcMethod::Naive => padded,
    }
}

/// GAZELLE matrix-vector product: returns ciphertext(s) whose slots contain
/// the `n_o` outputs (at slot `o·n_i_pad` for Hybrid/Naive chunks, slot `o`
/// for Diagonal), plus the slot index map.
pub fn fc(
    ev: &Evaluator,
    method: FcMethod,
    in_ct: &Ciphertext,
    layer: &Layer,
    n_i_real: usize,
    plan: &ScalePlan,
    weight_div: f64,
    gk: &GaloisKeys,
) -> (Vec<Ciphertext>, Vec<(usize, usize)>) {
    let ctx = &*ev.ctx;
    let crate::nn::layers::LayerKind::Fc { out_features: n_o } = layer.kind else {
        panic!("fc requires Fc layer")
    };
    let n_i = pad_pow2(n_i_real);
    let row = ctx.params.row_size();
    let quant = |v: f64| plan.quant_k(v / weight_div);
    let w_at = |o: usize, j: usize| -> i64 {
        if j < n_i_real {
            quant(layer.fc_w(n_i_real, o, j))
        } else {
            0
        }
    };

    match method {
        FcMethod::Naive => {
            // One output at a time: Mult by the row, rotate-and-sum over
            // log2(n_i) steps; output lands in slot 0 of each result ct.
            let mut outs = Vec::with_capacity(n_o);
            let mut map = Vec::with_capacity(n_o);
            for o in 0..n_o {
                let wrow: Vec<i64> = (0..n_i).map(|j| w_at(o, j)).collect();
                let op = ctx.mult_operand(&wrow);
                let mut acc = ev.mult_plain(in_ct, &op);
                let mut step = (n_i / 2) as i64;
                while step >= 1 {
                    let rot = ev.rotate_rows(&acc, step, gk);
                    ev.add_assign(&mut acc, &rot);
                    step /= 2;
                }
                map.push((outs.len(), 0));
                outs.push(acc);
            }
            (outs, map)
        }
        FcMethod::Diagonal => {
            // Halevi–Shoup: out[o] = Σ_d (rot(x, d))[o] · w[o][(o+d) mod n_i]
            // with outputs in slots 0..n_o of a single ciphertext.
            let mut acc: Option<Ciphertext> = None;
            for d in 0..n_i as i64 {
                let rotated = if d == 0 {
                    in_ct.clone()
                } else {
                    ev.rotate_rows_composed(in_ct, d, gk)
                };
                let diag: Vec<i64> = (0..row)
                    .map(|s| if s < n_o { w_at(s, (s + d as usize) % n_i) } else { 0 })
                    .collect();
                let op = ctx.mult_operand(&diag);
                let prod = ev.mult_plain(&rotated, &op);
                match &mut acc {
                    None => acc = Some(prod),
                    Some(a) => ev.add_assign(a, &prod),
                }
            }
            let map = (0..n_o).map(|o| (0, o)).collect();
            (vec![acc.unwrap()], map)
        }
        FcMethod::Hybrid => {
            // Input tiled row/n_i times: each chunk of g_o = row/n_i outputs
            // costs 1 Mult + log2(n_i) Perms (rotate-and-sum inside groups).
            let g_o = (row / n_i).max(1);
            let n_chunks = n_o.div_ceil(g_o);
            let mut outs = Vec::with_capacity(n_chunks);
            let mut map = Vec::with_capacity(n_o);
            for chunk in 0..n_chunks {
                let mut m = vec![0i64; row];
                for t in 0..g_o {
                    let o = chunk * g_o + t;
                    if o >= n_o {
                        break;
                    }
                    for j in 0..n_i {
                        m[t * n_i + j] = w_at(o, j);
                    }
                }
                let op = ctx.mult_operand(&m);
                let mut acc = ev.mult_plain(in_ct, &op);
                let mut step = (n_i / 2) as i64;
                while step >= 1 {
                    let rot = ev.rotate_rows(&acc, step, gk);
                    ev.add_assign(&mut acc, &rot);
                    step /= 2;
                }
                for t in 0..g_o {
                    let o = chunk * g_o + t;
                    if o < n_o {
                        map.push((chunk, t * n_i));
                    }
                }
                outs.push(acc);
            }
            (outs, map)
        }
    }
}

/// Plaintext reference (padded-input dot products).
pub fn fc_reference(
    x_q: &[i64],
    layer: &Layer,
    plan: &ScalePlan,
    weight_div: f64,
) -> Vec<i64> {
    let crate::nn::layers::LayerKind::Fc { out_features: n_o } = layer.kind else {
        panic!("requires Fc")
    };
    let n_i = x_q.len();
    (0..n_o)
        .map(|o| {
            (0..n_i)
                .map(|j| plan.quant_k(layer.fc_w(n_i, o, j) / weight_div) * x_q[j])
                .sum()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::phe::{Encryptor, Params};
    use crate::util::rng::SplitMix64;

    fn setup_fc(
        n_i: usize,
        n_o: usize,
        seed: u64,
    ) -> (std::sync::Arc<Context>, Layer, Vec<i64>, Vec<i64>) {
        let ctx = std::sync::Arc::new(Context::new(Params::new(1024, 20)));
        let plan = ScalePlan::default_plan();
        let mut srng = SplitMix64::new(seed);
        let mut layer = Layer::fc(n_o);
        layer.init_weights(1, 1, n_i, &mut srng);
        let x_q: Vec<i64> = (0..n_i).map(|_| srng.gen_i64_range(-128, 128)).collect();
        let reference = fc_reference(&x_q, &layer, &plan, 1.0);
        (ctx, layer, x_q, reference)
    }

    #[test]
    fn all_methods_match_reference() {
        let (n_i, n_o) = (64usize, 4usize);
        let (ctx, layer, x_q, reference) = setup_fc(n_i, n_o, 41);
        let plan = ScalePlan::default_plan();
        let mut rng = ChaCha20Rng::from_u64_seed(42);
        let enc = Encryptor::new(ctx.clone(), &mut rng);
        let ev = Evaluator::new(ctx.clone());
        let gk = fc_galois_keys(&ctx, &enc.sk, n_i, &mut rng);

        for method in [FcMethod::Naive, FcMethod::Diagonal, FcMethod::Hybrid] {
            let packed = pack_fc_input(&ctx, &x_q, method);
            let mut ct = enc.encrypt_slots(&packed, &mut rng);
            ev.to_ntt(&mut ct);
            ev.reset_counts();
            let (outs, map) = fc(&ev, method, &ct, &layer, n_i, &plan, 1.0, &gk);
            for (o, &(ct_idx, slot)) in map.iter().enumerate() {
                let dec = enc.decrypt_slots(&outs[ct_idx]);
                assert_eq!(dec[slot], reference[o], "{method:?} output {o}");
            }
        }
    }

    #[test]
    fn hybrid_perm_count_matches_paper_table4() {
        // Table 4: 4×512 → #Perm = 9 = log2(512); 16×128 → 7 = log2(128).
        for (n_o, n_i, expect) in [(4usize, 512usize, 9u64), (16, 128, 7)] {
            let (ctx, layer, x_q, _) = setup_fc(n_i, n_o, 50 + n_o as u64);
            let plan = ScalePlan::default_plan();
            let mut rng = ChaCha20Rng::from_u64_seed(5);
            let enc = Encryptor::new(ctx.clone(), &mut rng);
            let ev = Evaluator::new(ctx.clone());
            let gk = fc_galois_keys(&ctx, &enc.sk, n_i, &mut rng);
            let packed = pack_fc_input(&ctx, &x_q, FcMethod::Hybrid);
            let mut ct = enc.encrypt_slots(&packed, &mut rng);
            ev.to_ntt(&mut ct);
            ev.reset_counts();
            let _ = fc(&ev, FcMethod::Hybrid, &ct, &layer, n_i, &plan, 1.0, &gk);
            let c = ev.counts();
            // n_o·n_i = 2048 > row(512)? For n=1024 the row is 512, so
            // chunking multiplies counts; with row=512: g_o = 512/n_i.
            let row = ctx.params.row_size();
            let g_o = (row / n_i).max(1);
            let n_chunks = n_o.div_ceil(g_o) as u64;
            assert_eq!(c.perm, n_chunks * expect, "{n_o}x{n_i}");
            assert_eq!(c.mult, n_chunks);
        }
    }

    #[test]
    fn naive_uses_most_perms() {
        let (n_i, n_o) = (64usize, 4usize);
        let (ctx, layer, x_q, _) = setup_fc(n_i, n_o, 60);
        let plan = ScalePlan::default_plan();
        let mut rng = ChaCha20Rng::from_u64_seed(6);
        let enc = Encryptor::new(ctx.clone(), &mut rng);
        let ev = Evaluator::new(ctx.clone());
        let gk = fc_galois_keys(&ctx, &enc.sk, n_i, &mut rng);
        let mut counts = Vec::new();
        for method in [FcMethod::Naive, FcMethod::Diagonal, FcMethod::Hybrid] {
            let packed = pack_fc_input(&ctx, &x_q, method);
            let mut ct = enc.encrypt_slots(&packed, &mut rng);
            ev.to_ntt(&mut ct);
            ev.reset_counts();
            let _ = fc(&ev, method, &ct, &layer, n_i, &plan, 1.0, &gk);
            counts.push(ev.counts().perm);
        }
        // naive = n_o·log2(n_i) ≥ diagonal ≥ hybrid
        assert_eq!(counts[0], (n_o * 6) as u64);
        assert!(counts[2] <= counts[1], "hybrid {} vs diagonal {}", counts[2], counts[1]);
    }
}
