//! End-to-end GAZELLE baseline inference: rotation-based HE linear layers
//! + garbled-circuit ReLU, chained through additive shares mod p — the
//! system CHEETAH is benchmarked against in Tables 3–7.
//!
//! Per fused step:
//! 1. client packs + encrypts its share (per input channel / FC vector),
//! 2. server `AddPlain`s its own share, runs the rotation-based linear
//!    kernel (IR or OR conv, hybrid FC), adds a fresh mask `r`, replies,
//! 3. client decrypts its linear share; both parties run the batched GC
//!    ReLU (with built-in truncation) → fresh shares mod p,
//! 4. mean-pool = share-domain sum-pool (divisor absorbed into the next
//!    layer's weights), exactly as in the CHEETAH runner for fairness.
//!
//! Strided convolutions run at stride 1 and are share-downsampled (GAZELLE
//! packs strided kernels natively; this costs the baseline nothing extra
//! here because the stride-1 image already fits the ciphertext).

use super::conv::{conv, conv_galois_keys, ConvVariant};
use super::fc::{fc, fc_galois_keys, pack_fc_input, FcMethod};
use crate::fixed::ScalePlan;
use crate::gc::relu::{GcRelu, GcReluReport};
use crate::nn::layers::LayerKind;
use crate::nn::{Network, Tensor};
use crate::phe::keys::KeySwitchKey;
use crate::phe::serial::ciphertext_bytes;
use crate::phe::{Ciphertext, Context, Encryptor, Evaluator, GaloisKeys, OpCounts};
use crate::protocol::cheetah::server::pool_shares;
use crate::protocol::cheetah::{LinearSpec, ProtocolSpec, SpecError};
use crate::util::rng::ChaCha20Rng;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Per-query report for the GAZELLE baseline.
#[derive(Clone, Debug, Default)]
pub struct GazelleReport {
    pub argmax: usize,
    pub logits: Vec<f64>,
    pub server_linear: Duration,
    pub client_time: Duration,
    pub gc: GcReluReport,
    pub online_bytes: u64,
    /// Direction split of `online_bytes`; GC traffic (tables, labels, OT)
    /// is attributed server→client, its dominant direction.
    pub c2s_bytes: u64,
    pub s2c_bytes: u64,
    pub offline_bytes: u64,
    pub ops: OpCounts,
    /// Per-step (linear-layer) online compute, for Fig. 8 breakdowns.
    pub per_step: Vec<Duration>,
}

impl GazelleReport {
    pub fn online_compute(&self) -> Duration {
        self.server_linear + self.client_time + self.gc.eval_time
    }
}

/// ChaCha20 stream id for key generation; queries use `1 + query_index`
/// (the same per-query isolation scheme as the CHEETAH client — see
/// `protocol::cheetah::client` module docs).
const QUERY_STREAM_BASE: u64 = 1;

/// In-process GAZELLE deployment (both parties). Owns a shared
/// `Arc<Context>` (no lifetime parameter).
///
/// Scoring is stateless (`&self`, [`GazelleRunner::infer_with`]): the
/// share chain is local to each query and all RNG consumption (encryption
/// randomness, masks `r`, GC garbling) comes from a per-query
/// domain-separated stream, so [`GazelleRunner::infer_batch`] fans
/// independent queries across the [`crate::par`] pool with logits
/// bit-identical to the sequential loop. (GAZELLE logits do not depend on
/// the RNG at all — masks cancel on reconstruction and GC evaluation is
/// exact — so the isolation is about keeping draw *order*
/// schedule-independent.)
pub struct GazelleRunner {
    /// Shared PHE context.
    pub ctx: Arc<Context>,
    ev: Evaluator,
    client_enc: Encryptor,
    plan: ScalePlan,
    /// Compiled protocol spec (shared layer fusion with CHEETAH).
    pub spec: ProtocolSpec,
    net: Network,
    relu: GcRelu,
    conv_keys: Vec<Option<GaloisKeys>>,
    fc_keys: Vec<Option<GaloisKeys>>,
    seed_key: [u8; 32],
    next_query: u64,
}

impl GazelleRunner {
    /// A network the protocol cannot express is a typed [`SpecError`].
    pub fn new(
        ctx: Arc<Context>,
        net: Network,
        plan: ScalePlan,
        seed: u64,
    ) -> Result<Self, SpecError> {
        let seed_key = ChaCha20Rng::key_from_u64(seed);
        let mut rng = ChaCha20Rng::new(&seed_key, 0);
        let client_enc = Encryptor::new(ctx.clone(), &mut rng);
        let spec = ProtocolSpec::compile(&net)?;
        let relu = GcRelu::new(ctx.params.p, plan.k.frac_bits as usize);
        // Offline: rotation keys per step geometry (generated under the
        // client's key — GAZELLE's server evaluates on client ciphertexts).
        let mut conv_keys = Vec::new();
        let mut fc_keys = Vec::new();
        for step in &spec.steps {
            match &step.linear {
                LinearSpec::Conv(p) => {
                    conv_keys.push(Some(conv_galois_keys(
                        &ctx,
                        &client_enc.sk,
                        p.kernel,
                        p.in_shape.2,
                        &mut rng,
                    )));
                    fc_keys.push(None);
                }
                LinearSpec::Fc(p) => {
                    fc_keys.push(Some(fc_galois_keys(&ctx, &client_enc.sk, p.n_i, &mut rng)));
                    conv_keys.push(None);
                }
            }
        }
        Ok(Self {
            ev: Evaluator::new(ctx.clone()),
            client_enc,
            plan,
            spec,
            net,
            relu,
            conv_keys,
            fc_keys,
            seed_key,
            next_query: 0,
            ctx,
        })
    }

    /// Offline communication: rotation keys + garbled tables for every
    /// intermediate activation.
    pub fn offline_bytes(&self) -> u64 {
        let key_bytes: usize = self
            .conv_keys
            .iter()
            .chain(self.fc_keys.iter())
            .flatten()
            .map(|gk| gk.keys.len() * KeySwitchKey::serialized_size(&self.ctx.params))
            .sum();
        let relu_count: usize = self
            .spec
            .steps
            .iter()
            .take(self.spec.steps.len() - 1)
            .map(|s| s.linear.num_outputs())
            .sum();
        (key_bytes + relu_count * self.relu.offline_bytes_per_relu()) as u64
    }

    /// Run one private inference. Mirrors `CheetahRunner::infer`. Wrapper
    /// over [`GazelleRunner::infer_with`] that also attributes the HE op
    /// counts (meaningful only when queries run one at a time).
    pub fn infer(&mut self, input: &Tensor) -> GazelleReport {
        let qi = self.next_query;
        self.next_query += 1;
        self.ev.reset_counts();
        let mut report = self.infer_with(input, qi);
        report.ops = self.ev.counts();
        report
    }

    /// Run a batch of independent queries fanned across the
    /// [`crate::par`] pool. Logits are bit-identical to looping
    /// [`GazelleRunner::infer`] (per-query RNG streams; see the type
    /// docs). HE op counts are not attributed per query in batch mode
    /// (the evaluator counters are shared across concurrent queries), so
    /// each report's `ops` is zero.
    pub fn infer_batch(&mut self, inputs: &[Tensor]) -> Vec<GazelleReport> {
        let base = self.next_query;
        self.next_query += inputs.len() as u64;
        crate::par::map_indexed(inputs.len(), |i| self.infer_with(&inputs[i], base + i as u64))
    }

    /// Stateless single-query core: every draw comes from the query's own
    /// `(seed, query index)` ChaCha20 stream and the share chain is local,
    /// so any number of queries may run concurrently on one deployment.
    /// `ops` is left at its default (see [`GazelleRunner::infer`]).
    pub fn infer_with(&self, input: &Tensor, query_index: u64) -> GazelleReport {
        let mut rng = ChaCha20Rng::new(&self.seed_key, QUERY_STREAM_BASE + query_index);
        let p = self.ctx.params.p;
        let plan = self.plan;
        let mut report = GazelleReport::default();

        // Initial shares: client holds the quantized input, server zero.
        let mut client_share: Vec<u64> = input
            .data
            .iter()
            .map(|&v| {
                let q = plan.quant_x(v);
                if q < 0 {
                    p - (-q) as u64
                } else {
                    q as u64
                }
            })
            .collect();
        let mut server_share: Vec<u64> = vec![0; client_share.len()];

        let fresh = ciphertext_bytes(&self.ctx.params, true) as u64;
        let eval_sz = ciphertext_bytes(&self.ctx.params, false) as u64;
        let n_steps = self.spec.steps.len();

        for si in 0..n_steps {
            let step = self.spec.steps[si].clone();
            let last = si == n_steps - 1;
            let step_t0 = Instant::now();
            // ---- client: pack + encrypt its share ----
            let t0 = Instant::now();
            let (in_cts, fc_pack_len): (Vec<Ciphertext>, usize) = match &step.linear {
                LinearSpec::Conv(cp) => {
                    let (c_i, h, w) = cp.in_shape;
                    let hw = h * w;
                    let cts = (0..c_i)
                        .map(|i| {
                            let slots: Vec<i64> =
                                client_share[i * hw..(i + 1) * hw].iter().map(|&v| v as i64).collect();
                            let pt = self.ctx.encoder.encode_unsigned(
                                &slots.iter().map(|&v| v as u64).collect::<Vec<_>>(),
                            );
                            self.client_enc.encrypt(&pt, &mut rng)
                        })
                        .collect();
                    (cts, 0)
                }
                LinearSpec::Fc(_) => {
                    let x: Vec<i64> = client_share.iter().map(|&v| v as i64).collect();
                    // pack_fc_input expects signed values; shares are
                    // residues — pack residues directly (mod-p linearity).
                    let packed_res: Vec<u64> = pack_fc_input(&self.ctx, &x, FcMethod::Hybrid)
                        .iter()
                        .map(|&v| v as u64 % p)
                        .collect();
                    let pt = self.ctx.encoder.encode_unsigned(&packed_res);
                    (vec![self.client_enc.encrypt(&pt, &mut rng)], packed_res.len())
                }
            };
            report.client_time += t0.elapsed();
            report.online_bytes += in_cts.len() as u64 * fresh;
            report.c2s_bytes += in_cts.len() as u64 * fresh;

            // ---- server: add own share, rotation-based linear, mask ----
            let t1 = Instant::now();
            let mut in_ntt = in_cts;
            self.ev.to_ntt_batch(&mut in_ntt);
            // AddPlain the server's share, packed identically.
            match &step.linear {
                LinearSpec::Conv(cp) => {
                    let (_, h, w) = cp.in_shape;
                    let hw = h * w;
                    for (i, ct) in in_ntt.iter_mut().enumerate() {
                        let op = self
                            .ctx
                            .add_operand_unsigned(&server_share[i * hw..(i + 1) * hw]);
                        self.ev.add_plain(ct, &op);
                    }
                }
                LinearSpec::Fc(_) => {
                    let x: Vec<i64> = server_share.iter().map(|&v| v as i64).collect();
                    let packed: Vec<u64> = pack_fc_input(&self.ctx, &x, FcMethod::Hybrid)
                        .iter()
                        .map(|&v| v as u64 % p)
                        .collect();
                    let _ = fc_pack_len;
                    let op = self.ctx.add_operand_unsigned(&packed);
                    self.ev.add_plain(&mut in_ntt[0], &op);
                }
            }

            // Linear kernel.
            let layer = self.net.layers[step.layer_idx].clone();
            let (out_cts, out_map, out_shape): (Vec<Ciphertext>, Vec<(usize, usize)>, (usize, usize, usize)) =
                match &step.linear {
                    LinearSpec::Conv(cp) => {
                        let (c_i, h, w) = cp.in_shape;
                        let c_o = cp.out_shape.0;
                        // GAZELLE picks whichever rotation variant is cheaper.
                        let variant = if c_i <= c_o {
                            ConvVariant::InputRotation
                        } else {
                            ConvVariant::OutputRotation
                        };
                        // Strided conv: run at stride 1, downsample shares.
                        let mut l1 = layer.clone();
                        if let LayerKind::Conv2d { ref mut stride, ref mut pad, .. } = l1.kind {
                            *stride = 1;
                            *pad = cp.kernel / 2;
                        }
                        let outs = conv(
                            &self.ev,
                            variant,
                            &in_ntt,
                            &l1,
                            (c_i, h, w),
                            &plan,
                            step.weight_div,
                            self.conv_keys[si].as_ref().unwrap(),
                        );
                        let hw = h * w;
                        let map = (0..c_o * hw).map(|o| (o / hw, o % hw)).collect();
                        (outs, map, (c_o, h, w))
                    }
                    LinearSpec::Fc(fp) => {
                        let (outs, map) = fc(
                            &self.ev,
                            FcMethod::Hybrid,
                            &in_ntt[0],
                            &layer,
                            fp.n_i,
                            &plan,
                            step.weight_div,
                            self.fc_keys[si].as_ref().unwrap(),
                        );
                        (outs, map, (1, 1, fp.n_o))
                    }
                };

            // Mask with fresh server shares r (skip on the last layer: the
            // prediction is the protocol output).
            let mut masked = out_cts;
            let n_lin = out_map.len();
            let mut r_share: Vec<u64> = Vec::new();
            if !last {
                r_share = (0..n_lin).map(|_| rng.gen_range(p)).collect();
                // Scatter (p - r) into the mapped slots of each output ct.
                let row_slots = self.ctx.params.n;
                let mut scatter: Vec<Vec<u64>> =
                    vec![vec![0u64; row_slots]; masked.len()];
                for (o, &(ci, slot)) in out_map.iter().enumerate() {
                    scatter[ci][slot] = (p - r_share[o]) % p;
                }
                for (ci, ct) in masked.iter_mut().enumerate() {
                    let op = self.ctx.add_operand_unsigned(&scatter[ci]);
                    self.ev.add_plain(ct, &op);
                }
            }
            report.server_linear += t1.elapsed();
            report.online_bytes += masked.len() as u64 * eval_sz;
            report.s2c_bytes += masked.len() as u64 * eval_sz;

            // ---- client: decrypt its linear share ----
            let t2 = Instant::now();
            let mut client_lin: Vec<u64> = Vec::with_capacity(n_lin);
            // Per-ciphertext decryption is independent — parallel batch.
            let (ctx, client_enc) = (&self.ctx, &self.client_enc);
            let decs: Vec<Vec<u64>> = crate::par::map_collect(&masked, |_, ct| {
                ctx.encoder.decode_unsigned(&client_enc.decrypt(ct))
            });
            for &(ci, slot) in &out_map {
                client_lin.push(decs[ci][slot]);
            }
            report.client_time += t2.elapsed();

            if last {
                // Logits (scale x+k): client reconstructs directly.
                let scale = plan.x.mul(plan.k);
                let half = (p - 1) / 2;
                report.logits = client_lin
                    .iter()
                    .map(|&v| {
                        let c = if v > half { v as i64 - p as i64 } else { v as i64 };
                        scale.dequantize(c)
                    })
                    .collect();
                report.argmax = report
                    .logits
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap();
                report.per_step.push(step_t0.elapsed());
                break;
            }

            // ---- GC ReLU over shares (server garbles, client evaluates) ----
            let server_lin: Vec<u64> = r_share;
            let (mut c_new, mut s_new, gc_rep) =
                self.relu.run_batch(&server_lin, &client_lin, &mut rng);
            report.online_bytes += gc_rep.online_bytes;
            report.s2c_bytes += gc_rep.online_bytes;
            report.gc.merge(&gc_rep);

            // Strided conv downsample (shares, both parties identically).
            if let LinearSpec::Conv(cp) = &step.linear {
                if cp.stride > 1 {
                    let (c_o, h, w) = out_shape;
                    let (oh, ow) = (cp.out_shape.1, cp.out_shape.2);
                    let pick = |v: &[u64]| -> Vec<u64> {
                        let mut out = Vec::with_capacity(c_o * oh * ow);
                        for ch in 0..c_o {
                            for y in 0..oh {
                                for x in 0..ow {
                                    out.push(v[(ch * h + y * cp.stride) * w + x * cp.stride]);
                                }
                            }
                        }
                        out
                    };
                    c_new = pick(&c_new);
                    s_new = pick(&s_new);
                }
            }

            // Pooling on shares.
            if let Some(size) = step.pool_after {
                c_new = pool_shares(&c_new, step.out_shape, size, p);
                s_new = pool_shares(&s_new, step.out_shape, size, p);
            }
            client_share = c_new;
            server_share = s_new;
            report.per_step.push(step_t0.elapsed());
        }

        report.offline_bytes = self.offline_bytes();
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Layer;
    use crate::phe::Params;
    use crate::util::rng::SplitMix64;

    /// Stride-1 conv + ReLU + FC: GAZELLE e2e must agree with the
    /// flat-semantics plaintext composition.
    #[test]
    fn gazelle_e2e_small_net() {
        let ctx = std::sync::Arc::new(Context::new(Params::default_params()));
        let plan = ScalePlan::default_plan();
        let mut net = Network {
            name: "gz-test".into(),
            input_shape: (1, 6, 6),
            layers: vec![Layer::conv(2, 3, 1, 1), Layer::relu(), Layer::fc(4)],
        };
        net.init_weights(71);
        let netc = net.clone();
        let mut runner = GazelleRunner::new(ctx, net, plan, 72).expect("valid network");

        let mut srng = SplitMix64::new(73);
        let input = Tensor::from_vec(
            (0..36).map(|_| srng.gen_f64_range(-1.0, 1.0)).collect(),
            1,
            6,
            6,
        );
        let report = runner.infer(&input);
        assert!(report.ops.perm > 0, "GAZELLE must pay permutations");
        assert!(report.gc.and_gates_total > 0, "GAZELLE must garble");

        // Reference with identical flat-border semantics.
        let xq: Vec<i64> = input.data.iter().map(|&v| plan.quant_x(v)).collect();
        let lin = super::super::conv::conv_flat_reference(&xq, &netc.layers[0], (1, 6, 6), &plan, 1.0);
        let act: Vec<i64> = lin.iter().map(|&v| (v.max(0)) >> plan.k.frac_bits).collect();
        let logits = super::super::fc::fc_reference(&act, &netc.layers[2], &plan, 1.0);
        let scale = plan.x.mul(plan.k);
        for (i, (&got, &want)) in report.logits.iter().zip(&logits).enumerate() {
            let want_f = scale.dequantize(want);
            assert!(
                (got - want_f).abs() < 1e-9,
                "logit {i}: got {got} want {want_f}"
            );
        }
    }
}
