//! End-to-end GAZELLE baseline inference: rotation-based HE linear layers
//! + garbled-circuit ReLU, chained through additive shares mod p — the
//! system CHEETAH is benchmarked against in Tables 3–7.
//!
//! Per fused step:
//! 1. client packs + encrypts its share (per input channel / FC vector),
//! 2. server `AddPlain`s its own share, runs the rotation-based linear
//!    kernel (IR or OR conv, hybrid FC), adds a fresh mask `r`, replies,
//! 3. client decrypts its linear share; both parties run the batched GC
//!    ReLU (with built-in truncation) → fresh shares mod p,
//! 4. mean-pool = share-domain sum-pool (divisor absorbed into the next
//!    layer's weights), exactly as in the CHEETAH runner for fairness.
//!
//! Standalone average-pools are zero-ciphertext local steps (both parties
//! sum-pool their own shares), and post-activation residual adds are
//! share-level (both parties add their saved input shares) — mirroring the
//! CHEETAH runner step for step.
//!
//! Strided convolutions run at stride 1 and are share-downsampled (GAZELLE
//! packs strided kernels natively; this costs the baseline nothing extra
//! here because the stride-1 image already fits the ciphertext).
//!
//! The runner drives one of two linear-algebra families, selected by
//! [`GazelleMode`]: the classic hybrid/rotation path, or the GALA
//! greedy-packing path ([`crate::protocol::gala`]) in which an output is
//! the plaintext sum of a [`SlotRead`] run — the server masks every slot
//! of the run individually, so the obscuring guarantee (and the
//! reconstructed logits) are unchanged.

use super::conv::{conv, conv_galois_keys, ConvVariant};
use super::fc::{fc, fc_galois_keys, pack_fc_input, FcMethod};
use crate::fixed::ScalePlan;
use crate::gc::relu::{GcRelu, GcReluReport};
use crate::nn::layers::LayerKind;
use crate::nn::{Network, Tensor};
use crate::phe::keys::KeySwitchKey;
use crate::phe::serial::ciphertext_bytes;
use crate::phe::{Ciphertext, Context, Encryptor, Evaluator, GaloisKeys, OpCounts};
use crate::protocol::cheetah::server::pool_shares;
use crate::protocol::cheetah::{LinearSpec, ProtocolSpec, SpecError};
use crate::protocol::gala::{self, GalaConvGeometry, SlotRead};
use crate::util::rng::ChaCha20Rng;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Which linear-algebra family a [`GazelleRunner`] deployment evaluates.
///
/// Both modes share the PHE substrate, the share chain, the GC ReLU, and
/// the per-query RNG convention, so their logits are bit-identical — the
/// mode only moves where rotations are spent (a property the tests pin).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum GazelleMode {
    /// The classic GAZELLE path: hybrid FC (rotate-and-sum tree per output
    /// chunk) and IR/OR diagonal conv — rotation-heavy.
    #[default]
    Hybrid,
    /// The GALA greedy-packing path ([`crate::protocol::gala`]): the FC
    /// tree moves into share generation (zero Perms) and conv rotations
    /// are amortized baby-step/giant-step across channel groups.
    Gala,
}

impl GazelleMode {
    /// Stable lowercase key (bench/report rows).
    pub fn name(self) -> &'static str {
        match self {
            GazelleMode::Hybrid => "hybrid",
            GazelleMode::Gala => "gala",
        }
    }
}

/// Per-query report for the GAZELLE baseline.
#[derive(Clone, Debug, Default)]
pub struct GazelleReport {
    /// Predicted class (argmax of `logits`).
    pub argmax: usize,
    /// Dequantized logits, reconstructed by the client.
    pub logits: Vec<f64>,
    /// Server-side linear compute (HE kernels + masking).
    pub server_linear: Duration,
    /// Client-side compute (packing, encryption, decryption).
    pub client_time: Duration,
    /// Garbled-circuit ReLU report (garble/eval time, gates, traffic).
    pub gc: GcReluReport,
    /// Total online traffic, both directions.
    pub online_bytes: u64,
    /// Direction split of `online_bytes`; GC traffic (tables, labels, OT)
    /// is attributed server→client, its dominant direction.
    pub c2s_bytes: u64,
    /// Server→client bytes (see `c2s_bytes`).
    pub s2c_bytes: u64,
    /// Offline traffic: rotation keys + garbled tables.
    pub offline_bytes: u64,
    /// HE op counters for the query (single-query mode only).
    pub ops: OpCounts,
    /// Per-step (linear-layer) online compute, for Fig. 8 breakdowns.
    pub per_step: Vec<Duration>,
}

impl GazelleReport {
    /// Total online compute: server linear + client + GC evaluation.
    pub fn online_compute(&self) -> Duration {
        self.server_linear + self.client_time + self.gc.eval_time
    }
}

/// ChaCha20 stream id for key generation; queries use `1 + query_index`
/// (the same per-query isolation scheme as the CHEETAH client — see
/// `protocol::cheetah::client` module docs).
const QUERY_STREAM_BASE: u64 = 1;

/// In-process GAZELLE deployment (both parties). Owns a shared
/// `Arc<Context>` (no lifetime parameter).
///
/// Scoring is stateless (`&self`, [`GazelleRunner::infer_with`]): the
/// share chain is local to each query and all RNG consumption (encryption
/// randomness, masks `r`, GC garbling) comes from a per-query
/// domain-separated stream, so [`GazelleRunner::infer_batch`] fans
/// independent queries across the [`crate::par`] pool with logits
/// bit-identical to the sequential loop. (GAZELLE logits do not depend on
/// the RNG at all — masks cancel on reconstruction and GC evaluation is
/// exact — so the isolation is about keeping draw *order*
/// schedule-independent. The same argument makes [`GazelleMode::Gala`]
/// logits bit-identical to [`GazelleMode::Hybrid`]: per-slot masks cancel
/// against the client's slot sums mod p.)
pub struct GazelleRunner {
    /// Shared PHE context.
    pub ctx: Arc<Context>,
    ev: Evaluator,
    client_enc: Encryptor,
    plan: ScalePlan,
    /// Compiled protocol spec (shared layer fusion with CHEETAH).
    pub spec: ProtocolSpec,
    net: Network,
    relu: GcRelu,
    mode: GazelleMode,
    conv_keys: Vec<Option<GaloisKeys>>,
    fc_keys: Vec<Option<GaloisKeys>>,
    conv_geoms: Vec<Option<GalaConvGeometry>>,
    seed_key: [u8; 32],
    next_query: u64,
}

impl GazelleRunner {
    /// A [`GazelleMode::Hybrid`] deployment (the classic baseline). A
    /// network the protocol cannot express is a typed [`SpecError`].
    pub fn new(
        ctx: Arc<Context>,
        net: Network,
        plan: ScalePlan,
        seed: u64,
    ) -> Result<Self, SpecError> {
        Self::with_mode(ctx, net, plan, seed, GazelleMode::Hybrid)
    }

    /// A deployment evaluating linear layers in the given [`GazelleMode`].
    pub fn with_mode(
        ctx: Arc<Context>,
        net: Network,
        plan: ScalePlan,
        seed: u64,
        mode: GazelleMode,
    ) -> Result<Self, SpecError> {
        let seed_key = ChaCha20Rng::key_from_u64(seed);
        let mut rng = ChaCha20Rng::new(&seed_key, 0);
        let client_enc = Encryptor::new(ctx.clone(), &mut rng);
        let spec = ProtocolSpec::compile(&net)?;
        let relu = GcRelu::new(ctx.params.p, plan.k.frac_bits as usize);
        // Offline: rotation keys per step geometry (generated under the
        // client's key — GAZELLE's server evaluates on client ciphertexts).
        // GALA ships strictly fewer: ±dx/±dy·w conv elements only, no FC
        // keys at all (the rotate-and-sum tree is gone).
        let mut conv_keys = Vec::new();
        let mut fc_keys = Vec::new();
        let mut conv_geoms = Vec::new();
        for step in &spec.steps {
            let (ck, fk, geom) = match &step.linear {
                LinearSpec::Conv(p) => match mode {
                    GazelleMode::Hybrid => (
                        Some(conv_galois_keys(
                            &ctx,
                            &client_enc.sk,
                            p.kernel,
                            p.in_shape.2,
                            &mut rng,
                        )),
                        None,
                        None,
                    ),
                    GazelleMode::Gala => {
                        let geom = GalaConvGeometry::new(
                            ctx.params.row_size(),
                            p.in_shape,
                            p.out_shape.0,
                            p.kernel,
                        );
                        if geom.fits() {
                            (
                                Some(gala::gala_conv_galois_keys(
                                    &ctx,
                                    &client_enc.sk,
                                    p.kernel,
                                    p.in_shape.2,
                                    &mut rng,
                                )),
                                None,
                                Some(geom),
                            )
                        } else {
                            // Image + rotation gap exceeds the half-row:
                            // this layer cannot block-pack, so it falls
                            // back to the hybrid rotation path (geom stays
                            // `None`; every dispatch below keys off that).
                            (
                                Some(conv_galois_keys(
                                    &ctx,
                                    &client_enc.sk,
                                    p.kernel,
                                    p.in_shape.2,
                                    &mut rng,
                                )),
                                None,
                                None,
                            )
                        }
                    }
                },
                LinearSpec::Fc(p) => match mode {
                    GazelleMode::Hybrid => (
                        None,
                        Some(fc_galois_keys(&ctx, &client_enc.sk, p.n_i, &mut rng)),
                        None,
                    ),
                    GazelleMode::Gala => (None, None, None),
                },
                // Local steps move no ciphertexts and need no keys.
                LinearSpec::AvgPool { .. } => (None, None, None),
            };
            conv_keys.push(ck);
            fc_keys.push(fk);
            conv_geoms.push(geom);
        }
        Ok(Self {
            ev: Evaluator::new(ctx.clone()),
            client_enc,
            plan,
            spec,
            net,
            relu,
            mode,
            conv_keys,
            fc_keys,
            conv_geoms,
            seed_key,
            next_query: 0,
            ctx,
        })
    }

    /// The linear-algebra mode this deployment evaluates.
    pub fn mode(&self) -> GazelleMode {
        self.mode
    }

    /// Offline communication: rotation keys + garbled tables for every
    /// intermediate activation (local steps run no ReLU).
    pub fn offline_bytes(&self) -> u64 {
        let key_bytes: usize = self
            .conv_keys
            .iter()
            .chain(self.fc_keys.iter())
            .flatten()
            .map(|gk| gk.keys.len() * KeySwitchKey::serialized_size(&self.ctx.params))
            .sum();
        let relu_count: usize = self
            .spec
            .steps
            .iter()
            .take(self.spec.steps.len() - 1)
            .filter(|s| !s.is_local())
            .map(|s| s.linear.num_outputs())
            .sum();
        (key_bytes + relu_count * self.relu.offline_bytes_per_relu()) as u64
    }

    /// Run one private inference. Mirrors `CheetahRunner::infer`. Wrapper
    /// over [`GazelleRunner::infer_with`] that also attributes the HE op
    /// counts (meaningful only when queries run one at a time).
    pub fn infer(&mut self, input: &Tensor) -> GazelleReport {
        let qi = self.next_query;
        self.next_query += 1;
        self.ev.reset_counts();
        let mut report = self.infer_with(input, qi);
        report.ops = self.ev.counts();
        report
    }

    /// Run a batch of independent queries fanned across the
    /// [`crate::par`] pool. Logits are bit-identical to looping
    /// [`GazelleRunner::infer`] (per-query RNG streams; see the type
    /// docs). HE op counts are not attributed per query in batch mode
    /// (the evaluator counters are shared across concurrent queries), so
    /// each report's `ops` is zero.
    pub fn infer_batch(&mut self, inputs: &[Tensor]) -> Vec<GazelleReport> {
        let base = self.next_query;
        self.next_query += inputs.len() as u64;
        crate::par::map_indexed(inputs.len(), |i| self.infer_with(&inputs[i], base + i as u64))
    }

    /// Stateless single-query core: every draw comes from the query's own
    /// `(seed, query index)` ChaCha20 stream and the share chain is local,
    /// so any number of queries may run concurrently on one deployment.
    /// `ops` is left at its default (see [`GazelleRunner::infer`]).
    pub fn infer_with(&self, input: &Tensor, query_index: u64) -> GazelleReport {
        let mut rng = ChaCha20Rng::new(&self.seed_key, QUERY_STREAM_BASE + query_index);
        let p = self.ctx.params.p;
        let plan = self.plan;
        let mut report = GazelleReport::default();

        // Initial shares: client holds the quantized input, server zero.
        let mut client_share: Vec<u64> = input
            .data
            .iter()
            .map(|&v| {
                let q = plan.quant_x(v);
                if q < 0 {
                    p - (-q) as u64
                } else {
                    q as u64
                }
            })
            .collect();
        let mut server_share: Vec<u64> = vec![0; client_share.len()];

        let fresh = ciphertext_bytes(&self.ctx.params, true) as u64;
        let eval_sz = ciphertext_bytes(&self.ctx.params, false) as u64;
        let n_steps = self.spec.steps.len();

        for si in 0..n_steps {
            let step = self.spec.steps[si].clone();
            let last = si == n_steps - 1;
            let step_t0 = Instant::now();

            // Local steps (standalone AvgPool) exchange nothing: both
            // parties sum-pool their own shares (the mean divisor was
            // folded into the next linear layer's weights at compile
            // time), exactly as in the CHEETAH runner.
            if let LinearSpec::AvgPool { shape, size } = &step.linear {
                client_share = pool_shares(&client_share, *shape, *size, p);
                server_share = pool_shares(&server_share, *shape, *size, p);
                report.client_time += step_t0.elapsed();
                report.per_step.push(step_t0.elapsed());
                continue;
            }

            // Residual steps re-add the step's *input* shares after the
            // ReLU — save them before the share chain moves on.
            let residual_in = if step.residual_add {
                Some((client_share.clone(), server_share.clone()))
            } else {
                None
            };

            // ---- client: pack + encrypt its share ----
            let t0 = Instant::now();
            let in_cts: Vec<Ciphertext> = match &step.linear {
                LinearSpec::Conv(cp) => match self.conv_geoms[si].as_ref() {
                    None => {
                        let (c_i, h, w) = cp.in_shape;
                        let hw = h * w;
                        (0..c_i)
                            .map(|i| {
                                let pt = self
                                    .ctx
                                    .encoder
                                    .encode_unsigned(&client_share[i * hw..(i + 1) * hw]);
                                self.client_enc.encrypt(&pt, &mut rng)
                            })
                            .collect()
                    }
                    Some(geom) => gala::pack_conv_input(geom, &client_share)
                        .iter()
                        .map(|slots| {
                            let pt = self.ctx.encoder.encode_unsigned(slots);
                            self.client_enc.encrypt(&pt, &mut rng)
                        })
                        .collect(),
                },
                LinearSpec::Fc(_) => {
                    let x: Vec<i64> = client_share.iter().map(|&v| v as i64).collect();
                    // pack_fc_input expects signed values; shares are
                    // residues — pack residues directly (mod-p linearity).
                    // Both modes share the hybrid tiled layout.
                    let packed_res: Vec<u64> = pack_fc_input(&self.ctx, &x, FcMethod::Hybrid)
                        .iter()
                        .map(|&v| v as u64 % p)
                        .collect();
                    let pt = self.ctx.encoder.encode_unsigned(&packed_res);
                    vec![self.client_enc.encrypt(&pt, &mut rng)]
                }
                LinearSpec::AvgPool { .. } => unreachable!("local steps handled above"),
            };
            report.client_time += t0.elapsed();
            report.online_bytes += in_cts.len() as u64 * fresh;
            report.c2s_bytes += in_cts.len() as u64 * fresh;

            // ---- server: add own share, packed linear kernel, mask ----
            let t1 = Instant::now();
            let mut in_ntt = in_cts;
            self.ev.to_ntt_batch(&mut in_ntt);
            // AddPlain the server's share, packed identically.
            match &step.linear {
                LinearSpec::Conv(cp) => match self.conv_geoms[si].as_ref() {
                    None => {
                        let (_, h, w) = cp.in_shape;
                        let hw = h * w;
                        for (i, ct) in in_ntt.iter_mut().enumerate() {
                            let op = self
                                .ctx
                                .add_operand_unsigned(&server_share[i * hw..(i + 1) * hw]);
                            self.ev.add_plain(ct, &op);
                        }
                    }
                    Some(geom) => {
                        for (slots, ct) in
                            gala::pack_conv_input(geom, &server_share).iter().zip(&mut in_ntt)
                        {
                            let op = self.ctx.add_operand_unsigned(slots);
                            self.ev.add_plain(ct, &op);
                        }
                    }
                },
                LinearSpec::Fc(_) => {
                    let x: Vec<i64> = server_share.iter().map(|&v| v as i64).collect();
                    let packed: Vec<u64> = pack_fc_input(&self.ctx, &x, FcMethod::Hybrid)
                        .iter()
                        .map(|&v| v as u64 % p)
                        .collect();
                    let op = self.ctx.add_operand_unsigned(&packed);
                    self.ev.add_plain(&mut in_ntt[0], &op);
                }
                LinearSpec::AvgPool { .. } => unreachable!("local steps handled above"),
            }

            // Linear kernel. Every output is a [`SlotRead`] (a single slot
            // in hybrid mode; a strided run in GALA mode).
            let layer = self.net.layers[step.layer_idx].clone();
            let (out_cts, out_map, out_shape): (
                Vec<Ciphertext>,
                Vec<SlotRead>,
                (usize, usize, usize),
            ) = match &step.linear {
                LinearSpec::Conv(cp) => {
                    let (c_i, h, w) = cp.in_shape;
                    let c_o = cp.out_shape.0;
                    // Strided conv: run at stride 1, downsample shares.
                    let mut l1 = layer.clone();
                    if let LayerKind::Conv2d { ref mut stride, ref mut pad, .. } = l1.kind {
                        *stride = 1;
                        *pad = cp.kernel / 2;
                    }
                    let hw = h * w;
                    let (outs, map) = match self.conv_geoms[si].as_ref() {
                        None => {
                            // GAZELLE picks whichever rotation variant is
                            // cheaper.
                            let variant = if c_i <= c_o {
                                ConvVariant::InputRotation
                            } else {
                                ConvVariant::OutputRotation
                            };
                            let outs = conv(
                                &self.ev,
                                variant,
                                &in_ntt,
                                &l1,
                                (c_i, h, w),
                                &plan,
                                step.weight_div,
                                self.conv_keys[si].as_ref().unwrap(),
                            );
                            let map = (0..c_o * hw)
                                .map(|o| SlotRead::single(o / hw, o % hw))
                                .collect();
                            (outs, map)
                        }
                        Some(geom) => {
                            let outs = gala::conv(
                                &self.ev,
                                geom,
                                &in_ntt,
                                &l1,
                                &plan,
                                step.weight_div,
                                self.conv_keys[si].as_ref().unwrap(),
                            );
                            let map =
                                (0..c_o * hw).map(|o| geom.read(o / hw, o % hw)).collect();
                            (outs, map)
                        }
                    };
                    (outs, map, (c_o, h, w))
                }
                LinearSpec::Fc(fp) => {
                    let (outs, map) = match self.mode {
                        GazelleMode::Hybrid => {
                            let (outs, map) = fc(
                                &self.ev,
                                FcMethod::Hybrid,
                                &in_ntt[0],
                                &layer,
                                fp.n_i,
                                &plan,
                                step.weight_div,
                                self.fc_keys[si].as_ref().unwrap(),
                            );
                            let map = map
                                .into_iter()
                                .map(|(ci, slot)| SlotRead::single(ci, slot))
                                .collect();
                            (outs, map)
                        }
                        GazelleMode::Gala => gala::fc(
                            &self.ev,
                            &in_ntt[0],
                            &layer,
                            fp.n_i,
                            &plan,
                            step.weight_div,
                        ),
                    };
                    (outs, map, (1, 1, fp.n_o))
                }
                LinearSpec::AvgPool { .. } => unreachable!("local steps handled above"),
            };

            // Mask with fresh server shares r (skip on the last layer: the
            // prediction is the protocol output). Every *slot* of every
            // read gets its own mask; the server's GC share of output `o`
            // is the sum of its read's masks mod p, so reconstruction is
            // exact in both modes (and draw order matches the historical
            // hybrid behavior, where every read is a single slot).
            let mut masked = out_cts;
            let n_lin = out_map.len();
            let mut r_share: Vec<u64> = Vec::new();
            if !last {
                r_share = Vec::with_capacity(n_lin);
                let row_slots = self.ctx.params.n;
                let mut scatter: Vec<Vec<u64>> = vec![vec![0u64; row_slots]; masked.len()];
                for read in &out_map {
                    let mut srv = 0u64;
                    for s in read.slots() {
                        let r = rng.gen_range(p);
                        scatter[read.ct][s] = (p - r) % p;
                        srv = (srv + r) % p;
                    }
                    r_share.push(srv);
                }
                for (ci, ct) in masked.iter_mut().enumerate() {
                    let op = self.ctx.add_operand_unsigned(&scatter[ci]);
                    self.ev.add_plain(ct, &op);
                }
            }
            report.server_linear += t1.elapsed();
            report.online_bytes += masked.len() as u64 * eval_sz;
            report.s2c_bytes += masked.len() as u64 * eval_sz;

            // ---- client: decrypt its linear share (summing each read's
            // run mod p — a single slot in hybrid mode) ----
            let t2 = Instant::now();
            let mut client_lin: Vec<u64> = Vec::with_capacity(n_lin);
            // Per-ciphertext decryption is independent — parallel batch.
            let (ctx, client_enc) = (&self.ctx, &self.client_enc);
            let decs: Vec<Vec<u64>> = crate::par::map_collect(&masked, |_, ct| {
                ctx.encoder.decode_unsigned(&client_enc.decrypt(ct))
            });
            for read in &out_map {
                let mut v = 0u64;
                for s in read.slots() {
                    v = (v + decs[read.ct][s]) % p;
                }
                client_lin.push(v);
            }
            report.client_time += t2.elapsed();

            if last {
                // Logits (scale x+k): client reconstructs directly.
                let scale = plan.x.mul(plan.k);
                let half = (p - 1) / 2;
                report.logits = client_lin
                    .iter()
                    .map(|&v| {
                        let c = if v > half { v as i64 - p as i64 } else { v as i64 };
                        scale.dequantize(c)
                    })
                    .collect();
                report.argmax = report
                    .logits
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap();
                report.per_step.push(step_t0.elapsed());
                break;
            }

            // ---- GC ReLU over shares (server garbles, client evaluates) ----
            let server_lin: Vec<u64> = r_share;
            let (mut c_new, mut s_new, gc_rep) =
                self.relu.run_batch(&server_lin, &client_lin, &mut rng);
            report.online_bytes += gc_rep.online_bytes;
            report.s2c_bytes += gc_rep.online_bytes;
            report.gc.merge(&gc_rep);

            // Strided conv downsample (shares, both parties identically).
            if let LinearSpec::Conv(cp) = &step.linear {
                if cp.stride > 1 {
                    let (c_o, h, w) = out_shape;
                    let (oh, ow) = (cp.out_shape.1, cp.out_shape.2);
                    let pick = |v: &[u64]| -> Vec<u64> {
                        let mut out = Vec::with_capacity(c_o * oh * ow);
                        for ch in 0..c_o {
                            for y in 0..oh {
                                for x in 0..ow {
                                    out.push(v[(ch * h + y * cp.stride) * w + x * cp.stride]);
                                }
                            }
                        }
                        out
                    };
                    c_new = pick(&c_new);
                    s_new = pick(&s_new);
                }
            }

            // Residual skip-add: both parties re-add their saved input
            // shares mod p, so the reconstruction gains exactly
            // `ReLU(linear(x)) + x` (shape-preserving; never fused with a
            // pool — compile() guarantees both).
            if let Some((res_c, res_s)) = residual_in {
                assert_eq!(c_new.len(), res_c.len(), "residual shapes must match");
                for (dst, &old) in c_new.iter_mut().zip(&res_c) {
                    *dst = (*dst + old) % p;
                }
                for (dst, &old) in s_new.iter_mut().zip(&res_s) {
                    *dst = (*dst + old) % p;
                }
            }

            // Pooling on shares.
            if let Some(size) = step.pool_after {
                c_new = pool_shares(&c_new, step.out_shape, size, p);
                s_new = pool_shares(&s_new, step.out_shape, size, p);
            }
            client_share = c_new;
            server_share = s_new;
            report.per_step.push(step_t0.elapsed());
        }

        report.offline_bytes = self.offline_bytes();
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Layer;
    use crate::phe::Params;
    use crate::util::rng::SplitMix64;

    fn random_input(shape: (usize, usize, usize), seed: u64) -> Tensor {
        let (c, h, w) = shape;
        let mut srng = SplitMix64::new(seed);
        Tensor::from_vec(
            (0..c * h * w).map(|_| srng.gen_f64_range(-1.0, 1.0)).collect(),
            c,
            h,
            w,
        )
    }

    /// Stride-1 conv + ReLU + FC: GAZELLE e2e must agree with the
    /// flat-semantics plaintext composition.
    #[test]
    fn gazelle_e2e_small_net() {
        let ctx = std::sync::Arc::new(Context::new(Params::default_params()));
        let plan = ScalePlan::default_plan();
        let mut net = Network {
            name: "gz-test".into(),
            input_shape: (1, 6, 6),
            layers: vec![Layer::conv(2, 3, 1, 1), Layer::relu(), Layer::fc(4)],
        };
        net.init_weights(71);
        let netc = net.clone();
        let mut runner = GazelleRunner::new(ctx, net, plan, 72).expect("valid network");

        let input = random_input((1, 6, 6), 73);
        let report = runner.infer(&input);
        assert!(report.ops.perm > 0, "GAZELLE must pay permutations");
        assert!(report.gc.and_gates_total > 0, "GAZELLE must garble");

        // Reference with identical flat-border semantics.
        let xq: Vec<i64> = input.data.iter().map(|&v| plan.quant_x(v)).collect();
        let lin =
            super::super::conv::conv_flat_reference(&xq, &netc.layers[0], (1, 6, 6), &plan, 1.0);
        let act: Vec<i64> = lin.iter().map(|&v| (v.max(0)) >> plan.k.frac_bits).collect();
        let logits = super::super::fc::fc_reference(&act, &netc.layers[2], &plan, 1.0);
        let scale = plan.x.mul(plan.k);
        for (i, (&got, &want)) in report.logits.iter().zip(&logits).enumerate() {
            let want_f = scale.dequantize(want);
            assert!(
                (got - want_f).abs() < 1e-9,
                "logit {i}: got {got} want {want_f}"
            );
        }
    }

    /// The acceptance property of the GALA mode: logits bit-identical to
    /// the hybrid baseline under pinned seeds, with strictly fewer Perms
    /// and strictly less offline key material.
    #[test]
    fn gala_mode_logits_bit_identical_to_hybrid() {
        let ctx = std::sync::Arc::new(Context::new(Params::default_params()));
        let plan = ScalePlan::default_plan();
        let mut net = Network {
            name: "gala-vs-hybrid".into(),
            input_shape: (1, 6, 6),
            layers: vec![Layer::conv(3, 3, 1, 1), Layer::relu(), Layer::fc(4)],
        };
        net.init_weights(81);

        let mut hybrid =
            GazelleRunner::new(ctx.clone(), net.clone(), plan, 82).expect("valid network");
        let mut gala = GazelleRunner::with_mode(ctx, net, plan, 82, GazelleMode::Gala)
            .expect("valid network");
        assert_eq!(gala.mode(), GazelleMode::Gala);

        let input = random_input((1, 6, 6), 83);
        let hy = hybrid.infer(&input);
        let ga = gala.infer(&input);

        assert_eq!(hy.logits.len(), ga.logits.len());
        for (i, (a, b)) in hy.logits.iter().zip(&ga.logits).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "logit {i}: hybrid {a} vs gala {b}");
        }
        assert_eq!(hy.argmax, ga.argmax);
        assert!(
            ga.ops.perm < hy.ops.perm,
            "gala perms {} must be strictly below hybrid {}",
            ga.ops.perm,
            hy.ops.perm
        );
        assert!(
            ga.offline_bytes < hy.offline_bytes,
            "gala offline {} must be below hybrid {} (fewer rotation keys)",
            ga.offline_bytes,
            hy.offline_bytes
        );
    }

    /// Residual skip-adds are share-level in both modes and match the
    /// plaintext mirror `ReLU(conv(x)) + x`.
    #[test]
    fn residual_net_matches_plaintext_mirror_in_both_modes() {
        let ctx = std::sync::Arc::new(Context::new(Params::default_params()));
        let plan = ScalePlan::default_plan();
        let mut net = Network {
            name: "gz-res".into(),
            input_shape: (2, 5, 5),
            layers: vec![
                Layer::conv(2, 3, 1, 1),
                Layer::relu(),
                Layer::residual_add(),
                Layer::fc(4),
            ],
        };
        net.init_weights(91);
        let netc = net.clone();
        let input = random_input((2, 5, 5), 93);

        // Plaintext mirror with identical flat-border semantics:
        // act = (ReLU(conv(xq)) >> frac) + xq, then FC.
        let xq: Vec<i64> = input.data.iter().map(|&v| plan.quant_x(v)).collect();
        let lin =
            super::super::conv::conv_flat_reference(&xq, &netc.layers[0], (2, 5, 5), &plan, 1.0);
        let act: Vec<i64> = lin
            .iter()
            .zip(&xq)
            .map(|(&v, &x)| ((v.max(0)) >> plan.k.frac_bits) + x)
            .collect();
        let logits = super::super::fc::fc_reference(&act, &netc.layers[3], &plan, 1.0);
        let scale = plan.x.mul(plan.k);

        for mode in [GazelleMode::Hybrid, GazelleMode::Gala] {
            let mut runner = GazelleRunner::with_mode(ctx.clone(), net.clone(), plan, 92, mode)
                .expect("residual network must compile");
            let report = runner.infer(&input);
            for (i, (&got, &want)) in report.logits.iter().zip(&logits).enumerate() {
                let want_f = scale.dequantize(want);
                assert!(
                    (got - want_f).abs() < 1e-9,
                    "{mode:?} logit {i}: got {got} want {want_f}"
                );
            }
        }
    }

    /// A standalone leading average-pool is a zero-ciphertext local step
    /// (both parties sum-pool shares; the divisor folds into the next
    /// conv's weights) in both modes.
    #[test]
    fn standalone_avgpool_net_matches_reference_in_both_modes() {
        let ctx = std::sync::Arc::new(Context::new(Params::default_params()));
        let plan = ScalePlan::default_plan();
        let mut net = Network {
            name: "gz-pool".into(),
            input_shape: (1, 8, 8),
            layers: vec![
                Layer::mean_pool(2),
                Layer::conv(2, 3, 1, 1),
                Layer::relu(),
                Layer::fc(4),
            ],
        };
        net.init_weights(95);
        let netc = net.clone();
        let input = random_input((1, 8, 8), 97);

        // Plaintext mirror: sum-pool xq, conv with weight_div = 4 (the
        // folded mean divisor), ReLU >> frac, FC.
        let xq: Vec<i64> = input.data.iter().map(|&v| plan.quant_x(v)).collect();
        let mut pooled = Vec::with_capacity(16);
        for y in 0..4 {
            for x in 0..4 {
                let mut acc = 0i64;
                for dy in 0..2 {
                    for dx in 0..2 {
                        acc += xq[(2 * y + dy) * 8 + 2 * x + dx];
                    }
                }
                pooled.push(acc);
            }
        }
        let lin = super::super::conv::conv_flat_reference(
            &pooled,
            &netc.layers[1],
            (1, 4, 4),
            &plan,
            4.0,
        );
        let act: Vec<i64> = lin.iter().map(|&v| (v.max(0)) >> plan.k.frac_bits).collect();
        let logits = super::super::fc::fc_reference(&act, &netc.layers[3], &plan, 1.0);
        let scale = plan.x.mul(plan.k);

        for mode in [GazelleMode::Hybrid, GazelleMode::Gala] {
            let mut runner = GazelleRunner::with_mode(ctx.clone(), net.clone(), plan, 96, mode)
                .expect("avgpool network must compile");
            let report = runner.infer(&input);
            assert_eq!(report.per_step.len(), 3, "pool step must report too");
            for (i, (&got, &want)) in report.logits.iter().zip(&logits).enumerate() {
                let want_f = scale.dequantize(want);
                assert!(
                    (got - want_f).abs() < 1e-9,
                    "{mode:?} logit {i}: got {got} want {want_f}"
                );
            }
        }
    }
}
