//! GAZELLE packed convolution: the rotation-based baseline CHEETAH beats.
//!
//! Packing: one input channel per ciphertext, spatial positions row-major
//! in the first half-row (`h·w ≤ n/2`). The convolution is computed with
//! the diagonal method — each kernel offset `d = dy·w + dx` contributes
//! `Perm(input, d) ∘ broadcast(k[o][i][d])`, accumulated per output
//! channel. Two variants, as in the paper's Table 3:
//!
//! * **Input rotation (IR)**: rotate each input channel once per offset,
//!   reuse across output channels. `#Perm = c_i(r²−1)`,
//!   `#Mult = c_i·c_o·r²`.
//! * **Output rotation (OR)**: multiply first, rotate per-offset partial
//!   sums. `#Perm = c_o(r²−1)`, `#Mult = c_i·c_o·r²`.
//!
//! Border semantics: offsets index the *flattened* spatial vector with a
//! zero tail (not per-row zero padding). GAZELLE handles true borders with
//! extra masking multiplications; our op counts are therefore a slight
//! *under*-estimate of real GAZELLE cost — conservative in CHEETAH's favor.
//! The plaintext reference [`conv_flat_reference`] uses identical
//! semantics, so correctness tests are exact.

use crate::fixed::ScalePlan;
use crate::nn::layers::Layer;
use crate::phe::keys::galois_elt_for_step;
use crate::phe::{Ciphertext, Context, Evaluator, GaloisKeys, SecretKey};
use crate::util::rng::ChaCha20Rng;

/// Which rotation strategy to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConvVariant {
    /// Rotate each input channel per offset, reuse across output channels
    /// (`#Perm = c_i(r²−1)`).
    InputRotation,
    /// Multiply first, rotate per-offset partial sums
    /// (`#Perm = c_o(r²−1)`).
    OutputRotation,
}

/// The kernel-offset displacements for an `r×r` kernel centred at
/// `(r/2, r/2)` over a `w`-wide row-major image.
pub fn kernel_offsets(r: usize, w: usize) -> Vec<i64> {
    let c = (r / 2) as i64;
    let mut out = Vec::with_capacity(r * r);
    for ky in 0..r as i64 {
        for kx in 0..r as i64 {
            out.push((ky - c) * w as i64 + (kx - c));
        }
    }
    out
}

/// Galois elements needed for a conv shape (for offline key generation).
pub fn needed_galois_elts(ctx: &Context, r: usize, w: usize) -> Vec<u64> {
    kernel_offsets(r, w)
        .into_iter()
        .filter(|&d| d != 0)
        .map(|d| galois_elt_for_step(&ctx.params, d))
        .collect()
}

/// Generate rotation keys for a conv shape.
pub fn conv_galois_keys(
    ctx: &Context,
    sk: &SecretKey,
    r: usize,
    w: usize,
    rng: &mut ChaCha20Rng,
) -> GaloisKeys {
    GaloisKeys::generate_for(ctx, sk, rng, &needed_galois_elts(ctx, r, w))
}

/// GAZELLE convolution: `in_cts[i]` holds input channel `i` (NTT form),
/// stride 1. Returns one ciphertext per output channel, spatial outputs in
/// the same slots as the inputs. Quantization: inputs at `plan.x`, weights
/// at `plan.k` (divided by `weight_div` to absorb preceding mean-pools).
#[allow(clippy::too_many_arguments)]
pub fn conv(
    ev: &Evaluator,
    variant: ConvVariant,
    in_cts: &[Ciphertext],
    layer: &Layer,
    in_shape: (usize, usize, usize),
    plan: &ScalePlan,
    weight_div: f64,
    gk: &GaloisKeys,
) -> Vec<Ciphertext> {
    let ctx = &*ev.ctx;
    let (c_i, h, w) = in_shape;
    assert_eq!(in_cts.len(), c_i, "one ciphertext per input channel");
    assert!(h * w <= ctx.params.row_size(), "image must fit one half-row");
    let crate::nn::layers::LayerKind::Conv2d { out_channels, kernel, stride, .. } = layer.kind
    else {
        panic!("conv requires Conv2d layer")
    };
    assert_eq!(stride, 1, "GAZELLE packed conv path supports stride 1");
    let offsets = kernel_offsets(kernel, w);
    let hw = h * w;

    let quant = |v: f64| plan.quant_k(v / weight_div);
    // Broadcast multiplier for (o, i, tap): kernel coefficient in every
    // live spatial slot.
    let broadcast = |o: usize, i: usize, t: usize| -> Vec<i64> {
        let kq = quant(layer.conv_w(c_i, kernel, o, i, t / kernel, t % kernel));
        vec![kq; hw]
    };

    // Both variants fan out over GAZELLE's independent units: the
    // per-(input-channel, offset) rotations and the per-output-channel
    // accumulation chains. Accumulation order *within* a channel stays
    // exactly the sequential order, so results are bit-identical at any
    // thread count; only op-counter increments interleave (atomic).
    match variant {
        ConvVariant::InputRotation => {
            // Rotate each input channel per offset once — every rotation
            // (i, t) is independent.
            let n_off = offsets.len();
            let rotated_flat: Vec<Ciphertext> = crate::par::map_indexed(c_i * n_off, |k| {
                let (i, t) = (k / n_off, k % n_off);
                let d = offsets[t];
                if d == 0 {
                    in_cts[i].clone()
                } else {
                    ev.rotate_rows(&in_cts[i], d, gk)
                }
            });
            let rotated: Vec<&[Ciphertext]> = rotated_flat.chunks(n_off).collect();
            crate::par::map_indexed(out_channels, |o| {
                let mut acc: Option<Ciphertext> = None;
                for (i, rot_i) in rotated.iter().enumerate() {
                    for (t, _) in offsets.iter().enumerate() {
                        let op = ctx.mult_operand(&broadcast(o, i, t));
                        let prod = ev.mult_plain(&rot_i[t], &op);
                        match &mut acc {
                            None => acc = Some(prod),
                            Some(a) => ev.add_assign(a, &prod),
                        }
                    }
                }
                acc.unwrap()
            })
        }
        ConvVariant::OutputRotation => {
            crate::par::map_indexed(out_channels, |o| {
                let mut acc: Option<Ciphertext> = None;
                for (t, &d) in offsets.iter().enumerate() {
                    // Sum over input channels first, then one rotation
                    // per (o, offset).
                    let mut partial: Option<Ciphertext> = None;
                    for (i, ct) in in_cts.iter().enumerate() {
                        let op = ctx.mult_operand(&broadcast(o, i, t));
                        let prod = ev.mult_plain(ct, &op);
                        match &mut partial {
                            None => partial = Some(prod),
                            Some(p) => ev.add_assign(p, &prod),
                        }
                    }
                    let mut part = partial.unwrap();
                    if d != 0 {
                        part = ev.rotate_rows(&part, d, gk);
                    }
                    match &mut acc {
                        None => acc = Some(part),
                        Some(a) => ev.add_assign(a, &part),
                    }
                }
                acc.unwrap()
            })
        }
    }
}

/// The plaintext reference with identical flat-index border semantics.
pub fn conv_flat_reference(
    input_q: &[i64],
    layer: &Layer,
    in_shape: (usize, usize, usize),
    plan: &ScalePlan,
    weight_div: f64,
) -> Vec<i64> {
    let (c_i, h, w) = in_shape;
    let crate::nn::layers::LayerKind::Conv2d { out_channels, kernel, .. } = layer.kind else {
        panic!("requires Conv2d")
    };
    let hw = h * w;
    let offsets = kernel_offsets(kernel, w);
    let quant = |v: f64| plan.quant_k(v / weight_div);
    let mut out = vec![0i64; out_channels * hw];
    for o in 0..out_channels {
        for s in 0..hw {
            let mut acc = 0i64;
            for i in 0..c_i {
                for (t, &d) in offsets.iter().enumerate() {
                    let src = s as i64 + d;
                    if src >= 0 && (src as usize) < hw {
                        let kq = quant(layer.conv_w(c_i, kernel, o, i, t / kernel, t % kernel));
                        acc += kq * input_q[i * hw + src as usize];
                    }
                }
            }
            out[o * hw + s] = acc;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::phe::{Encryptor, Params};
    use crate::util::rng::SplitMix64;

    #[test]
    fn both_variants_match_reference_and_counts() {
        let ctx = std::sync::Arc::new(Context::new(Params::new(1024, 20)));
        let plan = ScalePlan::default_plan();
        let mut rng = ChaCha20Rng::from_u64_seed(31);
        let mut srng = SplitMix64::new(32);
        let enc = Encryptor::new(ctx.clone(), &mut rng);
        let ev = Evaluator::new(ctx.clone());

        let (c_i, c_o, h, w, r) = (2usize, 3usize, 8usize, 8usize, 3usize);
        let mut layer = Layer::conv(c_o, r, 1, 1);
        layer.init_weights(c_i, h, w, &mut srng);
        let gk = conv_galois_keys(&ctx, &enc.sk, r, w, &mut rng);

        let input_q: Vec<i64> =
            (0..c_i * h * w).map(|_| srng.gen_i64_range(-128, 128)).collect();
        let mut in_cts: Vec<Ciphertext> = (0..c_i)
            .map(|i| enc.encrypt_slots(&input_q[i * h * w..(i + 1) * h * w], &mut rng))
            .collect();
        for ct in in_cts.iter_mut() {
            ev.to_ntt(ct);
        }

        let reference = conv_flat_reference(&input_q, &layer, (c_i, h, w), &plan, 1.0);

        for (variant, expect_perm) in [
            (ConvVariant::InputRotation, (c_i * (r * r - 1)) as u64),
            (ConvVariant::OutputRotation, (c_o * (r * r - 1)) as u64),
        ] {
            ev.reset_counts();
            let out = conv(&ev, variant, &in_cts, &layer, (c_i, h, w), &plan, 1.0, &gk);
            assert_eq!(out.len(), c_o);
            let counts = ev.counts();
            assert_eq!(counts.perm, expect_perm, "{variant:?} perm count");
            assert_eq!(counts.mult, (c_i * c_o * r * r) as u64, "{variant:?} mult count");
            for (o, ct) in out.iter().enumerate() {
                let dec = enc.decrypt_slots(ct);
                for s in 0..h * w {
                    assert_eq!(
                        dec[s],
                        reference[o * h * w + s],
                        "{variant:?} o={o} s={s}"
                    );
                }
            }
        }
    }

    #[test]
    fn offsets_cover_kernel() {
        let offs = kernel_offsets(3, 8);
        assert_eq!(offs.len(), 9);
        assert_eq!(offs[4], 0); // centre
        assert_eq!(offs[0], -9); // top-left: -w-1
        assert_eq!(offs[8], 9);
    }
}
