//! The **GAZELLE** baseline (Juvekar et al., USENIX Security'18) — the
//! fastest prior framework in the paper's Table 1, reimplemented on the
//! same PHE substrate so every comparison is apples-to-apples:
//!
//! * [`conv`] — rotation-based packed convolution (input-rotation and
//!   output-rotation variants; Table 3),
//! * [`fc`] — naive / Halevi–Shoup / hybrid matrix-vector products
//!   (Tables 2 and 4),
//! * [`runner`] — the full inference pipeline with GC ReLU between layers
//!   (Tables 6 and 7, Figs. 6 and 8).
//!
//! What the paper's analysis predicts — and these modules measure — is that
//! every linear layer pays `Perm` operations (each ≈ tens of `Mult`s) and
//! every nonlinear layer pays per-element garbled tables, both of which
//! CHEETAH eliminates.
//!
//! The runner also drives the greedy-packing successor of this baseline
//! ([`crate::protocol::gala`]) via [`GazelleMode::Gala`] — same substrate,
//! same shares, same GC ReLU, strictly fewer rotations.

pub mod conv;
pub mod fc;
pub mod runner;

pub use conv::{conv, conv_flat_reference, conv_galois_keys, ConvVariant};
pub use fc::{fc, fc_galois_keys, fc_reference, pack_fc_input, FcMethod};
pub use runner::{GazelleMode, GazelleReport, GazelleRunner};
