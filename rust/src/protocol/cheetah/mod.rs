//! **CHEETAH** — the paper's contribution: joint obscure linear and
//! nonlinear computation for private neural-network inference.
//!
//! The pipeline per fused step (paper §3.1, Fig. 3):
//!
//! ```text
//!  client                                   server
//!  ──────                                   ──────
//!  [T(share_C)]_C  ───────────────────────▶ MultPlain(k'∘v) ⊕ AddPlain(k'v∘T(share_S)+b)
//!                                             (zero Perm — the whole point)
//!  decrypt, block-sum → y = v·(Con+δ) ◀─────  [x'∘k'∘v + b]_C
//!  ID₁∘y + ID₂∘ReLU(y) − s₁  (under [·]_S)
//!                  ───────────────────────▶ decrypt → server share
//!  share_C := s₁                             share_S := ReLU(Con+δ)·2^x − s₁
//! ```
//!
//! Both parties then hold additive shares (mod p) of the exact, requantized
//! ReLU activation, and the next layer repeats. Pooling is a share-domain
//! sum-pool with the divisor folded into the next layer's weights. The last
//! layer returns the obscured linear result directly (paper's `f^OMI`).
//!
//! Differences from the paper text (documented in DESIGN.md):
//!
//! * Hidden layers run on **additive shares** with the client sending its
//!   *transformed* share. The paper claims untransformed `[a]_C` suffices
//!   (§3.4 communication analysis), but re-packing `a` into `x'` under HE
//!   would itself require the permutations CHEETAH eliminates; the share
//!   form keeps the protocol perm-free at slightly higher C→S bandwidth.
//! * The multiplicative blind is `±2^j` so that `v₁v₂ = 1` exactly (see
//!   [`blinding`]); recovery is bit-exact, preserving "approximation-free".
//!
//! # Seed and domain-separation convention
//!
//! Every RNG in the protocol derives deterministically from a small number
//! of `u64` seeds, so pinned-seed runs are reproducible bit for bit:
//!
//! * **server** — engine seed `s` drives key generation, per-block blinds
//!   `v₁ = ±2^j`, noise targets δ, and one fresh `u64` noise seed per step;
//!   that seed expands to a ChaCha20 *key*
//!   ([`crate::util::rng::ChaCha20Rng::key_from_u64`]) and output channel
//!   `ch` draws its per-tap noise stream
//!   `b` from **stream id `ch`** of that key — the same key/stream
//!   convention the client uses for per-query isolation. Distinct stream
//!   ids give disjoint keystreams, so channel streams can never collide
//!   across channels or steps, and channels fan out across threads without
//!   making the draw order scheduling-dependent. *Compat note:* through
//!   PR 4 channel streams were derived by seed XOR
//!   (`noise_seed ^ (channel << 32)`), which could alias across
//!   channel/step pairs; the key/stream derivation changes the per-tap `b`
//!   values of a pinned seed (ciphertexts differ from pre-PR-5 runs) but
//!   **not** the logits — each block's noise still sums exactly to `v₁·δ`,
//!   which is all the recovery observes. The in-process runner gives the
//!   client `s + 1`; a [`crate::serve::SecureServer`] hands sessions
//!   engine seeds `base, base+1, …`; the networked client XORs a 64-bit
//!   domain constant into its seed so its streams can never collide with a
//!   pool session's.
//! * **client** — seed expands to a ChaCha20 key; **stream 0** is key
//!   generation and **stream `1 + query_index`** is query `query_index`'s
//!   private stream (encryption randomness + fresh shares `s₁`). See
//!   [`client`] module docs — this per-query isolation is what makes
//!   batch-parallel inference bit-identical to the sequential loop.
//!
//! **Bit-exactness caveat** (from CHANGES.md): recovery requantization
//! rounds exact-tie values toward the blind's sign, so "bit-identical" is
//! always a *per-server-blinding-seed* property. Logits do not depend on
//! the client seed at all (decryption is exact and the shares `s₁` cancel
//! on reconstruction), which is why batch order, thread count, and client
//! RNG scheme cannot perturb them.

pub mod blinding;
pub mod client;
pub mod packing;
pub mod runner;
pub mod server;
pub mod spec;

pub use client::{CheetahClient, ClientQuery};
pub use runner::{CheetahRunner, InferenceReport, StepReport};
pub use server::CheetahServer;
pub use spec::{LinearSpec, ProtocolSpec, SpecError, StepSpec};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::ScalePlan;
    use crate::nn::{Network, NetworkArch, SyntheticDigits, Tensor};
    use crate::phe::{Context, Params};
    use crate::util::rng::SplitMix64;

    fn ctx() -> std::sync::Arc<Context> {
        std::sync::Arc::new(Context::new(Params::default_params()))
    }

    /// A tiny 2-layer CNN (the paper's §3 worked example shape): private
    /// inference must match the plaintext quantized forward pass closely.
    #[test]
    fn e2e_tiny_cnn_matches_plaintext() {
        let c = ctx();
        let plan = ScalePlan::default_plan();
        let mut net = Network {
            name: "tiny".into(),
            input_shape: (1, 4, 4),
            layers: vec![
                crate::nn::Layer::conv(2, 3, 1, 1),
                crate::nn::Layer::relu(),
                crate::nn::Layer::fc(4),
            ],
        };
        net.init_weights(77);
        let float_net = net.clone();

        let mut runner = CheetahRunner::new(c.clone(), net, plan, 0.0, 42).expect("valid network");
        let off = runner.run_offline();
        assert!(off > 0);

        let mut rng = SplitMix64::new(5);
        for trial in 0..3 {
            let input = Tensor::from_vec(
                (0..16).map(|_| rng.gen_f64_range(-1.0, 1.0)).collect(),
                1,
                4,
                4,
            );
            let report = runner.infer(&input);
            let expect = float_net.forward(&input);
            // Same argmax, values within quantization tolerance.
            assert_eq!(report.argmax, expect.argmax(), "trial {trial}");
            for (i, (&got, &want)) in report.logits.iter().zip(&expect.data).enumerate() {
                assert!(
                    (got - want).abs() < 0.12,
                    "trial {trial} logit {i}: got {got} want {want}"
                );
            }
            // CHEETAH must never permute.
            assert_eq!(report.total_ops().perm, 0, "CHEETAH used a Perm!");
        }
    }

    /// Network A end-to-end on a synthetic digit: private inference agrees
    /// with the plaintext float forward pass on argmax, with zero Perms,
    /// and the op counts match the paper's complexity table.
    #[test]
    fn e2e_net_a() {
        let c = ctx();
        let plan = ScalePlan::default_plan();
        let net = Network::build(NetworkArch::NetA, 11);
        let float_net = net.clone();
        let mut runner = CheetahRunner::new(c.clone(), net, plan, 0.01, 43).expect("valid network");
        runner.run_offline();

        let mut gen = SyntheticDigits::new(28, 9);
        let sample = gen.render(3);
        let report = runner.infer(&sample.image);
        let expect = float_net.forward(&sample.image);
        assert_eq!(report.argmax, expect.argmax());
        assert_eq!(report.total_ops().perm, 0);

        // Paper Table 2 (CH-MIMO/CH-FC): Mult count == number of
        // (channel × input-ct) pairs, no more.
        let n = c.params.n;
        let expected_mults: u64 = runner
            .spec()
            .steps
            .iter()
            .map(|s| (s.linear.num_channels() * s.linear.num_in_cts(n)) as u64)
            .sum();
        let server_mults: u64 = report.steps.iter().map(|s| s.server_ops.mult).sum();
        assert_eq!(server_mults, expected_mults);
        assert!(report.online_bytes() > 0);
        assert!(report.wire_time > std::time::Duration::ZERO);
    }

    /// Network B exercises pooling on shares.
    #[test]
    fn e2e_net_b_with_pooling() {
        let c = ctx();
        let plan = ScalePlan::default_plan();
        // Scaled-down Net B for test speed (structure preserved: 2 conv,
        // 2 pools, 2 fc).
        let net = Network::build_scaled(NetworkArch::NetB, 13, 0.5);
        let float_net = net.clone();
        let mut runner = CheetahRunner::new(c.clone(), net, plan, 0.0, 44).expect("valid network");
        runner.run_offline();

        let mut gen = SyntheticDigits::new(14, 3);
        let sample = gen.render(7);
        let report = runner.infer(&sample.image);
        let expect = float_net.forward(&sample.image);
        // Random-weight Net B has near-zero logit margins (~0.003), so the
        // check is value-closeness, not argmax (argmax is asserted on the
        // larger-margin Net A test and on trained nets in integration
        // tests).
        for (i, (&got, &want)) in report.logits.iter().zip(&expect.data).enumerate() {
            assert!(
                (got - want).abs() < 0.08,
                "logit {i}: got {got} want {want} (quantization drift too large)"
            );
        }
        assert_eq!(report.total_ops().perm, 0);
    }

    /// Batch-parallel inference must be bit-identical to the looped
    /// sequential path on an identically-seeded deployment — and per-query
    /// traffic accounting must agree between the two drivers.
    #[test]
    fn batch_inference_is_bit_exact_vs_looped() {
        let c = ctx();
        let plan = ScalePlan::default_plan();
        let mut net = Network {
            name: "batch".into(),
            input_shape: (1, 5, 5),
            layers: vec![
                crate::nn::Layer::conv(2, 3, 1, 1),
                crate::nn::Layer::relu(),
                crate::nn::Layer::fc(3),
            ],
        };
        net.init_weights(88);
        let mut srng = SplitMix64::new(89);
        let inputs: Vec<Tensor> = (0..5)
            .map(|_| {
                Tensor::from_vec(
                    (0..25).map(|_| srng.gen_f64_range(-1.0, 1.0)).collect(),
                    1,
                    5,
                    5,
                )
            })
            .collect();

        // Looped reference on a fresh deployment.
        let mut looped =
            CheetahRunner::new(c.clone(), net.clone(), plan, 0.0, 91).expect("valid network");
        looped.run_offline();
        let want: Vec<_> = inputs.iter().map(|x| looped.infer(x)).collect();

        // Batch on an identically-seeded fresh deployment.
        let mut batched = CheetahRunner::new(c, net, plan, 0.0, 91).expect("valid network");
        batched.run_offline();
        let got = batched.infer_batch(&inputs);
        assert_eq!(got.len(), want.len());
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(g.logits, w.logits, "query {i}: batch diverged from loop");
            assert_eq!(g.argmax, w.argmax, "query {i}");
            assert_eq!(
                g.online_bytes(),
                w.online_bytes(),
                "query {i}: batch traffic accounting diverged from the metered loop"
            );
        }

        // Interleaving loop and batch on one deployment stays bit-exact
        // too (blinding is per-deployment, not per-query-order).
        let tail = looped.infer_batch(&inputs[..2]);
        assert_eq!(tail[0].logits, want[0].logits);
        assert_eq!(tail[1].logits, want[1].logits);
    }

    /// The offline/online attribution contract: with a warm operand cache
    /// (the default), the online phase of `step_linear_with` constructs and
    /// allocates **zero** operand polynomials — cached `k'∘v` / `b`
    /// operands apply directly, and hidden-layer additive operands build in
    /// reused arena scratch. Instrumented via the server context's
    /// operand-build counter and the arena's fresh-allocation counter, at
    /// threads 1/2/8 (bit-exact logits throughout).
    #[test]
    fn online_phase_builds_no_operand_polys() {
        let plan = ScalePlan::default_plan();
        let mut net = Network {
            name: "arena".into(),
            input_shape: (1, 5, 5),
            layers: vec![
                crate::nn::Layer::conv(2, 3, 1, 1),
                crate::nn::Layer::relu(),
                crate::nn::Layer::conv(3, 3, 1, 1),
                crate::nn::Layer::relu(),
                crate::nn::Layer::fc(3),
            ],
        };
        net.init_weights(31);
        // Separate (equal) contexts: the server counter must see only
        // server-side constructions — the client builds its recovery
        // operands online by design.
        let server_ctx = std::sync::Arc::new(Context::new(Params::default_params()));
        let client_ctx = std::sync::Arc::new(Context::new(Params::default_params()));
        let server =
            CheetahServer::new(server_ctx.clone(), net, plan, 0.0, 71).expect("valid network");
        assert!(server.cached_operand_bytes() > 0, "small net must fit the default budget");
        let mut client = CheetahClient::new(client_ctx, server.spec.clone(), plan, 72);
        for si in 0..server.spec.steps.len() {
            let (id1, id2) = server.indicator_cts(si);
            client.install_indicators(si, id1.to_vec(), id2.to_vec());
        }
        let input =
            Tensor::from_vec((0..25).map(|i| (i as f64 - 12.0) / 13.0).collect(), 1, 5, 5);

        let run = |client: &mut CheetahClient, threads: usize| {
            crate::par::with_threads(threads, || {
                client.begin_query(&input);
                let mut s_share = server.fresh_share();
                for si in 0..server.spec.steps.len() {
                    let in_cts = client.step_send(si);
                    let out = server.step_linear_with(si, &in_cts, &s_share);
                    if let Some(rec) = client.step_receive(si, &out) {
                        s_share = server.finish_nonlinear_with(si, &rec);
                    }
                }
                client.logits()
            })
        };

        // Cover the worst-case concurrent scratch demand, then warm up.
        server.scratch().reserve(&server.ctx.params, 16);
        let want = run(&mut client, 8);
        let builds0 = server_ctx.operand_builds();
        let fresh0 = server.scratch().stats().fresh_allocs;
        for threads in [1usize, 2, 8] {
            let got = run(&mut client, threads);
            assert_eq!(got, want, "threads={threads}: logits diverged");
        }
        assert_eq!(
            server_ctx.operand_builds(),
            builds0,
            "online phase constructed operand polynomials"
        );
        assert_eq!(
            server.scratch().stats().fresh_allocs,
            fresh0,
            "online phase allocated scratch buffers"
        );
        assert!(server.scratch().stats().checkouts > 0, "hidden layers must use the arena");
    }

    /// Cached-operand scoring must be bit-identical to the rebuild-per-query
    /// (tiled, budget 0) path at every thread count: the cache budget gates
    /// only *where* operands are built, never the blinding draws — so two
    /// same-seed deployments agree ciphertext-for-ciphertext.
    #[test]
    fn cached_operand_scoring_is_bit_exact_vs_rebuild() {
        let c = ctx();
        let plan = ScalePlan::default_plan();
        let mut net = Network {
            name: "cachecmp".into(),
            input_shape: (1, 5, 5),
            layers: vec![
                crate::nn::Layer::conv(2, 3, 1, 1),
                crate::nn::Layer::relu(),
                crate::nn::Layer::fc(4),
            ],
        };
        net.init_weights(91);
        let cached =
            CheetahServer::new(c.clone(), net.clone(), plan, 0.01, 77).expect("valid network");
        let rebuild = CheetahServer::with_cache_budget(c.clone(), net, plan, 0.01, 77, 0)
            .expect("valid network");
        assert!(cached.cached_operand_bytes() > 0, "default budget must cache this net");
        assert_eq!(rebuild.cached_operand_bytes(), 0, "budget 0 must disable the cache");
        let mut client_a = CheetahClient::new(c.clone(), cached.spec.clone(), plan, 78);
        let mut client_b = CheetahClient::new(c.clone(), rebuild.spec.clone(), plan, 78);
        for si in 0..cached.spec.steps.len() {
            let (id1, id2) = cached.indicator_cts(si);
            client_a.install_indicators(si, id1.to_vec(), id2.to_vec());
            let (id1, id2) = rebuild.indicator_cts(si);
            client_b.install_indicators(si, id1.to_vec(), id2.to_vec());
        }
        let input =
            Tensor::from_vec((0..25).map(|i| (i as f64 - 10.0) / 15.0).collect(), 1, 5, 5);
        for threads in [1usize, 2, 8] {
            crate::par::with_threads(threads, || {
                client_a.begin_query(&input);
                client_b.begin_query(&input);
                let mut sa = cached.fresh_share();
                let mut sb = rebuild.fresh_share();
                for si in 0..cached.spec.steps.len() {
                    let ia = client_a.step_send(si);
                    let ib = client_b.step_send(si);
                    let oa = cached.step_linear_with(si, &ia, &sa);
                    let ob = rebuild.step_linear_with(si, &ib, &sb);
                    assert_eq!(oa.len(), ob.len());
                    for (k, (x, y)) in oa.iter().zip(&ob).enumerate() {
                        assert_eq!(
                            x.c0, y.c0,
                            "threads={threads} step {si} ct {k}: products diverged"
                        );
                        assert_eq!(x.c1, y.c1);
                    }
                    if let Some(ra) = client_a.step_receive(si, &oa) {
                        let rb = client_b.step_receive(si, &ob).expect("same round shape");
                        sa = cached.finish_nonlinear_with(si, &ra);
                        sb = rebuild.finish_nonlinear_with(si, &rb);
                        assert_eq!(sa, sb, "threads={threads} step {si}: shares diverged");
                    }
                }
                assert_eq!(client_a.logits(), client_b.logits(), "threads={threads}");
            });
        }
    }

    /// Noise ε must perturb logits but keep them within ε-ish of the clean
    /// run (the Fig. 7 mechanism).
    #[test]
    fn epsilon_noise_bounded() {
        let c = ctx();
        let plan = ScalePlan::default_plan();
        let mut net = Network {
            name: "t".into(),
            input_shape: (1, 4, 4),
            layers: vec![crate::nn::Layer::fc(6), crate::nn::Layer::relu(), crate::nn::Layer::fc(4)],
        };
        net.init_weights(5);

        let input = Tensor::from_vec((0..16).map(|i| i as f64 / 16.0).collect(), 1, 4, 4);
        let mut clean_runner = CheetahRunner::new(c.clone(), net.clone(), plan, 0.0, 50).expect("valid network");
        clean_runner.run_offline();
        let clean = clean_runner.infer(&input);

        let mut noisy_runner = CheetahRunner::new(c.clone(), net, plan, 0.2, 51).expect("valid network");
        noisy_runner.run_offline();
        let noisy = noisy_runner.infer(&input);

        for (a, b) in clean.logits.iter().zip(&noisy.logits) {
            // Each linear output picks up at most ~ε plus propagation
            // through one hidden layer (bounded by sum of |w| ≤ fan-in·k_max
            // — loose bound 3.0 here).
            assert!((a - b).abs() < 3.0, "noise blew up: {a} vs {b}");
        }
    }

    /// Shares at every hop are uniform-looking: the client share stream and
    /// server share stream reconstruct the plaintext activation.
    #[test]
    fn share_reconstruction_midway() {
        let c = ctx();
        let plan = ScalePlan::default_plan();
        let mut net = Network {
            name: "t".into(),
            input_shape: (1, 3, 3),
            layers: vec![
                crate::nn::Layer::conv(1, 3, 1, 1),
                crate::nn::Layer::relu(),
                crate::nn::Layer::fc(2),
            ],
        };
        net.init_weights(6);
        let float_net = net.clone();
        let mut runner = CheetahRunner::new(c.clone(), net, plan, 0.0, 60).expect("valid network");
        runner.run_offline();
        let input = Tensor::from_vec((0..9).map(|i| (i as f64 - 4.0) / 5.0).collect(), 1, 3, 3);
        let _ = runner.infer(&input);

        // After the run, shares correspond to the *last intermediate*
        // activation (the conv+relu output).
        let p = c.params.p;
        let cs = runner.client.share();
        let ss = runner.server.share();
        assert_eq!(cs.len(), ss.len());
        let conv_out = {
            let x = crate::nn::layers::forward_layer(&float_net.layers[0], &input);
            crate::nn::layers::forward_layer(&float_net.layers[1], &x)
        };
        for i in 0..cs.len() {
            let rec = (cs[i] + ss[i]) % p;
            let centered =
                if rec > (p - 1) / 2 { rec as i64 - p as i64 } else { rec as i64 };
            let got = plan.x.dequantize(centered);
            assert!(
                (got - conv_out.data[i]).abs() < 0.1,
                "share reconstruction at {i}: {got} vs {}",
                conv_out.data[i]
            );
        }
    }
}
