//! The CHEETAH server: holds the model, performs the perm-free obscure
//! linear computation (paper §3.1–3.3), and finishes the nonlinear step by
//! decrypting its share of the recovered activation.
//!
//! Per query and per fused step `linear [+ReLU] [+pool]`:
//!
//! 1. receive `[T(share_C)]_C` — client-encrypted expanded client share,
//! 2. compute `T(share_S)` locally (shares are mod-p; `T` is linear),
//! 3. per output channel: `MultPlain` by the blinded kernel `k'∘v`, then
//!    `AddPlain` of `k'v∘T(share_S) + b` — **zero permutations**,
//! 4. send the obscured products back; the client block-sums in plaintext,
//! 5. receive the recovery ciphertexts `[ReLU(Con+δ) − s₁]_S`, decrypt →
//!    the server's additive share of the next activation,
//! 6. shares are sum-pooled locally when the network pools (the mean
//!    divisor was absorbed into this step's weights at preparation time).
//!
//! Timing is split into `online` (query-dependent work the paper measures)
//! and `offline` (weight/blinding material preparation, amortizable).

use super::blinding::{sample_block_noise, Blind};
use super::spec::{LinearSpec, ProtocolSpec, SpecError, StepSpec};
use crate::fixed::ScalePlan;

use crate::nn::Network;
use crate::par;
use crate::phe::{Ciphertext, Context, Encryptor, Evaluator, OpCounts, PlainOperand};
use crate::util::rng::ChaCha20Rng;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Per-tap additive-noise magnitude bound (see `fixed` docs: products ≤
/// ~2^21, noise ≤ 2^17 keeps every slot within ±(p−1)/2).
pub const NOISE_BOUND: i64 = 1 << 17;

/// Online/offline compute timer snapshot ([`CheetahServer::timers`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct Timers {
    /// Query-dependent work (the paper's "online time").
    pub online: Duration,
    /// Query-independent preparation (amortizable offline work).
    pub offline: Duration,
}

/// Interior-mutable nanosecond accumulators behind the [`Timers`]
/// snapshots, so the `&self` scoring core (shared by concurrent batch
/// queries) can time itself. Concurrent queries fold into one total —
/// per-query attribution in batch mode is the batch driver's job.
#[derive(Default)]
struct TimerCell {
    online_ns: AtomicU64,
    offline_ns: AtomicU64,
}

impl TimerCell {
    fn add_online(&self, d: Duration) {
        self.online_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    fn add_offline(&self, d: Duration) {
        self.offline_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    fn snapshot(&self) -> Timers {
        Timers {
            online: Duration::from_nanos(self.online_ns.load(Ordering::Relaxed)),
            offline: Duration::from_nanos(self.offline_ns.load(Ordering::Relaxed)),
        }
    }

    fn take(&self) -> Timers {
        Timers {
            online: Duration::from_nanos(self.online_ns.swap(0, Ordering::Relaxed)),
            offline: Duration::from_nanos(self.offline_ns.swap(0, Ordering::Relaxed)),
        }
    }
}

/// Offline material for one step.
struct PreparedStep {
    /// Quantized kernel taps per output channel (weights pre-divided by the
    /// inherited pool divisor): `kq[channel][tap]`.
    kq: Vec<Vec<i64>>,
    /// Blinding factor per output index (channel-major).
    #[allow(dead_code)]
    blinds: Vec<Blind>,
    /// `v₁` as fixed-point int per output index.
    v_int: Vec<i64>,
    /// Noise targets `v₁·δ` per output index, at the product scale.
    targets: Vec<i64>,
    /// Seed for regenerating the per-tap noise stream `b` (not stored:
    /// regenerating is cheaper than holding `len × channels` words).
    noise_seed: u64,
    /// Server-encrypted polar indicators, output-indexed packing
    /// (transmitted to the client in the offline phase).
    id1: Vec<Ciphertext>,
    id2: Vec<Ciphertext>,
}

/// The server side of the CHEETAH protocol. Owns a shared `Arc<Context>`,
/// so prepared engines move freely between serving threads (blinding pool,
/// session workers) with no lifetime plumbing.
///
/// Scoring is **stateless** (`&self`): the per-query state — the server's
/// additive share of the activation chain — lives outside the engine and is
/// threaded through [`CheetahServer::step_linear_with`] /
/// [`CheetahServer::finish_nonlinear_with`]. One prepared engine therefore
/// serves any number of concurrent queries (the batch driver in
/// [`super::runner::CheetahRunner::infer_batch`] and the serve sessions
/// both rely on this). The `&mut self` wrappers ([`CheetahServer::begin_query`],
/// [`CheetahServer::step_linear`], …) keep one internal share for the
/// classic single-query call sequence.
pub struct CheetahServer {
    /// Shared PHE context (parameters, encoder, NTT tables).
    pub ctx: Arc<Context>,
    /// Homomorphic evaluator (op counters are atomic — `Sync`).
    pub ev: Evaluator,
    /// The server's encryptor/decryptor (holds the server secret key).
    pub enc: Encryptor,
    /// Fixed-point scale plan shared with the client.
    pub plan: ScalePlan,
    /// Compiled protocol spec both parties agree on.
    pub spec: ProtocolSpec,
    /// Obscuring-noise bound ε (0.0 = exact inference).
    pub epsilon: f64,
    net: Network,
    steps: Vec<PreparedStep>,
    /// Server's additive share (mod p) of the current activation — the
    /// single-query convenience state behind the `&mut self` wrappers.
    share: Vec<u64>,
    rng: ChaCha20Rng,
    timers: TimerCell,
}

impl CheetahServer {
    /// Prepare the model: quantize weights, sample per-query-independent
    /// blinding, and encrypt the indicator vectors. (The paper prepares
    /// v/b/ID offline per query; we re-prepare per `refresh_blinding` call —
    /// `new` counts as the first offline phase.) A network the protocol
    /// cannot express is a typed [`SpecError`], not a panic.
    pub fn new(
        ctx: Arc<Context>,
        net: Network,
        plan: ScalePlan,
        epsilon: f64,
        seed: u64,
    ) -> Result<Self, SpecError> {
        let spec = ProtocolSpec::compile(&net)?;
        Ok(Self::with_spec(ctx, net, spec, plan, epsilon, seed))
    }

    /// Like [`CheetahServer::new`] with an already-validated spec —
    /// infallible, so serving-path builders (the blinding pool) that
    /// validated the network once at configuration time never risk a
    /// worker-thread death on a malformed architecture.
    pub fn with_spec(
        ctx: Arc<Context>,
        net: Network,
        spec: ProtocolSpec,
        plan: ScalePlan,
        epsilon: f64,
        seed: u64,
    ) -> Self {
        let mut rng = ChaCha20Rng::from_u64_seed(seed);
        let enc = Encryptor::new(ctx.clone(), &mut rng);
        plan.check_fits(ctx.params.p);
        let mut server = Self {
            ev: Evaluator::new(ctx.clone()),
            enc,
            plan,
            spec,
            epsilon,
            net,
            steps: Vec::new(),
            share: Vec::new(),
            ctx,
            rng,
            timers: TimerCell::default(),
        };
        server.refresh_blinding();
        server
    }

    /// (Re-)sample all per-query blinding material and re-encrypt the
    /// indicator ciphertexts — the offline phase.
    pub fn refresh_blinding(&mut self) {
        let t0 = Instant::now();
        let prod_scale = self.plan.product();
        let mut steps = Vec::with_capacity(self.spec.steps.len());
        for (si, step) in self.spec.steps.iter().enumerate() {
            let n_out = step.linear.num_outputs();
            let last = si == self.spec.last_idx();
            let kq = self.quantize_weights(step);
            let mut blinds = Vec::with_capacity(n_out);
            let mut v_int = Vec::with_capacity(n_out);
            let mut targets = Vec::with_capacity(n_out);
            // The last layer uses one shared positive blind (the paper's
            // ideal functionality reveals the last linear result under a
            // single v) — we use the identity so logits keep their scale.
            for _ in 0..n_out {
                let b = if last { Blind::identity() } else { Blind::sample(&mut self.rng) };
                let delta = if self.epsilon > 0.0 {
                    let u = self.rng.gen_range(1 << 24) as f64 / (1u64 << 23) as f64 - 1.0;
                    prod_scale.quantize(u * self.epsilon)
                } else {
                    0
                };
                v_int.push(b.v1_int(&self.plan));
                // target = v1·δ at product scale: v1 is a power of two ⇒
                // shift δ (sampled at product scale) by j and sign.
                let shifted = match b.j {
                    1 => delta * 2,
                    0 => delta,
                    _ => delta / 2,
                };
                targets.push(shifted * b.s as i64);
                blinds.push(b);
            }
            // Indicator ciphertexts (skipped for the last layer).
            let (id1, id2) = if last {
                (Vec::new(), Vec::new())
            } else {
                let n = self.ctx.params.n;
                let mut id1_vals = vec![0i64; n_out];
                let mut id2_vals = vec![0i64; n_out];
                for (i, b) in blinds.iter().enumerate() {
                    let (a, c) = b.indicator(&self.plan);
                    id1_vals[i] = a;
                    id2_vals[i] = c;
                }
                let n_cts = step.linear.num_recovery_cts(n);
                let mut id1 = Vec::with_capacity(n_cts);
                let mut id2 = Vec::with_capacity(n_cts);
                for c in 0..n_cts {
                    let lo = c * n;
                    let hi = ((c + 1) * n).min(n_out);
                    id1.push(self.enc.encrypt_slots(&id1_vals[lo..hi], &mut self.rng));
                    id2.push(self.enc.encrypt_slots(&id2_vals[lo..hi], &mut self.rng));
                }
                (id1, id2)
            };
            steps.push(PreparedStep {
                kq,
                blinds,
                v_int,
                targets,
                noise_seed: self.rng.next_u64(),
                id1,
                id2,
            });
        }
        self.steps = steps;
        self.timers.add_offline(t0.elapsed());
    }

    /// Quantized kernel taps per channel, with the inherited pool divisor
    /// folded in (`mean = sum / div` absorbed into the next linear layer).
    /// Pure per-channel work, fanned out across the pool (this runs inside
    /// every blinding-pool background build).
    fn quantize_weights(&self, step: &StepSpec) -> Vec<Vec<i64>> {
        let layer = &self.net.layers[step.layer_idx];
        let div = step.weight_div;
        let plan = &self.plan;
        match &step.linear {
            LinearSpec::Conv(p) => {
                let (c_i, _, _) = p.in_shape;
                let r = p.kernel;
                par::map_indexed(p.out_shape.0, |o| {
                    (0..p.block)
                        .map(|t| {
                            let i = t / (r * r);
                            let rem = t % (r * r);
                            plan.quant_k(layer.conv_w(c_i, r, o, i, rem / r, rem % r) / div)
                        })
                        .collect()
                })
            }
            LinearSpec::Fc(p) => {
                // FC: one "channel"; blocks are output neurons, so kq is
                // indexed per block at multiplier-build time. Store rows.
                par::map_indexed(p.n_o, |o| {
                    (0..p.n_i).map(|j| plan.quant_k(layer.fc_w(p.n_i, o, j) / div)).collect()
                })
            }
        }
    }

    /// The indicator ciphertexts for step `si` (offline transmission).
    pub fn indicator_cts(&self, si: usize) -> (&[Ciphertext], &[Ciphertext]) {
        (&self.steps[si].id1, &self.steps[si].id2)
    }

    /// A zeroed server-side share for a fresh query (at step 0 the client
    /// holds the whole input) — the starting per-query state for the
    /// stateless scoring path ([`CheetahServer::step_linear_with`]).
    pub fn fresh_share(&self) -> Vec<u64> {
        let (c, h, w) = self.spec.input_shape;
        vec![0u64; c * h * w]
    }

    /// Begin a query on the internal single-query state: the client holds
    /// the whole input, so the server's initial share is zero.
    pub fn begin_query(&mut self) {
        self.share = self.fresh_share();
    }

    /// Direct share injection (tests / mid-network entry).
    pub fn set_share(&mut self, share: Vec<u64>) {
        self.share = share;
    }

    /// The internal single-query share (after the wrappers ran).
    pub fn share(&self) -> &[u64] {
        &self.share
    }

    /// Single-query wrapper over [`CheetahServer::step_linear_with`] using
    /// the internal share set by [`CheetahServer::begin_query`] /
    /// [`CheetahServer::finish_nonlinear`].
    pub fn step_linear(&mut self, si: usize, in_cts: &[Ciphertext]) -> Vec<Ciphertext> {
        self.step_linear_with(si, in_cts, &self.share)
    }

    /// The obscure linear computation for step `si`. Input: the client's
    /// encrypted expanded share and the server's additive share of the
    /// current activation (`share`; zeros for step 0). Output:
    /// channel-major obscured-product ciphertexts (`channels × num_in_cts`).
    ///
    /// The per-output-channel streams are the paper's embarrassingly
    /// parallel unit: every channel's multiplier, noise stream, and
    /// Mult+Add chain is independent, so both phases fan out across the
    /// [`crate::par`] pool. Results land in channel-ordered slots and each
    /// channel's noise stream comes from its own deterministically-seeded
    /// RNG, so the output is bit-identical at every thread count.
    ///
    /// `&self`: all mutable state is the caller-owned `share`, so any
    /// number of queries may score concurrently against one prepared
    /// engine (they share the blinding material — exactly like repeated
    /// queries on one deployment).
    pub fn step_linear_with(
        &self,
        si: usize,
        in_cts: &[Ciphertext],
        share: &[u64],
    ) -> Vec<Ciphertext> {
        let step = &self.spec.steps[si];
        let prep = &self.steps[si];
        let n = self.ctx.params.n;
        let p = self.ctx.params.p;
        let len = step.linear.stream_len();
        let n_cts = step.linear.num_in_cts(n);
        assert_eq!(in_cts.len(), n_cts, "wrong input ciphertext count");
        let channels = step.linear.num_channels();
        let blocks = step.linear.blocks_per_channel();
        let block = step.linear.block_len();

        // Online: convert incoming ciphertexts to NTT form once (parallel
        // batch), and expand the server's share T(share_S) — zero for the
        // first layer of a fresh query (client holds the input).
        let t_on = Instant::now();
        let mut in_ntt: Vec<Ciphertext> = in_cts.to_vec();
        self.ev.to_ntt_batch(&mut in_ntt);
        let share_zero = share.iter().all(|&s| s == 0);
        let ts: Vec<u64> = if share_zero {
            Vec::new()
        } else {
            step.linear.expand_u64(share)
        };
        self.timers.add_online(t_on.elapsed());

        /// Query-independent material for one (channel, input-ct) slot.
        /// Holding the whole grid at once costs ~1 extra operand poly per
        /// output ciphertext (≈ +50% over the output itself, which is
        /// inherently `channels × n_cts` two-poly ciphertexts) — the price
        /// of splitting operand construction (offline-attributed) from the
        /// Mult+Add streams (online). Per-slot scratch that one phase does
        /// not need is not retained (see ROADMAP: scratch reuse).
        struct SlotOps {
            /// Raw `k'·v` slot values — retained only for hidden layers,
            /// where the online additive operand needs them again.
            kv_slot: Option<Vec<i64>>,
            /// The `MultPlain` operand `k'∘v`.
            kv_op: PlainOperand,
            /// First layer only: the `AddPlain` operand for `b` alone.
            b_op: Option<PlainOperand>,
        }

        let ev = &self.ev;
        let ctx = &self.ctx;
        let linear = &step.linear;

        // Offline-attributed (all query-independent), wall-timed around
        // the parallel regions. First the per-channel noise streams — each
        // channel draws from its own deterministically-seeded RNG, exactly
        // the sequential derivation, so values are thread-count-invariant.
        // Then the blinded-kernel multipliers, fanned out over the finer
        // (channel × input-ct) grid so FC steps (one channel, many
        // ciphertexts) parallelize just as well as conv steps.
        let t_off = Instant::now();
        let b_streams: Vec<Vec<i64>> = par::map_indexed(channels, |ch| {
            let mut nrng = ChaCha20Rng::from_u64_seed(prep.noise_seed ^ (ch as u64) << 32);
            let mut b_stream: Vec<i64> = Vec::with_capacity(blocks * block);
            for blk in 0..blocks {
                let out_idx = ch * blocks + blk;
                b_stream.extend(sample_block_noise(
                    block,
                    prep.targets[out_idx],
                    NOISE_BOUND,
                    &mut nrng,
                ));
            }
            b_stream
        });
        let slot_ops: Vec<SlotOps> = par::map_indexed(channels * n_cts, |k| {
            let (ch, c) = (k / n_cts, k % n_cts);
            let lo = c * n;
            let hi = ((c + 1) * n).min(len);
            let mut kv_slot = vec![0i64; hi - lo];
            for (slot, g) in (lo..hi).enumerate() {
                let (blk, tap) = (g / block, g % block);
                let kq = match linear {
                    LinearSpec::Conv(_) => prep.kq[ch][tap],
                    LinearSpec::Fc(_) => prep.kq[blk][tap],
                };
                kv_slot[slot] = kq * prep.v_int[ch * blocks + blk];
            }
            let kv_op = ctx.mult_operand(&kv_slot);
            let b_op = if share_zero {
                // First layer: the additive operand is b alone —
                // query-independent, so built (and attributed) here.
                let b_res: Vec<u64> = (lo..hi)
                    .map(|g| {
                        let bb = b_streams[ch][g];
                        if bb < 0 {
                            p - ((-bb) as u64 % p)
                        } else {
                            bb as u64 % p
                        }
                    })
                    .collect();
                Some(ctx.add_operand_unsigned(&b_res))
            } else {
                None
            };
            SlotOps { kv_slot: (!share_zero).then_some(kv_slot), kv_op, b_op }
        });
        // First layer: the online phase reads neither b nor kv_slot —
        // free the streams before fanning out the Mult+Add grid.
        let b_streams = if share_zero { Vec::new() } else { b_streams };
        self.timers.add_offline(t_off.elapsed());

        // Online: for hidden layers the query-dependent additive operands
        // `k'v∘T(share_S) + b`, then the paper's 1 Mult + 1 Add per
        // ciphertext — the (channel × input-ct) grid fanned out in
        // parallel, each result written to its channel-major slot.
        let t_on = Instant::now();
        let out: Vec<Ciphertext> = par::map_indexed(channels * n_cts, |k| {
            let (ch, c) = (k / n_cts, k % n_cts);
            let sops = &slot_ops[k];
            let in_ct = &in_ntt[c];
            let lo = c * n;
            let hi = ((c + 1) * n).min(len);
            let online_add;
            let add_op = match &sops.b_op {
                Some(op) => op,
                None => {
                    let kv_slot =
                        sops.kv_slot.as_deref().expect("hidden layers retain kv_slot");
                    let add_res: Vec<u64> = (lo..hi)
                        .map(|g| {
                            let bb = b_streams[ch][g];
                            let b_res =
                                if bb < 0 { p - ((-bb) as u64 % p) } else { bb as u64 % p };
                            let kv = kv_slot[g - lo];
                            let kv_res =
                                if kv < 0 { p - ((-kv) as u64 % p) } else { kv as u64 % p };
                            (crate::util::math::mul_mod(kv_res, ts[g], p) + b_res) % p
                        })
                        .collect();
                    online_add = ctx.add_operand_unsigned(&add_res);
                    &online_add
                }
            };
            let mut prod = ev.mult_plain(in_ct, &sops.kv_op);
            ev.add_plain(&mut prod, add_op);
            prod
        });
        self.timers.add_online(t_on.elapsed());
        out
    }

    /// Single-query wrapper over [`CheetahServer::finish_nonlinear_with`]:
    /// stores the next share in the internal single-query state.
    pub fn finish_nonlinear(&mut self, si: usize, rec_cts: &[Ciphertext]) {
        self.share = self.finish_nonlinear_with(si, rec_cts);
    }

    /// Finish the nonlinear step: decrypt the recovery ciphertexts into the
    /// server's share of the (ReLU'd, requantized) activation, applying the
    /// share-domain sum-pool when the network pools here. Returns the
    /// next-layer share (`&self` — see [`CheetahServer::step_linear_with`]
    /// on concurrent queries).
    pub fn finish_nonlinear_with(&self, si: usize, rec_cts: &[Ciphertext]) -> Vec<u64> {
        let step = &self.spec.steps[si];
        let n = self.ctx.params.n;
        let n_out = step.linear.num_outputs();
        assert_eq!(rec_cts.len(), step.linear.num_recovery_cts(n));
        let t0 = Instant::now();
        // Each recovery ciphertext decrypts independently — parallel batch,
        // concatenated in ciphertext order.
        let enc = &self.enc;
        let ctx = &self.ctx;
        let parts: Vec<Vec<u64>> = par::map_collect(rec_cts, |c, ct| {
            let vals = ctx.encoder.decode_unsigned(&enc.decrypt(ct));
            let hi = ((c + 1) * n).min(n_out) - c * n;
            vals[..hi].to_vec()
        });
        let mut share = Vec::with_capacity(n_out);
        for part in parts {
            share.extend(part);
        }
        if let Some(size) = step.pool_after {
            share = pool_shares(&share, step.out_shape, size, self.ctx.params.p);
        }
        self.timers.add_online(t0.elapsed());
        share
    }

    /// Reset and return evaluator op counters.
    pub fn take_ops(&self) -> OpCounts {
        let c = self.ev.counts();
        self.ev.reset_counts();
        c
    }

    /// Snapshot of the accumulated online/offline compute timers.
    pub fn timers(&self) -> Timers {
        self.timers.snapshot()
    }

    /// Take (and zero) the accumulated online/offline compute timers.
    /// Under concurrent batch queries the totals interleave across queries;
    /// the single-query runner uses this per step for exact attribution.
    pub fn reset_timers(&self) -> Timers {
        self.timers.take()
    }
}

/// Sum-pool additive shares (mod p) over `size×size` windows — used by both
/// parties; the mean divisor is folded into the next layer's weights.
pub fn pool_shares(
    share: &[u64],
    shape: (usize, usize, usize),
    size: usize,
    p: u64,
) -> Vec<u64> {
    let (c, h, w) = shape;
    assert_eq!(share.len(), c * h * w);
    let (oh, ow) = (h / size, w / size);
    let mut out = vec![0u64; c * oh * ow];
    for ch in 0..c {
        for oy in 0..oh {
            for ox in 0..ow {
                let mut acc = 0u64;
                for dy in 0..size {
                    for dx in 0..size {
                        acc = (acc + share[(ch * h + oy * size + dy) * w + ox * size + dx]) % p;
                    }
                }
                out[(ch * oh + oy) * ow + ox] = acc;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_shares_reconstructs_sum() {
        let p = 8380417u64;
        let mut rng = crate::util::rng::SplitMix64::new(4);
        let shape = (2, 4, 4);
        let total = 32;
        let a: Vec<u64> = (0..total).map(|_| rng.gen_range(p)).collect();
        let b: Vec<u64> = (0..total).map(|_| rng.gen_range(p)).collect();
        let pa = pool_shares(&a, shape, 2, p);
        let pb = pool_shares(&b, shape, 2, p);
        // Reconstructed pooled value == pooled reconstructed value.
        for i in 0..pa.len() {
            let rec_pool = (pa[i] + pb[i]) % p;
            // compute pooled (a+b) directly
            let sum: Vec<u64> = a.iter().zip(&b).map(|(&x, &y)| (x + y) % p).collect();
            let pooled = pool_shares(&sum, shape, 2, p);
            assert_eq!(rec_pool, pooled[i]);
        }
    }
}
